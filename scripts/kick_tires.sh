#!/usr/bin/env bash
# kick_tires.sh — one-command "does the repro actually reproduce?" check.
#
# Rebuilds the paper's headline artifacts — fig5 (EMCM active-learning
# convergence), fig6 (tuning trajectories) and table2 (GRID/flag-selection
# comparison) — into results/kick_tires/ and renders a single markdown
# report (KICK_TIRES.md) embedding the three tables.
#
#   scripts/kick_tires.sh           # copy the committed precomputed tables
#   scripts/kick_tires.sh --fresh   # actually run the experiments (needs
#                                   # a Rust toolchain; CI uses this)
#
# The default path exists so the report renders on machines without a
# toolchain; --fresh is the real check and is what CI runs.  Exits
# non-zero if any expected artifact is missing afterwards.

set -euo pipefail

ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
OUT="$ROOT/results/kick_tires"
PRE="$ROOT/scripts/precomputed"
ARTIFACTS=(fig5 fig6 table2)

MODE="precomputed"
if [[ "${1:-}" == "--fresh" ]]; then
  MODE="fresh"
elif [[ $# -gt 0 ]]; then
  echo "usage: $0 [--fresh]" >&2
  exit 2
fi

mkdir -p "$OUT"

if [[ "$MODE" == "fresh" ]]; then
  command -v cargo >/dev/null || {
    echo "kick_tires: --fresh needs a Rust toolchain (cargo not found)" >&2
    exit 1
  }
  for a in "${ARTIFACTS[@]}"; do
    echo "== repro $a (--fast) =="
    (cd "$ROOT/rust" && cargo run --release --quiet -- repro "$a" --fast --out "$OUT")
  done
  # Determinism lint: the sweep's markdown summary (rule table + allow
  # ledger) goes into the report so a reader sees the reproducibility
  # contract is actually enforced, not just claimed.  detlint exits
  # non-zero when dirty, which fails this script via set -e.
  echo "== detlint sweep =="
  (cd "$ROOT/rust" && cargo run --release --quiet --bin detlint -- \
    --out "$OUT/detlint.json") | tee "$OUT/detlint.md"
else
  for a in "${ARTIFACTS[@]}"; do
    for ext in csv txt; do
      cp "$PRE/$a.$ext" "$OUT/$a.$ext"
    done
  done
fi

missing=0
for a in "${ARTIFACTS[@]}"; do
  for ext in csv txt; do
    if [[ ! -s "$OUT/$a.$ext" ]]; then
      echo "kick_tires: missing or empty artifact $OUT/$a.$ext" >&2
      missing=1
    fi
  done
done
[[ "$missing" == 0 ]] || exit 1

REPORT="$OUT/KICK_TIRES.md"
{
  echo "# Kick-the-tires report"
  echo
  echo "- provenance: \`$MODE\`$([[ "$MODE" == precomputed ]] && echo ' (committed placeholder tables — run with `--fresh` for a real reproduction)')"
  echo "- command: \`scripts/kick_tires.sh${1:+ $1}\`"
  echo
  for a in "${ARTIFACTS[@]}"; do
    echo "## $a"
    echo
    echo '```text'
    cat "$OUT/$a.txt"
    echo '```'
    echo
  done
  if [[ -s "$OUT/detlint.md" ]]; then
    cat "$OUT/detlint.md"
    echo
  else
    echo "## detlint — determinism & concurrency lint"
    echo
    echo '_Skipped (precomputed mode needs no toolchain) — run with `--fresh`,'
    echo 'or `cargo run --release --bin detlint` directly.  See LINTS.md._'
    echo
  fi
} > "$REPORT"

echo "kick_tires: OK ($MODE) — report at ${REPORT#"$ROOT"/}"
