//! The paper's headline scenario: DenseKMeans under ParallelGC is GC-bound
//! (72 GB input, frequent long full-GC pauses) and flag tuning recovers
//! ~1.35x (paper Table III).  This example walks the three phases manually
//! — the long-form version of what `run_pipeline` automates — and prints
//! what each phase contributed.
//!
//! Run with:  cargo run --release --example tune_densekmeans


use onestoptuner::datagen::{characterize, DataGenConfig, Strategy};
use onestoptuner::featsel::{grid_search_lambda, select_flags};
use onestoptuner::flags::FlagConfig;
use onestoptuner::pipeline::measure;
use onestoptuner::runtime::load_backend;
use onestoptuner::tuner::{bo::BoConfig, BoTuner, SimObjective, TuneSpace, Tuner};
use onestoptuner::{Benchmark, GcMode, Metric, SparkRunner};

fn main() -> anyhow::Result<()> {
    let backend = load_backend("artifacts");
    let bench = Benchmark::DenseKMeans;
    let mode = GcMode::ParallelGC;
    let metric = Metric::ExecTime;
    let runner = SparkRunner::paper_default(bench);

    // Baseline: what the stock JVM does.
    let default_cfg = FlagConfig::default_for(mode);
    let base = measure(&runner, &default_cfg, metric, 10, 0xba5e);
    let base_run = runner.run(&default_cfg, 1);
    println!("default: {:.1} +- {:.1} s, {} full GCs per run — GC-bound", base.mean, base.std, base_run.gc.full);

    // Phase 1: BEMCM active learning.
    let ch = characterize(
        &runner,
        mode,
        metric,
        Strategy::Bemcm,
        &DataGenConfig::default(),
        &backend,
    )?;
    println!(
        "\nphase 1: {} labelled samples from {} runs ({} AL rounds, RMSE {:.1} -> {:.1} s)",
        ch.dataset.len(),
        ch.runs_executed,
        ch.rounds,
        ch.rmse_history.first().unwrap(),
        ch.rmse_history.last().unwrap()
    );

    // Phase 2: lasso selection, with the paper's lambda grid search.
    let (lambda, grid) = grid_search_lambda(
        &ch.dataset,
        &[0.003, 0.01, 0.03, 0.1],
        &backend,
    )?;
    println!("\nphase 2: lambda grid search");
    for (lam, mse, kept) in &grid {
        println!("  lambda={lam:<6} holdout MSE {mse:.4}  flags kept {kept}");
    }
    let sel = select_flags(&ch.dataset, lambda, &backend)?;
    println!(
        "  -> lambda {} keeps {} of {} flags",
        lambda,
        sel.n_selected(),
        sel.group_size
    );

    // Phase 3: BO with warm start over the selected subspace.
    let space = TuneSpace::from_selection(mode, &sel);
    let mut objective = SimObjective::new(&runner, metric, 0x7e57);
    let mut tuner = BoTuner::warm_start(backend.clone(), BoConfig::default(), &space, &ch.dataset);
    let result = tuner.tune(&space, &mut objective, 20)?;

    let tuned = measure(&runner, &result.best_config, metric, 10, 0x0f00);
    let tuned_run = runner.run(&result.best_config, 1);
    println!(
        "\nphase 3 (BO warm start, 20 iters): {:.1} +- {:.1} s, {} full GCs",
        tuned.mean, tuned.std, tuned_run.gc.full
    );
    println!(
        "speedup over default: {:.2}x  (paper Table III: ~1.35x)",
        base.mean / tuned.mean
    );

    // Show which flags moved the needle.
    println!("\nkey tuned flags vs defaults:");
    for name in [
        "MaxHeapSize",
        "MaxNewSize",
        "NewRatio",
        "ParallelGCThreads",
        "CompileThreshold",
        "MaxInlineSize",
    ] {
        println!(
            "  {name:<22} default {:>8}   tuned {:>8}",
            default_cfg.get(name),
            result.best_config.get(name)
        );
    }
    Ok(())
}
