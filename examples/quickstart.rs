//! Quickstart: the full OneStopTuner pipeline end-to-end on one benchmark.
//!
//! Phases (paper Fig 1): (1) BEMCM active-learning characterization on the
//! simulated Spark cluster, (2) lasso flag selection, (3) tuning with BO,
//! BO-warm-start, RBO and the SA baseline — then a 10-repeat measurement of
//! each recommendation against the JVM defaults.
//!
//! Run with:  cargo run --release --example quickstart [bench] [gc]
//! (defaults: densekmeans parallelgc — the paper's headline 1.35x case)


use onestoptuner::pipeline::{run_pipeline, Algo, PipelineConfig};
use onestoptuner::runtime::load_backend;
use onestoptuner::{Benchmark, GcMode, Metric};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let bench = args
        .get(1)
        .and_then(|s| Benchmark::parse(s))
        .unwrap_or(Benchmark::DenseKMeans);
    let mode = args
        .get(2)
        .and_then(|s| GcMode::parse(s))
        .unwrap_or(GcMode::ParallelGC);

    let backend = load_backend("artifacts");
    println!("== OneStopTuner quickstart ==");
    println!("benchmark: {}   GC: {}   backend: {}", bench.name(), mode.name(), backend.name());

    let cfg = PipelineConfig::default();
    let algos = [Algo::Bo, Algo::Rbo, Algo::BoWarm, Algo::Sa];
    let out = run_pipeline(bench, mode, Metric::ExecTime, &algos, &cfg, &backend)?;

    println!(
        "\nphase 1 (AL characterization): {} runs over {} rounds, RMSE {:.2} -> {:.2} s",
        out.characterization.runs_executed,
        out.characterization.rounds,
        out.characterization.rmse_history.first().unwrap(),
        out.characterization.rmse_history.last().unwrap(),
    );
    println!(
        "phase 2 (lasso selection): {} of {} flags kept (lambda = {})",
        out.selection.n_selected(),
        out.selection.group_size,
        out.selection.lambda,
    );
    println!(
        "\ndefault execution time: {:.1} +- {:.1} s (n={})",
        out.default_summary.mean, out.default_summary.std, out.default_summary.n
    );
    println!("\nphase 3 (tuning, {} iterations each):", cfg.tune_iters);
    for o in &out.outcomes {
        println!(
            "  {:<15} tuned {:>6.1} +- {:>4.1} s   speedup {:>5.2}x   tuning time {:>7.1} s   ({} evals)",
            o.algo.name(),
            o.tuned_summary.mean,
            o.tuned_summary.std,
            o.improvement,
            o.tuning_time_s,
            o.tune.evals,
        );
    }

    let best = out
        .outcomes
        .iter()
        .max_by(|a, b| a.improvement.partial_cmp(&b.improvement).unwrap())
        .unwrap();
    println!(
        "\nheadline: {} achieves {:.2}x speedup over default ({} {})",
        best.algo.name(),
        best.improvement,
        bench.name(),
        mode.name()
    );
    Ok(())
}
