//! The Fig 2 backend in action: start the REST API, then act as the UI —
//! characterize (watching live progress), select flags, tune, cancel a
//! running tune mid-flight, degrade a tune under injected measurement
//! faults, and finally "restart" the backend on the same state directory
//! to show the datasets and terminal job records survive.
//!
//! The long-running endpoints are asynchronous: POST returns
//! `202 Accepted` + a job id; the client polls `/api/jobs/:id` (which
//! carries a `progress` object while running) and can abort with
//! `DELETE /api/jobs/:id`.
//!
//! Run with:
//!   cargo run --release --example rest_server \
//!     [-- --threads N] [--state-dir DIR] [--chaos-out FILE]
//!
//! `--chaos-out FILE` writes the degraded job's full record (status +
//! best-so-far result + per-kind failure histogram) to FILE so CI can
//! schema-check the chaos leg with jq.
//!
//! Exits non-zero if any lifecycle invariant breaks — CI runs this as the
//! end-to-end check of the job subsystem.

use onestoptuner::runtime::load_backend;
use onestoptuner::server::{http_request, persist, spawn_with, ApiOptions};
use onestoptuner::util::json::Json;

fn main() -> anyhow::Result<()> {
    // Same global flag as the CLI: pin the execution-pool width (the
    // default is the auto-detected core count; results never depend on it).
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Some(i) = args.iter().position(|a| a == "--threads") {
        let n: usize = args
            .get(i + 1)
            .and_then(|s| s.parse().ok())
            .filter(|&n| n >= 1)
            .ok_or_else(|| anyhow::anyhow!("--threads needs a positive integer"))?;
        if !onestoptuner::exec::set_global_threads(n) {
            eprintln!("warning: execution pool already initialized; --threads {n} ignored");
        }
    }
    let state_dir = args
        .iter()
        .position(|a| a == "--state-dir")
        .and_then(|i| args.get(i + 1))
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::env::temp_dir().join("onestoptuner-rest-demo"));
    let chaos_out = args
        .iter()
        .position(|a| a == "--chaos-out")
        .and_then(|i| args.get(i + 1))
        .map(std::path::PathBuf::from);
    // Fresh demo every run: drop any state file a previous run left.
    let _ = std::fs::remove_file(state_dir.join(persist::STATE_FILE));

    let opts = ApiOptions { state_dir: Some(state_dir.clone()), ..Default::default() };
    let addr = spawn_with("127.0.0.1:0", load_backend("artifacts"), opts)?;
    println!("REST API up on http://{addr}  (state dir: {})\n", state_dir.display());

    let get = move |path: &str| http_request(addr, "GET", path, "").unwrap();
    let post = move |path: &str, body: &str| http_request(addr, "POST", path, body).unwrap();

    // Poll an async job until it reaches a terminal state, printing each
    // new progress snapshot along the way; returns the final record.
    let watch = move |job_id: f64| -> anyhow::Result<Json> {
        let mut last_progress = String::new();
        loop {
            let (code, body) = get(&format!("/api/jobs/{job_id}"));
            anyhow::ensure!(code == 200, "poll {job_id}: {code} {body}");
            let v = Json::parse(&body).map_err(|e| anyhow::anyhow!(e))?;
            let status = v.get("status").and_then(Json::as_str).unwrap_or("?").to_string();
            if let Some(p) = v.get("progress") {
                let line = p.to_string();
                if line != last_progress {
                    println!("  job {job_id} [{status}] progress: {line}");
                    last_progress = line;
                }
            }
            match status.as_str() {
                "done" | "failed" | "cancelled" | "degraded" => return Ok(v),
                _ => std::thread::sleep(std::time::Duration::from_millis(100)),
            }
        }
    };

    let (_, body) = get("/api/health");
    println!("GET /api/health\n  {body}\n");

    println!("POST /api/run (DenseKMeans, ParallelGC, 32G heap)");
    let (_, body) = post(
        "/api/run",
        r#"{"bench":"densekmeans","gc":"parallel","flags":{"MaxHeapSize":32768}}"#,
    );
    println!("  {body}\n");

    // ---- characterize: async job with live AL-round progress ----------
    println!("POST /api/characterize (LDA, G1GC — the AL loop runs as an async job)");
    let (code, body) = post(
        "/api/characterize",
        r#"{"bench":"lda","gc":"g1","pool":200,"rounds":3}"#,
    );
    println!("  {code} {body}");
    anyhow::ensure!(code == 202, "characterize must answer 202");
    let job = Json::parse(&body).unwrap().get("job_id").unwrap().as_f64().unwrap();
    let rec = watch(job)?;
    anyhow::ensure!(
        rec.get("status").and_then(Json::as_str) == Some("done"),
        "characterize job failed: {rec}"
    );
    let result = rec.get("result").unwrap().clone();
    println!("  job {job} done: {result}\n");
    let id = result.get("dataset_id").unwrap().as_f64().unwrap();

    println!("POST /api/select (lasso on dataset {id})");
    let (_, body) = post("/api/select", &format!(r#"{{"dataset_id":{id}}}"#));
    let sel = Json::parse(&body).unwrap();
    println!(
        "  kept {} of {} flags\n",
        sel.get("n_selected").unwrap(),
        sel.get("group_size").unwrap()
    );

    println!("POST /api/tune (BO warm start, ARD GP hypers, 10 iterations, async)");
    // Grossly long initial length-scales (one per lasso-selected flag —
    // the select call above fixes the dimension count) so the ML ascent
    // must move them: the record only claims gp_ard/ard_relevance when
    // adaptation actually happened.
    let n_sel = sel.get("n_selected").unwrap().as_f64().unwrap() as usize;
    let init_ls: Vec<String> = (0..n_sel).map(|_| "10.0".to_string()).collect();
    let (code, body) = post(
        "/api/tune",
        &format!(
            r#"{{"bench":"lda","gc":"g1","algo":"bo-warm","dataset_id":{id},"iters":10,"gp_ard":true,
                "gp_init_hypers":{{"lengthscales":[{}]}}}}"#,
            init_ls.join(",")
        ),
    );
    println!("  {code} {body}");
    let job = Json::parse(&body).unwrap().get("job_id").unwrap().as_f64().unwrap();
    let rec = watch(job)?;
    anyhow::ensure!(rec.get("status").and_then(Json::as_str) == Some("done"));
    let v = rec.get("result").unwrap();
    println!(
        "  improvement {}x, tuning time {} s",
        v.get("improvement").unwrap(),
        v.get("tuning_time_s").unwrap()
    );
    // ARD closes the feature-selection loop: the record reports the
    // adapted per-flag hypers and a relevance object next to the lasso
    // selection, and the hypers round-trip into a follow-up job.
    anyhow::ensure!(
        v.get("gp_ard").and_then(Json::as_bool) == Some(true),
        "ARD tune must report an effective gp_ard=true: {v}"
    );
    let ls = v
        .get("gp_lengthscales")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("ARD tune must report gp_lengthscales: {v}"))?;
    anyhow::ensure!(!ls.is_empty(), "gp_lengthscales must be non-empty");
    anyhow::ensure!(
        v.get("ard_relevance").is_some(),
        "ARD tune must report ard_relevance next to the selection: {v}"
    );
    let s2n = v.get("gp_sigma_n2").and_then(Json::as_f64).unwrap_or(0.01);
    let ls_json: Vec<String> =
        ls.iter().map(|l| format!("{}", l.as_f64().unwrap())).collect();
    println!("  adapted {} per-flag length-scales; warm-starting a follow-up tune\n", ls.len());
    let (code, body) = post(
        "/api/tune",
        &format!(
            r#"{{"bench":"lda","gc":"g1","algo":"bo-warm","dataset_id":{id},"iters":2,
                "gp_hypers":"adapt","gp_init_hypers":{{"lengthscales":[{}],"sigma_n2":{s2n}}}}}"#,
            ls_json.join(",")
        ),
    );
    anyhow::ensure!(code == 202, "warm-started tune must be accepted: {body}");
    let job = Json::parse(&body).unwrap().get("job_id").unwrap().as_f64().unwrap();
    let rec = watch(job)?;
    anyhow::ensure!(
        rec.get("status").and_then(Json::as_str) == Some("done"),
        "warm-started tune failed: {rec}"
    );
    println!("  warm-started job {job} done\n");

    // ---- batched proposal: q-EI with two concurrent runs per round ----
    println!("POST /api/tune (BO, batch_q 2 — two evaluations per iteration, async)");
    let (code, body) = post(
        "/api/tune",
        r#"{"bench":"lda","gc":"g1","algo":"bo","iters":4,"batch_q":2}"#,
    );
    println!("  {code} {body}");
    anyhow::ensure!(code == 202, "batched tune must be accepted: {body}");
    let job = Json::parse(&body).unwrap().get("job_id").unwrap().as_f64().unwrap();
    let rec = watch(job)?;
    anyhow::ensure!(
        rec.get("status").and_then(Json::as_str) == Some("done"),
        "batched tune failed: {rec}"
    );
    println!("  batched job {job} done\n");
    // A zero batch width is rejected synchronously, never as a failed job.
    let (code, body) = post(
        "/api/tune",
        r#"{"bench":"lda","gc":"g1","algo":"bo","iters":4,"batch_q":0}"#,
    );
    anyhow::ensure!(code == 400, "batch_q 0 must be a synchronous 400: {code} {body}");
    println!("POST /api/tune with batch_q 0 -> {code} (synchronous validation)\n");

    // ---- kernel tier: blocked linear algebra behind gp_kernels --------
    println!("POST /api/tune (BO, gp_kernels blocked — panel/lane surrogate tier, async)");
    let (code, body) = post(
        "/api/tune",
        r#"{"bench":"lda","gc":"g1","algo":"bo","iters":2,"gp_kernels":"blocked"}"#,
    );
    println!("  {code} {body}");
    anyhow::ensure!(code == 202, "blocked-kernel tune must be accepted: {body}");
    let job = Json::parse(&body).unwrap().get("job_id").unwrap().as_f64().unwrap();
    let rec = watch(job)?;
    anyhow::ensure!(
        rec.get("status").and_then(Json::as_str) == Some("done"),
        "blocked-kernel tune failed: {rec}"
    );
    anyhow::ensure!(
        rec.get("result").and_then(|v| v.get("gp_kernels")).and_then(Json::as_str)
            == Some("blocked"),
        "record must echo the effective kernel tier: {rec}"
    );
    println!("  blocked-kernel job {job} done\n");
    // An unknown tier is rejected synchronously, never as a failed job.
    let (code, body) = post(
        "/api/tune",
        r#"{"bench":"lda","gc":"g1","algo":"bo","iters":2,"gp_kernels":"bogus"}"#,
    );
    anyhow::ensure!(code == 400, "unknown gp_kernels must be a synchronous 400: {code} {body}");
    println!("POST /api/tune with gp_kernels bogus -> {code} (synchronous validation)\n");

    // ---- cancellation: abort a long tune mid-flight -------------------
    println!("POST /api/tune (BO, 500 iterations — then DELETE it mid-run)");
    let (code, body) = post(
        "/api/tune",
        r#"{"bench":"densekmeans","gc":"parallel","algo":"bo","iters":500}"#,
    );
    anyhow::ensure!(code == 202, "tune must answer 202: {body}");
    let job = Json::parse(&body).unwrap().get("job_id").unwrap().as_f64().unwrap();
    // Wait until the loop reports progress, so the cancel lands mid-run.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(120);
    loop {
        let (_, body) = get(&format!("/api/jobs/{job}"));
        let v = Json::parse(&body).unwrap();
        let iter = v
            .get("progress")
            .and_then(|p| p.get("iteration"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        if iter >= 1.0 {
            println!("  job {job} running at iteration {iter}; cancelling");
            break;
        }
        anyhow::ensure!(std::time::Instant::now() < deadline, "tune never reported progress");
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    let (code, body) = http_request(addr, "DELETE", &format!("/api/jobs/{job}"), "").unwrap();
    println!("  DELETE /api/jobs/{job} -> {code} {body}");
    anyhow::ensure!(code == 202, "cancel must answer 202");
    let rec = watch(job)?;
    anyhow::ensure!(
        rec.get("status").and_then(Json::as_str) == Some("cancelled"),
        "cancelled tune must land in 'cancelled': {rec}"
    );
    anyhow::ensure!(
        rec.get("result").is_some(),
        "cancelled tune must carry its best-so-far partial result"
    );
    println!("  job {job} cancelled with best-so-far partial result\n");

    // ---- graceful degradation: tune under injected faults -------------
    println!("POST /api/tune (SA under crash injection, fail_budget 2 — degrades, keeps best-so-far)");
    let (code, body) = post(
        "/api/tune",
        r#"{"bench":"lda","gc":"g1","algo":"sa","iters":10,"fail_budget":2,
            "faults":{"seed":7,"crash_p":1.0,"max_retries":1}}"#,
    );
    anyhow::ensure!(code == 202, "faulty tune must still be accepted: {body}");
    let chaos_job = Json::parse(&body).unwrap().get("job_id").unwrap().as_f64().unwrap();
    let chaos_rec = watch(chaos_job)?;
    anyhow::ensure!(
        chaos_rec.get("status").and_then(Json::as_str) == Some("degraded"),
        "fault-budget exhaustion must land in 'degraded': {chaos_rec}"
    );
    let v = chaos_rec
        .get("result")
        .ok_or_else(|| anyhow::anyhow!("degraded job must keep its best-so-far result"))?;
    let failures = v
        .get("failures")
        .ok_or_else(|| anyhow::anyhow!("degraded result must carry the failure histogram"))?;
    let total = failures.get("total").and_then(Json::as_f64).unwrap_or(0.0);
    anyhow::ensure!(total > 2.0, "budget 2 means >2 recorded failures: {failures}");
    anyhow::ensure!(
        v.get("best_java_args").is_some(),
        "degraded result must still name a best configuration: {v}"
    );
    println!("  job {chaos_job} degraded after {total} failures; histogram {failures}\n");
    if let Some(path) = &chaos_out {
        std::fs::write(path, format!("{chaos_rec}\n"))?;
        println!("  wrote degraded job record to {}\n", path.display());
    }

    // ---- restart: a second backend on the same state dir --------------
    println!("restarting the backend on the same --state-dir ...");
    // The terminal hook persists *after* the record turns visible over
    // HTTP, so wait until the cancelled record actually reached the state
    // file — the file merely existing only proves the earlier dataset
    // store ran.
    let state_file = state_dir.join(persist::STATE_FILE);
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let persisted = std::fs::read_to_string(&state_file).unwrap_or_default();
        if persisted.contains("\"status\":\"cancelled\"")
            && persisted.contains("\"status\":\"degraded\"")
        {
            break;
        }
        anyhow::ensure!(
            std::time::Instant::now() < deadline,
            "cancelled/degraded jobs never reached the state file"
        );
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    let opts = ApiOptions { state_dir: Some(state_dir.clone()), ..Default::default() };
    let addr2 = spawn_with("127.0.0.1:0", load_backend("artifacts"), opts)?;
    println!("  second instance on http://{addr2}");

    let (_, body) = http_request(addr2, "GET", "/api/datasets", "").unwrap();
    anyhow::ensure!(
        body.contains(&format!("\"dataset_id\":{id}")),
        "dataset {id} did not survive the restart: {body}"
    );
    println!("  GET /api/datasets\n    {body}");
    let (code, body) = http_request(addr2, "GET", &format!("/api/jobs/{job}"), "").unwrap();
    anyhow::ensure!(code == 200, "terminal job records did not survive the restart");
    anyhow::ensure!(body.contains("\"status\":\"cancelled\""), "restored job lost its state: {body}");
    println!("  GET /api/jobs/{job}\n    {body}");
    // The degraded record survives too, histogram and all.
    let (code, body) =
        http_request(addr2, "GET", &format!("/api/jobs/{chaos_job}"), "").unwrap();
    anyhow::ensure!(code == 200, "degraded job record did not survive the restart");
    anyhow::ensure!(
        body.contains("\"status\":\"degraded\"") && body.contains("\"failures\""),
        "restored degraded job lost its state: {body}"
    );
    println!("  GET /api/jobs/{chaos_job}\n    {body}");
    // The restored dataset is live, not just listed: select works on it.
    let (code, _) =
        http_request(addr2, "POST", "/api/select", &format!(r#"{{"dataset_id":{id}}}"#)).unwrap();
    anyhow::ensure!(code == 200, "select on a restored dataset failed");
    println!(
        "\njob lifecycle demo complete: progress, cancellation, graceful degradation, \
         and restart persistence OK"
    );
    Ok(())
}
