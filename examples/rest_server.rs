//! The Fig 2 backend in action: start the REST API, then act as the UI —
//! characterize, select flags, and tune over HTTP.
//!
//! Run with:  cargo run --release --example rest_server

use onestoptuner::runtime::load_backend;
use onestoptuner::server::{http_request, spawn};
use onestoptuner::util::json::Json;

fn main() -> anyhow::Result<()> {
    let backend = load_backend("artifacts");
    let addr = spawn("127.0.0.1:0", backend)?;
    println!("REST API up on http://{addr}\n");

    let get = |path: &str| http_request(addr, "GET", path, "").unwrap();
    let post = |path: &str, body: &str| http_request(addr, "POST", path, body).unwrap();

    let (_, body) = get("/api/health");
    println!("GET /api/health\n  {body}\n");

    let (_, body) = get("/api/benchmarks");
    println!("GET /api/benchmarks\n  {body}\n");

    println!("POST /api/run (DenseKMeans, ParallelGC, 32G heap)");
    let (_, body) = post(
        "/api/run",
        r#"{"bench":"densekmeans","gc":"parallel","flags":{"MaxHeapSize":32768}}"#,
    );
    println!("  {body}\n");

    println!("POST /api/characterize (LDA, G1GC — this runs the AL loop)");
    let (_, body) = post(
        "/api/characterize",
        r#"{"bench":"lda","gc":"g1","pool":200,"rounds":3}"#,
    );
    println!("  {body}\n");
    let v = Json::parse(&body).unwrap();
    let id = v.get("dataset_id").unwrap().as_f64().unwrap();

    println!("POST /api/select (lasso on dataset {id})");
    let (_, body) = post("/api/select", &format!(r#"{{"dataset_id":{id}}}"#));
    let sel = Json::parse(&body).unwrap();
    println!(
        "  kept {} of {} flags\n",
        sel.get("n_selected").unwrap(),
        sel.get("group_size").unwrap()
    );

    println!("POST /api/tune (BO warm start, 10 iterations)");
    let (_, body) = post(
        "/api/tune",
        &format!(r#"{{"bench":"lda","gc":"g1","algo":"bo-warm","dataset_id":{id},"iters":10}}"#),
    );
    let v = Json::parse(&body).unwrap();
    println!(
        "  improvement {}x, tuning time {} s",
        v.get("improvement").unwrap(),
        v.get("tuning_time_s").unwrap()
    );
    Ok(())
}
