//! The Fig 2 backend in action: start the REST API, then act as the UI —
//! characterize, select flags, and tune over HTTP.  The long-running
//! endpoints are asynchronous: POST returns `202 Accepted` + a job id and
//! the client polls `/api/jobs/:id` until the job is done.
//!
//! Run with:  cargo run --release --example rest_server [-- --threads N]

use onestoptuner::runtime::load_backend;
use onestoptuner::server::{http_request, spawn};
use onestoptuner::util::json::Json;

fn main() -> anyhow::Result<()> {
    // Same global flag as the CLI: pin the execution-pool width (the
    // default is the auto-detected core count; results never depend on it).
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Some(i) = args.iter().position(|a| a == "--threads") {
        let n: usize = args
            .get(i + 1)
            .and_then(|s| s.parse().ok())
            .filter(|&n| n >= 1)
            .ok_or_else(|| anyhow::anyhow!("--threads needs a positive integer"))?;
        if !onestoptuner::exec::set_global_threads(n) {
            eprintln!("warning: execution pool already initialized; --threads {n} ignored");
        }
    }

    let backend = load_backend("artifacts");
    let addr = spawn("127.0.0.1:0", backend)?;
    println!("REST API up on http://{addr}\n");

    let get = |path: &str| http_request(addr, "GET", path, "").unwrap();
    let post = |path: &str, body: &str| http_request(addr, "POST", path, body).unwrap();

    // Poll an async job until it finishes, returning its result payload.
    let wait_done = |job_id: f64| -> Json {
        loop {
            let (_, body) = get(&format!("/api/jobs/{job_id}"));
            let v = Json::parse(&body).unwrap();
            match v.get("status").and_then(Json::as_str) {
                Some("done") => return v.get("result").unwrap().clone(),
                Some("failed") => panic!("job {job_id} failed: {body}"),
                _ => std::thread::sleep(std::time::Duration::from_millis(250)),
            }
        }
    };

    let (_, body) = get("/api/health");
    println!("GET /api/health\n  {body}\n");

    let (_, body) = get("/api/benchmarks");
    println!("GET /api/benchmarks\n  {body}\n");

    println!("POST /api/run (DenseKMeans, ParallelGC, 32G heap)");
    let (_, body) = post(
        "/api/run",
        r#"{"bench":"densekmeans","gc":"parallel","flags":{"MaxHeapSize":32768}}"#,
    );
    println!("  {body}\n");

    println!("POST /api/characterize (LDA, G1GC — the AL loop runs as an async job)");
    let (code, body) = post(
        "/api/characterize",
        r#"{"bench":"lda","gc":"g1","pool":200,"rounds":3}"#,
    );
    println!("  {code} {body}");
    let job = Json::parse(&body).unwrap().get("job_id").unwrap().as_f64().unwrap();
    let result = wait_done(job);
    println!("  job {job} done: {result}\n");
    let id = result.get("dataset_id").unwrap().as_f64().unwrap();

    println!("POST /api/select (lasso on dataset {id})");
    let (_, body) = post("/api/select", &format!(r#"{{"dataset_id":{id}}}"#));
    let sel = Json::parse(&body).unwrap();
    println!(
        "  kept {} of {} flags\n",
        sel.get("n_selected").unwrap(),
        sel.get("group_size").unwrap()
    );

    println!("POST /api/tune (BO warm start, 10 iterations, async)");
    let (code, body) = post(
        "/api/tune",
        &format!(r#"{{"bench":"lda","gc":"g1","algo":"bo-warm","dataset_id":{id},"iters":10}}"#),
    );
    println!("  {code} {body}");
    let job = Json::parse(&body).unwrap().get("job_id").unwrap().as_f64().unwrap();
    let v = wait_done(job);
    println!(
        "  improvement {}x, tuning time {} s",
        v.get("improvement").unwrap(),
        v.get("tuning_time_s").unwrap()
    );
    Ok(())
}
