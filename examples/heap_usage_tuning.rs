//! Paper §V-F / Table IV / Fig 7: optimize average heap-usage percentage
//! (eq. 8/9) instead of execution time — "tuning for low memory footprint
//! is common as it is desirable to reduce the cost incurred on virtual
//! machines."  Also demonstrates the time/memory trade-off the paper warns
//! about.
//!
//! Run with:  cargo run --release --example heap_usage_tuning [bench]

use onestoptuner::pipeline::{measure, run_pipeline, Algo, PipelineConfig};
use onestoptuner::runtime::load_backend;
use onestoptuner::{Benchmark, GcMode, Metric};

fn main() -> anyhow::Result<()> {
    let bench = std::env::args()
        .nth(1)
        .and_then(|s| Benchmark::parse(&s))
        .unwrap_or(Benchmark::Lda);
    let mode = GcMode::G1GC;
    let backend = load_backend("artifacts");
    let cfg = PipelineConfig::default();

    println!("tuning {} ({}) for heap usage\n", bench.name(), mode.name());
    let out = run_pipeline(
        bench,
        mode,
        Metric::HeapUsage,
        &[Algo::Bo, Algo::BoWarm, Algo::Sa],
        &cfg,
        &backend,
    )?;

    println!(
        "default heap usage: {:.1} +- {:.1} %",
        out.default_summary.mean, out.default_summary.std
    );
    for o in &out.outcomes {
        let impr = 100.0 * (out.default_summary.mean - o.tuned_summary.mean)
            / out.default_summary.mean;
        println!(
            "  {:<15} {:.1} +- {:.1} %   improvement {impr:.1}%",
            o.algo.name(),
            o.tuned_summary.mean,
            o.tuned_summary.std
        );
    }

    // The paper's §V-F caveat: a memory-tuned config may slow the job down.
    let best = out
        .outcomes
        .iter()
        .min_by(|a, b| a.tuned_summary.mean.partial_cmp(&b.tuned_summary.mean).unwrap())
        .unwrap();
    let runner = onestoptuner::SparkRunner::paper_default(bench);
    let time_default = measure(
        &runner,
        &onestoptuner::FlagConfig::default_for(mode),
        Metric::ExecTime,
        5,
        77,
    );
    let time_tuned = measure(&runner, &best.tune.best_config, Metric::ExecTime, 5, 77);
    println!(
        "\ntrade-off check ({}): exec time default {:.1} s -> memory-tuned {:.1} s ({:+.1}%)",
        best.algo.name(),
        time_default.mean,
        time_tuned.mean,
        100.0 * (time_tuned.mean - time_default.mean) / time_default.mean
    );
    println!("(\"tuning for small memory footprint may lead to worse configurations,\n  that may end up slowing down the application\" — paper SectionV-F)");
    Ok(())
}
