//! Paper §V-E / Fig 6: tuning a benchmark while another job shares the
//! cluster ("this better mirrors real time industrial scenarios").  LDA is
//! tuned under G1GC with DenseKMeans running concurrently at its defaults,
//! on the 2-executor x 15-core x 60 GB topology.
//!
//! Run with:  cargo run --release --example parallel_tuning

use onestoptuner::datagen::{characterize, DataGenConfig, Strategy};
use onestoptuner::featsel::select_flags;
use onestoptuner::flags::FlagConfig;
use onestoptuner::runtime::load_backend;
use onestoptuner::sparksim::{ClusterSpec, ExecutorSpec};
use onestoptuner::tuner::{bo::BoConfig, BoTuner, ParallelSimObjective, TuneSpace, Tuner};
use onestoptuner::{Benchmark, GcMode, Metric, SparkRunner};

fn main() -> anyhow::Result<()> {
    let backend = load_backend("artifacts");
    let cluster = ClusterSpec::paper();
    let mode = GcMode::G1GC;
    let metric = Metric::ExecTime;
    let exec = ExecutorSpec::parallel_2x15();

    println!("cluster: {} nodes x {} cores; both jobs get 2 executors x 15 cores x 60 GB",
             cluster.nodes, cluster.cores_per_node);

    // Phase 1+2 on the exclusive cluster (characterization is per-benchmark).
    let runner = SparkRunner::paper_default(Benchmark::Lda);
    let ch = characterize(
        &runner,
        mode,
        metric,
        Strategy::Bemcm,
        &DataGenConfig::default(),
        &backend,
    )?;
    let sel = select_flags(&ch.dataset, 0.01, &backend)?;
    let space = TuneSpace::from_selection(mode, &sel);
    println!("characterized LDA: {} samples; lasso kept {}/{} flags",
             ch.dataset.len(), sel.n_selected(), sel.group_size);

    let default_cfg = FlagConfig::default_for(mode);
    let mk_obj = |seed: u64| {
        ParallelSimObjective::new(
            cluster,
            (Benchmark::Lda, exec),
            (Benchmark::DenseKMeans, default_cfg.clone(), exec),
            metric,
            seed,
        )
    };

    // Baseline: LDA at defaults while DK runs alongside.
    let mut base_obj = mk_obj(1);
    let base: Vec<f64> = (0..10).map(|_| base_obj.run_once(&default_cfg).exec_time_s).collect();
    let base_mean = base.iter().sum::<f64>() / base.len() as f64;
    println!("\nLDA default (parallel with DK): {base_mean:.1} s");

    // Tune under contention with warm-started BO.
    let mut obj = mk_obj(2);
    let mut tuner = BoTuner::warm_start(backend, BoConfig::default(), &space, &ch.dataset);
    let r = tuner.tune(&space, &mut obj, 20)?;

    let mut meas = mk_obj(3);
    let tuned: Vec<f64> = (0..10).map(|_| meas.run_once(&r.best_config).exec_time_s).collect();
    let tuned_mean = tuned.iter().sum::<f64>() / tuned.len() as f64;
    println!("LDA tuned   (parallel with DK): {tuned_mean:.1} s");
    println!(
        "speedup: {:.2}x  (paper Fig 6a: BO warm start ~1.37x)",
        base_mean / tuned_mean
    );
    println!("tuning consumed {:.0} s of simulated cluster time over {} evals",
             r.sim_time_s, r.evals);
    Ok(())
}
