"""Pure-jnp reference oracles for every Pallas kernel and L2 composition.

These are the ground truth for pytest: each Pallas kernel in this package
must match its `ref_*` counterpart to float32 tolerance, and each exported
model function in model.py must match the corresponding `ref_*` composition.
No pallas imports here — plain jax.numpy only.
"""

import jax.numpy as jnp
import jax.scipy.linalg as jsl
from jax.scipy.special import erf

# ---------------------------------------------------------------------------
# EMCM (Batch-mode Expected Model Change Maximization) candidate scoring
# ---------------------------------------------------------------------------


def ref_emcm_score(w_ens, w0, x, feat_mask):
    """Expected model change per candidate (paper eq. 5, bootstrap form).

    For a linear model the parameter-change magnitude when adding candidate
    x* with unknown label y* is ||(f(x*) - y*) x*||.  The label is estimated
    by the bootstrap ensemble, giving

        score(x*) = mean_z |f_z(x*) - f(x*)| * ||x*||_2

    w_ens: (Z, D) bootstrap ensemble weights
    w0:    (D,)   central model weights
    x:     (M, D) candidate feature rows
    feat_mask: (D,) 1.0 for live feature columns, 0.0 for padding
    returns (M,) scores
    """
    xm = x * feat_mask[None, :]
    preds = xm @ w_ens.T                      # (M, Z)
    fbar = xm @ w0                            # (M,)
    resid = jnp.abs(preds - fbar[:, None])    # (M, Z)
    xnorm = jnp.sqrt(jnp.sum(xm * xm, axis=1))
    return jnp.mean(resid, axis=1) * xnorm


# ---------------------------------------------------------------------------
# RBF kernel matrix
# ---------------------------------------------------------------------------


def ref_rbf(x1, x2, lengthscale, sigma_f2):
    """K[i,j] = sigma_f2 * exp(-||x1_i - x2_j||^2 / (2 l^2))."""
    n1 = jnp.sum(x1 * x1, axis=1)[:, None]
    n2 = jnp.sum(x2 * x2, axis=1)[None, :]
    sq = jnp.maximum(n1 + n2 - 2.0 * (x1 @ x2.T), 0.0)
    return sigma_f2 * jnp.exp(-sq / (2.0 * lengthscale * lengthscale))


# ---------------------------------------------------------------------------
# Expected Improvement (minimization form)
# ---------------------------------------------------------------------------

_SQRT2 = 1.4142135623730951
_INV_SQRT_2PI = 0.3989422804014327


def _phi(z):
    return _INV_SQRT_2PI * jnp.exp(-0.5 * z * z)


def _Phi(z):
    return 0.5 * (1.0 + erf(z / _SQRT2))


def ref_ei(mu, sigma, best):
    """EI for minimization: E[max(0, best - f(x))] under N(mu, sigma^2)."""
    sig = jnp.maximum(sigma, 1e-9)
    z = (best - mu) / sig
    ei = jnp.maximum(sig * (z * _Phi(z) + _phi(z)), 0.0)
    return jnp.where(sigma > 1e-9, ei, jnp.maximum(best - mu, 0.0))


# ---------------------------------------------------------------------------
# ISTA step for Lasso
# ---------------------------------------------------------------------------


def ref_ista_step(w, gram, xty, step, lam):
    """One ISTA update: w <- soft(w - step * (G w - X^T y), step * lam)."""
    grad = gram @ w - xty
    u = w - step * grad
    thr = step * lam
    return jnp.sign(u) * jnp.maximum(jnp.abs(u) - thr, 0.0)


# ---------------------------------------------------------------------------
# L2-composition references (padded/masked, matching model.py exports)
# ---------------------------------------------------------------------------


def ref_lr_fit(x, y, row_mask, feat_mask, ridge):
    """Masked ridge-regularized least squares via normal equations.

    Padded feature columns get weight exactly 0 (their normal-equation row
    is ridge * I only, with rhs 0).
    """
    xm = x * row_mask[:, None] * feat_mask[None, :]
    ym = y * row_mask
    d = x.shape[1]
    a = xm.T @ xm + ridge * jnp.eye(d, dtype=x.dtype)
    b = xm.T @ ym
    c, low = jsl.cho_factor(a)
    return jsl.cho_solve((c, low), b)


def ref_lasso_fit(x, y, row_mask, feat_mask, lam, iters=400, power_iters=16):
    """Lasso by ISTA with a power-iteration Lipschitz estimate.

    Objective: (1/2n) ||y - Xw||^2 + lam * ||w||_1 over live rows/features.
    """
    xm = x * row_mask[:, None] * feat_mask[None, :]
    ym = y * row_mask
    n_eff = jnp.maximum(jnp.sum(row_mask), 1.0)
    gram = (xm.T @ xm) / n_eff
    xty = (xm.T @ ym) / n_eff

    d = x.shape[1]
    v = jnp.ones((d,), dtype=x.dtype) / jnp.sqrt(jnp.asarray(d, x.dtype))
    for _ in range(power_iters):
        v = gram @ v
        v = v / jnp.maximum(jnp.linalg.norm(v), 1e-12)
    lmax = jnp.maximum(v @ (gram @ v), 1e-6)
    step = 1.0 / (lmax * 1.01)

    w = jnp.zeros((d,), dtype=x.dtype)
    for _ in range(iters):
        w = ref_ista_step(w, gram, xty, step, lam)
    return w * feat_mask


def ref_gp_ei(xtr, ytr, row_mask, xc, feat_mask, lengthscale, sigma_f2,
              sigma_n2, best):
    """GP posterior at candidates + EI, with exact padding via masks.

    Padded training rows are spliced out of the kernel matrix by pinning
    their rows/columns to the identity, so the Cholesky factor is block
    diagonal (active block, identity block) and padded rows contribute
    nothing to the posterior.  Returns (ei, mu, sigma), each (M,).
    """
    xtr_m = xtr * row_mask[:, None] * feat_mask[None, :]
    xc_m = xc * feat_mask[None, :]
    ytr_m = ytr * row_mask
    n = xtr.shape[0]

    k = ref_rbf(xtr_m, xtr_m, lengthscale, sigma_f2)
    pair = row_mask[:, None] * row_mask[None, :]
    eye = jnp.eye(n, dtype=xtr.dtype)
    k_eff = pair * (k + sigma_n2 * eye) + (1.0 - pair) * eye

    low = jnp.linalg.cholesky(k_eff)
    # alpha = K^-1 y via two triangular solves
    t = jsl.solve_triangular(low, ytr_m, lower=True)
    alpha = jsl.solve_triangular(low.T, t, lower=False)

    kc = ref_rbf(xc_m, xtr_m, lengthscale, sigma_f2) * row_mask[None, :]
    mu = kc @ alpha

    v = jsl.solve_triangular(low, kc.T, lower=True)  # (N, M)
    var = sigma_f2 - jnp.sum(v * v, axis=0)
    sigma = jnp.sqrt(jnp.maximum(var, 1e-12))
    ei = ref_ei(mu, sigma, best)
    return ei, mu, sigma
