"""L1 Pallas kernel: Expected Improvement acquisition (minimization form).

Elementwise over the M candidates of a BO acquisition sweep:
    z  = (best - mu) / sigma
    EI = sigma * (z * Phi(z) + phi(z))
with a deterministic fallback max(0, best - mu) when sigma ~ 0.  Pure VPU
work over the same TILE_M tiles the GP kernels produce.  interpret=True for
CPU PJRT.

NOTE: Phi is computed from a rational erf approximation (Abramowitz &
Stegun 7.1.26, |err| <= 1.5e-7) spelled out in mul/exp ops — jax's
`erf` primitive lowers to an `erf` HLO opcode that the xla_extension 0.5.1
text parser (the version the rust `xla` crate links) does not know.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..shapes import TILE_M

_SQRT2 = 1.4142135623730951
_INV_SQRT_2PI = 0.3989422804014327


def erf_approx(x):
    """A&S 7.1.26 rational erf approximation using only basic HLO ops."""
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * ax)
    poly = ((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
            - 0.284496736) * t + 0.254829592
    y = 1.0 - poly * t * jnp.exp(-ax * ax)
    return sign * y


def _ei_kernel(mu_ref, sigma_ref, best_ref, out_ref):
    mu = mu_ref[...]
    sigma = sigma_ref[...]
    best = best_ref[0]
    sig = jnp.maximum(sigma, 1e-9)
    z = (best - mu) / sig
    cdf = 0.5 * (1.0 + erf_approx(z / _SQRT2))
    pdf = _INV_SQRT_2PI * jnp.exp(-0.5 * z * z)
    ei = jnp.maximum(sig * (z * cdf + pdf), 0.0)
    out_ref[...] = jnp.where(sigma > 1e-9, ei, jnp.maximum(best - mu, 0.0))


def expected_improvement(mu, sigma, best, tile_m=TILE_M, interpret=True):
    """Pallas EI; matches ref.ref_ei.  mu, sigma (M,) -> (M,)."""
    m = mu.shape[0]
    assert m % tile_m == 0, (m, tile_m)
    best_arr = jnp.asarray(best, mu.dtype).reshape(1)
    grid = (m // tile_m,)
    return pl.pallas_call(
        _ei_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m,), lambda i: (i,)),
            pl.BlockSpec((tile_m,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), mu.dtype),
        interpret=interpret,
    )(mu, sigma, best_arr)
