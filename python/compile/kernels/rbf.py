"""L1 Pallas kernel: tiled RBF (squared-exponential) kernel matrix.

The GP hot spot (phase 3, paper §III-D): K[i,j] = sf2 * exp(-||xi-xj||^2 /
(2 l^2)) computed with the matmul trick ||x||^2 + ||y||^2 - 2 x.y so the
inner product hits the MXU.  Grid tiles are TILE x TILE over the output;
each grid step streams one (TILE, D) row block of each input HBM->VMEM.
interpret=True for CPU PJRT.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..shapes import TILE_M, TILE_N


def _rbf_kernel(x1_ref, x2_ref, theta_ref, out_ref):
    x1 = x1_ref[...]                          # (TA, D)
    x2 = x2_ref[...]                          # (TB, D)
    lengthscale = theta_ref[0, 0]
    sf2 = theta_ref[0, 1]
    n1 = jnp.sum(x1 * x1, axis=1)[:, None]
    n2 = jnp.sum(x2 * x2, axis=1)[None, :]
    cross = jnp.dot(x1, x2.T)                 # MXU
    sq = jnp.maximum(n1 + n2 - 2.0 * cross, 0.0)
    out_ref[...] = sf2 * jnp.exp(-sq / (2.0 * lengthscale * lengthscale))


def rbf_matrix(x1, x2, lengthscale, sigma_f2, tile_a=TILE_M, tile_b=TILE_N,
               interpret=True):
    """Pallas RBF kernel matrix; matches ref.ref_rbf.

    x1 (A, D), x2 (B, D) -> (A, B).  A % tile_a == 0, B % tile_b == 0.
    """
    a, d = x1.shape
    b = x2.shape[0]
    assert a % tile_a == 0 and b % tile_b == 0, (a, b, tile_a, tile_b)
    theta = jnp.stack([jnp.asarray(lengthscale, x1.dtype),
                       jnp.asarray(sigma_f2, x1.dtype)]).reshape(1, 2)
    grid = (a // tile_a, b // tile_b)
    return pl.pallas_call(
        _rbf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_a, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_b, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_a, tile_b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a, b), x1.dtype),
        interpret=interpret,
    )(x1, x2, theta)
