"""L1 Pallas kernel: one ISTA step for Lasso feature selection.

Phase 2 of the pipeline (paper §III-C) runs LASSO_ITERS of these inside a
lax.fori_loop in the lasso_fit artifact.  With the Gram matrix G = X^T X / n
precomputed once in L2, each step is

    w <- soft(w - step * (G w - X^T y), step * lam)

Grid tiles rows of G (TILE_D x D) so the matvec hits the MXU in row blocks;
w stays fully resident in VMEM (D = 320 floats).  interpret=True for CPU
PJRT.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..shapes import TILE_D


def _ista_kernel(gram_ref, w_ref, xty_ref, hp_ref, out_ref):
    g = gram_ref[...]                          # (TILE_D, D)
    w = w_ref[...]                             # (1, D) resident
    xty = xty_ref[...]                         # (TILE_D,)
    step = hp_ref[0, 0]
    lam = hp_ref[0, 1]
    i0 = pl.program_id(0) * g.shape[0]
    grad = jnp.dot(g, w[0]) - xty              # (TILE_D,) — MXU matvec
    w_rows = jax.lax.dynamic_slice(w[0], (i0,), (g.shape[0],))
    u = w_rows - step * grad
    thr = step * lam
    out_ref[...] = jnp.sign(u) * jnp.maximum(jnp.abs(u) - thr, 0.0)


def ista_step(w, gram, xty, step, lam, tile_d=TILE_D, interpret=True):
    """Pallas ISTA step; matches ref.ref_ista_step.

    w (D,), gram (D, D), xty (D,) -> (D,).  D % tile_d == 0.
    """
    d = w.shape[0]
    assert d % tile_d == 0, (d, tile_d)
    hp = jnp.stack([jnp.asarray(step, w.dtype),
                    jnp.asarray(lam, w.dtype)]).reshape(1, 2)
    grid = (d // tile_d,)
    return pl.pallas_call(
        _ista_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_d, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((tile_d,), lambda i: (i,)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), w.dtype),
        interpret=interpret,
    )(gram, w.reshape(1, d), xty, hp)
