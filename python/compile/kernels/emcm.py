"""L1 Pallas kernel: batched EMCM candidate scoring.

The data-generation hot spot (phase 1 of the pipeline, paper §III-B): for a
pool chunk of M candidate flag configurations and a bootstrap ensemble of Z
linear models, compute the expected-model-change score

    score(x*) = mean_z |f_z(x*) - f0(x*)| * ||x*||_2

TPU mapping: the M x D candidate block streams HBM->VMEM in TILE_M x D
tiles; the (Z, D) ensemble weight matrix is small and stays resident in
VMEM; each grid step does a (TILE_M, D) @ (D, Z) MXU matmul plus VPU
elementwise reduction.  interpret=True for CPU PJRT (see DESIGN.md
§Hardware-Adaptation).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..shapes import TILE_M


def _emcm_kernel(x_ref, wens_ref, w0_ref, mask_ref, out_ref):
    x = x_ref[...] * mask_ref[...]            # (TILE_M, D) masked in VMEM
    wens = wens_ref[...]                      # (Z, D), resident
    w0 = w0_ref[...]                          # (1, D)
    preds = jnp.dot(x, wens.T)                # (TILE_M, Z) — MXU
    fbar = jnp.sum(x * w0, axis=1)            # (TILE_M,)
    resid = jnp.abs(preds - fbar[:, None])
    xnorm = jnp.sqrt(jnp.sum(x * x, axis=1))
    out_ref[...] = jnp.mean(resid, axis=1) * xnorm


def emcm_score(w_ens, w0, x, feat_mask, tile_m=TILE_M, interpret=True):
    """Pallas EMCM scores; matches ref.ref_emcm_score.

    w_ens (Z, D), w0 (D,), x (M, D), feat_mask (D,) -> (M,) float32.
    M must be a multiple of tile_m.
    """
    m, d = x.shape
    z = w_ens.shape[0]
    assert m % tile_m == 0, (m, tile_m)
    grid = (m // tile_m,)
    return pl.pallas_call(
        _emcm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, d), lambda i: (i, 0)),
            pl.BlockSpec((z, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
        interpret=interpret,
    )(x, w_ens, w0.reshape(1, d), feat_mask.reshape(1, d))
