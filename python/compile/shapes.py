"""Fixed AOT export shapes shared by L1 kernels, L2 model, aot.py and the
rust runtime (rust/src/runtime/shapes.rs mirrors these constants).

Everything the rust coordinator sends through PJRT is padded to these static
shapes and masked; masks make the padding exact (see model.py docstrings).
"""

# Feature dimension: normalized flag values for the larger GC group (141)
# plus squared terms for continuous flags, padded up to a multiple of the
# 128-lane tile width used by the Pallas kernels.
D_FEAT = 320

# Max labelled rows per fit call (AL training set / GP training set).
N_TRAIN = 256

# Candidates scored per XLA call (AL pool chunk / BO acquisition grid chunk).
M_CAND = 512

# Bootstrap ensemble size for BEMCM.
Z_ENS = 8

# Pallas tile sizes (MXU-oriented: 128x128 f32 tiles; the ISTA matvec tiles
# D = 320 rows in 64-row blocks since 320 is not a multiple of 128).
TILE_M = 128
TILE_N = 128
TILE_D = 64

# ISTA iteration count inside the lasso_fit artifact.
LASSO_ITERS = 400

# Power-iteration steps for the Lipschitz estimate inside lasso_fit.
POWER_ITERS = 16

ARTIFACTS = ("emcm_score", "gp_ei", "lr_fit", "lasso_fit")
