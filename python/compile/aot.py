"""AOT exporter: lower every L2 function to HLO *text* artifacts.

HLO text (NOT .serialize()): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
`xla` 0.1.6 rust crate links) rejects with `proto.id() <= INT_MAX`.  The
text parser on the rust side reassigns ids, so text round-trips cleanly.
See /opt/xla-example/gen_hlo.py.

Also writes artifacts/manifest.json describing shapes and argument order so
the rust runtime can validate itself against the python side.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model, shapes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "shapes": {
            "d_feat": shapes.D_FEAT,
            "n_train": shapes.N_TRAIN,
            "m_cand": shapes.M_CAND,
            "z_ens": shapes.Z_ENS,
            "lasso_iters": shapes.LASSO_ITERS,
        },
        "artifacts": {},
    }
    for name, (fn, args) in model.export_specs().items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [list(a.shape) for a in args],
            "chars": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    export_all(args.out)


if __name__ == "__main__":
    main()
