"""L2: the OneStopTuner compute graph, written in JAX and calling the L1
Pallas kernels, lowered once by aot.py to fixed-shape HLO artifacts that the
rust coordinator executes via PJRT.

Every exported function is total over padded inputs: row masks splice out
unused training rows, the feature mask splices out unused flag columns, so
the rust side can run any (bench x GC-mode) problem size below the static
maxima in shapes.py.

Exports (all float32):
  emcm_score(w_ens, w0, x, feat_mask)                    -> (M,)
  gp_ei(xtr, ytr, row_mask, xc, feat_mask, theta)        -> (ei, mu, sigma)
  lr_fit(x, y, row_mask, feat_mask, ridge)               -> (D,)
  lasso_fit(x, y, row_mask, feat_mask, lam)              -> (D,)
"""

import jax
import jax.numpy as jnp

from . import shapes
from .kernels import ei as ei_k
from .kernels import emcm as emcm_k
from .kernels import ista as ista_k
from .kernels import rbf as rbf_k

# ---------------------------------------------------------------------------
# Phase 1: EMCM active-learning candidate scoring
# ---------------------------------------------------------------------------


def emcm_score(w_ens, w0, x, feat_mask):
    """Score a pool chunk of M candidates for batch-mode AL selection."""
    return emcm_k.emcm_score(w_ens, w0, x, feat_mask)


# ---------------------------------------------------------------------------
# Dense linear algebra in basic HLO ops
#
# jnp.linalg.cholesky / jsl.solve_triangular lower to lapack_*_ffi
# custom-calls (API_VERSION_TYPED_FFI) that xla_extension 0.5.1 — the
# runtime the rust `xla` crate links — can neither parse nor execute, so we
# spell out left-looking Cholesky and substitution solves with fori_loop +
# dynamic slices.  O(n^3) matvec formulation; n <= 320.
# ---------------------------------------------------------------------------


def _cholesky(a):
    """Lower-triangular L with a = L L^T (a must be PD)."""
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(j, l):
        # s_i = a[i, j] - sum_{k<j} l[i, k] l[j, k]; columns >= j of l are
        # still zero, so a full matvec gives exactly the k<j sum.
        lj = jax.lax.dynamic_slice(l, (j, 0), (1, n))[0]      # row j
        s = jax.lax.dynamic_slice(a, (0, j), (n, 1))[:, 0] - l @ lj
        d = jnp.sqrt(jnp.maximum(s[j], 1e-20))
        col = jnp.where(idx >= j, s / d, 0.0)
        return jax.lax.dynamic_update_slice(l, col[:, None], (0, j))

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(a))


def _solve_lower(l, b):
    """x with L x = b (forward substitution), b of shape (n,) or (n, m)."""
    n = l.shape[0]
    vec = b.ndim == 1
    bm = b[:, None] if vec else b
    m = bm.shape[1]

    def body(i, x):
        li = jax.lax.dynamic_slice(l, (i, 0), (1, n))[0]
        bi = jax.lax.dynamic_slice(bm, (i, 0), (1, m))[0]
        xi = (bi - li @ x) / li[i]
        return jax.lax.dynamic_update_slice(x, xi[None, :], (i, 0))

    x = jax.lax.fori_loop(0, n, body, jnp.zeros_like(bm))
    return x[:, 0] if vec else x


def _solve_lower_t(l, b):
    """x with L^T x = b (backward substitution), b of shape (n,)."""
    n = l.shape[0]

    def body(k, x):
        i = n - 1 - k
        # (L^T)[i, :] = L[:, i]
        ci = jax.lax.dynamic_slice(l, (0, i), (n, 1))[:, 0]
        xi = (b[i] - ci @ x) / ci[i]
        return jax.lax.dynamic_update_slice(x, xi[None], (i,))

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


# ---------------------------------------------------------------------------
# Phase 3: GP posterior + Expected Improvement
# ---------------------------------------------------------------------------


def gp_ei(xtr, ytr, row_mask, xc, feat_mask, theta):
    """GP-EI acquisition over a candidate chunk.

    theta = [lengthscale, sigma_f2, sigma_n2, best_y] (shape (4,)).
    Padded training rows are pinned to the identity block of the kernel
    matrix (see kernels.ref.ref_gp_ei), making the padding exact.
    """
    lengthscale, sigma_f2, sigma_n2, best = (theta[0], theta[1], theta[2],
                                             theta[3])
    n = xtr.shape[0]
    xtr_m = xtr * row_mask[:, None] * feat_mask[None, :]
    xc_m = xc * feat_mask[None, :]
    ytr_m = ytr * row_mask

    k = rbf_k.rbf_matrix(xtr_m, xtr_m, lengthscale, sigma_f2)
    pair = row_mask[:, None] * row_mask[None, :]
    eye = jnp.eye(n, dtype=xtr.dtype)
    k_eff = pair * (k + sigma_n2 * eye) + (1.0 - pair) * eye

    low = _cholesky(k_eff)
    t = _solve_lower(low, ytr_m)
    alpha = _solve_lower_t(low, t)

    kc = rbf_k.rbf_matrix(xc_m, xtr_m, lengthscale, sigma_f2) \
        * row_mask[None, :]
    mu = kc @ alpha

    v = _solve_lower(low, kc.T)                       # (N, M)
    var = sigma_f2 - jnp.sum(v * v, axis=0)
    sigma = jnp.sqrt(jnp.maximum(var, 1e-12))
    ei = ei_k.expected_improvement(mu, sigma, best)
    return ei, mu, sigma


# ---------------------------------------------------------------------------
# Phases 1 & 3 (RBO): masked ridge linear-regression fit
# ---------------------------------------------------------------------------


def lr_fit(x, y, row_mask, feat_mask, ridge):
    """Ridge LR via masked normal equations; ridge is shape (1,)."""
    xm = x * row_mask[:, None] * feat_mask[None, :]
    ym = y * row_mask
    d = x.shape[1]
    a = xm.T @ xm + ridge[0] * jnp.eye(d, dtype=x.dtype)
    b = xm.T @ ym
    low = _cholesky(a)
    return _solve_lower_t(low, _solve_lower(low, b))


# ---------------------------------------------------------------------------
# Phase 2: Lasso feature selection (ISTA around the L1 step kernel)
# ---------------------------------------------------------------------------


def lasso_fit(x, y, row_mask, feat_mask, lam):
    """Lasso weights via LASSO_ITERS ISTA steps; lam is shape (1,)."""
    xm = x * row_mask[:, None] * feat_mask[None, :]
    ym = y * row_mask
    d = x.shape[1]
    n_eff = jnp.maximum(jnp.sum(row_mask), 1.0)
    gram = (xm.T @ xm) / n_eff
    xty = (xm.T @ ym) / n_eff

    # Lipschitz constant by power iteration (fixed step count).
    v = jnp.ones((d,), dtype=x.dtype) / jnp.sqrt(jnp.asarray(d, x.dtype))

    def power_body(_, vv):
        vv = gram @ vv
        return vv / jnp.maximum(jnp.linalg.norm(vv), 1e-12)

    v = jax.lax.fori_loop(0, shapes.POWER_ITERS, power_body, v)
    lmax = jnp.maximum(v @ (gram @ v), 1e-6)
    step = 1.0 / (lmax * 1.01)

    def ista_body(_, w):
        return ista_k.ista_step(w, gram, xty, step, lam[0])

    w0 = jnp.zeros((d,), dtype=x.dtype)
    w = jax.lax.fori_loop(0, shapes.LASSO_ITERS, ista_body, w0)
    return w * feat_mask


# ---------------------------------------------------------------------------
# AOT export table: name -> (function, example argument shapes)
# ---------------------------------------------------------------------------


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def export_specs():
    """name -> (callable, tuple of ShapeDtypeStructs) for aot.py."""
    d, n, m, z = shapes.D_FEAT, shapes.N_TRAIN, shapes.M_CAND, shapes.Z_ENS
    return {
        "emcm_score": (emcm_score, (_f32(z, d), _f32(d), _f32(m, d),
                                    _f32(d))),
        "gp_ei": (gp_ei, (_f32(n, d), _f32(n), _f32(n), _f32(m, d), _f32(d),
                          _f32(4))),
        "lr_fit": (lr_fit, (_f32(n, d), _f32(n), _f32(n), _f32(d),
                            _f32(1))),
        "lasso_fit": (lasso_fit, (_f32(n, d), _f32(n), _f32(n), _f32(d),
                                  _f32(1))),
    }
