"""Pallas kernels vs pure-jnp oracle (ref.py) — the core L1 correctness
signal.  Hypothesis sweeps shapes, masks and hyper-parameters; fixed cases
pin the exact export shapes used by aot.py."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import shapes
from compile.kernels import ei, emcm, ista, rbf, ref

RNG = np.random.default_rng(1234)


def _f32(a):
    return jnp.asarray(np.asarray(a, dtype=np.float32))


def _rand(*shape, scale=1.0, rng=RNG):
    return _f32(rng.normal(size=shape) * scale)


# ---------------------------------------------------------------------------
# EMCM scoring
# ---------------------------------------------------------------------------


class TestEmcm:
    def test_export_shape(self):
        z, d, m = shapes.Z_ENS, shapes.D_FEAT, shapes.M_CAND
        w_ens, w0, x = _rand(z, d), _rand(d), _rand(m, d)
        mask = _f32(np.ones(d))
        got = emcm.emcm_score(w_ens, w0, x, mask)
        want = ref.ref_emcm_score(w_ens, w0, x, mask)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_feature_mask_zeroes_padding(self):
        z, d, m = 4, 320, 128
        w_ens, w0 = _rand(z, d), _rand(d)
        x = _rand(m, d)
        mask = _f32((np.arange(d) < 200).astype(np.float32))
        got = emcm.emcm_score(w_ens, w0, x, mask)
        # Zeroing the padded columns of x by hand must give the same scores.
        x2 = _f32(np.array(x) * np.array(mask)[None, :])
        got2 = emcm.emcm_score(w_ens, w0, x2, mask)
        np.testing.assert_allclose(got, got2, rtol=1e-5, atol=1e-5)

    def test_zero_ensemble_spread_gives_zero_score(self):
        z, d, m = 4, 320, 128
        w0 = _rand(d)
        w_ens = jnp.tile(w0[None, :], (z, 1))
        x = _rand(m, d)
        mask = _f32(np.ones(d))
        got = np.array(emcm.emcm_score(w_ens, w0, x, mask))
        assert np.all(np.abs(got) < 1e-3)

    def test_score_scales_with_candidate_norm(self):
        z, d = 4, 320
        w_ens, w0 = _rand(z, d), _rand(d)
        base = np.tile(RNG.normal(size=(1, d)).astype(np.float32), (128, 1))
        base[64:] *= 2.0  # second half = same direction, twice the norm
        mask = _f32(np.ones(d))
        got = np.array(emcm.emcm_score(_f32(base), w0, w_ens[0] * 0 + _f32(base), mask))
        # |resid| and ||x|| both scale linearly -> score scales ~4x
        np.testing.assert_allclose(got[64:] / np.maximum(got[:64], 1e-9),
                                   4.0, rtol=1e-3)

    @settings(max_examples=20, deadline=None)
    @given(
        m_tiles=st.integers(1, 4),
        z=st.integers(2, 8),
        live=st.integers(1, 320),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_random(self, m_tiles, z, live, seed):
        rng = np.random.default_rng(seed)
        d, m = 320, m_tiles * shapes.TILE_M
        w_ens, w0 = _rand(z, d, rng=rng), _rand(d, rng=rng)
        x = _rand(m, d, rng=rng)
        mask = _f32((np.arange(d) < live).astype(np.float32))
        got = emcm.emcm_score(w_ens, w0, x, mask)
        want = ref.ref_emcm_score(w_ens, w0, x, mask)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# RBF kernel matrix
# ---------------------------------------------------------------------------


class TestRbf:
    def test_export_shapes(self):
        n, m, d = shapes.N_TRAIN, shapes.M_CAND, shapes.D_FEAT
        x1, x2 = _rand(n, d), _rand(m, d)
        got = rbf.rbf_matrix(x1, x2, 2.0, 1.5)
        want = ref.ref_rbf(x1, x2, 2.0, 1.5)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_diagonal_is_sigma_f2(self):
        d = 320
        x = _rand(128, d)
        k = np.array(rbf.rbf_matrix(x, x, 3.0, 2.5))
        np.testing.assert_allclose(np.diag(k), 2.5, rtol=1e-4)

    def test_symmetry(self):
        x = _rand(128, 320)
        k = np.array(rbf.rbf_matrix(x, x, 1.0, 1.0))
        np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-6)

    def test_values_in_range(self):
        x1, x2 = _rand(128, 320), _rand(256, 320)
        k = np.array(rbf.rbf_matrix(x1, x2, 2.0, 1.0))
        assert np.all(k >= 0.0) and np.all(k <= 1.0 + 1e-6)

    def test_identical_points_give_max(self):
        x = _rand(128, 320)
        k = np.array(rbf.rbf_matrix(x, x, 2.0, 1.0))
        assert np.all(k <= np.diag(k)[:, None] + 1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        a_tiles=st.integers(1, 2),
        b_tiles=st.integers(1, 4),
        ls=st.floats(0.3, 10.0),
        sf2=st.floats(0.1, 5.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_random(self, a_tiles, b_tiles, ls, sf2, seed):
        rng = np.random.default_rng(seed)
        a, b, d = a_tiles * 128, b_tiles * 128, 320
        x1, x2 = _rand(a, d, rng=rng), _rand(b, d, rng=rng)
        got = rbf.rbf_matrix(x1, x2, ls, sf2)
        want = ref.ref_rbf(x1, x2, ls, sf2)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# Expected Improvement
# ---------------------------------------------------------------------------


class TestEi:
    def test_export_shape(self):
        m = shapes.M_CAND
        mu = _rand(m)
        sigma = _f32(np.abs(RNG.normal(size=m)) + 0.01)
        got = ei.expected_improvement(mu, sigma, 0.25)
        want = ref.ref_ei(mu, sigma, 0.25)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)

    def test_nonnegative(self):
        mu = _rand(256, scale=3.0)
        sigma = _f32(np.abs(RNG.normal(size=256)))
        got = np.array(ei.expected_improvement(mu, sigma, 0.0))
        assert np.all(got >= -1e-7)

    def test_zero_sigma_fallback(self):
        mu = _f32(np.array([1.0, -1.0] * 64))
        sigma = _f32(np.zeros(128))
        got = np.array(ei.expected_improvement(mu, sigma, 0.0))
        want = np.maximum(0.0 - np.array(mu), 0.0)
        np.testing.assert_allclose(got, want, atol=1e-6)

    def test_monotone_in_best(self):
        mu = _rand(128)
        sigma = _f32(np.abs(RNG.normal(size=128)) + 0.1)
        lo = np.array(ei.expected_improvement(mu, sigma, -1.0))
        hi = np.array(ei.expected_improvement(mu, sigma, 1.0))
        assert np.all(hi >= lo - 1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        tiles=st.integers(1, 4),
        best=st.floats(-3.0, 3.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_random(self, tiles, best, seed):
        rng = np.random.default_rng(seed)
        m = tiles * 128
        mu = _rand(m, rng=rng)
        sigma = _f32(np.abs(rng.normal(size=m)))
        got = ei.expected_improvement(mu, sigma, best)
        want = ref.ref_ei(mu, sigma, best)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-5)


# ---------------------------------------------------------------------------
# ISTA step
# ---------------------------------------------------------------------------


def _spd(d, rng):
    a = rng.normal(size=(d, d)).astype(np.float32)
    return a @ a.T / d


class TestIsta:
    def test_export_shape(self):
        d = shapes.D_FEAT
        gram = _f32(_spd(d, RNG))
        w, xty = _rand(d), _rand(d)
        got = ista.ista_step(w, gram, xty, 0.01, 0.05)
        want = ref.ref_ista_step(w, gram, xty, 0.01, 0.05)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)

    def test_soft_threshold_sparsifies(self):
        d = 320
        gram = _f32(np.eye(d, dtype=np.float32))
        w = _f32(np.zeros(d))
        xty = _rand(d, scale=0.01)
        # One step from zero with huge lambda must stay exactly zero.
        got = np.array(ista.ista_step(w, gram, xty, 1.0, 10.0))
        assert np.all(got == 0.0)

    def test_fixed_point_of_zero_gradient(self):
        # With gram = I, xty = w and lam = 0 the update is the identity.
        d = 320
        gram = _f32(np.eye(d, dtype=np.float32))
        w = _rand(d)
        got = np.array(ista.ista_step(w, gram, w, 1.0, 0.0))
        np.testing.assert_allclose(got, np.array(w), rtol=1e-5, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        step=st.floats(1e-4, 0.5),
        lam=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_random(self, step, lam, seed):
        rng = np.random.default_rng(seed)
        d = 320
        gram = _f32(_spd(d, rng))
        w, xty = _rand(d, rng=rng), _rand(d, rng=rng)
        got = ista.ista_step(w, gram, xty, step, lam)
        want = ref.ref_ista_step(w, gram, xty, step, lam)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-5)
