"""L2 model compositions vs ref.py oracles, on the exact export shapes, plus
masking/padding invariants that the rust runtime relies on."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model, shapes
from compile.kernels import ref

RNG = np.random.default_rng(99)
D, N, M, Z = shapes.D_FEAT, shapes.N_TRAIN, shapes.M_CAND, shapes.Z_ENS


def _f32(a):
    return jnp.asarray(np.asarray(a, dtype=np.float32))


def _problem(n_live, d_live, rng):
    """A smooth synthetic regression problem padded to export shapes."""
    x = np.zeros((N, D), dtype=np.float32)
    x[:n_live, :d_live] = rng.uniform(0, 1, size=(n_live, d_live))
    w_true = np.zeros(D, dtype=np.float32)
    w_true[:d_live] = rng.normal(size=d_live) * (rng.uniform(size=d_live) < 0.3)
    y = np.zeros(N, dtype=np.float32)
    y[:n_live] = x[:n_live] @ w_true + 0.01 * rng.normal(size=n_live)
    rm = (np.arange(N) < n_live).astype(np.float32)
    fm = (np.arange(D) < d_live).astype(np.float32)
    return _f32(x), _f32(y), _f32(rm), _f32(fm), w_true


class TestLrFit:
    def test_matches_ref(self):
        # Underdetermined system: weights are numerically ill-determined, so
        # compare the models' *predictions* (the quantity the pipeline uses),
        # not raw weights (model.lr_fit uses a hand-rolled pure-HLO Cholesky,
        # ref uses LAPACK).
        x, y, rm, fm, _ = _problem(120, 260, RNG)
        got = np.array(model.lr_fit(x, y, rm, fm, _f32([1e-3])))
        want = np.array(ref.ref_lr_fit(x, y, rm, fm, 1e-3))
        pa = np.array(x) @ got
        pb = np.array(x) @ want
        np.testing.assert_allclose(pa, pb, atol=5e-2)

    def test_padded_features_are_zero(self):
        x, y, rm, fm, _ = _problem(80, 150, RNG)
        w = np.array(model.lr_fit(x, y, rm, fm, _f32([1e-3])))
        assert np.all(w[150:] == 0.0)

    def test_recovers_clean_linear_model(self):
        rng = np.random.default_rng(7)
        x, y, rm, fm, w_true = _problem(200, 64, rng)
        w = np.array(model.lr_fit(x, y, rm, fm, _f32([1e-5])))
        pred = np.array(x[:200]) @ w
        np.testing.assert_allclose(pred, np.array(y[:200]), atol=0.15)

    def test_padding_rows_do_not_leak(self):
        """Garbage in padded rows must not change the fit."""
        rng = np.random.default_rng(3)
        x, y, rm, fm, _ = _problem(100, 200, rng)
        w1 = np.array(model.lr_fit(x, y, rm, fm, _f32([1e-3])))
        x2 = np.array(x)
        x2[100:] = rng.normal(size=(N - 100, D)) * 100.0
        y2 = np.array(y)
        y2[100:] = 1e6
        w2 = np.array(model.lr_fit(_f32(x2), _f32(y2), rm, fm, _f32([1e-3])))
        np.testing.assert_allclose(w1, w2, rtol=1e-4, atol=1e-5)


class TestLassoFit:
    def test_matches_ref(self):
        x, y, rm, fm, _ = _problem(150, 280, RNG)
        got = model.lasso_fit(x, y, rm, fm, _f32([0.01]))
        want = ref.ref_lasso_fit(x, y, rm, fm, 0.01)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_sparsity_increases_with_lambda(self):
        x, y, rm, fm, _ = _problem(180, 250, np.random.default_rng(5))
        nnz = []
        for lam in (1e-4, 1e-2, 1e-1):
            w = np.array(model.lasso_fit(x, y, rm, fm, _f32([lam])))
            nnz.append(int((np.abs(w) > 1e-7).sum()))
        assert nnz[0] >= nnz[1] >= nnz[2]

    def test_huge_lambda_gives_all_zero(self):
        x, y, rm, fm, _ = _problem(100, 100, np.random.default_rng(6))
        w = np.array(model.lasso_fit(x, y, rm, fm, _f32([1e4])))
        assert np.all(w == 0.0)

    def test_padded_features_are_zero(self):
        x, y, rm, fm, _ = _problem(100, 170, np.random.default_rng(8))
        w = np.array(model.lasso_fit(x, y, rm, fm, _f32([0.01])))
        assert np.all(w[170:] == 0.0)

    def test_selects_true_support_on_sparse_problem(self):
        rng = np.random.default_rng(11)
        n_live, d_live = 220, 120
        x = np.zeros((N, D), dtype=np.float32)
        x[:n_live, :d_live] = rng.uniform(-1, 1, size=(n_live, d_live))
        w_true = np.zeros(D, dtype=np.float32)
        support = rng.choice(d_live, size=8, replace=False)
        w_true[support] = rng.choice([-2.0, 2.0], size=8)
        y = np.zeros(N, dtype=np.float32)
        y[:n_live] = x[:n_live] @ w_true + 0.02 * rng.normal(size=n_live)
        rm = _f32((np.arange(N) < n_live).astype(np.float32))
        fm = _f32((np.arange(D) < d_live).astype(np.float32))
        w = np.array(model.lasso_fit(_f32(x), _f32(y), rm, fm, _f32([0.02])))
        picked = set(np.where(np.abs(w) > 1e-3)[0])
        assert set(support) <= picked
        # and it should not pick up everything
        assert len(picked) < d_live // 2


class TestGpEi:
    def _inputs(self, n_live, d_live, seed):
        rng = np.random.default_rng(seed)
        x, y, rm, fm, _ = _problem(n_live, d_live, rng)
        xc = np.zeros((M, D), dtype=np.float32)
        xc[:, :d_live] = rng.uniform(0, 1, size=(M, d_live))
        theta = np.array([2.0, 1.0, 0.01, float(np.array(y)[:n_live].min())],
                         dtype=np.float32)
        return x, y, rm, _f32(xc), fm, _f32(theta)

    def test_matches_ref(self):
        x, y, rm, xc, fm, theta = self._inputs(90, 260, 21)
        got = model.gp_ei(x, y, rm, xc, fm, theta)
        want = ref.ref_gp_ei(x, y, rm, xc, fm, float(theta[0]),
                             float(theta[1]), float(theta[2]),
                             float(theta[3]))
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-3, atol=1e-4)

    def test_posterior_interpolates_training_points(self):
        """With tiny noise, mu at a training input ~= its label."""
        rng = np.random.default_rng(31)
        n_live, d_live = 40, 50
        x = np.zeros((N, D), dtype=np.float32)
        x[:n_live, :d_live] = rng.uniform(0, 1, size=(n_live, d_live))
        y = np.zeros(N, dtype=np.float32)
        y[:n_live] = np.sin(x[:n_live, :d_live].sum(axis=1))
        rm = _f32((np.arange(N) < n_live).astype(np.float32))
        fm = _f32((np.arange(D) < d_live).astype(np.float32))
        xc = np.zeros((M, D), dtype=np.float32)
        xc[:n_live] = x[:n_live]
        theta = _f32(np.array([1.5, 1.0, 1e-4, float(y[:n_live].min())],
                              dtype=np.float32))
        ei_v, mu, sigma = model.gp_ei(_f32(x), _f32(y), rm, _f32(xc), fm,
                                      theta)
        np.testing.assert_allclose(np.array(mu)[:n_live], y[:n_live],
                                   atol=0.05)
        # posterior uncertainty at training points is ~ noise level
        assert np.all(np.array(sigma)[:n_live] < 0.1)

    def test_padding_rows_do_not_leak(self):
        x, y, rm, xc, fm, theta = self._inputs(60, 200, 41)
        got1 = model.gp_ei(x, y, rm, xc, fm, theta)
        x2, y2 = np.array(x), np.array(y)
        rng = np.random.default_rng(0)
        x2[60:] = rng.normal(size=(N - 60, D))
        y2[60:] = -1e3
        got2 = model.gp_ei(_f32(x2), _f32(y2), rm, xc, fm, theta)
        for a, b in zip(got1, got2):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    def test_ei_nonnegative_and_finite(self):
        x, y, rm, xc, fm, theta = self._inputs(100, 150, 51)
        ei_v, mu, sigma = model.gp_ei(x, y, rm, xc, fm, theta)
        assert np.all(np.isfinite(np.array(ei_v)))
        assert np.all(np.array(ei_v) >= -1e-6)
        assert np.all(np.array(sigma) > 0.0)

    @settings(max_examples=8, deadline=None)
    @given(n_live=st.integers(10, 200), d_live=st.integers(10, 300),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_random(self, n_live, d_live, seed):
        x, y, rm, xc, fm, theta = self._inputs(n_live, d_live, seed)
        got = model.gp_ei(x, y, rm, xc, fm, theta)
        want = ref.ref_gp_ei(x, y, rm, xc, fm, float(theta[0]),
                             float(theta[1]), float(theta[2]),
                             float(theta[3]))
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=5e-3, atol=5e-4)


class TestEmcmModel:
    def test_matches_ref_on_export_shapes(self):
        rng = np.random.default_rng(61)
        w_ens = _f32(rng.normal(size=(Z, D)))
        w0 = _f32(rng.normal(size=D))
        x = _f32(rng.normal(size=(M, D)))
        fm = _f32((np.arange(D) < 282).astype(np.float32))
        got = model.emcm_score(w_ens, w0, x, fm)
        want = ref.ref_emcm_score(w_ens, w0, x, fm)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
