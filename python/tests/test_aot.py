"""AOT export sanity: every artifact lowers, the HLO text parses the way the
rust runtime expects (ENTRY + parameters in declared order), and the manifest
matches shapes.py."""

import json
import os
import re

import pytest

from compile import aot, model, shapes

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _artifacts_present():
    return all(
        os.path.exists(os.path.join(ART_DIR, f"{n}.hlo.txt"))
        for n in shapes.ARTIFACTS
    )


@pytest.fixture(scope="module")
def artifacts():
    if not _artifacts_present():
        aot.export_all(ART_DIR)
    return ART_DIR


def test_export_specs_cover_all_artifacts():
    assert set(model.export_specs().keys()) == set(shapes.ARTIFACTS)


def test_manifest_matches_shapes(artifacts):
    with open(os.path.join(artifacts, "manifest.json")) as f:
        manifest = json.load(f)
    s = manifest["shapes"]
    assert s["d_feat"] == shapes.D_FEAT
    assert s["n_train"] == shapes.N_TRAIN
    assert s["m_cand"] == shapes.M_CAND
    assert s["z_ens"] == shapes.Z_ENS
    assert set(manifest["artifacts"].keys()) == set(shapes.ARTIFACTS)


@pytest.mark.parametrize("name", shapes.ARTIFACTS)
def test_hlo_text_structure(artifacts, name):
    path = os.path.join(artifacts, f"{name}.hlo.txt")
    text = open(path).read()
    assert "ENTRY" in text, "rust loader needs an ENTRY computation"
    # Parameter count must match the export spec arity.
    spec = model.export_specs()[name]
    entry = text[text.index("ENTRY"):]
    params = re.findall(r"parameter\((\d+)\)", entry)
    assert len(set(params)) == len(spec[1]), (
        f"{name}: {len(set(params))} params vs {len(spec[1])} spec args")
    # Tuple root (return_tuple=True) so rust unwraps with to_tuple.
    assert re.search(r"ROOT\s+\S+\s+=\s+\(", entry), "root must be a tuple"


@pytest.mark.parametrize("name", shapes.ARTIFACTS)
def test_no_custom_calls(artifacts, name):
    """interpret=True pallas must lower to plain HLO — a Mosaic custom-call
    would make the artifact unloadable on the CPU PJRT client."""
    text = open(os.path.join(artifacts, f"{name}.hlo.txt")).read()
    assert "custom-call" not in text or "mosaic" not in text.lower()


@pytest.mark.parametrize("name", shapes.ARTIFACTS)
def test_f32_only_interface(artifacts, name):
    """The rust runtime sends f32 literals only."""
    text = open(os.path.join(artifacts, f"{name}.hlo.txt")).read()
    entry = text[text.index("ENTRY"):]
    first_line = entry.splitlines()[0]
    assert "f64" not in first_line
