//! Surrogate benchmark, six scenarios behind one JSON writer:
//!
//! * `acquisition` — one-shot serial `gp_ei` (kernel rebuilt + O(n³)
//!   Cholesky + serial candidate scoring every iteration) vs the
//!   incremental surrogate session (cached Cholesky extended in place,
//!   candidates sharded over the exec pool in blocked solves).  Both
//!   paths replay the same observation/candidate streams and are
//!   asserted bit-identical before timing.
//! * `eviction` — an eviction-heavy loop at the N_TRAIN-style cap (one
//!   worst-point eviction per iteration): `HyperMode::Fixed` (O(n³)
//!   `cholesky_rebuild` per eviction) vs the O(n²) rank-1
//!   `cholesky_downdate` path, asserted equal within 1e-8 before timing.
//! * `adaptation` — the acquisition loop with marginal-likelihood
//!   hyper-parameter adaptation on vs off (overhead of the ascent
//!   rounds), reporting where the hypers moved.
//! * `ard` — isotropic-adapt vs ARD-adapt acquisition loops at
//!   d ∈ {8, 16}: the cost of freeing the per-dimension length-scales
//!   (d+1-parameter gradient + per-dimension distance cache) over the
//!   tied 2-parameter ascent, reporting the adapted length-scale spread.
//! * `batch` — whole-tuner constant-liar q-EI at q ∈ {1, 2, 4} over a
//!   bowl objective with a fixed slab of numeric work per evaluation:
//!   per-evaluation wall cost as the batch fan-out reclaims concurrency,
//!   with both the single-point and the batched path asserted
//!   bit-identical across pool widths before timing.
//! * `kernels` — `KernelPolicy::Scalar` vs `KernelPolicy::Blocked`
//!   acquisition loops at n ∈ {64, 128, 256}, d ∈ {8, 16}: the panel/lane
//!   multi-RHS solve tier against the bitwise-pinned scalar arithmetic.
//!   Before timing, the blocked EIs are asserted within 1e-8 of scalar
//!   and bit-identical across pool widths (the tier's two pins).
//!
//! Emits `BENCH_surrogate.json` at the repo root; `--smoke` runs reduced
//! sizes for CI and writes `BENCH_surrogate_smoke.json`.  Both files come
//! from the same writer ([`write_doc`]) and therefore always share the
//! same schema — after writing, the bench re-parses its own output and
//! asserts every [`SCENARIO_KEYS`] entry is present, so the committed
//! full-size file and the CI smoke file cannot drift apart silently (CI
//! re-asserts the keys on the smoke JSON with `jq`).
//!
//! Run with:  cargo bench --bench surrogate [-- --smoke]

#[path = "harness/mod.rs"]
mod harness;

use std::sync::Arc;

use harness::{section, Bench};
use onestoptuner::exec::{self, ExecPool};
use onestoptuner::flags::{FlagConfig, GcMode};
use onestoptuner::native::gp::GpSurrogate;
use onestoptuner::runtime::{
    one_shot_gp, GpConfig, GpSession, HyperMode, KernelPolicy, MlBackend, NativeBackend, N_TRAIN,
};
use onestoptuner::tuner::bo::BoConfig;
use onestoptuner::tuner::{BoTuner, EvalOutcome, Objective, TuneSpace, Tuner};
use onestoptuner::util::json::Json;
use onestoptuner::util::rng::Pcg;
use onestoptuner::util::stats::argmax;

/// Tuning-subspace dimension (lasso typically keeps 10-25 flags).
const D: usize = 16;

/// Scenario keys the output document must always carry — shared between
/// the builder and the post-write assertion so they cannot drift.
const SCENARIO_KEYS: [&str; 6] =
    ["acquisition", "eviction", "adaptation", "ard", "batch", "kernels"];

fn rand_rows(n: usize, d: usize, rng: &mut Pcg) -> Vec<Vec<f64>> {
    (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect()
}

/// One pre-generated loop: the initial design plus, per iteration, a
/// candidate pool and the observation appended afterwards.
struct Scenario {
    init_x: Vec<Vec<f64>>,
    init_y: Vec<f64>,
    iters: Vec<(Vec<Vec<f64>>, Vec<f64>, f64)>, // (candidates, next x, next y)
}

fn synth_y(x: &[f64]) -> f64 {
    (x[0] * 3.0).sin() + x[1] * x[2] - 0.5 * x[x.len() - 1]
}

fn scenario_d(d: usize, n0: usize, m: usize, iters: usize, seed: u64) -> Scenario {
    let mut rng = Pcg::new(seed);
    let init_x = rand_rows(n0, d, &mut rng);
    let init_y: Vec<f64> = init_x.iter().map(|r| synth_y(r)).collect();
    let iters = (0..iters)
        .map(|_| {
            let cands = rand_rows(m, d, &mut rng);
            let next: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
            let y = synth_y(&next);
            (cands, next, y)
        })
        .collect();
    Scenario { init_x, init_y, iters }
}

fn scenario(n0: usize, m: usize, iters: usize, seed: u64) -> Scenario {
    scenario_d(D, n0, m, iters, seed)
}

fn gp_cfg_d(d: usize, cap: usize, hyper: HyperMode) -> GpConfig {
    GpConfig::isotropic(d, 0.30 * (d as f64).sqrt(), 1.0, 0.01, cap, hyper)
}

fn gp_cfg(cap: usize, hyper: HyperMode) -> GpConfig {
    gp_cfg_d(D, cap, hyper)
}

/// Replay an append-only acquisition loop; returns the last iteration's
/// EI (the cross-check payload).
fn replay(gp: &mut dyn GpSession, epool: &ExecPool, sc: &Scenario) -> Vec<f64> {
    for (x, &y) in sc.init_x.iter().zip(&sc.init_y) {
        gp.observe(x, y).unwrap();
    }
    let mut best = sc.init_y.iter().cloned().fold(f64::INFINITY, f64::min);
    let mut last_ei = Vec::new();
    for (cands, next, y) in &sc.iters {
        let (ei, _, _) = gp.acquire(epool, cands, best).unwrap();
        last_ei = ei;
        gp.observe(next, *y).unwrap();
        best = best.min(*y);
    }
    last_ei
}

/// Replay an eviction-heavy loop: the session starts at its cap, so every
/// iteration evicts the worst point before observing the next one —
/// exactly the BO loop's behaviour past N_TRAIN.
fn replay_evict(gp: &mut dyn GpSession, epool: &ExecPool, sc: &Scenario) -> Vec<f64> {
    for (x, &y) in sc.init_x.iter().zip(&sc.init_y) {
        gp.observe(x, y).unwrap();
    }
    let mut best = sc.init_y.iter().cloned().fold(f64::INFINITY, f64::min);
    let mut last_ei = Vec::new();
    for (cands, next, y) in &sc.iters {
        gp.forget(argmax(gp.ys())).unwrap();
        let (ei, _, _) = gp.acquire(epool, cands, best).unwrap();
        last_ei = ei;
        gp.observe(next, *y).unwrap();
        best = best.min(*y);
    }
    last_ei
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// Bowl-shaped tuning objective with a fixed slab of numeric work per
/// evaluation, so the q-EI batch fan-out has real wall-clock to reclaim.
/// Each evaluation is a pure function of the configuration (no seed
/// stream), so the batch override is bit-identical at any pool width.
struct BusyBowl {
    space: TuneSpace,
    pool: ExecPool,
    count: usize,
    work: usize,
}

impl BusyBowl {
    fn eval_one(space: &TuneSpace, work: usize, cfg: &FlagConfig) -> EvalOutcome {
        let u = space.project(cfg);
        let mut acc = 0.0f64;
        for i in 0..work {
            acc = (acc + u[i % u.len()] + 1.0).sqrt();
        }
        let acc = std::hint::black_box(acc);
        let y = u.iter().map(|&x| (x - 0.7) * (x - 0.7)).sum::<f64>() + acc * 0.0;
        EvalOutcome { y, failure: None, attempts: 1 }
    }
}

impl Objective for BusyBowl {
    fn eval_outcome(&mut self, cfg: &FlagConfig) -> EvalOutcome {
        self.count += 1;
        Self::eval_one(&self.space, self.work, cfg)
    }

    fn eval_outcomes_batch(&mut self, cfgs: &[FlagConfig]) -> Vec<EvalOutcome> {
        let (space, work) = (&self.space, self.work);
        let outs = self.pool.par_map(cfgs, |_, cfg| Self::eval_one(space, work, cfg));
        self.count += outs.len();
        outs
    }

    fn evals(&self) -> usize {
        self.count
    }

    fn sim_time_s(&self) -> f64 {
        0.0
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let backend = NativeBackend;
    let epool = *exec::global();
    let serial = ExecPool::serial();
    let reps = if smoke { (1, 2) } else { (1, 3) };

    // ---- acquisition: one-shot vs incremental session ----------------
    let (ns, m, iters): (&[usize], usize, usize) =
        if smoke { (&[32, 64], 128, 4) } else { (&[64, 128, 256], 1024, 12) };
    let mut acq_rows = Vec::new();
    for &n in ns {
        assert!(n <= N_TRAIN);
        let cfg = gp_cfg(N_TRAIN, HyperMode::Fixed);
        let sc = scenario(n - iters, m, iters, 0x5eed ^ n as u64);

        // Cross-check: both paths must agree bitwise before we time them.
        let a = replay(&mut *one_shot_gp(&backend, &cfg), &serial, &sc);
        let b = replay(&mut *backend.gp_open(&cfg).unwrap(), &epool, &sc);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a), bits(&b), "one-shot and incremental EI diverged (n={n})");

        section(&format!("acquisition loop: {iters} iters ending at n={n}, m={m} candidates"));
        let one = Bench::new(format!("one_shot/{n}tr_{m}c/serial"))
            .iters(reps.0, reps.1)
            .run(|| replay(&mut *one_shot_gp(&backend, &cfg), &serial, &sc));
        let inc = Bench::new(format!("incremental/{n}tr_{m}c/pool{}", epool.threads()))
            .iters(reps.0, reps.1)
            .run(|| replay(&mut *backend.gp_open(&cfg).unwrap(), &epool, &sc));
        let speedup = one.mean_ns / inc.mean_ns;
        println!("  speedup: {speedup:.2}x");

        acq_rows.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("m", Json::num(m as f64)),
            ("iters", Json::num(iters as f64)),
            ("one_shot_ms", Json::num(one.mean_ns / 1e6)),
            ("incremental_ms", Json::num(inc.mean_ns / 1e6)),
            ("speedup", Json::num(speedup)),
        ]));
    }

    // ---- eviction-heavy: rebuild-per-eviction vs rank-1 downdate ------
    // Small candidate pools keep the factor maintenance (the thing under
    // test) dominant over acquisition scoring.
    let (ev_ns, ev_m, ev_iters): (&[usize], usize, usize) =
        if smoke { (&[32, 48], 32, 6) } else { (&[128, 256], 32, 16) };
    let mut ev_rows = Vec::new();
    for &n in ev_ns {
        let fixed_cfg = gp_cfg(n, HyperMode::Fixed);
        // Adaptation disabled (`every` unreachable): isolates the
        // downdate eviction path.
        let down_cfg = gp_cfg(n, HyperMode::Adapt { every: usize::MAX });
        let sc = scenario(n, ev_m, ev_iters, 0xe71c ^ n as u64);

        let a = replay_evict(&mut *backend.gp_open(&fixed_cfg).unwrap(), &epool, &sc);
        let b = replay_evict(&mut GpSurrogate::new(&down_cfg), &epool, &sc);
        let diff = max_abs_diff(&a, &b);
        assert!(diff <= 1e-8, "downdate diverged from rebuild: max |Δei| = {diff:e} (n={n})");

        section(&format!(
            "eviction-heavy loop: {ev_iters} evictions at cap n={n}, m={ev_m} candidates"
        ));
        let rebuild = Bench::new(format!("evict_rebuild/{n}tr_{ev_m}c"))
            .iters(reps.0, reps.1)
            .run(|| replay_evict(&mut *backend.gp_open(&fixed_cfg).unwrap(), &epool, &sc));
        let downdate = Bench::new(format!("evict_downdate/{n}tr_{ev_m}c"))
            .iters(reps.0, reps.1)
            .run(|| replay_evict(&mut GpSurrogate::new(&down_cfg), &epool, &sc));
        let speedup = rebuild.mean_ns / downdate.mean_ns;
        println!("  speedup: {speedup:.2}x  (max |Δei| = {diff:.2e})");

        ev_rows.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("m", Json::num(ev_m as f64)),
            ("iters", Json::num(ev_iters as f64)),
            ("rebuild_ms", Json::num(rebuild.mean_ns / 1e6)),
            ("downdate_ms", Json::num(downdate.mean_ns / 1e6)),
            ("speedup", Json::num(speedup)),
            ("max_abs_ei_diff", Json::num(diff)),
        ]));
    }

    // ---- adaptation on/off: overhead of the ascent rounds -------------
    let (ad_n, ad_m, ad_iters) = if smoke { (48, 64, 6) } else { (128, 256, 12) };
    let mut ad_rows = Vec::new();
    {
        let fixed_cfg = gp_cfg(N_TRAIN, HyperMode::Fixed);
        let adapt_cfg = gp_cfg(N_TRAIN, HyperMode::Adapt { every: 4 });
        let sc = scenario(ad_n - ad_iters, ad_m, ad_iters, 0xada7 ^ ad_n as u64);

        section(&format!(
            "adaptation on/off: {ad_iters} iters ending at n={ad_n}, m={ad_m} candidates"
        ));
        let fixed = Bench::new(format!("hypers_fixed/{ad_n}tr_{ad_m}c"))
            .iters(reps.0, reps.1)
            .run(|| replay(&mut *backend.gp_open(&fixed_cfg).unwrap(), &epool, &sc));
        let mut final_hypers = (adapt_cfg.lengthscales.clone(), adapt_cfg.sigma_n2);
        let adapt = Bench::new(format!("hypers_adapt/{ad_n}tr_{ad_m}c")).iters(reps.0, reps.1).run(
            || {
                let mut gp = GpSurrogate::new(&adapt_cfg);
                let ei = replay(&mut gp, &epool, &sc);
                final_hypers = gp.hypers();
                ei
            },
        );
        let overhead = adapt.mean_ns / fixed.mean_ns;
        println!(
            "  overhead: {overhead:.2}x  (lengthscale {:.3} -> {:.3}, noise {:.4} -> {:.4})",
            adapt_cfg.lengthscales[0], final_hypers.0[0], adapt_cfg.sigma_n2, final_hypers.1
        );

        ad_rows.push(Json::obj(vec![
            ("n", Json::num(ad_n as f64)),
            ("m", Json::num(ad_m as f64)),
            ("iters", Json::num(ad_iters as f64)),
            ("adapt_every", Json::num(4.0)),
            ("fixed_ms", Json::num(fixed.mean_ns / 1e6)),
            ("adapt_ms", Json::num(adapt.mean_ns / 1e6)),
            ("overhead", Json::num(overhead)),
            // ARD off: the length-scales move as one tied value.
            ("adapted_lengthscale", Json::num(final_hypers.0[0])),
            ("adapted_noise", Json::num(final_hypers.1)),
        ]));
    }

    // ---- ard: isotropic-adapt vs ARD-adapt acquisition cost -----------
    // Same adaptive loop, tied 2-parameter ascent vs the free
    // d+1-parameter one, across the tuning dimensions the lasso stage
    // typically leaves (d ∈ {8, 16}).
    let (ard_ds, ard_n, ard_m, ard_iters): (&[usize], usize, usize, usize) =
        if smoke { (&[8, 16], 32, 64, 4) } else { (&[8, 16], 96, 256, 10) };
    let mut ard_rows = Vec::new();
    for &d in ard_ds {
        let iso_cfg = GpConfig {
            hyper: HyperMode::Adapt { every: 4 },
            ..gp_cfg_d(d, N_TRAIN, HyperMode::Fixed)
        };
        let ard_cfg = GpConfig { ard: true, ..iso_cfg.clone() };
        let sc = scenario_d(d, ard_n - ard_iters, ard_m, ard_iters, 0xa4d ^ d as u64);

        section(&format!(
            "isotropic-adapt vs ARD-adapt: d={d}, {ard_iters} iters ending at n={ard_n}, m={ard_m} candidates"
        ));
        let iso = Bench::new(format!("adapt_iso/d{d}_{ard_n}tr_{ard_m}c"))
            .iters(reps.0, reps.1)
            .run(|| replay(&mut GpSurrogate::new(&iso_cfg), &epool, &sc));
        let mut ard_hypers = (ard_cfg.lengthscales.clone(), ard_cfg.sigma_n2);
        let ard = Bench::new(format!("adapt_ard/d{d}_{ard_n}tr_{ard_m}c"))
            .iters(reps.0, reps.1)
            .run(|| {
                let mut gp = GpSurrogate::new(&ard_cfg);
                let ei = replay(&mut gp, &epool, &sc);
                ard_hypers = gp.hypers();
                ei
            });
        let overhead = ard.mean_ns / iso.mean_ns;
        let (ls_min, ls_max) = ard_hypers
            .0
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &l| (lo.min(l), hi.max(l)));
        println!("  overhead: {overhead:.2}x  (adapted lengthscales {ls_min:.3}..{ls_max:.3})");

        ard_rows.push(Json::obj(vec![
            ("d", Json::num(d as f64)),
            ("n", Json::num(ard_n as f64)),
            ("m", Json::num(ard_m as f64)),
            ("iters", Json::num(ard_iters as f64)),
            ("adapt_every", Json::num(4.0)),
            ("iso_adapt_ms", Json::num(iso.mean_ns / 1e6)),
            ("ard_adapt_ms", Json::num(ard.mean_ns / 1e6)),
            ("overhead", Json::num(overhead)),
            ("adapted_lengthscale_min", Json::num(ls_min)),
            ("adapted_lengthscale_max", Json::num(ls_max)),
        ]));
    }

    // ---- batch: whole-tuner constant-liar q-EI at q ∈ {1, 2, 4} -------
    // Same iteration count per q, so q > 1 buys extra evaluations whose
    // wall cost the concurrent measurement round amortizes; reported as
    // per-evaluation milliseconds against the single-point baseline.
    let (bq_init, bq_cands, bq_iters, bq_work): (usize, usize, usize, usize) =
        if smoke { (4, 32, 4, 100_000) } else { (6, 64, 10, 1_000_000) };
    let mut batch_rows = Vec::new();
    {
        let mut space = TuneSpace::full(GcMode::G1GC);
        space.selected.truncate(8);
        let run = |q: usize, pool: ExecPool| {
            let mut obj = BusyBowl { space: space.clone(), pool, count: 0, work: bq_work };
            let mut bo = BoTuner::new(
                Arc::new(NativeBackend),
                BoConfig {
                    n_init: bq_init,
                    n_candidates: bq_cands,
                    batch_q: q,
                    epool: pool,
                    ..Default::default()
                },
            );
            bo.tune(&space, &mut obj, bq_iters).unwrap()
        };

        // Cross-check: the single-point path and the batched path must
        // both be bit-identical across pool widths before we time them.
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for q in [1usize, 4] {
            let a = run(q, serial);
            let b = run(q, epool);
            assert_eq!(
                bits(&a.best_history),
                bits(&b.best_history),
                "q={q} tune diverged across pool widths"
            );
        }

        section(&format!(
            "q-EI batch tuning: q ∈ {{1, 2, 4}}, {bq_iters} iters after {bq_init} init points"
        ));
        let mut q1_per_eval_ms = f64::NAN;
        for q in [1usize, 2, 4] {
            let evals = bq_init + q * bq_iters;
            let mut best_y = f64::NAN;
            let b = Bench::new(format!("batch_q{q}/{bq_init}init_{bq_iters}it/pool{}", epool.threads()))
                .iters(reps.0, reps.1)
                .run(|| {
                    let r = run(q, epool);
                    best_y = r.best_y;
                    r.best_history
                });
            let per_eval_ms = b.mean_ns / 1e6 / evals as f64;
            if q == 1 {
                q1_per_eval_ms = per_eval_ms;
            }
            let speedup = q1_per_eval_ms / per_eval_ms;
            println!("  q={q}: best_y={best_y:.4}, {per_eval_ms:.2} ms/eval ({speedup:.2}x vs q=1)");

            batch_rows.push(Json::obj(vec![
                ("q", Json::num(q as f64)),
                ("iters", Json::num(bq_iters as f64)),
                ("evals", Json::num(evals as f64)),
                ("eval_rounds", Json::num((bq_init + bq_iters) as f64)),
                ("best_y", Json::num(best_y)),
                ("wall_ms", Json::num(b.mean_ns / 1e6)),
                ("per_eval_ms", Json::num(per_eval_ms)),
                ("per_eval_speedup_vs_q1", Json::num(speedup)),
            ]));
        }
    }

    // ---- kernels: Scalar vs Blocked linear-algebra tier ---------------
    // Pure acquisition loops (Fixed hypers, no evictions): the multi-RHS
    // solve and kernel-row evaluation are the knobs under test.
    let (kr_ds, kr_ns, kr_m, kr_iters): (&[usize], &[usize], usize, usize) =
        if smoke { (&[8, 16], &[24, 48], 96, 3) } else { (&[8, 16], &[64, 128, 256], 512, 8) };
    let mut kr_rows = Vec::new();
    for &d in kr_ds {
        for &n in kr_ns {
            let scalar_cfg = gp_cfg_d(d, N_TRAIN, HyperMode::Fixed);
            let mut blocked_cfg = scalar_cfg.clone();
            blocked_cfg.kernels = KernelPolicy::Blocked;
            let sc = scenario_d(d, n - kr_iters, kr_m, kr_iters, 0x5e7 ^ (d * 1000 + n) as u64);

            // Pin 1: blocked tracks scalar within 1e-8.
            let a = replay(&mut GpSurrogate::new(&scalar_cfg), &epool, &sc);
            let b = replay(&mut GpSurrogate::new(&blocked_cfg), &epool, &sc);
            let diff = max_abs_diff(&a, &b);
            assert!(
                diff <= 1e-8,
                "blocked diverged from scalar: max |Δei| = {diff:e} (d={d}, n={n})"
            );
            // Pin 2: blocked is bitwise pool-width invariant.
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            let b_serial = replay(&mut GpSurrogate::new(&blocked_cfg), &serial, &sc);
            assert_eq!(
                bits(&b),
                bits(&b_serial),
                "blocked EI diverged across pool widths (d={d}, n={n})"
            );

            section(&format!(
                "kernel tier: d={d}, {kr_iters} iters ending at n={n}, m={kr_m} candidates"
            ));
            let scalar = Bench::new(format!("kernels_scalar/d{d}_{n}tr_{kr_m}c"))
                .iters(reps.0, reps.1)
                .run(|| replay(&mut GpSurrogate::new(&scalar_cfg), &epool, &sc));
            let blocked = Bench::new(format!("kernels_blocked/d{d}_{n}tr_{kr_m}c"))
                .iters(reps.0, reps.1)
                .run(|| replay(&mut GpSurrogate::new(&blocked_cfg), &epool, &sc));
            let speedup = scalar.mean_ns / blocked.mean_ns;
            println!("  speedup: {speedup:.2}x  (max |Δei| = {diff:.2e})");

            kr_rows.push(Json::obj(vec![
                ("d", Json::num(d as f64)),
                ("n", Json::num(n as f64)),
                ("m", Json::num(kr_m as f64)),
                ("iters", Json::num(kr_iters as f64)),
                ("scalar_ms", Json::num(scalar.mean_ns / 1e6)),
                ("blocked_ms", Json::num(blocked.mean_ns / 1e6)),
                ("speedup", Json::num(speedup)),
                ("max_abs_ei_diff", Json::num(diff)),
            ]));
        }
    }

    let path = write_doc(
        smoke,
        epool.threads(),
        [acq_rows, ev_rows, ad_rows, ard_rows, batch_rows, kr_rows],
    );
    println!("\nwrote {path}");
}

/// The single writer both output files go through: the scenario keys come
/// from [`SCENARIO_KEYS`], and the written file is parsed back and
/// re-checked against the same constant, so the full-size and smoke
/// documents cannot diverge in shape.
fn write_doc(smoke: bool, threads: usize, rows: [Vec<Json>; 6]) -> &'static str {
    let scenarios: Vec<(&str, Json)> =
        SCENARIO_KEYS.iter().zip(rows).map(|(&k, r)| (k, Json::Arr(r))).collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("surrogate")),
        ("threads", Json::num(threads as f64)),
        ("smoke", Json::Bool(smoke)),
        ("scenarios", Json::obj(scenarios)),
    ]);
    // Smoke runs (reduced sizes) go to a sibling file so they never
    // clobber full-size acceptance numbers at the repo root.
    let path = if smoke {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_surrogate_smoke.json")
    } else {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_surrogate.json")
    };
    std::fs::write(path, format!("{doc}\n")).expect("write surrogate bench json");
    let back = Json::parse(&std::fs::read_to_string(path).expect("re-read bench json"))
        .expect("bench json must parse back");
    let sc = back.get("scenarios").expect("bench json must carry 'scenarios'");
    for key in SCENARIO_KEYS {
        assert!(sc.get(key).is_some(), "bench json lost scenario key '{key}'");
    }
    path
}
