//! Acquisition-loop benchmark: one-shot serial `gp_ei` (kernel rebuilt +
//! O(n³) Cholesky + serial candidate scoring every iteration) vs the
//! incremental surrogate session (cached Cholesky extended in place,
//! candidates sharded over the exec pool in blocked solves).  Both paths
//! replay the same observation/candidate streams and are asserted
//! bit-identical before timing.
//!
//! Emits `BENCH_surrogate.json` at the repo root.  `--smoke` runs reduced
//! sizes for CI.
//!
//! Run with:  cargo bench --bench surrogate [-- --smoke]

#[path = "harness/mod.rs"]
mod harness;

use harness::{section, Bench};
use onestoptuner::exec::{self, ExecPool};
use onestoptuner::runtime::{one_shot_gp, GpConfig, GpSession, MlBackend, NativeBackend, N_TRAIN};
use onestoptuner::util::json::Json;
use onestoptuner::util::rng::Pcg;

/// Tuning-subspace dimension (lasso typically keeps 10-25 flags).
const D: usize = 16;

fn rand_rows(n: usize, d: usize, rng: &mut Pcg) -> Vec<Vec<f64>> {
    (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect()
}

/// One pre-generated acquisition loop: the initial design plus, per
/// iteration, a candidate pool and the observation appended afterwards.
struct Scenario {
    init_x: Vec<Vec<f64>>,
    init_y: Vec<f64>,
    iters: Vec<(Vec<Vec<f64>>, Vec<f64>, f64)>, // (candidates, next x, next y)
}

fn synth_y(x: &[f64]) -> f64 {
    (x[0] * 3.0).sin() + x[1] * x[2] - 0.5 * x[D - 1]
}

fn scenario(n_final: usize, m: usize, iters: usize, seed: u64) -> Scenario {
    let mut rng = Pcg::new(seed);
    let n0 = n_final - iters;
    let init_x = rand_rows(n0, D, &mut rng);
    let init_y: Vec<f64> = init_x.iter().map(|r| synth_y(r)).collect();
    let iters = (0..iters)
        .map(|_| {
            let cands = rand_rows(m, D, &mut rng);
            let next: Vec<f64> = (0..D).map(|_| rng.f64()).collect();
            let y = synth_y(&next);
            (cands, next, y)
        })
        .collect();
    Scenario { init_x, init_y, iters }
}

/// Replay the whole loop on a session; returns the last iteration's EI
/// (the cross-check payload).
fn replay(mut gp: Box<dyn GpSession + '_>, epool: &ExecPool, sc: &Scenario) -> Vec<f64> {
    for (x, &y) in sc.init_x.iter().zip(&sc.init_y) {
        gp.observe(x, y).unwrap();
    }
    let mut best = sc.init_y.iter().cloned().fold(f64::INFINITY, f64::min);
    let mut last_ei = Vec::new();
    for (cands, next, y) in &sc.iters {
        let (ei, _, _) = gp.acquire(epool, cands, best).unwrap();
        last_ei = ei;
        gp.observe(next, *y).unwrap();
        best = best.min(*y);
    }
    last_ei
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (ns, m, iters): (&[usize], usize, usize) =
        if smoke { (&[32, 64], 128, 4) } else { (&[64, 128, 256], 1024, 12) };

    let backend = NativeBackend;
    let epool = *exec::global();
    let serial = ExecPool::serial();
    let mut rows = Vec::new();

    for &n in ns {
        assert!(n <= N_TRAIN);
        let cfg = GpConfig {
            dim: D,
            lengthscale: 0.30 * (D as f64).sqrt(),
            sigma_f2: 1.0,
            sigma_n2: 0.01,
            cap: N_TRAIN,
        };
        let sc = scenario(n, m, iters, 0x5eed ^ n as u64);

        // Cross-check: both paths must agree bitwise before we time them.
        let a = replay(one_shot_gp(&backend, &cfg), &serial, &sc);
        let b = replay(backend.gp_open(&cfg).unwrap(), &epool, &sc);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a), bits(&b), "one-shot and incremental EI diverged (n={n})");

        section(&format!("acquisition loop: {iters} iters ending at n={n}, m={m} candidates"));
        let one = Bench::new(format!("one_shot/{n}tr_{m}c/serial"))
            .iters(1, if smoke { 2 } else { 3 })
            .run(|| replay(one_shot_gp(&backend, &cfg), &serial, &sc));
        let inc = Bench::new(format!("incremental/{n}tr_{m}c/pool{}", epool.threads()))
            .iters(1, if smoke { 2 } else { 3 })
            .run(|| replay(backend.gp_open(&cfg).unwrap(), &epool, &sc));
        let speedup = one.mean_ns / inc.mean_ns;
        println!("  speedup: {speedup:.2}x");

        rows.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("m", Json::num(m as f64)),
            ("iters", Json::num(iters as f64)),
            ("one_shot_ms", Json::num(one.mean_ns / 1e6)),
            ("incremental_ms", Json::num(inc.mean_ns / 1e6)),
            ("speedup", Json::num(speedup)),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("surrogate_acquisition")),
        ("threads", Json::num(epool.threads() as f64)),
        ("smoke", Json::Bool(smoke)),
        ("results", Json::Arr(rows)),
    ]);
    // Smoke runs (reduced sizes) go to a sibling file so they never
    // clobber full-size acceptance numbers at the repo root.
    let path = if smoke {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_surrogate_smoke.json")
    } else {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_surrogate.json")
    };
    std::fs::write(path, format!("{doc}\n")).expect("write surrogate bench json");
    println!("\nwrote {path}");
}
