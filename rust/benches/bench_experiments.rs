//! End-to-end experiment benchmarks: wall time to regenerate each paper
//! artifact at `--fast` budget (one per table/figure — the paper's own
//! "time to tune" Section V-C is reported inside table3/timing output).

#[path = "harness/mod.rs"]
mod harness;

use std::sync::Arc;

use harness::{section, Bench};
use onestoptuner::pipeline::experiments::{
    run_fig4, run_fig5, run_fig6, run_heap_usage, run_table2, ExperimentCtx,
};
use onestoptuner::runtime::{engine::XlaEngine, MlBackend, NativeBackend};

fn ctx() -> ExperimentCtx {
    let backend: Arc<dyn MlBackend> = match XlaEngine::load("artifacts") {
        Ok(e) => Arc::new(e),
        Err(_) => Arc::new(NativeBackend),
    };
    let dir = std::env::temp_dir().join("ost_bench_experiments");
    let mut c = ExperimentCtx::new(backend, dir).fast();
    // trim further: benches measure wall cost, not statistical quality
    c.cfg.datagen.pool_size = 120;
    c.cfg.datagen.max_rounds = 2;
    c.cfg.tune_iters = 5;
    c.cfg.repeats = 3;
    c
}

fn main() {
    let ctx = ctx();
    println!("(backend: {})", ctx.backend.name());

    section("paper-artifact regeneration wall time (fast budget)");
    Bench::new("repro/table2").iters(0, 2).run(|| run_table2(&ctx).unwrap());
    Bench::new("repro/table4+fig7").iters(0, 1).run(|| run_heap_usage(&ctx).unwrap());
    Bench::new("repro/fig4").iters(0, 2).run(|| run_fig4(&ctx).unwrap());
    Bench::new("repro/fig5").iters(0, 2).run(|| run_fig5(&ctx).unwrap());
    Bench::new("repro/fig6").iters(0, 1).run(|| run_fig6(&ctx).unwrap());
    println!("\n(table3/fig3/timing share the exec-time pipeline; see bench_tuners for its parts)");
}
