//! ML-operation benchmarks: each L2 artifact through PJRT (xla backend)
//! vs the pure-rust native mirror, at the pipeline's production shapes.
//! This is the L1/L2-vs-L3 comparison the perf pass optimizes (see
//! EXPERIMENTS.md §Perf).

#[path = "harness/mod.rs"]
mod harness;

use std::sync::Arc;

use harness::{section, Bench};
use onestoptuner::runtime::{engine::XlaEngine, MlBackend, NativeBackend, Z_ENS};
use onestoptuner::util::rng::Pcg;

fn rand_rows(n: usize, d: usize, rng: &mut Pcg) -> Vec<Vec<f64>> {
    (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect()
}

fn main() {
    let mut rng = Pcg::new(1);
    let backends: Vec<Arc<dyn MlBackend>> = {
        let mut v: Vec<Arc<dyn MlBackend>> = vec![Arc::new(NativeBackend)];
        match XlaEngine::load("artifacts") {
            Ok(e) => v.push(Arc::new(e)),
            Err(e) => eprintln!("(xla backend unavailable: {e:#}; native only)"),
        }
        v
    };

    // Production shapes: G1 group features d=241, AL pool chunk 512,
    // GP with ~120 training points and 1024 candidates.
    let d = 241;

    section("emcm_score: AL pool scoring (M=512 chunk, paper Algorithm 1)");
    let w_ens: Vec<Vec<f64>> = (0..Z_ENS).map(|_| (0..d).map(|_| rng.normal() * 0.2).collect()).collect();
    let w0: Vec<f64> = (0..d).map(|_| rng.normal() * 0.2).collect();
    let pool = rand_rows(512, d, &mut rng);
    for b in &backends {
        Bench::new(format!("emcm_score/512x{d}/{}", b.name()))
            .run_throughput(512.0, "cand", || b.emcm_score(&w_ens, &w0, &pool).unwrap());
    }

    section("lr_fit: ridge LR (N=224, the AL model refit)");
    let x = rand_rows(224, d, &mut rng);
    let y: Vec<f64> = x.iter().map(|r| r.iter().sum::<f64>() / d as f64).collect();
    for b in &backends {
        Bench::new(format!("lr_fit/224x{d}/{}", b.name()))
            .run(|| b.lr_fit(&x, &y, 1e-3).unwrap());
    }

    section("lasso_fit: 400 ISTA iterations (phase 2)");
    for b in &backends {
        Bench::new(format!("lasso_fit/224x{d}/{}", b.name()))
            .iters(2, 6)
            .run(|| b.lasso_fit(&x, &y, 0.01).unwrap());
    }

    section("gp_ei: GP posterior + EI (N=120 train, M=1024 candidates)");
    let ls = vec![4.0; d];
    let xtr = rand_rows(120, d, &mut rng);
    let ytr: Vec<f64> = xtr.iter().map(|r| r.iter().sum::<f64>() / d as f64).collect();
    let xc = rand_rows(1024, d, &mut rng);
    for b in &backends {
        Bench::new(format!("gp_ei/120tr_1024c/{}", b.name()))
            .iters(2, 8)
            .run_throughput(1024.0, "cand", || {
                b.gp_ei(&xtr, &ytr, &xc, &ls, 1.0, 0.01, 0.0).unwrap()
            });
    }

    section("gp_ei scaling in training-set size (BO iteration cost)");
    for n in [32usize, 64, 128, 250] {
        let xtr = rand_rows(n, d, &mut rng);
        let ytr: Vec<f64> = xtr.iter().map(|r| r.iter().sum::<f64>() / d as f64).collect();
        let xc = rand_rows(512, d, &mut rng);
        for b in &backends {
            Bench::new(format!("gp_ei/{n}tr_512c/{}", b.name()))
                .iters(2, 6)
                .run(|| b.gp_ei(&xtr, &ytr, &xc, &ls, 1.0, 0.01, 0.0).unwrap());
        }
    }
}
