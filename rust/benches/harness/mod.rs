//! Minimal criterion-style bench harness (the offline image has no
//! criterion crate): warmup, timed iterations, mean/std/min, ns/iter and
//! throughput reporting.  Used by every `cargo bench` target.

use std::time::Instant;

#[allow(dead_code)]
pub struct Bench {
    pub name: String,
    warmup_iters: usize,
    measure_iters: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub iters: usize,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Bench {
        Bench { name: name.into(), warmup_iters: 3, measure_iters: 12 }
    }

    pub fn iters(mut self, warmup: usize, measure: usize) -> Bench {
        self.warmup_iters = warmup;
        self.measure_iters = measure;
        self
    }

    /// Time `f`, which performs one logical operation per call.
    pub fn run<R>(&self, mut f: impl FnMut() -> R) -> Sample {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_nanos() as f64);
        }
        let n = times.len() as f64;
        let mean = times.iter().sum::<f64>() / n;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let s = Sample { mean_ns: mean, std_ns: var.sqrt(), min_ns: min, iters: self.measure_iters };
        self.report(&s, None);
        s
    }

    /// Like `run`, but reports `units` of work per call as throughput.
    #[allow(dead_code)]
    pub fn run_throughput<R>(&self, units: f64, unit_name: &str, mut f: impl FnMut() -> R) -> Sample {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_nanos() as f64);
        }
        let n = times.len() as f64;
        let mean = times.iter().sum::<f64>() / n;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let s = Sample { mean_ns: mean, std_ns: var.sqrt(), min_ns: min, iters: self.measure_iters };
        self.report(&s, Some((units, unit_name)));
        s
    }

    fn report(&self, s: &Sample, throughput: Option<(f64, &str)>) {
        let fmt = |ns: f64| -> String {
            if ns >= 1e9 {
                format!("{:.2} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.2} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.2} us", ns / 1e3)
            } else {
                format!("{ns:.0} ns")
            }
        };
        let mut line = format!(
            "{:<48} {:>10}/iter (+- {:>9}, min {:>10}, n={})",
            self.name,
            fmt(s.mean_ns),
            fmt(s.std_ns),
            fmt(s.min_ns),
            s.iters
        );
        if let Some((units, name)) = throughput {
            let per_s = units / (s.mean_ns / 1e9);
            line.push_str(&format!("   {per_s:>12.1} {name}/s"));
        }
        println!("{line}");
    }
}

pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
