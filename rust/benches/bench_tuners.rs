//! Tuner-loop benchmarks: optimizer overhead per iteration (excluding the
//! benchmark runs the paper's §V-C timing is dominated by) and full small
//! tuning loops per algorithm — the L3 perf-pass targets.

#[path = "harness/mod.rs"]
mod harness;

use std::sync::Arc;

use harness::{section, Bench};
use onestoptuner::datagen::{characterize, DataGenConfig, Strategy};
use onestoptuner::featsel::select_flags;
use onestoptuner::flags::GcMode;
use onestoptuner::runtime::{engine::XlaEngine, MlBackend, NativeBackend};
use onestoptuner::sparksim::SparkRunner;
use onestoptuner::tuner::{
    bo::BoConfig, sa::SaConfig, BoTuner, EvalOutcome, Objective, RboTuner, SaTuner,
    SimObjective, TuneSpace, Tuner,
};
use onestoptuner::{Benchmark, Metric};

/// Free objective to isolate optimizer overhead from simulator time.
struct FreeObjective {
    space: TuneSpace,
    count: usize,
}

impl Objective for FreeObjective {
    fn eval_outcome(&mut self, cfg: &onestoptuner::FlagConfig) -> EvalOutcome {
        self.count += 1;
        let u = self.space.project(cfg);
        let y = u.iter().map(|&x| (x - 0.6) * (x - 0.6)).sum();
        EvalOutcome { y, failure: None, attempts: 1 }
    }
    fn evals(&self) -> usize {
        self.count
    }
    fn sim_time_s(&self) -> f64 {
        0.0
    }
}

fn main() {
    let backend: Arc<dyn MlBackend> = match XlaEngine::load("artifacts") {
        Ok(e) => Arc::new(e),
        Err(_) => Arc::new(NativeBackend),
    };
    println!("(backend: {})", backend.name());

    // A realistic tuning problem: characterize DK/ParallelGC, select flags.
    let runner = SparkRunner::paper_default(Benchmark::DenseKMeans);
    let dg = DataGenConfig {
        pool_size: 200,
        seed_runs: 24,
        test_runs: 10,
        batch_k: 16,
        max_rounds: 4,
        rmse_rel_tol: 0.0,
        ridge: 1e-3,
        seed: 9,
    };
    let ch = characterize(
        &runner,
        GcMode::ParallelGC,
        Metric::ExecTime,
        Strategy::Bemcm,
        &dg,
        &backend,
    )
    .unwrap();
    let sel = select_flags(&ch.dataset, 0.01, &backend).unwrap();
    let space = TuneSpace::from_selection(GcMode::ParallelGC, &sel);
    println!("(tuning space: {} of {} flags)", space.dim(), sel.group_size);

    section("optimizer overhead per 10 iterations (objective cost = 0)");
    Bench::new("bo/10iters/overhead").iters(2, 8).run(|| {
        let mut obj = FreeObjective { space: space.clone(), count: 0 };
        let mut t = BoTuner::new(backend.clone(), BoConfig { n_init: 4, ..Default::default() });
        t.tune(&space, &mut obj, 10).unwrap()
    });
    Bench::new("bo_warm/10iters/overhead").iters(2, 8).run(|| {
        let mut obj = FreeObjective { space: space.clone(), count: 0 };
        let mut t = BoTuner::warm_start(backend.clone(), BoConfig::default(), &space, &ch.dataset);
        t.tune(&space, &mut obj, 10).unwrap()
    });
    Bench::new("rbo/10iters/overhead").iters(2, 8).run(|| {
        let mut obj = FreeObjective { space: space.clone(), count: 0 };
        let mut t = RboTuner::new(backend.clone(), BoConfig::default(), ch.dataset.clone());
        t.tune(&space, &mut obj, 10).unwrap()
    });
    Bench::new("sa/10iters/overhead").iters(2, 8).run(|| {
        let mut obj = FreeObjective { space: space.clone(), count: 0 };
        let mut t = SaTuner::new(SaConfig::default());
        t.tune(&space, &mut obj, 10).unwrap()
    });

    section("full tuning loop incl. simulated runs (8 iterations)");
    Bench::new("bo/8iters/full").iters(1, 4).run(|| {
        let mut obj = SimObjective::new(&runner, Metric::ExecTime, 3);
        let mut t = BoTuner::new(backend.clone(), BoConfig { n_init: 4, ..Default::default() });
        t.tune(&space, &mut obj, 8).unwrap()
    });
    Bench::new("sa/8iters/full").iters(1, 4).run(|| {
        let mut obj = SimObjective::new(&runner, Metric::ExecTime, 3);
        let mut t = SaTuner::new(SaConfig::default());
        t.tune(&space, &mut obj, 8).unwrap()
    });

    section("phase 1: one BEMCM AL round (fit ensemble + score pool)");
    Bench::new("characterize/4rounds_200pool").iters(1, 3).run(|| {
        characterize(
            &runner,
            GcMode::ParallelGC,
            Metric::ExecTime,
            Strategy::Bemcm,
            &dg,
            &backend,
        )
        .unwrap()
    });

    section("phase 2: lasso selection");
    Bench::new("select_flags/lambda0.01").iters(2, 6).run(|| {
        select_flags(&ch.dataset, 0.01, &backend).unwrap()
    });
}
