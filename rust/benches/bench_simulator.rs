//! Benchmarks of the simulated testbed (the objective function Q): single
//! JVM runs, full Spark jobs, parallel jobs — per (benchmark x GC mode).
//! The simulator is the pipeline's hot path (hundreds of runs per phase-1
//! characterization), so runs/s here bounds end-to-end tuning throughput.

#[path = "harness/mod.rs"]
mod harness;

use harness::{section, Bench};
use onestoptuner::flags::{FlagConfig, GcMode};
use onestoptuner::jvmsim::{self, JvmParams};
use onestoptuner::sparksim::{run_parallel, ClusterSpec, ExecutorSpec, SparkRunner};
use onestoptuner::util::rng::Pcg;
use onestoptuner::Benchmark;

fn main() {
    section("jvmsim: single-executor JVM run");
    for mode in [GcMode::ParallelGC, GcMode::G1GC] {
        for bench in Benchmark::all() {
            let cfg = FlagConfig::default_for(mode);
            let p = JvmParams::derive(&cfg, 81920.0, 20.0);
            let load = bench.executor_load(3);
            let mut seed = 0u64;
            Bench::new(format!("jvm_run/{}/{}", bench.name(), mode.name()))
                .iters(5, 30)
                .run(|| {
                    seed += 1;
                    jvmsim::run(&p, &load, 20.0, &mut Pcg::new(seed))
                });
        }
    }

    section("sparksim: full 3-executor job (the tuner's objective)");
    for mode in [GcMode::ParallelGC, GcMode::G1GC] {
        for bench in Benchmark::all() {
            let runner = SparkRunner::paper_default(bench);
            let cfg = FlagConfig::default_for(mode);
            let mut seed = 0u64;
            Bench::new(format!("spark_run/{}/{}", bench.name(), mode.name()))
                .iters(5, 30)
                .run_throughput(1.0, "runs", || {
                    seed += 1;
                    runner.run(&cfg, seed)
                });
        }
    }

    section("sparksim: parallel two-job contention (Fig 6 setting)");
    let cluster = ClusterSpec::paper();
    let cfg = FlagConfig::default_for(GcMode::G1GC);
    let jobs = vec![
        (Benchmark::Lda, cfg.clone(), ExecutorSpec::parallel_2x15()),
        (Benchmark::DenseKMeans, cfg.clone(), ExecutorSpec::parallel_2x15()),
    ];
    let mut seed = 0u64;
    Bench::new("spark_parallel/lda+dk/G1GC").iters(5, 20).run(|| {
        seed += 1;
        run_parallel(&cluster, &jobs, seed)
    });

    section("flags: config plumbing");
    let mut rng = Pcg::new(7);
    let enc = onestoptuner::FeatureEncoder::new(GcMode::G1GC);
    Bench::new("flag_config/random+encode/G1GC").iters(10, 50).run(|| {
        let c = FlagConfig::random(GcMode::G1GC, &mut rng);
        enc.encode(&c)
    });
    Bench::new("jvm_params/derive/G1GC").iters(10, 50).run(|| {
        JvmParams::derive(&FlagConfig::default_for(GcMode::G1GC), 81920.0, 20.0)
    });
}
