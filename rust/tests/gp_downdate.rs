//! Differential suite for the GP session's Adapt-mode fast paths: the
//! O(n²) rank-1 Cholesky downdate eviction and marginal-likelihood
//! hyper-parameter adaptation.
//!
//! # Tolerance policy
//!
//! * `HyperMode::Fixed` is **bitwise** pinned to the one-shot `gp_ei`
//!   reference, including across evictions (`tests/gp_incremental.rs`).
//! * The downdate eviction path (`HyperMode::Adapt`, adaptation idle)
//!   rotates the cached factor instead of refactoring, so its factor
//!   differs from a rebuild in low-order bits: predictions (ei, mu,
//!   sigma) are pinned to the rebuild path within `TOL = 1e-8`
//!   (absolute + relative), across eviction positions, repeated
//!   evictions, and pool widths 1/2/8.
//! * Once adaptation actually fires, Adapt *intentionally* diverges from
//!   the fixed-hyper reference (it is a different, better-fitting
//!   model); what is pinned instead is (a) the marginal-likelihood trace
//!   is non-decreasing per accepted step, (b) the committed kernel +
//!   factor are bitwise what a scratch session at the adapted
//!   hyper-parameters would build, and (c) hypers stay inside their
//!   documented box.

use onestoptuner::exec::ExecPool;
use onestoptuner::native::gp::GpSurrogate;
use onestoptuner::runtime::{GpConfig, GpSession, HyperMode, MlBackend, NativeBackend};
use onestoptuner::util::rng::Pcg;
use onestoptuner::util::stats::argmax;

const TOL: f64 = 1e-8;

fn rand_rows(n: usize, d: usize, rng: &mut Pcg) -> Vec<Vec<f64>> {
    (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect()
}

fn cfg(d: usize, cap: usize, hyper: HyperMode) -> GpConfig {
    GpConfig::isotropic(d, 0.7, 1.0, 0.01, cap, hyper)
}

/// Adapt-mode config whose adaptation never triggers: isolates the
/// downdate eviction path.
fn downdate_only(d: usize, cap: usize) -> GpConfig {
    cfg(d, cap, HyperMode::Adapt { every: usize::MAX })
}

fn assert_close(a: &[f64], b: &[f64], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.is_finite(), "{tag}[{i}] not finite: {x}");
        assert!(
            (x - y).abs() <= TOL * (1.0 + y.abs()),
            "{tag}[{i}]: {x} vs {y} (|Δ| = {:e})",
            (x - y).abs()
        );
    }
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Single evictions at the buffer edges and in the middle: downdate
/// predictions match a rebuild (Fixed session over the same history)
/// within TOL, at pool widths 1, 2 and 8.
#[test]
fn downdate_then_predict_matches_rebuild_then_predict() {
    let backend = NativeBackend;
    let d = 5;
    let mut rng = Pcg::new(0xdd01);
    let xs = rand_rows(26, d, &mut rng);
    let ys: Vec<f64> = xs.iter().map(|r| (r[0] * 4.0).sin() + r[1] * r[2] - r[4]).collect();
    let cands = rand_rows(90, d, &mut rng);

    for evict in [0usize, 13, 25] {
        let mut down = GpSurrogate::new(&downdate_only(d, 64));
        let mut rebuild = backend.gp_open(&cfg(d, 64, HyperMode::Fixed)).unwrap();
        for (x, &y) in xs.iter().zip(&ys) {
            down.observe(x, y).unwrap();
            rebuild.observe(x, y).unwrap();
        }
        down.forget(evict).unwrap();
        rebuild.forget(evict).unwrap();
        for width in [1usize, 2, 8] {
            let pool = ExecPool::new(width);
            let a = down.acquire(&pool, &cands, 0.2).unwrap();
            let b = rebuild.acquire(&pool, &cands, 0.2).unwrap();
            assert_close(&a.0, &b.0, &format!("ei, evict {evict} width {width}"));
            assert_close(&a.1, &b.1, &format!("mu, evict {evict} width {width}"));
            assert_close(&a.2, &b.2, &format!("sigma, evict {evict} width {width}"));
        }
    }
}

/// The downdate session's sharded acquisition stays pool-width invariant
/// (bitwise) — the exec-subsystem guarantee must hold on the new path
/// too, including after evictions.
#[test]
fn downdate_session_is_pool_width_invariant() {
    let d = 4;
    let mut rng = Pcg::new(0xdd02);
    let xs = rand_rows(20, d, &mut rng);
    let cands = rand_rows(70, d, &mut rng); // not a multiple of the EI block
    let mut gp = GpSurrogate::new(&downdate_only(d, 32));
    for (i, x) in xs.iter().enumerate() {
        gp.observe(x, (i as f64 * 0.9).sin()).unwrap();
    }
    gp.forget(3).unwrap();
    gp.forget(11).unwrap();
    let serial = gp.acquire(&ExecPool::serial(), &cands, 0.1).unwrap();
    for width in [2usize, 3, 8] {
        let par = gp.acquire(&ExecPool::new(width), &cands, 0.1).unwrap();
        assert_eq!(bits(&serial.0), bits(&par.0), "ei, width {width}");
        assert_eq!(bits(&serial.1), bits(&par.1), "mu, width {width}");
        assert_eq!(bits(&serial.2), bits(&par.2), "sigma, width {width}");
    }
}

/// Eviction-heavy churn at the cap — the BO loop's regime past N_TRAIN:
/// every step evicts the worst point (mixing edge and interior indices)
/// and appends a new one.  After the whole sequence the downdate session
/// must still match both the rebuild session and a from-scratch fit of
/// the surviving set within TOL.
#[test]
fn repeated_evictions_stay_within_tolerance_of_rebuild_and_scratch() {
    let backend = NativeBackend;
    let d = 4;
    let cap = 24;
    let mut rng = Pcg::new(0xdd03);
    let synth = |r: &[f64]| (r[0] * 5.0).sin() + 0.5 * r[1] - r[2] * r[3];

    let mut down = GpSurrogate::new(&downdate_only(d, cap));
    let mut rebuild = backend.gp_open(&cfg(d, cap, HyperMode::Fixed)).unwrap();
    let mut live: Vec<(Vec<f64>, f64)> = Vec::new();
    for x in rand_rows(cap, d, &mut rng) {
        let y = synth(&x);
        down.observe(&x, y).unwrap();
        rebuild.observe(&x, y).unwrap();
        live.push((x, y));
    }
    for step in 0..30 {
        // Worst-point eviction (the tuner's policy), with the edges
        // forced in periodically so index 0 and the last index are
        // exercised across the sequence.
        let evict = match step % 5 {
            0 => 0,
            1 => down.len() - 1,
            _ => argmax(down.ys()),
        };
        down.forget(evict).unwrap();
        rebuild.forget(evict).unwrap();
        live.remove(evict);
        let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
        let y = synth(&x);
        down.observe(&x, y).unwrap();
        rebuild.observe(&x, y).unwrap();
        live.push((x, y));
    }

    let mut scratch = GpSurrogate::new(&cfg(d, cap, HyperMode::Fixed));
    for (x, y) in &live {
        scratch.observe(x, *y).unwrap();
    }

    let cands = rand_rows(60, d, &mut rng);
    let pool = ExecPool::serial();
    let a = down.acquire(&pool, &cands, 0.0).unwrap();
    let b = rebuild.acquire(&pool, &cands, 0.0).unwrap();
    let c = scratch.acquire(&pool, &cands, 0.0).unwrap();
    for (got, want, tag) in [
        (&a.0, &b.0, "ei vs rebuild"),
        (&a.1, &b.1, "mu vs rebuild"),
        (&a.2, &b.2, "sigma vs rebuild"),
        (&a.0, &c.0, "ei vs scratch"),
        (&a.1, &c.1, "mu vs scratch"),
        (&a.2, &c.2, "sigma vs scratch"),
    ] {
        assert_close(got, want, tag);
    }
    // The rebuild path itself is bitwise-equal to the scratch fit — the
    // Fixed contract, re-pinned here on the same history for contrast.
    assert_eq!(bits(&b.0), bits(&c.0));
    assert_eq!(bits(&b.1), bits(&c.1));
    assert_eq!(bits(&b.2), bits(&c.2));
}

/// Adaptation monotonicity: every accepted ascent step increases the log
/// marginal likelihood, the committed state reflects the last accepted
/// step, and the hypers stay inside their documented box.  The initial
/// length-scale is grossly mis-specified (10x the cube diagonal), so at
/// least one step must be accepted.
#[test]
fn adapt_ml_trace_is_monotone_and_commits_last_step() {
    let d = 3;
    let mut c = cfg(d, 64, HyperMode::Adapt { every: usize::MAX });
    c.lengthscales = vec![10.0; d];
    let mut gp = GpSurrogate::new(&c);
    let mut rng = Pcg::new(0xdd04);
    for x in rand_rows(24, d, &mut rng) {
        let y = (x[0] * 6.0).sin() + x[1];
        gp.observe(&x, y).unwrap();
    }
    let out = gp.adapt();
    assert!(out.steps >= 1, "a grossly mis-specified lengthscale must move");
    assert!(out.moved);
    assert_eq!(out.ml.len(), out.steps + 1, "trace = start + one entry per accepted step");
    for w in out.ml.windows(2) {
        assert!(w[1] > w[0], "accepted steps must strictly increase ML: {:?}", out.ml);
    }
    // The committed factor is the one the last accepted step scored.
    assert_eq!(gp.log_marginal().to_bits(), out.ml.last().unwrap().to_bits());
    let (ls, s2n) = gp.hypers();
    assert!(ls.iter().all(|l| (1e-2..=1e2).contains(l)), "lengthscale out of box: {ls:?}");
    assert!((1e-8..=1.0).contains(&s2n), "noise out of box: {s2n}");
    assert!(
        ls.iter().all(|l| *l < 10.0),
        "ascent should shorten a too-long lengthscale (got {ls:?})"
    );
    // ARD off: the length-scales move as one tied parameter.
    assert!(ls.windows(2).all(|w| w[0] == w[1]), "tied scales split: {ls:?}");
}

/// After an adaptation round, the committed kernel + factor must be
/// bitwise what a scratch `Fixed` session at the adapted hypers builds
/// over the same data — adaptation swaps in an *exact* refactor, not an
/// approximation (and later appends extend it consistently).
#[test]
fn adapted_session_equals_scratch_session_at_adapted_hypers() {
    let d = 4;
    let mut c = cfg(d, 64, HyperMode::Adapt { every: usize::MAX });
    c.lengthscales = vec![3.0; d];
    let mut gp = GpSurrogate::new(&c);
    let mut rng = Pcg::new(0xdd05);
    let xs = rand_rows(20, d, &mut rng);
    let ys: Vec<f64> = xs.iter().map(|r| (r[0] * 5.0).sin() - r[3]).collect();
    for (x, &y) in xs.iter().zip(&ys) {
        gp.observe(x, y).unwrap();
    }
    gp.adapt();
    // A couple of post-adaptation appends: new rows must extend the
    // swapped factor at the adapted hypers.
    let extra = rand_rows(3, d, &mut rng);
    for x in &extra {
        gp.observe(x, (x[0] * 5.0).sin() - x[3]).unwrap();
    }

    let (ls, s2n) = gp.hypers();
    let mut scratch_cfg = cfg(d, 64, HyperMode::Fixed);
    scratch_cfg.lengthscales = ls;
    scratch_cfg.sigma_n2 = s2n;
    let mut scratch = GpSurrogate::new(&scratch_cfg);
    for (x, &y) in xs.iter().zip(&ys) {
        scratch.observe(x, y).unwrap();
    }
    for x in &extra {
        scratch.observe(x, (x[0] * 5.0).sin() - x[3]).unwrap();
    }

    let cands = rand_rows(50, d, &mut rng);
    let pool = ExecPool::serial();
    let a = gp.acquire(&pool, &cands, 0.3).unwrap();
    let b = scratch.acquire(&pool, &cands, 0.3).unwrap();
    assert_eq!(bits(&a.0), bits(&b.0), "ei");
    assert_eq!(bits(&a.1), bits(&b.1), "mu");
    assert_eq!(bits(&a.2), bits(&b.2), "sigma");
}

/// Full Adapt mode under churn: adaptation firing between downdate
/// evictions keeps the session healthy (finite posteriors, usable
/// factor) for the whole run.
#[test]
fn adapt_with_evictions_stays_healthy() {
    let d = 4;
    let cap = 20;
    let mut gp = GpSurrogate::new(&cfg(d, cap, HyperMode::Adapt { every: 4 }));
    let mut rng = Pcg::new(0xdd06);
    let synth = |r: &[f64]| (r[0] * 4.0).sin() + r[1] * r[2];
    for x in rand_rows(cap, d, &mut rng) {
        let y = synth(&x);
        gp.observe(&x, y).unwrap();
    }
    let cands = rand_rows(40, d, &mut rng);
    let pool = ExecPool::new(2);
    for _ in 0..25 {
        gp.forget(argmax(gp.ys())).unwrap();
        let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
        gp.observe(&x, synth(&x)).unwrap();
        let (ei, mu, sigma) = gp.acquire(&pool, &cands, 0.0).unwrap();
        for v in ei.iter().chain(&mu).chain(&sigma) {
            assert!(v.is_finite());
        }
    }
    let (ls, s2n) = gp.hypers();
    assert!(ls.iter().all(|l| (1e-2..=1e2).contains(l)));
    assert!((1e-8..=1.0).contains(&s2n));
}
