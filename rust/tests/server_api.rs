//! Integration tests for the REST API (Fig 2 backend): spin up the server
//! on an ephemeral port and exercise every endpoint end-to-end, including
//! the async job contract of /api/characterize and /api/tune.

use std::sync::Arc;
use std::time::{Duration, Instant};

use onestoptuner::runtime::NativeBackend;
use onestoptuner::server::{http_request, spawn};
use onestoptuner::util::json::Json;

fn server() -> std::net::SocketAddr {
    spawn("127.0.0.1:0", Arc::new(NativeBackend)).expect("bind")
}

/// Poll /api/jobs/:id until the job reaches a terminal state; panics on
/// `failed` (tests that expect failure inspect the snapshot themselves).
fn wait_done(addr: std::net::SocketAddr, job_id: f64) -> Json {
    let deadline = Instant::now() + Duration::from_secs(300);
    loop {
        let (code, body) =
            http_request(addr, "GET", &format!("/api/jobs/{job_id}"), "").unwrap();
        assert_eq!(code, 200, "{body}");
        let v = Json::parse(&body).unwrap();
        match v.get("status").and_then(Json::as_str) {
            Some("done") => return v.get("result").unwrap().clone(),
            Some("failed") => panic!("job {job_id} failed: {body}"),
            _ => {
                assert!(Instant::now() < deadline, "job {job_id} never finished");
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

/// Submit an async endpoint, assert the 202 contract, return the job id.
fn submit(addr: std::net::SocketAddr, path: &str, body: &str) -> f64 {
    let (code, resp) = http_request(addr, "POST", path, body).unwrap();
    assert_eq!(code, 202, "{resp}");
    let v = Json::parse(&resp).unwrap();
    assert_eq!(v.get("status").unwrap().as_str(), Some("queued"));
    let id = v.get("job_id").unwrap().as_f64().unwrap();
    assert_eq!(
        v.get("poll").unwrap().as_str().unwrap(),
        format!("/api/jobs/{id}")
    );
    id
}

#[test]
fn health_reports_backend() {
    let addr = server();
    let (code, body) = http_request(addr, "GET", "/api/health", "").unwrap();
    assert_eq!(code, 200);
    let v = Json::parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(v.get("backend").unwrap().as_str().unwrap(), "native");
}

#[test]
fn benchmarks_lists_table1() {
    let addr = server();
    let (code, body) = http_request(addr, "GET", "/api/benchmarks", "").unwrap();
    assert_eq!(code, 200);
    let v = Json::parse(&body).unwrap();
    let arr = v.as_arr().unwrap();
    assert_eq!(arr.len(), 2);
    assert!(arr
        .iter()
        .any(|b| b.get("name").unwrap().as_str() == Some("DenseKMeans")));
}

#[test]
fn flags_catalog_sizes() {
    let addr = server();
    let (_, body) = http_request(addr, "GET", "/api/flags?gc=g1", "").unwrap();
    let v = Json::parse(&body).unwrap();
    assert_eq!(v.as_arr().unwrap().len(), 141);
    let (_, body) = http_request(addr, "GET", "/api/flags?gc=parallel", "").unwrap();
    assert_eq!(Json::parse(&body).unwrap().as_arr().unwrap().len(), 126);
    let (code, _) = http_request(addr, "GET", "/api/flags", "").unwrap();
    assert_eq!(code, 400);
}

#[test]
fn run_endpoint_executes_benchmark() {
    let addr = server();
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/run",
        r#"{"bench": "lda", "gc": "g1", "seed": 3}"#,
    )
    .unwrap();
    assert_eq!(code, 200, "{body}");
    let v = Json::parse(&body).unwrap();
    let t = v.get("exec_time_s").unwrap().as_f64().unwrap();
    assert!(t > 40.0 && t < 600.0, "{t}");
    assert!(v.get("minor_gcs").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn run_with_custom_flags() {
    let addr = server();
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/run",
        r#"{"bench": "densekmeans", "gc": "parallel",
            "flags": {"MaxHeapSize": 32768, "ParallelGCThreads": 20}}"#,
    )
    .unwrap();
    assert_eq!(code, 200, "{body}");
    // unknown flag for the group is a client error
    let (code, _) = http_request(
        addr,
        "POST",
        "/api/run",
        r#"{"bench": "lda", "gc": "parallel", "flags": {"G1ReservePercent": 5}}"#,
    )
    .unwrap();
    assert_eq!(code, 400);
}

#[test]
fn characterize_select_tune_flow() {
    let addr = server();
    // 1. characterize is now an async job (small pool to stay fast)
    let job = submit(
        addr,
        "/api/characterize",
        r#"{"bench": "lda", "gc": "g1", "pool": 120, "rounds": 2}"#,
    );
    let result = wait_done(addr, job);
    let id = result.get("dataset_id").unwrap().as_f64().unwrap();
    assert!(result.get("samples").unwrap().as_f64().unwrap() > 10.0);
    assert!(result.get("runs_executed").unwrap().as_f64().unwrap() > 10.0);

    // 2. datasets listing shows it
    let (_, body) = http_request(addr, "GET", "/api/datasets", "").unwrap();
    assert!(body.contains("dataset_id"));

    // 3. select (stays synchronous — it is a single fast fit)
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/select",
        &format!(r#"{{"dataset_id": {id}}}"#),
    )
    .unwrap();
    assert_eq!(code, 200, "{body}");
    let v = Json::parse(&body).unwrap();
    assert_eq!(v.get("group_size").unwrap().as_f64().unwrap() as i64, 141);
    assert!(v.get("n_selected").unwrap().as_f64().unwrap() > 0.0);

    // 4. tune: 202 + job id, result carries the old blocking payload
    let job = submit(
        addr,
        "/api/tune",
        &format!(
            r#"{{"bench": "lda", "gc": "g1", "algo": "bo-warm",
                 "dataset_id": {id}, "iters": 3}}"#
        ),
    );
    let v = wait_done(addr, job);
    assert!(v.get("improvement").unwrap().as_f64().unwrap() > 0.3);
    assert!(v
        .get("best_java_args")
        .unwrap()
        .as_str()
        .unwrap()
        .contains("-XX:+UseG1GC"));
}

#[test]
fn tune_submission_is_immediate() {
    let addr = server();
    // Submitting a full 20-iteration tuning run must return the moment the
    // job is queued, not after minutes of simulated benchmarks.
    let t0 = Instant::now();
    let job = submit(
        addr,
        "/api/tune",
        r#"{"bench": "densekmeans", "gc": "parallel", "algo": "sa", "iters": 8}"#,
    );
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_secs(2),
        "submission took {elapsed:?} — endpoint is blocking again"
    );
    // The job must show up in the queue listing immediately...
    let (code, body) = http_request(addr, "GET", "/api/jobs", "").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("\"kind\":\"tune\""), "{body}");
    // ...and still complete with a real result.
    let v = wait_done(addr, job);
    assert!(v.get("tuned_mean").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn job_endpoint_edge_cases() {
    let addr = server();
    let (code, _) = http_request(addr, "GET", "/api/jobs/999", "").unwrap();
    assert_eq!(code, 404);
    let (code, _) = http_request(addr, "GET", "/api/jobs/banana", "").unwrap();
    assert_eq!(code, 400);
    // empty queue lists as an empty array
    let (code, body) = http_request(addr, "GET", "/api/jobs", "").unwrap();
    assert_eq!(code, 200);
    assert_eq!(body.trim(), "[]");
}

#[test]
fn tune_without_dataset_requires_cold_algo() {
    let addr = server();
    // validation failures are synchronous 400s, not failed jobs
    let (code, _) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "rbo", "iters": 2}"#,
    )
    .unwrap();
    assert_eq!(code, 400);
    let (code, _) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "bo-warm", "dataset_id": 42, "iters": 2}"#,
    )
    .unwrap();
    assert_eq!(code, 400);
}

#[test]
fn unknown_route_404s() {
    let addr = server();
    let (code, _) = http_request(addr, "GET", "/api/nope", "").unwrap();
    assert_eq!(code, 404);
    let (code, _) = http_request(addr, "PUT", "/api/health", "").unwrap();
    assert_eq!(code, 404);
}

#[test]
fn malformed_json_rejected() {
    let addr = server();
    let (code, _) = http_request(addr, "POST", "/api/run", "{not json").unwrap();
    assert_eq!(code, 400);
}
