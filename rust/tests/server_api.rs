//! Integration tests for the REST API (Fig 2 backend): spin up the server
//! on an ephemeral port and exercise every endpoint end-to-end, including
//! the async job contract of /api/characterize and /api/tune.

use std::sync::Arc;
use std::time::{Duration, Instant};

use onestoptuner::runtime::NativeBackend;
use onestoptuner::server::{http_request, persist, spawn, spawn_with, ApiOptions};
use onestoptuner::util::json::Json;

fn server() -> std::net::SocketAddr {
    spawn("127.0.0.1:0", Arc::new(NativeBackend)).expect("bind")
}

/// Poll /api/jobs/:id until the job reaches any terminal state and return
/// the full record (status + result/error).
fn wait_terminal(addr: std::net::SocketAddr, job_id: f64) -> Json {
    let deadline = Instant::now() + Duration::from_secs(300);
    loop {
        let (code, body) =
            http_request(addr, "GET", &format!("/api/jobs/{job_id}"), "").unwrap();
        assert_eq!(code, 200, "{body}");
        let v = Json::parse(&body).unwrap();
        match v.get("status").and_then(Json::as_str) {
            Some("done") | Some("failed") | Some("cancelled") | Some("degraded") => return v,
            _ => {
                assert!(Instant::now() < deadline, "job {job_id} never finished");
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

/// Poll /api/jobs/:id until the job reaches a terminal state; panics on
/// `failed` (tests that expect failure inspect the snapshot themselves).
fn wait_done(addr: std::net::SocketAddr, job_id: f64) -> Json {
    let deadline = Instant::now() + Duration::from_secs(300);
    loop {
        let (code, body) =
            http_request(addr, "GET", &format!("/api/jobs/{job_id}"), "").unwrap();
        assert_eq!(code, 200, "{body}");
        let v = Json::parse(&body).unwrap();
        match v.get("status").and_then(Json::as_str) {
            Some("done") => return v.get("result").unwrap().clone(),
            Some("failed") | Some("degraded") => panic!("job {job_id} did not finish clean: {body}"),
            _ => {
                assert!(Instant::now() < deadline, "job {job_id} never finished");
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

/// Submit an async endpoint, assert the 202 contract, return the job id.
fn submit(addr: std::net::SocketAddr, path: &str, body: &str) -> f64 {
    let (code, resp) = http_request(addr, "POST", path, body).unwrap();
    assert_eq!(code, 202, "{resp}");
    let v = Json::parse(&resp).unwrap();
    assert_eq!(v.get("status").unwrap().as_str(), Some("queued"));
    let id = v.get("job_id").unwrap().as_f64().unwrap();
    assert_eq!(
        v.get("poll").unwrap().as_str().unwrap(),
        format!("/api/jobs/{id}")
    );
    id
}

#[test]
fn health_reports_backend() {
    let addr = server();
    let (code, body) = http_request(addr, "GET", "/api/health", "").unwrap();
    assert_eq!(code, 200);
    let v = Json::parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(v.get("backend").unwrap().as_str().unwrap(), "native");
}

#[test]
fn benchmarks_lists_table1() {
    let addr = server();
    let (code, body) = http_request(addr, "GET", "/api/benchmarks", "").unwrap();
    assert_eq!(code, 200);
    let v = Json::parse(&body).unwrap();
    let arr = v.as_arr().unwrap();
    assert_eq!(arr.len(), 2);
    assert!(arr
        .iter()
        .any(|b| b.get("name").unwrap().as_str() == Some("DenseKMeans")));
}

#[test]
fn flags_catalog_sizes() {
    let addr = server();
    let (_, body) = http_request(addr, "GET", "/api/flags?gc=g1", "").unwrap();
    let v = Json::parse(&body).unwrap();
    assert_eq!(v.as_arr().unwrap().len(), 141);
    let (_, body) = http_request(addr, "GET", "/api/flags?gc=parallel", "").unwrap();
    assert_eq!(Json::parse(&body).unwrap().as_arr().unwrap().len(), 126);
    let (code, _) = http_request(addr, "GET", "/api/flags", "").unwrap();
    assert_eq!(code, 400);
}

#[test]
fn run_endpoint_executes_benchmark() {
    let addr = server();
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/run",
        r#"{"bench": "lda", "gc": "g1", "seed": 3}"#,
    )
    .unwrap();
    assert_eq!(code, 200, "{body}");
    let v = Json::parse(&body).unwrap();
    let t = v.get("exec_time_s").unwrap().as_f64().unwrap();
    assert!(t > 40.0 && t < 600.0, "{t}");
    assert!(v.get("minor_gcs").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn run_with_custom_flags() {
    let addr = server();
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/run",
        r#"{"bench": "densekmeans", "gc": "parallel",
            "flags": {"MaxHeapSize": 32768, "ParallelGCThreads": 20}}"#,
    )
    .unwrap();
    assert_eq!(code, 200, "{body}");
    // unknown flag for the group is a client error
    let (code, _) = http_request(
        addr,
        "POST",
        "/api/run",
        r#"{"bench": "lda", "gc": "parallel", "flags": {"G1ReservePercent": 5}}"#,
    )
    .unwrap();
    assert_eq!(code, 400);
}

#[test]
fn characterize_select_tune_flow() {
    let addr = server();
    // 1. characterize is now an async job (small pool to stay fast)
    let job = submit(
        addr,
        "/api/characterize",
        r#"{"bench": "lda", "gc": "g1", "pool": 120, "rounds": 2}"#,
    );
    let result = wait_done(addr, job);
    let id = result.get("dataset_id").unwrap().as_f64().unwrap();
    assert!(result.get("samples").unwrap().as_f64().unwrap() > 10.0);
    assert!(result.get("runs_executed").unwrap().as_f64().unwrap() > 10.0);

    // 2. datasets listing shows it
    let (_, body) = http_request(addr, "GET", "/api/datasets", "").unwrap();
    assert!(body.contains("dataset_id"));

    // 3. select (stays synchronous — it is a single fast fit)
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/select",
        &format!(r#"{{"dataset_id": {id}}}"#),
    )
    .unwrap();
    assert_eq!(code, 200, "{body}");
    let v = Json::parse(&body).unwrap();
    assert_eq!(v.get("group_size").unwrap().as_f64().unwrap() as i64, 141);
    assert!(v.get("n_selected").unwrap().as_f64().unwrap() > 0.0);

    // 4. tune: 202 + job id, result carries the old blocking payload
    let job = submit(
        addr,
        "/api/tune",
        &format!(
            r#"{{"bench": "lda", "gc": "g1", "algo": "bo-warm",
                 "dataset_id": {id}, "iters": 3}}"#
        ),
    );
    let v = wait_done(addr, job);
    assert!(v.get("improvement").unwrap().as_f64().unwrap() > 0.3);
    assert!(v
        .get("best_java_args")
        .unwrap()
        .as_str()
        .unwrap()
        .contains("-XX:+UseG1GC"));
}

#[test]
fn tune_submission_is_immediate() {
    let addr = server();
    // Submitting a full 20-iteration tuning run must return the moment the
    // job is queued, not after minutes of simulated benchmarks.
    let t0 = Instant::now();
    let job = submit(
        addr,
        "/api/tune",
        r#"{"bench": "densekmeans", "gc": "parallel", "algo": "sa", "iters": 8}"#,
    );
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_secs(2),
        "submission took {elapsed:?} — endpoint is blocking again"
    );
    // The job must show up in the queue listing immediately...
    let (code, body) = http_request(addr, "GET", "/api/jobs", "").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("\"kind\":\"tune\""), "{body}");
    // ...and still complete with a real result.
    let v = wait_done(addr, job);
    assert!(v.get("tuned_mean").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn job_endpoint_edge_cases() {
    let addr = server();
    let (code, _) = http_request(addr, "GET", "/api/jobs/999", "").unwrap();
    assert_eq!(code, 404);
    let (code, _) = http_request(addr, "GET", "/api/jobs/banana", "").unwrap();
    assert_eq!(code, 400);
    // empty queue lists as an empty array
    let (code, body) = http_request(addr, "GET", "/api/jobs", "").unwrap();
    assert_eq!(code, 200);
    assert_eq!(body.trim(), "[]");
}

#[test]
fn tune_without_dataset_requires_cold_algo() {
    let addr = server();
    // validation failures are synchronous 400s, not failed jobs
    let (code, _) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "rbo", "iters": 2}"#,
    )
    .unwrap();
    assert_eq!(code, 400);
    let (code, _) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "bo-warm", "dataset_id": 42, "iters": 2}"#,
    )
    .unwrap();
    assert_eq!(code, 400);
}

#[test]
fn gp_hypers_validation_on_tune() {
    let addr = server();
    // Present-but-unknown policy is a client error, like `metric`.
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "bo", "gp_hypers": "wibble"}"#,
    )
    .unwrap();
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("gp_hypers"), "{body}");
    // gp_adapt_every must be a positive integer...
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "bo", "gp_hypers": "adapt", "gp_adapt_every": 0}"#,
    )
    .unwrap();
    assert_eq!(code, 400, "{body}");
    // ...and contradicting an explicit "fixed" is a client error too.
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "bo", "gp_hypers": "fixed", "gp_adapt_every": 4}"#,
    )
    .unwrap();
    assert_eq!(code, 400, "{body}");
    // A cadence alone never implies adaptation: the fixed default stays
    // bit-reproducible unless "adapt" is requested explicitly.
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "bo", "gp_adapt_every": 4}"#,
    )
    .unwrap();
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("gp_hypers"), "{body}");
    // A valid adaptive submission is accepted as an async job.
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "sa", "iters": 1,
            "gp_hypers": "adapt", "gp_adapt_every": 4}"#,
    )
    .unwrap();
    assert_eq!(code, 202, "{body}");
}

#[test]
fn gp_ard_validation_on_tune() {
    let addr = server();
    // Non-boolean gp_ard is a client error.
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "bo", "gp_ard": "yes"}"#,
    )
    .unwrap();
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("gp_ard"), "{body}");
    // ARD against an explicit "fixed" policy is a contradiction.
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "bo", "gp_hypers": "fixed", "gp_ard": true}"#,
    )
    .unwrap();
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("gp_ard"), "{body}");
    // Bare gp_ard implies adapt: accepted, and it satisfies the
    // gp_adapt_every precondition too.
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "sa", "iters": 1,
            "gp_ard": true, "gp_adapt_every": 4}"#,
    )
    .unwrap();
    assert_eq!(code, 202, "{body}");
    // gp_ard: false is a no-op, not an error.
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "sa", "iters": 1, "gp_ard": false}"#,
    )
    .unwrap();
    assert_eq!(code, 202, "{body}");
}

#[test]
fn gp_init_hypers_validation_on_tune() {
    let addr = server();
    // Shape errors: missing/non-array lengthscales, non-positive values.
    for bad_body in [
        r#"{"bench": "lda", "gc": "g1", "algo": "bo", "gp_init_hypers": {}}"#,
        r#"{"bench": "lda", "gc": "g1", "algo": "bo", "gp_init_hypers": {"lengthscales": "x"}}"#,
        r#"{"bench": "lda", "gc": "g1", "algo": "bo", "gp_init_hypers": {"lengthscales": []}}"#,
        r#"{"bench": "lda", "gc": "g1", "algo": "bo",
            "gp_init_hypers": {"lengthscales": [0.5, -1.0]}}"#,
        r#"{"bench": "lda", "gc": "g1", "algo": "bo",
            "gp_init_hypers": {"lengthscales": [0.5], "sigma_n2": 0}}"#,
    ] {
        let (code, body) = http_request(addr, "POST", "/api/tune", bad_body).unwrap();
        assert_eq!(code, 400, "{bad_body} -> {body}");
        assert!(body.contains("gp_init_hypers"), "{body}");
    }
    // Wrong dimension count is a *synchronous* 400: a dataset-less g1
    // tune runs over the full 141-flag group.
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "bo",
            "gp_init_hypers": {"lengthscales": [0.5, 0.7]}}"#,
    )
    .unwrap();
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("141"), "must name the tuning dimension: {body}");
}

#[test]
fn batch_q_validation_on_tune() {
    let addr = server();
    // Zero, non-integer and oversized widths are synchronous 400s: the
    // job must never 202-accept a q the tuner would reject at its first
    // iteration.
    for bad_body in [
        r#"{"bench": "lda", "gc": "g1", "algo": "bo", "batch_q": 0}"#,
        r#"{"bench": "lda", "gc": "g1", "algo": "bo", "batch_q": 1.5}"#,
        r#"{"bench": "lda", "gc": "g1", "algo": "bo", "batch_q": 1025}"#,
    ] {
        let (code, body) = http_request(addr, "POST", "/api/tune", bad_body).unwrap();
        assert_eq!(code, 400, "{bad_body} -> {body}");
        assert!(body.contains("batch_q"), "{body}");
    }
    // An explicit q of 1 is the default single-point path: accepted.
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "sa", "iters": 1, "batch_q": 1}"#,
    )
    .unwrap();
    assert_eq!(code, 202, "{body}");
}

/// End-to-end ARD loop closure: an ARD tune reports per-flag hypers and a
/// relevance object next to the selection, and the reported hypers feed
/// back into a warm-started follow-up job.  The initial length-scales are
/// grossly long (50, near the box edge) so the ML ascent must accept a
/// step — the record's `gp_ard`/`ard_relevance` only appear when the
/// scales actually moved.
#[test]
fn ard_tune_reports_relevance_and_hypers_round_trip() {
    let addr = server();
    let init: Vec<String> = (0..141).map(|_| "50.0".to_string()).collect();
    let job = submit(
        addr,
        "/api/tune",
        &format!(
            r#"{{"bench": "lda", "gc": "g1", "algo": "bo", "iters": 1, "gp_ard": true,
                "gp_init_hypers": {{"lengthscales": [{}]}}}}"#,
            init.join(",")
        ),
    );
    let v = wait_done(addr, job);
    assert_eq!(v.get("gp_hypers").unwrap().as_str(), Some("adapt"));
    assert_eq!(v.get("gp_ard").unwrap().as_bool(), Some(true), "{v}");
    let ls = v.get("gp_lengthscales").unwrap().as_arr().unwrap();
    assert_eq!(ls.len(), 141, "dataset-less g1 tune runs the full group");
    assert!(v.get("gp_sigma_n2").unwrap().as_f64().unwrap() > 0.0);
    let rel = v.get("ard_relevance").unwrap();
    // Relevance is keyed by flag name and normalized over the group.
    let Json::Obj(pairs) = rel else { panic!("ard_relevance must be an object: {rel}") };
    assert_eq!(pairs.len(), 141);
    let sum: f64 = pairs.iter().filter_map(|(_, v)| v.as_f64()).sum();
    assert!((sum - 1.0).abs() < 1e-6, "relevance must be normalized: {sum}");

    // Round-trip: the reported length-scales seed a follow-up job.
    let ls_csv: Vec<String> =
        ls.iter().map(|l| format!("{}", l.as_f64().unwrap())).collect();
    let s2n = v.get("gp_sigma_n2").unwrap().as_f64().unwrap();
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/tune",
        &format!(
            r#"{{"bench": "lda", "gc": "g1", "algo": "bo", "iters": 1,
                "gp_hypers": "adapt",
                "gp_init_hypers": {{"lengthscales": [{}], "sigma_n2": {s2n}}}}}"#,
            ls_csv.join(",")
        ),
    )
    .unwrap();
    assert_eq!(code, 202, "{body}");
    let job2 = Json::parse(&body).unwrap().get("job_id").unwrap().as_f64().unwrap();
    wait_done(addr, job2);
}

#[test]
fn unknown_route_404s() {
    let addr = server();
    let (code, _) = http_request(addr, "GET", "/api/nope", "").unwrap();
    assert_eq!(code, 404);
    let (code, _) = http_request(addr, "PUT", "/api/health", "").unwrap();
    assert_eq!(code, 404);
}

#[test]
fn malformed_json_rejected() {
    let addr = server();
    let (code, _) = http_request(addr, "POST", "/api/run", "{not json").unwrap();
    assert_eq!(code, 400);
}

#[test]
fn unknown_metric_is_a_400_while_absent_metric_defaults() {
    let addr = server();
    // A typo'd metric used to silently fall back to exec_time — the
    // client would tune the wrong objective with no signal at all.
    for path in ["/api/characterize", "/api/tune"] {
        let (code, body) = http_request(
            addr,
            "POST",
            path,
            r#"{"bench": "lda", "gc": "g1", "algo": "sa", "metric": "exectime "}"#,
        )
        .unwrap();
        assert_eq!(code, 400, "{path}: {body}");
        assert!(body.contains("metric"), "{body}");
    }
    // Absent metric still means the default objective: the submission is
    // accepted as an async job (we don't wait for it).
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "sa", "iters": 1}"#,
    )
    .unwrap();
    assert_eq!(code, 202, "{body}");
}

#[test]
fn running_tune_reports_progress_and_cancels_with_partial_result() {
    let addr = server();
    // A long cold BO run: enough iterations that the DELETE lands mid-run.
    let job = submit(
        addr,
        "/api/tune",
        r#"{"bench": "densekmeans", "gc": "parallel", "algo": "bo", "iters": 300}"#,
    );

    // Progress must surface and advance monotonically while running.
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut seen: Vec<f64> = Vec::new();
    while seen.len() < 2 {
        let (code, body) =
            http_request(addr, "GET", &format!("/api/jobs/{job}"), "").unwrap();
        assert_eq!(code, 200);
        let v = Json::parse(&body).unwrap();
        let status = v.get("status").unwrap().as_str().unwrap().to_string();
        assert!(
            status == "queued" || status == "running",
            "300-iteration tune finished before progress was observed: {body}"
        );
        // Non-terminal jobs report elapsed-since-submit too (the old code
        // only emitted elapsed_s once finished).
        assert!(v.get("elapsed_s").unwrap().as_f64().unwrap() >= 0.0, "{body}");
        if let Some(it) = v
            .get("progress")
            .and_then(|p| p.get("iteration"))
            .and_then(Json::as_f64)
        {
            if seen.last() != Some(&it) {
                seen.push(it);
            }
        }
        assert!(Instant::now() < deadline, "progress never advanced: {seen:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(seen.windows(2).all(|w| w[0] < w[1]), "iteration regressed: {seen:?}");

    // Cancel mid-run: 202, then the job lands in `cancelled` with its
    // best-so-far partial result.
    let (code, body) =
        http_request(addr, "DELETE", &format!("/api/jobs/{job}"), "").unwrap();
    assert_eq!(code, 202, "{body}");
    let rec = wait_terminal(addr, job);
    assert_eq!(rec.get("status").unwrap().as_str(), Some("cancelled"), "{rec}");
    let result = rec.get("result").expect("cancelled tune keeps a partial result");
    assert!(result.get("tuned_mean").unwrap().as_f64().unwrap() > 0.0);
    assert!(result.get("best_java_args").is_some());

    // A second DELETE is refused: the record is terminal and immutable.
    let (code, _) = http_request(addr, "DELETE", &format!("/api/jobs/{job}"), "").unwrap();
    assert_eq!(code, 409);
}

#[test]
fn cancel_endpoint_edge_cases() {
    let addr = server();
    let (code, _) = http_request(addr, "DELETE", "/api/jobs/999", "").unwrap();
    assert_eq!(code, 404);
    let (code, _) = http_request(addr, "DELETE", "/api/jobs/banana", "").unwrap();
    assert_eq!(code, 400);
    // Cancelling a finished job answers 409 Conflict.
    let job = submit(
        addr,
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "sa", "iters": 1}"#,
    );
    wait_done(addr, job);
    let (code, body) = http_request(addr, "DELETE", &format!("/api/jobs/{job}"), "").unwrap();
    assert_eq!(code, 409, "{body}");
}

#[test]
fn faults_validation_on_tune() {
    let addr = server();
    // Malformed fault plans are synchronous 400s, not failed jobs.
    for bad_body in [
        r#"{"bench": "lda", "gc": "g1", "algo": "sa", "faults": "chaos"}"#,
        r#"{"bench": "lda", "gc": "g1", "algo": "sa", "faults": {"crash_p": 1.5}}"#,
        r#"{"bench": "lda", "gc": "g1", "algo": "sa", "faults": {"spike_mult": 0.5}}"#,
        r#"{"bench": "lda", "gc": "g1", "algo": "sa", "faults": {"max_retries": -1}}"#,
        r#"{"bench": "lda", "gc": "g1", "algo": "sa",
            "faults": {"crash_regions": [{"flag": "NoSuchFlag", "lo": 0, "hi": 1}]}}"#,
        r#"{"bench": "lda", "gc": "g1", "algo": "sa",
            "faults": {"crash_regions": [{"flag": "MaxHeapSize", "lo": 0.9, "hi": 0.1}]}}"#,
    ] {
        let (code, body) = http_request(addr, "POST", "/api/tune", bad_body).unwrap();
        assert_eq!(code, 400, "{bad_body} -> {body}");
    }
    // A non-integer fail_budget is a client error too.
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "sa", "fail_budget": 1.5}"#,
    )
    .unwrap();
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("fail_budget"), "{body}");
}

/// The tentpole end-to-end: a tune under an injected fault mix with a
/// tight failure budget lands in `degraded`, still carrying its
/// best-so-far result and an accurate per-kind failure histogram.
#[test]
fn faulty_tune_degrades_with_histogram_and_best_so_far() {
    let addr = server();
    // Every measurement crashes (crash_p 1.0, one retry) so the budget of
    // 2 total failures trips during SA's 5-point init phase.
    let job = submit(
        addr,
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "sa", "iters": 10, "fail_budget": 2,
            "faults": {"seed": 7, "crash_p": 1.0, "max_retries": 1}}"#,
    );
    let rec = wait_terminal(addr, job);
    assert_eq!(rec.get("status").unwrap().as_str(), Some("degraded"), "{rec}");
    let result = rec.get("result").expect("degraded job keeps its best-so-far result");
    let failures = result.get("failures").expect("tune results always carry the histogram");
    let crash = failures.get("crash").unwrap().as_f64().unwrap();
    let total = failures.get("total").unwrap().as_f64().unwrap();
    assert!(crash > 2.0, "budget 2 means at least 3 failures recorded: {failures}");
    assert_eq!(crash, total, "only crashes were injected: {failures}");
    assert_eq!(failures.get("oom").unwrap().as_f64(), Some(0.0));
    assert!(result.get("best_java_args").is_some(), "{result}");
    // Cancelling a degraded (terminal) job is refused like any other.
    let (code, _) = http_request(addr, "DELETE", &format!("/api/jobs/{job}"), "").unwrap();
    assert_eq!(code, 409);

    // The same faulty tune without a budget runs to `done` — and its
    // histogram is reproducible from the seeds alone.
    let job2 = submit(
        addr,
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "sa", "iters": 3,
            "faults": {"seed": 7, "crash_p": 1.0, "max_retries": 1}}"#,
    );
    let rec2 = wait_terminal(addr, job2);
    assert_eq!(rec2.get("status").unwrap().as_str(), Some("done"), "{rec2}");
    let f2 = rec2.get("result").unwrap().get("failures").unwrap();
    // SA: 4 LHS init points + 3 iterations, all crashing (injection is
    // deterministic given the plan seed + run seeds).
    assert_eq!(f2.get("crash").unwrap().as_f64(), Some(8.0), "{f2}");
    // A fault-free tune reports the all-zero histogram, not a missing key.
    let job3 = submit(
        addr,
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "sa", "iters": 1}"#,
    );
    let v = wait_done(addr, job3);
    assert_eq!(v.get("failures").unwrap().get("total").unwrap().as_f64(), Some(0.0));
}

#[test]
fn saturated_queue_answers_429_with_retry_after() {
    use std::io::{Read as _, Write as _};
    // Capacity 1, single worker: one blocking-ish job saturates the queue.
    let opts = ApiOptions { workers: 1, queue_capacity: Some(1), ..Default::default() };
    let addr = spawn_with("127.0.0.1:0", Arc::new(NativeBackend), opts).unwrap();
    let blocker = submit(
        addr,
        "/api/tune",
        r#"{"bench": "densekmeans", "gc": "parallel", "algo": "bo", "iters": 200}"#,
    );
    // Raw client so the Retry-After *header* is visible.
    let body = r#"{"bench": "lda", "gc": "g1", "algo": "sa", "iters": 1}"#;
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "POST /api/tune HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    )
    .unwrap();
    let mut resp = String::new();
    stream.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 429 Too Many Requests"), "{resp}");
    let head = resp.split("\r\n\r\n").next().unwrap();
    assert!(head.contains("Retry-After: "), "{head}");
    assert!(resp.contains("queue full"), "{resp}");
    // Characterize submissions hit the same bound.
    let (code, body) = http_request(
        addr,
        "POST",
        "/api/characterize",
        r#"{"bench": "lda", "gc": "g1", "pool": 100, "rounds": 1}"#,
    )
    .unwrap();
    assert_eq!(code, 429, "{body}");
    // Draining the queue re-admits: cancel the blocker and wait it out.
    let (code, _) = http_request(addr, "DELETE", &format!("/api/jobs/{blocker}"), "").unwrap();
    assert_eq!(code, 202);
    wait_terminal(addr, blocker);
    let job = submit(
        addr,
        "/api/tune",
        r#"{"bench": "lda", "gc": "g1", "algo": "sa", "iters": 1}"#,
    );
    wait_done(addr, job);
}

#[test]
fn datasets_and_terminal_jobs_survive_a_restart() {
    let dir = std::env::temp_dir().join(format!("ost-restart-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // First server: characterize a small dataset, then "crash".
    let opts = ApiOptions { state_dir: Some(dir.clone()), ..Default::default() };
    let addr = spawn_with("127.0.0.1:0", Arc::new(NativeBackend), opts).unwrap();
    let job = submit(
        addr,
        "/api/characterize",
        r#"{"bench": "lda", "gc": "g1", "pool": 100, "rounds": 1}"#,
    );
    let result = wait_done(addr, job);
    let ds_id = result.get("dataset_id").unwrap().as_f64().unwrap();

    // The terminal hook persists synchronously on the worker thread; give
    // the write a moment in case our poll raced it.
    let state_file = dir.join(persist::STATE_FILE);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let has_job = std::fs::read_to_string(&state_file)
            .ok()
            .is_some_and(|s| s.contains("\"job_id\""));
        if has_job {
            break;
        }
        assert!(Instant::now() < deadline, "state file never written");
        std::thread::sleep(Duration::from_millis(20));
    }

    // Second server on the same state dir: everything is back.
    let opts = ApiOptions { state_dir: Some(dir.clone()), ..Default::default() };
    let addr2 = spawn_with("127.0.0.1:0", Arc::new(NativeBackend), opts).unwrap();

    let (code, body) = http_request(addr2, "GET", "/api/datasets", "").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains(&format!("\"dataset_id\":{ds_id}")), "{body}");

    let (code, body) = http_request(addr2, "GET", &format!("/api/jobs/{job}"), "").unwrap();
    assert_eq!(code, 200, "terminal job record lost in restart");
    let v = Json::parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str(), Some("done"));
    assert_eq!(v.get("kind").unwrap().as_str(), Some("characterize"));
    assert!(v.get("elapsed_s").unwrap().as_f64().unwrap() >= 0.0);
    assert!(v.get("result").is_some(), "restored record kept its payload");

    // The restored dataset is usable, not just listed: select and a
    // warm-started tune both run against it.
    let (code, body) = http_request(
        addr2,
        "POST",
        "/api/select",
        &format!(r#"{{"dataset_id": {ds_id}}}"#),
    )
    .unwrap();
    assert_eq!(code, 200, "{body}");

    // New jobs on the restarted server get ids past the restored ones.
    let job2 = submit(
        addr2,
        "/api/tune",
        &format!(
            r#"{{"bench": "lda", "gc": "g1", "algo": "bo-warm", "dataset_id": {ds_id}, "iters": 1}}"#
        ),
    );
    assert!(job2 > job, "restored job ids must not be reused (old {job}, new {job2})");
    wait_done(addr2, job2);

    let _ = std::fs::remove_dir_all(&dir);
}
