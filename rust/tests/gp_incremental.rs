//! Incremental-surrogate regression guard: the stateful GP session
//! (cached kernel + incrementally-extended Cholesky, pool-sharded
//! acquisition) under `HyperMode::Fixed` must be **bit-identical** to the
//! one-shot `gp_ei` path — per-candidate (ei, mu, sigma) and whole
//! `TuneResult`s — at every pool width, including across an N_TRAIN
//! eviction (where the Fixed surrogate refactors its kernel cache from
//! scratch).
//!
//! Tolerance policy: `Fixed` (the default everywhere in this file) is the
//! bitwise side of the contract and nothing here is allowed a tolerance.
//! `HyperMode::Adapt` deliberately gives that up — O(n²) downdate
//! evictions are pinned to the rebuild path within 1e-8 and adaptation is
//! pinned by monotonicity/scratch-refactor equalities instead, all in
//! `tests/gp_downdate.rs` (ARD specifics in `tests/gp_ard.rs`).  This
//! file must keep passing unchanged whatever happens on the Adapt side:
//! that is the PR-2 guarantee, extended by the ARD refactor — ARD off
//! (or all per-dimension length-scales equal, which selects the same
//! isotropic summation order) reproduces the pre-refactor scalar path
//! bitwise, and unequal Fixed length-scales are pinned session-vs-one-shot
//! bitwise too.

use std::sync::Arc;

use onestoptuner::exec::ExecPool;
use onestoptuner::flags::GcMode;
use onestoptuner::runtime::{
    one_shot_gp, GpConfig, GpSession, HyperMode, KernelPolicy, MlBackend, NativeBackend, N_TRAIN,
};
use onestoptuner::tuner::bo::{BoConfig, BoTuner, GpHypers, SurrogateMode};
use onestoptuner::tuner::objective::Objective;
use onestoptuner::tuner::{TuneResult, TuneSpace, Tuner};
use onestoptuner::util::rng::Pcg;

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn rand_rows(n: usize, d: usize, rng: &mut Pcg) -> Vec<Vec<f64>> {
    (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect()
}

fn gp_cfg(d: usize) -> GpConfig {
    GpConfig {
        dim: d,
        lengthscales: vec![0.7; d],
        sigma_f2: 1.0,
        sigma_n2: 0.01,
        cap: N_TRAIN,
        hyper: HyperMode::Fixed,
        ard: false,
        kernels: KernelPolicy::Scalar,
    }
}

/// Drive an incremental and a one-shot session through the same history of
/// observe/forget/acquire operations and assert every acquisition is
/// bitwise equal, at pool widths 1, 2 and 8.
#[test]
fn session_matches_one_shot_at_every_pool_width() {
    let backend = NativeBackend;
    let d = 6;
    let cfg = gp_cfg(d);
    let mut rng = Pcg::new(0x61);
    let xs = rand_rows(48, d, &mut rng);
    let ys: Vec<f64> = xs.iter().map(|r| (r[0] * 4.0).sin() + r[1] * r[2] - r[5]).collect();
    let cands = rand_rows(200, d, &mut rng);

    for width in [1usize, 2, 8] {
        let epool = ExecPool::new(width);
        let mut inc = backend.gp_open(&cfg).unwrap();
        let mut one = one_shot_gp(&backend, &cfg);
        let mut best = f64::INFINITY;
        for (i, (x, &y)) in xs.iter().zip(&ys).enumerate() {
            inc.observe(x, y).unwrap();
            one.observe(x, y).unwrap();
            best = best.min(y);
            // interleave evictions to cross the full-refactor path
            if i == 20 || i == 33 {
                inc.forget(i / 2).unwrap();
                one.forget(i / 2).unwrap();
            }
            if i % 7 == 0 {
                let a = inc.acquire(&epool, &cands, best).unwrap();
                let b = one.acquire(&epool, &cands, best).unwrap();
                assert_eq!(bits(&a.0), bits(&b.0), "ei, step {i} width {width}");
                assert_eq!(bits(&a.1), bits(&b.1), "mu, step {i} width {width}");
                assert_eq!(bits(&a.2), bits(&b.2), "sigma, step {i} width {width}");
            }
        }
        assert_eq!(inc.len(), one.len());
        assert_eq!(bits(inc.ys()), bits(one.ys()));
    }
}

/// The ARD refactor's all-equal-lengthscales pin: a session whose
/// per-dimension length-scales are all equal (with the `ard` flag set,
/// exercising the full vector code path) must stay **bitwise** equal to
/// the plain isotropic session — same kernel summation order — through
/// the same observe/forget/acquire history at pool widths 1/2/8,
/// including across the full-refactor eviction path.
#[test]
fn ard_flag_with_equal_lengthscales_is_bitwise_isotropic() {
    let backend = NativeBackend;
    let d = 6;
    let iso_cfg = gp_cfg(d);
    let mut ard_cfg = gp_cfg(d);
    ard_cfg.ard = true;
    let mut rng = Pcg::new(0x63);
    let xs = rand_rows(40, d, &mut rng);
    let ys: Vec<f64> = xs.iter().map(|r| (r[0] * 4.0).sin() + r[1] * r[2] - r[5]).collect();
    let cands = rand_rows(150, d, &mut rng);

    for width in [1usize, 2, 8] {
        let epool = ExecPool::new(width);
        let mut iso = backend.gp_open(&iso_cfg).unwrap();
        let mut ard = backend.gp_open(&ard_cfg).unwrap();
        let mut best = f64::INFINITY;
        for (i, (x, &y)) in xs.iter().zip(&ys).enumerate() {
            iso.observe(x, y).unwrap();
            ard.observe(x, y).unwrap();
            best = best.min(y);
            if i == 18 || i == 31 {
                iso.forget(i / 3).unwrap();
                ard.forget(i / 3).unwrap();
            }
            if i % 6 == 0 {
                let a = iso.acquire(&epool, &cands, best).unwrap();
                let b = ard.acquire(&epool, &cands, best).unwrap();
                assert_eq!(bits(&a.0), bits(&b.0), "ei, step {i} width {width}");
                assert_eq!(bits(&a.1), bits(&b.1), "mu, step {i} width {width}");
                assert_eq!(bits(&a.2), bits(&b.2), "sigma, step {i} width {width}");
            }
        }
    }
}

/// Unequal per-dimension length-scales under `Fixed`: the session's
/// weighted-sum kernel path must stay bitwise equal to the one-shot
/// `gp_ei` reference (which runs the same ARD arithmetic in `ops::rbf`),
/// through observes, an eviction, and acquires at widths 1/2/8.
#[test]
fn fixed_ard_lengthscales_match_one_shot_bitwise() {
    let backend = NativeBackend;
    let d = 5;
    let mut cfg = gp_cfg(d);
    cfg.lengthscales = vec![0.25, 0.7, 1.4, 0.4, 2.2];
    let mut rng = Pcg::new(0x64);
    let xs = rand_rows(32, d, &mut rng);
    let ys: Vec<f64> = xs.iter().map(|r| (r[2] * 3.0).cos() + r[0] - r[4]).collect();
    let cands = rand_rows(120, d, &mut rng);

    for width in [1usize, 2, 8] {
        let epool = ExecPool::new(width);
        let mut inc = backend.gp_open(&cfg).unwrap();
        let mut one = one_shot_gp(&backend, &cfg);
        let mut best = f64::INFINITY;
        for (i, (x, &y)) in xs.iter().zip(&ys).enumerate() {
            inc.observe(x, y).unwrap();
            one.observe(x, y).unwrap();
            best = best.min(y);
            if i == 21 {
                inc.forget(4).unwrap();
                one.forget(4).unwrap();
            }
            if i % 8 == 0 {
                let a = inc.acquire(&epool, &cands, best).unwrap();
                let b = one.acquire(&epool, &cands, best).unwrap();
                assert_eq!(bits(&a.0), bits(&b.0), "ei, step {i} width {width}");
                assert_eq!(bits(&a.1), bits(&b.1), "mu, step {i} width {width}");
                assert_eq!(bits(&a.2), bits(&b.2), "sigma, step {i} width {width}");
            }
        }
    }
}

/// Eviction-order regression: evicting index 0 and the *last* index must
/// keep (ei, mu, sigma) finite and bitwise-consistent with a scratch fit
/// of the surviving points — previously only mid-buffer evictions were
/// exercised, and the edges are exactly where splice/offset bugs live.
#[test]
fn edge_evictions_match_scratch_fit_bitwise() {
    let backend = NativeBackend;
    let d = 5;
    let cfg = gp_cfg(d);
    let mut rng = Pcg::new(0x62);
    let xs = rand_rows(30, d, &mut rng);
    let ys: Vec<f64> = xs.iter().map(|r| (r[1] * 3.0).cos() + r[0] - r[4]).collect();
    let cands = rand_rows(80, d, &mut rng);
    let pool = ExecPool::serial();

    for evict in [0usize, 29] {
        let mut inc = backend.gp_open(&cfg).unwrap();
        let mut one = one_shot_gp(&backend, &cfg);
        for (x, &y) in xs.iter().zip(&ys) {
            inc.observe(x, y).unwrap();
            one.observe(x, y).unwrap();
        }
        inc.forget(evict).unwrap();
        one.forget(evict).unwrap();

        let mut scratch = backend.gp_open(&cfg).unwrap();
        for (i, (x, &y)) in xs.iter().zip(&ys).enumerate() {
            if i != evict {
                scratch.observe(x, y).unwrap();
            }
        }

        let a = inc.acquire(&pool, &cands, 0.2).unwrap();
        let b = one.acquire(&pool, &cands, 0.2).unwrap();
        let c = scratch.acquire(&pool, &cands, 0.2).unwrap();
        for v in a.0.iter().chain(&a.1).chain(&a.2) {
            assert!(v.is_finite(), "evict {evict}: non-finite posterior");
        }
        for (got, want, tag) in [
            (&a.0, &b.0, "ei vs one-shot"),
            (&a.1, &b.1, "mu vs one-shot"),
            (&a.2, &b.2, "sigma vs one-shot"),
            (&a.0, &c.0, "ei vs scratch"),
            (&a.1, &c.1, "mu vs scratch"),
            (&a.2, &c.2, "sigma vs scratch"),
        ] {
            assert_eq!(bits(got), bits(want), "evict {evict}: {tag}");
        }
    }
}

/// Cheap synthetic objective: quadratic bowl in the unit cube.
struct Bowl {
    space: TuneSpace,
    count: usize,
}

impl Objective for Bowl {
    fn eval_outcome(
        &mut self,
        cfg: &onestoptuner::flags::FlagConfig,
    ) -> onestoptuner::tuner::EvalOutcome {
        self.count += 1;
        let u = self.space.project(cfg);
        let y = u.iter().map(|&x| (x - 0.7) * (x - 0.7)).sum();
        onestoptuner::tuner::EvalOutcome { y, failure: None, attempts: 1 }
    }
    fn evals(&self) -> usize {
        self.count
    }
    fn sim_time_s(&self) -> f64 {
        self.count as f64
    }
}

fn small_space() -> TuneSpace {
    let mut sp = TuneSpace::full(GcMode::ParallelGC);
    sp.selected.truncate(6);
    sp
}

fn run_bo(surrogate: SurrogateMode, width: usize, n_init: usize, iters: usize) -> TuneResult {
    let space = small_space();
    let mut obj = Bowl { space: space.clone(), count: 0 };
    let mut bo = BoTuner::new(
        Arc::new(NativeBackend),
        BoConfig {
            n_init,
            n_candidates: 64,
            surrogate,
            epool: ExecPool::new(width),
            ..Default::default()
        },
    );
    bo.tune(&space, &mut obj, iters).unwrap()
}

fn assert_results_identical(a: &TuneResult, b: &TuneResult, tag: &str) {
    assert_eq!(a.best_y.to_bits(), b.best_y.to_bits(), "best_y ({tag})");
    assert_eq!(a.best_config, b.best_config, "best_config ({tag})");
    assert_eq!(bits(&a.history), bits(&b.history), "history ({tag})");
    assert_eq!(bits(&a.best_history), bits(&b.best_history), "best_history ({tag})");
    assert_eq!(a.evals, b.evals, "evals ({tag})");
}

/// Whole-tuner equivalence at a small size: session vs one-shot, widths
/// 1/2/8.
#[test]
fn bo_tune_result_identical_across_paths_and_widths() {
    let reference = run_bo(SurrogateMode::OneShot, 1, 8, 10);
    for width in [1usize, 2, 8] {
        let inc = run_bo(SurrogateMode::Session, width, 8, 10);
        assert_results_identical(&reference, &inc, &format!("width {width}"));
    }
}

/// The same equivalence with `HyperMode::Fixed` pinned *explicitly*
/// (rather than through `GpHypers::default()`): if a future change flips
/// the default hyper policy, this test keeps guarding the contract that
/// a Fixed session is bitwise-equal to the one-shot reference.
#[test]
fn bo_tune_result_identical_with_explicit_fixed_hypers() {
    let space = small_space();
    let run = |surrogate: SurrogateMode| {
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut bo = BoTuner::new(
            Arc::new(NativeBackend),
            BoConfig {
                n_init: 6,
                n_candidates: 64,
                surrogate,
                hypers: GpHypers { mode: HyperMode::Fixed, ..Default::default() },
                epool: ExecPool::new(4),
                ..Default::default()
            },
        );
        bo.tune(&space, &mut obj, 8).unwrap()
    };
    let one = run(SurrogateMode::OneShot);
    let inc = run(SurrogateMode::Session);
    assert_results_identical(&one, &inc, "explicit HyperMode::Fixed");
}

/// The q-EI entry point at `batch_q: 1` must take the exact legacy
/// single-point code path: a whole tune with the batch width explicitly
/// set to 1 stays bitwise equal to the one-shot reference (which has no
/// batch machinery at all) at every pool width.
#[test]
fn batch_q_one_is_bitwise_the_single_point_path() {
    let space = small_space();
    let run = |surrogate: SurrogateMode, batch_q: usize, width: usize| {
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut bo = BoTuner::new(
            Arc::new(NativeBackend),
            BoConfig {
                n_init: 8,
                n_candidates: 64,
                surrogate,
                batch_q,
                epool: ExecPool::new(width),
                ..Default::default()
            },
        );
        bo.tune(&space, &mut obj, 10).unwrap()
    };
    let reference = run(SurrogateMode::OneShot, 1, 1);
    for width in [1usize, 2, 8] {
        let inc = run(SurrogateMode::Session, 1, width);
        assert_results_identical(&reference, &inc, &format!("batch_q 1, width {width}"));
    }
}

/// Fantasy-scope round trip: after q constant-liar fantasies are pushed
/// and popped again, the session must be restored **bitwise** — same
/// length, same observations, and a bit-identical acquisition — at pool
/// widths 1/2/8.  This is the push-inverse contract batched q-EI leans
/// on every iteration.
#[test]
fn fantasize_pop_round_trip_restores_acquisition_bitwise() {
    let backend = NativeBackend;
    let d = 6;
    let cfg = gp_cfg(d);
    let mut rng = Pcg::new(0x65);
    let xs = rand_rows(24, d, &mut rng);
    let ys: Vec<f64> = xs.iter().map(|r| (r[0] * 4.0).sin() + r[1] * r[2] - r[5]).collect();
    let cands = rand_rows(100, d, &mut rng);
    let fantasies = rand_rows(3, d, &mut rng);

    for width in [1usize, 2, 8] {
        let epool = ExecPool::new(width);
        let mut gp = backend.gp_open(&cfg).unwrap();
        for (x, &y) in xs.iter().zip(&ys) {
            gp.observe(x, y).unwrap();
        }
        let best = ys.iter().cloned().fold(f64::INFINITY, f64::min);
        let before = gp.acquire(&epool, &cands, best).unwrap();

        let liar = gp.ys().iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        for f in &fantasies {
            gp.fantasize(f, liar).unwrap();
        }
        assert_eq!(gp.len(), xs.len() + fantasies.len());
        // The fantasies must actually be in scope: the acquisition with
        // the liars pushed differs from the clean one.
        let during = gp.acquire(&epool, &cands, best).unwrap();
        assert_ne!(bits(&before.0), bits(&during.0), "fantasies must move EI (width {width})");
        for _ in 0..fantasies.len() {
            gp.pop_fantasy().unwrap();
        }

        assert_eq!(gp.len(), xs.len(), "width {width}");
        assert_eq!(bits(gp.ys()), bits(&ys), "width {width}");
        let after = gp.acquire(&epool, &cands, best).unwrap();
        assert_eq!(bits(&before.0), bits(&after.0), "ei, width {width}");
        assert_eq!(bits(&before.1), bits(&after.1), "mu, width {width}");
        assert_eq!(bits(&before.2), bits(&after.2), "sigma, width {width}");
    }
}

/// Same equivalence across the N_TRAIN cap: n_init 250 + 10 iterations
/// forces evictions (kernel-cache removal + Cholesky rebuild) from
/// iteration 7 on.
#[test]
fn bo_tune_result_identical_across_n_train_eviction() {
    let n_init = N_TRAIN - 6;
    let iters = 10; // crosses the cap at iteration 7
    let reference = run_bo(SurrogateMode::OneShot, 1, n_init, iters);
    assert_eq!(reference.history.len(), n_init + iters);
    for width in [1usize, 2, 8] {
        let inc = run_bo(SurrogateMode::Session, width, n_init, iters);
        assert_results_identical(&reference, &inc, &format!("eviction width {width}"));
    }
}
