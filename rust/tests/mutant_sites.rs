//! Static checks on the mutation-site scanner against the *real* kernel
//! sources: every target file yields sites, every operator fires
//! somewhere, and every smoke pin resolves.  This runs in plain
//! `cargo test` (no mutant builds), so pin rot — editing a pinned kernel
//! line without re-pointing the pin — fails tier-1 immediately instead of
//! waiting for the next `mutant-hunter --smoke` run.

use std::collections::BTreeSet;
use std::path::PathBuf;

use onestoptuner::mutate::{pinned, resolve_pin, scan_source, Op, Site, TARGET_FILES};

/// Repo root = parent of the crate dir (`rust/`).
fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ crate dir has a parent")
        .to_path_buf()
}

fn scan_all() -> Vec<Site> {
    let root = repo_root();
    let mut sites = Vec::new();
    for file in TARGET_FILES {
        let src = std::fs::read_to_string(root.join(file))
            .unwrap_or_else(|e| panic!("reading {file}: {e}"));
        sites.extend(scan_source(file, &src));
    }
    sites
}

#[test]
fn every_target_file_yields_sites() {
    let sites = scan_all();
    for file in TARGET_FILES {
        let n = sites.iter().filter(|s| s.file == file).count();
        assert!(n > 0, "{file}: scanner found no mutation sites");
        // A kernel file with only a handful of sites would mean the
        // scanner regressed (masking everything, or stopping early).
        assert!(n >= 10, "{file}: only {n} sites — scanner regression?");
    }
}

#[test]
fn every_operator_fires_somewhere() {
    let sites = scan_all();
    let seen: BTreeSet<&str> = sites.iter().map(|s| s.op.label()).collect();
    for op in Op::ALL {
        assert!(
            seen.contains(op.label()),
            "operator {op} matched nothing across the target files"
        );
    }
}

#[test]
fn all_smoke_pins_resolve_and_mutate() {
    let sites = scan_all();
    for pin in pinned() {
        let site = resolve_pin(&pin, &sites)
            .unwrap_or_else(|e| panic!("smoke pin must resolve: {e:#}"));
        let src = std::fs::read_to_string(repo_root().join(site.file)).unwrap();
        let mutated = onestoptuner::mutate::scanner::apply(&src, site);
        assert_ne!(mutated, src, "pin {} produced an identical source", pin.id);
        assert_eq!(
            mutated.lines().count(),
            src.lines().count(),
            "pin {} changed the line count (mutations are in-line)",
            pin.id
        );
        // The replacement sits exactly at the site's byte offset.
        let window = &mutated[site.byte_start..site.byte_start + site.replacement.len()];
        assert_eq!(window, site.replacement, "pin {}", pin.id);
    }
}

#[test]
fn sites_are_sorted_and_unique_ids() {
    let sites = scan_all();
    for file in TARGET_FILES {
        let per: Vec<&Site> = sites.iter().filter(|s| s.file == file).collect();
        let n = per.len();
        // id = file:line:col:op can repeat when one operator offers two
        // replacements at the same spot; (id, replacement) must not.
        let mut full: Vec<String> =
            per.iter().map(|s| format!("{}->{}", s.id(), s.replacement)).collect();
        full.sort_unstable();
        full.dedup();
        assert_eq!(full.len(), n, "{file}: duplicate (site, replacement) pair");
    }
}
