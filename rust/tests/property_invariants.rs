//! Property-based tests over the coordinator's core invariants (hand-rolled
//! generator sweep — the offline image has no proptest crate): randomized
//! inputs over many seeds, asserting the invariants the pipeline relies on.

use onestoptuner::flags::{FeatureEncoder, FlagConfig, GcMode, Kind};
use onestoptuner::jvmsim::{self, JvmParams, MutatorLoad};
use onestoptuner::tuner::TuneSpace;
use onestoptuner::util::json::Json;
use onestoptuner::util::rng::Pcg;
use onestoptuner::util::sobol::Sobol;
use onestoptuner::{Benchmark, SparkRunner};

const CASES: u64 = 60;

fn modes() -> [GcMode; 2] {
    [GcMode::ParallelGC, GcMode::G1GC]
}

#[test]
fn prop_config_unit_roundtrip_is_projection() {
    // from_unit(to_unit(c)) must be idempotent: applying it twice equals
    // applying it once (quantization is a projection).
    for seed in 0..CASES {
        let mut rng = Pcg::new(seed);
        for mode in modes() {
            let c = FlagConfig::random(mode, &mut rng);
            let once = FlagConfig::from_unit(mode, &c.to_unit());
            let twice = FlagConfig::from_unit(mode, &once.to_unit());
            assert_eq!(once, twice, "seed {seed} {}", mode.name());
        }
    }
}

#[test]
fn prop_encoded_features_bounded() {
    // All features live in [0, 1]: unit values plus squares of unit values.
    for seed in 0..CASES {
        let mut rng = Pcg::new(1000 + seed);
        for mode in modes() {
            let enc = FeatureEncoder::new(mode);
            let c = FlagConfig::random(mode, &mut rng);
            let f = enc.encode(&c);
            assert_eq!(f.len(), enc.n_features());
            assert!(
                f.iter().all(|&v| (-1e-12..=1.0 + 1e-12).contains(&v)),
                "seed {seed}"
            );
        }
    }
}

#[test]
fn prop_flag_values_always_in_catalog_range() {
    for seed in 0..CASES {
        let mut rng = Pcg::new(2000 + seed);
        for mode in modes() {
            let c = FlagConfig::random(mode, &mut rng);
            for (f, &v) in c.defs().iter().zip(&c.values) {
                match f.kind {
                    Kind::Bool { .. } => assert!(v == 0.0 || v == 1.0),
                    Kind::Int { min, max, .. } => {
                        assert!((min..=max).contains(&v), "{} = {v}", f.name)
                    }
                }
            }
        }
    }
}

#[test]
fn prop_simulator_total_and_deterministic() {
    // Any random configuration terminates with finite, positive outputs and
    // identical results for identical seeds.
    for seed in 0..CASES / 2 {
        let mut rng = Pcg::new(3000 + seed);
        for mode in modes() {
            let cfg = FlagConfig::random(mode, &mut rng);
            let runner = SparkRunner::paper_default(Benchmark::DenseKMeans);
            let a = runner.run(&cfg, seed);
            let b = runner.run(&cfg, seed);
            assert!(a.exec_time_s.is_finite() && a.exec_time_s > 0.0);
            assert!(a.hu_avg_pct.is_finite() && a.hu_avg_pct >= 0.0);
            assert!(a.wall_clock_s <= a.exec_time_s + 1e-9);
            assert_eq!(a.exec_time_s, b.exec_time_s, "nondeterministic");
            assert_eq!(a.gc, b.gc);
        }
    }
}

#[test]
fn prop_jvm_pause_accounting_consistent() {
    // Total pause never exceeds wall time; max pause never exceeds total.
    for seed in 0..CASES {
        let mut rng = Pcg::new(4000 + seed);
        let cfg = FlagConfig::random(GcMode::ParallelGC, &mut rng);
        let p = JvmParams::derive(&cfg, 81920.0, 20.0);
        let load = MutatorLoad {
            work_core_s: 800.0,
            alloc_mb_per_core_s: 120.0,
            live_mb: 8000.0,
            cache_work_frac: 0.3,
            young_survival: 0.1,
            promote_frac: 0.2,
            humongous_mb_per_core_s: 1.0,
        };
        let r = jvmsim::run(&p, &load, 20.0, &mut Pcg::new(seed));
        assert!(r.gc.total_pause_ms / 1000.0 <= r.wall_s + 1e-6, "seed {seed}");
        assert!(r.gc.max_pause_ms <= r.gc.total_pause_ms + 1e-9);
        assert!(r.hu_avg_pct <= 100.0 + 1e-9, "HU {}", r.hu_avg_pct);
    }
}

#[test]
fn prop_tunespace_to_config_respects_unselected_flags() {
    // Tuning must never move a flag outside the selected subspace.
    for seed in 0..CASES {
        let mut rng = Pcg::new(5000 + seed);
        for mode in modes() {
            let enc = FeatureEncoder::new(mode);
            let k = 5 + rng.below(30);
            let selected = rng.sample_indices(enc.n_flags(), k);
            let mut space = TuneSpace::full(mode);
            space.selected = selected.clone();
            let u: Vec<f64> = (0..k).map(|_| rng.f64()).collect();
            let cfg = space.to_config(&u);
            let default = FlagConfig::default_for(mode);
            for (i, (a, b)) in cfg.values.iter().zip(&default.values).enumerate() {
                if !selected.contains(&i) {
                    assert_eq!(a, b, "unselected flag {i} moved (seed {seed})");
                }
            }
        }
    }
}

#[test]
fn prop_sobol_points_distinct_and_bounded() {
    for dim in [1usize, 3, 17, 64, 141] {
        let mut s = Sobol::new(dim);
        let pts = s.points(128);
        for (i, p) in pts.iter().enumerate() {
            assert!(p.iter().all(|&x| (0.0..1.0).contains(&x)), "dim {dim}");
            if i > 0 {
                assert_ne!(pts[i - 1], *p, "dup at {i} (dim {dim})");
            }
        }
    }
}

#[test]
fn prop_json_roundtrip_fuzz() {
    // Randomly generated JSON values survive emit -> parse.
    fn gen(rng: &mut Pcg, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool()),
            2 => Json::Num((rng.normal() * 1e3).round() / 8.0),
            3 => {
                let n = rng.below(12);
                Json::Str((0..n).map(|_| (b'a' + rng.below(26) as u8) as char).collect())
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..200 {
        let mut rng = Pcg::new(seed);
        let v = gen(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, v, "seed {seed}");
    }
}

#[test]
fn prop_dataset_csv_roundtrip_random() {
    use onestoptuner::datagen::Dataset;
    use onestoptuner::Metric;
    for seed in 0..10 {
        let mut rng = Pcg::new(7000 + seed);
        let mode = if rng.bool() { GcMode::G1GC } else { GcMode::ParallelGC };
        let enc = FeatureEncoder::new(mode);
        let n = 5 + rng.below(20);
        let mut unit_rows = Vec::new();
        let mut feat_rows = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let c = FlagConfig::random(mode, &mut rng);
            unit_rows.push(c.to_unit());
            feat_rows.push(enc.encode(&c));
            y.push(rng.uniform(10.0, 500.0));
        }
        let ds = Dataset { mode, metric: Metric::ExecTime, unit_rows, feat_rows, y };
        let back = Dataset::from_table(&ds.to_table(), mode, Metric::ExecTime).unwrap();
        assert_eq!(back.len(), ds.len());
        for (a, b) in back.y.iter().zip(&ds.y) {
            assert!((a - b).abs() < 1e-9);
        }
        // feature re-encoding from units must agree
        for (a, b) in back.feat_rows.iter().zip(&ds.feat_rows) {
            for (x, w) in a.iter().zip(b) {
                assert!((x - w).abs() < 1e-6);
            }
        }
    }
}
