//! Property-based tests over the coordinator's core invariants (hand-rolled
//! generator sweep — the offline image has no proptest crate): randomized
//! inputs over many seeds, asserting the invariants the pipeline relies on.

use onestoptuner::flags::{FeatureEncoder, FlagConfig, GcMode, Kind};
use onestoptuner::jvmsim::{self, JvmParams, MutatorLoad};
use onestoptuner::native::linalg::{
    cholesky, cholesky_downdate, cholesky_push, Mat, PackedDims, PackedLower,
};
use onestoptuner::tuner::TuneSpace;
use onestoptuner::util::stats::{argmax, argmin, summarize};
use onestoptuner::util::json::Json;
use onestoptuner::util::rng::Pcg;
use onestoptuner::util::sobol::Sobol;
use onestoptuner::{Benchmark, SparkRunner};

const CASES: u64 = 60;

fn modes() -> [GcMode; 2] {
    [GcMode::ParallelGC, GcMode::G1GC]
}

#[test]
fn prop_config_unit_roundtrip_is_projection() {
    // from_unit(to_unit(c)) must be idempotent: applying it twice equals
    // applying it once (quantization is a projection).
    for seed in 0..CASES {
        let mut rng = Pcg::new(seed);
        for mode in modes() {
            let c = FlagConfig::random(mode, &mut rng);
            let once = FlagConfig::from_unit(mode, &c.to_unit());
            let twice = FlagConfig::from_unit(mode, &once.to_unit());
            assert_eq!(once, twice, "seed {seed} {}", mode.name());
        }
    }
}

#[test]
fn prop_encoded_features_bounded() {
    // All features live in [0, 1]: unit values plus squares of unit values.
    for seed in 0..CASES {
        let mut rng = Pcg::new(1000 + seed);
        for mode in modes() {
            let enc = FeatureEncoder::new(mode);
            let c = FlagConfig::random(mode, &mut rng);
            let f = enc.encode(&c);
            assert_eq!(f.len(), enc.n_features());
            assert!(
                f.iter().all(|&v| (-1e-12..=1.0 + 1e-12).contains(&v)),
                "seed {seed}"
            );
        }
    }
}

#[test]
fn prop_flag_values_always_in_catalog_range() {
    for seed in 0..CASES {
        let mut rng = Pcg::new(2000 + seed);
        for mode in modes() {
            let c = FlagConfig::random(mode, &mut rng);
            for (f, &v) in c.defs().iter().zip(&c.values) {
                match f.kind {
                    Kind::Bool { .. } => assert!(v == 0.0 || v == 1.0),
                    Kind::Int { min, max, .. } => {
                        assert!((min..=max).contains(&v), "{} = {v}", f.name)
                    }
                }
            }
        }
    }
}

#[test]
fn prop_simulator_total_and_deterministic() {
    // Any random configuration terminates with finite, positive outputs and
    // identical results for identical seeds.
    for seed in 0..CASES / 2 {
        let mut rng = Pcg::new(3000 + seed);
        for mode in modes() {
            let cfg = FlagConfig::random(mode, &mut rng);
            let runner = SparkRunner::paper_default(Benchmark::DenseKMeans);
            let a = runner.run(&cfg, seed);
            let b = runner.run(&cfg, seed);
            assert!(a.exec_time_s.is_finite() && a.exec_time_s > 0.0);
            assert!(a.hu_avg_pct.is_finite() && a.hu_avg_pct >= 0.0);
            assert!(a.wall_clock_s <= a.exec_time_s + 1e-9);
            assert_eq!(a.exec_time_s, b.exec_time_s, "nondeterministic");
            assert_eq!(a.gc, b.gc);
        }
    }
}

#[test]
fn prop_jvm_pause_accounting_consistent() {
    // Total pause never exceeds wall time; max pause never exceeds total.
    for seed in 0..CASES {
        let mut rng = Pcg::new(4000 + seed);
        let cfg = FlagConfig::random(GcMode::ParallelGC, &mut rng);
        let p = JvmParams::derive(&cfg, 81920.0, 20.0);
        let load = MutatorLoad {
            work_core_s: 800.0,
            alloc_mb_per_core_s: 120.0,
            live_mb: 8000.0,
            cache_work_frac: 0.3,
            young_survival: 0.1,
            promote_frac: 0.2,
            humongous_mb_per_core_s: 1.0,
        };
        let r = jvmsim::run(&p, &load, 20.0, &mut Pcg::new(seed));
        assert!(r.gc.total_pause_ms / 1000.0 <= r.wall_s + 1e-6, "seed {seed}");
        assert!(r.gc.max_pause_ms <= r.gc.total_pause_ms + 1e-9);
        assert!(r.hu_avg_pct <= 100.0 + 1e-9, "HU {}", r.hu_avg_pct);
    }
}

#[test]
fn prop_tunespace_to_config_respects_unselected_flags() {
    // Tuning must never move a flag outside the selected subspace.
    for seed in 0..CASES {
        let mut rng = Pcg::new(5000 + seed);
        for mode in modes() {
            let enc = FeatureEncoder::new(mode);
            let k = 5 + rng.below(30);
            let selected = rng.sample_indices(enc.n_flags(), k);
            let mut space = TuneSpace::full(mode);
            space.selected = selected.clone();
            let u: Vec<f64> = (0..k).map(|_| rng.f64()).collect();
            let cfg = space.to_config(&u);
            let default = FlagConfig::default_for(mode);
            for (i, (a, b)) in cfg.values.iter().zip(&default.values).enumerate() {
                if !selected.contains(&i) {
                    assert_eq!(a, b, "unselected flag {i} moved (seed {seed})");
                }
            }
        }
    }
}

/// Random well-conditioned SPD matrix (kernel-like: Gram + ridge).
fn random_spd(n: usize, rng: &mut Pcg) -> Mat {
    let rows: Vec<Vec<f64>> =
        (0..n).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let x = Mat::from_rows(&rows);
    let mut g = x.gram();
    for i in 0..n {
        *g.at_mut(i, i) += n as f64;
    }
    g
}

/// Factor an SPD matrix into a `PackedLower` via successive pushes.
fn packed_factor(a: &Mat) -> PackedLower {
    let mut l = PackedLower::new();
    for i in 0..a.rows {
        let krow: Vec<f64> = (0..=i).map(|j| a.at(i, j)).collect();
        assert!(cholesky_push(&mut l, &krow), "random SPD must factor");
    }
    l
}

#[test]
fn prop_packed_push_then_downdate_last_is_identity() {
    // Appending an observation and immediately deleting it must be a
    // bitwise no-op: downdate(last) has an empty rotation column and is a
    // pure truncation — the exact inverse of cholesky_push.
    for seed in 0..CASES {
        let mut rng = Pcg::new(8000 + seed);
        let n = 2 + rng.below(12);
        let a = random_spd(n + 1, &mut rng);
        let mut l = PackedLower::new();
        for i in 0..n {
            let krow: Vec<f64> = (0..=i).map(|j| a.at(i, j)).collect();
            assert!(cholesky_push(&mut l, &krow));
        }
        let before = l.clone();
        let krow: Vec<f64> = (0..=n).map(|j| a.at(n, j)).collect();
        assert!(cholesky_push(&mut l, &krow));
        cholesky_downdate(&mut l, n);
        assert_eq!(l, before, "seed {seed} n {n}");
    }
}

#[test]
fn prop_packed_downdate_matches_scratch_factor_of_reduced_kernel() {
    // Deleting row i via Givens rotations must equal the from-scratch
    // factor of the kernel with row/column i removed, to tolerance
    // (the rotations reorder the arithmetic, so bitwise equality is not
    // expected — 1e-8 relative is the documented downdate contract).
    for seed in 0..CASES {
        let mut rng = Pcg::new(8100 + seed);
        let n = 3 + rng.below(12);
        let a = random_spd(n, &mut rng);
        let idx = rng.below(n);
        let mut l = packed_factor(&a);
        cholesky_downdate(&mut l, idx);
        assert_eq!(l.n(), n - 1);
        let keep: Vec<usize> = (0..n).filter(|&r| r != idx).collect();
        let mut sub = Mat::zeros(n - 1, n - 1);
        for (i, &ri) in keep.iter().enumerate() {
            for (j, &rj) in keep.iter().enumerate() {
                *sub.at_mut(i, j) = a.at(ri, rj);
            }
        }
        let dense = cholesky(&sub).expect("reduced SPD must factor");
        for i in 0..n - 1 {
            for j in 0..=i {
                let (got, want) = (l.at(i, j), dense.at(i, j));
                assert!(
                    (got - want).abs() <= 1e-8 * (1.0 + want.abs()),
                    "seed {seed} idx {idx} ({i},{j}): {got} vs {want}"
                );
            }
        }
    }
}

#[test]
fn prop_packed_downdate_never_produces_nan_on_spd() {
    // Every Givens pivot on a valid factor has r = hypot(d, v) >= d > 0,
    // so SPD inputs can never push a NaN (or a non-positive diagonal)
    // into the factor, no matter how many deletions run back-to-back.
    for seed in 0..CASES / 2 {
        let mut rng = Pcg::new(8200 + seed);
        let n = 4 + rng.below(12);
        let a = random_spd(n, &mut rng);
        let mut l = packed_factor(&a);
        while l.n() > 1 {
            cholesky_downdate(&mut l, rng.below(l.n()));
            for i in 0..l.n() {
                assert!(l.at(i, i) > 0.0, "seed {seed}: diagonal must stay positive");
                for j in 0..=i {
                    assert!(l.at(i, j).is_finite(), "seed {seed}: NaN/inf at ({i},{j})");
                }
            }
        }
    }
}

#[test]
fn prop_packed_accessor_boundary_roundtrip() {
    // at/at_mut honor the packed layout across the whole documented
    // `j <= i < n` triangle, including the boundary entries (diagonal
    // j == i, last row i == n-1): values written through at_mut come back
    // via at and row(), so no entry aliases another.  PackedDims gets the
    // same sweep over its d-blocks.
    for seed in 0..CASES {
        let mut rng = Pcg::new(8600 + seed);
        let n = 1 + rng.below(8);
        let mut l = PackedLower::new();
        for i in 0..n {
            let zeros = vec![0.0; i + 1];
            l.push_row(&zeros);
        }
        for i in 0..n {
            for j in 0..=i {
                *l.at_mut(i, j) = (i * (i + 1) / 2 + j) as f64;
            }
        }
        for i in 0..n {
            for j in 0..=i {
                let want = (i * (i + 1) / 2 + j) as f64;
                assert_eq!(l.at(i, j), want, "seed {seed} ({i},{j})");
                assert_eq!(l.row(i)[j], want, "seed {seed} row ({i},{j})");
            }
        }

        let d = 1 + rng.below(3);
        let mut pd = PackedDims::new(d);
        for i in 0..n {
            let flat: Vec<f64> = (0..=i)
                .flat_map(|j| (0..d).map(move |k| ((i * (i + 1) / 2 + j) * d + k) as f64))
                .collect();
            pd.push_row(&flat);
        }
        assert_eq!(pd.dims(), d);
        for i in 0..n {
            for j in 0..=i {
                let want: Vec<f64> =
                    (0..d).map(|k| ((i * (i + 1) / 2 + j) * d + k) as f64).collect();
                assert_eq!(pd.at(i, j), &want[..], "seed {seed} dims ({i},{j})");
            }
        }
    }
}

#[test]
fn prop_mat_remove_row_edge_indices() {
    // Direct splice contract for Mat::remove_row at the boundary indices —
    // first row, last row, the singleton matrix — plus a random interior
    // row, against a Vec-of-rows reference.
    for seed in 0..CASES {
        let mut rng = Pcg::new(8300 + seed);
        let rows = 1 + rng.below(8);
        let cols = 1 + rng.below(5);
        let reference: Vec<Vec<f64>> = (0..rows)
            .map(|i| (0..cols).map(|j| (i * cols + j) as f64 + rng.f64()).collect())
            .collect();
        let mut idxs = vec![0, rows - 1];
        if rows > 1 {
            idxs.push(rng.below(rows));
        }
        for idx in idxs {
            let mut m = Mat::from_rows(&reference);
            m.remove_row(idx);
            let mut want = reference.clone();
            want.remove(idx);
            assert_eq!(m.rows, rows - 1, "seed {seed} idx {idx}");
            assert_eq!(m.cols, cols);
            for (i, wr) in want.iter().enumerate() {
                assert_eq!(m.row(i), &wr[..], "seed {seed} idx {idx} row {i}");
            }
        }
    }
}

#[test]
fn prop_packed_remove_edge_indices() {
    // PackedLower::remove must splice exactly row/column idx and nothing
    // else — checked entry-by-entry against the pre-removal triangle at
    // first/last/singleton and a random interior index.  (Values are all
    // distinct, so keeping a wrong column cannot pass by coincidence.)
    for seed in 0..CASES {
        let mut rng = Pcg::new(8400 + seed);
        let n = 1 + rng.below(8);
        let mut l = PackedLower::new();
        for i in 0..n {
            let row: Vec<f64> =
                (0..=i).map(|j| (i * (i + 1) / 2 + j) as f64 + rng.f64() * 0.5).collect();
            l.push_row(&row);
        }
        let dense: Vec<Vec<f64>> = (0..n).map(|i| l.row(i).to_vec()).collect();
        let mut idxs = vec![0, n - 1];
        if n > 1 {
            idxs.push(rng.below(n));
        }
        for idx in idxs {
            let mut p = l.clone();
            p.remove(idx);
            assert_eq!(p.n(), n - 1, "seed {seed} idx {idx}");
            let keep: Vec<usize> = (0..n).filter(|&r| r != idx).collect();
            for (i, &ri) in keep.iter().enumerate() {
                for (j, &rj) in keep.iter().enumerate().take(i + 1) {
                    assert_eq!(
                        p.at(i, j),
                        dense[ri][rj],
                        "seed {seed} idx {idx} ({i},{j}) <- ({ri},{rj})"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_packed_dims_remove_edge_indices() {
    // PackedDims::remove, same splice contract as PackedLower::remove but
    // over d-blocks (copy_within instead of element moves).
    for seed in 0..CASES {
        let mut rng = Pcg::new(8500 + seed);
        let n = 1 + rng.below(6);
        let d = 1 + rng.below(4);
        let mut pd = PackedDims::new(d);
        let mut dense: Vec<Vec<Vec<f64>>> = Vec::new();
        for i in 0..n {
            let mut flat = Vec::new();
            let mut drow = Vec::new();
            for j in 0..=i {
                let block: Vec<f64> =
                    (0..d).map(|k| ((i * 31 + j) * 7 + k) as f64 + rng.f64()).collect();
                flat.extend_from_slice(&block);
                drow.push(block);
            }
            pd.push_row(&flat);
            dense.push(drow);
        }
        let mut idxs = vec![0, n - 1];
        if n > 1 {
            idxs.push(rng.below(n));
        }
        for idx in idxs {
            let mut p = pd.clone();
            p.remove(idx);
            assert_eq!(p.n(), n - 1, "seed {seed} idx {idx}");
            let keep: Vec<usize> = (0..n).filter(|&r| r != idx).collect();
            for (i, &ri) in keep.iter().enumerate() {
                for (j, &rj) in keep.iter().enumerate().take(i + 1) {
                    assert_eq!(
                        p.at(i, j),
                        &dense[ri][rj][..],
                        "seed {seed} idx {idx} ({i},{j}) <- ({ri},{rj})"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_summarize_matches_naive_reference() {
    // summarize against an inline two-pass reference (mean, then
    // Bessel-corrected variance): any drift in the divisor or the
    // accumulation shows up immediately.  n >= 2 so the n-1 divisor is
    // always on the live path.
    for seed in 0..CASES {
        let mut rng = Pcg::new(8700 + seed);
        let n = 2 + rng.below(20);
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform(-50.0, 50.0)).collect();
        let s = summarize(&xs);
        let mean: f64 = xs.iter().sum::<f64>() / n as f64;
        let mut var = 0.0;
        for x in &xs {
            var += (x - mean) * (x - mean);
        }
        var /= (n as f64) - 1.0;
        assert_eq!(s.n, n, "seed {seed}");
        assert!((s.mean - mean).abs() <= 1e-12 * (1.0 + mean.abs()), "seed {seed}");
        assert!(
            (s.std - var.sqrt()).abs() <= 1e-9 * (1.0 + var.sqrt()),
            "seed {seed}: std {} vs {}",
            s.std,
            var.sqrt()
        );
        let mn = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let mx = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(s.min, mn, "seed {seed}");
        assert_eq!(s.max, mx, "seed {seed}");
    }
}

#[test]
fn prop_argminmax_match_naive_reference() {
    // Discrete values from {0..3} force ties on nearly every seed, so the
    // first-occurrence tie-break is always exercised; NaN injection checks
    // the skip path.  Reference: first strict optimum among non-NaN
    // entries, index 0 when none exist.
    for seed in 0..CASES {
        let mut rng = Pcg::new(8800 + seed);
        let n = 1 + rng.below(12);
        let xs: Vec<f64> = (0..n)
            .map(|_| if rng.below(6) == 0 { f64::NAN } else { rng.below(4) as f64 })
            .collect();
        let mut lo: Option<(usize, f64)> = None;
        let mut hi: Option<(usize, f64)> = None;
        for (i, x) in xs.iter().enumerate() {
            if x.is_nan() {
                continue;
            }
            match lo {
                Some((_, v)) if v <= *x => {}
                _ => lo = Some((i, *x)),
            }
            match hi {
                Some((_, v)) if v >= *x => {}
                _ => hi = Some((i, *x)),
            }
        }
        let want_min = match lo {
            Some((i, _)) => i,
            None => 0,
        };
        let want_max = match hi {
            Some((i, _)) => i,
            None => 0,
        };
        assert_eq!(argmin(&xs), want_min, "seed {seed} {xs:?}");
        assert_eq!(argmax(&xs), want_max, "seed {seed} {xs:?}");
    }
}

#[test]
fn prop_sobol_points_distinct_and_bounded() {
    for dim in [1usize, 3, 17, 64, 141] {
        let mut s = Sobol::new(dim);
        let pts = s.points(128);
        for (i, p) in pts.iter().enumerate() {
            assert!(p.iter().all(|&x| (0.0..1.0).contains(&x)), "dim {dim}");
            if i > 0 {
                assert_ne!(pts[i - 1], *p, "dup at {i} (dim {dim})");
            }
        }
    }
}

#[test]
fn prop_json_roundtrip_fuzz() {
    // Randomly generated JSON values survive emit -> parse.
    fn gen(rng: &mut Pcg, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool()),
            2 => Json::Num((rng.normal() * 1e3).round() / 8.0),
            3 => {
                let n = rng.below(12);
                Json::Str((0..n).map(|_| (b'a' + rng.below(26) as u8) as char).collect())
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..200 {
        let mut rng = Pcg::new(seed);
        let v = gen(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, v, "seed {seed}");
    }
}

#[test]
fn prop_dataset_csv_roundtrip_random() {
    use onestoptuner::datagen::Dataset;
    use onestoptuner::Metric;
    for seed in 0..10 {
        let mut rng = Pcg::new(7000 + seed);
        let mode = if rng.bool() { GcMode::G1GC } else { GcMode::ParallelGC };
        let enc = FeatureEncoder::new(mode);
        let n = 5 + rng.below(20);
        let mut unit_rows = Vec::new();
        let mut feat_rows = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let c = FlagConfig::random(mode, &mut rng);
            unit_rows.push(c.to_unit());
            feat_rows.push(enc.encode(&c));
            y.push(rng.uniform(10.0, 500.0));
        }
        let ds = Dataset { mode, metric: Metric::ExecTime, unit_rows, feat_rows, y };
        let back = Dataset::from_table(&ds.to_table(), mode, Metric::ExecTime).unwrap();
        assert_eq!(back.len(), ds.len());
        for (a, b) in back.y.iter().zip(&ds.y) {
            assert!((a - b).abs() < 1e-9);
        }
        // feature re-encoding from units must agree
        for (a, b) in back.feat_rows.iter().zip(&ds.feat_rows) {
            for (x, w) in a.iter().zip(b) {
                assert!((x - w).abs() < 1e-6);
            }
        }
    }
}
