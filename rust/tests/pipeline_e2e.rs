//! End-to-end pipeline integration: all three phases composed, through the
//! XLA backend when artifacts are present (falling back to native), with
//! the paper's qualitative claims asserted at reduced budget:
//!   * the pipeline improves (or at least does not regress) the benchmark,
//!   * lasso prunes the flag group but keeps the dominant knobs,
//!   * DenseKMeans/ParallelGC shows the largest headroom,
//!   * RBO consumes far less benchmark time than iterating BO,
//!   * AL (BEMCM) converges at least as well as random selection.

use std::sync::Arc;

use onestoptuner::datagen::{characterize, DataGenConfig, Strategy};
use onestoptuner::pipeline::{run_pipeline, Algo, PipelineConfig};
use onestoptuner::runtime::{engine::XlaEngine, MlBackend, NativeBackend};
use onestoptuner::sparksim::SparkRunner;
use onestoptuner::tuner::bo::BoConfig;
use onestoptuner::tuner::sa::SaConfig;
use onestoptuner::{Benchmark, GcMode, Metric};

fn backend() -> Arc<dyn MlBackend> {
    match XlaEngine::load("artifacts") {
        Ok(e) => Arc::new(e),
        Err(_) => Arc::new(NativeBackend),
    }
}

fn small_config() -> PipelineConfig {
    PipelineConfig {
        datagen: DataGenConfig {
            pool_size: 400,
            seed_runs: 30,
            test_runs: 12,
            batch_k: 22,
            max_rounds: 6,
            rmse_rel_tol: 0.0,
            ridge: 1e-3,
            seed: 1234,
        },
        lambda: 0.01,
        bo: BoConfig { n_init: 6, n_candidates: 512, ..Default::default() },
        sa: SaConfig::default(),
        tune_iters: 14,
        repeats: 5,
        seed: 99,
    }
}

#[test]
fn dk_parallelgc_pipeline_beats_default() {
    let out = run_pipeline(
        Benchmark::DenseKMeans,
        GcMode::ParallelGC,
        Metric::ExecTime,
        &[Algo::BoWarm, Algo::Sa],
        &small_config(),
        &backend(),
    )
    .unwrap();

    // Lasso pruned but kept a meaningful subset including a dominant
    // heap/GC knob (otherwise the tuner cannot fix the full-GC pressure).
    assert!(out.selection.n_selected() > 20);
    assert!(out.selection.n_selected() < out.selection.group_size);
    assert!(
        out.selection.names.iter().any(|n| n == "MaxHeapSize"
            || n == "MaxNewSize"
            || n == "NewRatio"),
        "no dominant heap flag kept: {:?}",
        out.selection.names
    );

    // The GC-bound case must show real improvement even at reduced budget.
    let warm = &out.outcomes[0];
    assert!(
        warm.improvement > 1.12,
        "DK/ParallelGC BO-warm improvement only {:.2}x",
        warm.improvement
    );
    // SA does not beat the BO-warm recommendation (paper shape; small
    // slack for the reduced test budget).
    assert!(out.outcomes[1].improvement <= warm.improvement + 0.2);
}

#[test]
fn rbo_is_cheap_and_sane() {
    let out = run_pipeline(
        Benchmark::Lda,
        GcMode::G1GC,
        Metric::ExecTime,
        &[Algo::Rbo],
        &small_config(),
        &backend(),
    )
    .unwrap();
    let rbo = &out.outcomes[0];
    // At most two real runs (surrogate pick + measured fallback).
    assert!(rbo.tune.evals <= 2, "evals {}", rbo.tune.evals);
    // Cheap: far less benchmark time than 10 BO iterations would burn.
    assert!(rbo.tune.sim_time_s < 600.0, "sim time {}", rbo.tune.sim_time_s);
    // Sane: not a catastrophic recommendation.
    assert!(rbo.improvement > 0.85, "improvement {:.2}", rbo.improvement);
}

#[test]
fn heap_usage_pipeline_reduces_hu() {
    let out = run_pipeline(
        Benchmark::DenseKMeans,
        GcMode::G1GC,
        Metric::HeapUsage,
        &[Algo::BoWarm],
        &small_config(),
        &backend(),
    )
    .unwrap();
    let warm = &out.outcomes[0];
    assert!(
        warm.tuned_summary.mean < out.default_summary.mean,
        "HU not reduced: {} -> {}",
        out.default_summary.mean,
        warm.tuned_summary.mean
    );
    // Tuned config still finishes (no OOM exploit).
    assert!(warm.tuned_summary.mean > 1.0);
}

#[test]
fn bemcm_converges_no_worse_than_random() {
    let runner = SparkRunner::paper_default(Benchmark::Lda);
    let b = backend();
    let dg = DataGenConfig {
        pool_size: 240,
        seed_runs: 24,
        test_runs: 16,
        batch_k: 18,
        max_rounds: 5,
        rmse_rel_tol: 0.0,
        ridge: 1e-3,
        seed: 777,
    };
    let al = characterize(&runner, GcMode::G1GC, Metric::ExecTime, Strategy::Bemcm, &dg, &b)
        .unwrap();
    let rnd = characterize(&runner, GcMode::G1GC, Metric::ExecTime, Strategy::Random, &dg, &b)
        .unwrap();
    // The paper's claim is about convergence *speed*: BEMCM must reach the
    // random strategy's final RMSE in no more rounds than random took
    // (usually far fewer — Fig 5 / the 70%-fewer-runs claim).
    let rnd_final = *rnd.rmse_history.last().unwrap();
    let al_reach = al
        .rmse_history
        .iter()
        .position(|&r| r <= rnd_final * 1.05)
        .unwrap_or(al.rmse_history.len());
    assert!(
        al_reach <= rnd.rmse_history.len() - 1,
        "BEMCM never reached random's final RMSE {rnd_final:.2} (history {:?})",
        al.rmse_history
    );
}

#[test]
fn characterization_runs_are_accounted() {
    let runner = SparkRunner::paper_default(Benchmark::Lda);
    let b = backend();
    let dg = DataGenConfig {
        pool_size: 100,
        seed_runs: 10,
        test_runs: 6,
        batch_k: 8,
        max_rounds: 2,
        rmse_rel_tol: 0.0,
        ridge: 1e-3,
        seed: 5,
    };
    let r = characterize(&runner, GcMode::ParallelGC, Metric::ExecTime, Strategy::Qbc, &dg, &b)
        .unwrap();
    // runs = default (cap calibration) + seed + test + labelled batches
    assert_eq!(r.runs_executed, 1 + 10 + 6 + r.rounds * 8);
    assert_eq!(r.dataset.len(), 10 + r.rounds * 8);
    assert!(r.sim_time_s > 0.0);
}
