//! ARD differential suite: finite-difference validation of the analytic
//! d+1-parameter marginal-likelihood gradient, per-dimension
//! distance-cache integrity under append/evict churn, monotone ML traces
//! under ARD adaptation, and relevance ranking of a planted irrelevant
//! dimension — the acceptance surface of the per-dimension length-scale
//! refactor.
//!
//! # Tolerance policy
//!
//! The gradient check compares the analytic
//! `∂L/∂θ = ½ tr((ααᵀ − K⁻¹) ∂K/∂θ)` against **central finite
//! differences** of the log marginal likelihood in log-hyper space with
//! step `H = 1e-5`.  Central differences have truncation error
//! `O(H²·|∂³L|) ≈ 1e-10·|∂³L|` and round-off error `O(ε·|L|/H)`: with
//! `|L| = O(n) ≈ 30` and a Cholesky-evaluated likelihood accurate to
//! ~1e-12 relative, the round-off term sits near 1e-7.  `GRAD_TOL = 1e-4`
//! (absolute + relative) leaves three orders of magnitude of slack over
//! both terms, so a failure means a wrong gradient, not numerics.
//! Everything else in this file is exact: the distance cache is pinned
//! **bitwise** against direct recomputation, and ML traces are strict
//! inequalities per accepted step.

use onestoptuner::exec::ExecPool;
use onestoptuner::featsel::ard_relevance;
use onestoptuner::native::gp::GpSurrogate;
use onestoptuner::runtime::{GpConfig, GpSession, HyperMode, KernelPolicy};
use onestoptuner::util::rng::Pcg;
use onestoptuner::util::stats::{argmax, argmin};

const H: f64 = 1e-5;
const GRAD_TOL: f64 = 1e-4;

fn rand_rows(n: usize, d: usize, rng: &mut Pcg) -> Vec<Vec<f64>> {
    (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect()
}

/// Adapt-mode config whose automatic cadence never triggers, so the tests
/// drive `adapt()` (or just the cache/gradient surface) explicitly.
fn ard_cfg(d: usize, cap: usize) -> GpConfig {
    GpConfig {
        dim: d,
        lengthscales: vec![0.6; d],
        sigma_f2: 1.0,
        sigma_n2: 0.01,
        cap,
        hyper: HyperMode::Adapt { every: usize::MAX },
        ard: true,
        kernels: KernelPolicy::Scalar,
    }
}

fn assert_grad_close(analytic: f64, fd: f64, tag: &str) {
    assert!(analytic.is_finite(), "{tag}: analytic gradient not finite");
    assert!(fd.is_finite(), "{tag}: finite difference not finite");
    assert!(
        (analytic - fd).abs() <= GRAD_TOL * (1.0 + fd.abs()),
        "{tag}: analytic {analytic} vs central FD {fd} (|Δ| = {:e})",
        (analytic - fd).abs()
    );
}

/// The analytic ARD gradient (d+1 entries: ln ℓ₁..ln ℓ_d, ln σₙ²) must
/// match central finite differences of the log marginal likelihood on
/// seeded problems with deliberately unequal length-scales.
#[test]
fn ard_gradient_matches_central_finite_differences() {
    for seed in [0x41u64, 0x42, 0x43] {
        let d = 4;
        let mut c = ard_cfg(d, 64);
        // Unequal scales: exercise every per-dimension term.
        c.lengthscales = vec![0.35, 0.8, 1.6, 0.5];
        let mut gp = GpSurrogate::new(&c);
        let mut rng = Pcg::new(seed);
        for x in rand_rows(28, d, &mut rng) {
            let y = (x[0] * 5.0).sin() + 0.7 * x[1] - x[2] * x[3];
            gp.observe(&x, y).unwrap();
        }
        let g = gp.ml_gradient_now();
        assert_eq!(g.len(), d + 1, "ARD gradient is d+1 parameters");
        let (ls, s2n) = gp.hypers();
        for j in 0..d {
            let mut up = ls.clone();
            let mut dn = ls.clone();
            up[j] = (ls[j].ln() + H).exp();
            dn[j] = (ls[j].ln() - H).exp();
            let fd = (gp.log_marginal_at(&up, s2n).unwrap()
                - gp.log_marginal_at(&dn, s2n).unwrap())
                / (2.0 * H);
            assert_grad_close(g[j], fd, &format!("seed {seed:#x}, ln l_{j}"));
        }
        let fd_noise = (gp.log_marginal_at(&ls, (s2n.ln() + H).exp()).unwrap()
            - gp.log_marginal_at(&ls, (s2n.ln() - H).exp()).unwrap())
            / (2.0 * H);
        assert_grad_close(g[d], fd_noise, &format!("seed {seed:#x}, ln sigma_n2"));
    }
}

/// The tied (ARD-off) gradient is 2 parameters; its length-scale entry
/// must equal the finite difference of shifting *every* dimension by the
/// same log step — the sum of the per-dimension gradients.
#[test]
fn tied_gradient_matches_common_shift_finite_difference() {
    for seed in [0x51u64, 0x52] {
        let d = 3;
        let mut c = ard_cfg(d, 64);
        c.ard = false;
        // Tied but warm-started unequal: the general tied path.
        c.lengthscales = vec![0.4, 0.9, 1.3];
        let mut gp = GpSurrogate::new(&c);
        let mut rng = Pcg::new(seed);
        for x in rand_rows(26, d, &mut rng) {
            let y = (x[1] * 4.0).cos() + x[0];
            gp.observe(&x, y).unwrap();
        }
        let g = gp.ml_gradient_now();
        assert_eq!(g.len(), 2, "tied gradient is (ln l, ln sigma_n2)");
        let (ls, s2n) = gp.hypers();
        let up: Vec<f64> = ls.iter().map(|l| (l.ln() + H).exp()).collect();
        let dn: Vec<f64> = ls.iter().map(|l| (l.ln() - H).exp()).collect();
        let fd = (gp.log_marginal_at(&up, s2n).unwrap()
            - gp.log_marginal_at(&dn, s2n).unwrap())
            / (2.0 * H);
        assert_grad_close(g[0], fd, &format!("seed {seed:#x}, tied ln l"));
    }
}

/// Seeded property: after arbitrary append/evict churn, every cached
/// per-dimension squared distance equals direct recomputation from the
/// surviving training points — **bitwise** (the cache stores the exact
/// `(x_i - x_j)²` terms, in dimension order).
#[test]
fn distance_cache_matches_direct_recomputation_after_churn() {
    for seed in 0..12u64 {
        let mut rng = Pcg::new(0x9000 + seed);
        let d = 2 + (seed as usize % 4);
        let mut c = ard_cfg(d, 48);
        c.ard = seed % 2 == 0; // the cache is mode-independent
        let mut gp = GpSurrogate::new(&c);
        for x in rand_rows(14, d, &mut rng) {
            gp.observe(&x, rng.f64()).unwrap();
        }
        for _ in 0..20 {
            if gp.len() > 4 && rng.bool() {
                gp.forget(rng.below(gp.len())).unwrap();
            } else {
                let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
                gp.observe(&x, rng.f64()).unwrap();
            }
        }
        for i in 0..gp.len() {
            for j in 0..=i {
                let cached = gp.cached_sqdists(i, j);
                let (a, b) = (gp.point(i), gp.point(j));
                for k in 0..d {
                    let direct = (a[k] - b[k]) * (a[k] - b[k]);
                    assert_eq!(
                        cached[k].to_bits(),
                        direct.to_bits(),
                        "seed {seed} pair ({i},{j}) dim {k}"
                    );
                }
            }
        }
    }
}

/// ARD adaptation keeps a strictly increasing ML trace per accepted step
/// across rounds, stays inside the hyper box, and — on a synthetic
/// objective that depends on dims 0 and 1 but *not* on the planted dim 2
/// — ranks the irrelevant dimension last (longest length-scale, smallest
/// normalized relevance).
#[test]
fn ard_ranks_planted_irrelevant_dimension_last() {
    let d = 3;
    let mut c = ard_cfg(d, 64);
    c.lengthscales = vec![0.5; d];
    let mut gp = GpSurrogate::new(&c);
    let mut rng = Pcg::new(0xa4d);
    for x in rand_rows(32, d, &mut rng) {
        // x[2] is pure decoy: the response never reads it.  Both live
        // dims carry clear curvature, so their adapted scales stay short
        // while the decoy's grows toward the box.
        let y = (x[0] * 4.0).sin() + (x[1] * 3.0).cos();
        gp.observe(&x, y).unwrap();
    }
    let mut rounds = 0;
    loop {
        let out = gp.adapt();
        for w in out.ml.windows(2) {
            assert!(w[1] > w[0], "accepted steps must strictly increase ML: {:?}", out.ml);
        }
        rounds += 1;
        if out.steps == 0 || rounds >= 40 {
            break;
        }
    }
    let (ls, s2n) = gp.hypers();
    assert!(ls.iter().all(|l| (1e-2..=1e2).contains(l)), "out of box: {ls:?}");
    assert!((1e-8..=1.0).contains(&s2n), "noise out of box: {s2n}");
    assert!(
        ls[2] > ls[0] && ls[2] > ls[1],
        "irrelevant dim must get the longest length-scale: {ls:?}"
    );
    let rel = ard_relevance(&ls);
    assert!((rel.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    assert_eq!(argmin(&rel), 2, "irrelevant dim must rank last: {rel:?}");
    assert_ne!(argmax(&rel), 2);
}

/// ARD adaptation composed with downdate evictions (the full Adapt-mode
/// regime) keeps the session healthy: finite posteriors at every step,
/// per-dimension scales inside the box, and a usable factor throughout.
#[test]
fn ard_adaptation_with_evictions_stays_healthy() {
    let d = 4;
    let cap = 20;
    let mut c = ard_cfg(d, cap);
    c.hyper = HyperMode::Adapt { every: 4 };
    let mut gp = GpSurrogate::new(&c);
    let mut rng = Pcg::new(0xa4e);
    let synth = |r: &[f64]| (r[0] * 4.0).sin() + r[1] * r[2];
    for x in rand_rows(cap, d, &mut rng) {
        let y = synth(&x);
        gp.observe(&x, y).unwrap();
    }
    let cands = rand_rows(40, d, &mut rng);
    let pool = ExecPool::new(2);
    for _ in 0..25 {
        gp.forget(argmax(gp.ys())).unwrap();
        let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
        gp.observe(&x, synth(&x)).unwrap();
        let (ei, mu, sigma) = gp.acquire(&pool, &cands, 0.0).unwrap();
        for v in ei.iter().chain(&mu).chain(&sigma) {
            assert!(v.is_finite());
        }
    }
    let (ls, s2n) = gp.hypers();
    assert!(ls.iter().all(|l| (1e-2..=1e2).contains(l)));
    assert!((1e-8..=1.0).contains(&s2n));
}
