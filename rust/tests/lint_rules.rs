//! Unit tier for the detlint determinism lint: the shared masking
//! module (byte-offset preservation across strings, raw strings and
//! comments) and one positive + one negative snippet per rule, plus the
//! allow-annotation workflow (suppression, mandatory reason, unknown
//! rule, staleness).  Everything runs in-memory through
//! `lint::rules::scan_source` — no scratch workspace, plain `cargo test`.

use onestoptuner::lint::rules::scan_source;
use onestoptuner::lint::{FileScan, Rule};
use onestoptuner::util::source::{mask_source, Masker};

/// Fake repo-relative path in ordinary (unexempt) territory.
const PLAIN: &str = "rust/src/tuner/fake.rs";

fn findings_of(scan: &FileScan, rule: Rule) -> Vec<usize> {
    scan.findings.iter().filter(|f| f.rule == rule).map(|f| f.line).collect()
}

// ---- masking ----------------------------------------------------------

#[test]
fn masking_blanks_string_contents_at_exact_offsets() {
    let line = r#"    call("HashMap iter inside string", x); // HashMap comment"#;
    let masked = Masker::new().mask_line(line);
    assert_eq!(masked.len(), line.len(), "masking must preserve byte length");
    assert!(!masked.contains("HashMap"), "string/comment text leaked: {masked}");
    // the code part survives at identical offsets
    let i = masked.find("call(").unwrap();
    assert_eq!(&line[i..i + 5], "call(");
    let j = masked.find(", x)").unwrap();
    assert_eq!(&line[j..j + 4], ", x)");
}

#[test]
fn masking_handles_raw_strings_with_hashes() {
    let line = r###"    let p = r##"Instant::now() "quoted" here"##; let q = 1;"###;
    let masked = Masker::new().mask_line(line);
    assert_eq!(masked.len(), line.len());
    assert!(!masked.contains("Instant::now"));
    assert!(masked.contains("let q = 1;"), "code after raw string lost: {masked}");
}

#[test]
fn masking_carries_state_across_multiline_strings() {
    let src = "let s = \"first\nInstant::now() still in string\n\"; let t = 2;\n";
    let lines = mask_source(src);
    assert_eq!(lines.len(), 3);
    assert!(!lines[1].contains("Instant::now"), "multi-line string leaked: {}", lines[1]);
    assert!(lines[2].contains("let t = 2;"));
}

#[test]
fn banned_tokens_inside_strings_and_comments_do_not_fire() {
    let src = concat!(
        "fn f() {\n",
        "    let a = \"Instant::now() thread_rng SystemTime\";\n",
        "    // Instant::now() in a comment\n",
        "    let b = r#\"thread::spawn in a raw string\"#;\n",
        "}\n",
    );
    let scan = scan_source(PLAIN, src);
    assert!(scan.findings.is_empty(), "masked text fired: {:?}", scan.findings);
}

// ---- hash-iter --------------------------------------------------------

#[test]
fn hash_iter_flags_iteration_not_declaration_or_lookup() {
    let src = concat!(
        "use std::collections::HashMap;\n",
        "fn f() {\n",
        "    let mut m: HashMap<u64, f64> = HashMap::new();\n",
        "    m.insert(1, 2.0);\n",
        "    let v = m.get(&1).copied();\n",
        "    for (k, x) in m.iter() {\n",
        "        let _ = (k, x, v);\n",
        "    }\n",
        "    let ks: Vec<u64> = m.keys().copied().collect();\n",
        "}\n",
    );
    let scan = scan_source(PLAIN, src);
    assert_eq!(findings_of(&scan, Rule::HashIter), vec![6, 9]);
}

#[test]
fn hash_iter_does_not_blame_the_map_for_a_vec_values_iteration() {
    // `m.get(k)` yields a Vec; iterating *that* is deterministic.
    let src = concat!(
        "fn f(m: &std::collections::HashMap<String, Vec<u64>>) -> usize {\n",
        "    m.get(\"k\").map(|v| v.iter().count()).unwrap_or(0)\n",
        "}\n",
    );
    let scan = scan_source(PLAIN, src);
    assert!(scan.findings.is_empty(), "{:?}", scan.findings);
}

// ---- wall-clock -------------------------------------------------------

#[test]
fn wall_clock_flags_instant_and_systemtime_but_not_mutate() {
    let src = concat!(
        "use std::time::Instant;\n", // use line: declaration, not a read
        "fn f() -> f64 {\n",
        "    let t0 = Instant::now();\n",
        "    let wall = std::time::SystemTime::now();\n",
        "    let _ = wall;\n",
        "    t0.elapsed().as_secs_f64()\n",
        "}\n",
    );
    let scan = scan_source(PLAIN, src);
    assert_eq!(findings_of(&scan, Rule::WallClock), vec![3, 4]);
    // mutate/ measures real build/test timeouts: exempt by path scope
    let scan = scan_source("rust/src/mutate/runner.rs", src);
    assert!(findings_of(&scan, Rule::WallClock).is_empty());
}

// ---- ambient-rng ------------------------------------------------------

#[test]
fn ambient_rng_flags_entropy_constructors() {
    let src = concat!(
        "fn f() {\n",
        "    let s = std::collections::hash_map::RandomState::new();\n",
        "    let _ = s;\n",
        "}\n",
    );
    let scan = scan_source(PLAIN, src);
    assert_eq!(findings_of(&scan, Rule::AmbientRng), vec![2]);
    // the seeded discipline itself is fine
    let ok = "fn g() { let r = crate::util::rng::Pcg::seeded(7, 0); let _ = r; }\n";
    assert!(scan_source(PLAIN, ok).findings.is_empty());
}

// ---- thread-outside-exec ----------------------------------------------

#[test]
fn threads_flagged_outside_exec_and_mutate_only() {
    let src = "fn f() { std::thread::spawn(|| {}); }\n";
    assert_eq!(
        findings_of(&scan_source("rust/src/pipeline/mod.rs", src), Rule::ThreadOutsideExec),
        vec![1]
    );
    assert!(scan_source("rust/src/exec/mod.rs", src).findings.is_empty());
    assert!(scan_source("rust/src/mutate/runner.rs", src).findings.is_empty());
}

// ---- unordered-float-reduce -------------------------------------------

#[test]
fn float_reduce_flags_fanout_chains_and_shared_accumulators() {
    let src = concat!(
        "fn f(pool: &Pool) -> f64 {\n",
        "    let acc: std::sync::Mutex<f64> = std::sync::Mutex::new(0.0);\n",
        "    let s: f64 = pool.par_run(8, |i| i as f64).iter().sum();\n",
        "    let plain: f64 = vec![1.0, 2.0].iter().sum();\n", // ordered Vec: legal
        "    s + plain + *acc.lock().unwrap()\n",
        "}\n",
    );
    let scan = scan_source(PLAIN, src);
    assert_eq!(findings_of(&scan, Rule::UnorderedFloatReduce), vec![2, 3]);
    // the approved fixed-order reducers live in exec/ and util/stats.rs
    assert!(scan_source("rust/src/util/stats.rs", src)
        .findings
        .iter()
        .all(|f| f.rule != Rule::UnorderedFloatReduce));
}

// ---- lock-across-io ---------------------------------------------------

#[test]
fn lock_across_io_tracks_guard_lifetimes() {
    let src = concat!(
        "fn f(&self) {\n",
        "    let guard = self.state.lock().unwrap();\n",
        "    std::fs::write(\"/tmp/x\", \"y\").unwrap();\n", // under the guard
        "}\n",
        "fn g(&self) {\n",
        "    self.state.lock().unwrap().insert(1);\n", // temp guard dies at `;`
        "    std::fs::write(\"/tmp/x\", \"y\").unwrap();\n", // lock-free
        "}\n",
    );
    let scan = scan_source("rust/src/server/api.rs", src);
    assert_eq!(findings_of(&scan, Rule::LockAcrossIo), vec![3]);
    // outside server/ the rule does not apply at all
    assert!(scan_source(PLAIN, src).findings.is_empty());
}

#[test]
fn lock_across_io_guard_released_by_block_end() {
    let src = concat!(
        "fn f(&self) {\n",
        "    {\n",
        "        let guard = self.state.lock().unwrap();\n",
        "        let _ = &*guard;\n",
        "    }\n",
        "    std::fs::write(\"/tmp/x\", \"y\").unwrap();\n", // after the block
        "}\n",
    );
    let scan = scan_source("rust/src/server/api.rs", src);
    assert!(scan.findings.is_empty(), "{:?}", scan.findings);
}

// ---- allow workflow ---------------------------------------------------

#[test]
fn allow_with_reason_suppresses_trailing_and_standalone() {
    let src = concat!(
        "fn f() {\n",
        "    let t = std::time::Instant::now(); // detlint: allow(wall-clock) -- timing telemetry only\n",
        "    // detlint: allow(wall-clock) -- second site, standalone form\n",
        "    let u = std::time::Instant::now();\n",
        "    let _ = (t, u);\n",
        "}\n",
    );
    let scan = scan_source(PLAIN, src);
    assert!(scan.findings.is_empty(), "allows failed to suppress: {:?}", scan.findings);
    assert_eq!(scan.allows.len(), 2);
    assert!(scan.allows.iter().all(|a| a.rule == Rule::WallClock && !a.reason.is_empty()));
    assert!(scan.problems.is_empty() && scan.stale_allows.is_empty());
}

#[test]
fn allow_without_reason_or_with_unknown_rule_is_fatal() {
    let no_reason = "fn f() { let t = std::time::Instant::now(); } // detlint: allow(wall-clock)\n";
    let scan = scan_source(PLAIN, no_reason);
    assert_eq!(scan.problems.len(), 1, "{:?}", scan.problems);
    assert!(scan.problems[0].message.contains("reason"));

    let unknown = "// detlint: allow(no-such-rule) -- whatever\nfn f() {}\n";
    let scan = scan_source(PLAIN, unknown);
    assert_eq!(scan.problems.len(), 1);
    assert!(scan.problems[0].message.contains("unknown detlint rule"));
}

#[test]
fn stale_allow_is_reported_but_not_fatal() {
    let src = concat!(
        "// detlint: allow(wall-clock) -- nothing here reads a clock anymore\n",
        "fn f() -> u64 { 7 }\n",
    );
    let scan = scan_source(PLAIN, src);
    assert!(scan.findings.is_empty() && scan.problems.is_empty());
    assert_eq!(scan.stale_allows.len(), 1);
    assert_eq!(scan.stale_allows[0].rule, Rule::WallClock);
}

#[test]
fn detlint_marker_inside_a_string_is_not_an_annotation() {
    let src = concat!(
        "fn f() -> &'static str {\n",
        "    \"// detlint: allow(wall-clock)\"\n", // string literal, not a comment
        "}\n",
    );
    let scan = scan_source(PLAIN, src);
    assert!(scan.problems.is_empty(), "string content parsed as annotation: {:?}", scan.problems);
}

// ---- test exemption ---------------------------------------------------

#[test]
fn scanning_stops_at_cfg_test() {
    let src = concat!(
        "fn f() {}\n",
        "#[cfg(test)]\n",
        "mod tests {\n",
        "    fn t() { let t0 = std::time::Instant::now(); let _ = t0; }\n",
        "}\n",
    );
    let scan = scan_source(PLAIN, src);
    assert!(scan.findings.is_empty(), "tests are exempt: {:?}", scan.findings);
}
