//! Differential suite for the blocked linear-algebra kernel tier
//! (`KernelPolicy::Blocked`): the panel/lane multi-RHS solves, the
//! blocked Cholesky rebuild, and the fixed-lane kernel sums, pinned
//! against the bitwise scalar tier end-to-end.
//!
//! # Tolerance policy
//!
//! * `KernelPolicy::Scalar` (the default) is **bitwise** pinned to the
//!   pre-policy arithmetic by the existing suites
//!   (`tests/gp_incremental.rs`, `tests/gp_downdate.rs`,
//!   `tests/gp_ard.rs`) — nothing here re-tests it beyond using it as
//!   the reference.
//! * Direct solve/rebuild differentials (no session churn between them)
//!   are pinned at `DIRECT_TOL = 1e-10`: a single blocked reduction
//!   differs from its scalar twin only by the re-association of ~n
//!   additions, far below 1e-10 at the conditioning these factors have.
//! * Whole-session differentials (acquire/adapt/evict churn, where
//!   round-off compounds through refactors and hyper moves) are pinned
//!   at `TOL = 1e-8` (absolute + relative) — the same budget the
//!   downdate-vs-rebuild suite uses for its reordered arithmetic.
//! * `Blocked` is **bitwise self-reproducible**: every block size and
//!   reduction tree is an algorithm constant, so the same history gives
//!   the same bits at any `ExecPool` width — asserted directly at
//!   widths 1/2/3/8.

use onestoptuner::exec::ExecPool;
use onestoptuner::native::gp::GpSurrogate;
use onestoptuner::native::kernels::{
    cholesky_rebuild_blocked, lane_dot, lane_sum, solve_lower_multi, solve_lower_t_multi,
    sum_f32acc,
};
use onestoptuner::native::linalg::{cholesky_rebuild, PackedLower};
use onestoptuner::runtime::{GpConfig, GpSession, HyperMode, KernelPolicy};
use onestoptuner::util::rng::Pcg;
use onestoptuner::util::stats::argmax;

const TOL: f64 = 1e-8;
const DIRECT_TOL: f64 = 1e-10;

fn rand_rows(n: usize, d: usize, rng: &mut Pcg) -> Vec<Vec<f64>> {
    (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect()
}

fn cfg(d: usize, cap: usize, hyper: HyperMode, kernels: KernelPolicy) -> GpConfig {
    let mut c = GpConfig::isotropic(d, 0.7, 1.0, 0.01, cap, hyper);
    c.kernels = kernels;
    c
}

fn assert_close(a: &[f64], b: &[f64], tol: f64, tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.is_finite(), "{tag}[{i}] not finite: {x}");
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "{tag}[{i}]: {x} vs {y} (|Δ| = {:e})",
            (x - y).abs()
        );
    }
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// A random well-conditioned packed factor (unit-ish diagonal).
fn rand_factor(n: usize, rng: &mut Pcg) -> PackedLower {
    let mut l = PackedLower::new();
    let mut row = Vec::new();
    for i in 0..n {
        row.clear();
        for _ in 0..i {
            row.push(0.3 * rng.normal());
        }
        row.push(1.0 + rng.f64());
        l.push_row(&row);
    }
    l
}

/// Direct multi-RHS differential: the blocked forward and transposed
/// solves match the scalar-order ones within DIRECT_TOL over sizes that
/// straddle the panel width (32) and lane width (8), including m = 16 —
/// the EI block the tier was built for.  Deleting a lane accumulation or
/// shifting the transpose's panel start by one (the two mutation-smoke
/// pins on `native/kernels.rs`) breaks this test at every size.
#[test]
fn blocked_solves_match_scalar_directly() {
    let mut rng = Pcg::new(0x6b01);
    for &(n, m) in &[(5usize, 1usize), (5, 7), (33, 16), (64, 16), (64, 11), (80, 3)] {
        let l = rand_factor(n, &mut rng);
        let b: Vec<f64> = (0..n * m).map(|_| rng.normal()).collect();

        let mut fs = b.clone();
        let mut fb = b.clone();
        solve_lower_multi(&l, &mut fs, m, KernelPolicy::Scalar);
        solve_lower_multi(&l, &mut fb, m, KernelPolicy::Blocked);
        assert_close(&fb, &fs, DIRECT_TOL, &format!("fwd n={n} m={m}"));

        let mut ts = b.clone();
        let mut tb = b;
        solve_lower_t_multi(&l, &mut ts, m, KernelPolicy::Scalar);
        solve_lower_t_multi(&l, &mut tb, m, KernelPolicy::Blocked);
        assert_close(&tb, &ts, DIRECT_TOL, &format!("bwd n={n} m={m}"));
    }
}

/// Direct rebuild differential: `cholesky_rebuild_blocked` factors the
/// same packed kernels `cholesky_rebuild` does, within DIRECT_TOL, at
/// sizes below and above the panel width.
#[test]
fn blocked_rebuild_matches_scalar_directly() {
    let mut rng = Pcg::new(0x6b02);
    for &n in &[4usize, 31, 33, 70] {
        // K = G Gᵀ + I from a random factor G: PD by construction.
        let g = rand_factor(n, &mut rng);
        let mut k = PackedLower::new();
        let mut row = Vec::new();
        for i in 0..n {
            row.clear();
            for j in 0..=i {
                let mut s = 0.0;
                for t in 0..=j {
                    s += g.at(i, t) * g.at(j, t);
                }
                row.push(if i == j { s + 1.0 } else { s });
            }
            k.push_row(&row);
        }
        let mut ls = PackedLower::new();
        let mut lb = PackedLower::new();
        assert!(cholesky_rebuild(&k, &mut ls), "scalar rebuild must succeed (n={n})");
        assert!(cholesky_rebuild_blocked(&k, &mut lb), "blocked rebuild must succeed (n={n})");
        for i in 0..n {
            assert_close(lb.row(i), ls.row(i), DIRECT_TOL, &format!("n={n} row {i}"));
        }
    }
}

/// Whole-session differential over acquire + Fixed-mode evict churn: a
/// Blocked session's (ei, mu, sigma) track its Scalar twin within TOL
/// through rebuild-per-eviction cycles, at pool widths 1, 2 and 8.
#[test]
fn blocked_session_tracks_scalar_through_fixed_evictions() {
    let d = 6;
    let mut rng = Pcg::new(0x6b03);
    let xs = rand_rows(30, d, &mut rng);
    let ys: Vec<f64> = xs.iter().map(|r| (r[0] * 4.0).sin() + r[1] * r[2] - r[5]).collect();
    let cands = rand_rows(70, d, &mut rng);
    let extra = rand_rows(6, d, &mut rng);

    for width in [1usize, 2, 8] {
        let pool = if width == 1 { ExecPool::serial() } else { ExecPool::new(width) };
        let mut scalar =
            GpSurrogate::new(&cfg(d, 64, HyperMode::Fixed, KernelPolicy::Scalar));
        let mut blocked =
            GpSurrogate::new(&cfg(d, 64, HyperMode::Fixed, KernelPolicy::Blocked));
        for (x, &y) in xs.iter().zip(&ys) {
            scalar.observe(x, y).unwrap();
            blocked.observe(x, y).unwrap();
        }
        let mut best = ys.iter().cloned().fold(f64::INFINITY, f64::min);
        for (round, x) in extra.iter().enumerate() {
            // Same eviction index on both sides: the histories stay twins.
            let evict = argmax(scalar.ys());
            scalar.forget(evict).unwrap();
            blocked.forget(evict).unwrap();
            let (es, ms, ss) = scalar.acquire(&pool, &cands, best).unwrap();
            let (eb, mb, sb) = blocked.acquire(&pool, &cands, best).unwrap();
            assert_close(&eb, &es, TOL, &format!("w={width} r={round} ei"));
            assert_close(&mb, &ms, TOL, &format!("w={width} r={round} mu"));
            assert_close(&sb, &ss, TOL, &format!("w={width} r={round} sigma"));
            let y = (x[0] * 4.0).sin() + x[1] * x[2] - x[5];
            scalar.observe(x, y).unwrap();
            blocked.observe(x, y).unwrap();
            best = best.min(y);
        }
    }
}

/// Whole-session differential with hyper adaptation and downdate
/// evictions live (the full Adapt regime): the Blocked session's
/// posteriors track Scalar within TOL.  Few adaptation rounds on an
/// early, far-from-converged ascent: each accepted step improves the
/// likelihood by a wide margin there, so the tiers' ~1e-13 likelihood
/// differences cannot flip an accept/reject decision and fork the
/// histories.
#[test]
fn blocked_session_tracks_scalar_through_adaptation() {
    let d = 4;
    let mut rng = Pcg::new(0x6b04);
    let xs = rand_rows(24, d, &mut rng);
    let ys: Vec<f64> = xs.iter().map(|r| (r[0] * 5.0).sin() + 0.8 * r[1] - r[2] * r[3]).collect();
    let cands = rand_rows(50, d, &mut rng);
    let extra = rand_rows(6, d, &mut rng);
    let pool = ExecPool::new(2);

    let mode = HyperMode::Adapt { every: 8 };
    let mut scalar = GpSurrogate::new(&cfg(d, 64, mode, KernelPolicy::Scalar));
    let mut blocked = GpSurrogate::new(&cfg(d, 64, mode, KernelPolicy::Blocked));
    for (x, &y) in xs.iter().zip(&ys) {
        scalar.observe(x, y).unwrap();
        blocked.observe(x, y).unwrap();
    }
    let mut best = ys.iter().cloned().fold(f64::INFINITY, f64::min);
    for (round, x) in extra.iter().enumerate() {
        let evict = argmax(scalar.ys());
        scalar.forget(evict).unwrap();
        blocked.forget(evict).unwrap();
        let (es, ms, ss) = scalar.acquire(&pool, &cands, best).unwrap();
        let (eb, mb, sb) = blocked.acquire(&pool, &cands, best).unwrap();
        assert_close(&eb, &es, TOL, &format!("r={round} ei"));
        assert_close(&mb, &ms, TOL, &format!("r={round} mu"));
        assert_close(&sb, &ss, TOL, &format!("r={round} sigma"));
        let y = (x[0] * 5.0).sin() + 0.8 * x[1] - x[2] * x[3];
        scalar.observe(x, y).unwrap();
        blocked.observe(x, y).unwrap();
        best = best.min(y);
    }
    // Both sessions' hypers moved the same way (the accept decisions
    // never forked): close within TOL, not merely both finite.
    let (ls_s, s2n_s) = scalar.hypers();
    let (ls_b, s2n_b) = blocked.hypers();
    assert_close(&ls_b, &ls_s, TOL, "adapted lengthscales");
    assert_close(&[s2n_b], &[s2n_s], TOL, "adapted noise");
}

/// Blocked is bitwise self-reproducible across pool widths: the same
/// history scored serially and at widths 2, 3 and 8 gives identical
/// bits — the chunking is a constant of the algorithm, not of the pool.
#[test]
fn blocked_is_bitwise_reproducible_across_pool_widths() {
    let d = 5;
    let mut rng = Pcg::new(0x6b05);
    let xs = rand_rows(40, d, &mut rng);
    let ys: Vec<f64> = xs.iter().map(|r| r[0] * 2.0 - (r[3] * 3.0).cos()).collect();
    let cands = rand_rows(100, d, &mut rng);
    let best = ys.iter().cloned().fold(f64::INFINITY, f64::min);

    let run = |pool: &ExecPool| {
        let mut gp = GpSurrogate::new(&cfg(d, 64, HyperMode::Fixed, KernelPolicy::Blocked));
        for (x, &y) in xs.iter().zip(&ys) {
            gp.observe(x, y).unwrap();
        }
        gp.forget(argmax(gp.ys())).unwrap();
        gp.acquire(pool, &cands, best).unwrap()
    };
    let (e1, m1, s1) = run(&ExecPool::serial());
    for width in [2usize, 3, 8] {
        let (ew, mw, sw) = run(&ExecPool::new(width));
        assert_eq!(bits(&e1), bits(&ew), "ei diverged at width {width}");
        assert_eq!(bits(&m1), bits(&mw), "mu diverged at width {width}");
        assert_eq!(bits(&s1), bits(&sw), "sigma diverged at width {width}");
    }
}

/// The lane reductions agree with sequential sums within round-off, and
/// the opt-in f32-accumulate variant is f32-close only — the measured
/// reason it is excluded from `KernelPolicy::Blocked`'s 1e-8 contract.
#[test]
fn lane_reductions_and_f32_variant_hold_their_tolerances() {
    let mut rng = Pcg::new(0x6b06);
    for &len in &[1usize, 4, 7, 16, 31, 64] {
        let v: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
        let w: Vec<f64> = (0..len).map(|_| rng.f64() + 0.1).collect();
        let seq_sum: f64 = v.iter().sum();
        let seq_dot: f64 = v.iter().zip(&w).map(|(a, b)| a * b).sum();
        assert!(
            (lane_sum(&v) - seq_sum).abs() <= 1e-12 * (1.0 + seq_sum.abs()),
            "lane_sum len {len}"
        );
        assert!(
            (lane_dot(&v, &w) - seq_dot).abs() <= 1e-12 * (1.0 + seq_dot.abs()),
            "lane_dot len {len}"
        );
    }
    // f32 accumulation over a long positive sum: within ~1e-5 relative,
    // nowhere near the 1e-8 pin.
    let v: Vec<f64> = (0..512).map(|_| rng.f64()).collect();
    let exact: f64 = v.iter().sum();
    let approx = sum_f32acc(&v);
    let rel = (approx - exact).abs() / exact;
    assert!(rel <= 1e-4, "f32 accumulation out of its own tolerance: rel = {rel:e}");
}
