//! Integration: the PJRT-loaded HLO artifacts must agree with the native
//! rust mirrors on every operation (the L1/L2 <-> L3 contract).
//!
//! Requires `make artifacts` to have run; tests are skipped (not failed)
//! when artifacts are missing so `cargo test` works in a fresh checkout.

use std::sync::Arc;

use onestoptuner::runtime::{engine::XlaEngine, MlBackend, NativeBackend, Z_ENS};
use onestoptuner::util::rng::Pcg;

fn engine() -> Option<Arc<XlaEngine>> {
    match XlaEngine::load("artifacts") {
        Ok(e) => Some(Arc::new(e)),
        Err(err) => {
            // Missing artifacts (fresh checkout) -> skip; *broken* artifacts
            // (e.g. an opcode xla_extension 0.5.1 cannot parse) -> fail
            // loudly, that is exactly the regression this test guards.
            if std::path::Path::new("artifacts/manifest.json").exists() {
                panic!("artifacts exist but failed to load: {err:#}");
            }
            eprintln!("skipping XLA cross-check: {err:#}");
            None
        }
    }
}

fn rand_rows(n: usize, d: usize, rng: &mut Pcg) -> Vec<Vec<f64>> {
    (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect()
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn emcm_scores_match() {
    let Some(xla) = engine() else { return };
    let native = NativeBackend;
    let mut rng = Pcg::new(1);
    for &(m, d) in &[(64usize, 267usize), (513, 50), (1, 320)] {
        let w_ens: Vec<Vec<f64>> = (0..Z_ENS)
            .map(|_| (0..d).map(|_| rng.normal() * 0.3).collect())
            .collect();
        let w0: Vec<f64> = (0..d).map(|_| rng.normal() * 0.3).collect();
        let x = rand_rows(m, d, &mut rng);
        let a = xla.emcm_score(&w_ens, &w0, &x).unwrap();
        let b = native.emcm_score(&w_ens, &w0, &x).unwrap();
        assert_eq!(a.len(), m);
        let diff = max_abs_diff(&a, &b);
        assert!(diff < 1e-3, "emcm (m={m}, d={d}): diff {diff}");
    }
}

#[test]
fn lr_fit_matches() {
    let Some(xla) = engine() else { return };
    let native = NativeBackend;
    let mut rng = Pcg::new(2);
    for &(n, d) in &[(100usize, 120usize), (256, 320), (30, 10)] {
        let x = rand_rows(n, d, &mut rng);
        let w_true: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let y: Vec<f64> = x
            .iter()
            .map(|r| {
                r.iter().zip(&w_true).map(|(a, b)| a * b).sum::<f64>()
                    + 0.01 * rng.normal()
            })
            .collect();
        let a = xla.lr_fit(&x, &y, 1e-2).unwrap();
        let b = native.lr_fit(&x, &y, 1e-2).unwrap();
        assert_eq!(a.len(), d);
        // f32 Cholesky vs f64 Cholesky on a (possibly underdetermined)
        // system: compare predictions, not raw weights.
        let pa: Vec<f64> = x
            .iter()
            .map(|r| r.iter().zip(&a).map(|(v, w)| v * w).sum())
            .collect();
        let pb: Vec<f64> = x
            .iter()
            .map(|r| r.iter().zip(&b).map(|(v, w)| v * w).sum())
            .collect();
        let diff = max_abs_diff(&pa, &pb);
        let scale = y.iter().map(|v| v.abs()).fold(0.0, f64::max).max(1.0);
        assert!(diff / scale < 5e-2, "lr (n={n}, d={d}): rel diff {}", diff / scale);
    }
}

#[test]
fn lasso_fit_matches_and_sparsifies() {
    let Some(xla) = engine() else { return };
    let native = NativeBackend;
    let mut rng = Pcg::new(3);
    let (n, d) = (150usize, 80usize);
    let x = rand_rows(n, d, &mut rng);
    let mut w_true = vec![0.0; d];
    w_true[5] = 2.0;
    w_true[40] = -1.0;
    let y: Vec<f64> = x
        .iter()
        .map(|r| r.iter().zip(&w_true).map(|(a, b)| a * b).sum::<f64>())
        .collect();
    let a = xla.lasso_fit(&x, &y, 0.02).unwrap();
    let b = native.lasso_fit(&x, &y, 0.02).unwrap();
    let diff = max_abs_diff(&a, &b);
    assert!(diff < 5e-3, "lasso diff {diff}");
    assert!(a[5] > 0.5 && a[40] < -0.2, "support lost: {} {}", a[5], a[40]);
    let nnz_a = a.iter().filter(|v| v.abs() > 1e-4).count();
    assert!(nnz_a < d / 2);
}

#[test]
fn gp_ei_matches() {
    let Some(xla) = engine() else { return };
    let native = NativeBackend;
    let mut rng = Pcg::new(4);
    for &(n, m, d) in &[(40usize, 100usize, 60usize), (200, 600, 141)] {
        let xtr = rand_rows(n, d, &mut rng);
        let ytr: Vec<f64> = xtr
            .iter()
            .map(|r| (r.iter().sum::<f64>() / d as f64 - 0.5) * 2.0)
            .collect();
        let xc = rand_rows(m, d, &mut rng);
        let ls = vec![(d as f64).sqrt() * 0.3; d];
        let best = ytr.iter().cloned().fold(f64::INFINITY, f64::min);
        let (ea, ma, sa) = xla.gp_ei(&xtr, &ytr, &xc, &ls, 1.0, 0.01, best).unwrap();
        let (eb, mb, sb) = native.gp_ei(&xtr, &ytr, &xc, &ls, 1.0, 0.01, best).unwrap();
        assert_eq!(ea.len(), m);
        assert!(max_abs_diff(&ma, &mb) < 2e-3, "gp mu (n={n})");
        assert!(max_abs_diff(&sa, &sb) < 2e-3, "gp sigma (n={n})");
        assert!(max_abs_diff(&ea, &eb) < 2e-3, "gp ei (n={n})");
        // and the argmax — what BO actually consumes — should agree
        let arg_a = onestoptuner::util::stats::argmax(&ea);
        let arg_b = onestoptuner::util::stats::argmax(&eb);
        let tol = (ea[arg_a] - eb[arg_b]).abs();
        assert!(tol < 1e-3, "argmax EI differs materially: {tol}");
    }
}

#[test]
fn backend_names() {
    assert_eq!(NativeBackend.name(), "native");
    if let Some(x) = engine() {
        assert_eq!(x.name(), "xla");
    }
}
