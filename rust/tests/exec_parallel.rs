//! Serial/parallel equivalence: every hot path routed through the `exec`
//! pool must produce **bit-identical** results at pool width 1 and N.
//! Seeds are index-derived and reductions run in index order, so pool
//! width can never leak into metrics, datasets, or rendered experiment
//! artifacts — these tests are the guard on that invariant.

use std::sync::Arc;

use onestoptuner::datagen::{characterize_on, DataGenConfig, Strategy};
use onestoptuner::exec::ExecPool;
use onestoptuner::flags::{FlagConfig, GcMode};
use onestoptuner::pipeline::experiments::{run_table2, ExperimentCtx};
use onestoptuner::pipeline::measure_on;
use onestoptuner::runtime::{MlBackend, NativeBackend};
use onestoptuner::sparksim::{
    run_benchmark_with_contention_on, run_parallel_on, ClusterSpec, CrashRegion, ExecutorSpec,
    FaultPlan,
};
use onestoptuner::tuner::{bo::BoConfig, BoTuner, SimObjective, TuneSpace, Tuner};
use onestoptuner::{Benchmark, Metric, SparkRunner};

fn backend() -> Arc<dyn MlBackend> {
    Arc::new(NativeBackend)
}

const WIDTHS: [usize; 2] = [4, 7];

#[test]
fn run_benchmark_identical_across_pool_widths() {
    let cluster = ClusterSpec::paper();
    let exec = ExecutorSpec::full_cluster(&cluster);
    for mode in [GcMode::ParallelGC, GcMode::G1GC] {
        let cfg = FlagConfig::default_for(mode);
        for seed in [1u64, 42, 0xdead] {
            let serial = run_benchmark_with_contention_on(
                &ExecPool::serial(),
                Benchmark::Lda,
                &cfg,
                &exec,
                1.0,
                seed,
            );
            for width in WIDTHS {
                let parallel = run_benchmark_with_contention_on(
                    &ExecPool::new(width),
                    Benchmark::Lda,
                    &cfg,
                    &exec,
                    1.0,
                    seed,
                );
                assert_eq!(serial, parallel, "seed {seed} width {width}");
            }
        }
    }
}

#[test]
fn run_parallel_jobs_identical_across_pool_widths() {
    let cluster = ClusterSpec::paper();
    let cfg = FlagConfig::default_for(GcMode::G1GC);
    let jobs = vec![
        (Benchmark::Lda, cfg.clone(), ExecutorSpec::parallel_2x15()),
        (Benchmark::DenseKMeans, cfg.clone(), ExecutorSpec::parallel_2x15()),
    ];
    let serial = run_parallel_on(&ExecPool::serial(), &cluster, &jobs, 3);
    for width in WIDTHS {
        let parallel = run_parallel_on(&ExecPool::new(width), &cluster, &jobs, 3);
        assert_eq!(serial, parallel, "width {width}");
    }
}

#[test]
fn measure_identical_across_pool_widths() {
    let runner = SparkRunner::paper_default(Benchmark::DenseKMeans);
    let cfg = FlagConfig::default_for(GcMode::ParallelGC);
    let serial = measure_on(&ExecPool::serial(), &runner, &cfg, Metric::ExecTime, 10, 7);
    for width in WIDTHS {
        let parallel = measure_on(&ExecPool::new(width), &runner, &cfg, Metric::ExecTime, 10, 7);
        assert_eq!(serial.n, parallel.n);
        assert_eq!(serial.mean.to_bits(), parallel.mean.to_bits(), "width {width}");
        assert_eq!(serial.std.to_bits(), parallel.std.to_bits());
        assert_eq!(serial.min.to_bits(), parallel.min.to_bits());
        assert_eq!(serial.max.to_bits(), parallel.max.to_bits());
    }
}

#[test]
fn characterize_identical_across_pool_widths() {
    let runner = SparkRunner::paper_default(Benchmark::Lda);
    let b = backend();
    let dg = DataGenConfig {
        pool_size: 100,
        seed_runs: 10,
        test_runs: 6,
        batch_k: 8,
        max_rounds: 2,
        rmse_rel_tol: 0.0,
        ridge: 1e-3,
        seed: 11,
    };
    let serial = characterize_on(
        &ExecPool::serial(),
        &runner,
        GcMode::G1GC,
        Metric::ExecTime,
        Strategy::Bemcm,
        &dg,
        &b,
    )
    .unwrap();
    for width in WIDTHS {
        let parallel = characterize_on(
            &ExecPool::new(width),
            &runner,
            GcMode::G1GC,
            Metric::ExecTime,
            Strategy::Bemcm,
            &dg,
            &b,
        )
        .unwrap();
        assert_eq!(serial.dataset.unit_rows, parallel.dataset.unit_rows, "width {width}");
        assert_eq!(serial.dataset.feat_rows, parallel.dataset.feat_rows);
        let sy: Vec<u64> = serial.dataset.y.iter().map(|v| v.to_bits()).collect();
        let py: Vec<u64> = parallel.dataset.y.iter().map(|v| v.to_bits()).collect();
        assert_eq!(sy, py, "labels differ at width {width}");
        let sr: Vec<u64> = serial.rmse_history.iter().map(|v| v.to_bits()).collect();
        let pr: Vec<u64> = parallel.rmse_history.iter().map(|v| v.to_bits()).collect();
        assert_eq!(sr, pr, "rmse history differs at width {width}");
        assert_eq!(serial.runs_executed, parallel.runs_executed);
        assert_eq!(serial.rounds, parallel.rounds);
        assert_eq!(serial.sim_time_s.to_bits(), parallel.sim_time_s.to_bits());
    }
}

/// Fault injection rides the same determinism invariant: every injected
/// decision is a pure function of (plan seed, run seed, attempt, executor
/// index), so a full tuning loop under an active fault mix — transient
/// crashes, hangs, noise spikes, and a deterministic crash region — must
/// be bit-identical at any `ExecPool` width.
#[test]
fn faulty_tune_identical_across_pool_widths() {
    let plan = FaultPlan {
        seed: 0xc4a05,
        crash_p: 0.25,
        hang_p: 0.10,
        spike_p: 0.30,
        crash_regions: vec![CrashRegion { flag: "MaxHeapSize".to_string(), lo: 0.0, hi: 0.05 }],
        max_retries: 2,
        ..Default::default()
    };
    let runner = SparkRunner::paper_default(Benchmark::Lda).with_faults(plan);
    let mut space = TuneSpace::full(GcMode::G1GC);
    space.selected.truncate(6);
    let tune_at = |width: usize| {
        let pool = if width == 1 { ExecPool::serial() } else { ExecPool::new(width) };
        let mut obj = SimObjective::new_on(&runner, Metric::ExecTime, 3, pool.clone());
        let mut bo = BoTuner::new(
            backend(),
            BoConfig { n_init: 5, n_candidates: 64, epool: pool, ..Default::default() },
        );
        bo.tune(&space, &mut obj, 8).unwrap()
    };
    let serial = tune_at(1);
    assert!(
        serial.failures.total() > 0,
        "the fault mix must actually fire for this test to mean anything"
    );
    for width in [2usize, 8] {
        let parallel = tune_at(width);
        let sh: Vec<u64> = serial.history.iter().map(|v| v.to_bits()).collect();
        let ph: Vec<u64> = parallel.history.iter().map(|v| v.to_bits()).collect();
        assert_eq!(sh, ph, "history differs at width {width}");
        assert_eq!(serial.best_y.to_bits(), parallel.best_y.to_bits(), "width {width}");
        assert_eq!(serial.best_config, parallel.best_config, "width {width}");
        assert_eq!(serial.evals, parallel.evals);
        assert_eq!(serial.failures, parallel.failures, "histogram differs at width {width}");
    }
}

/// The batched q-EI path rides the same invariant: a q=4 tune under the
/// full fault mix — concurrent measurement rounds fanned out over the
/// pool, failures quarantined per outcome — must be bit-identical at any
/// `ExecPool` width (the batch round derives each run's seed from its
/// index, never from scheduling order).
#[test]
fn batch_faulty_tune_identical_across_pool_widths() {
    let plan = FaultPlan {
        seed: 0xc4a05,
        crash_p: 0.25,
        hang_p: 0.10,
        spike_p: 0.30,
        crash_regions: vec![CrashRegion { flag: "MaxHeapSize".to_string(), lo: 0.0, hi: 0.05 }],
        max_retries: 2,
        ..Default::default()
    };
    let runner = SparkRunner::paper_default(Benchmark::Lda).with_faults(plan);
    let mut space = TuneSpace::full(GcMode::G1GC);
    space.selected.truncate(6);
    let tune_at = |width: usize| {
        let pool = if width == 1 { ExecPool::serial() } else { ExecPool::new(width) };
        let mut obj = SimObjective::new_on(&runner, Metric::ExecTime, 3, pool.clone());
        let mut bo = BoTuner::new(
            backend(),
            BoConfig { n_init: 5, n_candidates: 64, batch_q: 4, epool: pool, ..Default::default() },
        );
        bo.tune(&space, &mut obj, 8).unwrap()
    };
    let serial = tune_at(1);
    assert_eq!(serial.history.len(), 5 + 4 * 8, "q=4 must run 4 evals per iteration");
    assert!(
        serial.failures.total() > 0,
        "the fault mix must actually fire for this test to mean anything"
    );
    for width in [2usize, 8] {
        let parallel = tune_at(width);
        let sh: Vec<u64> = serial.history.iter().map(|v| v.to_bits()).collect();
        let ph: Vec<u64> = parallel.history.iter().map(|v| v.to_bits()).collect();
        assert_eq!(sh, ph, "history differs at width {width}");
        assert_eq!(serial.best_y.to_bits(), parallel.best_y.to_bits(), "width {width}");
        assert_eq!(serial.best_config, parallel.best_config, "width {width}");
        assert_eq!(serial.evals, parallel.evals);
        assert_eq!(serial.failures, parallel.failures, "histogram differs at width {width}");
    }
}

/// A crash region planted directly under the first Sobol init point (all
/// coordinates 0.5): the init sweep takes a deterministic failure, and
/// the final winner must be a configuration *outside* the region — a
/// config that always crashes can never become the incumbent — with the
/// whole result bit-identical at pool widths 1/2/8.
#[test]
fn crashing_init_point_cannot_win_and_is_pool_width_invariant() {
    let region = CrashRegion { flag: "MaxHeapSize".to_string(), lo: 0.4, hi: 0.6 };
    let plan = FaultPlan {
        seed: 0x1417,
        crash_regions: vec![region.clone()],
        max_retries: 2,
        ..Default::default()
    };
    let runner = SparkRunner::paper_default(Benchmark::Lda).with_faults(plan);
    let mut space = TuneSpace::full(GcMode::G1GC);
    space.selected.truncate(6);
    let tune_at = |width: usize| {
        let pool = if width == 1 { ExecPool::serial() } else { ExecPool::new(width) };
        let mut obj = SimObjective::new_on(&runner, Metric::ExecTime, 3, pool.clone());
        let mut bo = BoTuner::new(
            backend(),
            BoConfig { n_init: 5, n_candidates: 64, epool: pool, ..Default::default() },
        );
        bo.tune(&space, &mut obj, 6).unwrap()
    };
    let serial = tune_at(1);
    assert!(
        serial.failures.total() >= 1,
        "the first init point sits inside the crash region and must have failed"
    );
    assert!(serial.best_y.is_finite());
    assert!(
        !region.matches(&serial.best_config),
        "an always-crashing configuration became the incumbent"
    );
    for width in [2usize, 8] {
        let parallel = tune_at(width);
        let sh: Vec<u64> = serial.history.iter().map(|v| v.to_bits()).collect();
        let ph: Vec<u64> = parallel.history.iter().map(|v| v.to_bits()).collect();
        assert_eq!(sh, ph, "history differs at width {width}");
        assert_eq!(serial.best_y.to_bits(), parallel.best_y.to_bits(), "width {width}");
        assert_eq!(serial.best_config, parallel.best_config, "width {width}");
        assert_eq!(serial.failures, parallel.failures, "histogram differs at width {width}");
    }
}

/// The experiment drivers must render identical artifacts whatever the
/// cell fan-out width (`bench_experiments` exercises the same drivers for
/// wall-clock speedup; this guards that the speedup changes nothing).
#[test]
fn table2_output_identical_across_pool_widths() {
    fn tiny(pool: ExecPool, dir: &str) -> ExperimentCtx {
        let dir = std::env::temp_dir().join(dir);
        let mut ctx = ExperimentCtx::new(Arc::new(NativeBackend), dir)
            .fast()
            .with_pool(pool);
        ctx.cfg.datagen.pool_size = 60;
        ctx.cfg.datagen.seed_runs = 12;
        ctx.cfg.datagen.test_runs = 6;
        ctx.cfg.datagen.batch_k = 6;
        ctx.cfg.datagen.max_rounds = 1;
        ctx
    }
    let serial = run_table2(&tiny(ExecPool::serial(), "ost_detser")).unwrap();
    let parallel = run_table2(&tiny(ExecPool::new(4), "ost_detpar")).unwrap();
    assert_eq!(serial, parallel);
}
