//! # OneStopTuner
//!
//! A production-grade reproduction of *"OneStopTuner: An End to End
//! Architecture for JVM Tuning of Spark Applications"* (cs.DC 2020) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the tuning pipeline: active-learning data
//!   generation, lasso feature selection, Bayesian-optimization tuning, the
//!   simulated-annealing baseline, the simulated Spark/JVM testbed, the CLI
//!   and the REST API.
//! * **L2/L1 (python/, build-time only)** — the ML compute graph (EMCM
//!   scoring, GP + EI, ridge LR, lasso ISTA) written in JAX over Pallas
//!   kernels and AOT-lowered to HLO artifacts.
//! * **runtime/** — loads those artifacts via PJRT (`xla` crate) so Python
//!   never runs on the tuning path.

// The whole crate is safe Rust, with exactly one vetted exception:
// `runtime::engine::Inner` (compiled only under `feature = "xla"`) wraps a
// PJRT handle in `unsafe impl Send`.  `forbid` cannot be overridden by an
// inner `allow`, so the crate-level lint is gated off for that build.
#![cfg_attr(not(feature = "xla"), forbid(unsafe_code))]

pub mod datagen;
pub mod exec;
pub mod featsel;
pub mod flags;
pub mod jvmsim;
pub mod lint;
pub mod mutate;
pub mod native;
pub mod pipeline;
pub mod report;
pub mod runtime;
pub mod server;
pub mod sparksim;
pub mod tuner;
pub mod util;

pub use flags::{FeatureEncoder, FlagConfig, GcMode};
pub use jvmsim::FailureKind;
pub use sparksim::{Benchmark, FailureHisto, FaultPlan, RunMetrics, RunOutcome, SparkRunner};

/// Which metric the user optimizes (paper §IV-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Job execution time in seconds (minimize).
    ExecTime,
    /// Average heap-usage percentage, eq. (8)/(9) (minimize).
    HeapUsage,
}

impl Metric {
    pub fn name(self) -> &'static str {
        match self {
            Metric::ExecTime => "exec_time",
            Metric::HeapUsage => "heap_usage",
        }
    }

    pub fn parse(s: &str) -> Option<Metric> {
        match s.to_ascii_lowercase().as_str() {
            "exec_time" | "exec-time" | "time" => Some(Metric::ExecTime),
            "heap_usage" | "heap-usage" | "heap" => Some(Metric::HeapUsage),
            _ => None,
        }
    }

    /// Extract this metric from run metrics.
    pub fn of(self, m: &RunMetrics) -> f64 {
        match self {
            Metric::ExecTime => m.exec_time_s,
            Metric::HeapUsage => m.hu_avg_pct,
        }
    }
}
