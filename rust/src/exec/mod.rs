//! `exec` — the evaluation execution subsystem.
//!
//! Everything expensive in this crate is an embarrassingly-parallel batch
//! of *deterministic* simulations or fits: per-executor JVM runs inside one
//! Spark job, AL batch labelling, the bootstrap-ensemble `lr_fit`s,
//! repeated measurements, and whole experiment-grid cells.  This module
//! provides the two primitives those hot paths share:
//!
//! * [`ExecPool`] — a scoped-thread fork/join pool.  `par_map`/`par_run`
//!   hand out work by index and return results **in index order**, so any
//!   computation whose per-item seed derives from its index (see
//!   [`index_seed`]) produces bit-identical results at every pool size,
//!   including 1.  Determinism is a hard invariant here: the paper's
//!   experiments must reproduce exactly whether they ran on a laptop core
//!   or a 64-way box (guarded by `tests/exec_parallel.rs`).
//! * [`JobRunner`] — a small detached worker pool for fire-and-forget
//!   background jobs; the REST server's async `/api/jobs` queue runs on
//!   it.
//!
//! Pools are cheap value types (`ExecPool` is just a thread count; threads
//! are scoped per call), so nesting `par_map` inside a `par_map` worker is
//! safe — there is no shared queue to deadlock on.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::rng::splitmix64;

/// Environment variable overriding the global pool width.
pub const THREADS_ENV: &str = "ONESTOPTUNER_THREADS";

/// Derive the seed for item `index` of a batch keyed by `base`.
///
/// A splitmix64 finalizer on both operands keeps streams for neighbouring
/// indices (and for `base ^ small_int` style call sites) decorrelated —
/// plain `base + index` or `base ^ index` leaves low-bit lattice structure
/// and, worse, collides across components (`seed ^ 0` == `seed`).
pub fn index_seed(base: u64, index: u64) -> u64 {
    splitmix64(base ^ splitmix64(index.wrapping_add(1)))
}

/// A fork/join pool of scoped worker threads.
///
/// `par_run(n, f)` evaluates `f(0..n)` on up to `threads` workers and
/// returns the results in index order; with `threads == 1` (or `n <= 1`)
/// it degenerates to a plain serial loop on the caller's thread.  Worker
/// panics propagate to the caller when the scope joins.
#[derive(Clone, Copy, Debug)]
pub struct ExecPool {
    threads: usize,
}

impl ExecPool {
    /// Pool with an explicit width (clamped to >= 1).
    pub fn new(threads: usize) -> ExecPool {
        ExecPool { threads: threads.max(1) }
    }

    /// Strictly serial pool (useful as the determinism baseline in tests).
    pub fn serial() -> ExecPool {
        ExecPool::new(1)
    }

    /// Width from `ONESTOPTUNER_THREADS`, else the machine's parallelism.
    pub fn from_env() -> ExecPool {
        let n = std::env::var(THREADS_ENV)
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        ExecPool::new(n)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Evaluate `f(i)` for `i in 0..n` and return results in index order.
    pub fn par_run<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        if self.threads <= 1 || n == 1 {
            return (0..n).map(f).collect();
        }
        let next = AtomicUsize::new(0);
        // One slot per item; workers write their own slot, so the only
        // contention is the per-slot lock each index takes exactly once.
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let workers = self.threads.min(n);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                // Handles are dropped: the scope itself joins every worker
                // (and re-raises any worker panic) before returning.
                let _ = scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(i);
                    *slots[i].lock().unwrap() = Some(r);
                });
            }
        });
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .expect("exec worker poisoned a result slot")
                    .expect("exec worker skipped a slot")
            })
            .collect()
    }

    /// Evaluate `f(i, &items[i])` for every item, results in item order.
    pub fn par_map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        self.par_run(items.len(), |i| f(i, &items[i]))
    }

    /// Evaluate `f(chunk_index, chunk)` over fixed-size `chunk`-item
    /// slices of `items` (the last may be short) and concatenate the
    /// results in item order.  The chunk size is part of the call
    /// contract — never derived from the pool width — so outputs stay
    /// width-invariant by construction.
    pub fn par_chunks<T, R, F>(&self, items: &[T], chunk: usize, f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &[T]) -> Vec<R> + Sync,
    {
        let chunks: Vec<&[T]> = items.chunks(chunk.max(1)).collect();
        self.par_map(&chunks, |i, c| f(i, c)).into_iter().flatten().collect()
    }
}

impl Default for ExecPool {
    fn default() -> Self {
        ExecPool::from_env()
    }
}

static GLOBAL_POOL: OnceLock<ExecPool> = OnceLock::new();

/// The process-wide pool the public pipeline entry points run on.
/// Width comes from `--threads`/[`set_global_threads`], else
/// `ONESTOPTUNER_THREADS`, else the machine; results never depend on it
/// (see module docs), so there is no per-call override on the public
/// API — tests that exercise pool-width invariance use the `*_on`
/// function variants with explicit pools instead.
pub fn global() -> &'static ExecPool {
    GLOBAL_POOL.get_or_init(ExecPool::from_env)
}

/// Pin the global pool width (the CLI's `--threads` flag).  Must run
/// before the first `global()` use; returns false — width unchanged —
/// once the pool already exists.
pub fn set_global_threads(threads: usize) -> bool {
    GLOBAL_POOL.set(ExecPool::new(threads)).is_ok()
}

/// Live progress counters a long-running job publishes for pollers.
///
/// Each producer fills only the fields that make sense for it: the AL
/// characterization loop reports `round`/`runs_executed`/`last_rmse`, the
/// phase-3 tuner loops report `iteration`/`best_y`.  All fields are
/// optional so one snapshot type serves every job kind.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Progress {
    /// Completed AL rounds (0 after the seed fit).
    pub round: Option<usize>,
    /// Round budget (`DataGenConfig::max_rounds`).
    pub max_rounds: Option<usize>,
    /// Completed tuning iterations.
    pub iteration: Option<usize>,
    /// Iteration budget for the tuning loop.
    pub iters: Option<usize>,
    /// Benchmark runs executed so far.
    pub runs_executed: Option<usize>,
    /// Runs currently being measured concurrently (the q-EI batch tuner
    /// sets this to its batch width for the measurement phase and back to
    /// 0 at the iteration boundary; single-point loops never set it).
    pub runs_in_flight: Option<usize>,
    /// Validation RMSE after the most recent fit.
    pub last_rmse: Option<f64>,
    /// Best objective value seen so far (minimization).
    pub best_y: Option<f64>,
    /// Per-kind failure counts accumulated so far (failure-aware loops).
    pub failures: Option<crate::sparksim::FailureHisto>,
}

impl Progress {
    pub fn is_empty(&self) -> bool {
        *self == Progress::default()
    }
}

/// Shared control cell between a job's owner (the REST queue) and the
/// loops doing the work: the owner reads [`Progress`] snapshots and can
/// request cooperative cancellation; the worker publishes progress at
/// round/iteration boundaries and polls [`JobControl::should_stop`] at
/// the same boundaries, returning its best-so-far partial result when a
/// stop is requested.  A stop comes from two places: explicit
/// cancellation ([`JobControl::cancel`]) or the job's failure budget
/// being exhausted ([`JobControl::set_fail_budget`] +
/// [`JobControl::note_failures`]) — the latter marks the job *degraded*,
/// which the queue maps to its own terminal status.  A default
/// (unattached) control is free to construct and turns both sides into
/// no-ops (the default failure budget is unlimited), so library callers
/// that don't care about lifecycle pay nothing.
#[derive(Debug)]
pub struct JobControl {
    cancelled: AtomicBool,
    degraded: AtomicBool,
    /// Max failures tolerated before the job degrades; `usize::MAX`
    /// means unlimited.
    fail_budget: AtomicUsize,
    progress: Mutex<Progress>,
}

impl Default for JobControl {
    fn default() -> Self {
        JobControl {
            cancelled: AtomicBool::new(false),
            degraded: AtomicBool::new(false),
            fail_budget: AtomicUsize::new(usize::MAX),
            progress: Mutex::new(Progress::default()),
        }
    }
}

impl JobControl {
    /// Request cooperative cancellation; the running loop notices at its
    /// next round/iteration boundary.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::SeqCst)
    }

    /// Set the job's failure budget: once more than `budget` measurement
    /// failures are reported via [`JobControl::note_failures`], the job
    /// degrades (stops with best-so-far results).
    pub fn set_fail_budget(&self, budget: usize) {
        self.fail_budget.store(budget, Ordering::SeqCst);
    }

    /// Report the *total* failure count observed so far (idempotent —
    /// callers pass a running total, not a delta).  Trips the degraded
    /// latch when the total exceeds the budget.
    pub fn note_failures(&self, total: usize) {
        if total > self.fail_budget.load(Ordering::SeqCst) {
            self.degraded.store(true, Ordering::SeqCst);
        }
    }

    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::SeqCst)
    }

    /// Should the working loop stop at its next boundary?  True on
    /// explicit cancellation or an exhausted failure budget; either way
    /// the loop returns its best-so-far partial result.
    pub fn should_stop(&self) -> bool {
        self.is_cancelled() || self.is_degraded()
    }

    /// Publish a progress update (workers mutate only their own fields).
    pub fn update(&self, f: impl FnOnce(&mut Progress)) {
        f(&mut self.progress.lock().unwrap());
    }

    /// Snapshot the current progress.
    pub fn progress(&self) -> Progress {
        *self.progress.lock().unwrap()
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Detached background worker pool for fire-and-forget jobs.
///
/// Workers live until the `JobRunner` is dropped (closing the channel);
/// submitted closures run in FIFO order across `workers` threads.  Worker
/// threads swallow nothing: panic isolation is the submitter's job (the
/// server's job queue wraps work in `catch_unwind`).
pub struct JobRunner {
    // Mutex-wrapped so JobRunner is Sync on every toolchain (bare
    // mpsc::Sender only became Sync with the 1.72 mpsc rewrite);
    // submission is a hashmap-insert-scale critical section.
    tx: Mutex<mpsc::Sender<Job>>,
}

impl JobRunner {
    pub fn new(workers: usize) -> JobRunner {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..workers.max(1) {
            let rx = Arc::clone(&rx);
            // Workers are detached on purpose: they die when the channel
            // closes (runner dropped) or the process exits.
            let _ = std::thread::Builder::new()
                .name(format!("ost-job-{i}"))
                .spawn(move || loop {
                    // Take the lock only to receive; release before running.
                    let job = match rx.lock() {
                        Ok(guard) => guard.recv(),
                        Err(_) => break,
                    };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // channel closed: runner dropped
                    }
                })
                .expect("spawn job worker");
        }
        JobRunner { tx: Mutex::new(tx) }
    }

    /// Enqueue `job`; returns immediately.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        // Send only fails when every worker is gone (process teardown);
        // dropping the job then is the right behavior.
        let _ = self.tx.lock().unwrap().send(Box::new(job));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_run_returns_in_index_order() {
        let pool = ExecPool::new(4);
        let out = pool.par_run(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_run_matches_serial_for_any_width() {
        let work = |i: usize| {
            let mut rng = crate::util::rng::Pcg::new(index_seed(42, i as u64));
            (0..50).map(|_| rng.f64()).sum::<f64>()
        };
        let serial = ExecPool::serial().par_run(17, work);
        for threads in [2, 3, 8] {
            let parallel = ExecPool::new(threads).par_run(17, work);
            assert_eq!(serial, parallel, "width {threads} changed results");
        }
    }

    #[test]
    fn par_map_passes_items_and_indices() {
        let pool = ExecPool::new(3);
        let items = vec!["a", "bb", "ccc"];
        let out = pool.par_map(&items, |i, s| (i, s.len()));
        assert_eq!(out, vec![(0, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn par_chunks_concatenates_in_item_order() {
        let items: Vec<u64> = (0..23).collect();
        let work = |ci: usize, c: &[u64]| -> Vec<u64> {
            c.iter().map(|&v| v * 10 + ci as u64).collect()
        };
        let serial = ExecPool::serial().par_chunks(&items, 5, work);
        assert_eq!(serial.len(), 23);
        for width in [2, 4, 9] {
            let parallel = ExecPool::new(width).par_chunks(&items, 5, work);
            assert_eq!(serial, parallel, "width {width}");
        }
        // chunk index is the fixed-size chunk number, not a pool artifact
        assert_eq!(serial[0], 0);
        assert_eq!(serial[22], 224);
        assert!(ExecPool::new(3).par_chunks(&[] as &[u64], 4, work).is_empty());
    }

    #[test]
    fn par_run_actually_uses_multiple_threads() {
        use std::collections::HashSet;
        let pool = ExecPool::new(4);
        let ids = Mutex::new(HashSet::new());
        pool.par_run(64, |_| {
            ids.lock().unwrap().insert(std::thread::current().id());
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert!(ids.into_inner().unwrap().len() > 1, "never left the main thread");
    }

    #[test]
    fn empty_and_single_inputs() {
        let pool = ExecPool::new(8);
        assert!(pool.par_run(0, |i| i).is_empty());
        assert_eq!(pool.par_run(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn index_seed_decorrelates_neighbours() {
        let a = index_seed(1, 0);
        let b = index_seed(1, 1);
        let c = index_seed(2, 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
        // xor-style collisions (seed ^ 0 == seed) must not survive mixing
        assert_ne!(index_seed(7, 0), 7);
    }

    #[test]
    fn job_control_flags_and_progress() {
        let ctl = JobControl::default();
        assert!(!ctl.is_cancelled());
        assert!(ctl.progress().is_empty());
        ctl.update(|p| {
            p.iteration = Some(2);
            p.best_y = Some(0.5);
        });
        let p = ctl.progress();
        assert_eq!(p.iteration, Some(2));
        assert_eq!(p.best_y, Some(0.5));
        assert!(!p.is_empty());
        // updates merge: a later writer touching other fields keeps mine
        ctl.update(|p| p.runs_executed = Some(7));
        assert_eq!(ctl.progress().iteration, Some(2));
        ctl.cancel();
        assert!(ctl.is_cancelled());
        assert!(ctl.should_stop());
    }

    #[test]
    fn fail_budget_trips_the_degraded_latch() {
        let ctl = JobControl::default();
        // Unlimited by default: totals never degrade an unbudgeted job.
        ctl.note_failures(1_000_000);
        assert!(!ctl.is_degraded());
        assert!(!ctl.should_stop());
        ctl.set_fail_budget(3);
        ctl.note_failures(3); // at the budget: still fine
        assert!(!ctl.is_degraded());
        ctl.note_failures(4); // over: degraded, and it latches
        assert!(ctl.is_degraded());
        assert!(ctl.should_stop());
        assert!(!ctl.is_cancelled());
        ctl.note_failures(0);
        assert!(ctl.is_degraded(), "degraded must latch");
    }

    #[test]
    fn job_runner_executes_submissions() {
        let runner = JobRunner::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            runner.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while counter.load(Ordering::SeqCst) < 10 {
            assert!(std::time::Instant::now() < deadline, "jobs never ran");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
    }
}
