//! OneStopTuner CLI — the leader entrypoint.
//!
//! ```text
//! onestoptuner <command> [options]
//!
//! commands:
//!   list-benchmarks                         Table I workloads
//!   list-flags      --gc g1|parallel        flag catalog (PrintFlagsFinal-style)
//!   run             --bench B --gc G [--seed N] [--set Flag=V ...]
//!   characterize    --bench B --gc G [--metric M] [--strategy S] [--out F.csv]
//!   select          --data F.csv --gc G [--metric M] [--lambda L] [--grid]
//!   tune            --bench B --gc G [--metric M] [--algo A|all] [--iters N]
//!                   [--gp-hypers fixed|adapt] [--gp-adapt-every K]
//!                   [--gp-ard] [--gp-init-hypers "l1,..,ld[:noise]"]
//!                   [--batch-q Q] [--gp-kernels scalar|blocked]
//!   repro           table1|table2|table3|fig3|timing|table4|fig7|fig4|fig5|fig6|all [--fast]
//!   serve           [--port 7878] [--state-dir DIR] [--job-ttl-s 3600]
//!
//! global options:
//!   --threads N     execution-pool width (default: auto-detected cores;
//!                   results never depend on it)
//! ```

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use onestoptuner::datagen::{self, DataGenConfig, Dataset, Strategy};
use onestoptuner::featsel;
use onestoptuner::flags::{FlagConfig, GcMode, Kind};
use onestoptuner::pipeline::{self, experiments, Algo, PipelineConfig};
use onestoptuner::report::TextTable;
use onestoptuner::runtime::load_backend;
use onestoptuner::sparksim::SparkRunner;
use onestoptuner::util::csv::Table;
use onestoptuner::{Benchmark, Metric};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parsed `--key value` options plus positional arguments.
struct Opts {
    positional: Vec<String>,
    named: HashMap<String, Vec<String>>,
}

impl Opts {
    fn parse(args: &[String]) -> Opts {
        let mut positional = Vec::new();
        let mut named: HashMap<String, Vec<String>> = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                let value = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    i += 1;
                    args[i].clone()
                } else {
                    "true".to_string()
                };
                named.entry(key.to_string()).or_default().push(value);
                i += 1;
            } else {
                positional.push(args[i].clone());
                i += 1;
            }
        }
        Opts { positional, named }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.named.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    fn get_all(&self, key: &str) -> Vec<&str> {
        self.named.get(key).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    fn has(&self, key: &str) -> bool {
        self.named.contains_key(key)
    }

    fn bench(&self) -> Result<Benchmark> {
        self.get("bench")
            .and_then(Benchmark::parse)
            .context("--bench lda|densekmeans required")
    }

    fn gc(&self) -> Result<GcMode> {
        self.get("gc").and_then(GcMode::parse).context("--gc g1|parallel required")
    }

    fn metric(&self) -> Metric {
        self.get("metric").and_then(Metric::parse).unwrap_or(Metric::ExecTime)
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    // `--threads` is a global flag: accept it before or after the
    // subcommand, and strip it so command parsing never sees it.
    let mut args = args.to_vec();
    if let Some(i) = args.iter().position(|a| a == "--threads") {
        anyhow::ensure!(i + 1 < args.len(), "--threads needs a value");
        let n: usize = args[i + 1].parse().context("--threads must be a positive integer")?;
        anyhow::ensure!(n >= 1, "--threads must be >= 1");
        if !onestoptuner::exec::set_global_threads(n) {
            eprintln!("warning: execution pool already initialized; --threads {n} ignored");
        }
        args.drain(i..=i + 1);
    }
    let Some(cmd) = args.first().map(|s| s.as_str()) else {
        print_usage();
        return Ok(());
    };
    let opts = Opts::parse(&args[1..]);
    match cmd {
        "list-benchmarks" => list_benchmarks(),
        "list-flags" => list_flags(&opts),
        "run" => cmd_run(&opts),
        "characterize" => cmd_characterize(&opts),
        "select" => cmd_select(&opts),
        "tune" => cmd_tune(&opts),
        "repro" => cmd_repro(&opts),
        "serve" => cmd_serve(&opts),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try: onestoptuner help)"),
    }
}

fn print_usage() {
    println!(
        "OneStopTuner — ML-based JVM flag autotuning for Spark applications\n\n\
         usage: onestoptuner <command> [options]\n\n\
         commands:\n\
         \x20 list-benchmarks                        Table I workloads\n\
         \x20 list-flags    --gc g1|parallel         flag catalog for a GC group\n\
         \x20 run           --bench B --gc G [--seed N] [--set Flag=V ...]\n\
         \x20 characterize  --bench B --gc G [--metric M] [--strategy bemcm|qbc|random] [--out data.csv]\n\
         \x20 select        --data data.csv --gc G [--metric M] [--lambda 0.01] [--grid]\n\
         \x20 tune          --bench B --gc G [--metric M] [--algo bo|rbo|bo-warm|sa|all] [--iters 20]\n\
         \x20               [--gp-hypers fixed|adapt] [--gp-adapt-every K]   GP surrogate hyper-parameter policy\n\
         \x20               [--gp-ard]                 per-dimension (ARD) length-scales; implies --gp-hypers adapt\n\
         \x20               [--gp-init-hypers \"l1,..,ld[:noise]\"]           warm-start hypers from a previous run\n\
         \x20               [--batch-q Q]              q-EI: propose and evaluate Q configs per iteration (default 1)\n\
         \x20               [--gp-kernels scalar|blocked]                    surrogate linear-algebra tier (default scalar)\n\
         \x20 repro         table1|table2|table3|fig3|timing|table4|fig7|fig4|fig5|fig6|all [--fast] [--out results]\n\
         \x20 serve         [--port 7878] [--state-dir DIR] [--job-ttl-s 3600]\n\n\
         global options:\n\
         \x20 --threads N   execution-pool width (default: auto-detected cores; results never depend on it)\n"
    );
}

fn list_benchmarks() -> Result<()> {
    let mut t = TextTable::new("Benchmarks (paper Table I)", &["Application", "Dataset", "input", "tasks"]);
    for b in Benchmark::all() {
        let s = b.spec();
        t.row(vec![
            s.name.to_string(),
            s.dataset.to_string(),
            format!("{} GB", s.input_gb),
            s.n_tasks.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn list_flags(opts: &Opts) -> Result<()> {
    let gc = opts.gc()?;
    let cfg = FlagConfig::default_for(gc);
    let mut t = TextTable::new(
        format!("JVM flags, {} group ({} flags)", gc.name(), cfg.len()),
        &["flag", "type", "range", "default"],
    );
    for f in cfg.defs() {
        let (ty, range, default) = match f.kind {
            Kind::Bool { default } => ("bool".to_string(), "-/+".to_string(), default.to_string()),
            Kind::Int { min, max, default, log } => (
                if log { "int (log)".into() } else { "int".into() },
                format!("[{min}, {max}]"),
                format!("{default}"),
            ),
        };
        t.row(vec![f.name.to_string(), ty, range, default]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_run(opts: &Opts) -> Result<()> {
    let bench = opts.bench()?;
    let gc = opts.gc()?;
    let seed: u64 = opts.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let mut cfg = FlagConfig::default_for(gc);
    for kv in opts.get_all("set") {
        let (name, value) = kv.split_once('=').context("--set needs Flag=Value")?;
        let v: f64 = match value {
            "true" | "+" => 1.0,
            "false" | "-" => 0.0,
            other => other.parse().with_context(|| format!("bad value for {name}"))?,
        };
        cfg.set(name, v);
    }
    let m = SparkRunner::paper_default(bench).run(&cfg, seed);
    println!("benchmark:     {} ({})", bench.name(), gc.name());
    let fail_tag = match m.failure {
        Some(kind) => format!("  [FAILED: {}]", kind.name()),
        None => String::new(),
    };
    println!("exec time:     {:.1} s{}", m.exec_time_s, fail_tag);
    println!("heap usage:    {:.1} %", m.hu_avg_pct);
    println!(
        "gc:            {} minor, {} mixed, {} full, {} conc cycles",
        m.gc.minor, m.gc.mixed, m.gc.full, m.gc.conc_cycles
    );
    println!("total pause:   {:.0} ms (max {:.0} ms)", m.gc.total_pause_ms, m.gc.max_pause_ms);
    println!("java args:     {}", cfg.to_java_args());
    Ok(())
}

fn datagen_config(opts: &Opts) -> DataGenConfig {
    let mut dg = DataGenConfig::default();
    if let Some(v) = opts.get("pool").and_then(|s| s.parse().ok()) {
        dg.pool_size = v;
    }
    if let Some(v) = opts.get("rounds").and_then(|s| s.parse().ok()) {
        dg.max_rounds = v;
    }
    if let Some(v) = opts.get("batch").and_then(|s| s.parse().ok()) {
        dg.batch_k = v;
    }
    if let Some(v) = opts.get("seed").and_then(|s| s.parse().ok()) {
        dg.seed = v;
    }
    dg
}

fn cmd_characterize(opts: &Opts) -> Result<()> {
    let bench = opts.bench()?;
    let gc = opts.gc()?;
    let metric = opts.metric();
    let strategy = opts
        .get("strategy")
        .and_then(Strategy::parse)
        .unwrap_or(Strategy::Bemcm);
    let backend = load_backend("artifacts");
    let runner = SparkRunner::paper_default(bench);
    let dg = datagen_config(opts);
    let r = datagen::characterize(&runner, gc, metric, strategy, &dg, &backend)?;
    println!(
        "characterized {} ({}) for {} via {}: {} labelled samples, {} runs, {} AL rounds",
        bench.name(),
        gc.name(),
        metric.name(),
        strategy.name(),
        r.dataset.len(),
        r.runs_executed,
        r.rounds
    );
    println!(
        "validation RMSE: {}",
        r.rmse_history.iter().map(|v| format!("{v:.2}")).collect::<Vec<_>>().join(" -> ")
    );
    println!("simulated benchmark time: {:.0} s", r.sim_time_s);
    let out = opts.get("out").unwrap_or("data.csv");
    r.dataset.to_table().save(out)?;
    println!("dataset written to {out}");
    Ok(())
}

fn cmd_select(opts: &Opts) -> Result<()> {
    let gc = opts.gc()?;
    let metric = opts.metric();
    let path = opts.get("data").context("--data data.csv required")?;
    let table = Table::load(path).map_err(|e| anyhow::anyhow!(e))?;
    let ds = Dataset::from_table(&table, gc, metric)?;
    let backend = load_backend("artifacts");

    if opts.has("grid") {
        let lambdas = [0.001, 0.003, 0.01, 0.03, 0.1];
        let (best, grid) = featsel::grid_search_lambda(&ds, &lambdas, &backend)?;
        let mut t = TextTable::new("lambda grid search", &["lambda", "holdout MSE", "flags kept"]);
        for (lam, mse, kept) in grid {
            t.row(vec![format!("{lam}"), format!("{mse:.4}"), kept.to_string()]);
        }
        print!("{}", t.render());
        println!("best lambda: {best}");
        return Ok(());
    }

    let lambda: f64 =
        opts.get("lambda").map(|s| s.parse()).transpose()?.unwrap_or(featsel::DEFAULT_LAMBDA);
    let sel = featsel::select_flags(&ds, lambda, &backend)?;
    println!(
        "lasso (lambda={lambda}) kept {} of {} flags for {}:",
        sel.n_selected(),
        sel.group_size,
        metric.name()
    );
    for name in &sel.names {
        println!("  {name}");
    }
    Ok(())
}

fn cmd_tune(opts: &Opts) -> Result<()> {
    let bench = opts.bench()?;
    let gc = opts.gc()?;
    let metric = opts.metric();
    let iters: usize = opts.get("iters").map(|s| s.parse()).transpose()?.unwrap_or(20);
    let algos: Vec<Algo> = match opts.get("algo").unwrap_or("all") {
        "all" => Algo::all().to_vec(),
        s => vec![Algo::parse(s).context("--algo bo|rbo|bo-warm|sa|all")?],
    };
    let backend = load_backend("artifacts");
    let mut cfg = PipelineConfig { tune_iters: iters, ..Default::default() };
    cfg.datagen = datagen_config(opts);
    // GP surrogate hyper-parameter policy: fixed (bit-reproducible,
    // default) or adaptive (marginal-likelihood ascent + O(n²) downdate
    // evictions in the native session).
    if let Some(s) = opts.get("gp-hypers") {
        cfg.bo.hypers.mode =
            onestoptuner::runtime::HyperMode::parse(s).context("--gp-hypers fixed|adapt")?;
    }
    // ARD frees the per-dimension length-scales, which only exists under
    // adaptation: bare --gp-ard implies --gp-hypers adapt, while an
    // explicit "fixed" alongside it is a contradiction, not an override.
    if opts.has("gp-ard") {
        match cfg.bo.hypers.mode {
            onestoptuner::runtime::HyperMode::Adapt { .. } => {}
            onestoptuner::runtime::HyperMode::Fixed if opts.get("gp-hypers").is_some() => {
                bail!("--gp-ard requires --gp-hypers adapt (fixed length-scales cannot adapt per dimension)")
            }
            onestoptuner::runtime::HyperMode::Fixed => {
                cfg.bo.hypers.mode = onestoptuner::runtime::HyperMode::adapt();
            }
        }
        cfg.bo.hypers.ard = true;
    }
    if let Some(v) = opts.get("gp-adapt-every") {
        let every: usize = v.parse().context("--gp-adapt-every must be a positive integer")?;
        anyhow::ensure!(every >= 1, "--gp-adapt-every must be >= 1");
        // A cadence never implies adaptation: the fixed default stays
        // bit-reproducible unless --gp-hypers adapt (or --gp-ard) asks
        // otherwise.
        anyhow::ensure!(
            matches!(cfg.bo.hypers.mode, onestoptuner::runtime::HyperMode::Adapt { .. }),
            "--gp-adapt-every requires --gp-hypers adapt"
        );
        cfg.bo.hypers.mode = onestoptuner::runtime::HyperMode::Adapt { every };
    }
    // Warm-started hypers from a previous run's report: the dimension
    // count must match the lasso-selected tuning subspace, which is only
    // known after characterization — the tuner checks it and errors.
    if let Some(spec) = opts.get("gp-init-hypers") {
        let (ls, noise) = parse_init_hypers(spec)?;
        cfg.bo.hypers.init = Some((ls, noise.unwrap_or(cfg.bo.hypers.sigma_n2)));
    }
    // Batched q-EI proposal width.  The default of 1 is the bitwise
    // single-point path; the tuner validates the upper bounds (candidate
    // pool, GP training budget) before any evaluation runs.
    if let Some(v) = opts.get("batch-q") {
        let q: usize = v.parse().context("--batch-q must be a positive integer")?;
        anyhow::ensure!(q >= 1, "--batch-q must be >= 1");
        cfg.bo.batch_q = q;
    }
    // Surrogate linear-algebra tier: `scalar` (default) is the
    // bitwise-pinned reference arithmetic; `blocked` runs the panel/lane
    // kernels (1e-8 from scalar, bitwise self-reproducible at any
    // --threads width).
    if let Some(s) = opts.get("gp-kernels") {
        cfg.bo.hypers.kernels =
            onestoptuner::runtime::KernelPolicy::parse(s).context("--gp-kernels scalar|blocked")?;
    }

    let out = pipeline::run_pipeline(bench, gc, metric, &algos, &cfg, &backend)?;
    println!(
        "characterization: {} runs; lasso kept {}/{} flags",
        out.characterization.runs_executed,
        out.selection.n_selected(),
        out.selection.group_size
    );
    println!(
        "default {}: {:.2} +- {:.2} ({} runs)\n",
        metric.name(),
        out.default_summary.mean,
        out.default_summary.std,
        out.default_summary.n
    );
    let mut t = TextTable::new(
        format!("tuning results — {} ({}), {}", bench.name(), gc.name(), metric.name()),
        &["algorithm", "tuned (mean +- std)", "improvement", "tuning time [s]", "evals"],
    );
    for o in &out.outcomes {
        t.row(vec![
            o.algo.name().to_string(),
            format!("{:.2} +- {:.2}", o.tuned_summary.mean, o.tuned_summary.std),
            format!("{:.2}x", o.improvement),
            format!("{:.0}", o.tuning_time_s),
            o.tune.evals.to_string(),
        ]);
    }
    print!("{}", t.render());
    // ARD relevance next to the lasso selection: the surrogate's own
    // per-flag relevance signal, for cross-checking the paper's
    // feature-selection stage.
    let enc = onestoptuner::flags::FeatureEncoder::new(gc);
    let tuned_names: Vec<&str> =
        out.selection.selected.iter().map(|&p| enc.flag_name(p)).collect();
    for o in &out.outcomes {
        if let Some(rel) = &o.tune.ard_relevance {
            let mut ranked: Vec<(&str, f64)> =
                tuned_names.iter().copied().zip(rel.iter().copied()).collect();
            ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let mut rt = TextTable::new(
                format!("ARD relevance (1/lengthscale^2, normalized) — {}", o.algo.name()),
                &["flag", "relevance"],
            );
            for (name, r) in ranked {
                rt.row(vec![name.to_string(), format!("{r:.4}")]);
            }
            print!("{}", rt.render());
        }
        // Adapted hypers are worth echoing only when they could move:
        // print them in the ready-to-paste warm-start format.
        if matches!(cfg.bo.hypers.mode, onestoptuner::runtime::HyperMode::Adapt { .. }) {
            if let Some((ls, s2n)) = &o.tune.gp_hypers {
                let spec: Vec<String> = ls.iter().map(|l| format!("{l:.6}")).collect();
                println!(
                    "{} adapted GP hypers (reusable via --gp-init-hypers \"{}:{s2n:.6}\")",
                    o.algo.name(),
                    spec.join(",")
                );
            }
        }
    }
    if let Some(best) = out
        .outcomes
        .iter()
        .max_by(|a, b| a.improvement.partial_cmp(&b.improvement).unwrap())
    {
        println!("\nbest ({}) java args:\n{}", best.algo.name(), best.tune.best_config.to_java_args());
    }
    Ok(())
}

/// Parse `--gp-init-hypers "l1,l2,...,ld[:noise]"`: one positive
/// length-scale per tuned dimension, optionally followed by the noise
/// variance after a colon — the format `tune` prints after an adaptive
/// run so hypers round-trip between jobs.
fn parse_init_hypers(spec: &str) -> Result<(Vec<f64>, Option<f64>)> {
    let (ls_part, noise_part) = match spec.split_once(':') {
        Some((a, b)) => (a, Some(b)),
        None => (spec, None),
    };
    let ls = ls_part
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<f64>()
                .ok()
                .filter(|v| v.is_finite() && *v > 0.0)
                .with_context(|| format!("bad length-scale '{s}' in --gp-init-hypers (want positive numbers)"))
        })
        .collect::<Result<Vec<f64>>>()?;
    anyhow::ensure!(!ls.is_empty(), "--gp-init-hypers needs at least one length-scale");
    let noise = noise_part
        .map(|s| {
            s.trim()
                .parse::<f64>()
                .ok()
                .filter(|v| v.is_finite() && *v > 0.0)
                .with_context(|| format!("bad noise variance '{s}' in --gp-init-hypers"))
        })
        .transpose()?;
    Ok((ls, noise))
}

fn cmd_repro(opts: &Opts) -> Result<()> {
    let what = opts.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let out_dir = opts.get("out").unwrap_or("results").to_string();
    let backend = load_backend("artifacts");
    let mut ctx = experiments::ExperimentCtx::new(backend, &out_dir);
    if opts.has("fast") {
        ctx = ctx.fast();
    }
    let text = match what {
        "table1" => experiments::run_table1(&ctx)?,
        "table2" => experiments::run_table2(&ctx)?,
        "table3" | "fig3" | "timing" | "exec" => experiments::run_exec_time(&ctx)?,
        "table4" | "fig7" | "heap" => experiments::run_heap_usage(&ctx)?,
        "fig4" => experiments::run_fig4(&ctx)?,
        "fig5" => experiments::run_fig5(&ctx)?,
        "fig6" => experiments::run_fig6(&ctx)?,
        "all" => experiments::run_all(&ctx)?,
        other => bail!("unknown experiment '{other}'"),
    };
    println!("{text}");
    println!("(results written under {out_dir}/)");
    Ok(())
}

fn cmd_serve(opts: &Opts) -> Result<()> {
    let port: u16 = opts.get("port").map(|s| s.parse()).transpose()?.unwrap_or(7878);
    let backend = load_backend("artifacts");
    let mut api = onestoptuner::server::ApiOptions::default();
    if let Some(dir) = opts.get("state-dir") {
        api.state_dir = Some(std::path::PathBuf::from(dir));
    }
    if let Some(secs) = opts.get("job-ttl-s") {
        let secs: u64 = secs.parse().context("--job-ttl-s must be a positive integer")?;
        anyhow::ensure!(secs >= 1, "--job-ttl-s must be >= 1");
        api.job_ttl = std::time::Duration::from_secs(secs);
    }
    onestoptuner::server::serve_forever_with(&format!("127.0.0.1:{port}"), backend, api)?;
    Ok(())
}
