//! detlint: the determinism & concurrency lint gate.
//!
//! Modes:
//!
//! * default (sweep) — lint every `.rs` file under `rust/src/`, print
//!   the markdown summary, write `detlint.json` at the repo root, and
//!   exit non-zero on any unsuppressed violation or malformed allow
//!   annotation.  This is the CI step.
//! * `--self-check` — patch known violations into in-memory copies of
//!   real files (one-plus per rule, plus negative controls) and exit
//!   non-zero unless every plant is flagged at the expected file/rule.
//!   Guards the lint itself against silent rot; also a CI step.
//!
//! Examples:
//!
//! ```text
//! cargo run --release --bin detlint
//! cargo run --release --bin detlint -- --self-check
//! cargo run --release --bin detlint -- --out /tmp/detlint.json
//! ```
//!
//! Rules, rationale and the allow workflow are documented in `LINTS.md`.

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{Context, Result};

use onestoptuner::lint::{self, report, selfcheck};
use onestoptuner::mutate::find_root;

struct Opts {
    self_check: bool,
    out: Option<PathBuf>,
}

const USAGE: &str = "usage: detlint [--self-check] [--out PATH]";

fn parse_opts(args: &[String]) -> Result<Opts> {
    let mut o = Opts { self_check: false, out: None };
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--self-check" => o.self_check = true,
            "--out" => {
                let v = it.next().with_context(|| format!("--out needs a value\n{USAGE}"))?;
                o.out = Some(PathBuf::from(v));
            }
            "--help" | "-h" => {
                println!("{USAGE}");
                std::process::exit(0);
            }
            other => anyhow::bail!("unknown argument `{other}`\n{USAGE}"),
        }
    }
    Ok(o)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("detlint: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> Result<ExitCode> {
    let opts = parse_opts(args)?;
    let root = find_root()?;
    if opts.self_check {
        return run_self_check(&root);
    }
    run_sweep(&opts, &root)
}

fn run_sweep(opts: &Opts, root: &std::path::Path) -> Result<ExitCode> {
    let rep = lint::lint_root(root)?;
    let out = opts.out.clone().unwrap_or_else(|| root.join("detlint.json"));
    std::fs::write(&out, format!("{}\n", report::to_json(&rep)))
        .with_context(|| format!("writing {}", out.display()))?;
    println!("{}", report::summary_markdown(&rep));
    println!("wrote {}", out.display());
    if !rep.clean() {
        eprintln!(
            "detlint: {} violation(s) / {} problem(s) — fix the site, use an ordered \
             container, or add `// detlint: allow(<rule>) -- <reason>` (see LINTS.md)",
            rep.findings.len(),
            rep.problems.len()
        );
        return Ok(ExitCode::FAILURE);
    }
    Ok(ExitCode::SUCCESS)
}

fn run_self_check(root: &std::path::Path) -> Result<ExitCode> {
    let results = selfcheck::run(root)?;
    println!("{}", selfcheck::summary_markdown(&results));
    if !selfcheck::all_ok(&results) {
        eprintln!("detlint: self-check failed — the lint no longer catches what it claims to");
        return Ok(ExitCode::FAILURE);
    }
    Ok(ExitCode::SUCCESS)
}
