//! mutant-hunter: mutation-testing driver for the numeric kernels.
//!
//! Modes:
//!
//! * `--smoke` — run the pinned, curated mutant set (see
//!   `src/mutate/smoke.rs`) against the fast differential tier and demand
//!   a 100% kill rate.  Writes `mutants_smoke.json` at the repo root and
//!   exits non-zero on any surviving/undead pin or on pin rot.  This is
//!   the CI step.
//! * default (full sweep) — scan all mutation sites in the six kernel
//!   files, run each against its mapped suites plus the `--lib` tier, and
//!   write `mutants.json` + `mutants.md` at the repo root.  Exits
//!   non-zero while any survivor lacks an `equivalent` disposition in
//!   `rust/mutants.dispositions.json`.  `--shard i/n` splits the sweep
//!   across machines/jobs.
//! * `--list` — print the discovered sites without building anything.
//!
//! Examples:
//!
//! ```text
//! cargo run --release --bin mutant-hunter -- --smoke
//! cargo run --release --bin mutant-hunter -- --shard 0/4 --workers 2
//! cargo run --release --bin mutant-hunter -- --list --files linalg
//! ```

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{Context, Result};

use onestoptuner::mutate::{
    find_root, pinned, report, resolve_pin, runner, scan_targets, MutantResult, RunConfig,
    Site, Verdict,
};

struct Opts {
    smoke: bool,
    list: bool,
    workers: Option<usize>,
    timeout_s: Option<u64>,
    shard: Option<(usize, usize)>,
    files: Vec<String>,
    out: Option<PathBuf>,
}

const USAGE: &str = "usage: mutant-hunter [--smoke | --list] [--workers N] [--timeout-s S] \
                     [--shard I/N] [--files substr,substr] [--out PATH]";

fn next_value<'a>(it: &mut std::slice::Iter<'a, String>, flag: &str) -> Result<&'a String> {
    it.next().with_context(|| format!("{flag} needs a value\n{USAGE}"))
}

fn parse_opts(args: &[String]) -> Result<Opts> {
    let mut o = Opts {
        smoke: false,
        list: false,
        workers: None,
        timeout_s: None,
        shard: None,
        files: Vec::new(),
        out: None,
    };
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--smoke" => o.smoke = true,
            "--list" => o.list = true,
            "--workers" => {
                o.workers = Some(next_value(&mut it, a)?.parse().context("--workers")?)
            }
            "--timeout-s" => {
                o.timeout_s = Some(next_value(&mut it, a)?.parse().context("--timeout-s")?)
            }
            "--shard" => {
                let v = next_value(&mut it, a)?;
                let (i, n) = v.split_once('/').context("--shard wants I/N, e.g. 0/4")?;
                let (i, n): (usize, usize) =
                    (i.parse().context("--shard")?, n.parse().context("--shard")?);
                anyhow::ensure!(n > 0 && i < n, "--shard index must satisfy I < N");
                o.shard = Some((i, n));
            }
            "--files" => {
                o.files =
                    next_value(&mut it, a)?.split(',').map(|s| s.trim().to_string()).collect();
            }
            "--out" => o.out = Some(PathBuf::from(next_value(&mut it, a)?)),
            "--help" | "-h" => {
                println!("{USAGE}");
                std::process::exit(0);
            }
            other => anyhow::bail!("unknown argument `{other}`\n{USAGE}"),
        }
    }
    anyhow::ensure!(
        !(o.smoke && (o.list || o.shard.is_some() || !o.files.is_empty())),
        "--smoke runs exactly the pinned set; it does not combine with \
         --list/--shard/--files"
    );
    Ok(o)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("mutant-hunter: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> Result<ExitCode> {
    let opts = parse_opts(args)?;
    let root = find_root()?;
    let sites = scan_targets(&root)?;
    eprintln!("scanned {} mutation sites across {} files", sites.len(), {
        let mut files: Vec<_> = sites.iter().map(|s| s.file.as_str()).collect();
        files.dedup();
        files.len()
    });

    if opts.smoke {
        return run_smoke(&opts, &root, &sites);
    }
    if opts.list {
        return run_list(&opts, &sites);
    }
    run_full(&opts, &root, &sites)
}

fn config(opts: &Opts, root: &std::path::Path, full_suites: bool) -> RunConfig {
    let mut cfg = RunConfig::new(root.to_path_buf());
    if let Some(w) = opts.workers {
        cfg.workers = w.max(1);
    }
    if let Some(t) = opts.timeout_s {
        cfg.timeout_s = t.max(1);
    }
    cfg.full_suites = full_suites;
    cfg
}

/// The CI gate: every pinned mutant must be Killed — not survived, not
/// build-failed (a pin that stops compiling is a stale pin), not timed
/// out (a pin that hangs the suite needs investigation, not silent
/// credit).
fn run_smoke(opts: &Opts, root: &std::path::Path, sites: &[Site]) -> Result<ExitCode> {
    let pins = pinned();
    let mut pin_sites = Vec::with_capacity(pins.len());
    for pin in &pins {
        let site = resolve_pin(pin, sites)?;
        eprintln!("pin {:<28} -> {}", pin.id, site.id());
        pin_sites.push(site.clone());
    }

    let cfg = config(opts, root, false);
    eprintln!(
        "running {} pinned mutants on {} worker(s), fast differential tier",
        pin_sites.len(),
        cfg.workers
    );
    let results = runner::run_mutants(&cfg, &pin_sites)?;

    let out = opts.out.clone().unwrap_or_else(|| root.join("mutants_smoke.json"));
    let json = report::to_json("smoke", None, &results, &[]);
    std::fs::write(&out, format!("{json}\n"))
        .with_context(|| format!("writing {}", out.display()))?;
    println!("{}", report::summary_markdown("smoke", &results, &[]));
    println!("wrote {}", out.display());

    let mut failed = false;
    for (pin, r) in pins.iter().zip(&results) {
        if r.verdict != Verdict::Killed {
            failed = true;
            eprintln!(
                "SMOKE FAILURE: pin `{}` {} ({}). Kill argument was: {}",
                pin.id,
                r.verdict.label(),
                r.site.diff(),
                pin.kill_argument
            );
        }
    }
    if failed {
        eprintln!("smoke demands a 100% kill rate on the pinned set");
        return Ok(ExitCode::FAILURE);
    }
    println!("smoke OK: {}/{} pinned mutants killed", results.len(), results.len());
    Ok(ExitCode::SUCCESS)
}

fn selected<'a>(opts: &Opts, sites: &'a [Site]) -> Vec<&'a Site> {
    sites
        .iter()
        .filter(|s| opts.files.is_empty() || opts.files.iter().any(|f| s.file.contains(f.as_str())))
        .collect()
}

fn run_list(opts: &Opts, sites: &[Site]) -> Result<ExitCode> {
    let chosen = selected(opts, sites);
    for s in &chosen {
        println!("{:<52} {}", s.id(), s.diff());
    }
    println!("\n{} sites", chosen.len());
    Ok(ExitCode::SUCCESS)
}

fn run_full(opts: &Opts, root: &std::path::Path, sites: &[Site]) -> Result<ExitCode> {
    let chosen = selected(opts, sites);
    let sharded: Vec<Site> = match opts.shard {
        Some((i, n)) => chosen
            .iter()
            .enumerate()
            .filter(|(idx, _)| idx % n == i)
            .map(|(_, s)| (*s).clone())
            .collect(),
        None => chosen.into_iter().cloned().collect(),
    };
    anyhow::ensure!(!sharded.is_empty(), "selection is empty (files filter / shard too narrow?)");

    let cfg = config(opts, root, true);
    eprintln!(
        "running {} mutants on {} worker(s), full tier (differential suites + --lib)",
        sharded.len(),
        cfg.workers
    );
    let results: Vec<MutantResult> = runner::run_mutants(&cfg, &sharded)?;

    let dispositions = report::load_dispositions(&root.join("rust/mutants.dispositions.json"))?;
    let out = opts.out.clone().unwrap_or_else(|| root.join("mutants.json"));
    let json = report::to_json("full", opts.shard, &results, &dispositions);
    std::fs::write(&out, format!("{json}\n"))
        .with_context(|| format!("writing {}", out.display()))?;
    let md = report::summary_markdown("full", &results, &dispositions);
    let md_path = out.with_extension("md");
    std::fs::write(&md_path, &md)
        .with_context(|| format!("writing {}", md_path.display()))?;
    println!("{md}");
    println!("wrote {} and {}", out.display(), md_path.display());

    let open = report::undispositioned(&results, &dispositions);
    if !open.is_empty() {
        eprintln!(
            "{} survivor(s) lack an `equivalent` disposition — add a killing test or a \
             disposition entry (see MUTANTS.md)",
            open.len()
        );
        return Ok(ExitCode::FAILURE);
    }
    Ok(ExitCode::SUCCESS)
}
