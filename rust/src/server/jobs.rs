//! Async job queue behind the REST API's `202 Accepted` endpoints.
//!
//! Long-running work (`/api/characterize`, `/api/tune`) used to block the
//! HTTP connection for its full duration — minutes of simulated cluster
//! time per request.  Service-style tuners treat tuning as asynchronous
//! jobs over a parallel measurement backend; this module is that queue:
//!
//! * [`JobQueue::submit`] records a job (`queued`), hands the work closure
//!   to an [`exec::JobRunner`] worker, and returns the job id immediately;
//! * workers flip the record to `running`, then `done` (with the result
//!   payload the old blocking endpoint would have returned) or `failed`;
//! * `GET /api/jobs/:id` polls the record; `GET /api/jobs` lists them.
//!
//! Work closures are wrapped in `catch_unwind` so a panicking job marks
//! itself `failed` instead of killing its worker thread.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::exec::JobRunner;
use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobStatus {
    pub fn name(self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
        }
    }

    /// Terminal states carry a result or an error and never change again.
    pub fn is_terminal(self) -> bool {
        matches!(self, JobStatus::Done | JobStatus::Failed)
    }
}

/// One submitted job and (eventually) its outcome.
pub struct JobRecord {
    pub id: u64,
    /// Endpoint kind, e.g. "characterize" | "tune".
    pub kind: &'static str,
    pub status: JobStatus,
    pub result: Option<Json>,
    pub error: Option<String>,
    pub submitted: Instant,
    pub finished: Option<Instant>,
}

impl JobRecord {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("job_id", Json::num(self.id as f64)),
            ("kind", Json::str(self.kind)),
            ("status", Json::str(self.status.name())),
        ];
        if let Some(fin) = self.finished {
            pairs.push((
                "elapsed_s",
                Json::num(fin.duration_since(self.submitted).as_secs_f64()),
            ));
        }
        if let Some(r) = &self.result {
            pairs.push(("result", r.clone()));
        }
        if let Some(e) = &self.error {
            pairs.push(("error", Json::str(e.clone())));
        }
        Json::obj(pairs)
    }
}

/// The queue: job records + the detached worker pool executing them.
pub struct JobQueue {
    runner: JobRunner,
    jobs: Mutex<HashMap<u64, JobRecord>>,
    next_id: Mutex<u64>,
}

impl JobQueue {
    pub fn new(workers: usize) -> Arc<JobQueue> {
        Arc::new(JobQueue {
            runner: JobRunner::new(workers),
            jobs: Mutex::new(HashMap::new()),
            next_id: Mutex::new(1),
        })
    }

    /// Enqueue `work` and return its job id without waiting.  `work` runs
    /// on a queue worker; its `Ok` payload becomes the job's `result`,
    /// its `Err` (or a panic) the job's `error`.
    pub fn submit(
        self: &Arc<Self>,
        kind: &'static str,
        work: impl FnOnce() -> Result<Json, String> + Send + 'static,
    ) -> u64 {
        let id = {
            let mut next = self.next_id.lock().unwrap();
            let id = *next;
            *next += 1;
            id
        };
        self.jobs.lock().unwrap().insert(
            id,
            JobRecord {
                id,
                kind,
                status: JobStatus::Queued,
                result: None,
                error: None,
                submitted: Instant::now(),
                finished: None,
            },
        );
        let queue = Arc::clone(self);
        self.runner.submit(move || {
            queue.set_status(id, JobStatus::Running);
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(work))
                .unwrap_or_else(|_| Err("job panicked".to_string()));
            queue.finish(id, outcome);
        });
        id
    }

    fn set_status(&self, id: u64, status: JobStatus) {
        if let Some(rec) = self.jobs.lock().unwrap().get_mut(&id) {
            rec.status = status;
        }
    }

    fn finish(&self, id: u64, outcome: Result<Json, String>) {
        if let Some(rec) = self.jobs.lock().unwrap().get_mut(&id) {
            rec.finished = Some(Instant::now());
            match outcome {
                Ok(json) => {
                    rec.status = JobStatus::Done;
                    rec.result = Some(json);
                }
                Err(msg) => {
                    rec.status = JobStatus::Failed;
                    rec.error = Some(msg);
                }
            }
        }
    }

    /// Snapshot of one job, if it exists.
    pub fn get(&self, id: u64) -> Option<Json> {
        self.jobs.lock().unwrap().get(&id).map(JobRecord::to_json)
    }

    /// Snapshot of every job, ascending by id.
    pub fn list(&self) -> Json {
        let jobs = self.jobs.lock().unwrap();
        let mut ids: Vec<u64> = jobs.keys().copied().collect();
        ids.sort_unstable();
        Json::Arr(ids.iter().map(|id| jobs[id].to_json()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn wait_terminal(q: &Arc<JobQueue>, id: u64) -> Json {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let snap = q.get(id).expect("job exists");
            let status = snap.get("status").unwrap().as_str().unwrap();
            if status == "done" || status == "failed" {
                return snap;
            }
            assert!(Instant::now() < deadline, "job {id} never finished");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn job_runs_to_done_with_result() {
        let q = JobQueue::new(2);
        let id = q.submit("test", || Ok(Json::obj(vec![("answer", Json::num(42.0))])));
        let snap = wait_terminal(&q, id);
        assert_eq!(snap.get("status").unwrap().as_str(), Some("done"));
        assert_eq!(
            snap.get("result").unwrap().get("answer").unwrap().as_f64(),
            Some(42.0)
        );
        assert!(snap.get("elapsed_s").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn failing_job_reports_error() {
        let q = JobQueue::new(1);
        let id = q.submit("test", || Err("boom".to_string()));
        let snap = wait_terminal(&q, id);
        assert_eq!(snap.get("status").unwrap().as_str(), Some("failed"));
        assert_eq!(snap.get("error").unwrap().as_str(), Some("boom"));
    }

    #[test]
    fn panicking_job_fails_without_killing_workers() {
        let q = JobQueue::new(1);
        let id = q.submit("test", || panic!("kaboom"));
        let snap = wait_terminal(&q, id);
        assert_eq!(snap.get("status").unwrap().as_str(), Some("failed"));
        // The single worker must survive to run the next job.
        let id2 = q.submit("test", || Ok(Json::num(1.0)));
        let snap2 = wait_terminal(&q, id2);
        assert_eq!(snap2.get("status").unwrap().as_str(), Some("done"));
    }

    #[test]
    fn list_orders_by_id_and_get_unknown_is_none() {
        let q = JobQueue::new(2);
        let a = q.submit("test", || Ok(Json::num(1.0)));
        let b = q.submit("test", || Ok(Json::num(2.0)));
        wait_terminal(&q, a);
        wait_terminal(&q, b);
        let listed = q.list();
        let arr = listed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert!(arr[0].get("job_id").unwrap().as_f64() < arr[1].get("job_id").unwrap().as_f64());
        assert!(q.get(999).is_none());
    }
}
