//! Async job queue behind the REST API's `202 Accepted` endpoints — the
//! job *lifecycle* subsystem.
//!
//! Long-running work (`/api/characterize`, `/api/tune`) used to block the
//! HTTP connection for its full duration — minutes of simulated cluster
//! time per request.  Service-style tuners treat tuning as asynchronous
//! jobs over a parallel measurement backend; this module is that queue:
//!
//! * [`JobQueue::submit_ctl`] records a job (`queued`), hands the work
//!   closure (plus a fresh [`JobControl`]) to an [`exec::JobRunner`]
//!   worker, and returns the job id immediately;
//! * workers flip the record to `running`, then to a terminal state:
//!   `done` (result payload), `failed` (error), `cancelled`, or
//!   `degraded` (the job's measurement-failure budget was exhausted — see
//!   [`crate::exec::JobControl::note_failures`] — and the cooperative loop
//!   stopped early, handing back its best-so-far payload plus a per-kind
//!   failure histogram);
//! * `GET /api/jobs/:id` polls the record — while `running` it carries a
//!   live `progress` object (including the failure histogram so far) and
//!   an `elapsed_s` since submission;
//! * [`JobQueue::cancel`] requests cooperative cancellation: a queued job
//!   lands in `cancelled` immediately (it never started, so no result),
//!   a running one at its next round/iteration boundary — still carrying
//!   its best-so-far partial result;
//! * [`JobQueue::try_submit_ctl`] bounds admission: when the number of
//!   non-terminal jobs reaches the queue's capacity the submission is
//!   refused ([`QueueFull`]) instead of queueing unboundedly — the API
//!   layer translates this to `429 Too Many Requests` + `Retry-After`;
//! * terminal records never change again ([`JobStatus::is_terminal`]) and
//!   are evicted lazily once older than the queue's TTL, bounding memory
//!   without a background reaper thread;
//! * [`JobQueue::terminal_snapshot`] / [`JobQueue::restore`] move terminal
//!   records across a server restart (see `server::persist`).
//!
//! Work closures are wrapped in `catch_unwind` so a panicking job marks
//! itself `failed` instead of killing its worker thread.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::exec::{JobControl, JobRunner, Progress};
use crate::sparksim::FailureHisto;
use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
    /// The job's measurement-failure budget was exhausted: the cooperative
    /// loop stopped early but still handed back its best-so-far payload.
    Degraded,
}

impl JobStatus {
    pub fn name(self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
            JobStatus::Cancelled => "cancelled",
            JobStatus::Degraded => "degraded",
        }
    }

    pub fn parse(s: &str) -> Option<JobStatus> {
        match s {
            "queued" => Some(JobStatus::Queued),
            "running" => Some(JobStatus::Running),
            "done" => Some(JobStatus::Done),
            "failed" => Some(JobStatus::Failed),
            "cancelled" => Some(JobStatus::Cancelled),
            "degraded" => Some(JobStatus::Degraded),
            _ => None,
        }
    }

    /// Terminal states carry a result or an error and never change again
    /// (enforced by every queue mutation, tested below).
    pub fn is_terminal(self) -> bool {
        matches!(
            self,
            JobStatus::Done | JobStatus::Failed | JobStatus::Cancelled | JobStatus::Degraded
        )
    }
}

/// One submitted job and (eventually) its outcome.
pub struct JobRecord {
    pub id: u64,
    /// Endpoint kind, e.g. "characterize" | "tune".
    pub kind: String,
    pub status: JobStatus,
    pub result: Option<Json>,
    pub error: Option<String>,
    pub submitted: Instant,
    pub finished: Option<Instant>,
    /// Elapsed seconds carried over from a previous process: restored
    /// records have no meaningful [`Instant`]s, so `to_json` reports this
    /// instead of a computed duration.
    pub elapsed_restored: Option<f64>,
    /// Progress/cancellation cell shared with the running work closure.
    pub ctl: Arc<JobControl>,
}

impl JobRecord {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("job_id", Json::num(self.id as f64)),
            ("kind", Json::str(self.kind.clone())),
            ("status", Json::str(self.status.name())),
        ];
        // Elapsed-since-submit is reported for *every* state: a polling
        // client needs to see how long a running job has been going, not
        // only the final duration once it finishes.
        let elapsed = self.elapsed_restored.unwrap_or_else(|| {
            self.finished
                // detlint: allow(wall-clock) -- elapsed_s reporting for a still-running job; never feeds a result value
                .unwrap_or_else(Instant::now)
                .duration_since(self.submitted)
                .as_secs_f64()
        });
        pairs.push(("elapsed_s", Json::num(elapsed)));
        if self.status == JobStatus::Running {
            let p = self.ctl.progress();
            if !p.is_empty() {
                pairs.push(("progress", progress_json(&p)));
            }
        }
        if let Some(r) = &self.result {
            pairs.push(("result", r.clone()));
        }
        if let Some(e) = &self.error {
            pairs.push(("error", Json::str(e.clone())));
        }
        Json::obj(pairs)
    }
}

fn progress_json(p: &Progress) -> Json {
    let mut pairs: Vec<(&str, Json)> = Vec::new();
    if let Some(v) = p.round {
        pairs.push(("round", Json::num(v as f64)));
    }
    if let Some(v) = p.max_rounds {
        pairs.push(("max_rounds", Json::num(v as f64)));
    }
    if let Some(v) = p.iteration {
        pairs.push(("iteration", Json::num(v as f64)));
    }
    if let Some(v) = p.iters {
        pairs.push(("iters", Json::num(v as f64)));
    }
    if let Some(v) = p.runs_executed {
        pairs.push(("runs_executed", Json::num(v as f64)));
    }
    if let Some(v) = p.runs_in_flight {
        pairs.push(("runs_in_flight", Json::num(v as f64)));
    }
    if let Some(v) = p.last_rmse {
        pairs.push(("last_rmse", Json::num(v)));
    }
    if let Some(v) = p.best_y {
        pairs.push(("best_y", Json::num(v)));
    }
    if let Some(h) = p.failures {
        if !h.is_empty() {
            pairs.push(("failures", failures_json(&h)));
        }
    }
    Json::obj(pairs)
}

/// Serialize a per-kind failure histogram — the schema the chaos smoke
/// test in CI asserts on (`.result.failures` of a degraded tune job).
pub(crate) fn failures_json(h: &FailureHisto) -> Json {
    Json::obj(vec![
        ("crash", Json::num(h.crash as f64)),
        ("oom", Json::num(h.oom as f64)),
        ("wall_cap", Json::num(h.wall_cap as f64)),
        ("hang", Json::num(h.hang as f64)),
        ("total", Json::num(h.total() as f64)),
    ])
}

/// A terminal job snapshot that can cross a process restart
/// (`server::persist` serializes these to the state file).
#[derive(Clone, Debug)]
pub struct PersistedJob {
    pub id: u64,
    pub kind: String,
    pub status: JobStatus,
    pub result: Option<Json>,
    pub error: Option<String>,
    pub elapsed_s: f64,
}

/// What [`JobQueue::cancel`] did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelOutcome {
    /// The job was still queued: it is `cancelled` (terminal) now.
    Cancelled,
    /// The job is running: cancellation was requested; the loop lands in
    /// `cancelled` at its next round/iteration boundary.
    Requested,
    /// The job already reached a terminal state; nothing to cancel.
    AlreadyTerminal,
    NotFound,
}

/// Default lifetime of terminal records before lazy eviction.
pub const DEFAULT_TTL: Duration = Duration::from_secs(3600);

/// [`JobQueue::try_submit_ctl`] refusal: the queue already holds
/// `capacity` non-terminal jobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QueueFull {
    /// Non-terminal (queued + running) jobs at refusal time.
    pub inflight: usize,
    pub capacity: usize,
}

type TerminalHook = Box<dyn Fn() + Send + Sync>;

/// The queue: job records + the detached worker pool executing them.
pub struct JobQueue {
    runner: JobRunner,
    /// `BTreeMap`, not `HashMap`: `list`/`terminal_snapshot` iterate the
    /// records, and the ordered map makes every traversal ascending by
    /// job id by construction (detlint rule `hash-iter`).
    jobs: Mutex<BTreeMap<u64, JobRecord>>,
    next_id: Mutex<u64>,
    /// Terminal records older than this are evicted on access (submit /
    /// get / list) — no background reaper thread needed to bound memory.
    ttl: Duration,
    /// Bound on non-terminal jobs for [`Self::try_submit_ctl`]; `None`
    /// means unbounded admission.
    capacity: Option<usize>,
    /// Called (lock-free) after a record turns terminal; the server hooks
    /// state persistence here.
    on_terminal: Mutex<Option<TerminalHook>>,
}

impl JobQueue {
    pub fn new(workers: usize) -> Arc<JobQueue> {
        Self::with_ttl(workers, DEFAULT_TTL)
    }

    /// Explicit TTL for terminal-record eviction.
    pub fn with_ttl(workers: usize, ttl: Duration) -> Arc<JobQueue> {
        Self::with_limits(workers, ttl, None)
    }

    /// Explicit TTL and admission bound.
    pub fn with_limits(workers: usize, ttl: Duration, capacity: Option<usize>) -> Arc<JobQueue> {
        Arc::new(JobQueue {
            runner: JobRunner::new(workers),
            jobs: Mutex::new(BTreeMap::new()),
            next_id: Mutex::new(1),
            ttl,
            capacity,
            on_terminal: Mutex::new(None),
        })
    }

    /// Install the hook called after any record turns terminal.  The hook
    /// runs on the worker (or cancelling) thread with no queue lock held,
    /// so it may call back into the queue (e.g. [`Self::terminal_snapshot`]).
    pub fn set_on_terminal(&self, hook: impl Fn() + Send + Sync + 'static) {
        *self.on_terminal.lock().unwrap() = Some(Box::new(hook));
    }

    fn notify_terminal(&self) {
        if let Some(hook) = &*self.on_terminal.lock().unwrap() {
            hook();
        }
    }

    /// Drop terminal records whose age (since finishing) exceeds the TTL.
    fn evict_expired(&self) {
        let now = Instant::now(); // detlint: allow(wall-clock) -- TTL eviction of terminal records, not a result value
        self.jobs.lock().unwrap().retain(|_, rec| {
            let expired = rec.status.is_terminal()
                && rec.finished.is_some_and(|f| now.duration_since(f) > self.ttl);
            !expired
        });
    }

    /// Enqueue `work` and return its job id without waiting.  `work` runs
    /// on a queue worker with a [`JobControl`] shared with the record; its
    /// `Ok` payload becomes the job's `result`, its `Err` (or a panic) the
    /// job's `error`.  If cancellation was requested and the work returned
    /// `Ok` (a cooperative loop handing back its partial payload), the
    /// terminal state is `cancelled` with that payload as `result`; an
    /// `Err` is always `failed`, cancel requested or not.
    pub fn submit_ctl(
        self: &Arc<Self>,
        kind: &str,
        work: impl FnOnce(&JobControl) -> Result<Json, String> + Send + 'static,
    ) -> u64 {
        self.evict_expired();
        self.submit_inner(kind, work)
    }

    /// `submit_ctl` behind the queue's admission bound: refused with
    /// [`QueueFull`] when `capacity` non-terminal jobs are already in
    /// flight.  Terminal records never count against the bound (they are
    /// bookkeeping, not load), so a saturated queue re-admits as soon as a
    /// job finishes — no TTL wait involved.
    pub fn try_submit_ctl(
        self: &Arc<Self>,
        kind: &str,
        work: impl FnOnce(&JobControl) -> Result<Json, String> + Send + 'static,
    ) -> Result<u64, QueueFull> {
        self.evict_expired();
        if let Some(cap) = self.capacity {
            let inflight =
                self.jobs.lock().unwrap().values().filter(|r| !r.status.is_terminal()).count();
            if inflight >= cap {
                return Err(QueueFull { inflight, capacity: cap });
            }
        }
        Ok(self.submit_inner(kind, work))
    }

    fn submit_inner(
        self: &Arc<Self>,
        kind: &str,
        work: impl FnOnce(&JobControl) -> Result<Json, String> + Send + 'static,
    ) -> u64 {
        let ctl = Arc::new(JobControl::default());
        let id = {
            let mut next = self.next_id.lock().unwrap();
            let id = *next;
            *next += 1;
            id
        };
        self.jobs.lock().unwrap().insert(
            id,
            JobRecord {
                id,
                kind: kind.to_string(),
                status: JobStatus::Queued,
                result: None,
                error: None,
                submitted: Instant::now(), // detlint: allow(wall-clock) -- elapsed_s bookkeeping only
                finished: None,
                elapsed_restored: None,
                ctl: Arc::clone(&ctl),
            },
        );
        let queue = Arc::clone(self);
        self.runner.submit(move || {
            // Cancelled while queued: the record is already terminal; a
            // late worker must not run the work or touch the record.
            if ctl.is_cancelled() || !queue.set_running(id) {
                return;
            }
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| work(&ctl)))
                .unwrap_or_else(|_| Err("job panicked".to_string()));
            queue.finish(id, outcome);
        });
        id
    }

    /// `submit_ctl` for work that ignores the control cell.
    pub fn submit(
        self: &Arc<Self>,
        kind: &str,
        work: impl FnOnce() -> Result<Json, String> + Send + 'static,
    ) -> u64 {
        self.submit_ctl(kind, move |_| work())
    }

    /// Flip `queued` -> `running`; false if the record is gone or already
    /// terminal (terminal records are immutable).
    fn set_running(&self, id: u64) -> bool {
        match self.jobs.lock().unwrap().get_mut(&id) {
            Some(rec) if rec.status == JobStatus::Queued => {
                rec.status = JobStatus::Running;
                true
            }
            _ => false,
        }
    }

    fn finish(&self, id: u64, outcome: Result<Json, String>) {
        let became_terminal = {
            let mut jobs = self.jobs.lock().unwrap();
            match jobs.get_mut(&id) {
                // Terminal records never change again, whatever a late
                // worker tries to write.
                Some(rec) if !rec.status.is_terminal() => {
                    rec.finished = Some(Instant::now()); // detlint: allow(wall-clock) -- elapsed_s/TTL bookkeeping only
                    match outcome {
                        Ok(json) => {
                            // Ok under a requested cancel is the cooperative
                            // loop handing back its best-so-far payload, so
                            // `cancelled` always implies a `result` — and
                            // likewise `degraded` (failure budget exhausted
                            // mid-run).  An explicit cancel wins over a
                            // degradation that raced with it.
                            rec.status = if rec.ctl.is_cancelled() {
                                JobStatus::Cancelled
                            } else if rec.ctl.is_degraded() {
                                JobStatus::Degraded
                            } else {
                                JobStatus::Done
                            };
                            rec.result = Some(json);
                        }
                        Err(msg) => {
                            // An error is `failed` even if a cancel was also
                            // requested: the work died before reaching a
                            // checkpoint and has no partial result to keep.
                            rec.status = JobStatus::Failed;
                            rec.error = Some(msg);
                        }
                    }
                    true
                }
                _ => false,
            }
        };
        if became_terminal {
            self.notify_terminal();
        }
    }

    /// Request cancellation of a job.  Queued jobs turn terminal at once;
    /// running jobs get the flag and land in `cancelled` (with their
    /// best-so-far partial result) at the next cooperative checkpoint.
    pub fn cancel(&self, id: u64) -> CancelOutcome {
        let (outcome, became_terminal) = {
            let mut jobs = self.jobs.lock().unwrap();
            match jobs.get_mut(&id) {
                None => (CancelOutcome::NotFound, false),
                Some(rec) if rec.status.is_terminal() => (CancelOutcome::AlreadyTerminal, false),
                Some(rec) if rec.status == JobStatus::Queued => {
                    rec.ctl.cancel();
                    rec.status = JobStatus::Cancelled;
                    rec.finished = Some(Instant::now()); // detlint: allow(wall-clock) -- elapsed_s/TTL bookkeeping only
                    (CancelOutcome::Cancelled, true)
                }
                Some(rec) => {
                    rec.ctl.cancel();
                    (CancelOutcome::Requested, false)
                }
            }
        };
        if became_terminal {
            self.notify_terminal();
        }
        outcome
    }

    /// Snapshot of one job, if it exists (and has not been TTL-evicted).
    pub fn get(&self, id: u64) -> Option<Json> {
        self.evict_expired();
        self.jobs.lock().unwrap().get(&id).map(JobRecord::to_json)
    }

    /// Snapshot of every job, ascending by id (the map is ordered).
    pub fn list(&self) -> Json {
        self.evict_expired();
        let jobs = self.jobs.lock().unwrap();
        Json::Arr(jobs.values().map(JobRecord::to_json).collect())
    }

    /// Terminal records as restart-safe snapshots, ascending by id (the
    /// map is ordered).
    pub fn terminal_snapshot(&self) -> Vec<PersistedJob> {
        let jobs = self.jobs.lock().unwrap();
        jobs.values()
            .filter(|r| r.status.is_terminal())
            .map(|r| PersistedJob {
                id: r.id,
                kind: r.kind.clone(),
                status: r.status,
                result: r.result.clone(),
                error: r.error.clone(),
                elapsed_s: r.elapsed_restored.unwrap_or_else(|| {
                    r.finished
                        .map_or(0.0, |f| f.duration_since(r.submitted).as_secs_f64())
                }),
            })
            .collect()
    }

    /// Re-insert terminal records from a previous process and advance the
    /// id counter past them so new submissions never collide.  Their TTL
    /// clock restarts now (the original wall-clock is not preserved).
    pub fn restore(&self, records: Vec<PersistedJob>) {
        let now = Instant::now(); // detlint: allow(wall-clock) -- restarts the TTL clock for restored records
        let mut jobs = self.jobs.lock().unwrap();
        let mut next = self.next_id.lock().unwrap();
        for pj in records {
            if !pj.status.is_terminal() {
                continue; // a live job cannot cross a restart
            }
            *next = (*next).max(pj.id + 1);
            jobs.insert(
                pj.id,
                JobRecord {
                    id: pj.id,
                    kind: pj.kind,
                    status: pj.status,
                    result: pj.result,
                    error: pj.error,
                    submitted: now,
                    finished: Some(now),
                    elapsed_restored: Some(pj.elapsed_s),
                    ctl: Arc::new(JobControl::default()),
                },
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::mpsc;

    fn wait_terminal(q: &Arc<JobQueue>, id: u64) -> Json {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let snap = q.get(id).expect("job exists");
            let status = snap.get("status").unwrap().as_str().unwrap();
            if JobStatus::parse(status).unwrap().is_terminal() {
                return snap;
            }
            assert!(Instant::now() < deadline, "job {id} never finished");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn job_runs_to_done_with_result() {
        let q = JobQueue::new(2);
        let id = q.submit("test", || Ok(Json::obj(vec![("answer", Json::num(42.0))])));
        let snap = wait_terminal(&q, id);
        assert_eq!(snap.get("status").unwrap().as_str(), Some("done"));
        assert_eq!(
            snap.get("result").unwrap().get("answer").unwrap().as_f64(),
            Some(42.0)
        );
        assert!(snap.get("elapsed_s").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn failing_job_reports_error() {
        let q = JobQueue::new(1);
        let id = q.submit("test", || Err("boom".to_string()));
        let snap = wait_terminal(&q, id);
        assert_eq!(snap.get("status").unwrap().as_str(), Some("failed"));
        assert_eq!(snap.get("error").unwrap().as_str(), Some("boom"));
    }

    #[test]
    fn panicking_job_fails_without_killing_workers() {
        let q = JobQueue::new(1);
        let id = q.submit("test", || panic!("kaboom"));
        let snap = wait_terminal(&q, id);
        assert_eq!(snap.get("status").unwrap().as_str(), Some("failed"));
        // The single worker must survive to run the next job.
        let id2 = q.submit("test", || Ok(Json::num(1.0)));
        let snap2 = wait_terminal(&q, id2);
        assert_eq!(snap2.get("status").unwrap().as_str(), Some("done"));
    }

    #[test]
    fn list_orders_by_id_and_get_unknown_is_none() {
        let q = JobQueue::new(2);
        let a = q.submit("test", || Ok(Json::num(1.0)));
        let b = q.submit("test", || Ok(Json::num(2.0)));
        wait_terminal(&q, a);
        wait_terminal(&q, b);
        let listed = q.list();
        let arr = listed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert!(arr[0].get("job_id").unwrap().as_f64() < arr[1].get("job_id").unwrap().as_f64());
        assert!(q.get(999).is_none());
    }

    #[test]
    fn running_job_exposes_progress_and_elapsed() {
        let q = JobQueue::new(1);
        let (tx, rx) = mpsc::channel::<()>();
        let id = q.submit_ctl("test", move |ctl| {
            ctl.update(|p| {
                p.iteration = Some(3);
                p.iters = Some(10);
                p.best_y = Some(1.5);
            });
            let _ = rx.recv_timeout(Duration::from_secs(10));
            Ok(Json::num(1.0))
        });
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let snap = q.get(id).expect("job exists");
            if snap.get("status").unwrap().as_str() == Some("running") {
                if let Some(p) = snap.get("progress") {
                    assert_eq!(p.get("iteration").unwrap().as_f64(), Some(3.0));
                    assert_eq!(p.get("iters").unwrap().as_f64(), Some(10.0));
                    assert_eq!(p.get("best_y").unwrap().as_f64(), Some(1.5));
                    // A *running* job reports elapsed-since-submit too.
                    assert!(snap.get("elapsed_s").unwrap().as_f64().unwrap() >= 0.0);
                    break;
                }
            }
            assert!(Instant::now() < deadline, "progress never surfaced");
            std::thread::sleep(Duration::from_millis(5));
        }
        tx.send(()).unwrap();
        let done = wait_terminal(&q, id);
        assert!(done.get("progress").is_none(), "terminal snapshots drop progress");
    }

    #[test]
    fn cancel_running_job_lands_cancelled_with_partial_result() {
        let q = JobQueue::new(1);
        let (tx, rx) = mpsc::channel::<()>();
        let id = q.submit_ctl("test", move |ctl| {
            tx.send(()).unwrap(); // signal: running
            let deadline = Instant::now() + Duration::from_secs(10);
            while !ctl.is_cancelled() && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(2));
            }
            Ok(Json::obj(vec![("partial", Json::Bool(true))]))
        });
        rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(q.cancel(id), CancelOutcome::Requested);
        let snap = wait_terminal(&q, id);
        assert_eq!(snap.get("status").unwrap().as_str(), Some("cancelled"));
        // The cooperative loop still handed back its best-so-far payload.
        assert_eq!(
            snap.get("result").unwrap().get("partial").unwrap().as_bool(),
            Some(true)
        );
        // Cancelling again (or an unknown id) is refused cleanly.
        assert_eq!(q.cancel(id), CancelOutcome::AlreadyTerminal);
        assert_eq!(q.cancel(999), CancelOutcome::NotFound);
    }

    #[test]
    fn error_after_cancel_request_is_failed_not_cancelled() {
        let q = JobQueue::new(1);
        let (tx, rx) = mpsc::channel::<()>();
        let id = q.submit_ctl("test", move |ctl| {
            tx.send(()).unwrap(); // signal: running
            let deadline = Instant::now() + Duration::from_secs(10);
            while !ctl.is_cancelled() && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(2));
            }
            // Died before reaching a checkpoint: no partial payload.
            Err("boom mid-round".to_string())
        });
        rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(q.cancel(id), CancelOutcome::Requested);
        let snap = wait_terminal(&q, id);
        // `cancelled` must imply a result, so an error stays `failed`.
        assert_eq!(snap.get("status").unwrap().as_str(), Some("failed"));
        assert_eq!(snap.get("error").unwrap().as_str(), Some("boom mid-round"));
        assert!(snap.get("result").is_none());
    }

    #[test]
    fn cancelled_queued_job_is_immutable_against_late_worker_write() {
        let q = JobQueue::new(1);
        let (tx, rx) = mpsc::channel::<()>();
        // The blocker occupies the only worker...
        let blocker = q.submit("test", move || {
            let _ = rx.recv_timeout(Duration::from_secs(10));
            Ok(Json::num(0.0))
        });
        // ...so the victim sits queued when we cancel it: terminal at once.
        let victim = q.submit("test", || Ok(Json::num(99.0)));
        assert_eq!(q.cancel(victim), CancelOutcome::Cancelled);
        let snap = q.get(victim).unwrap();
        assert_eq!(snap.get("status").unwrap().as_str(), Some("cancelled"));
        // Release the worker; it dequeues the victim next and must not
        // run it or touch the terminal record.
        tx.send(()).unwrap();
        wait_terminal(&q, blocker);
        std::thread::sleep(Duration::from_millis(50));
        let snap2 = q.get(victim).unwrap();
        assert_eq!(snap2, snap, "terminal record mutated by a late worker");
        assert!(snap2.get("result").is_none());
    }

    #[test]
    fn concurrent_submit_and_list_are_safe() {
        let q = JobQueue::new(4);
        let stop = Arc::new(AtomicBool::new(false));
        let reader = {
            let q = Arc::clone(&q);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    let listed = q.list();
                    let arr = listed.as_arr().unwrap();
                    // ids stay strictly ascending in every snapshot
                    for w in arr.windows(2) {
                        assert!(
                            w[0].get("job_id").unwrap().as_f64()
                                < w[1].get("job_id").unwrap().as_f64()
                        );
                    }
                }
            })
        };
        let submitters: Vec<_> = (0..4)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for _ in 0..25 {
                        q.submit("test", || Ok(Json::num(1.0)));
                    }
                })
            })
            .collect();
        for h in submitters {
            h.join().unwrap();
        }
        stop.store(true, Ordering::SeqCst);
        reader.join().unwrap();
        assert_eq!(q.list().as_arr().unwrap().len(), 100, "all submissions recorded");
    }

    #[test]
    fn terminal_records_evicted_after_ttl_but_live_ones_survive() {
        let q = JobQueue::with_ttl(1, Duration::from_millis(20));
        let id = q.submit("test", || Ok(Json::num(1.0)));
        wait_terminal(&q, id);
        std::thread::sleep(Duration::from_millis(60));
        assert!(q.get(id).is_none(), "terminal record outlived its TTL");
        assert!(q.list().as_arr().unwrap().is_empty());
        // A still-running record is never evicted, however old.
        let (tx, rx) = mpsc::channel::<()>();
        let id2 = q.submit("test", move || {
            let _ = rx.recv_timeout(Duration::from_secs(10));
            Ok(Json::num(2.0))
        });
        std::thread::sleep(Duration::from_millis(60));
        assert!(q.get(id2).is_some(), "live record must survive the TTL");
        let _ = tx.send(());
    }

    #[test]
    fn terminal_snapshot_restore_roundtrip_and_id_continuation() {
        let q = JobQueue::new(1);
        let id = q.submit("tune", || Ok(Json::num(7.0)));
        wait_terminal(&q, id);
        let snap = q.terminal_snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].id, id);
        assert_eq!(snap[0].status, JobStatus::Done);

        let q2 = JobQueue::new(1);
        q2.restore(snap);
        let rec = q2.get(id).unwrap();
        assert_eq!(rec.get("status").unwrap().as_str(), Some("done"));
        assert_eq!(rec.get("kind").unwrap().as_str(), Some("tune"));
        assert_eq!(rec.get("result").unwrap().as_f64(), Some(7.0));
        assert!(rec.get("elapsed_s").unwrap().as_f64().unwrap() >= 0.0);
        // New submissions continue past the restored id.
        let id2 = q2.submit("test", || Ok(Json::num(1.0)));
        assert!(id2 > id, "restored ids must not be reused");
        wait_terminal(&q2, id2);
    }

    #[test]
    fn degraded_job_is_terminal_with_result() {
        let q = JobQueue::new(1);
        let id = q.submit_ctl("tune", |ctl| {
            // The work trips its own failure budget mid-run, then hands
            // back its best-so-far payload like a cooperative loop would.
            ctl.set_fail_budget(2);
            ctl.note_failures(3);
            assert!(ctl.should_stop());
            Ok(Json::obj(vec![("best_y", Json::num(1.0))]))
        });
        let snap = wait_terminal(&q, id);
        assert_eq!(snap.get("status").unwrap().as_str(), Some("degraded"));
        assert_eq!(snap.get("result").unwrap().get("best_y").unwrap().as_f64(), Some(1.0));
        assert!(snap.get("error").is_none());
        // Degraded records cross restarts like any terminal state.
        let persisted = q.terminal_snapshot();
        assert_eq!(persisted[0].status, JobStatus::Degraded);
        let q2 = JobQueue::new(1);
        q2.restore(persisted);
        let rec = q2.get(id).unwrap();
        assert_eq!(rec.get("status").unwrap().as_str(), Some("degraded"));
    }

    #[test]
    fn bounded_queue_refuses_at_capacity_and_readmits_after_finish() {
        let q = JobQueue::with_limits(1, DEFAULT_TTL, Some(1));
        let (tx, rx) = mpsc::channel::<()>();
        let id = q
            .try_submit_ctl("test", move |_| {
                let _ = rx.recv_timeout(Duration::from_secs(10));
                Ok(Json::num(1.0))
            })
            .expect("empty queue admits");
        // One job in flight fills the capacity-1 queue.
        let err = q.try_submit_ctl("test", |_| Ok(Json::num(2.0))).unwrap_err();
        assert_eq!(err, QueueFull { inflight: 1, capacity: 1 });
        // Unbounded submit still bypasses the admission check.
        let forced = q.submit("test", || Ok(Json::num(3.0)));
        // Finish both; terminal records never count against the bound.
        tx.send(()).unwrap();
        wait_terminal(&q, id);
        wait_terminal(&q, forced);
        let id3 = q.try_submit_ctl("test", |_| Ok(Json::num(4.0))).expect("readmits");
        wait_terminal(&q, id3);
    }

    #[test]
    fn on_terminal_hook_fires_for_finish_and_queued_cancel() {
        use std::sync::atomic::AtomicUsize;
        let q = JobQueue::new(1);
        let fired = Arc::new(AtomicUsize::new(0));
        {
            let fired = Arc::clone(&fired);
            q.set_on_terminal(move || {
                fired.fetch_add(1, Ordering::SeqCst);
            });
        }
        let id = q.submit("test", || Ok(Json::num(1.0)));
        wait_terminal(&q, id);
        assert!(fired.load(Ordering::SeqCst) >= 1);
        let before = fired.load(Ordering::SeqCst);
        // Block the worker, cancel a queued job: the hook fires again.
        let (tx, rx) = mpsc::channel::<()>();
        let _blocker = q.submit("test", move || {
            let _ = rx.recv_timeout(Duration::from_secs(10));
            Ok(Json::num(0.0))
        });
        let victim = q.submit("test", || Ok(Json::num(2.0)));
        assert_eq!(q.cancel(victim), CancelOutcome::Cancelled);
        assert!(fired.load(Ordering::SeqCst) > before);
        let _ = tx.send(());
    }
}
