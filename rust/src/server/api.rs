//! REST API — the backend of the paper's ReactJS UI (Fig 2): "The backend
//! houses the optimization algorithms ... exposed through a REST API."
//!
//! Cheap endpoints respond synchronously; the two long-running ones
//! (`characterize`, `tune`) are **asynchronous jobs**: the POST validates
//! the request, enqueues the work on the server's job queue (executed by
//! the `exec` worker pool) and returns `202 Accepted` with a job id
//! immediately; clients poll `/api/jobs/:id` until `status` is `done`
//! (the `result` field then carries exactly the payload the old blocking
//! endpoint returned) or `failed`.
//!
//! Endpoints:
//!   GET  /api/health                         liveness + backend name
//!   GET  /api/benchmarks                     Table I workload descriptions
//!   GET  /api/flags?gc=g1|parallel           flag catalog for a GC group
//!   POST /api/run          {bench, gc, seed?, flags?{name:value}}
//!   POST /api/characterize {bench, gc, metric?, strategy?, pool?, rounds?}
//!                          -> 202 {job_id, status, poll}
//!   POST /api/select       {dataset_id, lambda?}
//!   POST /api/tune         {dataset_id?, bench, gc, metric?, algo, iters?,
//!                           gp_hypers?: "fixed"|"adapt", gp_adapt_every?,
//!                           gp_ard?: bool,
//!                           gp_init_hypers?: {lengthscales: [..], sigma_n2?},
//!                           faults?: {seed?, crash_p?, hang_p?, spike_p?,
//!                                     spike_mult?, max_retries?,
//!                                     backoff_base_s?, backoff_cap_s?,
//!                                     run_budget_s?,
//!                                     crash_regions?: [{flag, lo, hi}]},
//!                           fail_budget?: int, batch_q?: int,
//!                           gp_kernels?: "scalar"|"blocked"}
//!                          -> 202 {job_id, status, poll}
//!                          (`gp_hypers: "adapt"` turns on GP
//!                          marginal-likelihood hyper-parameter
//!                          adaptation + O(n²) downdate evictions in the
//!                          surrogate session; default "fixed" keeps the
//!                          bit-reproducible path.  `gp_ard: true` frees
//!                          the per-dimension length-scales (implies
//!                          adapt; 400 against an explicit "fixed") and
//!                          the job record gains an `ard_relevance`
//!                          object over the tuned flags next to the
//!                          lasso selection.  `gp_init_hypers`
//!                          warm-starts the surrogate at a previous
//!                          job's reported `gp_lengthscales` /
//!                          `gp_sigma_n2`; a length-scale count that
//!                          does not match the tuning subspace is a 400,
//!                          checked synchronously because feature
//!                          selection now runs at submission time.
//!                          `faults` activates seeded fault injection on
//!                          the job's measurements — validated to a 400
//!                          up front, deterministic from its seed (which
//!                          defaults to the pipeline seed).  `fail_budget`
//!                          caps total measurement failures; once
//!                          exceeded the job stops at its next checkpoint
//!                          and lands in the `degraded` terminal state,
//!                          still carrying its best-so-far result.
//!                          `batch_q` proposes that many configurations
//!                          per BO iteration (constant-liar q-EI) and
//!                          evaluates them concurrently; 0, non-integers
//!                          and values beyond the candidate pool size are
//!                          400s, and the default of 1 keeps the
//!                          bit-reproducible single-point path.
//!                          `gp_kernels` selects the surrogate's
//!                          linear-algebra tier: "scalar" (default) is
//!                          the bitwise-pinned reference arithmetic,
//!                          "blocked" the panel/lane kernel tier — 1e-8
//!                          from scalar, itself bitwise reproducible at
//!                          any pool width.  Unknown values are a
//!                          synchronous 400; the job record echoes the
//!                          effective tier as `gp_kernels`.  Tune
//!                          results always include a `failures` per-kind
//!                          histogram {crash, oom, wall_cap, hang, total})
//!   GET  /api/jobs                           all jobs, ascending id
//!   GET  /api/jobs/:id     {job_id, kind, status, elapsed_s,
//!                           progress?, result?|error?}
//!   DELETE /api/jobs/:id   cancel a queued/running job -> 202 snapshot
//!                          (404 unknown, 409 already terminal)
//!   GET  /api/datasets                       characterization sessions
//!
//! Job lifecycle: while a job is `running`, its snapshot carries a live
//! `progress` object (AL: `round`/`max_rounds`/`runs_executed`/
//! `last_rmse`; tuning: `iteration`/`iters`/`best_y`) plus `elapsed_s`
//! since submission.  `DELETE /api/jobs/:id` requests cooperative
//! cancellation — a *running* job lands in `cancelled` at its next
//! round/iteration boundary, still carrying its best-so-far partial
//! `result`; a job cancelled while still *queued* never started, so its
//! `cancelled` record has no `result`.  A job whose `fail_budget` is
//! exhausted stops the same cooperative way but lands in `degraded`,
//! always with a `result`.  Terminal records (`done` | `failed` |
//! `cancelled` | `degraded`) never change again and are evicted lazily
//! after the queue's TTL.  Submissions beyond the queue's capacity of
//! non-terminal jobs are refused with `429 Too Many Requests` + a
//! `Retry-After` header instead of queueing unboundedly.  With a state directory configured ([`ApiOptions`],
//! `serve --state-dir`), stored datasets and terminal job records are
//! persisted to a JSON state file on every completion and reloaded on
//! restart.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, Weak};
use std::time::Duration;

use crate::datagen::{self, DataGenConfig, Dataset, Strategy};
use crate::exec;
use crate::featsel;
use crate::flags::{FlagConfig, GcMode};
use crate::pipeline::{self, Algo, PipelineConfig};
use crate::runtime::{HyperMode, KernelPolicy, MlBackend};
use crate::server::http::{Request, Response};
use crate::server::jobs::{self, CancelOutcome, JobQueue};
use crate::server::persist;
use crate::sparksim::{CrashRegion, FaultPlan, SparkRunner};
use crate::tuner::TuneSpace;
use crate::util::json::Json;
use crate::{Benchmark, Metric};

/// Server construction options.
#[derive(Clone, Debug)]
pub struct ApiOptions {
    /// Background job-queue workers.  Two by default, not one per core:
    /// each job already saturates the cores through the global exec pool,
    /// so a wide queue would only oversubscribe the CPU; two give
    /// pipeline overlap with fair FIFO ordering.
    pub workers: usize,
    /// Lifetime of terminal job records before lazy eviction.
    pub job_ttl: Duration,
    /// Directory for the restart-persistence state file; `None` keeps
    /// everything in memory (tests, throwaway servers).
    pub state_dir: Option<PathBuf>,
    /// Max non-terminal jobs admitted before `/api/characterize` and
    /// `/api/tune` answer `429 Too Many Requests` + `Retry-After`;
    /// `None` disables backpressure.
    pub queue_capacity: Option<usize>,
}

/// Default admission bound: generous for interactive use, small enough
/// that a runaway submit loop hits backpressure before exhausting memory.
pub const DEFAULT_QUEUE_CAPACITY: usize = 64;

/// `Retry-After` hint (seconds) sent with a 429 — roughly the time a
/// queued quick job takes to drain.
pub const RETRY_AFTER_S: u64 = 5;

impl Default for ApiOptions {
    fn default() -> Self {
        ApiOptions {
            workers: 2,
            job_ttl: jobs::DEFAULT_TTL,
            state_dir: None,
            queue_capacity: Some(DEFAULT_QUEUE_CAPACITY),
        }
    }
}

/// Shared server state: the ML backend, characterization sessions, and
/// the async job queue.
pub struct ApiState {
    pub backend: Arc<dyn MlBackend>,
    /// `BTreeMap`, not `HashMap`: `/api/datasets` and the persisted
    /// snapshot iterate this map, and the ordered map makes both
    /// ascending-by-id by construction (detlint rule `hash-iter`).
    pub datasets: Mutex<BTreeMap<u64, StoredDataset>>,
    pub jobs: Arc<JobQueue>,
    next_id: Mutex<u64>,
    state_dir: Option<PathBuf>,
    /// Serializes state-file writes: `persist` is reached both from the
    /// queue's terminal hook and directly from `store` on (multiple)
    /// worker threads, and concurrent writers would race on the shared
    /// temp file, tearing the state they are trying to save.
    persist_lock: Mutex<()>,
}

#[derive(Clone)]
pub struct StoredDataset {
    pub bench: Benchmark,
    pub dataset: Dataset,
    pub rmse_history: Vec<f64>,
}

impl ApiState {
    pub fn new(backend: Arc<dyn MlBackend>) -> Arc<ApiState> {
        Self::with_options(backend, ApiOptions::default())
    }

    /// Explicit worker count for the background job queue.
    pub fn with_workers(backend: Arc<dyn MlBackend>, workers: usize) -> Arc<ApiState> {
        Self::with_options(backend, ApiOptions { workers, ..Default::default() })
    }

    /// Full construction: reloads persisted datasets + terminal job
    /// records when `opts.state_dir` holds a state file, and hooks
    /// persistence onto every subsequent completion.
    pub fn with_options(backend: Arc<dyn MlBackend>, opts: ApiOptions) -> Arc<ApiState> {
        let jobs = JobQueue::with_limits(opts.workers, opts.job_ttl, opts.queue_capacity);
        let mut datasets = BTreeMap::new();
        let mut next_id = 1u64;
        if let Some(dir) = &opts.state_dir {
            if let Some(saved) = persist::load(dir) {
                next_id = saved.next_dataset_id;
                for (id, d) in saved.datasets {
                    datasets.insert(id, d);
                }
                jobs.restore(saved.jobs);
            }
        }
        let state = Arc::new(ApiState {
            backend,
            datasets: Mutex::new(datasets),
            jobs,
            next_id: Mutex::new(next_id),
            state_dir: opts.state_dir,
            persist_lock: Mutex::new(()),
        });
        if state.state_dir.is_some() {
            // Weak: the queue outlives request handlers but must not keep
            // the state alive in a cycle (state -> jobs -> hook -> state).
            let weak: Weak<ApiState> = Arc::downgrade(&state);
            state.jobs.set_on_terminal(move || {
                if let Some(s) = weak.upgrade() {
                    s.persist();
                }
            });
        }
        state
    }

    fn store(&self, d: StoredDataset) -> u64 {
        let this = {
            let mut id = self.next_id.lock().unwrap();
            let this = *id;
            *id += 1;
            this
        };
        self.datasets.lock().unwrap().insert(this, d);
        // No persist here: store is only reached from inside a job whose
        // terminal transition fires the persist hook moments later, and
        // writing the full state twice per characterize gains nothing.
        this
    }

    /// Write datasets + terminal job records to the state file (no-op
    /// without a state dir).  The data locks are taken one at a time,
    /// never nested, so this is safe to call from the queue's terminal
    /// hook; `persist_lock` is held across the snapshot + write so
    /// concurrent completions serialize instead of tearing the temp file.
    fn persist(&self) {
        let Some(dir) = &self.state_dir else { return };
        let _write_guard = self.persist_lock.lock().unwrap();
        let next_dataset_id = *self.next_id.lock().unwrap();
        let datasets = persist::dataset_snapshot(&self.datasets.lock().unwrap());
        let jobs = self.jobs.terminal_snapshot();
        let state = persist::PersistedState { next_dataset_id, datasets, jobs };
        // detlint: allow(lock-across-io) -- persist_lock exists to serialize exactly this snapshot + atomic write; data locks are already released
        if let Err(e) = persist::save(dir, &state) {
            eprintln!("warning: failed to persist server state to {}: {e}", dir.display());
        }
    }
}

/// Route one request.
pub fn handle(state: &Arc<ApiState>, req: &Request) -> Response {
    let result = match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/api/health") => Ok((200, health(state))),
        ("GET", "/api/benchmarks") => Ok((200, benchmarks())),
        ("GET", "/api/flags") => flags(req),
        ("POST", "/api/run") => run(req),
        ("POST", "/api/characterize") => characterize(state, req),
        ("POST", "/api/select") => select(state, req),
        ("POST", "/api/tune") => tune(state, req),
        ("GET", "/api/jobs") => Ok((200, state.jobs.list())),
        ("GET", path) if path.starts_with("/api/jobs/") => job_status(state, path),
        ("DELETE", path) if path.starts_with("/api/jobs/") => cancel_job(state, path),
        ("GET", "/api/datasets") => Ok((200, datasets(state))),
        _ => Err((404, "no such endpoint".to_string())),
    };
    match result {
        Ok((status, json)) => Response::json(status, json.to_string()),
        Err((code, msg)) => {
            let resp = Response::json(
                code,
                Json::obj(vec![("error", Json::str(msg))]).to_string(),
            );
            // Backpressure refusals tell the client when to come back.
            if code == 429 {
                resp.with_retry_after(RETRY_AFTER_S)
            } else {
                resp
            }
        }
    }
}

type ApiResult = Result<(u16, Json), (u16, String)>;

fn bad(msg: impl Into<String>) -> (u16, String) {
    (400, msg.into())
}

fn body_json(req: &Request) -> Result<Json, (u16, String)> {
    if req.body.trim().is_empty() {
        return Ok(Json::obj(vec![]));
    }
    Json::parse(&req.body).map_err(|e| bad(format!("invalid json body: {e}")))
}

fn parse_bench(v: Option<&Json>) -> Result<Benchmark, (u16, String)> {
    v.and_then(Json::as_str)
        .and_then(Benchmark::parse)
        .ok_or_else(|| bad("missing/unknown 'bench' (lda | densekmeans)"))
}

fn parse_gc(v: Option<&Json>) -> Result<GcMode, (u16, String)> {
    v.and_then(Json::as_str)
        .and_then(GcMode::parse)
        .ok_or_else(|| bad("missing/unknown 'gc' (g1 | parallel)"))
}

/// Absent means the default objective; *present but unparseable* is a
/// client error — silently tuning `exec_time` because the caller typo'd
/// `"exectime "` would optimize the wrong objective with no signal.
fn parse_metric(v: Option<&Json>) -> Result<Metric, (u16, String)> {
    match v {
        None => Ok(Metric::ExecTime),
        Some(j) => j
            .as_str()
            .and_then(Metric::parse)
            .ok_or_else(|| bad("unknown 'metric' (exec_time | heap_usage)")),
    }
}

/// The `202 Accepted` submission payload.
fn accepted(id: u64) -> (u16, Json) {
    (
        202,
        Json::obj(vec![
            ("job_id", Json::num(id as f64)),
            ("status", Json::str("queued")),
            ("poll", Json::str(format!("/api/jobs/{id}"))),
        ]),
    )
}

fn job_id_from(path: &str) -> Result<u64, (u16, String)> {
    path.trim_start_matches("/api/jobs/")
        .parse()
        .map_err(|_| bad("job id must be an integer"))
}

fn job_status(state: &Arc<ApiState>, path: &str) -> ApiResult {
    let id = job_id_from(path)?;
    match state.jobs.get(id) {
        Some(snapshot) => Ok((200, snapshot)),
        None => Err((404, format!("no job {id}"))),
    }
}

/// `DELETE /api/jobs/:id` — cooperative cancellation.  Answers 202 with
/// the post-request snapshot (a queued job is already `cancelled`; a
/// running one flips at its next checkpoint), 409 for terminal jobs.
fn cancel_job(state: &Arc<ApiState>, path: &str) -> ApiResult {
    let id = job_id_from(path)?;
    match state.jobs.cancel(id) {
        CancelOutcome::NotFound => Err((404, format!("no job {id}"))),
        CancelOutcome::AlreadyTerminal => {
            Err((409, format!("job {id} already reached a terminal state")))
        }
        CancelOutcome::Cancelled | CancelOutcome::Requested => {
            let snapshot = state
                .jobs
                .get(id)
                .unwrap_or_else(|| Json::obj(vec![("job_id", Json::num(id as f64))]));
            Ok((202, snapshot))
        }
    }
}

fn health(state: &Arc<ApiState>) -> Json {
    Json::obj(vec![
        ("status", Json::str("ok")),
        ("backend", Json::str(state.backend.name())),
        ("version", Json::str(env!("CARGO_PKG_VERSION"))),
    ])
}

fn benchmarks() -> Json {
    Json::Arr(
        Benchmark::all()
            .iter()
            .map(|b| {
                let s = b.spec();
                Json::obj(vec![
                    ("name", Json::str(s.name)),
                    ("dataset", Json::str(s.dataset)),
                    ("input_gb", Json::num(s.input_gb)),
                    ("n_tasks", Json::num(s.n_tasks as f64)),
                ])
            })
            .collect(),
    )
}

fn flags(req: &Request) -> ApiResult {
    let gc = req
        .query_param("gc")
        .and_then(GcMode::parse)
        .ok_or_else(|| bad("query param gc=g1|parallel required"))?;
    let cfg = FlagConfig::default_for(gc);
    let arr = cfg
        .defs()
        .iter()
        .map(|f| {
            let (ty, min, max) = match f.kind {
                crate::flags::Kind::Bool { .. } => ("bool", 0.0, 1.0),
                crate::flags::Kind::Int { min, max, .. } => ("int", min, max),
            };
            Json::obj(vec![
                ("name", Json::str(f.name)),
                ("type", Json::str(ty)),
                ("min", Json::num(min)),
                ("max", Json::num(max)),
                ("default", Json::num(f.default_value())),
            ])
        })
        .collect();
    Ok((200, Json::Arr(arr)))
}

fn config_from_body(gc: GcMode, body: &Json) -> Result<FlagConfig, (u16, String)> {
    let mut cfg = FlagConfig::default_for(gc);
    if let Some(Json::Obj(flags)) = body.get("flags") {
        for (name, v) in flags {
            let v = v.as_f64().ok_or_else(|| bad(format!("flag {name} not numeric")))?;
            if !cfg.defs().iter().any(|f| f.name == name.as_str()) {
                return Err(bad(format!("unknown flag {name} for {}", gc.name())));
            }
            cfg.set(name, v);
        }
    }
    Ok(cfg)
}

fn run(req: &Request) -> ApiResult {
    let body = body_json(req)?;
    let bench = parse_bench(body.get("bench"))?;
    let gc = parse_gc(body.get("gc"))?;
    let seed = body.get("seed").and_then(Json::as_f64).unwrap_or(1.0) as u64;
    let cfg = config_from_body(gc, &body)?;
    let m = SparkRunner::paper_default(bench).run(&cfg, seed);
    let mut fields = vec![
        ("exec_time_s", Json::num(m.exec_time_s)),
        ("heap_usage_pct", Json::num(m.hu_avg_pct)),
        ("minor_gcs", Json::num(m.gc.minor as f64)),
        ("full_gcs", Json::num(m.gc.full as f64)),
        ("total_pause_ms", Json::num(m.gc.total_pause_ms)),
        ("failed", Json::Bool(m.failed())),
    ];
    if let Some(kind) = m.failure {
        fields.push(("failure", Json::str(kind.name())));
    }
    Ok((200, Json::obj(fields)))
}

/// Parse the optional `faults` object into a validated [`FaultPlan`];
/// a malformed or self-contradictory plan is a 400 here, not a failed
/// job later.  The plan seed defaults to `default_seed` (the pipeline
/// seed) so a faulty run is reproducible from the job parameters alone.
fn parse_faults(body: &Json, default_seed: u64) -> Result<Option<FaultPlan>, (u16, String)> {
    let Some(f) = body.get("faults") else { return Ok(None) };
    if !matches!(f, Json::Obj(_)) {
        return Err(bad("'faults' must be an object"));
    }
    let mut plan = FaultPlan { seed: default_seed, ..Default::default() };
    let num = |key: &str| -> Result<Option<f64>, (u16, String)> {
        match f.get(key) {
            None => Ok(None),
            Some(v) => v
                .as_f64()
                .filter(|x| x.is_finite())
                .map(Some)
                .ok_or_else(|| bad(format!("'faults.{key}' must be a finite number"))),
        }
    };
    if let Some(v) = num("seed")? {
        plan.seed = v as u64;
    }
    if let Some(v) = num("crash_p")? {
        plan.crash_p = v;
    }
    if let Some(v) = num("hang_p")? {
        plan.hang_p = v;
    }
    if let Some(v) = num("spike_p")? {
        plan.spike_p = v;
    }
    if let Some(v) = num("spike_mult")? {
        plan.spike_mult = v;
    }
    if let Some(v) = num("max_retries")? {
        if v < 0.0 || v.fract() != 0.0 {
            return Err(bad("'faults.max_retries' must be a non-negative integer"));
        }
        plan.max_retries = v as u32;
    }
    if let Some(v) = num("backoff_base_s")? {
        plan.backoff_base_s = v;
    }
    if let Some(v) = num("backoff_cap_s")? {
        plan.backoff_cap_s = v;
    }
    if let Some(v) = num("run_budget_s")? {
        plan.run_budget_s = v;
    }
    if let Some(regions) = f.get("crash_regions") {
        let arr = regions
            .as_arr()
            .ok_or_else(|| bad("'faults.crash_regions' must be an array"))?;
        for r in arr {
            let flag = r
                .get("flag")
                .and_then(Json::as_str)
                .ok_or_else(|| bad("crash region needs a 'flag' name"))?
                .to_string();
            let lo = r.get("lo").and_then(Json::as_f64).unwrap_or(0.0);
            let hi = r.get("hi").and_then(Json::as_f64).unwrap_or(1.0);
            plan.crash_regions.push(CrashRegion { flag, lo, hi });
        }
    }
    plan.validate().map_err(bad)?;
    Ok(Some(plan))
}

/// Validate, enqueue the AL characterization, answer 202 + job id.
fn characterize(state: &Arc<ApiState>, req: &Request) -> ApiResult {
    let body = body_json(req)?;
    let bench = parse_bench(body.get("bench"))?;
    let gc = parse_gc(body.get("gc"))?;
    let metric = parse_metric(body.get("metric"))?;
    let strategy = body
        .get("strategy")
        .and_then(Json::as_str)
        .and_then(Strategy::parse)
        .unwrap_or(Strategy::Bemcm);
    let mut dg = DataGenConfig::default();
    if let Some(p) = body.get("pool").and_then(Json::as_f64) {
        dg.pool_size = p as usize;
    }
    if let Some(r) = body.get("rounds").and_then(Json::as_f64) {
        dg.max_rounds = r as usize;
    }
    if let Some(s) = body.get("seed").and_then(Json::as_f64) {
        dg.seed = s as u64;
    }

    let job_state = Arc::clone(state);
    let submitted = state.jobs.try_submit_ctl("characterize", move |ctl| {
        let runner = SparkRunner::paper_default(bench);
        let r = datagen::characterize_ctl(
            exec::global(),
            &runner,
            gc,
            metric,
            strategy,
            &dg,
            &job_state.backend,
            ctl,
        )
        .map_err(|e| format!("{e:#}"))?;
        let id = job_state.store(StoredDataset {
            bench,
            dataset: r.dataset.clone(),
            rmse_history: r.rmse_history.clone(),
        });
        Ok(Json::obj(vec![
            ("dataset_id", Json::num(id as f64)),
            ("samples", Json::num(r.dataset.len() as f64)),
            ("runs_executed", Json::num(r.runs_executed as f64)),
            ("rounds", Json::num(r.rounds as f64)),
            ("rmse_history", Json::arr_f64(&r.rmse_history)),
            ("sim_time_s", Json::num(r.sim_time_s)),
            ("failures", jobs::failures_json(&r.failures)),
        ]))
    });
    match submitted {
        Ok(id) => Ok(accepted(id)),
        Err(full) => Err(queue_full(full)),
    }
}

/// Map a refused submission to the 429 body (the router attaches the
/// `Retry-After` header).
fn queue_full(full: jobs::QueueFull) -> (u16, String) {
    (
        429,
        format!(
            "job queue full: {} of {} jobs in flight; retry in ~{RETRY_AFTER_S}s",
            full.inflight, full.capacity
        ),
    )
}

fn select(state: &Arc<ApiState>, req: &Request) -> ApiResult {
    let body = body_json(req)?;
    let id = body
        .get("dataset_id")
        .and_then(Json::as_f64)
        .ok_or_else(|| bad("dataset_id required"))? as u64;
    let lambda = body.get("lambda").and_then(Json::as_f64).unwrap_or(featsel::DEFAULT_LAMBDA);
    let store = state.datasets.lock().unwrap();
    let stored = store.get(&id).ok_or_else(|| bad(format!("no dataset {id}")))?;
    let sel = featsel::select_flags(&stored.dataset, lambda, &state.backend)
        .map_err(|e| (500, format!("{e:#}")))?;
    Ok((
        200,
        Json::obj(vec![
            ("lambda", Json::num(sel.lambda)),
            ("group_size", Json::num(sel.group_size as f64)),
            ("n_selected", Json::num(sel.n_selected() as f64)),
            (
                "selected",
                Json::Arr(sel.names.iter().map(|n| Json::str(n.clone())).collect()),
            ),
        ]),
    ))
}

/// Validate, enqueue the tuning run, answer 202 + job id.
fn tune(state: &Arc<ApiState>, req: &Request) -> ApiResult {
    let body = body_json(req)?;
    let bench = parse_bench(body.get("bench"))?;
    let gc = parse_gc(body.get("gc"))?;
    let metric = parse_metric(body.get("metric"))?;
    let algo = body
        .get("algo")
        .and_then(Json::as_str)
        .and_then(Algo::parse)
        .ok_or_else(|| bad("missing/unknown 'algo' (bo | rbo | bo-warm | sa)"))?;
    let iters = body.get("iters").and_then(Json::as_f64).unwrap_or(20.0) as usize;
    // Surrogate hyper-parameter policy.  Absent means the default (fixed)
    // — but, like `metric`, a *present* unparseable value is a client
    // error, not a silent fallback.
    let mut gp_mode = match body.get("gp_hypers") {
        None => HyperMode::Fixed,
        Some(j) => j
            .as_str()
            .and_then(HyperMode::parse)
            .ok_or_else(|| bad("unknown 'gp_hypers' (fixed | adapt)"))?,
    };
    // ARD frees the per-dimension length-scales, which only exists under
    // adaptation: bare `gp_ard` implies adapt, while an explicit "fixed"
    // alongside it is a contradiction (400), not an override.
    let gp_ard = match body.get("gp_ard") {
        None => false,
        Some(j) => j.as_bool().ok_or_else(|| bad("'gp_ard' must be a boolean"))?,
    };
    if gp_ard {
        if matches!(gp_mode, HyperMode::Fixed) && body.get("gp_hypers").is_some() {
            return Err(bad(
                "'gp_ard' requires \"gp_hypers\": \"adapt\" (fixed length-scales cannot adapt per dimension)",
            ));
        }
        if matches!(gp_mode, HyperMode::Fixed) {
            gp_mode = HyperMode::adapt();
        }
    }
    if let Some(every) = body.get("gp_adapt_every") {
        let every = every
            .as_f64()
            .filter(|&v| v >= 1.0 && v.fract() == 0.0)
            .ok_or_else(|| bad("'gp_adapt_every' must be a positive integer"))?;
        // The cadence never *implies* adaptation: absent or "fixed"
        // gp_hypers with a cadence is a contradiction, not an opt-in —
        // the fixed default stays bit-reproducible unless asked (via
        // "adapt" or gp_ard).
        if matches!(gp_mode, HyperMode::Fixed) {
            return Err(bad("'gp_adapt_every' requires \"gp_hypers\": \"adapt\""));
        }
        gp_mode = HyperMode::Adapt { every: every as usize };
    }
    // Warm-start hypers from a previous job's record: shape errors are
    // 400s here; the dimension count is checked against the tuning
    // subspace below, once it is known.
    let gp_init: Option<(Vec<f64>, Option<f64>)> = match body.get("gp_init_hypers") {
        None => None,
        Some(j) => {
            let arr = j
                .get("lengthscales")
                .and_then(Json::as_arr)
                .ok_or_else(|| bad("'gp_init_hypers' needs a 'lengthscales' array"))?;
            let ls = arr
                .iter()
                .map(|v| v.as_f64().filter(|x| x.is_finite() && *x > 0.0))
                .collect::<Option<Vec<f64>>>()
                .ok_or_else(|| bad("'gp_init_hypers' length-scales must be positive numbers"))?;
            if ls.is_empty() {
                return Err(bad("'gp_init_hypers' length-scales must be non-empty"));
            }
            let s2n = match j.get("sigma_n2") {
                None => None,
                Some(v) => Some(
                    v.as_f64()
                        .filter(|x| x.is_finite() && *x > 0.0)
                        .ok_or_else(|| bad("'gp_init_hypers' sigma_n2 must be positive"))?,
                ),
            };
            Some((ls, s2n))
        }
    };

    // Fault injection + degradation knobs — validated synchronously like
    // every other parameter.
    let faults = parse_faults(&body, PipelineConfig::default().seed)?;
    let fail_budget = match body.get("fail_budget") {
        None => None,
        Some(j) => Some(
            j.as_f64()
                .filter(|v| *v >= 0.0 && v.fract() == 0.0)
                .ok_or_else(|| bad("'fail_budget' must be a non-negative integer"))?
                as usize,
        ),
    };
    // Batched q-EI proposal width.  Validated synchronously: a zero or
    // oversized q would otherwise 202-accept and then kill the job at its
    // first iteration.
    let batch_q = match body.get("batch_q") {
        None => 1usize,
        Some(j) => {
            let q = j
                .as_f64()
                .filter(|v| *v >= 1.0 && v.fract() == 0.0)
                .ok_or_else(|| bad("'batch_q' must be a positive integer"))?
                as usize;
            let n_candidates = PipelineConfig::default().bo.n_candidates;
            if q > n_candidates {
                return Err(bad(format!(
                    "'batch_q' ({q}) cannot exceed the candidate pool size ({n_candidates})"
                )));
            }
            q
        }
    };
    // Surrogate linear-algebra tier.  Validated synchronously like the
    // other surrogate knobs: an unknown tier is a 400 now, not a dead job.
    let gp_kernels = match body.get("gp_kernels") {
        None => KernelPolicy::Scalar,
        Some(j) => j
            .as_str()
            .and_then(KernelPolicy::parse)
            .ok_or_else(|| bad("unknown 'gp_kernels' (scalar | blocked)"))?,
    };

    // Dataset checks stay synchronous so bad requests fail with 400 now,
    // not with a failed job later; the dataset is snapshotted into the job.
    let dataset_id = body.get("dataset_id").and_then(Json::as_f64).map(|v| v as u64);
    let ch = match dataset_id {
        Some(id) => {
            let store = state.datasets.lock().unwrap();
            let stored = store.get(&id).ok_or_else(|| bad(format!("no dataset {id}")))?;
            if stored.dataset.mode != gc {
                return Err(bad(format!(
                    "dataset {id} is for {}",
                    stored.dataset.mode.name()
                )));
            }
            datagen::CharacterizeResult {
                strategy: Strategy::Bemcm,
                dataset: stored.dataset.clone(),
                rmse_history: stored.rmse_history.clone(),
                runs_executed: 0,
                rounds: 0,
                sim_time_s: 0.0,
                failures: Default::default(),
            }
        }
        None => {
            if matches!(algo, Algo::Rbo | Algo::BoWarm) {
                return Err(bad("algo needs a dataset_id from /api/characterize"));
            }
            datagen::CharacterizeResult {
                strategy: Strategy::Bemcm,
                dataset: Dataset {
                    mode: gc,
                    metric,
                    unit_rows: vec![],
                    feat_rows: vec![],
                    y: vec![],
                },
                rmse_history: vec![],
                runs_executed: 0,
                rounds: 0,
                sim_time_s: 0.0,
                failures: Default::default(),
            }
        }
    };

    // Selected subspace: from the dataset when available, else the full
    // group.  Computed synchronously (a single fast lasso fit, the same
    // cost `/api/select` already pays per request) so warm-start hypers
    // with the wrong dimension count fail with a 400 now instead of a
    // failed job minutes later.
    let space = if ch.dataset.is_empty() {
        TuneSpace::full(gc)
    } else {
        let sel = featsel::select_flags(&ch.dataset, featsel::DEFAULT_LAMBDA, &state.backend)
            .map_err(|e| (500, format!("{e:#}")))?;
        // An empty selection (near-constant targets zero every lasso
        // weight) would assert inside TuneSpace::from_selection — in this
        // handler thread that would drop the connection with no response,
        // so answer like every other validation failure instead.
        if sel.selected.is_empty() {
            return Err(bad(format!(
                "feature selection kept no flags for dataset {}; characterize with more \
                 signal or tune without a dataset_id",
                dataset_id.unwrap_or(0)
            )));
        }
        TuneSpace::from_selection(gc, &sel)
    };
    if let Some((ls, _)) = &gp_init {
        if ls.len() != space.dim() {
            return Err(bad(format!(
                "'gp_init_hypers' has {} length-scales but the tuning space has {} dimensions",
                ls.len(),
                space.dim()
            )));
        }
        // One-shot backends (XLA) evaluate the isotropic AOT artifact on
        // every acquire: unequal per-dimension scales would 202-accept
        // here and then kill the job at its first acquisition — fail at
        // submission instead, like the dimension check above.
        if !state.backend.supports_hyper_adaptation()
            && crate::native::ops::iso_lengthscale(ls).is_none()
        {
            return Err(bad(
                "'gp_init_hypers' with unequal length-scales requires a backend with an \
                 ARD-capable surrogate (this backend serves an isotropic one-shot session)",
            ));
        }
    }
    // Tuned-dimension flag names, for the ARD relevance report.
    let enc = crate::flags::FeatureEncoder::new(gc);
    let dim_names: Vec<String> =
        space.selected.iter().map(|&p| enc.flag_name(p).to_string()).collect();

    let job_state = Arc::clone(state);
    let submitted = state.jobs.try_submit_ctl("tune", move |ctl| {
        let mut runner = SparkRunner::paper_default(bench);
        if let Some(plan) = faults {
            runner = runner.with_faults(plan);
        }
        if let Some(budget) = fail_budget {
            ctl.set_fail_budget(budget);
        }
        let mut pc = PipelineConfig { tune_iters: iters, ..Default::default() };
        pc.bo.batch_q = batch_q;
        pc.bo.hypers.mode = gp_mode;
        pc.bo.hypers.ard = gp_ard;
        pc.bo.hypers.kernels = gp_kernels;
        let default_noise = pc.bo.hypers.sigma_n2;
        pc.bo.hypers.init = gp_init.map(|(ls, s2n)| (ls, s2n.unwrap_or(default_noise)));

        let default_summary =
            pipeline::measure(&runner, &FlagConfig::default_for(gc), metric, 5, pc.seed);
        let out = pipeline::run_algo_ctl(
            exec::global(),
            algo,
            &runner,
            &space,
            &ch,
            metric,
            &pc,
            &job_state.backend,
            default_summary.mean,
            ctl,
        )
        .map_err(|e| format!("{e:#}"))?;

        let flags_obj: Vec<(String, Json)> = out
            .tune
            .best_config
            .to_map()
            .into_iter()
            .map(|(k, v)| (k, Json::num(v)))
            .collect();
        // Report the *effective* surrogate policy, not the request: SA
        // has no GP surrogate at all, and one-shot backends (XLA) ignore
        // Adapt — echoing "adapt" there would claim adaptation ran when
        // the surrogate stayed fixed (or never existed).
        let effective_hypers = match algo {
            Algo::Sa => None,
            _ if matches!(gp_mode, HyperMode::Adapt { .. })
                && !job_state.backend.supports_hyper_adaptation() =>
            {
                Some("fixed")
            }
            _ => Some(gp_mode.name()),
        };
        let mut fields = vec![("algo", Json::str(out.algo.name()))];
        if let Some(h) = effective_hypers {
            fields.push(("gp_hypers", Json::str(h)));
            // Effective ARD, like the effective policy: true only when
            // the surrogate actually adapted per dimension — the tuner
            // withholds relevance when the backend/mode could not adapt,
            // or when the run was too short for the scales to move.
            fields.push(("gp_ard", Json::Bool(out.tune.ard_relevance.is_some())));
            // The kernel tier, echoed whenever a GP surrogate ran at
            // all: the knob changes arithmetic (within the 1e-8 pin),
            // so the record must say which tier produced the result.
            fields.push(("gp_kernels", Json::str(gp_kernels.name())));
        }
        // Final surrogate hypers: the warm-start payload a follow-up job
        // feeds back via "gp_init_hypers".
        if let Some((ls, s2n)) = &out.tune.gp_hypers {
            fields.push(("gp_lengthscales", Json::arr_f64(ls)));
            fields.push(("gp_sigma_n2", Json::num(*s2n)));
        }
        // ARD relevance per tuned flag, next to the lasso selection the
        // space came from — the cross-check the pipeline closes the
        // feature-selection loop with.
        if let Some(rel) = &out.tune.ard_relevance {
            fields.push((
                "ard_relevance",
                Json::Obj(
                    dim_names
                        .iter()
                        .cloned()
                        .zip(rel.iter().map(|&v| Json::num(v)))
                        .collect(),
                ),
            ));
        }
        fields.extend(vec![
            ("default_mean", Json::num(default_summary.mean)),
            ("tuned_mean", Json::num(out.tuned_summary.mean)),
            ("tuned_std", Json::num(out.tuned_summary.std)),
            ("improvement", Json::num(out.improvement)),
            ("tuning_time_s", Json::num(out.tuning_time_s)),
            ("evals", Json::num(out.tune.evals as f64)),
            // Always present, even when all-zero: the failure histogram is
            // part of the tune-result schema, not an optional extra.
            ("failures", jobs::failures_json(&out.tune.failures)),
            ("best_flags", Json::Obj(flags_obj.into_iter().collect())),
            ("best_java_args", Json::str(out.tune.best_config.to_java_args())),
        ]);
        Ok(Json::obj(fields))
    });
    match submitted {
        Ok(id) => Ok(accepted(id)),
        Err(full) => Err(queue_full(full)),
    }
}

fn datasets(state: &Arc<ApiState>) -> Json {
    let store = state.datasets.lock().unwrap();
    Json::Arr(
        store
            .iter()
            .map(|(id, d)| {
                Json::obj(vec![
                    ("dataset_id", Json::num(*id as f64)),
                    ("bench", Json::str(d.bench.name())),
                    ("gc", Json::str(d.dataset.mode.name())),
                    ("metric", Json::str(d.dataset.metric.name())),
                    ("samples", Json::num(d.dataset.len() as f64)),
                ])
            })
            .collect(),
    )
}
