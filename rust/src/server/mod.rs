//! REST API backend — the server side of the paper's ReactJS UI (Fig 2):
//! the optimization algorithms "are exposed through a REST API".
//!
//! `http` is a minimal std-net HTTP/1.1 server (the offline image has no
//! tokio/hyper); `api` implements the endpoints over the shared pipeline;
//! `jobs` is the lifecycle-aware async queue behind the 202-Accepted
//! endpoints (`/api/characterize`, `/api/tune` -> poll `/api/jobs/:id`,
//! cancel with `DELETE /api/jobs/:id`); `persist` carries stored datasets
//! and terminal job records across server restarts via a JSON state file.
//!
//! # Failure semantics
//!
//! Measurement failures are first-class through the whole stack.  A tune
//! request may carry a `faults` plan (seeded, deterministic fault
//! injection — [`crate::sparksim::FaultPlan`]) and a `fail_budget`; a job
//! whose budget is exhausted stops cooperatively and lands in the
//! `degraded` terminal state, still carrying its best-so-far result plus
//! a per-kind failure histogram (`failures`: crash / oom / wall_cap /
//! hang / total).  Degraded records persist and restore like any other
//! terminal state.  Admission is bounded: when the queue already holds
//! its capacity of non-terminal jobs, submissions are refused with
//! `429 Too Many Requests` and a `Retry-After` header instead of
//! queueing unboundedly.

pub mod api;
pub mod http;
pub mod jobs;
pub mod persist;

use std::sync::Arc;

pub use api::{ApiOptions, ApiState};
pub use http::{http_request, Request, Response};
pub use jobs::{CancelOutcome, JobQueue, JobStatus};

/// Build the request handler for an API state.
pub fn make_handler(state: Arc<ApiState>) -> Arc<http::Handler> {
    Arc::new(move |req: &Request| api::handle(&state, req))
}

/// Serve the API forever on `addr` (e.g. "127.0.0.1:7878").
pub fn serve_forever(
    addr: &str,
    backend: Arc<dyn crate::runtime::MlBackend>,
) -> std::io::Result<()> {
    serve_forever_with(addr, backend, ApiOptions::default())
}

/// `serve_forever` with explicit [`ApiOptions`] (job TTL, state dir).
pub fn serve_forever_with(
    addr: &str,
    backend: Arc<dyn crate::runtime::MlBackend>,
    opts: ApiOptions,
) -> std::io::Result<()> {
    let state = ApiState::with_options(backend, opts);
    http::serve(addr, make_handler(state), |bound| {
        println!("onestoptuner REST API listening on http://{bound}");
    })
}

/// Spawn the API on a background thread (tests, embedding).
pub fn spawn(
    addr: &str,
    backend: Arc<dyn crate::runtime::MlBackend>,
) -> std::io::Result<std::net::SocketAddr> {
    spawn_with(addr, backend, ApiOptions::default())
}

/// `spawn` with explicit [`ApiOptions`].
pub fn spawn_with(
    addr: &str,
    backend: Arc<dyn crate::runtime::MlBackend>,
    opts: ApiOptions,
) -> std::io::Result<std::net::SocketAddr> {
    let state = ApiState::with_options(backend, opts);
    http::spawn(addr, make_handler(state))
}
