//! Minimal HTTP/1.1 server over std::net (no tokio/hyper in the offline
//! image): thread-per-connection, enough for the REST API of Fig 2.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// A parsed request.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: Vec<(String, String)>,
    pub body: String,
}

impl Request {
    pub fn query_param(&self, key: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

/// A response to send.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
}

impl Response {
    pub fn json(status: u16, body: impl Into<String>) -> Response {
        Response { status, content_type: "application/json", body: body.into() }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response { status, content_type: "text/plain; charset=utf-8", body: body.into() }
    }

    fn status_line(&self) -> &'static str {
        match self.status {
            200 => "200 OK",
            201 => "201 Created",
            400 => "400 Bad Request",
            404 => "404 Not Found",
            405 => "405 Method Not Allowed",
            _ => "500 Internal Server Error",
        }
    }
}

pub type Handler = dyn Fn(&Request) -> Response + Send + Sync;

/// Serve forever on `addr`, dispatching every request to `handler`.
/// Returns the bound local address via the callback before blocking.
pub fn serve(
    addr: &str,
    handler: Arc<Handler>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    on_bound(listener.local_addr()?);
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let handler = handler.clone();
        std::thread::spawn(move || {
            let _ = handle_connection(stream, &handler);
        });
    }
    Ok(())
}

/// Spawn the server on a background thread, returning the bound address.
pub fn spawn(addr: &str, handler: Arc<Handler>) -> std::io::Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let bound = listener.local_addr()?;
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let handler = handler.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(stream, &handler);
            });
        }
    });
    Ok(bound)
}

fn handle_connection(mut stream: TcpStream, handler: &Arc<Handler>) -> std::io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(10)))?;
    let mut reader = BufReader::new(stream.try_clone()?);

    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("/").to_string();

    // Headers (we only need Content-Length).
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            break;
        }
        let line = line.trim();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_length.min(16 * 1024 * 1024)];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }

    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), parse_query(q)),
        None => (target, Vec::new()),
    };

    let req = Request {
        method,
        path,
        query,
        body: String::from_utf8_lossy(&body).into_owned(),
    };
    let resp = handler(&req);

    let out = format!(
        "HTTP/1.1 {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        resp.status_line(),
        resp.content_type,
        resp.body.len(),
        resp.body
    );
    stream.write_all(out.as_bytes())?;
    stream.flush()
}

fn parse_query(q: &str) -> Vec<(String, String)> {
    q.split('&')
        .filter(|s| !s.is_empty())
        .map(|pair| match pair.split_once('=') {
            Some((k, v)) => (url_decode(k), url_decode(v)),
            None => (url_decode(pair), String::new()),
        })
        .collect()
}

fn url_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' if i + 2 < bytes.len() + 1 && i + 2 < bytes.len() => {
                let hex = std::str::from_utf8(&bytes[i + 1..i + 3]).unwrap_or("");
                if let Ok(v) = u8::from_str_radix(hex, 16) {
                    out.push(v);
                    i += 3;
                } else {
                    out.push(bytes[i]);
                    i += 1;
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Tiny blocking HTTP client for tests and the CLI's `ping` convenience.
pub fn http_request(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut buf = String::new();
    BufReader::new(stream).read_to_string(&mut buf)?;
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> std::net::SocketAddr {
        let handler: Arc<Handler> = Arc::new(|req: &Request| {
            Response::json(
                200,
                format!(
                    "{{\"method\":\"{}\",\"path\":\"{}\",\"q\":\"{}\",\"len\":{}}}",
                    req.method,
                    req.path,
                    req.query_param("x").unwrap_or(""),
                    req.body.len()
                ),
            )
        });
        spawn("127.0.0.1:0", handler).unwrap()
    }

    #[test]
    fn get_roundtrip() {
        let addr = echo_server();
        let (status, body) = http_request(addr, "GET", "/hello?x=42", "").unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("\"path\":\"/hello\""), "{body}");
        assert!(body.contains("\"q\":\"42\""));
    }

    #[test]
    fn post_body_passed() {
        let addr = echo_server();
        let (status, body) =
            http_request(addr, "POST", "/submit", "{\"a\": 1}").unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("\"len\":8"), "{body}");
    }

    #[test]
    fn url_decode_basics() {
        assert_eq!(url_decode("a%20b+c"), "a b c");
        assert_eq!(url_decode("plain"), "plain");
        assert_eq!(url_decode("%zz"), "%zz");
    }

    #[test]
    fn concurrent_requests() {
        let addr = echo_server();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    http_request(addr, "GET", &format!("/r{i}"), "").unwrap().0
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 200);
        }
    }
}
