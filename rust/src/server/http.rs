//! Minimal HTTP/1.1 server over std::net (no tokio/hyper in the offline
//! image): thread-per-connection, enough for the REST API of Fig 2.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// A parsed request.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: Vec<(String, String)>,
    pub body: String,
}

impl Request {
    pub fn query_param(&self, key: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

/// A response to send.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
    /// Emitted as a `Retry-After: <secs>` header (429 backpressure).
    pub retry_after: Option<u64>,
}

impl Response {
    pub fn json(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.into(),
            retry_after: None,
        }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into(),
            retry_after: None,
        }
    }

    /// Attach a `Retry-After` hint (seconds).
    pub fn with_retry_after(mut self, secs: u64) -> Response {
        self.retry_after = Some(secs);
        self
    }

    fn status_line(&self) -> &'static str {
        match self.status {
            200 => "200 OK",
            201 => "201 Created",
            202 => "202 Accepted",
            400 => "400 Bad Request",
            404 => "404 Not Found",
            405 => "405 Method Not Allowed",
            409 => "409 Conflict",
            413 => "413 Payload Too Large",
            429 => "429 Too Many Requests",
            _ => "500 Internal Server Error",
        }
    }
}

/// Largest request body accepted (16 MiB); anything larger is refused with
/// 413 before a single body byte is read.
pub const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;

pub type Handler = dyn Fn(&Request) -> Response + Send + Sync;

/// Serve forever on `addr`, dispatching every request to `handler`.
/// Returns the bound local address via the callback before blocking.
pub fn serve(
    addr: &str,
    handler: Arc<Handler>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    on_bound(listener.local_addr()?);
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let handler = handler.clone();
        // detlint: allow(thread-outside-exec) -- I/O-bound connection handling; numeric work still runs on exec::ExecPool
        let _ = std::thread::spawn(move || {
            let _ = handle_connection(stream, &handler);
        });
    }
    Ok(())
}

/// Spawn the server on a background thread, returning the bound address.
pub fn spawn(addr: &str, handler: Arc<Handler>) -> std::io::Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let bound = listener.local_addr()?;
    // detlint: allow(thread-outside-exec) -- accept loop must outlive the caller; pure I/O, no numeric work
    let _ = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let handler = handler.clone();
            // detlint: allow(thread-outside-exec) -- I/O-bound connection handling; numeric work still runs on exec::ExecPool
            let _ = std::thread::spawn(move || {
                let _ = handle_connection(stream, &handler);
            });
        }
    });
    Ok(bound)
}

fn handle_connection(mut stream: TcpStream, handler: &Arc<Handler>) -> std::io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(10)))?;
    let mut reader = BufReader::new(stream.try_clone()?);

    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("/").to_string();

    // Headers (we only need Content-Length).
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            break;
        }
        let line = line.trim();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:") {
            match v.trim().parse::<usize>() {
                Ok(n) => content_length = n,
                Err(_) => {
                    // A garbled length used to be silently treated as 0,
                    // desynchronizing the connection from the body.
                    return refuse(
                        &mut stream,
                        &mut reader,
                        Response::json(400, r#"{"error":"invalid Content-Length header"}"#),
                    );
                }
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        // Refuse before reading the body: truncating the buffer and
        // read_exact-ing the wrong length (the old behavior) corrupted
        // the request.
        return refuse(
            &mut stream,
            &mut reader,
            Response::json(413, r#"{"error":"request body exceeds 16 MiB limit"}"#),
        );
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 && reader.read_exact(&mut body).is_err() {
        // Short body (client closed early) or read timeout on an
        // overstated Content-Length: tell the client instead of hanging
        // up silently.
        return write_response(
            &mut stream,
            &Response::json(400, r#"{"error":"request body shorter than Content-Length"}"#),
        );
    }

    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), parse_query(q)),
        None => (target, Vec::new()),
    };

    let req = Request {
        method,
        path,
        query,
        body: String::from_utf8_lossy(&body).into_owned(),
    };
    let resp = handler(&req);
    write_response(&mut stream, &resp)
}

/// Answer an early protocol error: send `resp`, then drain (a bounded
/// amount of) whatever body the client is still sending before closing.
/// Closing with unread data queued can turn into a TCP RST that destroys
/// the response before the client sees it.
fn refuse(
    stream: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    resp: Response,
) -> std::io::Result<()> {
    write_response(stream, &resp)?;
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_secs(2)));
    let mut sink = [0u8; 8192];
    let mut drained = 0usize;
    // Drain up to the largest body a well-formed client could still be
    // mid-send on (the 16 MiB cap plus slack): a write-then-read client
    // that posted at or near the limit must get its error response, not a
    // reset.  Beyond that the sender is abusive and a reset is fine.
    while drained <= MAX_BODY_BYTES {
        match reader.read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(n) => drained += n,
        }
    }
    Ok(())
}

fn write_response(stream: &mut TcpStream, resp: &Response) -> std::io::Result<()> {
    let retry = match resp.retry_after {
        Some(secs) => format!("Retry-After: {secs}\r\n"),
        None => String::new(),
    };
    let out = format!(
        "HTTP/1.1 {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n{}Connection: close\r\n\r\n{}",
        resp.status_line(),
        resp.content_type,
        resp.body.len(),
        retry,
        resp.body
    );
    stream.write_all(out.as_bytes())?;
    stream.flush()
}

fn parse_query(q: &str) -> Vec<(String, String)> {
    q.split('&')
        .filter(|s| !s.is_empty())
        .map(|pair| match pair.split_once('=') {
            Some((k, v)) => (url_decode(k), url_decode(v)),
            None => (url_decode(pair), String::new()),
        })
        .collect()
}

fn url_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            // A full escape needs two hex digits after the '%'.
            b'%' if i + 2 < bytes.len() => {
                let hex = std::str::from_utf8(&bytes[i + 1..i + 3]).unwrap_or("");
                if let Ok(v) = u8::from_str_radix(hex, 16) {
                    out.push(v);
                    i += 3;
                } else {
                    out.push(bytes[i]);
                    i += 1;
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Tiny blocking HTTP client for tests and the CLI's `ping` convenience.
pub fn http_request(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut buf = String::new();
    BufReader::new(stream).read_to_string(&mut buf)?;
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> std::net::SocketAddr {
        let handler: Arc<Handler> = Arc::new(|req: &Request| {
            Response::json(
                200,
                format!(
                    "{{\"method\":\"{}\",\"path\":\"{}\",\"q\":\"{}\",\"len\":{}}}",
                    req.method,
                    req.path,
                    req.query_param("x").unwrap_or(""),
                    req.body.len()
                ),
            )
        });
        spawn("127.0.0.1:0", handler).unwrap()
    }

    #[test]
    fn get_roundtrip() {
        let addr = echo_server();
        let (status, body) = http_request(addr, "GET", "/hello?x=42", "").unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("\"path\":\"/hello\""), "{body}");
        assert!(body.contains("\"q\":\"42\""));
    }

    #[test]
    fn post_body_passed() {
        let addr = echo_server();
        let (status, body) =
            http_request(addr, "POST", "/submit", "{\"a\": 1}").unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("\"len\":8"), "{body}");
    }

    #[test]
    fn url_decode_basics() {
        assert_eq!(url_decode("a%20b+c"), "a b c");
        assert_eq!(url_decode("plain"), "plain");
        assert_eq!(url_decode("%zz"), "%zz");
    }

    #[test]
    fn url_decode_truncated_trailing_escape() {
        // An escape cut off by the end of the string stays literal
        // instead of reading out of bounds or eating the digit.
        assert_eq!(url_decode("a%4"), "a%4");
        assert_eq!(url_decode("a%"), "a%");
        assert_eq!(url_decode("%"), "%");
        // ...while a complete trailing escape still decodes.
        assert_eq!(url_decode("a%41"), "aA");
    }

    /// Send raw bytes and read the full response (for malformed requests
    /// `http_request` cannot express).
    fn raw_roundtrip(addr: std::net::SocketAddr, bytes: &[u8], close_write: bool) -> (u16, String) {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(bytes).unwrap();
        if close_write {
            stream.shutdown(std::net::Shutdown::Write).unwrap();
        }
        let mut buf = String::new();
        BufReader::new(stream).read_to_string(&mut buf).unwrap();
        let status = buf.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
        let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
        (status, body)
    }

    #[test]
    fn oversized_content_length_rejected_with_413() {
        let addr = echo_server();
        let req = format!(
            "POST /submit HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        let (status, body) = raw_roundtrip(addr, req.as_bytes(), false);
        assert_eq!(status, 413, "{body}");
        assert!(body.contains("16 MiB"), "{body}");
    }

    #[test]
    fn body_at_exact_limit_boundary_is_not_rejected_as_oversized() {
        // A Content-Length of exactly MAX_BODY_BYTES passes the size gate
        // (the old code truncated anything >= the cap and then mis-read).
        let addr = echo_server();
        let req = format!(
            "POST /submit HTTP/1.1\r\nHost: x\r\nContent-Length: {MAX_BODY_BYTES}\r\n\r\n"
        );
        // We close without sending the body, so the server reports the
        // short body — but crucially as 400, not 413 and not a mis-read.
        let (status, _) = raw_roundtrip(addr, req.as_bytes(), true);
        assert_eq!(status, 400);
    }

    #[test]
    fn short_body_rejected_with_400() {
        let addr = echo_server();
        let req = b"POST /submit HTTP/1.1\r\nHost: x\r\nContent-Length: 10\r\n\r\nabc";
        let (status, body) = raw_roundtrip(addr, req, true);
        assert_eq!(status, 400, "{body}");
        assert!(body.contains("shorter than Content-Length"), "{body}");
    }

    #[test]
    fn invalid_content_length_rejected_with_400() {
        let addr = echo_server();
        let req = b"POST /submit HTTP/1.1\r\nHost: x\r\nContent-Length: banana\r\n\r\n";
        let (status, body) = raw_roundtrip(addr, req, true);
        assert_eq!(status, 400, "{body}");
        assert!(body.contains("invalid Content-Length"), "{body}");
    }

    #[test]
    fn retry_after_header_emitted_on_429() {
        let handler: Arc<Handler> = Arc::new(|_req: &Request| {
            Response::json(429, r#"{"error":"queue full"}"#).with_retry_after(5)
        });
        let addr = spawn("127.0.0.1:0", handler).unwrap();
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(b"GET /api/tune HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n")
            .unwrap();
        let mut buf = String::new();
        BufReader::new(stream).read_to_string(&mut buf).unwrap();
        let head = buf.split("\r\n\r\n").next().unwrap();
        assert!(head.starts_with("HTTP/1.1 429 Too Many Requests"), "{head}");
        assert!(head.contains("Retry-After: 5"), "{head}");
        // Normal responses never grow the header.
        let addr2 = echo_server();
        let mut stream = TcpStream::connect(addr2).unwrap();
        stream
            .write_all(b"GET /ok HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n")
            .unwrap();
        let mut buf = String::new();
        BufReader::new(stream).read_to_string(&mut buf).unwrap();
        assert!(!buf.contains("Retry-After"), "{buf}");
    }

    #[test]
    fn concurrent_requests() {
        let addr = echo_server();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    http_request(addr, "GET", &format!("/r{i}"), "").unwrap().0
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 200);
        }
    }
}
