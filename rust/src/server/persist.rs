//! Restart persistence for the REST server: stored datasets and terminal
//! job records are serialized to a JSON state file (via `util::json` — no
//! serde in the offline image) after every completion, and reloaded when
//! an [`super::ApiState`] is built with a state directory.
//!
//! Only restart-safe data crosses the file boundary: `StoredDataset`s
//! (whose `feat_rows` are *recomputed* from the unit rows on load, exactly
//! like `Dataset::from_table`) and terminal job snapshots
//! ([`PersistedJob`]).  Live jobs cannot survive a process death, so they
//! are simply dropped.  A missing or corrupt state file is treated as a
//! fresh start, never an error — a tuning service must come up even if
//! its scratch state was truncated mid-write (the write itself goes
//! through a temp file + rename to make that window as small as possible).

use std::collections::BTreeMap;
use std::path::Path;

use crate::datagen::Dataset;
use crate::flags::{FeatureEncoder, FlagConfig, GcMode};
use crate::server::api::StoredDataset;
use crate::server::jobs::{JobStatus, PersistedJob};
use crate::util::json::Json;
use crate::{Benchmark, Metric};

/// File name inside the state directory.
pub const STATE_FILE: &str = "onestoptuner_state.json";

/// Everything the server persists across restarts.
pub struct PersistedState {
    pub next_dataset_id: u64,
    pub datasets: Vec<(u64, StoredDataset)>,
    pub jobs: Vec<PersistedJob>,
}

fn dataset_json(id: u64, d: &StoredDataset) -> Json {
    Json::obj(vec![
        ("dataset_id", Json::num(id as f64)),
        ("bench", Json::str(d.bench.name())),
        ("gc", Json::str(d.dataset.mode.name())),
        ("metric", Json::str(d.dataset.metric.name())),
        ("rmse_history", Json::arr_f64(&d.rmse_history)),
        (
            "unit_rows",
            Json::Arr(d.dataset.unit_rows.iter().map(|r| Json::arr_f64(r)).collect()),
        ),
        ("y", Json::arr_f64(&d.dataset.y)),
    ])
}

fn job_json(j: &PersistedJob) -> Json {
    let mut pairs = vec![
        ("job_id", Json::num(j.id as f64)),
        ("kind", Json::str(j.kind.clone())),
        ("status", Json::str(j.status.name())),
        ("elapsed_s", Json::num(j.elapsed_s)),
    ];
    if let Some(r) = &j.result {
        pairs.push(("result", r.clone()));
    }
    if let Some(e) = &j.error {
        pairs.push(("error", Json::str(e.clone())));
    }
    Json::obj(pairs)
}

/// Write the state file atomically (temp file + rename) under `dir`,
/// creating the directory if needed.
pub fn save(dir: &Path, state: &PersistedState) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    let json = Json::obj(vec![
        ("version", Json::num(1.0)),
        ("next_dataset_id", Json::num(state.next_dataset_id as f64)),
        (
            "datasets",
            Json::Arr(state.datasets.iter().map(|(id, d)| dataset_json(*id, d)).collect()),
        ),
        ("jobs", Json::Arr(state.jobs.iter().map(job_json).collect())),
    ]);
    let tmp = dir.join(format!("{STATE_FILE}.tmp"));
    std::fs::write(&tmp, json.to_string())?;
    std::fs::rename(&tmp, dir.join(STATE_FILE))
}

fn f64_rows(v: &Json) -> Option<Vec<Vec<f64>>> {
    v.as_arr()?
        .iter()
        .map(|row| row.as_arr().map(|r| r.iter().filter_map(Json::as_f64).collect()))
        .collect()
}

fn f64_vec(v: &Json) -> Option<Vec<f64>> {
    Some(v.as_arr()?.iter().filter_map(Json::as_f64).collect())
}

fn load_dataset(v: &Json) -> Option<(u64, StoredDataset)> {
    let id = v.get("dataset_id")?.as_f64()? as u64;
    let bench = Benchmark::parse(v.get("bench")?.as_str()?)?;
    let mode = GcMode::parse(v.get("gc")?.as_str()?)?;
    let metric = Metric::parse(v.get("metric")?.as_str()?)?;
    let rmse_history = f64_vec(v.get("rmse_history")?)?;
    let unit_rows = f64_rows(v.get("unit_rows")?)?;
    let y = f64_vec(v.get("y")?)?;
    if unit_rows.len() != y.len() {
        return None;
    }
    // feat_rows are a pure function of the unit rows — recompute instead
    // of persisting them (same as Dataset::from_table).
    let enc = FeatureEncoder::new(mode);
    let feat_rows = unit_rows
        .iter()
        .map(|u| enc.encode(&FlagConfig::from_unit(mode, u)))
        .collect();
    Some((
        id,
        StoredDataset {
            bench,
            dataset: Dataset { mode, metric, unit_rows, feat_rows, y },
            rmse_history,
        },
    ))
}

fn load_job(v: &Json) -> Option<PersistedJob> {
    let status = JobStatus::parse(v.get("status")?.as_str()?)?;
    if !status.is_terminal() {
        return None;
    }
    Some(PersistedJob {
        id: v.get("job_id")?.as_f64()? as u64,
        kind: v.get("kind")?.as_str()?.to_string(),
        status,
        result: v.get("result").cloned(),
        error: v.get("error").and_then(Json::as_str).map(str::to_string),
        elapsed_s: v.get("elapsed_s").and_then(Json::as_f64).unwrap_or(0.0),
    })
}

/// Load the state file under `dir`.  Missing, unreadable, or malformed
/// state yields `None` (fresh start); individually malformed entries are
/// skipped rather than poisoning the rest.
pub fn load(dir: &Path) -> Option<PersistedState> {
    let raw = std::fs::read_to_string(dir.join(STATE_FILE)).ok()?;
    let v = Json::parse(&raw).ok()?;
    let datasets: Vec<(u64, StoredDataset)> = v
        .get("datasets")?
        .as_arr()?
        .iter()
        .filter_map(load_dataset)
        .collect();
    let jobs: Vec<PersistedJob> =
        v.get("jobs")?.as_arr()?.iter().filter_map(load_job).collect();
    // The persisted counter wins, but never hand out an id a stored
    // dataset already uses (e.g. a state file written by a newer build).
    let max_ds = datasets.iter().map(|(id, _)| *id).max().unwrap_or(0);
    let next_dataset_id = v
        .get("next_dataset_id")
        .and_then(Json::as_f64)
        .map(|n| n as u64)
        .unwrap_or(1)
        .max(max_ds + 1);
    Some(PersistedState { next_dataset_id, datasets, jobs })
}

/// Snapshot helper for `ApiState::persist`: clone the dataset map into a
/// stable, id-ordered vector (`BTreeMap` iteration is already ascending
/// by id, so the output order is fixed by construction).  `feat_rows`
/// are left empty — [`save`] never serializes them (they are recomputed
/// from the unit rows on load), and they are the bulk of a dataset, so
/// skipping them keeps the time spent under the datasets lock small.
pub fn dataset_snapshot(map: &BTreeMap<u64, StoredDataset>) -> Vec<(u64, StoredDataset)> {
    map.iter()
        .map(|(id, d)| {
            (
                *id,
                StoredDataset {
                    bench: d.bench,
                    dataset: Dataset {
                        mode: d.dataset.mode,
                        metric: d.dataset.metric,
                        unit_rows: d.dataset.unit_rows.clone(),
                        feat_rows: Vec::new(),
                        y: d.dataset.y.clone(),
                    },
                    rmse_history: d.rmse_history.clone(),
                },
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("ost-persist-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_dataset() -> StoredDataset {
        let mode = GcMode::G1GC;
        let enc = FeatureEncoder::new(mode);
        let mut rng = crate::util::rng::Pcg::new(11);
        let mut unit_rows = Vec::new();
        let mut feat_rows = Vec::new();
        let mut y = Vec::new();
        for i in 0..5 {
            let cfg = FlagConfig::random(mode, &mut rng);
            feat_rows.push(enc.encode(&cfg));
            unit_rows.push(cfg.to_unit());
            y.push(100.0 + i as f64);
        }
        StoredDataset {
            bench: Benchmark::Lda,
            dataset: Dataset { mode, metric: Metric::ExecTime, unit_rows, feat_rows, y },
            rmse_history: vec![3.0, 2.0],
        }
    }

    #[test]
    fn save_load_roundtrip_recomputes_features() {
        let dir = tmp_dir("roundtrip");
        let ds = sample_dataset();
        let jobs = vec![PersistedJob {
            id: 4,
            kind: "tune".into(),
            status: JobStatus::Cancelled,
            result: Some(Json::obj(vec![("best", Json::num(1.5))])),
            error: None,
            elapsed_s: 12.25,
        }];
        save(&dir, &PersistedState { next_dataset_id: 3, datasets: vec![(2, ds.clone())], jobs })
            .unwrap();

        let loaded = load(&dir).expect("state loads");
        assert_eq!(loaded.next_dataset_id, 3);
        assert_eq!(loaded.datasets.len(), 1);
        let (id, back) = &loaded.datasets[0];
        assert_eq!(*id, 2);
        assert_eq!(back.dataset.len(), ds.dataset.len());
        assert_eq!(back.dataset.mode, ds.dataset.mode);
        assert_eq!(back.rmse_history, ds.rmse_history);
        for (a, b) in back.dataset.y.iter().zip(&ds.dataset.y) {
            assert!((a - b).abs() < 1e-12);
        }
        // feat_rows were rebuilt from the unit rows, not stored.
        assert_eq!(back.dataset.feat_rows.len(), ds.dataset.feat_rows.len());
        for (a, b) in back.dataset.feat_rows.iter().zip(&ds.dataset.feat_rows) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-9, "feature recompute drifted");
            }
        }
        assert_eq!(loaded.jobs.len(), 1);
        assert_eq!(loaded.jobs[0].status, JobStatus::Cancelled);
        assert_eq!(loaded.jobs[0].elapsed_s, 12.25);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_or_corrupt_state_is_a_fresh_start() {
        let dir = tmp_dir("corrupt");
        assert!(load(&dir).is_none(), "missing dir");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(load(&dir).is_none(), "missing file");
        std::fs::write(dir.join(STATE_FILE), "{truncated").unwrap();
        assert!(load(&dir).is_none(), "corrupt file");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn next_dataset_id_never_collides_with_stored_ids() {
        let dir = tmp_dir("nextid");
        // A counter *behind* the stored ids (as a stale file could have).
        save(
            &dir,
            &PersistedState {
                next_dataset_id: 1,
                datasets: vec![(7, sample_dataset())],
                jobs: vec![],
            },
        )
        .unwrap();
        let loaded = load(&dir).unwrap();
        assert!(loaded.next_dataset_id > 7);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
