//! JVM simulator substrate (paper-testbed substitution; see DESIGN.md).
//!
//! `params` derives physical simulator parameters from a `FlagConfig`;
//! `engine` is the event-driven mutator/GC/JIT execution model with the
//! jstat-style heap-usage sampler.
//!
//! # Failure semantics
//!
//! A run is not an infallible number: [`JvmRunResult::failure`] carries a
//! [`FailureKind`] whenever the simulated JVM dies instead of finishing.
//! Two kinds arise naturally in the engine, and both are *deterministic*
//! for a given (config, seed) — retrying them can never succeed:
//!
//! * [`FailureKind::Oom`] — the live set outgrew the old generation; the
//!   executor dies almost immediately (`OutOfMemoryError` fast-fail), so
//!   the reported wall time is short and the sampled heap percentage is
//!   garbage (pinned near 100% by the death throes).
//! * [`FailureKind::WallCap`] — simulated wall time hit [`MAX_WALL_S`];
//!   the run is truncated the way a benchmark-harness timeout would.
//!
//! The remaining kinds ([`FailureKind::Crash`], [`FailureKind::Hang`])
//! never originate here: they are injected by `sparksim::FaultPlan`,
//! which also classifies each injected fault as deterministic
//! (crash-on-start flag regions) or transient (probabilistic crashes
//! and stragglers, which the measurement layer may retry).  Consumers
//! must treat the metrics of a failed run as penalty values, not
//! measurements — see `sparksim::RunOutcome` for the first-class
//! success/failure split.

pub mod engine;
pub mod params;

pub use engine::{run, FailureKind, GcStats, JvmRunResult, MutatorLoad, MAX_WALL_S};
pub use params::JvmParams;
