//! JVM simulator substrate (paper-testbed substitution; see DESIGN.md).
//!
//! `params` derives physical simulator parameters from a `FlagConfig`;
//! `engine` is the event-driven mutator/GC/JIT execution model with the
//! jstat-style heap-usage sampler.

pub mod engine;
pub mod params;

pub use engine::{run, GcStats, JvmRunResult, MutatorLoad, MAX_WALL_S};
pub use params::JvmParams;
