//! Event-driven JVM execution engine: mutator + generational GC + JIT
//! warmup, at minor-GC granularity.
//!
//! One `run` simulates a single executor JVM executing `MutatorLoad` units
//! of compute while allocating; GC pauses are stop-the-world events that
//! extend wall time, G1's concurrent phases steal mutator cores instead.
//! A jstat-style sampler records heap occupancy every 5 simulated seconds
//! and the run reports the paper's HU metric (eq. 8/9).

use super::params::JvmParams;
use crate::flags::GcMode;
use crate::util::rng::Pcg;

/// Why a run failed — the first-class replacement for the old
/// `timed_out` bool, so every consumer (retry policy, tuner, job
/// records) can tell an out-of-memory death from a wall-cap truncation
/// from an injected crash or hang.
///
/// `Oom` and `WallCap` arise naturally from the simulator and are
/// *deterministic* for a given (config, seed): retrying them is wasted
/// work.  `Crash` and `Hang` only come from the fault-injection layer
/// (`sparksim::FaultPlan`), where the plan classifies each occurrence
/// as deterministic (crash-on-start flag regions) or transient
/// (probabilistic executor crashes/hangs, which a retry may clear).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FailureKind {
    /// Executor/JVM crashed (refused to start or died mid-run).
    Crash,
    /// Live set outgrew the old generation: `OutOfMemoryError`.
    Oom,
    /// Simulated wall time hit [`MAX_WALL_S`] (GC thrash truncation).
    WallCap,
    /// Straggler/hang: the run exceeded the timeout without progressing.
    Hang,
}

impl FailureKind {
    pub fn name(self) -> &'static str {
        match self {
            FailureKind::Crash => "crash",
            FailureKind::Oom => "oom",
            FailureKind::WallCap => "wall_cap",
            FailureKind::Hang => "hang",
        }
    }

    pub fn parse(s: &str) -> Option<FailureKind> {
        match s.to_ascii_lowercase().as_str() {
            "crash" => Some(FailureKind::Crash),
            "oom" => Some(FailureKind::Oom),
            "wall_cap" | "wallcap" | "timeout" => Some(FailureKind::WallCap),
            "hang" => Some(FailureKind::Hang),
            _ => None,
        }
    }

    pub fn all() -> [FailureKind; 4] {
        [FailureKind::Crash, FailureKind::Oom, FailureKind::WallCap, FailureKind::Hang]
    }
}

/// Workload placed on one executor JVM.
#[derive(Clone, Debug)]
pub struct MutatorLoad {
    /// Total compute demand (core-seconds at steady speed 1.0).
    pub work_core_s: f64,
    /// Allocation intensity (MB allocated per core-second of work).
    pub alloc_mb_per_core_s: f64,
    /// Steady-state live set in MB (input cache + model state).
    pub live_mb: f64,
    /// Fraction of the work during which the live set builds up.
    pub cache_work_frac: f64,
    /// Fraction of eden surviving a minor collection.
    pub young_survival: f64,
    /// Fraction of survived bytes promoted regardless of survivor room.
    pub promote_frac: f64,
    /// Humongous allocation (G1: straight to old) MB per core-second.
    pub humongous_mb_per_core_s: f64,
}

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct GcStats {
    pub minor: u32,
    pub mixed: u32,
    pub full: u32,
    pub conc_cycles: u32,
    pub total_pause_ms: f64,
    pub max_pause_ms: f64,
}

#[derive(Clone, Debug)]
pub struct JvmRunResult {
    /// Wall-clock duration of the run (seconds, simulated).
    pub wall_s: f64,
    pub gc: GcStats,
    /// Average heap-usage percentage over the 5 s jstat samples (eq. 9).
    pub hu_avg_pct: f64,
    pub n_samples: usize,
    /// Why the run failed, if it did: [`FailureKind::WallCap`] when the
    /// wall-time cap truncated a thrashing run, [`FailureKind::Oom`]
    /// when the live set outgrew the old generation (the JVM dies fast,
    /// like a real `java.lang.OutOfMemoryError`).  `None` on success.
    pub failure: Option<FailureKind>,
}

impl JvmRunResult {
    /// Did the run fail (for call sites that only care yes/no)?
    pub fn failed(&self) -> bool {
        self.failure.is_some()
    }
}

/// Hard cap on simulated wall time: configurations that thrash are
/// truncated here, mirroring a benchmark timeout.
pub const MAX_WALL_S: f64 = 1800.0;

const SAMPLE_PERIOD_S: f64 = 5.0;
/// Concurrent-mark scan rate, MB per ms per concurrent thread.
const MARK_RATE: f64 = 9.0;

struct State {
    t_s: f64,
    work: f64,
    eden_used: f64,
    surv_used: f64,
    old_live: f64,
    old_garbage: f64,
    eden_cap: f64,
    marking_until: f64, // G1: wall time when concurrent mark finishes
    mixed_left: u32,
    garbage_at_mark: f64,
    next_sample: f64,
    hu_sum: f64,
    n_samples: usize,
    gc: GcStats,
}

pub fn run(p: &JvmParams, load: &MutatorLoad, cores: f64, rng: &mut Pcg) -> JvmRunResult {
    let speed_noise = rng.noise_factor(0.015);
    let copy_total = (p.copy_rate * p.gc_threads).max(0.05); // MB/ms
    let compact_total = (p.compact_rate * p.gc_threads).max(0.03);

    let survivor_total = 2.0 * p.survivor_mb;
    let old_cap = match p.mode {
        GcMode::ParallelGC => (p.heap_mb - p.young_mb - survivor_total).max(256.0),
        GcMode::G1GC => (p.heap_mb - p.young_min_mb).max(256.0),
    };

    let live_target = load.live_mb * p.live_scale;
    let alloc_per_core = load.alloc_mb_per_core_s * p.alloc_scale;

    let mut st = State {
        t_s: 0.0,
        work: 0.0,
        eden_used: 0.0,
        surv_used: 0.0,
        old_live: live_target.min(0.05 * live_target),
        old_garbage: 0.0,
        eden_cap: eden_capacity(p, load, copy_total, 0.0),
        marking_until: f64::NEG_INFINITY,
        mixed_left: 0,
        garbage_at_mark: 0.0,
        next_sample: SAMPLE_PERIOD_S,
        hu_sum: 0.0,
        n_samples: 0,
        gc: GcStats::default(),
    };

    let mut failure = None;
    loop {
        let marking = st.t_s < st.marking_until;
        let s = mutator_speed(p, st.t_s, cores, marking) * speed_noise;
        let alloc_rate = (alloc_per_core * s).max(1e-6); // MB/s
        let humongous_rate = load.humongous_mb_per_core_s * s * p.alloc_scale;

        let dt_eden = (st.eden_cap - st.eden_used).max(0.0) / alloc_rate;
        let dt_work = (load.work_core_s - st.work).max(0.0) / s;
        let dt = dt_work.min(dt_eden);

        advance(&mut st, p, old_cap, dt, s, alloc_rate, humongous_rate);

        if dt_work <= dt_eden {
            break; // job finished
        }
        if st.t_s > MAX_WALL_S {
            failure = Some(FailureKind::WallCap);
            break;
        }

        minor_gc(&mut st, p, load, old_cap, live_target, copy_total, rng);

        // OOM fast-fail: once the live set alone no longer fits in the old
        // generation, no amount of collecting helps — the executor dies
        // with OutOfMemoryError almost immediately (the paper avoids this
        // region by constraining heap-flag ranges; we let the tuner learn
        // it instead).
        if st.old_live > old_cap * 0.99 {
            failure = Some(FailureKind::Oom);
            break;
        }

        // Old-generation pressure handling.
        match p.mode {
            GcMode::ParallelGC => {
                let old_used = st.old_live + st.old_garbage;
                if old_used > old_cap * p.full_trigger_frac {
                    full_gc(&mut st, p, compact_total, old_used, rng, false);
                }
            }
            GcMode::G1GC => {
                g1_cycle(&mut st, p, old_cap, copy_total, compact_total, rng);
            }
        }
        // Re-derive the (G1-adaptive) eden for the next cycle.
        st.eden_cap = eden_capacity(p, load, copy_total, st.old_live + st.old_garbage);
    }

    let hu = if st.n_samples > 0 {
        st.hu_sum / st.n_samples as f64
    } else {
        // Short run: single synthetic sample at the end state.
        hu_now(&st, p, old_cap)
    };

    JvmRunResult {
        wall_s: st.t_s,
        gc: st.gc,
        hu_avg_pct: hu,
        n_samples: st.n_samples,
        failure,
    }
}

/// Mutator speed in core-equivalents: JIT warmup ramp, steady-state factor,
/// G1 concurrent work stealing cores.
fn mutator_speed(p: &JvmParams, t_s: f64, cores: f64, marking: bool) -> f64 {
    let ramp = 1.0 - (1.0 - p.interp_speed) * (-t_s / p.warmup_s).exp();
    let mut s = cores * p.steady_speed * ramp;
    s *= 1.0 - p.conc_overhead;
    if marking {
        let stolen = (p.conc_threads * 0.55).min(cores * 0.5);
        s *= 1.0 - stolen / cores;
    }
    s.max(0.05)
}

/// Eden capacity: fixed geometry for ParallelGC; pause-target-driven
/// adaptive young sizing for G1 (the MaxGCPauseMillis mechanism), further
/// shrunk under old-generation pressure the way real G1 resizes young.
fn eden_capacity(p: &JvmParams, load: &MutatorLoad, copy_total: f64, old_used: f64) -> f64 {
    match p.mode {
        GcMode::ParallelGC => (p.young_mb * p.eden_frac).max(16.0),
        GcMode::G1GC => {
            let survival = load.young_survival.max(0.01);
            let budget_ms = (p.pause_target_ms - p.minor_base_ms).max(2.0);
            let target = budget_ms * copy_total / survival;
            let lo = (p.young_min_mb * p.eden_frac).max(16.0);
            let pressure_cap = ((p.heap_mb - old_used) * 0.75).max(lo);
            let hi = (p.young_mb * p.eden_frac).max(lo).min(pressure_cap);
            target.clamp(lo, hi.max(lo))
        }
    }
}

/// Advance simulated time by `dt` seconds of mutator execution, taking
/// jstat samples at 5 s boundaries.
fn advance(
    st: &mut State,
    p: &JvmParams,
    old_cap: f64,
    dt: f64,
    s: f64,
    alloc_rate: f64,
    humongous_rate: f64,
) {
    let t_end = st.t_s + dt;
    while st.next_sample <= t_end {
        let frac = ((st.next_sample - st.t_s) / dt.max(1e-12)).clamp(0.0, 1.0);
        let eden_at = st.eden_used + alloc_rate * dt * frac;
        let old_at = st.old_live + st.old_garbage + humongous_rate * dt * frac;
        st.hu_sum += hu_of(eden_at, st.surv_used, old_at, st.eden_cap, p, old_cap);
        st.n_samples += 1;
        st.next_sample += SAMPLE_PERIOD_S;
    }
    st.work += s * dt;
    st.eden_used += alloc_rate * dt;
    st.old_garbage += humongous_rate * dt; // humongous: straight to old
    st.t_s = t_end;
}

fn hu_of(eu: f64, su: f64, ou: f64, ec: f64, p: &JvmParams, oc: f64) -> f64 {
    let s0c = p.survivor_mb.max(1.0);
    let caps = ec + 2.0 * s0c + oc;
    100.0 * (eu + su + ou.min(oc)) / caps.max(1.0)
}

fn hu_now(st: &State, p: &JvmParams, old_cap: f64) -> f64 {
    hu_of(
        st.eden_used,
        st.surv_used,
        st.old_live + st.old_garbage,
        st.eden_cap,
        p,
        old_cap,
    )
}

/// One stop-the-world minor collection.
fn minor_gc(
    st: &mut State,
    p: &JvmParams,
    load: &MutatorLoad,
    _old_cap: f64,
    live_target: f64,
    copy_total: f64,
    rng: &mut Pcg,
) {
    let tenuring_factor = 1.0 - 0.015 * (p.tenuring - 15.0).abs() / 15.0;
    let survived =
        st.eden_cap * load.young_survival * tenuring_factor * rng.noise_factor(0.03);

    // Survivor-space fit: overflow promotes directly.
    let surv_room = (p.survivor_mb * p.target_survivor).max(1.0);
    let to_survivor = survived.min(surv_room);
    let overflow = survived - to_survivor;
    let churn_promoted = survived * load.promote_frac + overflow
        + st.surv_used * (1.0 / (1.0 + p.tenuring));

    // Live-set buildup tracks job progress through the caching phase.
    let progress = (st.work / (load.work_core_s * load.cache_work_frac).max(1.0)).min(1.0);
    st.old_live = st.old_live.max(live_target * progress);
    st.old_garbage += churn_promoted;

    let pause_ms = (p.minor_base_ms
        + survived / copy_total
        + p.verify_ms_per_gc)
        * rng.noise_factor(0.04);
    apply_pause(st, pause_ms);
    st.gc.minor += 1;
    st.eden_used = 0.0;
    st.surv_used = to_survivor;
}

/// Stop-the-world full collection (ParallelGC old gen / G1 evac failure).
fn full_gc(
    st: &mut State,
    p: &JvmParams,
    compact_total: f64,
    old_used: f64,
    rng: &mut Pcg,
    degenerate: bool,
) {
    let rate = if degenerate {
        compact_total * 0.4 // G1 fallback full GC is badly parallelized
    } else {
        compact_total
    };
    let mut pause_ms = 55.0 + (st.old_live + 0.25 * old_used) / rate.max(0.02);
    if p.scavenge_before_full {
        pause_ms += st.eden_used * 0.6 / compact_total.max(0.02);
        st.eden_used = 0.0;
    }
    pause_ms = (pause_ms + p.verify_ms_per_gc) * rng.noise_factor(0.05);
    apply_pause(st, pause_ms);
    st.gc.full += 1;
    st.old_garbage = 0.0;
    st.surv_used = 0.0;
}

/// G1 concurrent cycle management: IHOP-triggered marking, then a burst of
/// mixed collections reclaiming old-gen garbage down to the waste floor.
fn g1_cycle(
    st: &mut State,
    p: &JvmParams,
    old_cap: f64,
    copy_total: f64,
    compact_total: f64,
    rng: &mut Pcg,
) {
    let old_used = st.old_live + st.old_garbage;

    // Evacuation failure -> degenerate full GC.
    if old_used > (p.heap_mb - st.eden_cap) * 0.97 || old_used > old_cap {
        full_gc(st, p, compact_total, old_used, rng, true);
        return;
    }

    let marking = st.t_s < st.marking_until;
    let occupancy = (old_used + st.eden_used + st.surv_used) / p.heap_mb;
    if !marking && st.mixed_left == 0 && occupancy > p.ihop {
        // Start a concurrent mark cycle.
        let mark_ms = old_used / (MARK_RATE * p.conc_threads).max(0.5);
        st.marking_until = st.t_s + mark_ms / 1000.0;
        st.gc.conc_cycles += 1;
        st.garbage_at_mark = st.old_garbage;
        st.mixed_left = p.mixed_count_target.max(1.0) as u32;
    }

    // Mixed collections piggyback on minor GCs once marking has finished.
    if st.mixed_left > 0 && st.t_s >= st.marking_until && st.marking_until > 0.0 {
        // Live-threshold: only regions below the threshold get collected;
        // a higher threshold reclaims more but copies more live data.
        let eff = (p.mixed_live_threshold - 0.45).clamp(0.1, 0.55) / 0.55;
        let reclaimable = (st.garbage_at_mark * eff).max(0.0);
        let per_mixed = reclaimable / p.mixed_count_target.max(1.0);
        let floor = p.heap_mb * p.heap_waste_frac;
        let take = per_mixed.min((st.old_garbage - floor).max(0.0));
        if take > 0.0 {
            let extra_ms =
                (take * (0.4 + 0.6 * p.mixed_live_threshold)) / (copy_total * 0.75);
            apply_pause(st, extra_ms * rng.noise_factor(0.05));
            st.old_garbage -= take;
            st.gc.mixed += 1;
        }
        st.mixed_left -= 1;
    }
}

fn apply_pause(st: &mut State, pause_ms: f64) {
    let pause_s = pause_ms / 1000.0;
    // STW: heap frozen; jstat samples during a pause see the pre-GC state.
    while st.next_sample <= st.t_s + pause_s {
        st.next_sample += SAMPLE_PERIOD_S;
        // skip sampling inside the pause window (jstat stalls too)
    }
    st.t_s += pause_s;
    st.gc.total_pause_ms += pause_ms;
    if pause_ms > st.gc.max_pause_ms {
        st.gc.max_pause_ms = pause_ms;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flags::FlagConfig;

    fn load() -> MutatorLoad {
        MutatorLoad {
            work_core_s: 1700.0,
            alloc_mb_per_core_s: 90.0,
            live_mb: 6000.0,
            cache_work_frac: 0.3,
            young_survival: 0.08,
            promote_frac: 0.25,
            humongous_mb_per_core_s: 0.0,
        }
    }

    fn params(mode: GcMode) -> JvmParams {
        JvmParams::derive(&FlagConfig::default_for(mode), 81920.0, 20.0)
    }

    #[test]
    fn run_completes_and_is_deterministic() {
        let p = params(GcMode::ParallelGC);
        let a = run(&p, &load(), 20.0, &mut Pcg::new(1));
        let b = run(&p, &load(), 20.0, &mut Pcg::new(1));
        assert_eq!(a.wall_s, b.wall_s);
        assert_eq!(a.gc, b.gc);
        assert!(a.wall_s > 0.0 && !a.failed());
    }

    #[test]
    fn wall_time_exceeds_ideal_compute_time() {
        let p = params(GcMode::ParallelGC);
        let l = load();
        let r = run(&p, &l, 20.0, &mut Pcg::new(2));
        let ideal = l.work_core_s / 20.0;
        assert!(r.wall_s > ideal, "wall {} <= ideal {}", r.wall_s, ideal);
        // ... but not pathologically so for the default config
        assert!(r.wall_s < ideal * 2.0, "wall {}", r.wall_s);
    }

    #[test]
    fn minor_gcs_happen() {
        let p = params(GcMode::ParallelGC);
        let r = run(&p, &load(), 20.0, &mut Pcg::new(3));
        assert!(r.gc.minor > 3, "minor={}", r.gc.minor);
        assert!(r.gc.total_pause_ms > 0.0);
    }

    #[test]
    fn heavy_live_set_triggers_full_gcs_on_parallel() {
        let p = params(GcMode::ParallelGC);
        let mut l = load();
        l.live_mb = 14000.0; // close to default old capacity
        l.alloc_mb_per_core_s = 130.0;
        l.work_core_s = 2000.0;
        let r = run(&p, &l, 20.0, &mut Pcg::new(4));
        assert!(r.gc.full > 0, "expected full GCs, got {:?}", r.gc);
    }

    #[test]
    fn g1_runs_concurrent_cycles_under_pressure() {
        let p = params(GcMode::G1GC);
        let mut l = load();
        l.live_mb = 14000.0;
        l.alloc_mb_per_core_s = 130.0;
        let r = run(&p, &l, 20.0, &mut Pcg::new(5));
        assert!(r.gc.conc_cycles > 0, "{:?}", r.gc);
        assert!(r.gc.full <= 2, "G1 should avoid full GCs: {:?}", r.gc);
    }

    #[test]
    fn g1_respects_pause_target_structure() {
        // Tight pause target -> smaller eden -> more, shorter pauses.
        let mut cfg = FlagConfig::default_for(GcMode::G1GC);
        cfg.set("MaxGCPauseMillis", 50.0);
        let tight = JvmParams::derive(&cfg, 81920.0, 20.0);
        cfg.set("MaxGCPauseMillis", 1000.0);
        let loose = JvmParams::derive(&cfg, 81920.0, 20.0);
        let rt = run(&tight, &load(), 20.0, &mut Pcg::new(6));
        let rl = run(&loose, &load(), 20.0, &mut Pcg::new(6));
        assert!(rt.gc.minor > rl.gc.minor, "{} vs {}", rt.gc.minor, rl.gc.minor);
        assert!(rt.gc.max_pause_ms < rl.gc.max_pause_ms);
    }

    #[test]
    fn bigger_heap_reduces_full_gc_pressure() {
        let mut l = load();
        l.live_mb = 14000.0;
        l.alloc_mb_per_core_s = 130.0;
        let mut cfg = FlagConfig::default_for(GcMode::ParallelGC);
        let small = run(
            &JvmParams::derive(&cfg, 81920.0, 20.0),
            &l,
            20.0,
            &mut Pcg::new(7),
        );
        cfg.set("MaxHeapSize", 32768.0);
        let big = run(
            &JvmParams::derive(&cfg, 81920.0, 20.0),
            &l,
            20.0,
            &mut Pcg::new(7),
        );
        assert!(big.gc.full < small.gc.full, "{:?} vs {:?}", big.gc, small.gc);
        assert!(big.wall_s < small.wall_s);
    }

    #[test]
    fn hu_metric_sampled_and_bounded() {
        let p = params(GcMode::G1GC);
        let r = run(&p, &load(), 20.0, &mut Pcg::new(8));
        assert!(r.n_samples > 3);
        assert!(r.hu_avg_pct > 0.0 && r.hu_avg_pct < 100.0, "{}", r.hu_avg_pct);
    }

    #[test]
    fn verify_flags_slow_the_run() {
        let mut cfg = FlagConfig::default_for(GcMode::ParallelGC);
        let base = run(
            &JvmParams::derive(&cfg, 81920.0, 20.0),
            &load(),
            20.0,
            &mut Pcg::new(9),
        );
        cfg.set("VerifyBeforeGC", 1.0);
        cfg.set("VerifyAfterGC", 1.0);
        let slow = run(
            &JvmParams::derive(&cfg, 81920.0, 20.0),
            &load(),
            20.0,
            &mut Pcg::new(9),
        );
        assert!(slow.wall_s > base.wall_s * 1.02);
    }

    #[test]
    fn pathological_config_times_out_not_hangs() {
        let mut cfg = FlagConfig::default_for(GcMode::ParallelGC);
        cfg.set("MaxHeapSize", 2048.0); // heap far below live set
        let p = JvmParams::derive(&cfg, 81920.0, 20.0);
        let mut l = load();
        l.live_mb = 14000.0;
        let r = run(&p, &l, 20.0, &mut Pcg::new(10));
        // Either times out or thrashes to completion; must terminate.
        assert!(r.wall_s <= MAX_WALL_S * 1.5);
    }

    #[test]
    fn live_set_beyond_old_cap_fails_as_oom_not_wall_cap() {
        // A heap far below the live set dies with OutOfMemoryError the
        // moment the cache builds — the failure kind must say so rather
        // than lumping it in with wall-cap thrash truncation.
        let mut cfg = FlagConfig::default_for(GcMode::ParallelGC);
        cfg.set("MaxHeapSize", 2048.0);
        let p = JvmParams::derive(&cfg, 81920.0, 20.0);
        let mut l = load();
        l.live_mb = 14000.0;
        let r = run(&p, &l, 20.0, &mut Pcg::new(10));
        assert_eq!(r.failure, Some(FailureKind::Oom), "wall {}", r.wall_s);
        assert!(r.failed());
        // ... and the OOM fast-fail really is fast: no 1800 s of thrash.
        assert!(r.wall_s < MAX_WALL_S / 2.0, "wall {}", r.wall_s);
    }

    #[test]
    fn failure_kind_names_roundtrip() {
        for k in FailureKind::all() {
            assert_eq!(FailureKind::parse(k.name()), Some(k));
        }
        assert_eq!(FailureKind::parse("timeout"), Some(FailureKind::WallCap));
        assert_eq!(FailureKind::parse("nope"), None);
    }

    #[test]
    fn noise_is_small_but_present() {
        let p = params(GcMode::G1GC);
        let walls: Vec<f64> = (0..8)
            .map(|s| run(&p, &load(), 20.0, &mut Pcg::new(100 + s)).wall_s)
            .collect();
        let s = crate::util::stats::summarize(&walls);
        assert!(s.std / s.mean < 0.08, "cv={}", s.std / s.mean);
        assert!(s.std > 0.0);
    }
}
