//! Map a `FlagConfig` onto the simulator's physical parameters.
//!
//! This is where "flags have effects": ~30 primary flags map onto explicit
//! heap/GC/JIT mechanics, a long tail of secondary flags contributes small
//! deterministic multiplicative effects (so feature selection has a real
//! signal-vs-noise problem to solve, like the real JVM), and the
//! diagnostics in `NOOP_FLAGS` do nothing at all.

use crate::flags::{FlagConfig, GcMode, NOOP_FLAGS};

/// Everything the GC/JIT engine needs, derived once per run from the flags.
#[derive(Clone, Debug)]
pub struct JvmParams {
    pub mode: GcMode,
    // --- heap geometry (MB) ---
    pub heap_mb: f64,
    pub young_mb: f64,       // ParallelGC fixed young size; G1 upper bound
    pub young_min_mb: f64,   // G1 adaptive floor
    pub eden_frac: f64,      // eden / young
    pub survivor_mb: f64,    // each survivor space (ParallelGC)
    pub target_survivor: f64,
    pub tenuring: f64,
    // --- GC behaviour ---
    pub gc_threads: f64,
    pub conc_threads: f64,
    pub pause_target_ms: f64,
    pub ihop: f64,                   // G1 concurrent-mark trigger fraction
    pub mixed_count_target: f64,     // G1
    pub mixed_live_threshold: f64,   // G1 (fraction)
    pub heap_waste_frac: f64,        // G1 reclaim floor
    pub full_trigger_frac: f64,      // ParallelGC old-occupancy trigger
    pub minor_base_ms: f64,
    pub copy_rate: f64,              // MB/ms per GC thread (minor)
    pub compact_rate: f64,           // MB/ms per GC thread (full)
    pub verify_ms_per_gc: f64,       // VerifyBeforeGC/VerifyAfterGC cost
    pub scavenge_before_full: bool,
    // --- mutator / JIT ---
    pub steady_speed: f64,   // steady-state mutator speed multiplier
    pub interp_speed: f64,   // relative speed at t=0 (warmup start)
    pub warmup_s: f64,       // JIT warmup time constant
    pub alloc_scale: f64,    // allocation volume multiplier (oops size etc.)
    pub live_scale: f64,     // live-set size multiplier
    pub conc_overhead: f64,  // G1 concurrent refinement CPU fraction
}

/// Smooth unimodal bonus: gaussian bump in log-space around `opt`,
/// normalized so the contribution at `def` is 0 (the default config scores
/// exactly 1.0 in the product).
fn bump(x: f64, def: f64, opt: f64, width: f64, amp: f64) -> f64 {
    let g = |v: f64| {
        let z = ((v.max(1e-9) / opt).ln()) / width;
        (-0.5 * z * z).exp()
    };
    amp * (g(x) - g(def))
}

/// FNV-1a for the deterministic long-tail effect assignment.
fn fnv(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Flags with explicit mechanics below (excluded from the long tail).
const PRIMARY: &[&str] = &[
    "MaxHeapSize",
    "InitialHeapSize",
    "NewRatio",
    "NewSize",
    "MaxNewSize",
    "SurvivorRatio",
    "TargetSurvivorRatio",
    "MaxTenuringThreshold",
    "ParallelGCThreads",
    "ConcGCThreads",
    "MaxGCPauseMillis",
    "UseAdaptiveSizePolicy",
    "MinHeapFreeRatio",
    "MaxHeapFreeRatio",
    "UseCompressedOops",
    "UseTLAB",
    "AlwaysPreTouch",
    "UseLargePages",
    "UseNUMA",
    "VerifyBeforeGC",
    "VerifyAfterGC",
    "ScavengeBeforeFullGC",
    "TieredCompilation",
    "CompileThreshold",
    "Tier4InvocationThreshold",
    "CICompilerCount",
    "MaxInlineSize",
    "FreqInlineSize",
    "InlineSmallCode",
    "LoopUnrollLimit",
    "UseSuperWord",
    "DoEscapeAnalysis",
    "EliminateAllocations",
    "ReservedCodeCacheSize",
    "InitiatingHeapOccupancyPercent",
    "G1NewSizePercent",
    "G1MaxNewSizePercent",
    "G1HeapRegionSize",
    "G1MixedGCCountTarget",
    "G1MixedGCLiveThresholdPercent",
    "G1HeapWastePercent",
    "G1ReservePercent",
    "G1ConcRefinementThreads",
    "UseParallelOldGC",
];

impl JvmParams {
    /// Derive simulator parameters from a flag configuration.
    ///
    /// `exec_mem_mb` is the Spark executor memory limit — the JVM heap is
    /// capped at ~92% of it (container overhead).  `cores` is executor
    /// cores (caps useful GC threads).
    pub fn derive(cfg: &FlagConfig, exec_mem_mb: f64, cores: f64) -> JvmParams {
        let mode = cfg.mode;
        let heap_cap = exec_mem_mb * 0.92;
        let mut heap_mb = cfg.get("MaxHeapSize").min(heap_cap);

        // Compressed oops die above 32 GB: object headers/pointers grow,
        // inflating both allocation volume and the live set.  This makes
        // heap sizing non-monotone — the paper's BO has a real cliff to find.
        let oops_on = cfg.get_bool("UseCompressedOops") && heap_mb <= 32768.0;
        let (alloc_scale, live_scale) = if oops_on { (1.0, 1.0) } else { (1.18, 1.22) };

        heap_mb = heap_mb.max(2048.0);

        // --- young generation geometry ---
        let sr = cfg.get("SurvivorRatio").max(2.0);
        let eden_frac = sr / (sr + 2.0);
        let (young_mb, young_min_mb, survivor_mb);
        match mode {
            GcMode::ParallelGC => {
                let ratio_young = heap_mb / (cfg.get("NewRatio") + 1.0);
                let y = ratio_young
                    .max(cfg.get("NewSize"))
                    .min(cfg.get("MaxNewSize"))
                    .min(heap_mb * 0.8);
                young_mb = y;
                young_min_mb = y;
                survivor_mb = y / (sr + 2.0);
            }
            GcMode::G1GC => {
                let lo = heap_mb * cfg.get("G1NewSizePercent") / 100.0;
                let hi = heap_mb * cfg.get("G1MaxNewSizePercent") / 100.0;
                young_mb = hi.max(lo + 1.0);
                young_min_mb = lo;
                survivor_mb = young_mb / (sr + 2.0);
            }
        }

        // --- GC threads & rates ---
        let gc_threads = cfg.get("ParallelGCThreads").min(cores * 2.0).max(1.0);
        let conc_threads = match mode {
            GcMode::G1GC => cfg
                .get("ConcGCThreads")
                .max(1.0)
                .min(cores),
            GcMode::ParallelGC => cfg.get("ConcGCThreads").max(1.0),
        };
        // Thread scaling is sub-linear (term copying saturates memory BW)
        // and oversubscription beyond physical cores hurts.
        let eff_threads = {
            let t = gc_threads.min(cores);
            let over = (gc_threads - cores).max(0.0);
            t.powf(0.82) * (1.0 - 0.03 * over / cores.max(1.0)).max(0.7)
        };
        let copy_rate = 0.85 * eff_threads / gc_threads.max(1.0); // per-thread MB/ms, folded below
        let compact_rate = 0.38 * eff_threads / gc_threads.max(1.0);

        // PLAB / TLAB efficiency tweaks on the copy path (ParallelGC).
        let mut copy_eff = 1.0;
        if mode == GcMode::ParallelGC {
            copy_eff += bump(cfg.get("YoungPLABSize"), 4096.0, 2048.0, 1.0, 0.04);
            copy_eff += bump(cfg.get("OldPLABSize"), 1024.0, 2048.0, 1.0, 0.03);
            if !cfg.get_bool("UseParallelOldGC") {
                copy_eff -= 0.25; // serial old compaction
            }
        } else {
            copy_eff += bump(cfg.get("G1UpdateBufferSize"), 256.0, 1024.0, 1.2, 0.03);
            copy_eff += bump(cfg.get("G1SATBBufferSize"), 1.0, 8.0, 1.5, 0.02);
        }

        // --- verification flags: catastrophic when enabled (default off) ---
        let mut verify_ms_per_gc = 0.0;
        if cfg.get_bool("VerifyBeforeGC") {
            verify_ms_per_gc += 120.0;
        }
        if cfg.get_bool("VerifyAfterGC") {
            verify_ms_per_gc += 120.0;
        }

        // --- JIT model ---
        let tiered = cfg.get_bool("TieredCompilation");
        let ct = cfg.get("CompileThreshold");
        let t4 = cfg.get("Tier4InvocationThreshold");
        let cic = cfg.get("CICompilerCount").max(1.0);
        let warmup_s = if tiered {
            26.0 * (ct / 10000.0).powf(0.35) * (t4 / 5000.0).powf(0.25)
                / (cic / 4.0).powf(0.4)
        } else {
            52.0 * (ct / 10000.0).powf(0.5) / (cic / 4.0).powf(0.4)
        };
        let interp_speed = if tiered { 0.52 } else { 0.38 };

        // Steady-state compiler speed: smooth bumps around non-default
        // optima (the tuner's compiler headroom), normalized to 1.0 at the
        // defaults.
        let mut steady = 1.0;
        steady += bump(cfg.get("MaxInlineSize"), 35.0, 90.0, 0.6, 0.055);
        steady += bump(cfg.get("FreqInlineSize"), 325.0, 520.0, 0.7, 0.030);
        steady += bump(cfg.get("InlineSmallCode"), 2000.0, 2600.0, 0.8, 0.020);
        steady += bump(cfg.get("LoopUnrollLimit").max(1.0), 60.0, 110.0, 0.7, 0.025);
        steady += bump(cfg.get("ReservedCodeCacheSize"), 240.0, 380.0, 0.8, 0.012);
        if !cfg.get_bool("UseSuperWord") {
            steady -= 0.035;
        }
        if !cfg.get_bool("DoEscapeAnalysis") {
            steady -= 0.030;
        }
        if !cfg.get_bool("EliminateAllocations") {
            steady -= 0.020;
        }
        if !cfg.get_bool("UseTLAB") {
            steady -= 0.12;
        }
        if cfg.get_bool("AlwaysPreTouch") {
            steady += 0.006;
        }
        if cfg.get_bool("UseLargePages") {
            steady += 0.011;
        }
        if cfg.get_bool("UseNUMA") {
            steady += 0.014;
        }

        // --- long tail: every other flag gets a tiny deterministic effect ---
        let mut speed_tail = 1.0;
        let mut pause_tail = 1.0;
        for (f, &v) in cfg.defs().iter().zip(&cfg.values) {
            if PRIMARY.contains(&f.name) || NOOP_FLAGS.contains(&f.name) {
                continue;
            }
            let u = (f.normalize(v) - f.normalize(f.default_value())).abs();
            if u <= 0.0 {
                continue;
            }
            let h = fnv(f.name);
            let amp = ((h >> 8) & 0xffff) as f64 / 65535.0; // [0,1)
            let amp = 0.0035 * amp * amp; // long-tailed toward 0
            let sign = if h & 1 == 0 { 1.0 } else { -1.0 };
            if (h >> 1) & 1 == 0 {
                speed_tail *= 1.0 + sign * amp * u;
            } else {
                pause_tail *= 1.0 + sign * 2.0 * amp * u;
            }
        }
        steady *= speed_tail;

        // --- G1 concurrent refinement overhead ---
        let conc_overhead = if mode == GcMode::G1GC {
            let refine = cfg.get("G1ConcRefinementThreads");
            0.012 + 0.002 * (refine / 15.0 - 1.0).abs()
        } else {
            0.0
        };

        let full_trigger_frac = {
            // ParallelGC runs a full GC when the old gen can no longer absorb
            // a promotion wave; MaxHeapFreeRatio nudges the effective slack.
            let mhfr = cfg.get("MaxHeapFreeRatio");
            (0.92 + (mhfr - 70.0) / 1000.0).clamp(0.85, 0.97)
        };

        JvmParams {
            mode,
            heap_mb,
            young_mb,
            young_min_mb,
            eden_frac,
            survivor_mb,
            target_survivor: cfg.get("TargetSurvivorRatio") / 100.0,
            tenuring: cfg.get("MaxTenuringThreshold"),
            gc_threads,
            conc_threads,
            pause_target_ms: cfg.get("MaxGCPauseMillis"),
            ihop: match mode {
                GcMode::G1GC => cfg.get("InitiatingHeapOccupancyPercent") / 100.0,
                GcMode::ParallelGC => 1.0,
            },
            mixed_count_target: cfg.get("G1MixedGCCountTarget"),
            mixed_live_threshold: cfg.get("G1MixedGCLiveThresholdPercent") / 100.0,
            heap_waste_frac: cfg.get("G1HeapWastePercent") / 100.0,
            full_trigger_frac,
            minor_base_ms: 9.0 * pause_tail,
            copy_rate: (copy_rate * copy_eff).max(0.02),
            compact_rate: (compact_rate * copy_eff).max(0.01),
            verify_ms_per_gc,
            scavenge_before_full: cfg.get_bool("ScavengeBeforeFullGC"),
            steady_speed: steady.max(0.3),
            interp_speed,
            warmup_s: warmup_s.clamp(1.0, 120.0),
            alloc_scale,
            live_scale,
            conc_overhead,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flags::FlagConfig;

    fn defaults(mode: GcMode) -> JvmParams {
        JvmParams::derive(&FlagConfig::default_for(mode), 81920.0, 20.0)
    }

    #[test]
    fn default_steady_speed_is_one() {
        for mode in [GcMode::ParallelGC, GcMode::G1GC] {
            let p = defaults(mode);
            assert!(
                (p.steady_speed - 1.0).abs() < 1e-9,
                "{}: steady={}",
                mode.name(),
                p.steady_speed
            );
        }
    }

    #[test]
    fn default_heap_geometry_parallel() {
        let p = defaults(GcMode::ParallelGC);
        assert!((p.heap_mb - 24576.0).abs() < 1.0);
        // NewRatio=2 -> young = heap/3, but capped by MaxNewSize=8192
        assert!((p.young_mb - 8192.0).abs() < 1.0, "young={}", p.young_mb);
        assert!(p.eden_frac > 0.7 && p.eden_frac < 0.9);
    }

    #[test]
    fn default_g1_young_range() {
        let p = defaults(GcMode::G1GC);
        assert!((p.young_min_mb - 24576.0 * 0.05).abs() < 1.0);
        assert!((p.young_mb - 24576.0 * 0.60).abs() < 1.0);
        assert!((p.ihop - 0.45).abs() < 1e-9);
    }

    #[test]
    fn heap_capped_by_executor_memory() {
        let mut cfg = FlagConfig::default_for(GcMode::G1GC);
        cfg.set("MaxHeapSize", 65536.0);
        let p = JvmParams::derive(&cfg, 40960.0, 20.0);
        assert!(p.heap_mb <= 40960.0 * 0.92 + 1.0);
    }

    #[test]
    fn compressed_oops_cliff_above_32g() {
        let mut cfg = FlagConfig::default_for(GcMode::G1GC);
        cfg.set("MaxHeapSize", 32768.0);
        let below = JvmParams::derive(&cfg, 81920.0, 20.0);
        cfg.set("MaxHeapSize", 36864.0);
        let above = JvmParams::derive(&cfg, 81920.0, 20.0);
        assert_eq!(below.alloc_scale, 1.0);
        assert!(above.alloc_scale > 1.1);
        assert!(above.live_scale > 1.1);
    }

    #[test]
    fn verify_flags_cost_pause_time() {
        let mut cfg = FlagConfig::default_for(GcMode::ParallelGC);
        assert_eq!(defaults(GcMode::ParallelGC).verify_ms_per_gc, 0.0);
        cfg.set("VerifyBeforeGC", 1.0);
        cfg.set("VerifyAfterGC", 1.0);
        let p = JvmParams::derive(&cfg, 81920.0, 20.0);
        assert!(p.verify_ms_per_gc >= 200.0);
    }

    #[test]
    fn tiered_off_slows_warmup() {
        let mut cfg = FlagConfig::default_for(GcMode::G1GC);
        let on = JvmParams::derive(&cfg, 81920.0, 20.0);
        cfg.set("TieredCompilation", 0.0);
        let off = JvmParams::derive(&cfg, 81920.0, 20.0);
        assert!(off.warmup_s > on.warmup_s);
        assert!(off.interp_speed < on.interp_speed);
    }

    #[test]
    fn lower_compile_threshold_warms_up_faster() {
        let mut cfg = FlagConfig::default_for(GcMode::G1GC);
        let base = JvmParams::derive(&cfg, 81920.0, 20.0).warmup_s;
        cfg.set("CompileThreshold", 1000.0);
        cfg.set("Tier4InvocationThreshold", 1500.0);
        let fast = JvmParams::derive(&cfg, 81920.0, 20.0).warmup_s;
        assert!(fast < base * 0.7, "{fast} vs {base}");
    }

    #[test]
    fn inline_tuning_beats_default_steady_speed() {
        let mut cfg = FlagConfig::default_for(GcMode::ParallelGC);
        cfg.set("MaxInlineSize", 90.0);
        cfg.set("FreqInlineSize", 520.0);
        cfg.set("LoopUnrollLimit", 110.0);
        let p = JvmParams::derive(&cfg, 81920.0, 20.0);
        assert!(p.steady_speed > 1.03, "steady={}", p.steady_speed);
    }

    #[test]
    fn disabling_tlab_is_expensive() {
        let mut cfg = FlagConfig::default_for(GcMode::G1GC);
        cfg.set("UseTLAB", 0.0);
        let p = JvmParams::derive(&cfg, 81920.0, 20.0);
        assert!(p.steady_speed < 0.92);
    }

    #[test]
    fn noop_flags_have_no_effect() {
        let mut cfg = FlagConfig::default_for(GcMode::G1GC);
        let base = JvmParams::derive(&cfg, 81920.0, 20.0);
        cfg.set("PrintGCDetails", 1.0);
        cfg.set("PerfDataMemorySize", 128.0);
        cfg.set("GCPauseIntervalMillis", 3000.0);
        let p = JvmParams::derive(&cfg, 81920.0, 20.0);
        assert_eq!(base.steady_speed, p.steady_speed);
        assert_eq!(base.minor_base_ms, p.minor_base_ms);
    }

    #[test]
    fn long_tail_flags_have_tiny_effect() {
        let mut cfg = FlagConfig::default_for(GcMode::G1GC);
        let base = JvmParams::derive(&cfg, 81920.0, 20.0);
        cfg.set("SymbolTableSize", 1000003.0);
        let p = JvmParams::derive(&cfg, 81920.0, 20.0);
        let rel = (p.steady_speed / base.steady_speed - 1.0).abs();
        assert!(rel < 0.005, "tail effect too large: {rel}");
    }

    #[test]
    fn gc_threads_capped_and_effective() {
        let mut cfg = FlagConfig::default_for(GcMode::ParallelGC);
        cfg.set("ParallelGCThreads", 40.0);
        let p = JvmParams::derive(&cfg, 81920.0, 10.0);
        assert!(p.gc_threads <= 20.0); // 2x cores cap
    }
}
