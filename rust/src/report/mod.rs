//! Result rendering: aligned text tables (the paper's Tables II-IV), ASCII
//! bar/line plots (Figs 3-7) and CSV/JSON persistence under `results/`.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// Aligned monospace table.
pub struct TextTable {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        TextTable {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.title);
        let sep: String = w.iter().map(|&x| "-".repeat(x + 2)).collect::<Vec<_>>().join("+");
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .zip(w)
                .map(|(c, &width)| format!(" {c:<width$} "))
                .collect::<Vec<_>>()
                .join("|")
        };
        let _ = writeln!(out, "{sep}");
        let _ = writeln!(out, "{}", line(&self.header, &w));
        let _ = writeln!(out, "{sep}");
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &w));
        }
        let _ = writeln!(out, "{sep}");
        out
    }
}

/// ASCII horizontal bar chart (for Fig 3/6/7-style default-vs-tuned plots).
pub fn bar_chart(title: &str, labels: &[String], values: &[f64], unit: &str) -> String {
    assert_eq!(labels.len(), values.len());
    let max = values.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(1e-9);
    let lw = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = format!("{title}\n");
    for (l, &v) in labels.iter().zip(values) {
        let n = ((v / max) * 48.0).round().max(0.0) as usize;
        let _ = writeln!(out, "  {l:<lw$} | {:<48} {v:.2} {unit}", "#".repeat(n));
    }
    out
}

/// ASCII line plot for convergence curves (Fig 5-style), one series per
/// label; x is the sample index.
pub fn line_plot(title: &str, series: &[(String, Vec<f64>)], height: usize) -> String {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    let width = series.iter().map(|(_, v)| v.len()).max().unwrap_or(0);
    for (_, v) in series {
        for &x in v {
            lo = lo.min(x);
            hi = hi.max(x);
        }
    }
    if !lo.is_finite() || width == 0 {
        return format!("{title}\n(no data)\n");
    }
    let span = (hi - lo).max(1e-12);
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['*', 'o', '+', 'x', '@'];
    for (si, (_, v)) in series.iter().enumerate() {
        for (i, &x) in v.iter().enumerate() {
            let r = ((hi - x) / span * (height - 1) as f64).round() as usize;
            grid[r.min(height - 1)][i] = marks[si % marks.len()];
        }
    }
    let mut out = format!("{title}   (y: {lo:.3} .. {hi:.3})\n");
    for row in grid {
        let _ = writeln!(out, "  |{}", row.into_iter().collect::<String>());
    }
    let _ = writeln!(out, "  +{}", "-".repeat(width));
    for (si, (name, _)) in series.iter().enumerate() {
        let _ = writeln!(out, "   {} = {}", marks[si % marks.len()], name);
    }
    out
}

/// Write text to `results/<name>` (creating directories), echoing to stdout.
pub fn save_result(dir: impl AsRef<Path>, name: &str, text: &str) -> io::Result<()> {
    let dir = dir.as_ref();
    fs::create_dir_all(dir)?;
    fs::write(dir.join(name), text)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = TextTable::new("T", &["a", "bench"]);
        t.row(vec!["1".into(), "LDA".into()]);
        t.row(vec!["22".into(), "DenseKMeans".into()]);
        let s = t.render();
        assert!(s.contains("bench"));
        assert!(s.contains("DenseKMeans"));
        let lines: Vec<&str> = s.lines().collect();
        // all data lines same width
        assert_eq!(lines[2].len(), lines[4].len());
    }

    #[test]
    #[should_panic]
    fn table_arity_enforced() {
        let mut t = TextTable::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart(
            "speed",
            &["default".into(), "tuned".into()],
            &[100.0, 50.0],
            "s",
        );
        assert!(s.contains("default"));
        let default_hashes = s.lines().nth(1).unwrap().matches('#').count();
        let tuned_hashes = s.lines().nth(2).unwrap().matches('#').count();
        assert!(default_hashes > tuned_hashes);
    }

    #[test]
    fn line_plot_handles_series() {
        let s = line_plot(
            "rmse",
            &[
                ("bemcm".into(), vec![3.0, 2.0, 1.0]),
                ("random".into(), vec![3.0, 2.8, 2.5]),
            ],
            8,
        );
        assert!(s.contains("bemcm"));
        assert!(s.contains('*'));
        assert!(s.contains('o'));
    }

    #[test]
    fn empty_line_plot_safe() {
        let s = line_plot("x", &[], 5);
        assert!(s.contains("no data"));
    }
}
