//! Deterministic PCG64-style RNG (no `rand` crate in the offline image).
//!
//! PCG-XSH-RR 64/32 with two independently-seedable streams, plus the
//! distribution helpers the pipeline needs (uniform, normal, lognormal,
//! bootstrap indices, shuffles).  Every stochastic component of the system
//! (simulator noise, seed sampling, bootstrap, SA proposals) draws from this
//! so experiments are exactly reproducible from a u64 seed.

const MUL: u64 = 6364136223846793005;

/// SplitMix64 finalizer: a full-avalanche mix of one u64.  Used wherever a
/// derived seed must not share a stream with its base (per-index batch
/// seeds, per-algorithm objective seeds) — unlike `seed ^ tag` or
/// `seed + tag`, every output bit depends on every input bit, so
/// `splitmix64(s) != s`-style collisions are vanishingly unlikely.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

impl Pcg {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.state.wrapping_mul(MUL).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(MUL).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child generator (for per-run / per-component
    /// streams that must not correlate with the parent).
    pub fn fork(&mut self, tag: u64) -> Pcg {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Pcg::with_stream(seed, tag.wrapping_add(0x5851_f42d_4c95_7f2d))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Log-uniform in [lo, hi), lo > 0.
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(lo > 0.0 && hi > lo);
        (self.uniform(lo.ln(), hi.ln())).exp()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free enough for our n << 2^32 uses.
        ((self.next_u64() >> 11) % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Multiplicative lognormal noise factor with the given sigma, mean 1.
    pub fn noise_factor(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma - 0.5 * sigma * sigma).exp()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u32() & 1 == 1
    }

    /// n bootstrap indices over [0, n).
    pub fn bootstrap_indices(&mut self, n: usize) -> Vec<usize> {
        (0..n).map(|_| self.below(n)).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices sampled without replacement from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Pcg::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn noise_factor_mean_close_to_one() {
        let mut r = Pcg::new(13);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.noise_factor(0.05)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg::new(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn log_uniform_within_bounds() {
        let mut r = Pcg::new(19);
        for _ in 0..1000 {
            let x = r.log_uniform(1.0, 1024.0);
            assert!((1.0..1024.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::new(23);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg::new(29);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 30);
    }

    #[test]
    fn splitmix_is_bijective_looking_and_nonfixed() {
        // distinct inputs -> distinct outputs, and no trivial fixed points
        let mut seen = std::collections::HashSet::new();
        for x in 0..1000u64 {
            let y = splitmix64(x);
            assert_ne!(y, x);
            assert!(seen.insert(y));
        }
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Pcg::new(5);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }
}
