//! Small statistics toolkit used across the pipeline: summary stats for
//! repeated tuning runs (paper reports mean ± std over 10 repeats), RMSE for
//! the AL convergence criterion, and standardization for lasso.

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize on empty slice");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    summarize(xs).mean
}

pub fn rmse(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    assert!(!pred.is_empty());
    let s: f64 = pred
        .iter()
        .zip(actual)
        .map(|(p, a)| (p - a) * (p - a))
        .sum();
    (s / pred.len() as f64).sqrt()
}

/// Pearson correlation; 0 when either side is constant.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx <= 0.0 || syy <= 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

/// Column-wise standardization parameters for a row-major matrix.
#[derive(Clone, Debug)]
pub struct Standardizer {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

impl Standardizer {
    pub fn fit(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty());
        let d = rows[0].len();
        let n = rows.len() as f64;
        let mut mean = vec![0.0; d];
        for r in rows {
            for (m, v) in mean.iter_mut().zip(r) {
                *m += v;
            }
        }
        for m in &mut mean {
            *m /= n;
        }
        let mut std = vec![0.0; d];
        for r in rows {
            for j in 0..d {
                let dv = r[j] - mean[j];
                std[j] += dv * dv;
            }
        }
        for s in &mut std {
            *s = (*s / n).sqrt();
            if *s < 1e-12 {
                *s = 1.0; // constant column -> leave centered at 0
            }
        }
        Standardizer { mean, std }
    }

    pub fn transform_row(&self, row: &[f64]) -> Vec<f64> {
        row.iter()
            .zip(self.mean.iter().zip(&self.std))
            .map(|(v, (m, s))| (v - m) / s)
            .collect()
    }

    pub fn transform(&self, rows: &[Vec<f64>]) -> Vec<Vec<f64>> {
        rows.iter().map(|r| self.transform_row(r)).collect()
    }
}

/// Scalar target standardization (zero mean, unit variance) with inverse.
#[derive(Clone, Copy, Debug)]
pub struct TargetScaler {
    pub mean: f64,
    pub std: f64,
}

impl TargetScaler {
    pub fn fit(ys: &[f64]) -> Self {
        let s = summarize(ys);
        TargetScaler { mean: s.mean, std: if s.std < 1e-12 { 1.0 } else { s.std } }
    }

    pub fn transform(&self, y: f64) -> f64 {
        (y - self.mean) / self.std
    }

    pub fn inverse(&self, z: f64) -> f64 {
        z * self.std + self.mean
    }
}

/// Arg-min / arg-max helpers over f64 slices (NaN-hostile: NaN never
/// wins).  NaN entries are skipped outright — the old "compare against
/// `xs[best]`" form let a NaN at index 0 win every time, because every
/// comparison against NaN is false and `best` never moved.  The result is
/// the first non-NaN optimum; an all-NaN (or empty) slice returns 0.
pub fn argmin(xs: &[f64]) -> usize {
    let mut best: Option<usize> = None;
    for (i, x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        match best {
            Some(b) if xs[b] <= *x => {}
            _ => best = Some(i),
        }
    }
    best.unwrap_or(0)
}

pub fn argmax(xs: &[f64]) -> usize {
    let mut best: Option<usize> = None;
    for (i, x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        match best {
            Some(b) if xs[b] >= *x => {}
            _ => best = Some(i),
        }
    }
    best.unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_single_value_zero_std() {
        let s = summarize(&[7.0]);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn rmse_zero_for_exact() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn rmse_known_value() {
        let r = rmse(&[0.0, 0.0], &[3.0, 4.0]);
        assert!((r - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let x = [1.0, 2.0, 3.0];
        assert!((pearson(&x, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((pearson(&x, &[-1.0, -2.0, -3.0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn standardizer_zero_mean_unit_var() {
        let rows = vec![
            vec![1.0, 10.0],
            vec![2.0, 20.0],
            vec![3.0, 30.0],
            vec![4.0, 40.0],
        ];
        let st = Standardizer::fit(&rows);
        let t = st.transform(&rows);
        for j in 0..2 {
            let col: Vec<f64> = t.iter().map(|r| r[j]).collect();
            let m = col.iter().sum::<f64>() / col.len() as f64;
            let v = col.iter().map(|x| x * x).sum::<f64>() / col.len() as f64;
            assert!(m.abs() < 1e-12);
            assert!((v - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn standardizer_constant_column_safe() {
        let rows = vec![vec![5.0], vec![5.0], vec![5.0]];
        let st = Standardizer::fit(&rows);
        let t = st.transform(&rows);
        assert!(t.iter().all(|r| r[0] == 0.0));
    }

    #[test]
    fn target_scaler_roundtrip() {
        let ys = [10.0, 20.0, 30.0];
        let sc = TargetScaler::fit(&ys);
        for y in ys {
            assert!((sc.inverse(sc.transform(y)) - y).abs() < 1e-12);
        }
    }

    #[test]
    fn argminmax() {
        let xs = [3.0, 1.0, 2.0, 5.0];
        assert_eq!(argmin(&xs), 1);
        assert_eq!(argmax(&xs), 3);
    }

    #[test]
    fn argminmax_skip_nan_in_first_position() {
        // The old form let a leading NaN win unconditionally.
        assert_eq!(argmin(&[f64::NAN, 1.0, 2.0]), 1);
        assert_eq!(argmax(&[f64::NAN, 1.0, 2.0]), 2);
    }

    #[test]
    fn argminmax_skip_nan_in_middle_and_last_position() {
        assert_eq!(argmin(&[3.0, f64::NAN, 2.0]), 2);
        assert_eq!(argmax(&[3.0, f64::NAN, 2.0]), 0);
        assert_eq!(argmin(&[3.0, 2.0, f64::NAN]), 1);
        assert_eq!(argmax(&[3.0, 2.0, f64::NAN]), 0);
    }

    #[test]
    fn argminmax_degenerate_inputs() {
        // All-NaN falls back to index 0 rather than panicking.
        assert_eq!(argmin(&[f64::NAN, f64::NAN]), 0);
        assert_eq!(argmax(&[f64::NAN, f64::NAN]), 0);
        // Ties keep the first occurrence (the old strict-compare behavior).
        assert_eq!(argmin(&[1.0, 1.0, 2.0]), 0);
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 0);
    }
}
