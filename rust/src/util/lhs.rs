//! Latin Hypercube Sampling — the sampler behind the Simulated Annealing
//! baseline (paper §IV-E: "We used Latin Hypercube sampling (LHS) of SA
//! ... empirically proven to be useful in cutting down processing time").
//!
//! `lhs(n, d)` returns n points in [0,1)^d such that each dimension's n
//! strata each contain exactly one point.

use super::rng::Pcg;

pub fn lhs(rng: &mut Pcg, n: usize, dim: usize) -> Vec<Vec<f64>> {
    assert!(n > 0 && dim > 0);
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(dim);
    for _ in 0..dim {
        let mut strata: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut strata);
        cols.push(
            strata
                .into_iter()
                .map(|s| (s as f64 + rng.f64()) / n as f64)
                .collect(),
        );
    }
    (0..n)
        .map(|i| (0..dim).map(|d| cols[d][i]).collect())
        .collect()
}

/// Centered LHS (midpoints of strata) — deterministic layout given the
/// permutations; useful for tests and ablations.
pub fn lhs_centered(rng: &mut Pcg, n: usize, dim: usize) -> Vec<Vec<f64>> {
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(dim);
    for _ in 0..dim {
        let mut strata: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut strata);
        cols.push(strata.into_iter().map(|s| (s as f64 + 0.5) / n as f64).collect());
    }
    (0..n)
        .map(|i| (0..dim).map(|d| cols[d][i]).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stratum_counts(points: &[Vec<f64>], d: usize, n: usize) -> Vec<usize> {
        let mut counts = vec![0usize; n];
        for p in points {
            let s = ((p[d] * n as f64) as usize).min(n - 1);
            counts[s] += 1;
        }
        counts
    }

    #[test]
    fn one_point_per_stratum() {
        let mut rng = Pcg::new(1);
        let n = 32;
        let pts = lhs(&mut rng, n, 10);
        assert_eq!(pts.len(), n);
        for d in 0..10 {
            let counts = stratum_counts(&pts, d, n);
            assert!(counts.iter().all(|&c| c == 1), "dim {d}: {counts:?}");
        }
    }

    #[test]
    fn centered_variant_one_point_per_stratum() {
        let mut rng = Pcg::new(2);
        let n = 16;
        let pts = lhs_centered(&mut rng, n, 5);
        for d in 0..5 {
            let counts = stratum_counts(&pts, d, n);
            assert!(counts.iter().all(|&c| c == 1));
        }
    }

    #[test]
    fn unit_cube_bounds() {
        let mut rng = Pcg::new(3);
        for p in lhs(&mut rng, 64, 141) {
            assert!(p.iter().all(|&x| (0.0..1.0).contains(&x)));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = lhs(&mut Pcg::new(9), 20, 6);
        let b = lhs(&mut Pcg::new(9), 20, 6);
        assert_eq!(a, b);
    }

    #[test]
    fn better_1d_coverage_than_iid() {
        // LHS guarantees max-gap <= 2/n; iid uniform typically violates it.
        let mut rng = Pcg::new(4);
        let n = 64;
        let pts = lhs(&mut rng, n, 1);
        let mut xs: Vec<f64> = pts.into_iter().map(|p| p[0]).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let max_gap = xs.windows(2).map(|w| w[1] - w[0]).fold(0.0, f64::max);
        assert!(max_gap <= 2.0 / n as f64 + 1e-12, "gap {max_gap}");
    }
}
