//! Shared substrates: deterministic RNG, quasi-random sequences, sampling
//! designs, statistics, and the JSON/CSV codecs the offline image lacks
//! crates for.

pub mod csv;
pub mod json;
pub mod lhs;
pub mod rng;
pub mod sobol;
pub mod source;
pub mod stats;

pub use json::Json;
pub use rng::Pcg;
pub use sobol::Sobol;
pub use stats::{summarize, Summary};
