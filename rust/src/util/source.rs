//! Shared Rust-source masking for the line-based analysis tools
//! (`mutate/scanner.rs` mutation-site discovery and `lint/` — the
//! `detlint` determinism pass).
//!
//! Neither tool parses Rust.  Both scan rustfmt'd source line by line
//! and pattern-match on *masked* text: string-literal contents, char
//! literals, and comments are replaced by spaces so that no pattern can
//! fire inside them, while every byte keeps its position — offsets into
//! the masked line are offsets into the pristine line.
//!
//! [`Masker`] carries state *across* lines, so multi-line string
//! literals, raw strings (`r"…"`, `r#"…"#`, any hash depth, `b`
//! prefixes) and nested block comments (`/* … /* … */ … */`) stay
//! masked from their opening line to their closing line.  Delimiters
//! themselves (`"`, `r#"`, `/*`) stay visible; only their interior is
//! blanked.  Non-ASCII bytes are masked too, so masked output is pure
//! ASCII and byte positions equal char positions.

/// Cross-line lexical state of [`Masker`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum State {
    Code,
    /// Inside a normal `"…"` string (escapes active).
    Str,
    /// Inside a raw string closed by `"` + this many `#`s.
    RawStr { hashes: usize },
    /// Inside a block comment at this nesting depth (Rust nests them).
    BlockComment { depth: usize },
}

/// Streaming source masker: feed lines top to bottom via
/// [`Masker::mask_line`]; string/comment state carries across calls.
pub struct Masker {
    state: State,
}

impl Default for Masker {
    fn default() -> Self {
        Self::new()
    }
}

impl Masker {
    pub fn new() -> Masker {
        Masker { state: State::Code }
    }

    /// True while the masker is inside a multi-line string or comment —
    /// i.e. the *next* line will not start in code state.
    pub fn in_suspension(&self) -> bool {
        self.state != State::Code
    }

    /// Mask one line (without its trailing newline).  The output has
    /// exactly the input's byte length: code bytes are copied, string
    /// contents / char-literal contents / comments / non-ASCII bytes
    /// become spaces, and string delimiters stay visible.
    pub fn mask_line(&mut self, line: &str) -> String {
        let b = line.as_bytes();
        let mut out = vec![b' '; b.len()];
        let mut i = 0;
        while i < b.len() {
            match self.state {
                State::Str => {
                    if b[i] == b'\\' {
                        i += 2; // escaped byte (or escape at EOL: string continues)
                        continue;
                    }
                    if b[i] == b'"' {
                        out[i] = b'"';
                        self.state = State::Code;
                    }
                    i += 1;
                }
                State::RawStr { hashes } => {
                    if b[i] == b'"' && b[i + 1..].len() >= hashes
                        && b[i + 1..i + 1 + hashes].iter().all(|&c| c == b'#')
                    {
                        out[i] = b'"';
                        for k in 0..hashes {
                            out[i + 1 + k] = b'#';
                        }
                        i += 1 + hashes;
                        self.state = State::Code;
                    } else {
                        i += 1;
                    }
                }
                State::BlockComment { depth } => {
                    if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        i += 2;
                        if depth == 1 {
                            self.state = State::Code;
                        } else {
                            self.state = State::BlockComment { depth: depth - 1 };
                        }
                    } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        i += 2;
                        self.state = State::BlockComment { depth: depth + 1 };
                    } else {
                        i += 1;
                    }
                }
                State::Code => {
                    let c = b[i];
                    if c == b'"' {
                        out[i] = b'"';
                        self.state = State::Str;
                        i += 1;
                    } else if let Some(hashes) = raw_string_start(b, i) {
                        // keep `r##"` visible, mask the interior
                        for (k, &rb) in b[i..=i + 1 + hashes].iter().enumerate() {
                            out[i + k] = rb;
                        }
                        i += 2 + hashes;
                        self.state = State::RawStr { hashes };
                    } else if c == b'\'' {
                        match char_literal_end(b, i) {
                            Some(end) => {
                                // mask the interior, keep both quotes
                                out[i] = b'\'';
                                out[end] = b'\'';
                                i = end + 1;
                            }
                            None => {
                                // a lifetime (`'a`) — plain code
                                out[i] = b'\'';
                                i += 1;
                            }
                        }
                    } else if c == b'/' && b.get(i + 1) == Some(&b'/') {
                        break; // line comment: rest stays masked
                    } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
                        i += 2;
                        self.state = State::BlockComment { depth: 1 };
                    } else {
                        if c.is_ascii() {
                            out[i] = c;
                        }
                        i += 1;
                    }
                }
            }
        }
        String::from_utf8(out).expect("mask output is pure ASCII")
    }
}

/// If `b[i]` opens a raw string (`r"`, `r#"`, `br"`, …), the hash count.
fn raw_string_start(b: &[u8], i: usize) -> Option<usize> {
    if b[i] != b'r' {
        return None;
    }
    // `r` must not be the tail of an identifier (`var"` is not raw);
    // a single preceding `b` (byte raw string) is allowed.
    if i > 0 && is_ident_byte(b[i - 1]) && !(b[i - 1] == b'b' && (i < 2 || !is_ident_byte(b[i - 2])))
    {
        return None;
    }
    let mut j = i + 1;
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    (j < b.len() && b[j] == b'"').then_some(j - i - 1)
}

/// If `b[i]` (a `'`) opens a char literal, the index of its closing
/// quote; `None` means it is a lifetime.
fn char_literal_end(b: &[u8], i: usize) -> Option<usize> {
    match b.get(i + 1) {
        // escaped char: `'\n'`, `'\u{…}'` — closing quote is the next `'`
        Some(b'\\') => b[i + 2..].iter().position(|&c| c == b'\'').map(|p| i + 2 + p),
        // plain one-byte char `'x'` needs the very next byte to close it —
        // anything longer (`'static`) is a lifetime
        Some(_) if b.get(i + 2) == Some(&b'\'') => Some(i + 2),
        _ => None,
    }
}

pub fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Mask a whole source: one masked line per input line (newlines
/// stripped), with string/comment state carried across lines.
pub fn mask_source(src: &str) -> Vec<String> {
    let mut m = Masker::new();
    src.lines().map(|l| m.mask_line(l)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_strings_and_line_comments_preserving_offsets() {
        let line = r#"    foo("a + b", x + y); // c + d"#;
        let m = Masker::new().mask_line(line);
        assert_eq!(m.len(), line.len());
        assert!(!m.contains("a + b"));
        assert!(!m.contains("c + d"));
        let i = m.find(" + ").unwrap();
        assert_eq!(&line[i - 1..i + 5], "x + y)");
    }

    #[test]
    fn raw_strings_masked_with_exact_offsets() {
        let line = r##"    let p = r#"a + "quoted" + b"#; let q = y + z;"##;
        let m = Masker::new().mask_line(line);
        assert_eq!(m.len(), line.len());
        assert!(!m.contains("a + "), "raw interior leaked: {m}");
        assert!(!m.contains("quoted"));
        let i = m.find(" + ").unwrap();
        assert_eq!(&line[i - 1..i + 5], "y + z;");
    }

    #[test]
    fn multiline_raw_string_state_carries() {
        let mut mk = Masker::new();
        let l1 = mk.mask_line(r##"let s = r#"first + line"##);
        assert!(mk.in_suspension());
        let l2 = mk.mask_line(r##"still + masked"#; let t = a + b;"##);
        assert!(!mk.in_suspension());
        assert!(!l1.contains("first"));
        assert!(!l2.contains("still"));
        let i = l2.find(" + ").unwrap();
        assert_eq!(&r##"still + masked"#; let t = a + b;"##[i..i + 3], " + ");
    }

    #[test]
    fn char_literals_do_not_toggle_string_state() {
        // the `'"'` char literal must not open a string
        let line = r#"    if c == '"' { x + y } else { s.push('\n') }"#;
        let m = Masker::new().mask_line(line);
        assert_eq!(m.len(), line.len());
        assert!(m.contains(" + "), "code after char literal stayed visible: {m}");
        assert!(!m.contains("\\n"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let line = "fn f<'a>(x: &'a str) -> &'a str { x }";
        let m = Masker::new().mask_line(line);
        assert_eq!(m, line); // pure code, nothing masked
    }

    #[test]
    fn nested_block_comments_masked_across_lines() {
        let mut mk = Masker::new();
        let l1 = mk.mask_line("let a = 1; /* outer /* inner + */ still");
        assert!(mk.in_suspension());
        let l2 = mk.mask_line("masked */ let b = a + 2;");
        assert!(!mk.in_suspension());
        assert!(l1.contains("let a = 1;"));
        assert!(!l1.contains("inner"));
        assert!(!l2.contains("masked"));
        assert!(l2.contains("let b = a + 2;"));
    }

    #[test]
    fn non_ascii_masked_to_keep_byte_positions() {
        let line = "let π = 3.0; let x = a + b;";
        let m = Masker::new().mask_line(line);
        assert_eq!(m.len(), line.len()); // byte length, π is 2 bytes
        let i = m.find(" + ").unwrap();
        assert_eq!(&line.as_bytes()[i..i + 3], b" + ");
    }

    #[test]
    fn mask_source_counts_lines() {
        let src = "fn f() {\n    let s = \"a\n b\";\n}\n";
        let lines = mask_source(src);
        assert_eq!(lines.len(), 4);
        assert!(!lines[2].contains('b'), "second string line masked: {:?}", lines[2]);
    }
}
