//! CSV reader/writer for the phase-1 data files ("the collected data is
//! stored in a csv file", paper §III-A) and for result tables.
//!
//! Numeric-matrix oriented: a header row of column names, then f64 rows.
//! Quoting is supported on read; we never emit values needing quotes.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub struct Table {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl Table {
    pub fn new(columns: Vec<String>) -> Self {
        Table { columns, rows: Vec::new() }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        assert_eq!(row.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(row);
    }

    pub fn col_index(&self, name: &str) -> Option<usize> {
        self.columns.iter().position(|c| c == name)
    }

    pub fn column(&self, name: &str) -> Option<Vec<f64>> {
        let i = self.col_index(name)?;
        Some(self.rows.iter().map(|r| r[i]).collect())
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.columns.join(","));
        out.push('\n');
        for row in &self.rows {
            let mut first = true;
            for v in row {
                if !first {
                    out.push(',');
                }
                first = false;
                let _ = write!(out, "{v}");
            }
            out.push('\n');
        }
        out
    }

    pub fn save(&self, path: impl AsRef<Path>) -> io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_csv())
    }

    pub fn parse(text: &str) -> Result<Table, String> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines.next().ok_or("empty csv")?;
        let columns: Vec<String> =
            split_csv_line(header).into_iter().map(|s| s.trim().to_string()).collect();
        let mut rows = Vec::new();
        for (ln, line) in lines.enumerate() {
            let fields = split_csv_line(line);
            if fields.len() != columns.len() {
                return Err(format!(
                    "line {}: {} fields, expected {}",
                    ln + 2,
                    fields.len(),
                    columns.len()
                ));
            }
            let row: Result<Vec<f64>, _> = fields
                .iter()
                .map(|f| f.trim().parse::<f64>().map_err(|e| format!("line {}: {e}", ln + 2)))
                .collect();
            rows.push(row?);
        }
        Ok(Table { columns, rows })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Table, String> {
        let text = fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e}", path.as_ref().display()))?;
        Table::parse(&text)
    }
}

fn split_csv_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    cur.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    out.push(cur);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut t = Table::new(vec!["a".into(), "b".into()]);
        t.push(vec![1.0, 2.5]);
        t.push(vec![-3.0, 0.125]);
        let parsed = Table::parse(&t.to_csv()).unwrap();
        assert_eq!(parsed, t);
    }

    #[test]
    fn column_access() {
        let mut t = Table::new(vec!["x".into(), "y".into()]);
        t.push(vec![1.0, 10.0]);
        t.push(vec![2.0, 20.0]);
        assert_eq!(t.column("y").unwrap(), vec![10.0, 20.0]);
        assert!(t.column("z").is_none());
    }

    #[test]
    fn quoted_fields() {
        let t = Table::parse("\"a\",\"b\"\n1,2\n").unwrap();
        assert_eq!(t.columns, vec!["a", "b"]);
        assert_eq!(t.rows, vec![vec![1.0, 2.0]]);
    }

    #[test]
    fn arity_mismatch_rejected() {
        assert!(Table::parse("a,b\n1\n").is_err());
    }

    #[test]
    fn bad_number_rejected() {
        assert!(Table::parse("a\nxyz\n").is_err());
    }

    #[test]
    fn skips_blank_lines() {
        let t = Table::parse("a\n\n1\n\n2\n").unwrap();
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    #[should_panic]
    fn push_wrong_arity_panics() {
        let mut t = Table::new(vec!["a".into()]);
        t.push(vec![1.0, 2.0]);
    }

    #[test]
    fn save_and_load(){
        let dir = std::env::temp_dir().join("ost_csv_test");
        let path = dir.join("t.csv");
        let mut t = Table::new(vec!["m".into()]);
        t.push(vec![42.0]);
        t.save(&path).unwrap();
        assert_eq!(Table::load(&path).unwrap(), t);
        let _ = std::fs::remove_dir_all(dir);
    }
}
