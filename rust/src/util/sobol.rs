//! Sobol low-discrepancy sequence for BO initialization (paper §III-D:
//! "We generate initial samples using quasi-random SOBOL sequence for
//! exploration").
//!
//! Direction numbers are built from an embedded table of primitive
//! polynomials over GF(2) (degrees 1..=9, enough for 160 dimensions — the
//! largest tuning space is the 141-flag G1 group) with deterministic valid
//! initial numbers (m_i odd, m_i < 2^i).  Gray-code generation, 32 bits of
//! resolution.

/// Primitive polynomials over GF(2), encoded with the convention of
/// Bratley & Fox: value = interior coefficient bits (a_1..a_{d-1}) of
/// x^d + a_1 x^{d-1} + ... + a_{d-1} x + 1.  Grouped by degree.
const PRIMITIVE_POLYS: &[(u32, u32)] = &[
    // (degree, interior bits)
    (1, 0),
    (2, 1),
    (3, 1), (3, 2),
    (4, 1), (4, 4),
    (5, 2), (5, 4), (5, 7), (5, 11), (5, 13), (5, 14),
    (6, 1), (6, 13), (6, 16), (6, 19), (6, 22), (6, 25),
    (7, 1), (7, 4), (7, 7), (7, 8), (7, 14), (7, 19), (7, 21), (7, 28),
    (7, 31), (7, 32), (7, 37), (7, 41), (7, 42), (7, 50), (7, 55), (7, 56),
    (7, 59), (7, 62),
    (8, 14), (8, 21), (8, 22), (8, 38), (8, 47), (8, 49), (8, 50), (8, 52),
    (8, 56), (8, 67), (8, 70), (8, 84), (8, 97), (8, 103), (8, 115), (8, 122),
    (9, 8), (9, 13), (9, 16), (9, 22), (9, 25), (9, 44), (9, 47), (9, 52),
    (9, 55), (9, 59), (9, 62), (9, 67), (9, 74), (9, 81), (9, 82), (9, 87),
    (9, 91), (9, 94), (9, 103), (9, 104), (9, 109), (9, 122), (9, 124),
    (9, 137), (9, 138), (9, 143), (9, 145), (9, 152), (9, 157), (9, 167),
    (9, 173), (9, 176), (9, 181), (9, 182), (9, 185), (9, 191), (9, 194),
    (9, 199), (9, 218), (9, 220), (9, 227), (9, 229), (9, 230), (9, 234),
    (9, 236), (9, 241), (9, 244), (9, 253),
];

const BITS: usize = 32;

/// Maximum supported dimensionality (dim 0 is van der Corput, the rest use
/// the polynomial table, each polynomial twice via two init-number seeds).
pub const MAX_DIM: usize = 1 + 2 * PRIMITIVE_POLYS.len();

#[derive(Clone)]
pub struct Sobol {
    dim: usize,
    /// direction numbers v[d][b], scaled into the top 32 bits
    v: Vec<[u32; BITS]>,
    x: Vec<u32>,
    index: u64,
}

impl Sobol {
    pub fn new(dim: usize) -> Self {
        assert!(dim >= 1 && dim <= MAX_DIM, "sobol dim {dim} > {MAX_DIM}");
        let mut v = Vec::with_capacity(dim);
        // Dimension 0: van der Corput (v_i = 2^{-i}).
        let mut v0 = [0u32; BITS];
        for (i, slot) in v0.iter_mut().enumerate() {
            *slot = 1u32 << (31 - i);
        }
        v.push(v0);
        for d in 1..dim {
            let (deg, poly) = PRIMITIVE_POLYS[(d - 1) % PRIMITIVE_POLYS.len()];
            // Two variants per polynomial via different init-number seeds.
            let variant = ((d - 1) / PRIMITIVE_POLYS.len()) as u32;
            v.push(direction_numbers(deg, poly, d as u32, variant));
        }
        Sobol { dim, v, x: vec![0; dim], index: 0 }
    }

    /// Next point in [0,1)^dim (Gray-code order; first emitted point is the
    /// sequence's index-1 point, i.e. 0.5 in every coordinate).
    pub fn next_point(&mut self) -> Vec<f64> {
        self.index += 1;
        let c = self.index.trailing_zeros() as usize;
        let c = c.min(BITS - 1);
        for d in 0..self.dim {
            self.x[d] ^= self.v[d][c];
        }
        self.x
            .iter()
            .map(|&xi| xi as f64 / (1u64 << 32) as f64)
            .collect()
    }

    /// Generate n points as rows.
    pub fn points(&mut self, n: usize) -> Vec<Vec<f64>> {
        (0..n).map(|_| self.next_point()).collect()
    }
}

/// Build the 32 direction numbers for one dimension.
fn direction_numbers(deg: u32, poly: u32, dim_tag: u32, variant: u32) -> [u32; BITS] {
    let s = deg as usize;
    // Initial m_1..m_s: odd, m_i < 2^i, chosen deterministically from a
    // small hash so each (dimension, variant) differs.  Any valid choice
    // yields a proper Sobol net; Joe-Kuo-style optimization only improves
    // 2D projections.
    let mut m = vec![0u64; s + 1];
    let mut h = dim_tag
        .wrapping_mul(0x9E37_79B9)
        .wrapping_add(variant.wrapping_mul(0x85EB_CA6B))
        .wrapping_add(poly.wrapping_mul(0xC2B2_AE35));
    for i in 1..=s {
        h ^= h >> 13;
        h = h.wrapping_mul(0x5bd1_e995);
        h ^= h >> 15;
        let span = 1u64 << (i - 1); // number of odd values below 2^i
        m[i] = 2 * (h as u64 % span) + 1;
        debug_assert!(m[i] % 2 == 1 && m[i] < (1 << i));
    }
    // Recurrence: m_k = 2 a_1 m_{k-1} ^ 4 a_2 m_{k-2} ^ ... ^
    //             2^{s-1} a_{s-1} m_{k-s+1} ^ 2^s m_{k-s} ^ m_{k-s}
    let mut v = [0u32; BITS];
    let mut mm = vec![0u64; BITS + 1];
    mm[1..=s].copy_from_slice(&m[1..=s]);
    for k in (s + 1)..=BITS {
        let mut val = mm[k - s] ^ (mm[k - s] << s);
        for j in 1..s {
            let a_j = (poly >> (s - 1 - j)) & 1;
            if a_j == 1 {
                val ^= mm[k - j] << j;
            }
        }
        mm[k] = val;
    }
    for (i, slot) in v.iter_mut().enumerate() {
        let k = i + 1;
        *slot = (mm[k] << (BITS - k)) as u32;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_dim_is_van_der_corput() {
        let mut s = Sobol::new(1);
        let got: Vec<f64> = (0..7).map(|_| s.next_point()[0]).collect();
        // Gray-code order of the van der Corput sequence
        let want = [0.5, 0.75, 0.25, 0.375, 0.875, 0.625, 0.125];
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-12, "{got:?}");
        }
    }

    #[test]
    fn points_in_unit_cube() {
        let mut s = Sobol::new(40);
        for _ in 0..500 {
            let p = s.next_point();
            assert!(p.iter().all(|&x| (0.0..1.0).contains(&x)));
        }
    }

    #[test]
    fn supports_141_dims() {
        let mut s = Sobol::new(141);
        let p = s.points(64);
        assert_eq!(p.len(), 64);
        assert!(p.iter().all(|row| row.len() == 141));
    }

    #[test]
    fn max_dim_constructs() {
        let _ = Sobol::new(MAX_DIM);
    }

    #[test]
    fn no_duplicate_points() {
        let mut s = Sobol::new(8);
        let pts = s.points(256);
        for i in 0..pts.len() {
            for j in (i + 1)..pts.len() {
                assert_ne!(pts[i], pts[j], "dup at {i},{j}");
            }
        }
    }

    #[test]
    fn balanced_first_256_per_dim() {
        // A Sobol net is perfectly balanced across halves in each dim over
        // any power-of-two prefix starting at index 1.
        let mut s = Sobol::new(16);
        let pts = s.points(256);
        for d in 0..16 {
            let lo = pts.iter().filter(|p| p[d] < 0.5).count();
            assert!(
                (120..=136).contains(&lo),
                "dim {d} unbalanced: {lo}/256 below 0.5"
            );
        }
    }

    #[test]
    fn lower_discrepancy_than_random_1d() {
        // Star-discrepancy proxy in 1D: max gap between sorted neighbours.
        let mut s = Sobol::new(4);
        let n = 512;
        let mut xs: Vec<f64> = s.points(n).into_iter().map(|p| p[3]).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let max_gap = xs.windows(2).map(|w| w[1] - w[0]).fold(0.0, f64::max);
        assert!(max_gap < 0.02, "max gap {max_gap}");
    }

    #[test]
    fn dims_not_identical() {
        let mut s = Sobol::new(64);
        let pts = s.points(32);
        for d1 in 0..8 {
            for d2 in (d1 + 1)..8 {
                let same = pts.iter().filter(|p| (p[d1] - p[d2]).abs() < 1e-12).count();
                assert!(same < pts.len(), "dims {d1},{d2} identical");
            }
        }
    }
}
