//! Minimal JSON parser/emitter (no serde in the offline image).
//!
//! Supports the full JSON grammar minus exotic number forms; used by the
//! REST API (server/), the artifact manifest check (runtime/) and result
//! files (report/).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        write!(f, "{}", *n as i64)
                    } else {
                        write!(f, "{n}")
                    }
                } else {
                    write!(f, "null") // JSON has no NaN/inf
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"flags":{"MaxHeapSize":4096,"UseG1GC":true},"score":1.23,"tags":["a","b"]}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" A");
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn integer_formatting_stable() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
