//! The JVM flag catalog: every tunable `-XX` flag the tuner sees, grouped by
//! GC mode the way the paper groups them ("we extract the list of JVM flags
//! using `java -XX:+PrintFlagsFinal` and group the flags according to GC
//! modes", §IV-D).
//!
//! Counts are pinned to the paper's Table II denominators: the ParallelGC
//! group has 126 flags, the G1GC group 141 (common flags + GC-specific
//! flags).  Names, defaults and ranges follow HotSpot 1.8.0_144; ranges are
//! the sane tuning intervals the data-generation phase samples from.

/// Value domain of one flag.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kind {
    /// Boolean (-XX:+Flag / -XX:-Flag).
    Bool { default: bool },
    /// Integer-valued with an inclusive range; `log` ranges are sampled
    /// log-uniformly (sizes, thresholds spanning decades).
    Int { min: f64, max: f64, default: f64, log: bool },
}

/// Which GC-mode group(s) a flag belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Group {
    /// In both the ParallelGC and G1GC groups (heap, TLAB, compiler, ...).
    Common,
    /// ParallelGC-specific.
    Parallel,
    /// G1GC-specific.
    G1,
}

#[derive(Clone, Copy, Debug)]
pub struct FlagDef {
    pub name: &'static str,
    pub kind: Kind,
    pub group: Group,
}

impl FlagDef {
    pub fn default_value(&self) -> f64 {
        match self.kind {
            Kind::Bool { default } => {
                if default {
                    1.0
                } else {
                    0.0
                }
            }
            Kind::Int { default, .. } => default,
        }
    }

    pub fn is_numeric(&self) -> bool {
        matches!(self.kind, Kind::Int { .. })
    }

    /// Normalize a raw value into [0,1] (log-scaled where flagged).
    pub fn normalize(&self, v: f64) -> f64 {
        match self.kind {
            Kind::Bool { .. } => v.clamp(0.0, 1.0),
            Kind::Int { min, max, log, .. } => {
                if log {
                    let lo = min.max(1.0).ln();
                    let hi = max.ln();
                    ((v.max(1.0).ln() - lo) / (hi - lo)).clamp(0.0, 1.0)
                } else {
                    ((v - min) / (max - min)).clamp(0.0, 1.0)
                }
            }
        }
    }

    /// Inverse of `normalize`: map u in [0,1] back to a raw value (rounded
    /// for integer flags, 0/1 for booleans).
    pub fn denormalize(&self, u: f64) -> f64 {
        let u = u.clamp(0.0, 1.0);
        match self.kind {
            Kind::Bool { .. } => {
                if u >= 0.5 {
                    1.0
                } else {
                    0.0
                }
            }
            Kind::Int { min, max, log, .. } => {
                let raw = if log {
                    let lo = min.max(1.0).ln();
                    let hi = max.ln();
                    (lo + u * (hi - lo)).exp()
                } else {
                    min + u * (max - min)
                };
                raw.round().clamp(min, max)
            }
        }
    }
}

const fn b(name: &'static str, default: bool, group: Group) -> FlagDef {
    FlagDef { name, kind: Kind::Bool { default }, group }
}

const fn i(
    name: &'static str,
    min: f64,
    max: f64,
    default: f64,
    group: Group,
) -> FlagDef {
    FlagDef { name, kind: Kind::Int { min, max, default, log: false }, group }
}

const fn il(
    name: &'static str,
    min: f64,
    max: f64,
    default: f64,
    group: Group,
) -> FlagDef {
    FlagDef { name, kind: Kind::Int { min, max, default, log: true }, group }
}

use Group::{Common as C, Parallel as P, G1 as G};

/// The full catalog.  111 Common + 15 Parallel + 30 G1 =>
/// ParallelGC group = 126, G1GC group = 141 (paper Table II).
pub const CATALOG: &[FlagDef] = &[
    // --- Heap & memory sizing (MB unless noted) -------------------------
    il("InitialHeapSize", 256.0, 65536.0, 2048.0, C),
    il("MaxHeapSize", 2048.0, 65536.0, 24576.0, C),
    i("NewRatio", 1.0, 8.0, 2.0, C),
    il("NewSize", 64.0, 16384.0, 683.0, C),
    il("MaxNewSize", 128.0, 32768.0, 8192.0, C),
    i("SurvivorRatio", 2.0, 16.0, 8.0, C),
    i("TargetSurvivorRatio", 20.0, 90.0, 50.0, C),
    i("MaxTenuringThreshold", 0.0, 15.0, 15.0, C),
    i("InitialTenuringThreshold", 0.0, 15.0, 7.0, C),
    i("PretenureSizeThreshold", 0.0, 4096.0, 0.0, C), // KB, 0 = off
    i("MinHeapFreeRatio", 10.0, 70.0, 40.0, C),
    i("MaxHeapFreeRatio", 30.0, 100.0, 70.0, C),
    il("MetaspaceSize", 16.0, 512.0, 21.0, C),
    il("MaxMetaspaceSize", 64.0, 2048.0, 512.0, C),
    il("CompressedClassSpaceSize", 64.0, 3072.0, 1024.0, C),
    i("MaxDirectMemorySize", 0.0, 8192.0, 0.0, C),
    // --- GC common -------------------------------------------------------
    i("ParallelGCThreads", 1.0, 40.0, 15.0, C),
    i("ConcGCThreads", 1.0, 20.0, 4.0, C),
    i("GCTimeRatio", 1.0, 99.0, 99.0, C),
    il("MaxGCPauseMillis", 10.0, 2000.0, 200.0, C),
    b("UseAdaptiveSizePolicy", true, C),
    i("AdaptiveSizePolicyWeight", 0.0, 100.0, 10.0, C),
    i("AdaptiveTimeWeight", 0.0, 100.0, 25.0, C),
    i("AdaptiveSizeDecrementScaleFactor", 1.0, 16.0, 4.0, C),
    i("GCHeapFreeLimit", 0.0, 50.0, 2.0, C),
    i("GCTimeLimit", 50.0, 100.0, 98.0, C),
    b("UseGCOverheadLimit", true, C),
    b("DisableExplicitGC", false, C),
    b("ExplicitGCInvokesConcurrent", false, C),
    b("ScavengeBeforeFullGC", true, C),
    il("SoftRefLRUPolicyMSPerMB", 1.0, 10000.0, 1000.0, C),
    il("StringTableSize", 1009.0, 1000003.0, 60013.0, C),
    il("SymbolTableSize", 1009.0, 1000003.0, 20011.0, C),
    b("AlwaysPreTouch", false, C),
    b("UseLargePages", false, C),
    i("LargePageSizeInBytes", 0.0, 16.0, 0.0, C), // MB, 0 = default
    b("UseNUMA", false, C),
    b("UseNUMAInterleaving", false, C),
    b("UseCompressedOops", true, C),
    b("UseCompressedClassPointers", true, C),
    // --- TLAB --------------------------------------------------------------
    b("UseTLAB", true, C),
    i("TLABSize", 0.0, 1024.0, 0.0, C), // KB, 0 = adaptive
    i("MinTLABSize", 1.0, 64.0, 2.0, C),
    i("TLABAllocationWeight", 1.0, 100.0, 35.0, C),
    i("TLABWasteTargetPercent", 1.0, 10.0, 1.0, C),
    i("TLABRefillWasteFraction", 1.0, 256.0, 64.0, C),
    i("TLABWasteIncrement", 1.0, 16.0, 4.0, C),
    b("ResizeTLAB", true, C),
    // --- JIT compiler ------------------------------------------------------
    b("TieredCompilation", true, C),
    i("TieredStopAtLevel", 1.0, 4.0, 4.0, C),
    il("CompileThreshold", 100.0, 100000.0, 10000.0, C),
    il("Tier3InvocationThreshold", 100.0, 10000.0, 200.0, C),
    il("Tier3CompileThreshold", 500.0, 20000.0, 2000.0, C),
    il("Tier4InvocationThreshold", 1000.0, 50000.0, 5000.0, C),
    il("Tier4CompileThreshold", 2000.0, 100000.0, 15000.0, C),
    i("CICompilerCount", 1.0, 8.0, 4.0, C),
    il("ReservedCodeCacheSize", 32.0, 512.0, 240.0, C), // MB
    il("InitialCodeCacheSize", 1.0, 64.0, 3.0, C),      // MB
    i("CodeCacheExpansionSize", 16.0, 512.0, 64.0, C),  // KB
    b("UseCodeCacheFlushing", true, C),
    i("MaxInlineSize", 5.0, 200.0, 35.0, C),
    i("FreqInlineSize", 50.0, 1000.0, 325.0, C),
    i("MaxInlineLevel", 1.0, 30.0, 9.0, C),
    i("MaxRecursiveInlineLevel", 0.0, 4.0, 1.0, C),
    i("InlineSmallCode", 500.0, 5000.0, 2000.0, C),
    i("MinInliningThreshold", 0.0, 1000.0, 250.0, C),
    i("LiveNodeCountInliningCutoff", 10000.0, 80000.0, 40000.0, C),
    b("BackgroundCompilation", true, C),
    b("UseCounterDecay", true, C),
    i("CounterHalfLifeTime", 1.0, 120.0, 30.0, C),
    i("OnStackReplacePercentage", 100.0, 2000.0, 140.0, C),
    i("InterpreterProfilePercentage", 0.0, 100.0, 33.0, C),
    b("DoEscapeAnalysis", true, C),
    b("EliminateAllocations", true, C),
    b("EliminateLocks", true, C),
    b("OptimizeStringConcat", true, C),
    b("UseSuperWord", true, C),
    i("LoopUnrollLimit", 0.0, 200.0, 60.0, C),
    i("LoopMaxUnroll", 0.0, 32.0, 16.0, C),
    b("UseLoopPredicate", true, C),
    b("AggressiveOpts", false, C),
    b("UseAES", true, C),
    b("UseAESIntrinsics", true, C),
    b("UseSSE42Intrinsics", true, C),
    b("UseBiasedLocking", true, C),
    i("BiasedLockingStartupDelay", 0.0, 10000.0, 4000.0, C),
    i("PreBlockSpin", 1.0, 100.0, 10.0, C),
    b("UseFastAccessorMethods", false, C),
    // --- Threads / stacks --------------------------------------------------
    il("ThreadStackSize", 256.0, 4096.0, 1024.0, C), // KB
    il("VMThreadStackSize", 256.0, 4096.0, 1024.0, C),
    i("CompilerThreadStackSize", 0.0, 8192.0, 0.0, C),
    i("ThreadPriorityPolicy", 0.0, 1.0, 0.0, C),
    b("UseThreadPriorities", true, C),
    b("ReduceSignalUsage", false, C),
    // --- Misc / diagnostics -------------------------------------------------
    b("ClassUnloading", true, C),
    b("ClassUnloadingWithConcurrentMark", true, C),
    b("UsePerfData", true, C),
    i("PerfDataMemorySize", 8.0, 128.0, 32.0, C), // KB
    i("PerfDataSamplingInterval", 10.0, 200.0, 50.0, C),
    i("MinHeapDeltaBytes", 64.0, 4096.0, 192.0, C), // KB
    i("HeapSizePerGCThread", 16.0, 256.0, 87.0, C), // MB
    i("GCPauseIntervalMillis", 0.0, 5000.0, 0.0, C),
    b("PrintGC", false, C),
    b("PrintGCDetails", false, C),
    b("PrintGCTimeStamps", false, C),
    b("VerifyBeforeGC", false, C),
    b("VerifyAfterGC", false, C),
    b("ReduceInitialCardMarks", true, C),
    b("UseCondCardMark", false, C),
    i("MarkSweepDeadRatio", 0.0, 20.0, 5.0, C),
    i("MarkSweepAlwaysCompactCount", 1.0, 8.0, 4.0, C),
    // --- ParallelGC-specific (15) -------------------------------------------
    b("UseParallelOldGC", true, P),
    il("YoungPLABSize", 256.0, 8192.0, 4096.0, P), // words
    il("OldPLABSize", 256.0, 8192.0, 1024.0, P),
    i("PLABWeight", 0.0, 100.0, 75.0, P),
    b("ResizePLAB", true, P),
    i("ParallelGCBufferWastePct", 1.0, 20.0, 10.0, P),
    b("UseAdaptiveGCBoundary", false, P),
    i("ParallelOldDeadWoodLimiterMean", 0.0, 100.0, 50.0, P),
    i("ParallelOldDeadWoodLimiterStdDev", 0.0, 100.0, 80.0, P),
    i("AdaptiveSizeMajorGCDecayTimeScale", 1.0, 64.0, 10.0, P),
    i("AdaptiveSizePolicyInitializingSteps", 1.0, 100.0, 20.0, P),
    i("AdaptiveSizeThroughPutPolicy", 0.0, 1.0, 0.0, P),
    i("ThresholdTolerance", 1.0, 50.0, 10.0, P),
    i("SurvivorPadding", 1.0, 10.0, 3.0, P),
    i("PromotedPadding", 1.0, 10.0, 3.0, P),
    // --- G1-specific (30) ----------------------------------------------------
    il("G1HeapRegionSize", 1.0, 32.0, 8.0, G), // MB (power of two in HotSpot)
    i("InitiatingHeapOccupancyPercent", 10.0, 90.0, 45.0, G),
    i("G1NewSizePercent", 1.0, 20.0, 5.0, G),
    i("G1MaxNewSizePercent", 20.0, 90.0, 60.0, G),
    i("G1ReservePercent", 0.0, 50.0, 10.0, G),
    i("G1HeapWastePercent", 0.0, 20.0, 5.0, G),
    i("G1MixedGCCountTarget", 1.0, 32.0, 8.0, G),
    i("G1MixedGCLiveThresholdPercent", 50.0, 100.0, 85.0, G),
    i("G1OldCSetRegionThresholdPercent", 1.0, 30.0, 10.0, G),
    i("G1ConfidencePercent", 0.0, 100.0, 50.0, G),
    i("G1RSetRegionEntries", 0.0, 4096.0, 0.0, G), // 0 = adaptive
    i("G1RSetSparseRegionEntries", 0.0, 128.0, 0.0, G),
    i("G1RSetUpdatingPauseTimePercent", 1.0, 50.0, 10.0, G),
    i("G1ConcRefinementThreads", 0.0, 40.0, 15.0, G),
    i("G1ConcRefinementGreenZone", 0.0, 1024.0, 0.0, G),
    i("G1ConcRefinementYellowZone", 0.0, 2048.0, 0.0, G),
    i("G1ConcRefinementRedZone", 0.0, 4096.0, 0.0, G),
    i("G1ConcRefinementThresholdStep", 0.0, 16.0, 0.0, G),
    i("G1ConcRefinementServiceIntervalMillis", 10.0, 1000.0, 300.0, G),
    b("G1UseAdaptiveConcRefinement", true, G),
    i("G1SATBBufferSize", 1.0, 64.0, 1.0, G), // KB
    i("G1SATBBufferEnqueueingThresholdPercent", 0.0, 100.0, 60.0, G),
    il("G1UpdateBufferSize", 64.0, 4096.0, 256.0, G),
    i("G1ConcMarkStepDurationMillis", 1.0, 50.0, 10.0, G),
    i("G1ConcRSLogCacheSize", 4.0, 16.0, 10.0, G),
    i("G1ConcRSHotCardLimit", 1.0, 16.0, 4.0, G),
    i("G1ExpandByPercentOfAvailable", 0.0, 100.0, 20.0, G),
    b("UseStringDeduplication", false, G),
    i("StringDeduplicationAgeThreshold", 1.0, 15.0, 3.0, G),
    i("G1PeriodicGCInterval", 0.0, 60000.0, 0.0, G), // ms, 0 = off
];

/// Flags that genuinely do nothing in the simulator (logging/diagnostics);
/// lasso should learn to drop these — part of the Table II reproduction.
pub const NOOP_FLAGS: &[&str] = &[
    "PrintGC",
    "PrintGCDetails",
    "PrintGCTimeStamps",
    "UsePerfData",
    "PerfDataMemorySize",
    "PerfDataSamplingInterval",
    "ReduceSignalUsage",
    "ThreadPriorityPolicy",
    "UseThreadPriorities",
    "GCPauseIntervalMillis",
    "MinHeapDeltaBytes",
    "LargePageSizeInBytes",
];

/// GC mode under tuning (the paper evaluates G1GC and ParallelGC).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GcMode {
    ParallelGC,
    G1GC,
}

impl GcMode {
    pub fn name(self) -> &'static str {
        match self {
            GcMode::ParallelGC => "ParallelGC",
            GcMode::G1GC => "G1GC",
        }
    }

    pub fn parse(s: &str) -> Option<GcMode> {
        match s.to_ascii_lowercase().as_str() {
            "parallel" | "parallelgc" => Some(GcMode::ParallelGC),
            "g1" | "g1gc" => Some(GcMode::G1GC),
            _ => None,
        }
    }
}

/// Indices into CATALOG for one GC mode's flag group, in catalog order.
/// Cached: this sits on the simulator hot path (`FlagConfig::get` during
/// `JvmParams::derive`, hundreds of thousands of calls per tuning run).
pub fn group_indices(mode: GcMode) -> &'static [usize] {
    fn build(mode: GcMode) -> Vec<usize> {
        CATALOG
            .iter()
            .enumerate()
            .filter(|(_, f)| match f.group {
                Group::Common => true,
                Group::Parallel => mode == GcMode::ParallelGC,
                Group::G1 => mode == GcMode::G1GC,
            })
            .map(|(i, _)| i)
            .collect()
    }
    static PARALLEL: std::sync::OnceLock<Vec<usize>> = std::sync::OnceLock::new();
    static G1: std::sync::OnceLock<Vec<usize>> = std::sync::OnceLock::new();
    match mode {
        GcMode::ParallelGC => PARALLEL.get_or_init(|| build(GcMode::ParallelGC)),
        GcMode::G1GC => G1.get_or_init(|| build(GcMode::G1GC)),
    }
}

/// Position of `name` within a mode's group (cached name -> position map).
pub fn group_position(mode: GcMode, name: &str) -> Option<usize> {
    use std::collections::HashMap;
    fn build(mode: GcMode) -> HashMap<&'static str, usize> {
        group_indices(mode)
            .iter()
            .enumerate()
            .map(|(pos, &i)| (CATALOG[i].name, pos))
            .collect()
    }
    static PARALLEL: std::sync::OnceLock<HashMap<&'static str, usize>> =
        std::sync::OnceLock::new();
    static G1: std::sync::OnceLock<HashMap<&'static str, usize>> = std::sync::OnceLock::new();
    match mode {
        GcMode::ParallelGC => PARALLEL.get_or_init(|| build(GcMode::ParallelGC)).get(name).copied(),
        GcMode::G1GC => G1.get_or_init(|| build(GcMode::G1GC)).get(name).copied(),
    }
}

pub fn flag_by_name(name: &str) -> Option<(usize, &'static FlagDef)> {
    CATALOG.iter().enumerate().find(|(_, f)| f.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_counts_match_paper_table2_denominators() {
        assert_eq!(group_indices(GcMode::ParallelGC).len(), 126);
        assert_eq!(group_indices(GcMode::G1GC).len(), 141);
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<&str> = CATALOG.iter().map(|f| f.name).collect();
        names.sort_unstable();
        let before = names.len();
        names.dedup();
        assert_eq!(before, names.len());
    }

    #[test]
    fn defaults_within_range() {
        for f in CATALOG {
            if let Kind::Int { min, max, default, .. } = f.kind {
                assert!(
                    (min..=max).contains(&default),
                    "{} default {default} outside [{min},{max}]",
                    f.name
                );
            }
        }
    }

    #[test]
    fn normalize_roundtrips_default() {
        for f in CATALOG {
            let d = f.default_value();
            let u = f.normalize(d);
            assert!((0.0..=1.0).contains(&u), "{}", f.name);
            let back = f.denormalize(u);
            match f.kind {
                Kind::Bool { .. } => assert_eq!(back, d, "{}", f.name),
                Kind::Int { min, max, .. } => {
                    // round-trip within quantization error of the range
                    let tol = ((max - min) * 1e-3).max(1.0);
                    assert!(
                        (back - d).abs() <= tol,
                        "{}: {d} -> {u} -> {back}",
                        f.name
                    );
                }
            }
        }
    }

    #[test]
    fn denormalize_endpoints() {
        for f in CATALOG {
            if let Kind::Int { min, max, .. } = f.kind {
                assert_eq!(f.denormalize(0.0), min.round(), "{}", f.name);
                assert_eq!(f.denormalize(1.0), max.round(), "{}", f.name);
            }
        }
    }

    #[test]
    fn noop_flags_exist_in_catalog() {
        for name in NOOP_FLAGS {
            assert!(flag_by_name(name).is_some(), "{name} not in catalog");
        }
    }

    #[test]
    fn g1_flags_not_in_parallel_group() {
        let par = group_indices(GcMode::ParallelGC);
        for &i in par {
            assert_ne!(CATALOG[i].group, Group::G1);
        }
    }

    #[test]
    fn gcmode_parse() {
        assert_eq!(GcMode::parse("g1"), Some(GcMode::G1GC));
        assert_eq!(GcMode::parse("ParallelGC"), Some(GcMode::ParallelGC));
        assert_eq!(GcMode::parse("cms"), None);
    }
}
