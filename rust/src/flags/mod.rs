//! JVM flag catalog, configurations and feature encoding (the search space
//! the tuner explores; paper §III-B and Table II).

pub mod catalog;
pub mod config;

pub use catalog::{flag_by_name, group_indices, FlagDef, GcMode, Group, Kind, CATALOG, NOOP_FLAGS};
pub use config::{FeatureEncoder, FlagConfig};
