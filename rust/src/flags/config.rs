//! A concrete flag assignment for one GC mode, plus the feature encoding
//! used by every ML stage (AL, lasso, GP): normalized flag values in [0,1]
//! followed by squared terms for numeric flags — the "linear regression
//! model with polynomial features" of paper §III-B.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::catalog::{self, FlagDef, GcMode, Kind, CATALOG};
use crate::util::rng::Pcg;

/// A full flag configuration for one GC mode.  `values` is aligned with
/// `catalog::group_indices(mode)` and stores raw flag values (bool as 0/1).
#[derive(Clone, Debug, PartialEq)]
pub struct FlagConfig {
    pub mode: GcMode,
    pub values: Vec<f64>,
}

impl FlagConfig {
    /// The JVM's default configuration for this GC mode.
    pub fn default_for(mode: GcMode) -> FlagConfig {
        let values = catalog::group_indices(mode)
            .iter()
            .map(|&i| CATALOG[i].default_value())
            .collect();
        FlagConfig { mode, values }
    }

    /// Uniformly random configuration (log-uniform for log-scaled flags) —
    /// the phase-1 sampling distribution.
    pub fn random(mode: GcMode, rng: &mut Pcg) -> FlagConfig {
        let values = catalog::group_indices(mode)
            .iter()
            .map(|&i| sample_flag(&CATALOG[i], rng))
            .collect();
        FlagConfig { mode, values }
    }

    /// Build from a normalized [0,1]^k vector (k = flag count for `mode`).
    pub fn from_unit(mode: GcMode, unit: &[f64]) -> FlagConfig {
        let idx = catalog::group_indices(mode);
        assert_eq!(unit.len(), idx.len(), "unit vector arity");
        let values = idx
            .iter()
            .zip(unit)
            .map(|(&i, &u)| CATALOG[i].denormalize(u))
            .collect();
        FlagConfig { mode, values }
    }

    /// Normalized [0,1] vector (one entry per flag in the group).
    pub fn to_unit(&self) -> Vec<f64> {
        self.defs()
            .iter()
            .zip(&self.values)
            .map(|(f, &v)| f.normalize(v))
            .collect()
    }

    /// Flag definitions in this config's group, aligned with `values`.
    pub fn defs(&self) -> Vec<&'static FlagDef> {
        catalog::group_indices(self.mode)
            .iter()
            .map(|&i| &CATALOG[i])
            .collect()
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Value of a flag by name; defaults apply for flags outside the group.
    pub fn get(&self, name: &str) -> f64 {
        if let Some(pos) = catalog::group_position(self.mode, name) {
            return self.values[pos];
        }
        catalog::flag_by_name(name)
            .map(|(_, f)| f.default_value())
            .unwrap_or_else(|| panic!("unknown flag {name}"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.get(name) >= 0.5
    }

    /// Set a flag by name (must be in this mode's group).
    pub fn set(&mut self, name: &str, value: f64) {
        match catalog::group_position(self.mode, name) {
            Some(pos) => {
                let i = catalog::group_indices(self.mode)[pos];
                self.values[pos] = clamp_to_range(&CATALOG[i], value);
            }
            None => panic!("flag {name} not in {} group", self.mode.name()),
        }
    }

    /// Render as `java` CLI arguments (`-XX:+Flag`, `-XX:Flag=value`) the
    /// way a real launcher would pass them.
    pub fn to_java_args(&self) -> String {
        let mut out = String::new();
        match self.mode {
            GcMode::ParallelGC => out.push_str("-XX:+UseParallelGC"),
            GcMode::G1GC => out.push_str("-XX:+UseG1GC"),
        }
        for (f, &v) in self.defs().iter().zip(&self.values) {
            match f.kind {
                Kind::Bool { .. } => {
                    let sign = if v >= 0.5 { '+' } else { '-' };
                    let _ = write!(out, " -XX:{}{}", sign, f.name);
                }
                Kind::Int { .. } => {
                    let _ = write!(out, " -XX:{}={}", f.name, v as i64);
                }
            }
        }
        out
    }

    /// Map of name -> value (for the REST API / JSON results).
    pub fn to_map(&self) -> BTreeMap<String, f64> {
        self.defs()
            .iter()
            .zip(&self.values)
            .map(|(f, &v)| (f.name.to_string(), v))
            .collect()
    }
}

fn clamp_to_range(f: &FlagDef, v: f64) -> f64 {
    match f.kind {
        Kind::Bool { .. } => {
            if v >= 0.5 {
                1.0
            } else {
                0.0
            }
        }
        Kind::Int { min, max, .. } => v.round().clamp(min, max),
    }
}

fn sample_flag(f: &FlagDef, rng: &mut Pcg) -> f64 {
    match f.kind {
        Kind::Bool { .. } => {
            if rng.bool() {
                1.0
            } else {
                0.0
            }
        }
        Kind::Int { min, max, log, .. } => {
            let v = if log {
                rng.log_uniform(min.max(1.0), max)
            } else {
                rng.uniform(min, max)
            };
            v.round().clamp(min, max)
        }
    }
}

// ---------------------------------------------------------------------------
// Feature encoding
// ---------------------------------------------------------------------------

/// Feature encoder for one GC mode: linear terms for all flags + squared
/// terms for numeric flags ("polynomial features", §III-B).  The same
/// encoder maps feature indices back to flag names for the lasso report.
#[derive(Clone, Debug)]
pub struct FeatureEncoder {
    pub mode: GcMode,
    catalog_idx: Vec<usize>,
    squared_pos: Vec<usize>, // positions (within group) that get x^2 terms
}

impl FeatureEncoder {
    pub fn new(mode: GcMode) -> Self {
        let catalog_idx = catalog::group_indices(mode).to_vec();
        let squared_pos = catalog_idx
            .iter()
            .enumerate()
            .filter(|(_, &i)| CATALOG[i].is_numeric())
            .map(|(pos, _)| pos)
            .collect();
        FeatureEncoder { mode, catalog_idx, squared_pos }
    }

    /// Number of flags in the group (126 or 141).
    pub fn n_flags(&self) -> usize {
        self.catalog_idx.len()
    }

    /// Total feature dimensionality (flags + squared terms).
    pub fn n_features(&self) -> usize {
        self.catalog_idx.len() + self.squared_pos.len()
    }

    /// Encode a config into its feature vector.
    pub fn encode(&self, cfg: &FlagConfig) -> Vec<f64> {
        assert_eq!(cfg.mode, self.mode);
        let unit = cfg.to_unit();
        let mut out = Vec::with_capacity(self.n_features());
        out.extend_from_slice(&unit);
        out.extend(self.squared_pos.iter().map(|&p| unit[p] * unit[p]));
        out
    }

    /// Which flag (position within the group) produced feature j.
    pub fn feature_flag_pos(&self, j: usize) -> usize {
        if j < self.catalog_idx.len() {
            j
        } else {
            self.squared_pos[j - self.catalog_idx.len()]
        }
    }

    /// Human-readable feature name ("MaxHeapSize" or "MaxHeapSize^2").
    pub fn feature_name(&self, j: usize) -> String {
        let pos = self.feature_flag_pos(j);
        let name = CATALOG[self.catalog_idx[pos]].name;
        if j < self.catalog_idx.len() {
            name.to_string()
        } else {
            format!("{name}^2")
        }
    }

    /// Collapse per-feature weights into per-flag relevance (a flag counts
    /// as selected if any of its features is non-zero — how the paper's
    /// Table II counts "flags selected by lasso").
    pub fn selected_flags(&self, weights: &[f64], tol: f64) -> Vec<usize> {
        assert_eq!(weights.len(), self.n_features());
        let mut hit = vec![false; self.n_flags()];
        for (j, &w) in weights.iter().enumerate() {
            if w.abs() > tol {
                hit[self.feature_flag_pos(j)] = true;
            }
        }
        hit.iter()
            .enumerate()
            .filter(|(_, &h)| h)
            .map(|(p, _)| p)
            .collect()
    }

    pub fn flag_name(&self, pos: usize) -> &'static str {
        CATALOG[self.catalog_idx[pos]].name
    }

    pub fn flag_def(&self, pos: usize) -> &'static FlagDef {
        &CATALOG[self.catalog_idx[pos]]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_matches_catalog_defaults() {
        let cfg = FlagConfig::default_for(GcMode::G1GC);
        assert_eq!(cfg.len(), 141);
        assert_eq!(cfg.get("MaxGCPauseMillis"), 200.0);
        assert_eq!(cfg.get("InitiatingHeapOccupancyPercent"), 45.0);
        assert!(cfg.get_bool("UseTLAB"));
    }

    #[test]
    fn parallel_group_excludes_g1_flags() {
        let cfg = FlagConfig::default_for(GcMode::ParallelGC);
        assert_eq!(cfg.len(), 126);
        // get() on an out-of-group flag falls back to its catalog default
        assert_eq!(cfg.get("G1HeapRegionSize"), 8.0);
        assert!(cfg.defs().iter().all(|f| f.name != "G1HeapRegionSize"));
    }

    #[test]
    fn random_configs_in_range() {
        let mut rng = Pcg::new(1);
        for _ in 0..20 {
            let cfg = FlagConfig::random(GcMode::G1GC, &mut rng);
            for (f, &v) in cfg.defs().iter().zip(&cfg.values) {
                match f.kind {
                    Kind::Bool { .. } => assert!(v == 0.0 || v == 1.0),
                    Kind::Int { min, max, .. } => {
                        assert!((min..=max).contains(&v), "{} = {v}", f.name)
                    }
                }
            }
        }
    }

    #[test]
    fn unit_roundtrip() {
        let mut rng = Pcg::new(2);
        let cfg = FlagConfig::random(GcMode::ParallelGC, &mut rng);
        let unit = cfg.to_unit();
        assert!(unit.iter().all(|&u| (0.0..=1.0).contains(&u)));
        let back = FlagConfig::from_unit(GcMode::ParallelGC, &unit);
        for ((f, &a), &b) in cfg.defs().iter().zip(&cfg.values).zip(&back.values) {
            let tol = match f.kind {
                Kind::Bool { .. } => 0.0,
                Kind::Int { min, max, log, .. } => {
                    if log {
                        (a.max(1.0) * 0.01).max(1.0)
                    } else {
                        ((max - min) * 1e-3).max(1.0)
                    }
                }
            };
            assert!((a - b).abs() <= tol, "{}: {a} vs {b}", f.name);
        }
    }

    #[test]
    fn set_and_get() {
        let mut cfg = FlagConfig::default_for(GcMode::G1GC);
        cfg.set("MaxHeapSize", 32768.0);
        assert_eq!(cfg.get("MaxHeapSize"), 32768.0);
        cfg.set("MaxHeapSize", 1e12); // clamped to range max
        assert_eq!(cfg.get("MaxHeapSize"), 65536.0);
    }

    #[test]
    #[should_panic]
    fn set_out_of_group_panics() {
        let mut cfg = FlagConfig::default_for(GcMode::ParallelGC);
        cfg.set("G1ReservePercent", 20.0);
    }

    #[test]
    fn java_args_format() {
        let cfg = FlagConfig::default_for(GcMode::G1GC);
        let args = cfg.to_java_args();
        assert!(args.starts_with("-XX:+UseG1GC"));
        assert!(args.contains("-XX:MaxGCPauseMillis=200"));
        assert!(args.contains("-XX:+UseTLAB"));
        assert!(args.contains("-XX:-AlwaysPreTouch"));
    }

    #[test]
    fn encoder_dimensions_fit_artifact_budget() {
        for mode in [GcMode::ParallelGC, GcMode::G1GC] {
            let enc = FeatureEncoder::new(mode);
            assert!(enc.n_features() <= 320, "{}: {}", mode.name(), enc.n_features());
            assert!(enc.n_features() > enc.n_flags());
        }
    }

    #[test]
    fn encoder_squared_terms() {
        let enc = FeatureEncoder::new(GcMode::ParallelGC);
        let cfg = FlagConfig::default_for(GcMode::ParallelGC);
        let feats = enc.encode(&cfg);
        assert_eq!(feats.len(), enc.n_features());
        let unit = cfg.to_unit();
        // check one squared term
        let j = enc.n_flags(); // first squared feature
        let pos = enc.feature_flag_pos(j);
        assert!((feats[j] - unit[pos] * unit[pos]).abs() < 1e-12);
        assert!(enc.feature_name(j).ends_with("^2"));
    }

    #[test]
    fn selected_flags_collapses_squares() {
        let enc = FeatureEncoder::new(GcMode::ParallelGC);
        let mut w = vec![0.0; enc.n_features()];
        // only the squared term of some numeric flag is active
        let j = enc.n_flags() + 3;
        w[j] = 0.5;
        let sel = enc.selected_flags(&w, 1e-9);
        assert_eq!(sel, vec![enc.feature_flag_pos(j)]);
    }

    #[test]
    fn to_map_contains_all_flags() {
        let cfg = FlagConfig::default_for(GcMode::G1GC);
        assert_eq!(cfg.to_map().len(), 141);
    }
}
