//! Cluster and executor topology (paper §IV: 3 nodes x 20 cores, 90 GB).

/// Physical cluster description.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClusterSpec {
    pub nodes: usize,
    pub cores_per_node: usize,
    pub mem_per_node_mb: f64,
}

impl ClusterSpec {
    /// The paper's evaluation cluster.
    pub fn paper() -> ClusterSpec {
        ClusterSpec { nodes: 3, cores_per_node: 20, mem_per_node_mb: 92160.0 }
    }

    pub fn total_cores(&self) -> usize {
        self.nodes * self.cores_per_node
    }
}

/// Spark executor fleet for one job.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExecutorSpec {
    pub count: usize,
    pub cores: usize,
    pub mem_mb: f64,
}

impl ExecutorSpec {
    /// Default single-benchmark deployment: one executor per node using the
    /// full node (paper §IV-A: "3 Spark executors, one executor at each
    /// node").
    pub fn full_cluster(cluster: &ClusterSpec) -> ExecutorSpec {
        ExecutorSpec {
            count: cluster.nodes,
            cores: cluster.cores_per_node,
            mem_mb: cluster.mem_per_node_mb * 0.9,
        }
    }

    /// Fig 6 (a, b): 2 executors, 15 cores, 60 GB each per benchmark.
    pub fn parallel_2x15() -> ExecutorSpec {
        ExecutorSpec { count: 2, cores: 15, mem_mb: 61440.0 }
    }

    /// Fig 6 (c, d): 3 executors, 10 cores each; 44 GB (LDA) / 50 GB (DK).
    pub fn parallel_3x10(mem_gb: f64) -> ExecutorSpec {
        ExecutorSpec { count: 3, cores: 10, mem_mb: mem_gb * 1024.0 }
    }
}

/// Global round-robin executor placement over nodes (all fleets share the
/// same counter, the way a cluster manager spreads containers).  Returns a
/// node index per executor per fleet.
pub fn placements(cluster: &ClusterSpec, fleets: &[ExecutorSpec]) -> Vec<Vec<usize>> {
    let mut next = 0usize;
    fleets
        .iter()
        .map(|f| {
            (0..f.count)
                .map(|_| {
                    let n = next % cluster.nodes;
                    next += 1;
                    n
                })
                .collect()
        })
        .collect()
}

/// Per-node total cores demanded under the global placement.
pub fn node_core_demand(cluster: &ClusterSpec, fleets: &[ExecutorSpec]) -> Vec<f64> {
    let mut demand = vec![0.0; cluster.nodes];
    for (fleet, nodes) in fleets.iter().zip(placements(cluster, fleets)) {
        for n in nodes {
            demand[n] += fleet.cores as f64;
        }
    }
    demand
}

/// Contention factor for a fleet: the worst oversubscription over the nodes
/// hosting its executors, plus a small co-location penalty (shared LLC and
/// memory bandwidth) when a node hosts executors of more than one job.
pub fn contention_factor(
    cluster: &ClusterSpec,
    fleets: &[ExecutorSpec],
    fleet_idx: usize,
) -> f64 {
    let place = placements(cluster, fleets);
    let demand = node_core_demand(cluster, fleets);
    let mut shared = vec![0usize; cluster.nodes];
    for nodes in &place {
        let mut seen = vec![false; cluster.nodes];
        for &n in nodes {
            if !seen[n] {
                shared[n] += 1;
                seen[n] = true;
            }
        }
    }
    let mut worst: f64 = 1.0;
    for &node in &place[fleet_idx] {
        let over = demand[node] / cluster.cores_per_node as f64;
        let mut f = if over > 1.0 { 1.0 / over } else { 1.0 };
        if shared[node] > 1 && fleets.len() > 1 {
            f *= 0.955; // co-location penalty
        }
        worst = worst.min(f);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cluster_dimensions() {
        let c = ClusterSpec::paper();
        assert_eq!(c.total_cores(), 60);
        assert!(c.mem_per_node_mb > 90_000.0);
    }

    #[test]
    fn full_cluster_fleet() {
        let c = ClusterSpec::paper();
        let f = ExecutorSpec::full_cluster(&c);
        assert_eq!(f.count, 3);
        assert_eq!(f.cores, 20);
    }

    #[test]
    fn solo_fleet_no_contention() {
        let c = ClusterSpec::paper();
        let f = ExecutorSpec::full_cluster(&c);
        assert_eq!(contention_factor(&c, &[f], 0), 1.0);
    }

    #[test]
    fn parallel_fleets_contend() {
        let c = ClusterSpec::paper();
        let fleets = [ExecutorSpec::parallel_2x15(), ExecutorSpec::parallel_2x15()];
        let f = contention_factor(&c, &fleets, 0);
        assert!(f < 1.0, "expected co-location penalty, got {f}");
        // 4 x 15-core executors on 3 x 20-core nodes: one node is 1.5x
        // oversubscribed, so the affected fleet loses ~1/3 of its speed.
        assert!(f > 0.55, "{f}");
    }

    #[test]
    fn oversubscription_scales_down() {
        let c = ClusterSpec::paper();
        // 6 executors x 15 cores = 90 demanded vs 60 cores
        let big = ExecutorSpec { count: 6, cores: 15, mem_mb: 30720.0 };
        let f = contention_factor(&c, &[big], 0);
        assert!(f < 0.7, "{f}");
    }

    #[test]
    fn demand_round_robin() {
        let c = ClusterSpec::paper();
        let fleets = [ExecutorSpec { count: 4, cores: 10, mem_mb: 1.0 }];
        let d = node_core_demand(&c, &fleets);
        assert_eq!(d, vec![20.0, 10.0, 10.0]);
    }
}
