//! Spark cluster + workload substrate (paper testbed substitution).
//!
//! Models the paper's evaluation cluster — 3 nodes x dual-socket Xeon
//! E5-2650 (20 physical cores/node, 60 total), 90 GB per node — with
//! executors hosting one simulated JVM each, the two HiBench workloads
//! (Table I), and the parallel-run contention scenarios of Fig 6.

pub mod cluster;
pub mod runner;
pub mod workloads;

pub use cluster::{ClusterSpec, ExecutorSpec};
pub use runner::{
    run_benchmark, run_benchmark_with_contention, run_benchmark_with_contention_on,
    run_parallel, run_parallel_on, RunMetrics, SparkRunner,
};
pub use workloads::{Benchmark, WorkloadSpec};
