//! Spark cluster + workload substrate (paper testbed substitution).
//!
//! Models the paper's evaluation cluster — 3 nodes x dual-socket Xeon
//! E5-2650 (20 physical cores/node, 60 total), 90 GB per node — with
//! executors hosting one simulated JVM each, the two HiBench workloads
//! (Table I), and the parallel-run contention scenarios of Fig 6.
//!
//! Measurement is failure-aware: a [`SparkRunner`] with a [`FaultPlan`]
//! attached injects deterministic, seeded faults (crash-on-start flag
//! regions, transient executor crashes, stragglers, noise spikes) and
//! wraps every measurement in a retry-with-backoff policy, reporting a
//! first-class [`RunOutcome`] instead of a bare number.  Without a plan
//! the runner is bit-identical to the fault-free path.

pub mod cluster;
pub mod fault;
pub mod runner;
pub mod workloads;

pub use cluster::{ClusterSpec, ExecutorSpec};
pub use fault::{CrashRegion, FailureHisto, FaultPlan};
pub use runner::{
    run_benchmark, run_benchmark_with_contention, run_benchmark_with_contention_on,
    run_parallel, run_parallel_on, RunMetrics, RunOutcome, SparkRunner,
};
pub use workloads::{Benchmark, WorkloadSpec};
