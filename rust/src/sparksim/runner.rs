//! Job runner: executes a benchmark (or several in parallel) on the
//! simulated cluster and reports the metrics the tuner optimizes.
//!
//! This is the objective function Q of the paper's eq. (1): flag config in,
//! (execution time, heap usage %) out.

use super::cluster::{contention_factor, ClusterSpec, ExecutorSpec};
use super::fault::FaultPlan;
use super::workloads::Benchmark;
use crate::exec::{self, ExecPool};
use crate::flags::FlagConfig;
use crate::jvmsim::{self, FailureKind, GcStats, JvmParams, MAX_WALL_S};
use crate::util::rng::Pcg;

/// Metrics recorded for one benchmark run (paper §IV-B).
#[derive(Clone, Debug, PartialEq)]
pub struct RunMetrics {
    /// Job execution time.  Failed runs (OOM / GC-thrash timeout / any
    /// injected fault) report the timeout budget — a failed configuration
    /// can never look fast.
    pub exec_time_s: f64,
    /// Actual simulated wall-clock (short for an OOM crash; includes
    /// retry attempts and backoff when a fault plan retried) — what
    /// tuning time accounting should charge.
    pub wall_clock_s: f64,
    pub hu_avg_pct: f64,
    pub gc: GcStats,
    /// Why the run failed, if it did: the worst executor's failure, with
    /// the first failing executor (in index order) deciding the kind.
    pub failure: Option<FailureKind>,
}

impl RunMetrics {
    pub fn failed(&self) -> bool {
        self.failure.is_some()
    }
}

/// First-class success/failure for one measured configuration — what the
/// objective, datagen, and the tuners consume instead of bare metrics.
/// `Failed` still carries metrics (penalty values: capped exec time,
/// garbage heap percentage), because downstream label policies need
/// *something* to record; they must treat it as a penalty, not a
/// measurement.
#[derive(Clone, Debug, PartialEq)]
pub enum RunOutcome {
    Ok(RunMetrics),
    Failed { kind: FailureKind, attempts: u32, metrics: RunMetrics },
}

impl RunOutcome {
    fn from_metrics(m: RunMetrics) -> RunOutcome {
        match m.failure {
            None => RunOutcome::Ok(m),
            Some(kind) => RunOutcome::Failed { kind, attempts: 1, metrics: m },
        }
    }

    /// The metrics of the (final) attempt, success or not.
    pub fn metrics(&self) -> &RunMetrics {
        match self {
            RunOutcome::Ok(m) => m,
            RunOutcome::Failed { metrics, .. } => metrics,
        }
    }

    pub fn failure(&self) -> Option<FailureKind> {
        match self {
            RunOutcome::Ok(_) => None,
            RunOutcome::Failed { kind, .. } => Some(*kind),
        }
    }

    /// Measurement attempts consumed (1 unless the retry policy ran).
    pub fn attempts(&self) -> u32 {
        match self {
            RunOutcome::Ok(_) => 1,
            RunOutcome::Failed { attempts, .. } => *attempts,
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, RunOutcome::Ok(_))
    }
}

/// Fixed driver-side overhead per Spark job (scheduling, result collection).
const DRIVER_OVERHEAD_S: f64 = 2.0;

/// Run `bench` with `cfg` on a fleet, under an external contention factor
/// (1.0 = exclusive cluster), with the per-executor JVM simulations fanned
/// out on `pool`.  Deterministic in `seed` and independent of the pool
/// width: every executor's RNG is forked from the job stream *before*
/// dispatch (fork order is the serial loop's), and the metrics are reduced
/// in executor order, so pool size 1 and N produce bit-identical results.
pub fn run_benchmark_with_contention_on(
    pool: &ExecPool,
    bench: Benchmark,
    cfg: &FlagConfig,
    exec: &ExecutorSpec,
    contention: f64,
    seed: u64,
) -> RunMetrics {
    run_attempt(pool, bench, cfg, exec, contention, seed, None)
}

/// One measurement attempt, optionally under a fault plan.  With
/// `fault == None` this is byte-for-byte the pre-fault-injection run path:
/// the fault RNG is never constructed and no extra draws happen, so all
/// happy-path results stay bit-identical.  With a plan, injection is a
/// post-processing step per executor — a pure function of
/// (plan seed, run seed, attempt, executor index) — so results remain
/// independent of the pool width.
fn run_attempt(
    pool: &ExecPool,
    bench: Benchmark,
    cfg: &FlagConfig,
    exec: &ExecutorSpec,
    contention: f64,
    seed: u64,
    fault: Option<(&FaultPlan, u32)>,
) -> RunMetrics {
    let mut p = JvmParams::derive(cfg, exec.mem_mb, exec.cores as f64);
    let load = bench.executor_load(exec.count);
    let cores_eff = exec.cores as f64 * contention;
    // Co-located jobs also contend for memory bandwidth during STW
    // collections: GC copy/compact rates degrade super-linearly with the
    // contention factor, which is why flag tuning pays off *more* in the
    // shared-cluster scenarios (paper SectionV-E).
    if contention < 1.0 {
        let gc_penalty = contention.powf(0.7);
        p.copy_rate *= gc_penalty;
        p.compact_rate *= gc_penalty;
    }

    let mut rng = Pcg::with_stream(seed, 0x5eed_0001);
    let erngs: Vec<Pcg> = (0..exec.count).map(|e| rng.fork(e as u64 + 1)).collect();
    let results = pool.par_map(&erngs, |_, erng| {
        let mut erng = erng.clone();
        jvmsim::run(&p, &load, cores_eff, &mut erng)
    });

    let mut worst_wall = 0.0f64;
    let mut hu_sum = 0.0;
    let mut gc = GcStats::default();
    let mut failure: Option<FailureKind> = None;
    for (e, r) in results.iter().enumerate() {
        let mut wall = r.wall_s;
        let mut exec_failure = r.failure;
        if let Some((plan, attempt)) = fault {
            // Natural (deterministic) failures take precedence: an OOM'd
            // executor is already dead, there is nothing left to inject.
            if exec_failure.is_none() {
                let (injected, w) = plan.executor_fault(seed, attempt, e, r.wall_s);
                exec_failure = injected;
                wall = w;
            }
        }
        worst_wall = worst_wall.max(wall);
        hu_sum += r.hu_avg_pct;
        gc.minor += r.gc.minor;
        gc.mixed += r.gc.mixed;
        gc.full += r.gc.full;
        gc.conc_cycles += r.gc.conc_cycles;
        gc.total_pause_ms += r.gc.total_pause_ms;
        gc.max_pause_ms = gc.max_pause_ms.max(r.gc.max_pause_ms);
        // The first failing executor (index order) decides the run's kind.
        if failure.is_none() {
            failure = exec_failure;
        }
    }

    let wall_clock_s = worst_wall + DRIVER_OVERHEAD_S;
    RunMetrics {
        exec_time_s: if failure.is_some() {
            MAX_WALL_S + DRIVER_OVERHEAD_S
        } else {
            wall_clock_s
        },
        wall_clock_s,
        hu_avg_pct: hu_sum / exec.count.max(1) as f64,
        gc,
        failure,
    }
}

/// `run_benchmark_with_contention_on` on the process-global pool.
pub fn run_benchmark_with_contention(
    bench: Benchmark,
    cfg: &FlagConfig,
    exec: &ExecutorSpec,
    contention: f64,
    seed: u64,
) -> RunMetrics {
    run_benchmark_with_contention_on(exec::global(), bench, cfg, exec, contention, seed)
}

/// Run one benchmark with exclusive use of the cluster (the paper's
/// single-benchmark tuning setup).
pub fn run_benchmark(
    bench: Benchmark,
    cfg: &FlagConfig,
    exec: &ExecutorSpec,
    seed: u64,
) -> RunMetrics {
    run_benchmark_with_contention(bench, cfg, exec, 1.0, seed)
}

/// Run several (benchmark, config, fleet) jobs concurrently on `cluster`
/// (paper §V-E) and return each job's metrics.  Jobs fan out on `pool`;
/// each job's seed and contention factor depend only on its index, so the
/// result vector is identical at every pool width.
pub fn run_parallel_on(
    pool: &ExecPool,
    cluster: &ClusterSpec,
    jobs: &[(Benchmark, FlagConfig, ExecutorSpec)],
    seed: u64,
) -> Vec<RunMetrics> {
    let fleets: Vec<ExecutorSpec> = jobs.iter().map(|(_, _, e)| *e).collect();
    // The job fan-out owns the cores; each job's executors run serially.
    let inner = ExecPool::serial();
    pool.par_map(jobs, |i, (bench, cfg, exec)| {
        let contention = contention_factor(cluster, &fleets, i);
        run_benchmark_with_contention_on(
            &inner,
            *bench,
            cfg,
            exec,
            contention,
            seed ^ ((i as u64) << 32),
        )
    })
}

/// `run_parallel_on` on the process-global pool.
pub fn run_parallel(
    cluster: &ClusterSpec,
    jobs: &[(Benchmark, FlagConfig, ExecutorSpec)],
    seed: u64,
) -> Vec<RunMetrics> {
    run_parallel_on(exec::global(), cluster, jobs, seed)
}

/// Convenience handle bundling the cluster + fleet + benchmark + metric
/// used throughout the pipeline ("run the application and record the
/// metrics of interest", §III-A).
#[derive(Clone, Debug)]
pub struct SparkRunner {
    pub cluster: ClusterSpec,
    pub exec: ExecutorSpec,
    pub bench: Benchmark,
    /// Optional deterministic fault-injection plan.  `None` (the default)
    /// keeps the measurement path bit-identical to the fault-free runner.
    pub faults: Option<FaultPlan>,
}

impl SparkRunner {
    pub fn paper_default(bench: Benchmark) -> SparkRunner {
        let cluster = ClusterSpec::paper();
        let exec = ExecutorSpec::full_cluster(&cluster);
        SparkRunner { cluster, exec, bench, faults: None }
    }

    /// Builder-style: attach a fault plan to this runner.
    pub fn with_faults(mut self, plan: FaultPlan) -> SparkRunner {
        self.faults = Some(plan);
        self
    }

    /// Run on the process-global pool (per-executor fan-out) — right for
    /// sequential call sites (one-off runs, `/api/run`).
    pub fn run(&self, cfg: &FlagConfig, seed: u64) -> RunMetrics {
        run_benchmark(self.bench, cfg, &self.exec, seed)
    }

    /// Run with an explicit pool for the per-executor fan-out.  Callers
    /// already running *inside* a pool worker (batch labelling, repeated
    /// measurements) pass `ExecPool::serial()` here: the outer batch owns
    /// the cores, and nesting another fan-out per simulated run would just
    /// pay thread churn for oversubscription.  Results are identical
    /// either way.
    pub fn run_on(&self, pool: &ExecPool, cfg: &FlagConfig, seed: u64) -> RunMetrics {
        run_benchmark_with_contention_on(pool, self.bench, cfg, &self.exec, 1.0, seed)
    }

    /// `run_outcome_on` on the process-global pool.
    pub fn run_outcome(&self, cfg: &FlagConfig, seed: u64) -> RunOutcome {
        self.run_outcome_on(exec::global(), cfg, seed)
    }

    /// Failure-aware measurement: run `cfg`, applying the fault plan (if
    /// any) and its retry policy, and report a first-class [`RunOutcome`].
    ///
    /// * No plan: exactly one `run_on` — same RNG draws, same floats —
    ///   with any natural failure (OOM / wall-cap) reported as `Failed`
    ///   with `attempts == 1` (natural failures are deterministic in
    ///   (config, seed): retrying cannot help).
    /// * Plan with a matching crash-on-start region: the JVM refuses to
    ///   boot — deterministic, never retried, near-zero cost.
    /// * Plan, transient fault (injected crash / hang): retried with
    ///   capped exponential backoff while the attempt count stays within
    ///   `max_retries` and accumulated simulated time plus backoff stays
    ///   under `run_budget_s`.  Each attempt redraws the fault stream
    ///   (keyed by attempt index), so a retry can genuinely clear a
    ///   transient fault.  Backoff and earlier attempts are charged to the
    ///   final metrics' `wall_clock_s`.
    pub fn run_outcome_on(&self, pool: &ExecPool, cfg: &FlagConfig, seed: u64) -> RunOutcome {
        let Some(plan) = &self.faults else {
            return RunOutcome::from_metrics(self.run_on(pool, cfg, seed));
        };
        if plan.crashes_on_start(cfg) {
            let metrics = RunMetrics {
                exec_time_s: MAX_WALL_S + DRIVER_OVERHEAD_S,
                wall_clock_s: DRIVER_OVERHEAD_S,
                hu_avg_pct: 0.0,
                gc: GcStats::default(),
                failure: Some(FailureKind::Crash),
            };
            return RunOutcome::Failed { kind: FailureKind::Crash, attempts: 1, metrics };
        }
        let mut attempt = 1u32;
        let mut spent_s = 0.0;
        loop {
            let mut m = run_attempt(
                pool,
                self.bench,
                cfg,
                &self.exec,
                1.0,
                seed,
                Some((plan, attempt)),
            );
            spent_s += m.wall_clock_s;
            let Some(kind) = m.failure else {
                m.wall_clock_s = spent_s;
                return RunOutcome::Ok(m);
            };
            let backoff = plan.backoff_s(attempt);
            if plan.is_transient(kind)
                && attempt <= plan.max_retries
                && spent_s + backoff < plan.run_budget_s
            {
                spent_s += backoff;
                attempt += 1;
                continue;
            }
            m.wall_clock_s = spent_s;
            return RunOutcome::Failed { kind, attempts: attempt, metrics: m };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flags::GcMode;
    use crate::sparksim::fault::CrashRegion;

    #[test]
    fn default_runs_land_in_expected_band() {
        // Defaults should produce O(100 s) jobs, not milliseconds or hours.
        for bench in Benchmark::all() {
            for mode in [GcMode::ParallelGC, GcMode::G1GC] {
                let r = SparkRunner::paper_default(bench)
                    .run(&FlagConfig::default_for(mode), 7);
                assert!(
                    r.exec_time_s > 40.0 && r.exec_time_s < 600.0,
                    "{} {}: {}",
                    bench.name(),
                    mode.name(),
                    r.exec_time_s
                );
                assert!(!r.failed());
            }
        }
    }

    #[test]
    fn dk_parallelgc_is_gc_bound_by_default() {
        let r = SparkRunner::paper_default(Benchmark::DenseKMeans)
            .run(&FlagConfig::default_for(GcMode::ParallelGC), 11);
        assert!(r.gc.full >= 2, "expected full-GC pressure: {:?}", r.gc);
    }

    #[test]
    fn dk_g1_avoids_full_gcs_by_default() {
        let r = SparkRunner::paper_default(Benchmark::DenseKMeans)
            .run(&FlagConfig::default_for(GcMode::G1GC), 11);
        assert!(r.gc.full <= 1, "G1 default should not thrash: {:?}", r.gc);
    }

    #[test]
    fn deterministic_in_seed() {
        let runner = SparkRunner::paper_default(Benchmark::Lda);
        let cfg = FlagConfig::default_for(GcMode::G1GC);
        let a = runner.run(&cfg, 42);
        let b = runner.run(&cfg, 42);
        assert_eq!(a.exec_time_s, b.exec_time_s);
        let c = runner.run(&cfg, 43);
        assert_ne!(a.exec_time_s, c.exec_time_s);
    }

    #[test]
    fn tuned_heap_beats_default_for_dk_parallel() {
        let runner = SparkRunner::paper_default(Benchmark::DenseKMeans);
        let default = FlagConfig::default_for(GcMode::ParallelGC);
        let mut tuned = default.clone();
        tuned.set("MaxHeapSize", 32768.0);
        tuned.set("ParallelGCThreads", 20.0);
        let rd: f64 = (0..5)
            .map(|s| runner.run(&default, s).exec_time_s)
            .sum::<f64>()
            / 5.0;
        let rt: f64 = (0..5).map(|s| runner.run(&tuned, s).exec_time_s).sum::<f64>() / 5.0;
        assert!(
            rt < rd * 0.9,
            "tuned {rt} should be well below default {rd}"
        );
    }

    #[test]
    fn parallel_jobs_slower_than_exclusive() {
        let cluster = ClusterSpec::paper();
        let cfg = FlagConfig::default_for(GcMode::G1GC);
        let exclusive = run_benchmark(
            Benchmark::Lda,
            &cfg,
            &ExecutorSpec::full_cluster(&cluster),
            3,
        );
        let jobs = vec![
            (Benchmark::Lda, cfg.clone(), ExecutorSpec::parallel_2x15()),
            (Benchmark::DenseKMeans, cfg.clone(), ExecutorSpec::parallel_2x15()),
        ];
        let rs = run_parallel(&cluster, &jobs, 3);
        assert_eq!(rs.len(), 2);
        assert!(
            rs[0].exec_time_s > exclusive.exec_time_s,
            "{} vs {}",
            rs[0].exec_time_s,
            exclusive.exec_time_s
        );
    }

    #[test]
    fn hu_metric_in_bounds() {
        let r = SparkRunner::paper_default(Benchmark::Lda)
            .run(&FlagConfig::default_for(GcMode::G1GC), 5);
        assert!(r.hu_avg_pct > 1.0 && r.hu_avg_pct < 100.0, "{}", r.hu_avg_pct);
    }

    #[test]
    fn no_plan_outcome_is_bitwise_the_plain_run() {
        let runner = SparkRunner::paper_default(Benchmark::PageRank);
        let cfg = FlagConfig::default_for(GcMode::G1GC);
        let plain = runner.run(&cfg, 31);
        let out = runner.run_outcome(&cfg, 31);
        assert!(out.is_ok());
        assert_eq!(out.attempts(), 1);
        assert_eq!(*out.metrics(), plain);
    }

    #[test]
    fn natural_oom_is_failed_without_retries() {
        // A config whose live set cannot fit OOMs deterministically; even
        // a retry-happy plan must not retry it.
        let plan = FaultPlan { max_retries: 5, ..Default::default() };
        let runner = SparkRunner::paper_default(Benchmark::DenseKMeans).with_faults(plan);
        let mut cfg = FlagConfig::default_for(GcMode::ParallelGC);
        cfg.set("MaxHeapSize", 2048.0);
        let out = runner.run_outcome(&cfg, 7);
        assert_eq!(out.failure(), Some(FailureKind::Oom), "{out:?}");
        assert_eq!(out.attempts(), 1);
        assert_eq!(out.metrics().exec_time_s, MAX_WALL_S + DRIVER_OVERHEAD_S);
    }

    #[test]
    fn crash_region_fails_fast_and_is_never_retried() {
        let plan = FaultPlan {
            crash_regions: vec![CrashRegion {
                flag: "MaxHeapSize".to_string(),
                lo: 0.0,
                hi: 1.0,
            }],
            max_retries: 3,
            ..Default::default()
        };
        let runner = SparkRunner::paper_default(Benchmark::Lda).with_faults(plan);
        let out = runner.run_outcome(&FlagConfig::default_for(GcMode::G1GC), 1);
        assert_eq!(out.failure(), Some(FailureKind::Crash));
        assert_eq!(out.attempts(), 1);
        // The JVM never booted: near-zero wall, full exec-time penalty.
        assert_eq!(out.metrics().wall_clock_s, DRIVER_OVERHEAD_S);
        assert_eq!(out.metrics().exec_time_s, MAX_WALL_S + DRIVER_OVERHEAD_S);
    }

    #[test]
    fn certain_crash_exhausts_retries_with_backoff_charged() {
        let plan = FaultPlan {
            seed: 5,
            crash_p: 1.0,
            max_retries: 2,
            backoff_base_s: 5.0,
            run_budget_s: 50_000.0,
            ..Default::default()
        };
        let runner = SparkRunner::paper_default(Benchmark::Lda).with_faults(plan);
        let cfg = FlagConfig::default_for(GcMode::G1GC);
        let out = runner.run_outcome(&cfg, 9);
        assert_eq!(out.failure(), Some(FailureKind::Crash));
        assert_eq!(out.attempts(), 3, "2 retries => 3 attempts");
        // wall_clock_s charges all attempts plus the 5 + 10 s of backoff.
        assert!(out.metrics().wall_clock_s > 15.0, "{}", out.metrics().wall_clock_s);
    }

    #[test]
    fn certain_hang_respects_run_budget() {
        // Every attempt hangs (~1.5x MAX_WALL_S); a budget of 2x MAX_WALL_S
        // cannot afford a second attempt, whatever max_retries says.
        let plan = FaultPlan {
            seed: 6,
            hang_p: 1.0,
            max_retries: 5,
            run_budget_s: 2.0 * MAX_WALL_S,
            ..Default::default()
        };
        let runner = SparkRunner::paper_default(Benchmark::Lda).with_faults(plan);
        let out = runner.run_outcome(&FlagConfig::default_for(GcMode::G1GC), 13);
        assert_eq!(out.failure(), Some(FailureKind::Hang));
        assert_eq!(out.attempts(), 1);
        assert!(out.metrics().wall_clock_s > MAX_WALL_S);
    }

    #[test]
    fn retry_can_clear_a_transient_crash() {
        // With a moderate crash rate, some seeds fail outright while
        // others clear on retry — both must occur across a seed sweep,
        // and every outcome must be reproducible.
        let plan = FaultPlan { seed: 21, crash_p: 0.2, max_retries: 2, ..Default::default() };
        let runner = SparkRunner::paper_default(Benchmark::Lda).with_faults(plan);
        let cfg = FlagConfig::default_for(GcMode::G1GC);
        let outcomes: Vec<RunOutcome> =
            (0..100u64).map(|s| runner.run_outcome(&cfg, s)).collect();
        assert!(outcomes.iter().any(|o| o.is_ok()), "no run ever succeeded");
        assert!(outcomes.iter().any(|o| !o.is_ok()), "no run ever exhausted retries");
        for (s, o) in outcomes.iter().enumerate() {
            assert_eq!(*o, runner.run_outcome(&cfg, s as u64), "seed {s} not reproducible");
        }
    }

    #[test]
    fn spikes_slow_the_run_without_failing_it() {
        let spiky = FaultPlan { seed: 2, spike_p: 1.0, spike_mult: 1.5, ..Default::default() };
        let runner = SparkRunner::paper_default(Benchmark::PageRank);
        let cfg = FlagConfig::default_for(GcMode::G1GC);
        let base = runner.run_outcome(&cfg, 17);
        let spiked = runner.clone().with_faults(spiky).run_outcome(&cfg, 17);
        assert!(base.is_ok() && spiked.is_ok());
        assert!(
            spiked.metrics().exec_time_s > base.metrics().exec_time_s * 1.3,
            "spike {} vs base {}",
            spiked.metrics().exec_time_s,
            base.metrics().exec_time_s
        );
    }
}
