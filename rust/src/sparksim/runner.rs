//! Job runner: executes a benchmark (or several in parallel) on the
//! simulated cluster and reports the metrics the tuner optimizes.
//!
//! This is the objective function Q of the paper's eq. (1): flag config in,
//! (execution time, heap usage %) out.

use super::cluster::{contention_factor, ClusterSpec, ExecutorSpec};
use super::workloads::Benchmark;
use crate::exec::{self, ExecPool};
use crate::flags::FlagConfig;
use crate::jvmsim::{self, GcStats, JvmParams};
use crate::util::rng::Pcg;

/// Metrics recorded for one benchmark run (paper §IV-B).
#[derive(Clone, Debug, PartialEq)]
pub struct RunMetrics {
    /// Job execution time.  Failed runs (OOM / GC-thrash timeout) report
    /// the timeout budget — a failed configuration can never look fast.
    pub exec_time_s: f64,
    /// Actual simulated wall-clock (short for an OOM crash) — what tuning
    /// time accounting should charge.
    pub wall_clock_s: f64,
    pub hu_avg_pct: f64,
    pub gc: GcStats,
    pub timed_out: bool,
}

/// Fixed driver-side overhead per Spark job (scheduling, result collection).
const DRIVER_OVERHEAD_S: f64 = 2.0;

/// Run `bench` with `cfg` on a fleet, under an external contention factor
/// (1.0 = exclusive cluster), with the per-executor JVM simulations fanned
/// out on `pool`.  Deterministic in `seed` and independent of the pool
/// width: every executor's RNG is forked from the job stream *before*
/// dispatch (fork order is the serial loop's), and the metrics are reduced
/// in executor order, so pool size 1 and N produce bit-identical results.
pub fn run_benchmark_with_contention_on(
    pool: &ExecPool,
    bench: Benchmark,
    cfg: &FlagConfig,
    exec: &ExecutorSpec,
    contention: f64,
    seed: u64,
) -> RunMetrics {
    let mut p = JvmParams::derive(cfg, exec.mem_mb, exec.cores as f64);
    let load = bench.executor_load(exec.count);
    let cores_eff = exec.cores as f64 * contention;
    // Co-located jobs also contend for memory bandwidth during STW
    // collections: GC copy/compact rates degrade super-linearly with the
    // contention factor, which is why flag tuning pays off *more* in the
    // shared-cluster scenarios (paper SectionV-E).
    if contention < 1.0 {
        let gc_penalty = contention.powf(0.7);
        p.copy_rate *= gc_penalty;
        p.compact_rate *= gc_penalty;
    }

    let mut rng = Pcg::with_stream(seed, 0x5eed_0001);
    let erngs: Vec<Pcg> = (0..exec.count).map(|e| rng.fork(e as u64 + 1)).collect();
    let results = pool.par_map(&erngs, |_, erng| {
        let mut erng = erng.clone();
        jvmsim::run(&p, &load, cores_eff, &mut erng)
    });

    let mut worst_wall = 0.0f64;
    let mut hu_sum = 0.0;
    let mut gc = GcStats::default();
    let mut timed_out = false;
    for r in &results {
        worst_wall = worst_wall.max(r.wall_s);
        hu_sum += r.hu_avg_pct;
        gc.minor += r.gc.minor;
        gc.mixed += r.gc.mixed;
        gc.full += r.gc.full;
        gc.conc_cycles += r.gc.conc_cycles;
        gc.total_pause_ms += r.gc.total_pause_ms;
        gc.max_pause_ms = gc.max_pause_ms.max(r.gc.max_pause_ms);
        timed_out |= r.timed_out;
    }

    let wall_clock_s = worst_wall + DRIVER_OVERHEAD_S;
    RunMetrics {
        exec_time_s: if timed_out {
            crate::jvmsim::MAX_WALL_S + DRIVER_OVERHEAD_S
        } else {
            wall_clock_s
        },
        wall_clock_s,
        hu_avg_pct: hu_sum / exec.count.max(1) as f64,
        gc,
        timed_out,
    }
}

/// `run_benchmark_with_contention_on` on the process-global pool.
pub fn run_benchmark_with_contention(
    bench: Benchmark,
    cfg: &FlagConfig,
    exec: &ExecutorSpec,
    contention: f64,
    seed: u64,
) -> RunMetrics {
    run_benchmark_with_contention_on(exec::global(), bench, cfg, exec, contention, seed)
}

/// Run one benchmark with exclusive use of the cluster (the paper's
/// single-benchmark tuning setup).
pub fn run_benchmark(
    bench: Benchmark,
    cfg: &FlagConfig,
    exec: &ExecutorSpec,
    seed: u64,
) -> RunMetrics {
    run_benchmark_with_contention(bench, cfg, exec, 1.0, seed)
}

/// Run several (benchmark, config, fleet) jobs concurrently on `cluster`
/// (paper §V-E) and return each job's metrics.  Jobs fan out on `pool`;
/// each job's seed and contention factor depend only on its index, so the
/// result vector is identical at every pool width.
pub fn run_parallel_on(
    pool: &ExecPool,
    cluster: &ClusterSpec,
    jobs: &[(Benchmark, FlagConfig, ExecutorSpec)],
    seed: u64,
) -> Vec<RunMetrics> {
    let fleets: Vec<ExecutorSpec> = jobs.iter().map(|(_, _, e)| *e).collect();
    // The job fan-out owns the cores; each job's executors run serially.
    let inner = ExecPool::serial();
    pool.par_map(jobs, |i, (bench, cfg, exec)| {
        let contention = contention_factor(cluster, &fleets, i);
        run_benchmark_with_contention_on(
            &inner,
            *bench,
            cfg,
            exec,
            contention,
            seed ^ ((i as u64) << 32),
        )
    })
}

/// `run_parallel_on` on the process-global pool.
pub fn run_parallel(
    cluster: &ClusterSpec,
    jobs: &[(Benchmark, FlagConfig, ExecutorSpec)],
    seed: u64,
) -> Vec<RunMetrics> {
    run_parallel_on(exec::global(), cluster, jobs, seed)
}

/// Convenience handle bundling the cluster + fleet + benchmark + metric
/// used throughout the pipeline ("run the application and record the
/// metrics of interest", §III-A).
#[derive(Clone, Debug)]
pub struct SparkRunner {
    pub cluster: ClusterSpec,
    pub exec: ExecutorSpec,
    pub bench: Benchmark,
}

impl SparkRunner {
    pub fn paper_default(bench: Benchmark) -> SparkRunner {
        let cluster = ClusterSpec::paper();
        let exec = ExecutorSpec::full_cluster(&cluster);
        SparkRunner { cluster, exec, bench }
    }

    /// Run on the process-global pool (per-executor fan-out) — right for
    /// sequential call sites (one-off runs, `/api/run`).
    pub fn run(&self, cfg: &FlagConfig, seed: u64) -> RunMetrics {
        run_benchmark(self.bench, cfg, &self.exec, seed)
    }

    /// Run with an explicit pool for the per-executor fan-out.  Callers
    /// already running *inside* a pool worker (batch labelling, repeated
    /// measurements) pass `ExecPool::serial()` here: the outer batch owns
    /// the cores, and nesting another fan-out per simulated run would just
    /// pay thread churn for oversubscription.  Results are identical
    /// either way.
    pub fn run_on(&self, pool: &ExecPool, cfg: &FlagConfig, seed: u64) -> RunMetrics {
        run_benchmark_with_contention_on(pool, self.bench, cfg, &self.exec, 1.0, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flags::GcMode;

    #[test]
    fn default_runs_land_in_expected_band() {
        // Defaults should produce O(100 s) jobs, not milliseconds or hours.
        for bench in Benchmark::all() {
            for mode in [GcMode::ParallelGC, GcMode::G1GC] {
                let r = SparkRunner::paper_default(bench)
                    .run(&FlagConfig::default_for(mode), 7);
                assert!(
                    r.exec_time_s > 40.0 && r.exec_time_s < 600.0,
                    "{} {}: {}",
                    bench.name(),
                    mode.name(),
                    r.exec_time_s
                );
                assert!(!r.timed_out);
            }
        }
    }

    #[test]
    fn dk_parallelgc_is_gc_bound_by_default() {
        let r = SparkRunner::paper_default(Benchmark::DenseKMeans)
            .run(&FlagConfig::default_for(GcMode::ParallelGC), 11);
        assert!(r.gc.full >= 2, "expected full-GC pressure: {:?}", r.gc);
    }

    #[test]
    fn dk_g1_avoids_full_gcs_by_default() {
        let r = SparkRunner::paper_default(Benchmark::DenseKMeans)
            .run(&FlagConfig::default_for(GcMode::G1GC), 11);
        assert!(r.gc.full <= 1, "G1 default should not thrash: {:?}", r.gc);
    }

    #[test]
    fn deterministic_in_seed() {
        let runner = SparkRunner::paper_default(Benchmark::Lda);
        let cfg = FlagConfig::default_for(GcMode::G1GC);
        let a = runner.run(&cfg, 42);
        let b = runner.run(&cfg, 42);
        assert_eq!(a.exec_time_s, b.exec_time_s);
        let c = runner.run(&cfg, 43);
        assert_ne!(a.exec_time_s, c.exec_time_s);
    }

    #[test]
    fn tuned_heap_beats_default_for_dk_parallel() {
        let runner = SparkRunner::paper_default(Benchmark::DenseKMeans);
        let default = FlagConfig::default_for(GcMode::ParallelGC);
        let mut tuned = default.clone();
        tuned.set("MaxHeapSize", 32768.0);
        tuned.set("ParallelGCThreads", 20.0);
        let rd: f64 = (0..5)
            .map(|s| runner.run(&default, s).exec_time_s)
            .sum::<f64>()
            / 5.0;
        let rt: f64 = (0..5).map(|s| runner.run(&tuned, s).exec_time_s).sum::<f64>() / 5.0;
        assert!(
            rt < rd * 0.9,
            "tuned {rt} should be well below default {rd}"
        );
    }

    #[test]
    fn parallel_jobs_slower_than_exclusive() {
        let cluster = ClusterSpec::paper();
        let cfg = FlagConfig::default_for(GcMode::G1GC);
        let exclusive = run_benchmark(
            Benchmark::Lda,
            &cfg,
            &ExecutorSpec::full_cluster(&cluster),
            3,
        );
        let jobs = vec![
            (Benchmark::Lda, cfg.clone(), ExecutorSpec::parallel_2x15()),
            (Benchmark::DenseKMeans, cfg.clone(), ExecutorSpec::parallel_2x15()),
        ];
        let rs = run_parallel(&cluster, &jobs, 3);
        assert_eq!(rs.len(), 2);
        assert!(
            rs[0].exec_time_s > exclusive.exec_time_s,
            "{} vs {}",
            rs[0].exec_time_s,
            exclusive.exec_time_s
        );
    }

    #[test]
    fn hu_metric_in_bounds() {
        let r = SparkRunner::paper_default(Benchmark::Lda)
            .run(&FlagConfig::default_for(GcMode::G1GC), 5);
        assert!(r.hu_avg_pct > 1.0 && r.hu_avg_pct < 100.0, "{}", r.hu_avg_pct);
    }
}
