//! Deterministic, seeded fault injection for the measurement pipeline.
//!
//! A [`FaultPlan`] describes the fault mix a `SparkRunner` should suffer:
//! crash-on-start regions of the flag space (deterministic — a config in
//! the region *always* refuses to start), transient per-executor crashes
//! and stragglers/hangs with configured probabilities, and benign noise
//! spikes that inflate a run's wall time without failing it.
//!
//! Every injected decision is a **pure function of indices**: the plan
//! seed, the run's own seed, the retry attempt, and the executor index
//! feed a dedicated [`Pcg`] stream that is constructed only when a plan
//! is active and never touches the simulator's run stream.  Results are
//! therefore bit-identical at any `ExecPool` width (the exec-module
//! determinism invariant), reproducible from the job seed alone, and a
//! runner with no plan consumes *exactly* the RNG draws it always did.
//!
//! The plan also owns the retry policy: transient faults (injected
//! crashes and hangs) are retried with capped exponential backoff under
//! a per-run simulated-time budget, while deterministic failures (OOM,
//! wall-cap, crash-on-start regions) are never retried — see
//! `SparkRunner::run_outcome_on`.

use crate::flags::{catalog, FlagConfig};
use crate::jvmsim::{FailureKind, MAX_WALL_S};
use crate::util::rng::{splitmix64, Pcg};

/// RNG stream selector for fault decisions — distinct from the run
/// stream (`0x5eed_0001`) so injection never perturbs simulation draws.
const FAULT_STREAM: u64 = 0xfa_0175_eed;

/// A deterministic crash-on-start region: configs whose `flag` sits in
/// `[lo, hi]` of that flag's normalized [0,1] range refuse to start
/// (think: a heap size the container rejects, a flag combination the JVM
/// bails on during argument parsing).
#[derive(Clone, Debug, PartialEq)]
pub struct CrashRegion {
    pub flag: String,
    pub lo: f64,
    pub hi: f64,
}

impl CrashRegion {
    /// Does `cfg` fall inside this region?  Unknown flag names never
    /// match (validated plans reject them up front).
    pub fn matches(&self, cfg: &FlagConfig) -> bool {
        let Some((_, def)) = catalog::flag_by_name(&self.flag) else {
            return false;
        };
        let u = def.normalize(cfg.get(&self.flag));
        u >= self.lo && u <= self.hi
    }
}

/// The fault mix injected into a `SparkRunner`'s measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed the fault stream derives from (mixed with each run's seed).
    pub seed: u64,
    /// Deterministic crash-on-start flag regions.
    pub crash_regions: Vec<CrashRegion>,
    /// Per-executor transient crash probability per attempt.
    pub crash_p: f64,
    /// Per-executor transient straggler/hang probability per attempt.
    pub hang_p: f64,
    /// Per-executor noise-spike probability (benign slowdown, no failure).
    pub spike_p: f64,
    /// Wall-time multiplier a spiked executor suffers (> 1).
    pub spike_mult: f64,
    /// Retry cap for transient faults (0 = never retry).
    pub max_retries: u32,
    /// First-retry backoff in simulated seconds; doubles per attempt.
    pub backoff_base_s: f64,
    /// Backoff ceiling in simulated seconds.
    pub backoff_cap_s: f64,
    /// Per-run budget: total simulated seconds (attempts + backoff) a
    /// single measurement may consume before retries stop.
    pub run_budget_s: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            crash_regions: Vec::new(),
            crash_p: 0.0,
            hang_p: 0.0,
            spike_p: 0.0,
            spike_mult: 1.5,
            max_retries: 2,
            backoff_base_s: 5.0,
            backoff_cap_s: 60.0,
            run_budget_s: 3.0 * MAX_WALL_S,
        }
    }
}

impl FaultPlan {
    /// Reject malformed plans with a human-readable reason (the REST
    /// layer maps this to a 400).
    pub fn validate(&self) -> Result<(), String> {
        for (name, p) in
            [("crash_p", self.crash_p), ("hang_p", self.hang_p), ("spike_p", self.spike_p)]
        {
            if !(0.0..=1.0).contains(&p) || !p.is_finite() {
                return Err(format!("{name} must be a probability in [0,1], got {p}"));
            }
        }
        if !self.spike_mult.is_finite() || self.spike_mult < 1.0 {
            return Err(format!("spike_mult must be >= 1, got {}", self.spike_mult));
        }
        if !self.backoff_base_s.is_finite()
            || self.backoff_base_s < 0.0
            || !self.backoff_cap_s.is_finite()
            || self.backoff_cap_s < self.backoff_base_s
        {
            return Err("backoff must satisfy 0 <= base <= cap".to_string());
        }
        if !self.run_budget_s.is_finite() || self.run_budget_s <= 0.0 {
            return Err(format!("run_budget_s must be positive, got {}", self.run_budget_s));
        }
        for r in &self.crash_regions {
            if catalog::flag_by_name(&r.flag).is_none() {
                return Err(format!("crash region names unknown flag '{}'", r.flag));
            }
            if !(0.0..=1.0).contains(&r.lo) || !(0.0..=1.0).contains(&r.hi) || r.lo > r.hi {
                return Err(format!(
                    "crash region for '{}' needs 0 <= lo <= hi <= 1, got [{}, {}]",
                    r.flag, r.lo, r.hi
                ));
            }
        }
        Ok(())
    }

    /// Deterministic crash-on-start: is `cfg` inside any crash region?
    pub fn crashes_on_start(&self, cfg: &FlagConfig) -> bool {
        self.crash_regions.iter().any(|r| r.matches(cfg))
    }

    /// The fault stream for one (run, attempt, executor) cell — a pure
    /// function of those indices plus the plan seed, so decisions are
    /// identical at any pool width and reproducible from the job seed.
    fn cell_rng(&self, run_seed: u64, attempt: u32, exec_idx: usize) -> Pcg {
        let cell = ((attempt as u64) << 32) | exec_idx as u64;
        let s = splitmix64(self.seed ^ splitmix64(run_seed)) ^ splitmix64(cell.wrapping_add(1));
        Pcg::with_stream(splitmix64(s), FAULT_STREAM)
    }

    /// Transient-fault decision for one executor of one attempt, as
    /// `(failure, adjusted_wall_s)`: a crashed executor died a fraction
    /// of the way through its work, a hung one sat past the wall cap,
    /// a spiked one finished late without failing, and an untouched one
    /// keeps its wall time.
    pub fn executor_fault(
        &self,
        run_seed: u64,
        attempt: u32,
        exec_idx: usize,
        exec_wall_s: f64,
    ) -> (Option<FailureKind>, f64) {
        let mut rng = self.cell_rng(run_seed, attempt, exec_idx);
        // Fixed draw order (crash, hang, spike) keeps the stream layout
        // stable however the probabilities are configured.
        let crash_u = rng.f64();
        let hang_u = rng.f64();
        let spike_u = rng.f64();
        let frac = rng.uniform(0.05, 0.6);
        if crash_u < self.crash_p {
            // Died a fraction of the way through its work.
            return (Some(FailureKind::Crash), (exec_wall_s * frac).max(1.0));
        }
        if hang_u < self.hang_p {
            // Straggler: sat past the harness timeout without finishing.
            return (Some(FailureKind::Hang), MAX_WALL_S * (1.0 + 0.5 * frac));
        }
        if spike_u < self.spike_p {
            return (None, exec_wall_s * self.spike_mult);
        }
        (None, exec_wall_s)
    }

    /// Is an observed failure worth retrying under this plan?  Injected
    /// crashes/hangs are transient (a retry redraws the fault stream);
    /// OOM and wall-cap come from the simulator deterministically.
    pub fn is_transient(&self, kind: FailureKind) -> bool {
        matches!(kind, FailureKind::Crash | FailureKind::Hang)
    }

    /// Capped exponential backoff before retry `attempt` (1-based:
    /// attempt 1 is the first *retry*).
    pub fn backoff_s(&self, attempt: u32) -> f64 {
        let factor = 2f64.powi(attempt.saturating_sub(1).min(16) as i32);
        (self.backoff_base_s * factor).min(self.backoff_cap_s)
    }
}

/// Per-kind failure counters — the histogram a tuning job accumulates
/// and the REST job record reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FailureHisto {
    pub crash: usize,
    pub oom: usize,
    pub wall_cap: usize,
    pub hang: usize,
}

impl FailureHisto {
    pub fn record(&mut self, kind: FailureKind) {
        match kind {
            FailureKind::Crash => self.crash += 1,
            FailureKind::Oom => self.oom += 1,
            FailureKind::WallCap => self.wall_cap += 1,
            FailureKind::Hang => self.hang += 1,
        }
    }

    pub fn count(&self, kind: FailureKind) -> usize {
        match kind {
            FailureKind::Crash => self.crash,
            FailureKind::Oom => self.oom,
            FailureKind::WallCap => self.wall_cap,
            FailureKind::Hang => self.hang,
        }
    }

    pub fn total(&self) -> usize {
        self.crash + self.oom + self.wall_cap + self.hang
    }

    pub fn is_empty(&self) -> bool {
        self.total() == 0
    }

    pub fn merge(&mut self, other: &FailureHisto) {
        self.crash += other.crash;
        self.oom += other.oom;
        self.wall_cap += other.wall_cap;
        self.hang += other.hang;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flags::GcMode;

    #[test]
    fn executor_fault_is_deterministic_per_cell() {
        let plan = FaultPlan { seed: 9, crash_p: 0.3, hang_p: 0.2, spike_p: 0.3, ..Default::default() };
        for run_seed in [1u64, 77, 0xbeef] {
            for attempt in [1u32, 2] {
                for e in 0..6usize {
                    let a = plan.executor_fault(run_seed, attempt, e, 100.0);
                    let b = plan.executor_fault(run_seed, attempt, e, 100.0);
                    assert_eq!(a, b, "cell ({run_seed},{attempt},{e}) not pure");
                }
            }
        }
        // ... and neighbouring cells decorrelate: not all identical.
        let outcomes: Vec<_> =
            (0..32).map(|e| plan.executor_fault(1, 1, e, 100.0).0).collect();
        assert!(outcomes.iter().any(|o| o.is_some()));
        assert!(outcomes.iter().any(|o| o.is_none()));
    }

    #[test]
    fn fault_rates_track_probabilities() {
        let plan = FaultPlan { seed: 3, crash_p: 0.25, hang_p: 0.0, ..Default::default() };
        let n = 2000;
        let crashes = (0..n)
            .filter(|&e| {
                matches!(plan.executor_fault(5, 1, e, 100.0).0, Some(FailureKind::Crash))
            })
            .count();
        let rate = crashes as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.05, "crash rate {rate}");
    }

    #[test]
    fn retry_redraws_the_fault_stream() {
        // A transient fault at attempt 1 must be able to clear at
        // attempt 2: the decisions across attempts are independent.
        let plan = FaultPlan { seed: 11, crash_p: 0.5, ..Default::default() };
        let cleared = (0..200usize).any(|e| {
            plan.executor_fault(1, 1, e, 100.0).0.is_some()
                && plan.executor_fault(1, 2, e, 100.0).0.is_none()
        });
        assert!(cleared, "attempt index never changed a fault decision");
    }

    #[test]
    fn crash_region_matches_unit_interval() {
        let region =
            CrashRegion { flag: "MaxHeapSize".to_string(), lo: 0.0, hi: 0.10 };
        let mut cfg = FlagConfig::default_for(GcMode::ParallelGC);
        cfg.set("MaxHeapSize", 1024.0); // bottom of the range
        assert!(region.matches(&cfg));
        cfg.set("MaxHeapSize", 65536.0); // top of the range
        assert!(!region.matches(&cfg));
        // Unknown flags never match (and fail validation).
        let bogus = CrashRegion { flag: "NoSuchFlag".into(), lo: 0.0, hi: 1.0 };
        assert!(!bogus.matches(&cfg));
        let plan = FaultPlan { crash_regions: vec![bogus], ..Default::default() };
        assert!(plan.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_probabilities_and_regions() {
        assert!(FaultPlan::default().validate().is_ok());
        assert!(FaultPlan { crash_p: 1.5, ..Default::default() }.validate().is_err());
        assert!(FaultPlan { spike_mult: 0.5, ..Default::default() }.validate().is_err());
        assert!(FaultPlan { run_budget_s: 0.0, ..Default::default() }.validate().is_err());
        let bad = FaultPlan {
            crash_regions: vec![CrashRegion { flag: "MaxHeapSize".into(), lo: 0.7, hi: 0.2 }],
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn backoff_is_capped_exponential() {
        let plan =
            FaultPlan { backoff_base_s: 5.0, backoff_cap_s: 60.0, ..Default::default() };
        assert_eq!(plan.backoff_s(1), 5.0);
        assert_eq!(plan.backoff_s(2), 10.0);
        assert_eq!(plan.backoff_s(3), 20.0);
        assert_eq!(plan.backoff_s(5), 60.0); // capped
        assert_eq!(plan.backoff_s(30), 60.0); // exponent clamped, no overflow
    }

    #[test]
    fn histogram_counts_by_kind() {
        let mut h = FailureHisto::default();
        assert!(h.is_empty());
        h.record(FailureKind::Crash);
        h.record(FailureKind::Crash);
        h.record(FailureKind::Oom);
        h.record(FailureKind::Hang);
        assert_eq!(h.total(), 4);
        assert_eq!(h.count(FailureKind::Crash), 2);
        assert_eq!(h.count(FailureKind::WallCap), 0);
        let mut m = FailureHisto::default();
        m.record(FailureKind::WallCap);
        m.merge(&h);
        assert_eq!(m.total(), 5);
        assert_eq!(m.wall_cap, 1);
    }
}
