//! The two HiBench workloads of the paper's Table I, as mutator models.
//!
//! Calibration targets (shape, not absolute numbers — see DESIGN.md):
//!   * DenseKMeans under ParallelGC defaults is GC-bound (72 GB input,
//!     1915 tasks, frequent long full-GC pauses) -> large tuning headroom.
//!   * DenseKMeans under G1GC defaults is already fine -> ~1.0x headroom.
//!   * LDA gains come from JIT warmup + compiler + young-gen sizing.

use crate::jvmsim::MutatorLoad;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Benchmark {
    /// HiBench LDAExample, large profile: 10 000 documents,
    /// spark.driver.maxResultSize = 3 GB.
    Lda,
    /// HiBench DenseKMeans, large profile: 20 M samples, 20 dimensions
    /// (72 GB input, 1915 tasks).
    DenseKMeans,
}

/// Cluster-level workload description (split across executors at run time).
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub name: &'static str,
    pub dataset: &'static str,
    pub input_gb: f64,
    pub n_tasks: usize,
    /// Total compute demand over the whole cluster, core-seconds.
    pub total_work_core_s: f64,
    /// Total long-lived data (cached input + model state), MB.
    pub total_live_mb: f64,
    pub alloc_mb_per_core_s: f64,
    pub cache_work_frac: f64,
    pub young_survival: f64,
    pub promote_frac: f64,
    pub humongous_mb_per_core_s: f64,
}

impl Benchmark {
    pub fn spec(self) -> WorkloadSpec {
        match self {
            Benchmark::Lda => WorkloadSpec {
                name: "LDA",
                dataset: "HiBench LDAExample, large, 10000 documents, maxResultSize 3GB",
                input_gb: 38.0,
                n_tasks: 1200,
                total_work_core_s: 5100.0,
                total_live_mb: 15_000.0,
                alloc_mb_per_core_s: 150.0,
                cache_work_frac: 0.25,
                young_survival: 0.09,
                promote_frac: 0.16,
                humongous_mb_per_core_s: 1.5,
            },
            Benchmark::DenseKMeans => WorkloadSpec {
                name: "DenseKMeans",
                dataset: "DenseKMeans, HiBench, large, 20M samples, 20 dimensions",
                input_gb: 72.0,
                n_tasks: 1915,
                total_work_core_s: 6200.0,
                total_live_mb: 36_000.0,
                alloc_mb_per_core_s: 135.0,
                cache_work_frac: 0.45,
                young_survival: 0.11,
                promote_frac: 0.28,
                humongous_mb_per_core_s: 0.6,
            },
        }
    }

    pub fn name(self) -> &'static str {
        self.spec().name
    }

    pub fn parse(s: &str) -> Option<Benchmark> {
        match s.to_ascii_lowercase().as_str() {
            "lda" => Some(Benchmark::Lda),
            "densekmeans" | "dk" | "kmeans" => Some(Benchmark::DenseKMeans),
            _ => None,
        }
    }

    pub fn all() -> [Benchmark; 2] {
        [Benchmark::Lda, Benchmark::DenseKMeans]
    }

    /// Per-executor mutator load for a fleet of `n_exec` executors.
    pub fn executor_load(self, n_exec: usize) -> MutatorLoad {
        let s = self.spec();
        let n = n_exec.max(1) as f64;
        MutatorLoad {
            work_core_s: s.total_work_core_s / n,
            alloc_mb_per_core_s: s.alloc_mb_per_core_s,
            live_mb: s.total_live_mb / n,
            cache_work_frac: s.cache_work_frac,
            young_survival: s.young_survival,
            promote_frac: s.promote_frac,
            humongous_mb_per_core_s: s.humongous_mb_per_core_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_metadata() {
        let lda = Benchmark::Lda.spec();
        assert!(lda.dataset.contains("10000 documents"));
        let dk = Benchmark::DenseKMeans.spec();
        assert!(dk.dataset.contains("20M samples"));
        assert_eq!(dk.n_tasks, 1915);
        assert_eq!(dk.input_gb, 72.0);
    }

    #[test]
    fn dk_heavier_than_lda() {
        let lda = Benchmark::Lda.spec();
        let dk = Benchmark::DenseKMeans.spec();
        assert!(dk.total_live_mb > lda.total_live_mb);
        assert!(dk.input_gb > lda.input_gb);
    }

    #[test]
    fn executor_load_splits_across_fleet() {
        let l3 = Benchmark::DenseKMeans.executor_load(3);
        let l2 = Benchmark::DenseKMeans.executor_load(2);
        assert!((l3.work_core_s * 3.0 - l2.work_core_s * 2.0).abs() < 1e-9);
        assert!(l2.live_mb > l3.live_mb);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Benchmark::parse("lda"), Some(Benchmark::Lda));
        assert_eq!(Benchmark::parse("DK"), Some(Benchmark::DenseKMeans));
        assert_eq!(Benchmark::parse("sort"), None);
    }
}
