//! Blocked multi-RHS linear-algebra kernels for the GP hot path, behind
//! the pinned [`KernelPolicy`].
//!
//! Every BO iteration burns its CPU time in three scalar loops: the
//! per-candidate forward solves of EI scoring (O(n²) each, one per
//! candidate), the O(n²d) weighted-sum trial-kernel rebuilds over the
//! `PackedDims` distance cache during hyper adaptation, and the O(n³)
//! Cholesky rebuild after an eviction or a hyper move.  This module is
//! the blocked/SIMD-friendly tier for those loops:
//!
//! * [`solve_lower_multi`] / [`solve_lower_t_multi`] — multi-RHS
//!   triangular solves over any [`LowerTri`] factor (packed or dense),
//!   solving a whole EI candidate block (16 right-hand sides) in one
//!   pass.  The `Blocked` tier splits the reduction over factor columns
//!   into fixed [`PANEL`]-wide panels (one partial sum per panel,
//!   combined in panel order) and walks the right-hand sides in fixed
//!   [`LANES`]-wide lanes of independent accumulators, so the inner loop
//!   is branch-free, contiguous, and trivially vectorizable.
//! * [`cholesky_push_blocked`] / [`cholesky_rebuild_blocked`] — the
//!   factor extension/rebuild with the same panel-blocked dot products.
//! * [`lane_sum`] / [`lane_dot`] / [`kval_blocked`] — fixed-lane
//!   reductions for the ARD weighted-sum kernel expression, used for
//!   trial-kernel evaluation and the blocked EI posterior terms.
//! * [`sum_f32acc`] — an *opt-in* f32-accumulate-f64 variant of the
//!   distance sums.  It is exported and tested but deliberately NOT
//!   wired into `KernelPolicy::Blocked`: single-precision accumulation
//!   costs ~1e-7 relative error per sum, which after the kernel `exp`
//!   and the triangular solves cannot honour the 1e-8 Blocked-vs-Scalar
//!   pin.  Callers that can afford a looser tolerance (e.g. candidate
//!   pre-filtering) may opt in explicitly.
//!
//! # The `KernelPolicy` contract — what is pinned, and how hard
//!
//! Blocking changes the floating-point **summation order**, never the
//! set of terms, so the two tiers agree analytically and differ only in
//! round-off.  The pins:
//!
//! * **`Scalar` is bitwise-pinned.**  Every `Scalar` entry point here
//!   (`solve_lower_multi` with `KernelPolicy::Scalar`) reproduces the
//!   per-RHS operation order of `PackedLower::solve_lower` /
//!   `solve_lower_t` *exactly* — subtract terms one column at a time in
//!   index order, divide last — so a Scalar session, and the one-shot
//!   `gp_ei` reference path that now routes through the multi-RHS
//!   solve, are byte-for-byte the pre-policy tuner.  Guarded by the
//!   existing `gp_incremental` / `gp_downdate` / `gp_ard` suites and
//!   the in-file bitwise tests below.
//! * **`Blocked` is 1e-8-pinned to `Scalar`.**  `tests/gp_kernels.rs`
//!   drives both tiers through acquire/adapt/evict churn and pins the
//!   posteriors within 1e-8 (absolute + relative), plus direct
//!   solve-level differentials at 1e-10.
//! * **`Blocked` is bitwise self-reproducible.**  [`PANEL`], [`LANES`]
//!   and every reduction tree are compile-time constants — never
//!   derived from pool width, data values, or thread count — and the
//!   code is free of shared accumulators, so the same inputs produce
//!   the same bits at any `ExecPool` width (detlint's
//!   `unordered-float-reduce` rule passes over this module with no
//!   allows; `tests/gp_kernels.rs` asserts width-invariance directly).

use super::linalg::{Mat, PackedLower};
use crate::runtime::KernelPolicy;

/// Factor columns per reduction panel in the blocked solves: each panel
/// contributes one partial sum per right-hand side, combined in panel
/// order.  A constant of the algorithm — changing it changes Blocked
/// results (within the 1e-8 pin) and would invalidate recorded bench
/// numbers, so treat it like a file format.
pub const PANEL: usize = 32;

/// Right-hand sides per accumulator lane group in the blocked solves,
/// and the lane width of [`lane_sum`]/[`lane_dot`].  Eight f64 lanes
/// fill a 512-bit vector register; the EI block (16 candidates) is two
/// full lane groups.
pub const LANES: usize = 8;

/// A lower-triangular factor the multi-RHS solves can walk: implemented
/// by the packed session factor ([`PackedLower`]) and the dense
/// reference factor ([`Mat`], as produced by `linalg::cholesky`).  Rows
/// expose at least `i + 1` entries (`tri_row(i)[k]` = `L[i][k]` for
/// `k <= i`); the column walk of the transposed solve goes through
/// [`LowerTri::tri_at`].
pub trait LowerTri {
    fn tri_n(&self) -> usize;
    /// Row `i`; indices `0..=i` are the lower-triangle entries.
    fn tri_row(&self, i: usize) -> &[f64];
    /// Entry `L[k][i]` for `k >= i` (below-diagonal column walk).
    fn tri_at(&self, k: usize, i: usize) -> f64;
}

impl LowerTri for PackedLower {
    fn tri_n(&self) -> usize {
        self.n()
    }

    fn tri_row(&self, i: usize) -> &[f64] {
        self.row(i)
    }

    fn tri_at(&self, k: usize, i: usize) -> f64 {
        self.at(k, i)
    }
}

impl LowerTri for Mat {
    fn tri_n(&self) -> usize {
        self.rows
    }

    fn tri_row(&self, i: usize) -> &[f64] {
        self.row(i)
    }

    fn tri_at(&self, k: usize, i: usize) -> f64 {
        self.at(k, i)
    }
}

/// Solve `L X = B` for `m` right-hand sides in one pass, in place.
///
/// `b` is row-major over factor rows: `b[i * m + c]` is entry `i` of
/// right-hand side `c` on input and `x[i][c]` on output (the k-major
/// layout the EI scorer already uses, so the innermost loop is
/// contiguous across candidates).
///
/// `KernelPolicy::Scalar` keeps the per-RHS operation order of
/// `PackedLower::solve_lower` exactly (bitwise); `Blocked` runs the
/// panel/lane reduction (1e-8-pinned).
pub fn solve_lower_multi<L: LowerTri>(l: &L, b: &mut [f64], m: usize, policy: KernelPolicy) {
    match policy {
        KernelPolicy::Scalar => solve_lower_multi_scalar(l, b, m),
        KernelPolicy::Blocked => solve_lower_multi_blocked(l, b, m),
    }
}

/// Solve `Lᵀ X = B` for `m` right-hand sides in one pass, in place —
/// layout and policy contract as [`solve_lower_multi`].
pub fn solve_lower_t_multi<L: LowerTri>(l: &L, b: &mut [f64], m: usize, policy: KernelPolicy) {
    match policy {
        KernelPolicy::Scalar => solve_lower_t_multi_scalar(l, b, m),
        KernelPolicy::Blocked => solve_lower_t_multi_blocked(l, b, m),
    }
}

/// Scalar-order multi-RHS forward solve: for each right-hand side the
/// operation sequence is exactly `solve_lower`'s (subtract `L[i][k]·x[k]`
/// for `k = 0..i` in order, then divide by the diagonal), so each output
/// column is bitwise the single-RHS solve of its input column.
fn solve_lower_multi_scalar<L: LowerTri>(l: &L, b: &mut [f64], m: usize) {
    let n = l.tri_n();
    assert_eq!(b.len(), n * m);
    if m == 0 {
        return;
    }
    for i in 0..n {
        let row = l.tri_row(i);
        let (xs, rest) = b.split_at_mut(i * m);
        let bi = &mut rest[..m];
        for (k, &lk) in row[..i].iter().enumerate() {
            let xk = &xs[k * m..k * m + m];
            for (a, &xv) in bi.iter_mut().zip(xk) {
                *a -= lk * xv;
            }
        }
        let diag = row[i];
        for a in bi.iter_mut() {
            *a /= diag;
        }
    }
}

/// Scalar-order multi-RHS transposed solve — per-RHS operation order
/// exactly `solve_lower_t`'s (column walk `k = i+1..n` in order).
fn solve_lower_t_multi_scalar<L: LowerTri>(l: &L, b: &mut [f64], m: usize) {
    let n = l.tri_n();
    assert_eq!(b.len(), n * m);
    if m == 0 {
        return;
    }
    for i in (0..n).rev() {
        let (pre, rest) = b.split_at_mut((i + 1) * m);
        let bi = &mut pre[i * m..];
        for k in (i + 1)..n {
            let lki = l.tri_at(k, i);
            let xk = &rest[(k - (i + 1)) * m..(k - (i + 1)) * m + m];
            for (a, &xv) in bi.iter_mut().zip(xk) {
                *a -= lki * xv;
            }
        }
        let diag = l.tri_at(i, i);
        for a in bi.iter_mut() {
            *a /= diag;
        }
    }
}

/// Panel/lane-blocked multi-RHS forward solve.  For each factor row the
/// column reduction runs in fixed [`PANEL`]-wide panels — one partial
/// sum per right-hand side per panel, subtracted from the accumulator
/// in panel order — and the right-hand sides advance in [`LANES`]-wide
/// groups of independent accumulators (remainder columns take the same
/// panel order one at a time).  The reduction tree is therefore a pure
/// function of `(n, m)`: bitwise reproducible, pool-width independent.
fn solve_lower_multi_blocked<L: LowerTri>(l: &L, b: &mut [f64], m: usize) {
    let n = l.tri_n();
    assert_eq!(b.len(), n * m);
    if m == 0 {
        return;
    }
    for i in 0..n {
        let row = l.tri_row(i);
        let (xs, rest) = b.split_at_mut(i * m);
        let bi = &mut rest[..m];
        let mut p0 = 0;
        while p0 < i {
            let p1 = (p0 + PANEL).min(i);
            let mut c = 0;
            while c + LANES <= m {
                let mut part = [0.0f64; LANES];
                for (k, &lk) in row[p0..p1].iter().enumerate() {
                    let xk = &xs[(p0 + k) * m + c..(p0 + k) * m + c + LANES];
                    for (pp, &xv) in part.iter_mut().zip(xk) {
                        *pp += lk * xv;
                    }
                }
                for (a, &pp) in bi[c..c + LANES].iter_mut().zip(&part) {
                    *a -= pp;
                }
                c += LANES;
            }
            for cc in c..m {
                let mut part = 0.0;
                for (k, &lk) in row[p0..p1].iter().enumerate() {
                    part += lk * xs[(p0 + k) * m + cc];
                }
                bi[cc] -= part;
            }
            p0 = p1;
        }
        let diag = row[i];
        for a in bi.iter_mut() {
            *a /= diag;
        }
    }
}

/// Panel/lane-blocked multi-RHS transposed solve — the below-diagonal
/// column walk in fixed [`PANEL`]-wide panels, lanes as in
/// [`solve_lower_multi_blocked`].
fn solve_lower_t_multi_blocked<L: LowerTri>(l: &L, b: &mut [f64], m: usize) {
    let n = l.tri_n();
    assert_eq!(b.len(), n * m);
    if m == 0 {
        return;
    }
    for i in (0..n).rev() {
        let (pre, rest) = b.split_at_mut((i + 1) * m);
        let bi = &mut pre[i * m..];
        let mut p0 = i + 1;
        while p0 < n {
            let p1 = (p0 + PANEL).min(n);
            let mut c = 0;
            while c + LANES <= m {
                let mut part = [0.0f64; LANES];
                for k in p0..p1 {
                    let lki = l.tri_at(k, i);
                    let xk = &rest[(k - (i + 1)) * m + c..(k - (i + 1)) * m + c + LANES];
                    for (pp, &xv) in part.iter_mut().zip(xk) {
                        *pp += lki * xv;
                    }
                }
                for (a, &pp) in bi[c..c + LANES].iter_mut().zip(&part) {
                    *a -= pp;
                }
                c += LANES;
            }
            for cc in c..m {
                let mut part = 0.0;
                for k in p0..p1 {
                    part += l.tri_at(k, i) * rest[(k - (i + 1)) * m + cc];
                }
                bi[cc] -= part;
            }
            p0 = p1;
        }
        let diag = l.tri_at(i, i);
        for a in bi.iter_mut() {
            *a /= diag;
        }
    }
}

/// Extend a Cholesky factor by one kernel row with panel-blocked dot
/// products: the blocked counterpart of `linalg::cholesky_push`, same
/// O(n²) shape, reduction split into [`PANEL`]-wide partial sums.  The
/// set of multiply-subtract terms is identical — only the summation
/// tree differs, so the factor matches the scalar push within solve
/// round-off (1e-8-pinned through `tests/gp_kernels.rs`).  Returns
/// false (factor untouched) if the extended matrix is not positive
/// definite.
pub fn cholesky_push_blocked(l: &mut PackedLower, krow: &[f64]) -> bool {
    let n = l.n();
    assert_eq!(krow.len(), n + 1);
    let mut row = Vec::with_capacity(n + 1);
    for j in 0..n {
        let lj = l.row(j);
        let mut sum = krow[j];
        let mut p0 = 0;
        while p0 < j {
            let p1 = (p0 + PANEL).min(j);
            let mut part = 0.0;
            for (rk, ljk) in row[p0..p1].iter().zip(&lj[p0..p1]) {
                part += rk * ljk;
            }
            sum -= part;
            p0 = p1;
        }
        row.push(sum / lj[j]);
    }
    let mut sum = krow[n];
    let mut p0 = 0;
    while p0 < n {
        let p1 = (p0 + PANEL).min(n);
        let mut part = 0.0;
        for v in &row[p0..p1] {
            part += v * v;
        }
        sum -= part;
        p0 = p1;
    }
    if sum <= 0.0 {
        return false;
    }
    row.push(sum.sqrt());
    l.push_row(&row);
    true
}

/// Refactor `l` from a packed kernel matrix with the blocked panel
/// push: the `KernelPolicy::Blocked` counterpart of
/// `linalg::cholesky_rebuild`, used for Fixed-mode evictions and
/// adaptation commits on Blocked sessions.
pub fn cholesky_rebuild_blocked(k: &PackedLower, l: &mut PackedLower) -> bool {
    l.clear();
    for i in 0..k.n() {
        if !cholesky_push_blocked(l, k.row(i)) {
            return false;
        }
    }
    true
}

/// Width of the fixed-lane reductions ([`lane_sum`]/[`lane_dot`]) over
/// `PackedDims` d-blocks.  Four lanes, unrolled by hand below, combined
/// in one fixed tree — small enough that d ∈ {4..32} dimension blocks
/// still fill at least one full group.
pub const D_LANES: usize = 4;

/// Fixed-lane sum: accumulate `v` into [`D_LANES`] independent lanes
/// (lane `j` takes elements `j, j + 4, j + 8, …`) and combine them in
/// the fixed tree `(l0 + l1) + (l2 + l3)`.  Deterministic for a given
/// length; differs from the sequential iterator sum only in summation
/// order.
pub fn lane_sum(v: &[f64]) -> f64 {
    let mut lanes = [0.0f64; D_LANES];
    let mut chunks = v.chunks_exact(D_LANES);
    for ch in &mut chunks {
        lanes[0] += ch[0];
        lanes[1] += ch[1];
        lanes[2] += ch[2];
        lanes[3] += ch[3];
    }
    for (lane, &x) in lanes.iter_mut().zip(chunks.remainder()) {
        *lane += x;
    }
    (lanes[0] + lanes[1]) + (lanes[2] + lanes[3])
}

/// Fixed-lane dot product of `a` and `b` (shorter length wins), same
/// lane layout and combine tree as [`lane_sum`].
pub fn lane_dot(a: &[f64], b: &[f64]) -> f64 {
    let len = a.len().min(b.len());
    let (a, b) = (&a[..len], &b[..len]);
    let mut lanes = [0.0f64; D_LANES];
    let mut ac = a.chunks_exact(D_LANES);
    let mut bc = b.chunks_exact(D_LANES);
    for (ca, cb) in (&mut ac).zip(&mut bc) {
        lanes[0] += ca[0] * cb[0];
        lanes[1] += ca[1] * cb[1];
        lanes[2] += ca[2] * cb[2];
        lanes[3] += ca[3] * cb[3];
    }
    for ((lane, &x), &y) in lanes.iter_mut().zip(ac.remainder()).zip(bc.remainder()) {
        *lane += x * y;
    }
    (lanes[0] + lanes[1]) + (lanes[2] + lanes[3])
}

/// The RBF kernel expression over a per-dimension squared-distance
/// block, with fixed-lane reductions — the `Blocked` counterpart of the
/// session's scalar `kval`: `iso = Some(1/(2ℓ²))` sums the block first
/// and scales once, otherwise the per-dimension weighted sum runs
/// through [`lane_dot`].  Same terms, fixed-lane summation order.
#[inline]
pub fn kval_blocked(sq: &[f64], iso: Option<f64>, inv2: &[f64], sf2: f64) -> f64 {
    match iso {
        Some(inv) => sf2 * (-lane_sum(sq) * inv).exp(),
        None => sf2 * (-lane_dot(sq, inv2)).exp(),
    }
}

/// f32-accumulate-f64 sum of a distance block: each term is rounded to
/// f32 and accumulated in f32 lanes, the combined result widened back
/// to f64.  Half the accumulator bandwidth of [`lane_sum`], at ~1e-7
/// relative error — deliberately NOT part of `KernelPolicy::Blocked`
/// (which must hold the 1e-8 pin); exported for callers that opt into
/// the looser tolerance explicitly.
pub fn sum_f32acc(v: &[f64]) -> f64 {
    let mut lanes = [0.0f32; D_LANES];
    let mut chunks = v.chunks_exact(D_LANES);
    for ch in &mut chunks {
        lanes[0] += ch[0] as f32;
        lanes[1] += ch[1] as f32;
        lanes[2] += ch[2] as f32;
        lanes[3] += ch[3] as f32;
    }
    for (lane, &x) in lanes.iter_mut().zip(chunks.remainder()) {
        *lane += x as f32;
    }
    ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3])) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::linalg::{cholesky_push, cholesky_rebuild};
    use crate::util::rng::Pcg;

    /// A random well-conditioned lower-triangular factor: unit-ish
    /// diagonal, small off-diagonal entries.
    fn rand_factor(n: usize, rng: &mut Pcg) -> PackedLower {
        let mut l = PackedLower::new();
        let mut row = Vec::new();
        for i in 0..n {
            row.clear();
            for _ in 0..i {
                row.push(0.3 * rng.normal());
            }
            row.push(1.0 + rng.f64());
            l.push_row(&row);
        }
        l
    }

    fn rand_rhs(n: usize, m: usize, rng: &mut Pcg) -> Vec<f64> {
        (0..n * m).map(|_| rng.normal()).collect()
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// The Scalar multi-RHS solves must be bitwise the per-column
    /// single-RHS solves — the refactor that routed `score_block` and
    /// `gp_ei` through this module rests on this identity.
    #[test]
    fn scalar_multi_is_bitwise_the_single_rhs_solve() {
        let mut rng = Pcg::new(0x4e01);
        for &(n, m) in &[(1usize, 1usize), (7, 3), (20, 16), (45, 5)] {
            let l = rand_factor(n, &mut rng);
            let b = rand_rhs(n, m, &mut rng);
            let mut fwd = b.clone();
            solve_lower_multi(&l, &mut fwd, m, KernelPolicy::Scalar);
            let mut bwd = b.clone();
            solve_lower_t_multi(&l, &mut bwd, m, KernelPolicy::Scalar);
            for c in 0..m {
                let col: Vec<f64> = (0..n).map(|i| b[i * m + c]).collect();
                let xf = l.solve_lower(&col);
                let xb = l.solve_lower_t(&col);
                let got_f: Vec<f64> = (0..n).map(|i| fwd[i * m + c]).collect();
                let got_b: Vec<f64> = (0..n).map(|i| bwd[i * m + c]).collect();
                assert_eq!(bits(&xf), bits(&got_f), "fwd n={n} m={m} c={c}");
                assert_eq!(bits(&xb), bits(&got_b), "bwd n={n} m={m} c={c}");
            }
        }
    }

    /// Blocked solves agree with Scalar within solve round-off, across
    /// panel boundaries (n around and past PANEL) and lane remainders
    /// (m not a multiple of LANES).
    #[test]
    fn blocked_solves_match_scalar_to_1e10() {
        let mut rng = Pcg::new(0x4e02);
        for &(n, m) in &[(5usize, 1usize), (31, 7), (32, 8), (33, 16), (80, 11)] {
            let l = rand_factor(n, &mut rng);
            let b = rand_rhs(n, m, &mut rng);
            for (tag, t) in [("fwd", false), ("bwd", true)] {
                let mut s = b.clone();
                let mut bl = b.clone();
                if t {
                    solve_lower_t_multi(&l, &mut s, m, KernelPolicy::Scalar);
                    solve_lower_t_multi(&l, &mut bl, m, KernelPolicy::Blocked);
                } else {
                    solve_lower_multi(&l, &mut s, m, KernelPolicy::Scalar);
                    solve_lower_multi(&l, &mut bl, m, KernelPolicy::Blocked);
                }
                for (i, (a, b)) in s.iter().zip(&bl).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-10 * (1.0 + a.abs()),
                        "{tag} n={n} m={m} [{i}]: {a} vs {b}"
                    );
                }
            }
        }
    }

    /// Blocked results are a pure function of the inputs: two runs,
    /// plus a run through buffers of different prior contents, agree
    /// bitwise.
    #[test]
    fn blocked_solves_are_bitwise_reproducible() {
        let mut rng = Pcg::new(0x4e03);
        let (n, m) = (40, 13);
        let l = rand_factor(n, &mut rng);
        let b = rand_rhs(n, m, &mut rng);
        let mut one = b.clone();
        let mut two = b.clone();
        solve_lower_multi(&l, &mut one, m, KernelPolicy::Blocked);
        solve_lower_multi(&l, &mut two, m, KernelPolicy::Blocked);
        assert_eq!(bits(&one), bits(&two));
    }

    /// The blocked push/rebuild factors the same kernels the scalar
    /// path does (within round-off), and fails PD exactly when the
    /// scalar path fails.
    #[test]
    fn blocked_rebuild_matches_scalar_rebuild() {
        let mut rng = Pcg::new(0x4e04);
        for &n in &[3usize, 17, 40] {
            // Build a PD kernel via K = G Gᵀ + n·I from a random factor.
            let g = rand_factor(n, &mut rng);
            let mut k = PackedLower::new();
            let mut row = Vec::new();
            for i in 0..n {
                row.clear();
                for j in 0..=i {
                    let mut s = 0.0;
                    for t in 0..=j.min(i) {
                        let gi = if t <= i { g.at(i, t) } else { 0.0 };
                        let gj = if t <= j { g.at(j, t) } else { 0.0 };
                        s += gi * gj;
                    }
                    row.push(if i == j { s + 1.0 } else { s });
                }
                k.push_row(&row);
            }
            let mut ls = PackedLower::new();
            let mut lb = PackedLower::new();
            assert!(cholesky_rebuild(&k, &mut ls));
            assert!(cholesky_rebuild_blocked(&k, &mut lb));
            for i in 0..n {
                for (a, b) in ls.row(i).iter().zip(lb.row(i)) {
                    assert!(
                        (a - b).abs() <= 1e-10 * (1.0 + a.abs()),
                        "n={n} row {i}: {a} vs {b}"
                    );
                }
            }
        }
        // Non-PD: both sides refuse.
        let mut bad = PackedLower::new();
        bad.push_row(&[1.0]);
        bad.push_row(&[2.0, 1.0]); // off-diagonal too large: not PD
        let mut l = PackedLower::new();
        assert!(!cholesky_rebuild(&bad, &mut l));
        assert!(!cholesky_rebuild_blocked(&bad, &mut l));
        let mut l2 = PackedLower::new();
        assert!(cholesky_push(&mut l2, &[1.0]));
        assert!(!cholesky_push_blocked(&mut l2, &[2.0, 1.0]));
    }

    /// Lane reductions: same terms as the sequential sums, fixed tree;
    /// agreement within round-off, exact on short inputs.
    #[test]
    fn lane_reductions_match_sequential() {
        let mut rng = Pcg::new(0x4e05);
        for &len in &[0usize, 1, 3, 4, 5, 16, 33] {
            let v: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let w: Vec<f64> = (0..len).map(|_| rng.f64() + 0.1).collect();
            let seq_sum: f64 = v.iter().sum();
            let seq_dot: f64 = v.iter().zip(&w).map(|(a, b)| a * b).sum();
            assert!((lane_sum(&v) - seq_sum).abs() <= 1e-12 * (1.0 + seq_sum.abs()), "len {len}");
            assert!((lane_dot(&v, &w) - seq_dot).abs() <= 1e-12 * (1.0 + seq_dot.abs()), "len {len}");
        }
        // kval_blocked equals the scalar kernel expression within round-off.
        let sq: Vec<f64> = (0..12).map(|_| rng.f64()).collect();
        let inv2: Vec<f64> = (0..12).map(|_| rng.f64() + 0.2).collect();
        let scalar_iso = 2.0 * (-(sq.iter().sum::<f64>()) * 0.7).exp();
        let scalar_w =
            2.0 * (-(sq.iter().zip(&inv2).map(|(s, w)| s * w).sum::<f64>())).exp();
        assert!((kval_blocked(&sq, Some(0.7), &inv2, 2.0) - scalar_iso).abs() <= 1e-12);
        assert!((kval_blocked(&sq, None, &inv2, 2.0) - scalar_w).abs() <= 1e-12);
    }

    /// The f32-accumulate variant lands within single-precision
    /// round-off of the exact sum — and demonstrably NOT within the
    /// 1e-8 pin's reach on long inputs, which is why it stays opt-in.
    #[test]
    fn f32_accumulate_is_close_but_only_f32_close() {
        let mut rng = Pcg::new(0x4e06);
        let v: Vec<f64> = (0..256).map(|_| rng.f64()).collect();
        let exact: f64 = v.iter().sum();
        let approx = sum_f32acc(&v);
        assert!((approx - exact).abs() <= 1e-4 * (1.0 + exact.abs()), "{approx} vs {exact}");
        assert!(approx != exact, "f32 accumulation of 256 random terms matching f64 exactly is wildly improbable");
    }
}
