//! Pure-rust mirrors of the L1/L2 compute (cross-check + fallback backend).

pub mod gp;
pub mod kernels;
pub mod linalg;
pub mod ops;
