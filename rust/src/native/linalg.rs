//! Dense linear algebra for the native (pure-rust) ML backend: row-major
//! matrices, Cholesky factorization and triangular solves — mirrors of what
//! the L2 JAX graph does inside the HLO artifacts.
//!
//! On top of the dense mirrors, the packed-triangular type backs the
//! incremental GP surrogate with three factor maintenance operations:
//!
//! * [`cholesky_push`] — O(n²) append of one observation, bit-identical
//!   to a scratch refactor (a Cholesky row only reads prior rows).
//! * [`cholesky_downdate`] — O(n²) *deletion* of row/column `idx`.  Rows
//!   above `idx` are untouched (same prefix argument as push, mirrored);
//!   the trailing block absorbs the deleted column `v = L[idx+1.., idx]`
//!   with a sweep of Givens rotations, because deleting a row turns the
//!   trailing factor equation into the positive rank-1 update
//!   `L' L'ᵀ = L₃₃ L₃₃ᵀ + v vᵀ` — unconditionally stable (every rotation
//!   has `r = hypot(d, v) ≥ d > 0`), so SPD inputs can never produce a
//!   NaN.  Rotated entries differ from a scratch refactor only in
//!   floating-point low-order bits (the differential suite
//!   `tests/gp_downdate.rs` pins predictions to 1e-8).
//! * [`cholesky_rebuild`] — the O(n³) from-scratch fallback, used by
//!   `HyperMode::Fixed` sessions (bitwise reproducibility contract) and
//!   whenever the kernel hyper-parameters change.
//!
//! [`PackedDims`] is the factor caches' sibling for the ARD surrogate: a
//! packed lower-triangular store holding a d-vector per pair (the
//! per-dimension squared distances), so trial kernels under any
//! per-dimension length-scale weighting rebuild in O(n²d) without
//! re-reading the training inputs.

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Empty matrix that can grow to `rows` rows without reallocating —
    /// the backing store for incremental row pushes (GP training set,
    /// flattened kernel blocks).
    pub fn with_row_capacity(rows: usize, cols: usize) -> Mat {
        Mat { rows: 0, cols, data: Vec::with_capacity(rows * cols) }
    }

    /// Append one row (must match `cols`).
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.cols);
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Remove row `i`, shifting later rows up (`Vec::remove` semantics).
    pub fn remove_row(&mut self, i: usize) {
        assert!(i < self.rows);
        let c = self.cols;
        self.data.drain(i * c..(i + 1) * c);
        self.rows -= 1;
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols);
            data.extend_from_slice(r);
        }
        Mat { rows: rows.len(), cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// self * v
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols);
        let mut out = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(v) {
                acc += a * b;
            }
            out[i] = acc;
        }
        out
    }

    /// self^T * v
    pub fn tmatvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            let vi = v[i];
            for (o, a) in out.iter_mut().zip(row) {
                *o += a * vi;
            }
        }
        out
    }

    /// self^T * self (Gram matrix), exploiting symmetry.
    pub fn gram(&self) -> Mat {
        let d = self.cols;
        let mut g = Mat::zeros(d, d);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..d {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                let gi = &mut g.data[i * d..(i + 1) * d];
                for j in i..d {
                    gi[j] += ri * row[j];
                }
            }
        }
        for i in 0..d {
            for j in 0..i {
                g.data[i * d + j] = g.data[j * d + i];
            }
        }
        g
    }
}

/// Packed lower-triangular matrix: row `i` occupies
/// `data[i(i+1)/2 .. i(i+1)/2 + i + 1]`.  Backs the incremental GP
/// surrogate's kernel cache and Cholesky factor: appending a row is a plain
/// `extend`, and evicting observation `idx` splices its row and column out
/// of every later row without re-laying-out the live prefix.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct PackedLower {
    n: usize,
    data: Vec<f64>,
}

impl PackedLower {
    pub fn new() -> PackedLower {
        PackedLower::default()
    }

    #[inline]
    fn off(i: usize) -> usize {
        i * (i + 1) / 2
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Entry `(i, j)` of the lower triangle.
    ///
    /// Invariant: `j <= i < n`.  Checked only by `debug_assert!` — in
    /// release builds an upper-triangle query `(i, j)` with `j > i` does
    /// NOT panic; `off(i) + j` still lands inside `data` and silently
    /// reads an unrelated entry of a *later* row.  Callers must supply
    /// lower-triangle indices; `tests/property_invariants.rs` sweeps the
    /// `j <= i < n` boundary against a dense mirror.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(j <= i && i < self.n);
        self.data[Self::off(i) + j]
    }

    /// Mutable entry `(i, j)`.  Same `j <= i < n` invariant (and same
    /// silent-misread hazard in release builds) as [`PackedLower::at`] —
    /// except here a bad index silently *corrupts* a later row.
    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(j <= i && i < self.n);
        &mut self.data[Self::off(i) + j]
    }

    /// Row `i` (length `i + 1`; last element is the diagonal).
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[Self::off(i)..Self::off(i) + i + 1]
    }

    /// Append a row (must have length `n + 1`).
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.n + 1);
        self.data.extend_from_slice(row);
        self.n += 1;
    }

    /// Remove row and column `idx` (`Vec::remove` semantics: the order of
    /// the remaining indices is preserved).
    pub fn remove(&mut self, idx: usize) {
        assert!(idx < self.n);
        let mut w = Self::off(idx);
        for r in idx + 1..self.n {
            let start = Self::off(r);
            for c in 0..=r {
                if c == idx {
                    continue;
                }
                self.data[w] = self.data[start + c];
                w += 1;
            }
        }
        self.n -= 1;
        self.data.truncate(w);
    }

    pub fn clear(&mut self) {
        self.n = 0;
        self.data.clear();
    }

    /// Solve `L x = b` — arithmetic identical to the free [`solve_lower`].
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        let mut x = vec![0.0; n];
        for i in 0..n {
            let row = self.row(i);
            let mut sum = b[i];
            for k in 0..i {
                sum -= row[k] * x[k];
            }
            x[i] = sum / row[i];
        }
        x
    }

    /// Solve `L^T x = b` — arithmetic identical to [`solve_lower_t`].
    ///
    /// The column walk reads `L[k][i]` for `k = i+1..n`; rather than
    /// recomputing the packed offset `off(k) + i` per element, the
    /// offset is carried as a running stride (`off(k+1) = off(k) + k + 1`).
    /// Same elements in the same order — the floating-point operation
    /// sequence is untouched.
    pub fn solve_lower_t(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = b[i];
            let mut o = Self::off(i + 1) + i;
            for k in (i + 1)..n {
                sum -= self.data[o] * x[k];
                o += k + 1;
            }
            x[i] = sum / self.at(i, i);
        }
        x
    }
}

/// Packed lower-triangular store with a fixed-length f64 block per entry:
/// entry `(i, j)` (`j <= i`) occupies `data[(i(i+1)/2 + j)·d .. +d]`.
///
/// Backs the GP surrogate's **per-dimension** squared-distance cache: the
/// ARD kernel weights every dimension's squared distance by its own
/// length-scale, so trial kernels at new hyper-parameters need the d
/// per-dimension components of every pair — not just their sum — to stay
/// O(n²d) with no re-reading of the training inputs.  Append is a plain
/// `extend` (one `(n+1)·d` row), eviction splices the row and column's
/// blocks out of every later row in place, mirroring [`PackedLower`].
#[derive(Clone, Debug, PartialEq, Default)]
pub struct PackedDims {
    n: usize,
    d: usize,
    data: Vec<f64>,
}

impl PackedDims {
    pub fn new(d: usize) -> PackedDims {
        PackedDims { n: 0, d, data: Vec::new() }
    }

    #[inline]
    fn off(i: usize) -> usize {
        i * (i + 1) / 2
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Values per entry (the input dimension of the cached pairs).
    pub fn dims(&self) -> usize {
        self.d
    }

    /// The d-block of entry `(i, j)`.
    ///
    /// Invariant: `j <= i < n`, checked only by `debug_assert!` like
    /// [`PackedLower::at`]: in release builds an upper-triangle query
    /// silently returns the d-block of a later row instead of panicking.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> &[f64] {
        debug_assert!(j <= i && i < self.n);
        let o = (Self::off(i) + j) * self.d;
        &self.data[o..o + self.d]
    }

    /// Append row `n`: `row` holds the `n + 1` entries `(n, 0..=n)`
    /// flattened in column order, d values each.
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), (self.n + 1) * self.d);
        self.data.extend_from_slice(row);
        self.n += 1;
    }

    /// Remove row and column `idx` (`Vec::remove` semantics: the order of
    /// the remaining indices is preserved).
    ///
    /// Each surviving row `r > idx` keeps two contiguous runs — the `idx`
    /// blocks before column `idx` and the `r - idx` blocks after it — so
    /// the splice is two block moves per row instead of one `copy_within`
    /// per d-block.  Same bytes in the same order as the per-block loop.
    pub fn remove(&mut self, idx: usize) {
        assert!(idx < self.n);
        let d = self.d;
        let mut w = Self::off(idx) * d;
        for r in idx + 1..self.n {
            let start = Self::off(r) * d;
            let pre = idx * d;
            self.data.copy_within(start..start + pre, w);
            w += pre;
            let post_src = start + (idx + 1) * d;
            let post = (r - idx) * d;
            self.data.copy_within(post_src..post_src + post, w);
            w += post;
        }
        self.n -= 1;
        self.data.truncate(w);
    }

    pub fn clear(&mut self) {
        self.n = 0;
        self.data.clear();
    }
}

/// Extend a Cholesky factor by one observation: given the next kernel row
/// `krow` (`k(x_new, x_0..=x_new)`, diagonal — noise included — last),
/// append row `n` of the factor in O(n²).  The arithmetic is exactly row
/// `n` of [`cholesky`] — a row only reads *prior* rows, so the result is
/// bit-identical to refactoring from scratch.  Returns false (factor
/// untouched) if the extended matrix is not positive definite.
pub fn cholesky_push(l: &mut PackedLower, krow: &[f64]) -> bool {
    let n = l.n();
    assert_eq!(krow.len(), n + 1);
    let mut row = Vec::with_capacity(n + 1);
    for j in 0..n {
        let lj = l.row(j);
        let mut sum = krow[j];
        for k in 0..j {
            sum -= row[k] * lj[k];
        }
        row.push(sum / lj[j]);
    }
    let mut sum = krow[n];
    for v in &row {
        sum -= v * v;
    }
    if sum <= 0.0 {
        return false;
    }
    row.push(sum.sqrt());
    l.push_row(&row);
    true
}

/// Remove observation `idx` from a Cholesky factor in place — the O(n²)
/// alternative to splicing the kernel and refactoring from scratch.
///
/// Partition `K` around `idx`: the factor rows above `idx` never read it,
/// so they survive verbatim, as do the sub-`idx` columns of the rows
/// below.  Writing `L₃₁`/`L₃₃` for the trailing rows' untouched prefix
/// columns and trailing square block, and `v = L[idx+1.., idx]` for the
/// deleted column, the reduced kernel block satisfies
/// `K₃₃ = L₃₁L₃₁ᵀ + L₃₃L₃₃ᵀ + v vᵀ`; the prefix columns are kept, so the
/// new trailing block must satisfy `L₃₃' L₃₃'ᵀ = L₃₃L₃₃ᵀ + v vᵀ` —
/// deleting a row is a *positive* rank-1 update of
/// the trailing factor, absorbed by the classic LINPACK Givens sweep
/// (`r = hypot(d, v) ≥ d > 0` at every pivot, so the sweep cannot fail or
/// produce NaN on a valid factor).  `downdate(n-1)` has an empty `v` and
/// is a pure truncation: bit-identical inverse of [`cholesky_push`].
///
/// Precondition: `l` is a valid Cholesky factor (positive diagonal).  The
/// result equals a scratch refactor of the spliced kernel up to rotation
/// round-off; `tests/gp_downdate.rs` pins GP predictions through this
/// path to the rebuild path within 1e-8.
pub fn cholesky_downdate(l: &mut PackedLower, idx: usize) {
    let n = l.n();
    assert!(idx < n);
    // The deleted column below the diagonal, saved before the splice.
    let mut v: Vec<f64> = (idx + 1..n).map(|r| l.at(r, idx)).collect();
    l.remove(idx);
    let m = l.n();
    for k in idx..m {
        let vk = v[k - idx];
        let dk = l.at(k, k);
        let r = dk.hypot(vk);
        let c = r / dk;
        let s = vk / dk;
        *l.at_mut(k, k) = r;
        for i in k + 1..m {
            let lik = (l.at(i, k) + s * v[i - idx]) / c;
            *l.at_mut(i, k) = lik;
            v[i - idx] = c * v[i - idx] - s * lik;
        }
    }
}

/// Refactor `l` from a packed kernel matrix `k` (noise on the diagonal) —
/// the full O(n³) path the incremental surrogate uses for evictions in
/// `HyperMode::Fixed` (where bitwise reproducibility matters more than
/// the O(n²) [`cholesky_downdate`]) and after a hyper-parameter change
/// (which invalidates every cached entry).  Row-by-row `cholesky_push`
/// in index order is exactly [`cholesky`]'s loop.
pub fn cholesky_rebuild(k: &PackedLower, l: &mut PackedLower) -> bool {
    l.clear();
    for i in 0..k.n() {
        if !cholesky_push(l, k.row(i)) {
            return false;
        }
    }
    true
}

/// In-place Cholesky: returns lower-triangular L with A = L L^T.
/// Fails (None) if A is not positive definite.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j);
            for k in 0..j {
                sum -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                *l.at_mut(i, j) = sum.sqrt();
            } else {
                *l.at_mut(i, j) = sum / l.at(j, j);
            }
        }
    }
    Some(l)
}

/// Solve L x = b (L lower-triangular).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l.at(i, k) * x[k];
        }
        x[i] = sum / l.at(i, i);
    }
    x
}

/// Solve L^T x = b (L lower-triangular, solving the transposed system).
pub fn solve_lower_t(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = b[i];
        for k in (i + 1)..n {
            sum -= l.at(k, i) * x[k];
        }
        x[i] = sum / l.at(i, i);
    }
    x
}

/// Solve A x = b for SPD A via Cholesky.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky(a)?;
    Some(solve_lower_t(&l, &solve_lower(&l, b)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn random_spd(n: usize, rng: &mut Pcg) -> Mat {
        let rows: Vec<Vec<f64>> =
            (0..n).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let x = Mat::from_rows(&rows);
        let mut g = x.gram();
        for i in 0..n {
            *g.at_mut(i, i) += n as f64; // well-conditioned
        }
        g
    }

    #[test]
    fn matvec_known() {
        let m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        assert_eq!(m.tmatvec(&[1.0, 1.0]), vec![4.0, 6.0]);
    }

    #[test]
    fn gram_matches_naive() {
        let mut rng = Pcg::new(1);
        let rows: Vec<Vec<f64>> =
            (0..7).map(|_| (0..5).map(|_| rng.normal()).collect()).collect();
        let x = Mat::from_rows(&rows);
        let g = x.gram();
        for i in 0..5 {
            for j in 0..5 {
                let want: f64 = rows.iter().map(|r| r[i] * r[j]).sum();
                assert!((g.at(i, j) - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg::new(2);
        let a = random_spd(12, &mut rng);
        let l = cholesky(&a).unwrap();
        for i in 0..12 {
            for j in 0..12 {
                let mut s = 0.0;
                for k in 0..12 {
                    s += l.at(i, k) * l.at(j, k);
                }
                assert!((s - a.at(i, j)).abs() < 1e-8, "({i},{j})");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eig -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_spd_roundtrip() {
        let mut rng = Pcg::new(3);
        let a = random_spd(20, &mut rng);
        let x_true: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        let b = a.matvec(&x_true);
        let x = solve_spd(&a, &b).unwrap();
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-8);
        }
    }

    /// Pack the lower triangle (diag included) of a dense matrix.
    fn pack(a: &Mat) -> PackedLower {
        let mut p = PackedLower::new();
        for i in 0..a.rows {
            let row: Vec<f64> = (0..=i).map(|j| a.at(i, j)).collect();
            p.push_row(&row);
        }
        p
    }

    #[test]
    fn mat_push_and_remove_rows() {
        let mut m = Mat::with_row_capacity(4, 3);
        m.push_row(&[1.0, 2.0, 3.0]);
        m.push_row(&[4.0, 5.0, 6.0]);
        m.push_row(&[7.0, 8.0, 9.0]);
        assert_eq!(m.rows, 3);
        m.remove_row(1);
        assert_eq!(m.rows, 2);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[7.0, 8.0, 9.0]);
    }

    #[test]
    fn packed_lower_roundtrips_dense() {
        let mut rng = Pcg::new(11);
        let a = random_spd(9, &mut rng);
        let p = pack(&a);
        for i in 0..9 {
            for j in 0..=i {
                assert_eq!(p.at(i, j), a.at(i, j));
            }
        }
        assert_eq!(p.row(4).len(), 5);
    }

    #[test]
    fn packed_remove_matches_dense_removal() {
        let mut rng = Pcg::new(12);
        let a = random_spd(8, &mut rng);
        for idx in [0usize, 3, 7] {
            let mut p = pack(&a);
            p.remove(idx);
            assert_eq!(p.n(), 7);
            let keep: Vec<usize> = (0..8).filter(|&r| r != idx).collect();
            for (i, &ri) in keep.iter().enumerate() {
                for (j, &rj) in keep.iter().take(i + 1).enumerate() {
                    assert_eq!(p.at(i, j), a.at(ri, rj), "idx {idx} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn cholesky_push_bit_identical_to_scratch() {
        let mut rng = Pcg::new(13);
        let a = random_spd(14, &mut rng);
        let dense = cholesky(&a).unwrap();
        let mut l = PackedLower::new();
        for i in 0..14 {
            let krow: Vec<f64> = (0..=i).map(|j| a.at(i, j)).collect();
            assert!(cholesky_push(&mut l, &krow));
        }
        for i in 0..14 {
            for j in 0..=i {
                assert_eq!(
                    l.at(i, j).to_bits(),
                    dense.at(i, j).to_bits(),
                    "({i},{j})"
                );
            }
        }
    }

    #[test]
    fn cholesky_push_rejects_indefinite_untouched() {
        let mut l = PackedLower::new();
        assert!(cholesky_push(&mut l, &[4.0]));
        // second row making the matrix indefinite: [[4, 5], [5, 4]]
        assert!(!cholesky_push(&mut l, &[5.0, 4.0]));
        assert_eq!(l.n(), 1, "failed push must leave the factor untouched");
    }

    #[test]
    fn cholesky_rebuild_after_eviction_matches_scratch() {
        let mut rng = Pcg::new(14);
        let a = random_spd(10, &mut rng);
        let mut k = pack(&a);
        k.remove(4);
        let mut l = PackedLower::new();
        assert!(cholesky_rebuild(&k, &mut l));
        // dense reference on the same 9x9 submatrix
        let keep: Vec<usize> = (0..10).filter(|&r| r != 4).collect();
        let mut sub = Mat::zeros(9, 9);
        for (i, &ri) in keep.iter().enumerate() {
            for (j, &rj) in keep.iter().enumerate() {
                *sub.at_mut(i, j) = a.at(ri, rj);
            }
        }
        let dense = cholesky(&sub).unwrap();
        for i in 0..9 {
            for j in 0..=i {
                assert_eq!(l.at(i, j).to_bits(), dense.at(i, j).to_bits());
            }
        }
    }

    #[test]
    fn packed_dims_push_at_roundtrip() {
        let d = 3;
        let mut p = PackedDims::new(d);
        // entry (i, j) block value = 100*i + 10*j + dim index
        for i in 0..4usize {
            let mut row = Vec::new();
            for j in 0..=i {
                for k in 0..d {
                    row.push((100 * i + 10 * j + k) as f64);
                }
            }
            p.push_row(&row);
        }
        assert_eq!(p.n(), 4);
        assert_eq!(p.dims(), d);
        for i in 0..4usize {
            for j in 0..=i {
                let want: Vec<f64> = (0..d).map(|k| (100 * i + 10 * j + k) as f64).collect();
                assert_eq!(p.at(i, j), &want[..], "({i},{j})");
            }
        }
    }

    #[test]
    fn packed_dims_remove_matches_index_relabelling() {
        let d = 2;
        for idx in [0usize, 2, 4] {
            let mut p = PackedDims::new(d);
            for i in 0..5usize {
                let mut row = Vec::new();
                for j in 0..=i {
                    row.push((10 * i + j) as f64);
                    row.push(-((10 * i + j) as f64));
                }
                p.push_row(&row);
            }
            p.remove(idx);
            assert_eq!(p.n(), 4);
            let keep: Vec<usize> = (0..5).filter(|&r| r != idx).collect();
            for (i, &ri) in keep.iter().enumerate() {
                for (j, &rj) in keep.iter().take(i + 1).enumerate() {
                    let v = (10 * ri + rj) as f64;
                    assert_eq!(p.at(i, j), &[v, -v][..], "idx {idx} ({i},{j})");
                }
            }
        }
    }

    // The downdate invariants (downdate-vs-scratch-factor to tolerance,
    // downdate(last) as a bitwise push-inverse, SPD-never-NaN under
    // repeated deletions) are pinned by the seeded property sweep in
    // tests/property_invariants.rs, which strictly subsumes fixed-seed
    // unit copies of the same assertions.

    #[test]
    fn packed_solves_match_dense_bitwise() {
        let mut rng = Pcg::new(15);
        let a = random_spd(11, &mut rng);
        let dense = cholesky(&a).unwrap();
        let packed = pack(&dense);
        let b: Vec<f64> = (0..11).map(|_| rng.normal()).collect();
        let (xd, xp) = (solve_lower(&dense, &b), packed.solve_lower(&b));
        assert_eq!(
            xd.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            xp.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        let (td, tp) = (solve_lower_t(&dense, &b), packed.solve_lower_t(&b));
        assert_eq!(
            td.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            tp.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn triangular_solves_inverse_each_other() {
        let mut rng = Pcg::new(4);
        let a = random_spd(9, &mut rng);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let y = solve_lower(&l, &b);
        // L y = b
        for i in 0..9 {
            let mut s = 0.0;
            for k in 0..=i {
                s += l.at(i, k) * y[k];
            }
            assert!((s - b[i]).abs() < 1e-9);
        }
        let z = solve_lower_t(&l, &b);
        for i in 0..9 {
            let mut s = 0.0;
            for k in i..9 {
                s += l.at(k, i) * z[k];
            }
            assert!((s - b[i]).abs() < 1e-9);
        }
    }
}
