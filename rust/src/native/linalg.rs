//! Dense linear algebra for the native (pure-rust) ML backend: row-major
//! matrices, Cholesky factorization and triangular solves — mirrors of what
//! the L2 JAX graph does inside the HLO artifacts.

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols);
            data.extend_from_slice(r);
        }
        Mat { rows: rows.len(), cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// self * v
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols);
        let mut out = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(v) {
                acc += a * b;
            }
            out[i] = acc;
        }
        out
    }

    /// self^T * v
    pub fn tmatvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            let vi = v[i];
            for (o, a) in out.iter_mut().zip(row) {
                *o += a * vi;
            }
        }
        out
    }

    /// self^T * self (Gram matrix), exploiting symmetry.
    pub fn gram(&self) -> Mat {
        let d = self.cols;
        let mut g = Mat::zeros(d, d);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..d {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                let gi = &mut g.data[i * d..(i + 1) * d];
                for j in i..d {
                    gi[j] += ri * row[j];
                }
            }
        }
        for i in 0..d {
            for j in 0..i {
                g.data[i * d + j] = g.data[j * d + i];
            }
        }
        g
    }
}

/// In-place Cholesky: returns lower-triangular L with A = L L^T.
/// Fails (None) if A is not positive definite.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j);
            for k in 0..j {
                sum -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                *l.at_mut(i, j) = sum.sqrt();
            } else {
                *l.at_mut(i, j) = sum / l.at(j, j);
            }
        }
    }
    Some(l)
}

/// Solve L x = b (L lower-triangular).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l.at(i, k) * x[k];
        }
        x[i] = sum / l.at(i, i);
    }
    x
}

/// Solve L^T x = b (L lower-triangular, solving the transposed system).
pub fn solve_lower_t(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = b[i];
        for k in (i + 1)..n {
            sum -= l.at(k, i) * x[k];
        }
        x[i] = sum / l.at(i, i);
    }
    x
}

/// Solve A x = b for SPD A via Cholesky.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky(a)?;
    Some(solve_lower_t(&l, &solve_lower(&l, b)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn random_spd(n: usize, rng: &mut Pcg) -> Mat {
        let rows: Vec<Vec<f64>> =
            (0..n).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let x = Mat::from_rows(&rows);
        let mut g = x.gram();
        for i in 0..n {
            *g.at_mut(i, i) += n as f64; // well-conditioned
        }
        g
    }

    #[test]
    fn matvec_known() {
        let m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        assert_eq!(m.tmatvec(&[1.0, 1.0]), vec![4.0, 6.0]);
    }

    #[test]
    fn gram_matches_naive() {
        let mut rng = Pcg::new(1);
        let rows: Vec<Vec<f64>> =
            (0..7).map(|_| (0..5).map(|_| rng.normal()).collect()).collect();
        let x = Mat::from_rows(&rows);
        let g = x.gram();
        for i in 0..5 {
            for j in 0..5 {
                let want: f64 = rows.iter().map(|r| r[i] * r[j]).sum();
                assert!((g.at(i, j) - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg::new(2);
        let a = random_spd(12, &mut rng);
        let l = cholesky(&a).unwrap();
        for i in 0..12 {
            for j in 0..12 {
                let mut s = 0.0;
                for k in 0..12 {
                    s += l.at(i, k) * l.at(j, k);
                }
                assert!((s - a.at(i, j)).abs() < 1e-8, "({i},{j})");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eig -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_spd_roundtrip() {
        let mut rng = Pcg::new(3);
        let a = random_spd(20, &mut rng);
        let x_true: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        let b = a.matvec(&x_true);
        let x = solve_spd(&a, &b).unwrap();
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-8);
        }
    }

    #[test]
    fn triangular_solves_inverse_each_other() {
        let mut rng = Pcg::new(4);
        let a = random_spd(9, &mut rng);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let y = solve_lower(&l, &b);
        // L y = b
        for i in 0..9 {
            let mut s = 0.0;
            for k in 0..=i {
                s += l.at(i, k) * y[k];
            }
            assert!((s - b[i]).abs() < 1e-9);
        }
        let z = solve_lower_t(&l, &b);
        for i in 0..9 {
            let mut s = 0.0;
            for k in i..9 {
                s += l.at(k, i) * z[k];
            }
            assert!((s - b[i]).abs() < 1e-9);
        }
    }
}
