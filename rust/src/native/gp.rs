//! Incremental GP surrogate — the native backend's `GpSession`.
//!
//! The one-shot `gp_ei` path rebuilds the full n×n RBF kernel and
//! refactors it with an O(n³) Cholesky on *every* BO iteration, then
//! scores each candidate serially.  This module keeps the surrogate
//! stateful across iterations instead:
//!
//! * **Kernel cache** (`PackedLower`): appending an observation computes
//!   one kernel row in O(nd); evicting one splices a row/column out in
//!   O(n²).  Entries are pure functions of the point pair, so cached and
//!   freshly-built kernels are the same f64s.
//! * **Cached Cholesky** (`cholesky_push`): row-wise Cholesky only reads
//!   *prior* rows, so extending the factor by the new kernel row in O(n²)
//!   is bit-identical to refactoring from scratch.  Only an eviction
//!   breaks the prefix property and triggers the O(n³) `cholesky_rebuild`.
//! * **Sharded acquisition**: candidates are scored in fixed
//!   [`EI_BLOCK`]-wide blocks fanned out on an [`ExecPool`], results in
//!   index order.  Within a block the forward solves are interleaved —
//!   each factor row is streamed once per block instead of once per
//!   candidate, and the per-candidate accumulators are independent, which
//!   breaks the scalar latency chain of a lone triangular solve.  The
//!   *per-candidate* operation order is exactly `solve_lower`'s, so every
//!   (ei, mu, sigma) is bit-identical to the one-shot path at any pool
//!   width — the same guarantee the exec subsystem gives the evaluation
//!   paths (guarded by `tests/gp_incremental.rs`).
//!
//! `cargo bench --bench surrogate` times the two paths head-to-head
//! (n∈{64,128,256} train, m=1024 candidates) and writes the measured
//! speedups to `BENCH_surrogate.json` at the repo root; the design target
//! at n=256 is ≥5x from the incremental factor + sharding + blocked
//! solves.

use anyhow::Result;

use super::linalg::{cholesky_push, cholesky_rebuild, Mat, PackedLower};
use super::ops::expected_improvement;
use crate::exec::ExecPool;
use crate::runtime::{GpConfig, GpSession};
use crate::util::stats::TargetScaler;

/// Candidates per pool task.  One block shares each streamed factor row
/// across all its forward solves and gives the compiler independent
/// accumulators to pipeline/vectorize; the size is a constant of the
/// algorithm (never derived from pool width), so chunking cannot leak
/// into results.
const EI_BLOCK: usize = 16;

/// Stateful GP surrogate with cached kernel + Cholesky factor.
pub struct GpSurrogate {
    lengthscale: f64,
    sigma_f2: f64,
    sigma_n2: f64,
    cap: usize,
    /// Training inputs, one flat row each.
    x: Mat,
    /// Raw (unstandardized) targets, observation order.
    y: Vec<f64>,
    /// Kernel cache K + sigma_n2 I (lower triangle, diagonal included).
    k: PackedLower,
    /// Cholesky factor of `k`.
    l: PackedLower,
}

impl GpSurrogate {
    pub fn new(cfg: &GpConfig) -> GpSurrogate {
        GpSurrogate {
            lengthscale: cfg.lengthscale,
            sigma_f2: cfg.sigma_f2,
            sigma_n2: cfg.sigma_n2,
            cap: cfg.cap,
            x: Mat::with_row_capacity(cfg.cap, cfg.dim),
            y: Vec::new(),
            k: PackedLower::new(),
            l: PackedLower::new(),
        }
    }

    /// k(a, b) — the same expression (same evaluation order) as
    /// `ops::rbf`, so cached entries match a fresh kernel build bitwise.
    #[inline]
    fn kval(&self, a: &[f64], b: &[f64]) -> f64 {
        let inv = 1.0 / (2.0 * self.lengthscale * self.lengthscale);
        let sq: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
        self.sigma_f2 * (-sq * inv).exp()
    }

    /// Score one candidate block: kernel rows, interleaved forward solves
    /// (per-candidate op order identical to `solve_lower`), then
    /// (ei, mu, sigma) per candidate.
    fn score_block(&self, cands: &[Vec<f64>], alpha: &[f64], best_sc: f64) -> Vec<(f64, f64, f64)> {
        let n = self.y.len();
        let bs = cands.len();
        // Candidate-major kernel rows k(c, x_j).
        let mut kc = vec![0.0; bs * n];
        for (c, cand) in cands.iter().enumerate() {
            let row = &mut kc[c * n..(c + 1) * n];
            for (j, o) in row.iter_mut().enumerate() {
                *o = self.kval(cand, self.x.row(j));
            }
        }
        // Interleaved forward solve L v = kc^T, v stored k-major so the
        // innermost loop is contiguous across candidates.
        let mut v = vec![0.0; n * bs];
        let mut acc = vec![0.0; bs];
        for i in 0..n {
            let li = self.l.row(i);
            for (c, a) in acc.iter_mut().enumerate() {
                *a = kc[c * n + i];
            }
            for (k, &lk) in li[..i].iter().enumerate() {
                let vk = &v[k * bs..k * bs + bs];
                for (a, &vv) in acc.iter_mut().zip(vk) {
                    *a -= lk * vv;
                }
            }
            let d = li[i];
            for (o, &a) in v[i * bs..i * bs + bs].iter_mut().zip(&acc) {
                *o = a / d;
            }
        }
        let mut out = Vec::with_capacity(bs);
        for c in 0..bs {
            let kci = &kc[c * n..(c + 1) * n];
            let m: f64 = kci.iter().zip(alpha).map(|(a, b)| a * b).sum();
            let mut s2 = 0.0;
            for k in 0..n {
                let vc = v[k * bs + c];
                s2 += vc * vc;
            }
            let var = (self.sigma_f2 - s2).max(1e-12);
            let s = var.sqrt();
            out.push((expected_improvement(m, s, best_sc), m, s));
        }
        out
    }
}

impl GpSession for GpSurrogate {
    fn len(&self) -> usize {
        self.y.len()
    }

    fn ys(&self) -> &[f64] {
        &self.y
    }

    fn observe(&mut self, x: &[f64], y: f64) -> Result<()> {
        anyhow::ensure!(
            x.len() == self.x.cols,
            "GP point dim {} != {}",
            x.len(),
            self.x.cols
        );
        anyhow::ensure!(self.y.len() < self.cap, "GP training rows at cap {}", self.cap);
        let n = self.y.len();
        let mut krow = Vec::with_capacity(n + 1);
        for j in 0..n {
            krow.push(self.kval(x, self.x.row(j)));
        }
        krow.push(self.kval(x, x) + self.sigma_n2);
        anyhow::ensure!(
            cholesky_push(&mut self.l, &krow),
            "GP kernel matrix must be PD (jitter too small?)"
        );
        self.k.push_row(&krow);
        self.x.push_row(x);
        self.y.push(y);
        Ok(())
    }

    fn forget(&mut self, i: usize) -> Result<()> {
        anyhow::ensure!(i < self.y.len(), "forget({i}) of {} rows", self.y.len());
        // The factor's prefix property breaks on eviction: full refactor
        // from the (still exact) kernel cache.  Refactor a scratch copy
        // first so a failure leaves the session untouched (and usable)
        // instead of with a factor shorter than its training set.
        let mut k = self.k.clone();
        k.remove(i);
        let mut l = PackedLower::new();
        anyhow::ensure!(
            cholesky_rebuild(&k, &mut l),
            "GP kernel matrix must be PD (jitter too small?)"
        );
        self.k = k;
        self.l = l;
        self.x.remove_row(i);
        self.y.remove(i);
        Ok(())
    }

    fn acquire(
        &self,
        pool: &ExecPool,
        xc: &[Vec<f64>],
        best: f64,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
        let n = self.y.len();
        anyhow::ensure!(n > 0, "GP needs observations before acquisition");
        let scaler = TargetScaler::fit(&self.y);
        let ysc: Vec<f64> = self.y.iter().map(|&v| scaler.transform(v)).collect();
        let best_sc = scaler.transform(best);
        let alpha = self.l.solve_lower_t(&self.l.solve_lower(&ysc));

        let scored =
            pool.par_chunks(xc, EI_BLOCK, |_, block| self.score_block(block, &alpha, best_sc));
        let mut ei = Vec::with_capacity(xc.len());
        let mut mu = Vec::with_capacity(xc.len());
        let mut sigma = Vec::with_capacity(xc.len());
        for (e, m, s) in scored {
            ei.push(e);
            mu.push(m);
            sigma.push(s);
        }
        Ok((ei, mu, sigma))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::ops::gp_ei;
    use crate::util::rng::Pcg;

    fn rand_rows(n: usize, d: usize, rng: &mut Pcg) -> Vec<Vec<f64>> {
        (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect()
    }

    fn cfg(d: usize) -> GpConfig {
        GpConfig { dim: d, lengthscale: 0.8, sigma_f2: 1.0, sigma_n2: 0.01, cap: 64 }
    }

    /// The incremental surrogate must reproduce the one-shot `gp_ei`
    /// posterior bitwise (acquire standardizes internally, so compare
    /// against gp_ei on pre-standardized targets).
    #[test]
    fn incremental_matches_one_shot_bitwise() {
        let mut rng = Pcg::new(21);
        let d = 5;
        let xs = rand_rows(30, d, &mut rng);
        let ys: Vec<f64> = xs.iter().map(|r| r[0] * 2.0 + r[1] - r[2]).collect();
        let xc = rand_rows(100, d, &mut rng);
        let c = cfg(d);

        let mut gp = GpSurrogate::new(&c);
        for (x, &y) in xs.iter().zip(&ys) {
            gp.observe(x, y).unwrap();
        }
        let best = ys.iter().cloned().fold(f64::INFINITY, f64::min);
        let (ei, mu, sigma) = gp.acquire(&ExecPool::serial(), &xc, best).unwrap();

        let scaler = TargetScaler::fit(&ys);
        let ysc: Vec<f64> = ys.iter().map(|&v| scaler.transform(v)).collect();
        let (e2, m2, s2) = gp_ei(
            &xs,
            &ysc,
            &xc,
            c.lengthscale,
            c.sigma_f2,
            c.sigma_n2,
            scaler.transform(best),
        );
        assert_eq!(bits(&ei), bits(&e2));
        assert_eq!(bits(&mu), bits(&m2));
        assert_eq!(bits(&sigma), bits(&s2));
    }

    #[test]
    fn pool_width_never_changes_acquisition() {
        let mut rng = Pcg::new(22);
        let d = 4;
        let xs = rand_rows(25, d, &mut rng);
        let ys: Vec<f64> = xs.iter().map(|r| (r[0] * 3.0).sin() + r[3]).collect();
        let xc = rand_rows(70, d, &mut rng); // not a multiple of EI_BLOCK
        let mut gp = GpSurrogate::new(&cfg(d));
        for (x, &y) in xs.iter().zip(&ys) {
            gp.observe(x, y).unwrap();
        }
        let serial = gp.acquire(&ExecPool::serial(), &xc, 0.1).unwrap();
        for width in [2, 3, 8] {
            let par = gp.acquire(&ExecPool::new(width), &xc, 0.1).unwrap();
            assert_eq!(bits(&serial.0), bits(&par.0), "width {width}");
            assert_eq!(bits(&serial.1), bits(&par.1), "width {width}");
            assert_eq!(bits(&serial.2), bits(&par.2), "width {width}");
        }
    }

    #[test]
    fn forget_rebuilds_factor_exactly() {
        let mut rng = Pcg::new(23);
        let d = 3;
        let xs = rand_rows(20, d, &mut rng);
        let ys: Vec<f64> = xs.iter().map(|r| r.iter().sum()).collect();
        let xc = rand_rows(40, d, &mut rng);
        let c = cfg(d);

        let mut gp = GpSurrogate::new(&c);
        for (x, &y) in xs.iter().zip(&ys) {
            gp.observe(x, y).unwrap();
        }
        gp.forget(7).unwrap();
        assert_eq!(gp.len(), 19);

        // reference: a fresh surrogate over the surviving points
        let mut fresh = GpSurrogate::new(&c);
        for (i, (x, &y)) in xs.iter().zip(&ys).enumerate() {
            if i != 7 {
                fresh.observe(x, y).unwrap();
            }
        }
        // Factors may differ (prefix property broke) in general, but the
        // posterior must match the scratch fit bitwise.
        let a = gp.acquire(&ExecPool::serial(), &xc, 0.5).unwrap();
        let b = fresh.acquire(&ExecPool::serial(), &xc, 0.5).unwrap();
        assert_eq!(bits(&a.0), bits(&b.0));
        assert_eq!(bits(&a.1), bits(&b.1));
        assert_eq!(bits(&a.2), bits(&b.2));
    }

    #[test]
    fn observe_past_cap_errors() {
        let d = 2;
        let mut c = cfg(d);
        c.cap = 3;
        let mut gp = GpSurrogate::new(&c);
        let mut rng = Pcg::new(24);
        for i in 0..3 {
            gp.observe(&[rng.f64(), rng.f64()], i as f64).unwrap();
        }
        assert!(gp.observe(&[0.5, 0.5], 9.0).is_err());
        assert!(gp.observe(&[0.5], 9.0).is_err(), "dim mismatch must error");
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }
}
