//! Incremental GP surrogate — the native backend's `GpSession`, under the
//! **vector hyper model**: one RBF length-scale per tuning dimension
//! (ln ℓ₁..ln ℓ_d) plus the noise variance (ln σₙ²).
//!
//! The one-shot `gp_ei` path rebuilds the full n×n RBF kernel and
//! refactors it with an O(n³) Cholesky on *every* BO iteration, then
//! scores each candidate serially.  This module keeps the surrogate
//! stateful across iterations instead:
//!
//! * **Kernel cache** (`PackedLower`): appending an observation computes
//!   one kernel row in O(nd); evicting one splices a row/column out in
//!   O(n²).  Entries are pure functions of the point pair, so cached and
//!   freshly-built kernels are the same f64s.  A parallel **per-dimension**
//!   squared-distance cache (`PackedDims`, hyper-parameter independent)
//!   lets the whole kernel be re-materialized for *any* trial length-scale
//!   vector in O(n²d) instead of re-reading the training inputs.
//! * **Cached Cholesky** (`cholesky_push`): row-wise Cholesky only reads
//!   *prior* rows, so extending the factor by the new kernel row in O(n²)
//!   is bit-identical to refactoring from scratch.  Eviction depends on
//!   the session's [`HyperMode`]: `Fixed` refactors the cached kernel
//!   from scratch (O(n³), keeps the bitwise contract below); `Adapt`
//!   runs the O(n²) Givens `cholesky_downdate`, whose factor matches a
//!   refactor only to rotation round-off.
//! * **Hyper-parameter adaptation** (`Adapt` only): every `every`
//!   appends on an actively-driven session (acquires interleaving the
//!   appends), amortized to one round per ~25% training-set growth
//!   during a bulk feed (warm start — nothing reads the intermediate
//!   hypers, so O(log n) rounds suffice), the session takes up to
//!   [`MAX_ADAPT_STEPS`] backtracking ascent steps on the log marginal
//!   likelihood, with the analytic gradient
//!   `∂L/∂θ = ½ tr((ααᵀ − K⁻¹) ∂K/∂θ)` computed from the cached factor.
//!   With `ard` **off** the length-scales move as one tied parameter —
//!   ascent over (ln ℓ, ln σₙ²), exactly the scalar behaviour this module
//!   grew out of; with `ard` **on** (Automatic Relevance Determination)
//!   every dimension moves independently and the gradient grows from 2 to
//!   d+1 entries (`∂K/∂(ln ℓⱼ) = K̃ ∘ D²ⱼ/ℓⱼ²`, zero diagonal;
//!   `∂K/∂(ln σₙ²) = σₙ² I`), validated against central finite
//!   differences in `tests/gp_ard.rs`.  A step is accepted only if the
//!   marginal likelihood increases (the trace is monotone by construction
//!   — `tests/gp_downdate.rs`, `tests/gp_ard.rs`), and the session's
//!   kernel + factor are swapped once, at the end, only when the
//!   hyper-parameters actually moved.
//! * **Sharded acquisition**: candidates are scored in fixed
//!   [`EI_BLOCK`]-wide blocks fanned out on an [`ExecPool`], results in
//!   index order.  Within a block the forward solves are interleaved —
//!   each factor row is streamed once per block instead of once per
//!   candidate, and the per-candidate accumulators are independent, which
//!   breaks the scalar latency chain of a lone triangular solve.  The
//!   *per-candidate* operation order is exactly `solve_lower`'s, so every
//!   (ei, mu, sigma) is bit-identical to the one-shot path at any pool
//!   width — the same guarantee the exec subsystem gives the evaluation
//!   paths (guarded by `tests/gp_incremental.rs`).
//! * **Fantasy scope** (constant-liar q-EI): `fantasize(x, y_liar)`
//!   extends the cached factor with `cholesky_push` exactly like
//!   `observe`, but records the row as *transient* — no adaptation
//!   cadence, no append bookkeeping.  `pop_fantasy` retracts the most
//!   recent fantasy with `cholesky_downdate(last)`, which on the last
//!   row is a pure truncation and therefore the **bitwise inverse** of
//!   the push (pinned by `tests/property_invariants.rs`), in Fixed and
//!   Adapt mode alike.  Any fantasize*q → pop_fantasy*q sequence leaves
//!   the session bit-for-bit where it started, so q-EI selects q points
//!   against fantasized models in O(qn²) without cloning the GP
//!   (round-trip pinned by `tests/gp_incremental.rs`).
//!
//! **Equality contract** (the lines the tests pin):
//! `HyperMode::Fixed` is bitwise-equal to the one-shot `gp_ei` reference
//! at every pool width, including across evictions
//! (`tests/gp_incremental.rs`) — for *any* length-scale vector.  With all
//! per-dimension length-scales equal the kernel takes the **isotropic
//! summation order** (squared distance summed across dimensions first,
//! scaled once), which is the exact arithmetic of the scalar
//! implementation this module replaced; with unequal entries both sides
//! use the same weighted per-dimension sum, so session and one-shot stay
//! bitwise twins either way.  `HyperMode::Adapt` keeps the same
//! per-candidate scoring arithmetic but evicts via downdate — predictions
//! after any eviction sequence match the rebuild path within 1e-8
//! (`tests/gp_downdate.rs`) — and, once adaptation fires, intentionally
//! diverges from the fixed-hyper reference (a better-fitting model, not a
//! numerical error).  ARD-off adaptation moves the length-scales as one
//! tied parameter, so an Adapt session with `ard: false` walks the same
//! 2-parameter ascent the scalar implementation did.
//!
//! After an ARD-adapted session, `1/ℓⱼ²` normalized over the tuned
//! dimensions is a relevance signal (`featsel::ard_relevance`) the
//! pipeline reports next to the lasso selection, closing the loop back to
//! the paper's feature-selection stage.
//!
//! **Kernel tier** ([`KernelPolicy`]): everything above describes the
//! default `Scalar` tier, whose arithmetic the bitwise pins guard.
//! `Blocked` routes the three hot loops through `native::kernels` — the
//! panel/lane multi-RHS solves for EI scoring, [`kval_blocked`]'s
//! fixed-lane weighted sums for trial-kernel rebuilds, and the
//! panel-blocked `cholesky_rebuild_blocked` for Fixed evictions and
//! adaptation commits.  Blocking changes summation order only, so a
//! Blocked session tracks its Scalar twin to 1e-8 (`tests/gp_kernels.rs`)
//! while staying bitwise self-reproducible at any pool width (fixed
//! block sizes, fixed reduction trees).  The O(n²)-bandwidth append path
//! (`push_point`, `cholesky_push`) stays scalar under both policies: the
//! tier targets the O(n²·m) scoring, O(n²d) trial-kernel, and O(n³)
//! refactor loops where blocking pays, and keeping appends shared means
//! a Blocked session's incremental factor is bit-identical to its
//! Scalar twin's until the first rebuild.
//!
//! `cargo bench --bench surrogate` times six scenarios — one-shot vs
//! incremental acquisition, eviction-heavy downdate vs rebuild, adaptation
//! on/off overhead, isotropic-adapt vs ARD-adapt at d∈{8,16}, batched
//! q-EI tuning at q∈{1,2,4}, and Scalar-vs-Blocked kernel-tier
//! acquisition at n∈{64,128,256} — and writes them to
//! `BENCH_surrogate.json` at the repo root.

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::Result;

use super::kernels::{
    cholesky_rebuild_blocked, kval_blocked, lane_dot, solve_lower_multi,
};
use super::linalg::{
    cholesky_downdate, cholesky_push, cholesky_rebuild, Mat, PackedDims, PackedLower,
};
use super::ops::{expected_improvement, iso_lengthscale};
use crate::exec::ExecPool;
use crate::runtime::{GpConfig, GpSession, HyperMode, KernelPolicy};
use crate::util::stats::TargetScaler;

/// Candidates per pool task.  One block shares each streamed factor row
/// across all its forward solves and gives the compiler independent
/// accumulators to pipeline/vectorize; the size is a constant of the
/// algorithm (never derived from pool width), so chunking cannot leak
/// into results.
const EI_BLOCK: usize = 16;

/// Adaptation starts once the training set can support a likelihood
/// gradient that is more signal than noise.
const MIN_ADAPT_OBS: usize = 8;
/// Accepted ascent steps per adaptation round ("a few bounded steps").
pub const MAX_ADAPT_STEPS: usize = 4;
/// Backtracking halvings per step before the round gives up.
const ADAPT_BACKTRACKS: usize = 6;
/// Initial step along the normalized gradient, in log-hyper space: each
/// accepted step moves the hypers by at most `e^0.5 ≈ 1.65x`.
const ADAPT_STEP0: f64 = 0.5;
/// Length-scale box (unit-cube inputs: anything outside is degenerate).
const LS_BOUNDS: (f64, f64) = (1e-2, 1e2);
/// Noise-variance box (targets are standardized before fitting).
const NOISE_BOUNDS: (f64, f64) = (1e-8, 1.0);

/// Per-dimension squared distances `out[j] = (a_j - b_j)²` — each entry is
/// the exact term the old scalar `sqdist` accumulated, in the same
/// dimension order, so summing `out` reproduces the scalar squared
/// distance bitwise.
#[inline]
fn sqdist_dims(a: &[f64], b: &[f64], out: &mut [f64]) {
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        let d = x - y;
        *o = d * d;
    }
}

/// The RBF kernel value from per-dimension squared distances — the single
/// home of the iso/weighted expression every cached-kernel path uses
/// (`kval_from_dims` at the session's hypers, `kernel_at` at trial
/// hypers), so the bitwise session-vs-one-shot contract cannot be broken
/// by one copy drifting.  `iso` is `Some(1/(2ℓ²))` for all-equal
/// length-scales (sum across dimensions first, scale once — the scalar
/// implementation's exact arithmetic); `inv2` holds `1/(2ℓⱼ²)` per
/// dimension otherwise.  `ops::rbf` mirrors this expression for the
/// one-shot path; `tests/gp_incremental.rs` pins the two bitwise-equal.
#[inline]
fn kval(sq: &[f64], iso: Option<f64>, inv2: &[f64], sf2: f64) -> f64 {
    match iso {
        Some(inv) => {
            let s: f64 = sq.iter().sum();
            sf2 * (-s * inv).exp()
        }
        None => {
            let e: f64 = sq.iter().zip(inv2).map(|(s, w)| s * w).sum();
            sf2 * (-e).exp()
        }
    }
}

/// What one adaptation round did — returned by [`GpSurrogate::adapt`] so
/// the differential tests can assert monotonicity directly.
#[derive(Clone, Debug)]
pub struct AdaptOutcome {
    /// Marginal-likelihood trace: the starting value, then one entry per
    /// *accepted* ascent step.  Non-decreasing by construction.
    pub ml: Vec<f64>,
    /// Accepted steps this round.
    pub steps: usize,
    /// Whether the hyper-parameters moved (and the cached kernel +
    /// factor were therefore swapped for refactored ones).
    pub moved: bool,
}

impl AdaptOutcome {
    fn unchanged() -> AdaptOutcome {
        AdaptOutcome { ml: Vec::new(), steps: 0, moved: false }
    }
}

/// Stateful GP surrogate with cached kernel + Cholesky factor.
pub struct GpSurrogate {
    /// Per-dimension RBF length-scales (`lengthscales.len() == dim`).
    lengthscales: Vec<f64>,
    /// `1/(2ℓⱼ²)` per dimension — refreshed whenever the length-scales
    /// move (the ARD kernel's per-dimension weights).
    inv2: Vec<f64>,
    /// `Some(1/(2ℓ²))` when every length-scale is (bitwise) equal: the
    /// isotropic fast path, which sums the squared distance across
    /// dimensions *before* scaling — the scalar implementation's exact
    /// arithmetic, so ARD-off kernels stay bit-identical to it.
    iso: Option<f64>,
    sigma_f2: f64,
    sigma_n2: f64,
    /// Free per-dimension length-scales during adaptation; off = tied.
    ard: bool,
    cap: usize,
    hyper: HyperMode,
    /// Which linear-algebra tier scores candidates and rebuilds factors:
    /// `Scalar` (bitwise-pinned default) or the panel/lane `Blocked`
    /// tier (1e-8-pinned to Scalar, bitwise self-reproducible).
    kernels: KernelPolicy,
    /// Training inputs, one flat row each.
    x: Mat,
    /// Raw (unstandardized) targets, observation order.
    y: Vec<f64>,
    /// Kernel cache K + sigma_n2 I (lower triangle, diagonal included).
    k: PackedLower,
    /// Cholesky factor of `k`.
    l: PackedLower,
    /// Per-dimension squared-distance cache (zero diagonal blocks) —
    /// hyper-parameter free, so adaptation can rebuild `k` for any trial
    /// length-scale vector in O(n²d).  Maintained only under
    /// [`HyperMode::Adapt`]; `Fixed` sessions never read it, so they skip
    /// its storage and splice costs entirely.
    d2: PackedDims,
    /// Appends since the last adaptation round.
    appends: usize,
    /// Acquisitions served so far (atomic: `acquire` takes `&self` and
    /// is shared across pool threads; incremented once per call on the
    /// calling thread, so it stays deterministic).
    acquires: AtomicUsize,
    /// `acquires` value when the last adaptation round ran — appends
    /// with no acquire in between are a *bulk feed*, whose intermediate
    /// hyper-parameters nothing ever reads.
    acquires_at_adapt: usize,
    /// Open fantasy depth (constant-liar rows appended and not yet
    /// retracted).  `pop_fantasy` refuses to truncate a real
    /// observation, and `observe`/`forget` refuse to run inside an open
    /// fantasy scope — the transient rows must be popped first.
    fantasies: usize,
}

impl GpSurrogate {
    pub fn new(cfg: &GpConfig) -> GpSurrogate {
        assert_eq!(
            cfg.lengthscales.len(),
            cfg.dim,
            "GpConfig.lengthscales must carry one entry per dimension"
        );
        let mut gp = GpSurrogate {
            lengthscales: Vec::new(),
            inv2: Vec::new(),
            iso: None,
            sigma_f2: cfg.sigma_f2,
            sigma_n2: cfg.sigma_n2,
            ard: cfg.ard,
            cap: cfg.cap,
            hyper: cfg.hyper,
            kernels: cfg.kernels,
            x: Mat::with_row_capacity(cfg.cap, cfg.dim),
            y: Vec::new(),
            k: PackedLower::new(),
            l: PackedLower::new(),
            d2: PackedDims::new(cfg.dim),
            appends: 0,
            acquires: AtomicUsize::new(0),
            acquires_at_adapt: 0,
            fantasies: 0,
        };
        gp.set_lengthscales(cfg.lengthscales.clone());
        gp
    }

    /// Install a new length-scale vector and refresh the derived kernel
    /// weights (`inv2`, the isotropic fast-path flag).
    fn set_lengthscales(&mut self, ls: Vec<f64>) {
        self.inv2 = ls.iter().map(|l| 1.0 / (2.0 * l * l)).collect();
        self.iso = iso_lengthscale(&ls).map(|l| 1.0 / (2.0 * l * l));
        self.lengthscales = ls;
    }

    /// Kernel value from per-dimension squared distances at the session's
    /// current hypers — [`kval`]'s expression (and evaluation order), so
    /// cached entries match a fresh kernel build bitwise.
    #[inline]
    fn kval_from_dims(&self, sq: &[f64]) -> f64 {
        kval(sq, self.iso, &self.inv2, self.sigma_f2)
    }

    /// Log marginal likelihood of the *standardized* targets under the
    /// current hyper-parameters, evaluated from the cached factor:
    /// `-½ yᵀα − Σᵢ ln Lᵢᵢ − (n/2) ln 2π`.  `-inf` on an empty session.
    pub fn log_marginal(&self) -> f64 {
        if self.y.is_empty() {
            return f64::NEG_INFINITY;
        }
        let scaler = TargetScaler::fit(&self.y);
        let ysc: Vec<f64> = self.y.iter().map(|&v| scaler.transform(v)).collect();
        log_marginal_of(&self.l, &ysc)
    }

    /// Log marginal likelihood the session would have at *trial*
    /// hyper-parameters, rebuilt from the distance cache
    /// ([`HyperMode::Adapt`] sessions only — `Fixed` keeps no cache).
    /// `None` when the
    /// trial kernel is not positive definite, the session is empty, or no
    /// cache exists.  The finite-difference half of the gradient
    /// validation in `tests/gp_ard.rs`.
    pub fn log_marginal_at(&self, lengthscales: &[f64], sigma_n2: f64) -> Option<f64> {
        if self.y.is_empty() || self.d2.n() != self.y.len() {
            return None;
        }
        let scaler = TargetScaler::fit(&self.y);
        let ysc: Vec<f64> = self.y.iter().map(|&v| scaler.transform(v)).collect();
        let (_, l) = self.kernel_at(lengthscales, sigma_n2)?;
        Some(log_marginal_of(&l, &ysc))
    }

    /// Analytic ML gradient at the *current* hyper-parameters: the vector
    /// `adapt` ascends — `[∂L/∂(ln ℓ₁) .. ∂L/∂(ln ℓ_d), ∂L/∂(ln σₙ²)]`
    /// under ARD, `[∂L/∂(ln ℓ), ∂L/∂(ln σₙ²)]` tied otherwise.  Empty on
    /// sessions with no distance cache (`Fixed`) or no data.  Exposed for
    /// the finite-difference validation suite.
    pub fn ml_gradient_now(&self) -> Vec<f64> {
        if self.y.is_empty() || self.d2.n() != self.y.len() {
            return Vec::new();
        }
        let scaler = TargetScaler::fit(&self.y);
        let ysc: Vec<f64> = self.y.iter().map(|&v| scaler.transform(v)).collect();
        self.ml_gradient(&self.k, &self.l, &ysc, &self.lengthscales, self.sigma_n2)
    }

    /// Cached per-dimension squared distances for the pair `(i, j)`
    /// (`j <= i`; [`HyperMode::Adapt`] sessions only) — exposed so the
    /// property suite can check the cache against direct recomputation
    /// after append/evict churn.
    pub fn cached_sqdists(&self, i: usize, j: usize) -> &[f64] {
        self.d2.at(i, j)
    }

    /// Training input row `i`.
    pub fn point(&self, i: usize) -> &[f64] {
        self.x.row(i)
    }

    /// Rebuild the packed kernel (noise on the diagonal) and its factor
    /// at trial hyper-parameters, from the per-dimension distance cache.
    /// All-equal trial length-scales take the isotropic summation order
    /// (bitwise the scalar arithmetic); unequal ones the weighted sum.
    /// `None` if the trial kernel is not positive definite (trial
    /// rejected).
    fn kernel_at(&self, ls: &[f64], s2n: f64) -> Option<(PackedLower, PackedLower)> {
        // A short slice would silently truncate dimensions out of the
        // weighted sum (or quietly go isotropic for len 1) and return a
        // plausible-looking likelihood for the wrong model.
        assert_eq!(
            ls.len(),
            self.d2.dims(),
            "trial length-scales must match the session dimension"
        );
        let n = self.y.len();
        let iso = iso_lengthscale(ls).map(|l| 1.0 / (2.0 * l * l));
        let inv2: Vec<f64> = match iso {
            Some(_) => Vec::new(),
            None => ls.iter().map(|l| 1.0 / (2.0 * l * l)).collect(),
        };
        let blocked = self.kernels == KernelPolicy::Blocked;
        let mut k = PackedLower::new();
        let mut row: Vec<f64> = Vec::with_capacity(n);
        for i in 0..n {
            row.clear();
            for j in 0..=i {
                row.push(if blocked {
                    kval_blocked(self.d2.at(i, j), iso, &inv2, self.sigma_f2)
                } else {
                    kval(self.d2.at(i, j), iso, &inv2, self.sigma_f2)
                });
            }
            // d2 diagonal blocks are all-zero, so row[i] was exactly
            // sigma_f2 before the noise.
            row[i] += s2n;
            k.push_row(&row);
        }
        let mut l = PackedLower::new();
        let pd = if blocked {
            cholesky_rebuild_blocked(&k, &mut l)
        } else {
            cholesky_rebuild(&k, &mut l)
        };
        if pd {
            Some((k, l))
        } else {
            None
        }
    }

    /// Analytic gradient of the log marginal likelihood from a factor of
    /// `k`: `∂L/∂θ = ½ Σᵢⱼ (αᵢαⱼ − K⁻¹ᵢⱼ) ∂Kᵢⱼ/∂θ`, with
    /// `∂K/∂(ln ℓⱼ) = K̃ ∘ D²ⱼ/ℓⱼ²` (zero diagonal) and
    /// `∂K/∂(ln σₙ²) = σₙ² I`.  Returns d+1 entries under ARD
    /// (ln ℓ₁..ln ℓ_d, ln σₙ² last) or 2 tied entries (the common
    /// log-shift `τ` with ℓⱼ ∝ e^τ — whose gradient is the sum of the
    /// per-dimension ones — then ln σₙ²).  Cost O(n³/2) for the explicit
    /// `K⁻¹` plus O(n²d) for the length-scale traces, paid only once per
    /// accepted adaptation step.
    fn ml_gradient(
        &self,
        k: &PackedLower,
        l: &PackedLower,
        ysc: &[f64],
        ls: &[f64],
        s2n: f64,
    ) -> Vec<f64> {
        let n = k.n();
        let d = ls.len();
        let alpha = l.solve_lower_t(&l.solve_lower(ysc));
        // W = L⁻¹ as a dense lower triangle: column j solves L w = e_j.
        let mut w = vec![0.0; n * n];
        for j in 0..n {
            for i in j..n {
                let row = l.row(i);
                let mut sum = if i == j { 1.0 } else { 0.0 };
                for t in j..i {
                    sum -= row[t] * w[t * n + j];
                }
                w[i * n + j] = sum / row[i];
            }
        }
        // K⁻¹ = Wᵀ W; only the entries the two traces touch are formed.
        let kinv = |i: usize, j: usize| -> f64 {
            let lo = i.max(j);
            let mut s = 0.0;
            for t in lo..n {
                s += w[t * n + i] * w[t * n + j];
            }
            s
        };
        let mut g = if self.ard { vec![0.0; d + 1] } else { vec![0.0; 2] };
        if self.ard {
            // Off-diagonal cache entries are pure kernel values (noise
            // only sits on the diagonal); the symmetric pair halves
            // cancel the ½ in front of the trace.
            for i in 0..n {
                for j in 0..i {
                    let coeff = (alpha[i] * alpha[j] - kinv(i, j)) * k.at(i, j);
                    let sq = self.d2.at(i, j);
                    for (gt, &s) in g[..d].iter_mut().zip(sq) {
                        *gt += coeff * s;
                    }
                }
            }
            for (gt, &lsj) in g[..d].iter_mut().zip(ls) {
                *gt /= lsj * lsj;
            }
        } else {
            // Tied length-scale: the gradient of the common log-shift is
            // the sum of the per-dimension gradients.  With all entries
            // equal, summing each pair's distance block first and scaling
            // once reproduces the scalar implementation's arithmetic
            // bitwise; unequal (warm-started) entries take the weighted
            // per-pair sum instead.
            let mut g_ls = 0.0;
            match iso_lengthscale(ls) {
                Some(l0) => {
                    for i in 0..n {
                        for j in 0..i {
                            let s: f64 = self.d2.at(i, j).iter().sum();
                            g_ls += (alpha[i] * alpha[j] - kinv(i, j)) * k.at(i, j) * s;
                        }
                    }
                    g_ls /= l0 * l0;
                }
                None => {
                    let inv: Vec<f64> = ls.iter().map(|l| 1.0 / (l * l)).collect();
                    for i in 0..n {
                        for j in 0..i {
                            let s: f64 =
                                self.d2.at(i, j).iter().zip(&inv).map(|(q, w)| q * w).sum();
                            g_ls += (alpha[i] * alpha[j] - kinv(i, j)) * k.at(i, j) * s;
                        }
                    }
                }
            }
            g[0] = g_ls;
        }
        let mut g_noise = 0.0;
        for (i, a) in alpha.iter().enumerate() {
            g_noise += a * a - kinv(i, i);
        }
        g_noise *= 0.5 * s2n;
        *g.last_mut().expect("gradient has at least the noise entry") = g_noise;
        g
    }

    /// One adaptation round: up to [`MAX_ADAPT_STEPS`] backtracking ascent
    /// steps on the log marginal likelihood — over (ln ℓ₁..ln ℓ_d, ln σₙ²)
    /// under ARD, over the tied (ln ℓ, ln σₙ²) otherwise — each accepted
    /// only if the likelihood strictly increases.  The session commits the
    /// final kernel + factor once, at the end, and only when the
    /// hyper-parameters actually moved; a round that accepts nothing
    /// leaves the session bit-for-bit untouched.  No-op below
    /// [`MIN_ADAPT_OBS`] observations, and on [`HyperMode::Fixed`]
    /// sessions (which keep no distance cache to rebuild trial kernels
    /// from — Fixed means fixed).
    pub fn adapt(&mut self) -> AdaptOutcome {
        let n = self.y.len();
        if n < MIN_ADAPT_OBS || !matches!(self.hyper, HyperMode::Adapt { .. }) {
            return AdaptOutcome::unchanged();
        }
        let scaler = TargetScaler::fit(&self.y);
        let ysc: Vec<f64> = self.y.iter().map(|&v| scaler.transform(v)).collect();

        let ls0 = self.lengthscales.clone();
        let s2n0 = self.sigma_n2;
        let mut ls = ls0.clone();
        let mut s2n = s2n0;
        let mut k = self.k.clone();
        let mut l = self.l.clone();
        let mut ml = log_marginal_of(&l, &ysc);
        let mut trace = vec![ml];
        let mut steps = 0;

        while steps < MAX_ADAPT_STEPS {
            let g = self.ml_gradient(&k, &l, &ysc, &ls, s2n);
            let norm = if g.len() == 2 {
                g[0].hypot(g[1])
            } else {
                g.iter().map(|v| v * v).sum::<f64>().sqrt()
            };
            if !norm.is_finite() || norm < 1e-10 {
                break;
            }
            let dir: Vec<f64> = g.iter().map(|v| v / norm).collect();
            let dir_noise = *dir.last().expect("gradient has a noise entry");
            let mut accepted = false;
            let mut step = ADAPT_STEP0;
            for _ in 0..ADAPT_BACKTRACKS {
                let t_ls: Vec<f64> = if self.ard {
                    ls.iter()
                        .zip(&dir[..dir.len() - 1])
                        .map(|(l, d)| {
                            (l.ln() + step * d).exp().clamp(LS_BOUNDS.0, LS_BOUNDS.1)
                        })
                        .collect()
                } else {
                    ls.iter()
                        .map(|l| {
                            (l.ln() + step * dir[0]).exp().clamp(LS_BOUNDS.0, LS_BOUNDS.1)
                        })
                        .collect()
                };
                let t_s2n =
                    (s2n.ln() + step * dir_noise).exp().clamp(NOISE_BOUNDS.0, NOISE_BOUNDS.1);
                if t_ls == ls && t_s2n == s2n {
                    break; // clamped into a no-op: the box is binding
                }
                if let Some((tk, tl)) = self.kernel_at(&t_ls, t_s2n) {
                    let t_ml = log_marginal_of(&tl, &ysc);
                    if t_ml.is_finite() && t_ml > ml {
                        (ls, s2n, k, l, ml) = (t_ls, t_s2n, tk, tl, t_ml);
                        trace.push(ml);
                        steps += 1;
                        accepted = true;
                        break;
                    }
                }
                step *= 0.5;
            }
            if !accepted {
                break;
            }
        }

        let moved = ls != ls0 || s2n != s2n0;
        if moved {
            self.set_lengthscales(ls);
            self.sigma_n2 = s2n;
            self.k = k;
            self.l = l;
        }
        AdaptOutcome { ml: trace, steps, moved }
    }

    /// Append one row to every cache: the shared body of `observe` and
    /// `fantasize` — kernel row, factor push, input/target rows — with
    /// *no* adaptation bookkeeping, so a fantasy append is exactly the
    /// real append minus side effects (and therefore bitwise retractable
    /// by a last-row truncation).
    fn push_point(&mut self, x: &[f64], y: f64) -> Result<()> {
        anyhow::ensure!(
            x.len() == self.x.cols,
            "GP point dim {} != {}",
            x.len(),
            self.x.cols
        );
        anyhow::ensure!(self.y.len() < self.cap, "GP training rows at cap {}", self.cap);
        let n = self.y.len();
        let d = self.x.cols;
        // One distance pass fills both caches (the per-dimension distance
        // cache only under Adapt — Fixed never reads it); the kernel
        // values are the same f64s the scalar kval produced.
        let adaptive = matches!(self.hyper, HyperMode::Adapt { .. });
        let mut drow = Vec::with_capacity(if adaptive { (n + 1) * d } else { 0 });
        let mut krow = Vec::with_capacity(n + 1);
        let mut sq = vec![0.0; d];
        for j in 0..n {
            sqdist_dims(x, self.x.row(j), &mut sq);
            if adaptive {
                drow.extend_from_slice(&sq);
            }
            krow.push(self.kval_from_dims(&sq));
        }
        sqdist_dims(x, x, &mut sq);
        if adaptive {
            drow.extend_from_slice(&sq);
        }
        krow.push(self.kval_from_dims(&sq) + self.sigma_n2);
        anyhow::ensure!(
            cholesky_push(&mut self.l, &krow),
            "GP kernel matrix must be PD (jitter too small?)"
        );
        self.k.push_row(&krow);
        if adaptive {
            self.d2.push_row(&drow);
        }
        self.x.push_row(x);
        self.y.push(y);
        Ok(())
    }

    /// Score one candidate block under the session's [`KernelPolicy`]:
    /// kernel rows, one multi-RHS forward solve over the whole block,
    /// then (ei, mu, sigma) per candidate.
    fn score_block(&self, cands: &[Vec<f64>], alpha: &[f64], best_sc: f64) -> Vec<(f64, f64, f64)> {
        match self.kernels {
            KernelPolicy::Scalar => self.score_block_scalar(cands, alpha, best_sc),
            KernelPolicy::Blocked => self.score_block_blocked(cands, alpha, best_sc),
        }
    }

    /// Scalar-tier block scoring.  The forward solves are interleaved —
    /// each factor row streamed once per block — through the k-major
    /// scalar-order multi-RHS solve, whose *per-candidate* operation
    /// order is exactly `solve_lower`'s, so every (ei, mu, sigma) is
    /// bit-identical to the one-shot path at any pool width.
    fn score_block_scalar(
        &self,
        cands: &[Vec<f64>],
        alpha: &[f64],
        best_sc: f64,
    ) -> Vec<(f64, f64, f64)> {
        let n = self.y.len();
        let bs = cands.len();
        let mut sq = vec![0.0; self.x.cols];
        // Candidate-major kernel rows k(c, x_j).
        let mut kc = vec![0.0; bs * n];
        for (c, cand) in cands.iter().enumerate() {
            let row = &mut kc[c * n..(c + 1) * n];
            for (j, o) in row.iter_mut().enumerate() {
                sqdist_dims(cand, self.x.row(j), &mut sq);
                *o = self.kval_from_dims(&sq);
            }
        }
        // k-major right-hand sides (the transpose is pure copying, no
        // arithmetic): the multi-RHS solve's innermost loop is contiguous
        // across candidates.
        let mut v = vec![0.0; n * bs];
        for c in 0..bs {
            for j in 0..n {
                v[j * bs + c] = kc[c * n + j];
            }
        }
        solve_lower_multi(&self.l, &mut v, bs, KernelPolicy::Scalar);
        let mut out = Vec::with_capacity(bs);
        for c in 0..bs {
            let kci = &kc[c * n..(c + 1) * n];
            let m: f64 = kci.iter().zip(alpha).map(|(a, b)| a * b).sum();
            let mut s2 = 0.0;
            for k in 0..n {
                let vc = v[k * bs + c];
                s2 += vc * vc;
            }
            let var = (self.sigma_f2 - s2).max(1e-12);
            let s = var.sqrt();
            out.push((expected_improvement(m, s, best_sc), m, s));
        }
        out
    }

    /// Blocked-tier block scoring: fixed-lane kernel rows, the panel/lane
    /// multi-RHS solve, lane-reduced posterior terms.  Same terms as the
    /// scalar tier in a different summation order — 1e-8-pinned by
    /// `tests/gp_kernels.rs` — and bitwise self-reproducible at any pool
    /// width (every block size is an algorithm constant).
    fn score_block_blocked(
        &self,
        cands: &[Vec<f64>],
        alpha: &[f64],
        best_sc: f64,
    ) -> Vec<(f64, f64, f64)> {
        let n = self.y.len();
        let bs = cands.len();
        let mut sq = vec![0.0; self.x.cols];
        let mut kc = vec![0.0; bs * n];
        for (c, cand) in cands.iter().enumerate() {
            let row = &mut kc[c * n..(c + 1) * n];
            for (j, o) in row.iter_mut().enumerate() {
                sqdist_dims(cand, self.x.row(j), &mut sq);
                *o = kval_blocked(&sq, self.iso, &self.inv2, self.sigma_f2);
            }
        }
        let mut v = vec![0.0; n * bs];
        for c in 0..bs {
            for j in 0..n {
                v[j * bs + c] = kc[c * n + j];
            }
        }
        solve_lower_multi(&self.l, &mut v, bs, KernelPolicy::Blocked);
        let mut col = vec![0.0; n];
        let mut out = Vec::with_capacity(bs);
        for c in 0..bs {
            let kci = &kc[c * n..(c + 1) * n];
            let m = lane_dot(kci, alpha);
            for (k, o) in col.iter_mut().enumerate() {
                *o = v[k * bs + c];
            }
            let s2 = lane_dot(&col, &col);
            let var = (self.sigma_f2 - s2).max(1e-12);
            let s = var.sqrt();
            out.push((expected_improvement(m, s, best_sc), m, s));
        }
        out
    }
}

/// `-½ yᵀα − Σᵢ ln Lᵢᵢ − (n/2) ln 2π` from a cached factor (the second
/// term is `-½ ln|K|`).
fn log_marginal_of(l: &PackedLower, ysc: &[f64]) -> f64 {
    let n = l.n();
    let alpha = l.solve_lower_t(&l.solve_lower(ysc));
    let fit: f64 = ysc.iter().zip(&alpha).map(|(y, a)| y * a).sum();
    let half_logdet: f64 = (0..n).map(|i| l.at(i, i).ln()).sum();
    -0.5 * fit - half_logdet - 0.5 * (n as f64) * (2.0 * std::f64::consts::PI).ln()
}

impl GpSession for GpSurrogate {
    fn len(&self) -> usize {
        self.y.len()
    }

    fn ys(&self) -> &[f64] {
        &self.y
    }

    /// Current (per-dimension length-scales, noise variance) — moves
    /// under [`HyperMode::Adapt`], frozen otherwise.
    fn hypers(&self) -> (Vec<f64>, f64) {
        (self.lengthscales.clone(), self.sigma_n2)
    }

    fn observe(&mut self, x: &[f64], y: f64) -> Result<()> {
        anyhow::ensure!(
            self.fantasies == 0,
            "observe inside an open fantasy scope ({} unpopped)",
            self.fantasies
        );
        self.push_point(x, y)?;
        if let HyperMode::Adapt { every } = self.hyper {
            self.appends += 1;
            // A session being *used* — acquires interleaving the appends
            // — honours the user cadence exactly: every intermediate
            // model is read.  A bulk feed (warm start, the BO init
            // design: no acquire since the last round) amortizes to one
            // round per ~25% training-set growth instead, costing
            // O(log n) rounds rather than n/every O(n³) rounds whose
            // intermediate hypers nothing ever reads.
            let bulk = self.acquires.load(Ordering::Relaxed) == self.acquires_at_adapt;
            let gate =
                if bulk { every.max(1).max(self.y.len() / 4) } else { every.max(1) };
            if self.appends >= gate && self.y.len() >= MIN_ADAPT_OBS {
                self.appends = 0;
                self.acquires_at_adapt = self.acquires.load(Ordering::Relaxed);
                self.adapt();
            }
        }
        Ok(())
    }

    fn forget(&mut self, i: usize) -> Result<()> {
        anyhow::ensure!(
            self.fantasies == 0,
            "forget inside an open fantasy scope ({} unpopped)",
            self.fantasies
        );
        anyhow::ensure!(i < self.y.len(), "forget({i}) of {} rows", self.y.len());
        match self.hyper {
            HyperMode::Fixed => {
                // The factor's prefix property breaks on eviction: full
                // refactor from the (still exact) kernel cache — O(n³),
                // but bit-identical to a scratch fit, which is what Fixed
                // promises.  Refactor a scratch copy first so a failure
                // leaves the session untouched (and usable) instead of
                // with a factor shorter than its training set.
                let mut k = self.k.clone();
                k.remove(i);
                let mut l = PackedLower::new();
                let pd = if self.kernels == KernelPolicy::Blocked {
                    cholesky_rebuild_blocked(&k, &mut l)
                } else {
                    cholesky_rebuild(&k, &mut l)
                };
                anyhow::ensure!(pd, "GP kernel matrix must be PD (jitter too small?)");
                self.k = k;
                self.l = l;
            }
            HyperMode::Adapt { .. } => {
                // O(n²) rank-1 downdate of the cached factor: infallible
                // on a valid factor (positive Givens pivots), equal to
                // the rebuild up to rotation round-off.  The distance
                // cache splices the evicted pair blocks out in O(n²d).
                self.k.remove(i);
                self.d2.remove(i);
                cholesky_downdate(&mut self.l, i);
            }
        }
        self.x.remove_row(i);
        self.y.remove(i);
        Ok(())
    }

    /// Fantasy append: the exact `observe` arithmetic (shared
    /// `push_point`) with no adaptation cadence and no append counter —
    /// the transient row must leave zero trace once popped.
    fn fantasize(&mut self, x: &[f64], y_liar: f64) -> Result<()> {
        self.push_point(x, y_liar)?;
        self.fantasies += 1;
        Ok(())
    }

    /// Retract the newest fantasy row from every cache.  On the last row
    /// `cholesky_downdate` is a pure truncation — the bitwise inverse of
    /// the `cholesky_push` that appended it (pinned by
    /// `tests/property_invariants.rs`) — so this is valid in Fixed mode
    /// too, where interior evictions would demand a rebuild.
    fn pop_fantasy(&mut self) -> Result<()> {
        anyhow::ensure!(self.fantasies > 0, "pop_fantasy with no open fantasy");
        let last = self.y.len() - 1;
        cholesky_downdate(&mut self.l, last);
        self.k.remove(last);
        if matches!(self.hyper, HyperMode::Adapt { .. }) {
            self.d2.remove(last);
        }
        self.x.remove_row(last);
        self.y.pop();
        self.fantasies -= 1;
        Ok(())
    }

    fn acquire(
        &self,
        pool: &ExecPool,
        xc: &[Vec<f64>],
        best: f64,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
        let n = self.y.len();
        anyhow::ensure!(n > 0, "GP needs observations before acquisition");
        // Counted once here, on the calling thread, before the fan-out:
        // the adaptation cadence uses it to tell an actively-driven
        // session from a bulk feed.
        self.acquires.fetch_add(1, Ordering::Relaxed);
        let scaler = TargetScaler::fit(&self.y);
        let ysc: Vec<f64> = self.y.iter().map(|&v| scaler.transform(v)).collect();
        let best_sc = scaler.transform(best);
        let alpha = self.l.solve_lower_t(&self.l.solve_lower(&ysc));

        let scored =
            pool.par_chunks(xc, EI_BLOCK, |_, block| self.score_block(block, &alpha, best_sc));
        let mut ei = Vec::with_capacity(xc.len());
        let mut mu = Vec::with_capacity(xc.len());
        let mut sigma = Vec::with_capacity(xc.len());
        for (e, m, s) in scored {
            ei.push(e);
            mu.push(m);
            sigma.push(s);
        }
        Ok((ei, mu, sigma))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::ops::gp_ei;
    use crate::util::rng::Pcg;

    fn rand_rows(n: usize, d: usize, rng: &mut Pcg) -> Vec<Vec<f64>> {
        (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect()
    }

    fn cfg(d: usize) -> GpConfig {
        GpConfig::isotropic(d, 0.8, 1.0, 0.01, 64, HyperMode::Fixed)
    }

    /// The incremental surrogate must reproduce the one-shot `gp_ei`
    /// posterior bitwise (acquire standardizes internally, so compare
    /// against gp_ei on pre-standardized targets).
    #[test]
    fn incremental_matches_one_shot_bitwise() {
        let mut rng = Pcg::new(21);
        let d = 5;
        let xs = rand_rows(30, d, &mut rng);
        let ys: Vec<f64> = xs.iter().map(|r| r[0] * 2.0 + r[1] - r[2]).collect();
        let xc = rand_rows(100, d, &mut rng);
        let c = cfg(d);

        let mut gp = GpSurrogate::new(&c);
        for (x, &y) in xs.iter().zip(&ys) {
            gp.observe(x, y).unwrap();
        }
        let best = ys.iter().cloned().fold(f64::INFINITY, f64::min);
        let (ei, mu, sigma) = gp.acquire(&ExecPool::serial(), &xc, best).unwrap();

        let scaler = TargetScaler::fit(&ys);
        let ysc: Vec<f64> = ys.iter().map(|&v| scaler.transform(v)).collect();
        let (e2, m2, s2) = gp_ei(
            &xs,
            &ysc,
            &xc,
            &c.lengthscales,
            c.sigma_f2,
            c.sigma_n2,
            scaler.transform(best),
        );
        assert_eq!(bits(&ei), bits(&e2));
        assert_eq!(bits(&mu), bits(&m2));
        assert_eq!(bits(&sigma), bits(&s2));
    }

    /// The same bitwise session-vs-one-shot identity with *unequal*
    /// per-dimension length-scales: both sides must use the same weighted
    /// per-dimension summation.
    #[test]
    fn incremental_matches_one_shot_bitwise_under_ard_lengthscales() {
        let mut rng = Pcg::new(27);
        let d = 4;
        let mut c = cfg(d);
        c.lengthscales = vec![0.3, 0.9, 2.0, 0.55];
        let xs = rand_rows(26, d, &mut rng);
        let ys: Vec<f64> = xs.iter().map(|r| (r[0] * 5.0).sin() - r[3]).collect();
        let xc = rand_rows(90, d, &mut rng);

        let mut gp = GpSurrogate::new(&c);
        for (x, &y) in xs.iter().zip(&ys) {
            gp.observe(x, y).unwrap();
        }
        let best = ys.iter().cloned().fold(f64::INFINITY, f64::min);
        let (ei, mu, sigma) = gp.acquire(&ExecPool::serial(), &xc, best).unwrap();

        let scaler = TargetScaler::fit(&ys);
        let ysc: Vec<f64> = ys.iter().map(|&v| scaler.transform(v)).collect();
        let (e2, m2, s2) = gp_ei(
            &xs,
            &ysc,
            &xc,
            &c.lengthscales,
            c.sigma_f2,
            c.sigma_n2,
            scaler.transform(best),
        );
        assert_eq!(bits(&ei), bits(&e2));
        assert_eq!(bits(&mu), bits(&m2));
        assert_eq!(bits(&sigma), bits(&s2));
    }

    #[test]
    fn pool_width_never_changes_acquisition() {
        let mut rng = Pcg::new(22);
        let d = 4;
        let xs = rand_rows(25, d, &mut rng);
        let ys: Vec<f64> = xs.iter().map(|r| (r[0] * 3.0).sin() + r[3]).collect();
        let xc = rand_rows(70, d, &mut rng); // not a multiple of EI_BLOCK
        let mut gp = GpSurrogate::new(&cfg(d));
        for (x, &y) in xs.iter().zip(&ys) {
            gp.observe(x, y).unwrap();
        }
        let serial = gp.acquire(&ExecPool::serial(), &xc, 0.1).unwrap();
        for width in [2, 3, 8] {
            let par = gp.acquire(&ExecPool::new(width), &xc, 0.1).unwrap();
            assert_eq!(bits(&serial.0), bits(&par.0), "width {width}");
            assert_eq!(bits(&serial.1), bits(&par.1), "width {width}");
            assert_eq!(bits(&serial.2), bits(&par.2), "width {width}");
        }
    }

    #[test]
    fn forget_rebuilds_factor_exactly() {
        let mut rng = Pcg::new(23);
        let d = 3;
        let xs = rand_rows(20, d, &mut rng);
        let ys: Vec<f64> = xs.iter().map(|r| r.iter().sum()).collect();
        let xc = rand_rows(40, d, &mut rng);
        let c = cfg(d);

        let mut gp = GpSurrogate::new(&c);
        for (x, &y) in xs.iter().zip(&ys) {
            gp.observe(x, y).unwrap();
        }
        gp.forget(7).unwrap();
        assert_eq!(gp.len(), 19);

        // reference: a fresh surrogate over the surviving points
        let mut fresh = GpSurrogate::new(&c);
        for (i, (x, &y)) in xs.iter().zip(&ys).enumerate() {
            if i != 7 {
                fresh.observe(x, y).unwrap();
            }
        }
        // Factors may differ (prefix property broke) in general, but the
        // posterior must match the scratch fit bitwise.
        let a = gp.acquire(&ExecPool::serial(), &xc, 0.5).unwrap();
        let b = fresh.acquire(&ExecPool::serial(), &xc, 0.5).unwrap();
        assert_eq!(bits(&a.0), bits(&b.0));
        assert_eq!(bits(&a.1), bits(&b.1));
        assert_eq!(bits(&a.2), bits(&b.2));
    }

    #[test]
    fn downdate_forget_keeps_session_usable() {
        let mut rng = Pcg::new(25);
        let d = 4;
        let mut c = cfg(d);
        // Adaptation disabled (`every` never reached): isolates the
        // downdate eviction path.
        c.hyper = HyperMode::Adapt { every: usize::MAX };
        let mut gp = GpSurrogate::new(&c);
        let xs = rand_rows(18, d, &mut rng);
        for (i, x) in xs.iter().enumerate() {
            gp.observe(x, (i as f64 * 0.7).sin()).unwrap();
        }
        for idx in [0usize, 8, 14] {
            gp.forget(idx).unwrap();
        }
        assert_eq!(gp.len(), 15);
        gp.observe(&[0.2, 0.4, 0.6, 0.8], 0.3).unwrap();
        let xc = rand_rows(20, d, &mut rng);
        let (ei, mu, sigma) = gp.acquire(&ExecPool::serial(), &xc, 0.1).unwrap();
        for v in ei.iter().chain(&mu).chain(&sigma) {
            assert!(v.is_finite());
        }
    }

    #[test]
    fn adapt_below_min_obs_is_a_noop() {
        let mut c = cfg(2);
        c.hyper = HyperMode::adapt();
        let mut gp = GpSurrogate::new(&c);
        for i in 0..(MIN_ADAPT_OBS - 1) {
            gp.observe(&[i as f64 * 0.1, 0.5], i as f64).unwrap();
        }
        let out = gp.adapt();
        assert_eq!(out.steps, 0);
        assert!(!out.moved);
        assert_eq!(gp.hypers(), (c.lengthscales.clone(), c.sigma_n2));
    }

    #[test]
    fn fixed_mode_never_moves_hypers() {
        let mut rng = Pcg::new(26);
        let d = 3;
        let c = cfg(d);
        let mut gp = GpSurrogate::new(&c);
        for x in rand_rows(30, d, &mut rng) {
            let y = (x[0] * 9.0).sin();
            gp.observe(&x, y).unwrap();
        }
        assert_eq!(gp.hypers(), (c.lengthscales.clone(), c.sigma_n2));
        // Even an explicit adapt() call is a no-op on a Fixed session:
        // it keeps no distance cache, and Fixed means fixed.
        let out = gp.adapt();
        assert!(!out.moved);
        assert_eq!(out.steps, 0);
        assert_eq!(gp.hypers(), (c.lengthscales.clone(), c.sigma_n2));
    }

    /// The fantasy scope is exclusive: real mutations refuse to run with
    /// fantasies open, and a pop with nothing open errors.
    #[test]
    fn fantasy_scope_guards() {
        let mut gp = GpSurrogate::new(&cfg(2));
        let mut rng = Pcg::new(30);
        for i in 0..5 {
            gp.observe(&[rng.f64(), rng.f64()], i as f64).unwrap();
        }
        assert!(gp.pop_fantasy().is_err(), "no open fantasy to pop");
        gp.fantasize(&[0.5, 0.5], 4.0).unwrap();
        assert_eq!(gp.len(), 6);
        assert!(gp.observe(&[0.1, 0.1], 1.0).is_err(), "observe must wait for pops");
        assert!(gp.forget(0).is_err(), "forget must wait for pops");
        gp.pop_fantasy().unwrap();
        assert_eq!(gp.len(), 5);
        gp.observe(&[0.1, 0.1], 1.0).unwrap();
        assert_eq!(gp.len(), 6);
    }

    #[test]
    fn observe_past_cap_errors() {
        let d = 2;
        let mut c = cfg(d);
        c.cap = 3;
        let mut gp = GpSurrogate::new(&c);
        let mut rng = Pcg::new(24);
        for i in 0..3 {
            gp.observe(&[rng.f64(), rng.f64()], i as f64).unwrap();
        }
        assert!(gp.observe(&[0.5, 0.5], 9.0).is_err());
        assert!(gp.observe(&[0.5], 9.0).is_err(), "dim mismatch must error");
    }

    /// ARD-off adaptation keeps the length-scales tied: after any number
    /// of accepted steps every per-dimension entry is still (bitwise) the
    /// same value.
    #[test]
    fn tied_adaptation_keeps_lengthscales_equal() {
        let d = 3;
        let mut c = cfg(d);
        c.hyper = HyperMode::Adapt { every: usize::MAX };
        c.lengthscales = vec![6.0; d]; // grossly long: a step must land
        let mut gp = GpSurrogate::new(&c);
        let mut rng = Pcg::new(28);
        for x in rand_rows(24, d, &mut rng) {
            let y = (x[0] * 6.0).sin() + x[1];
            gp.observe(&x, y).unwrap();
        }
        let out = gp.adapt();
        assert!(out.steps >= 1);
        let (ls, _) = gp.hypers();
        assert!(ls.windows(2).all(|w| w[0] == w[1]), "tied scales split: {ls:?}");
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }
}
