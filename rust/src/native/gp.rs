//! Incremental GP surrogate — the native backend's `GpSession`.
//!
//! The one-shot `gp_ei` path rebuilds the full n×n RBF kernel and
//! refactors it with an O(n³) Cholesky on *every* BO iteration, then
//! scores each candidate serially.  This module keeps the surrogate
//! stateful across iterations instead:
//!
//! * **Kernel cache** (`PackedLower`): appending an observation computes
//!   one kernel row in O(nd); evicting one splices a row/column out in
//!   O(n²).  Entries are pure functions of the point pair, so cached and
//!   freshly-built kernels are the same f64s.  A parallel
//!   squared-distance cache (hyper-parameter independent) lets the whole
//!   kernel be re-materialized for *new* hyper-parameters in O(n²)
//!   instead of O(n²d).
//! * **Cached Cholesky** (`cholesky_push`): row-wise Cholesky only reads
//!   *prior* rows, so extending the factor by the new kernel row in O(n²)
//!   is bit-identical to refactoring from scratch.  Eviction depends on
//!   the session's [`HyperMode`]: `Fixed` refactors the cached kernel
//!   from scratch (O(n³), keeps the bitwise contract below); `Adapt`
//!   runs the O(n²) Givens `cholesky_downdate`, whose factor matches a
//!   refactor only to rotation round-off.
//! * **Hyper-parameter adaptation** (`Adapt` only): every `every`
//!   appends on an actively-driven session (acquires interleaving the
//!   appends), amortized to one round per ~25% training-set growth
//!   during a bulk feed (warm start — nothing reads the intermediate
//!   hypers, so O(log n) rounds suffice), the session takes up to
//!   [`MAX_ADAPT_STEPS`] backtracking
//!   ascent steps on the log marginal likelihood over
//!   (log length-scale, log noise), with the analytic gradient
//!   `∂L/∂θ = ½ tr((ααᵀ − K⁻¹) ∂K/∂θ)` computed from the cached factor.
//!   A step is accepted only if the marginal likelihood increases (the
//!   trace is monotone by construction — `tests/gp_downdate.rs`), and the
//!   session's kernel + factor are swapped once, at the end, only when
//!   the hyper-parameters actually moved.
//! * **Sharded acquisition**: candidates are scored in fixed
//!   [`EI_BLOCK`]-wide blocks fanned out on an [`ExecPool`], results in
//!   index order.  Within a block the forward solves are interleaved —
//!   each factor row is streamed once per block instead of once per
//!   candidate, and the per-candidate accumulators are independent, which
//!   breaks the scalar latency chain of a lone triangular solve.  The
//!   *per-candidate* operation order is exactly `solve_lower`'s, so every
//!   (ei, mu, sigma) is bit-identical to the one-shot path at any pool
//!   width — the same guarantee the exec subsystem gives the evaluation
//!   paths (guarded by `tests/gp_incremental.rs`).
//!
//! **Equality contract** (the Fixed-vs-Adapt line the tests pin):
//! `HyperMode::Fixed` is bitwise-equal to the one-shot `gp_ei` reference
//! at every pool width, including across evictions
//! (`tests/gp_incremental.rs`).  `HyperMode::Adapt` keeps the same
//! per-candidate scoring arithmetic but evicts via downdate — predictions
//! after any eviction sequence match the rebuild path within 1e-8
//! (`tests/gp_downdate.rs`) — and, once adaptation fires, intentionally
//! diverges from the fixed-hyper reference (a better-fitting model, not a
//! numerical error).
//!
//! `cargo bench --bench surrogate` times three scenarios — one-shot vs
//! incremental acquisition (n∈{64,128,256}, m=1024; design target ≥5x at
//! n=256), eviction-heavy downdate vs rebuild-per-eviction at the cap
//! (downdate designed to win at n=256), and adaptation on/off overhead —
//! and writes them to `BENCH_surrogate.json` at the repo root.

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::Result;

use super::linalg::{cholesky_downdate, cholesky_push, cholesky_rebuild, Mat, PackedLower};
use super::ops::expected_improvement;
use crate::exec::ExecPool;
use crate::runtime::{GpConfig, GpSession, HyperMode};
use crate::util::stats::TargetScaler;

/// Candidates per pool task.  One block shares each streamed factor row
/// across all its forward solves and gives the compiler independent
/// accumulators to pipeline/vectorize; the size is a constant of the
/// algorithm (never derived from pool width), so chunking cannot leak
/// into results.
const EI_BLOCK: usize = 16;

/// Adaptation starts once the training set can support a likelihood
/// gradient that is more signal than noise.
const MIN_ADAPT_OBS: usize = 8;
/// Accepted ascent steps per adaptation round ("a few bounded steps").
pub const MAX_ADAPT_STEPS: usize = 4;
/// Backtracking halvings per step before the round gives up.
const ADAPT_BACKTRACKS: usize = 6;
/// Initial step along the normalized gradient, in log-hyper space: each
/// accepted step moves the hypers by at most `e^0.5 ≈ 1.65x`.
const ADAPT_STEP0: f64 = 0.5;
/// Length-scale box (unit-cube inputs: anything outside is degenerate).
const LS_BOUNDS: (f64, f64) = (1e-2, 1e2);
/// Noise-variance box (targets are standardized before fitting).
const NOISE_BOUNDS: (f64, f64) = (1e-8, 1.0);

/// Squared euclidean distance — the exact summation order `ops::rbf` and
/// the old inline `kval` used, so kernels built from cached distances
/// stay bitwise-equal to fresh builds.
#[inline]
fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// What one adaptation round did — returned by [`GpSurrogate::adapt`] so
/// the differential tests can assert monotonicity directly.
#[derive(Clone, Debug)]
pub struct AdaptOutcome {
    /// Marginal-likelihood trace: the starting value, then one entry per
    /// *accepted* ascent step.  Non-decreasing by construction.
    pub ml: Vec<f64>,
    /// Accepted steps this round.
    pub steps: usize,
    /// Whether the hyper-parameters moved (and the cached kernel +
    /// factor were therefore swapped for refactored ones).
    pub moved: bool,
}

impl AdaptOutcome {
    fn unchanged() -> AdaptOutcome {
        AdaptOutcome { ml: Vec::new(), steps: 0, moved: false }
    }
}

/// Stateful GP surrogate with cached kernel + Cholesky factor.
pub struct GpSurrogate {
    lengthscale: f64,
    sigma_f2: f64,
    sigma_n2: f64,
    cap: usize,
    hyper: HyperMode,
    /// Training inputs, one flat row each.
    x: Mat,
    /// Raw (unstandardized) targets, observation order.
    y: Vec<f64>,
    /// Kernel cache K + sigma_n2 I (lower triangle, diagonal included).
    k: PackedLower,
    /// Cholesky factor of `k`.
    l: PackedLower,
    /// Squared-distance cache (zero diagonal) — hyper-parameter free, so
    /// adaptation can rebuild `k` for trial hypers in O(n²).  Maintained
    /// only under [`HyperMode::Adapt`]; `Fixed` sessions never read it,
    /// so they skip its storage and splice costs entirely.
    d2: PackedLower,
    /// Appends since the last adaptation round.
    appends: usize,
    /// Acquisitions served so far (atomic: `acquire` takes `&self` and
    /// is shared across pool threads; incremented once per call on the
    /// calling thread, so it stays deterministic).
    acquires: AtomicUsize,
    /// `acquires` value when the last adaptation round ran — appends
    /// with no acquire in between are a *bulk feed*, whose intermediate
    /// hyper-parameters nothing ever reads.
    acquires_at_adapt: usize,
}

impl GpSurrogate {
    pub fn new(cfg: &GpConfig) -> GpSurrogate {
        GpSurrogate {
            lengthscale: cfg.lengthscale,
            sigma_f2: cfg.sigma_f2,
            sigma_n2: cfg.sigma_n2,
            cap: cfg.cap,
            hyper: cfg.hyper,
            x: Mat::with_row_capacity(cfg.cap, cfg.dim),
            y: Vec::new(),
            k: PackedLower::new(),
            l: PackedLower::new(),
            d2: PackedLower::new(),
            appends: 0,
            acquires: AtomicUsize::new(0),
            acquires_at_adapt: 0,
        }
    }

    /// Current (lengthscale, noise variance) — moves under
    /// [`HyperMode::Adapt`], frozen otherwise.
    pub fn hypers(&self) -> (f64, f64) {
        (self.lengthscale, self.sigma_n2)
    }

    /// k(a, b) — the same expression (same evaluation order) as
    /// `ops::rbf`, so cached entries match a fresh kernel build bitwise.
    #[inline]
    fn kval(&self, a: &[f64], b: &[f64]) -> f64 {
        self.kval_from_sq(sqdist(a, b))
    }

    /// The kernel value for a cached squared distance — identical
    /// arithmetic to `kval`, factored out so observe fills both caches
    /// from one distance pass.
    #[inline]
    fn kval_from_sq(&self, sq: f64) -> f64 {
        let inv = 1.0 / (2.0 * self.lengthscale * self.lengthscale);
        self.sigma_f2 * (-sq * inv).exp()
    }

    /// Log marginal likelihood of the *standardized* targets under the
    /// current hyper-parameters, evaluated from the cached factor:
    /// `-½ yᵀα − Σᵢ ln Lᵢᵢ − (n/2) ln 2π`.  `-inf` on an empty session.
    pub fn log_marginal(&self) -> f64 {
        if self.y.is_empty() {
            return f64::NEG_INFINITY;
        }
        let scaler = TargetScaler::fit(&self.y);
        let ysc: Vec<f64> = self.y.iter().map(|&v| scaler.transform(v)).collect();
        log_marginal_of(&self.l, &ysc)
    }

    /// Rebuild the packed kernel (noise on the diagonal) and its factor
    /// at trial hyper-parameters, from the distance cache.  `None` if the
    /// trial kernel is not positive definite (trial rejected).
    fn kernel_at(&self, ls: f64, s2n: f64) -> Option<(PackedLower, PackedLower)> {
        let inv = 1.0 / (2.0 * ls * ls);
        let n = self.y.len();
        let mut k = PackedLower::new();
        for i in 0..n {
            let mut row: Vec<f64> =
                self.d2.row(i).iter().map(|&sq| self.sigma_f2 * (-sq * inv).exp()).collect();
            row[i] += s2n; // d2 diagonal is 0, so row[i] was exactly sigma_f2
            k.push_row(&row);
        }
        let mut l = PackedLower::new();
        if cholesky_rebuild(&k, &mut l) {
            Some((k, l))
        } else {
            None
        }
    }

    /// Analytic gradient of the log marginal likelihood w.r.t.
    /// (log lengthscale, log noise variance), from a factor of `k`:
    /// `∂L/∂θ = ½ Σᵢⱼ (αᵢαⱼ − K⁻¹ᵢⱼ) ∂Kᵢⱼ/∂θ`, with
    /// `∂K/∂(ln ℓ) = K̃ ∘ D²/ℓ²` (zero diagonal) and
    /// `∂K/∂(ln σₙ²) = σₙ² I`.  Cost O(n³/2) for the explicit `K⁻¹`,
    /// paid only once per adaptation round per accepted step.
    fn ml_gradient(
        &self,
        k: &PackedLower,
        l: &PackedLower,
        ysc: &[f64],
        ls: f64,
        s2n: f64,
    ) -> (f64, f64) {
        let n = k.n();
        let alpha = l.solve_lower_t(&l.solve_lower(ysc));
        // W = L⁻¹ as a dense lower triangle: column j solves L w = e_j.
        let mut w = vec![0.0; n * n];
        for j in 0..n {
            for i in j..n {
                let row = l.row(i);
                let mut sum = if i == j { 1.0 } else { 0.0 };
                for t in j..i {
                    sum -= row[t] * w[t * n + j];
                }
                w[i * n + j] = sum / row[i];
            }
        }
        // K⁻¹ = Wᵀ W; only the entries the two traces touch are formed.
        let kinv = |i: usize, j: usize| -> f64 {
            let lo = i.max(j);
            let mut s = 0.0;
            for t in lo..n {
                s += w[t * n + i] * w[t * n + j];
            }
            s
        };
        let mut g_ls = 0.0;
        for i in 0..n {
            for j in 0..i {
                // Off-diagonal cache entries are pure kernel values (noise
                // only sits on the diagonal); the symmetric pair halves
                // cancel the ½ in front of the trace.
                g_ls += (alpha[i] * alpha[j] - kinv(i, j)) * k.at(i, j) * self.d2.at(i, j);
            }
        }
        g_ls /= ls * ls;
        let mut g_noise = 0.0;
        for (i, a) in alpha.iter().enumerate() {
            g_noise += a * a - kinv(i, i);
        }
        g_noise *= 0.5 * s2n;
        (g_ls, g_noise)
    }

    /// One adaptation round: up to [`MAX_ADAPT_STEPS`] backtracking ascent
    /// steps on the log marginal likelihood over (ln ℓ, ln σₙ²), each
    /// accepted only if the likelihood strictly increases.  The session
    /// commits the final kernel + factor once, at the end, and only when
    /// the hyper-parameters actually moved; a round that accepts nothing
    /// leaves the session bit-for-bit untouched.  No-op below
    /// [`MIN_ADAPT_OBS`] observations, and on [`HyperMode::Fixed`]
    /// sessions (which keep no distance cache to rebuild trial kernels
    /// from — Fixed means fixed).
    pub fn adapt(&mut self) -> AdaptOutcome {
        let n = self.y.len();
        if n < MIN_ADAPT_OBS || !matches!(self.hyper, HyperMode::Adapt { .. }) {
            return AdaptOutcome::unchanged();
        }
        let scaler = TargetScaler::fit(&self.y);
        let ysc: Vec<f64> = self.y.iter().map(|&v| scaler.transform(v)).collect();

        let (ls0, s2n0) = (self.lengthscale, self.sigma_n2);
        let mut ls = ls0;
        let mut s2n = s2n0;
        let mut k = self.k.clone();
        let mut l = self.l.clone();
        let mut ml = log_marginal_of(&l, &ysc);
        let mut trace = vec![ml];
        let mut steps = 0;

        while steps < MAX_ADAPT_STEPS {
            let (g_ls, g_noise) = self.ml_gradient(&k, &l, &ysc, ls, s2n);
            let norm = g_ls.hypot(g_noise);
            if !norm.is_finite() || norm < 1e-10 {
                break;
            }
            let (dir_ls, dir_noise) = (g_ls / norm, g_noise / norm);
            let mut accepted = false;
            let mut step = ADAPT_STEP0;
            for _ in 0..ADAPT_BACKTRACKS {
                let t_ls = (ls.ln() + step * dir_ls).exp().clamp(LS_BOUNDS.0, LS_BOUNDS.1);
                let t_s2n =
                    (s2n.ln() + step * dir_noise).exp().clamp(NOISE_BOUNDS.0, NOISE_BOUNDS.1);
                if t_ls == ls && t_s2n == s2n {
                    break; // clamped into a no-op: the box is binding
                }
                if let Some((tk, tl)) = self.kernel_at(t_ls, t_s2n) {
                    let t_ml = log_marginal_of(&tl, &ysc);
                    if t_ml.is_finite() && t_ml > ml {
                        (ls, s2n, k, l, ml) = (t_ls, t_s2n, tk, tl, t_ml);
                        trace.push(ml);
                        steps += 1;
                        accepted = true;
                        break;
                    }
                }
                step *= 0.5;
            }
            if !accepted {
                break;
            }
        }

        let moved = ls != ls0 || s2n != s2n0;
        if moved {
            self.lengthscale = ls;
            self.sigma_n2 = s2n;
            self.k = k;
            self.l = l;
        }
        AdaptOutcome { ml: trace, steps, moved }
    }

    /// Score one candidate block: kernel rows, interleaved forward solves
    /// (per-candidate op order identical to `solve_lower`), then
    /// (ei, mu, sigma) per candidate.
    fn score_block(&self, cands: &[Vec<f64>], alpha: &[f64], best_sc: f64) -> Vec<(f64, f64, f64)> {
        let n = self.y.len();
        let bs = cands.len();
        // Candidate-major kernel rows k(c, x_j).
        let mut kc = vec![0.0; bs * n];
        for (c, cand) in cands.iter().enumerate() {
            let row = &mut kc[c * n..(c + 1) * n];
            for (j, o) in row.iter_mut().enumerate() {
                *o = self.kval(cand, self.x.row(j));
            }
        }
        // Interleaved forward solve L v = kc^T, v stored k-major so the
        // innermost loop is contiguous across candidates.
        let mut v = vec![0.0; n * bs];
        let mut acc = vec![0.0; bs];
        for i in 0..n {
            let li = self.l.row(i);
            for (c, a) in acc.iter_mut().enumerate() {
                *a = kc[c * n + i];
            }
            for (k, &lk) in li[..i].iter().enumerate() {
                let vk = &v[k * bs..k * bs + bs];
                for (a, &vv) in acc.iter_mut().zip(vk) {
                    *a -= lk * vv;
                }
            }
            let d = li[i];
            for (o, &a) in v[i * bs..i * bs + bs].iter_mut().zip(&acc) {
                *o = a / d;
            }
        }
        let mut out = Vec::with_capacity(bs);
        for c in 0..bs {
            let kci = &kc[c * n..(c + 1) * n];
            let m: f64 = kci.iter().zip(alpha).map(|(a, b)| a * b).sum();
            let mut s2 = 0.0;
            for k in 0..n {
                let vc = v[k * bs + c];
                s2 += vc * vc;
            }
            let var = (self.sigma_f2 - s2).max(1e-12);
            let s = var.sqrt();
            out.push((expected_improvement(m, s, best_sc), m, s));
        }
        out
    }
}

/// `-½ yᵀα − Σᵢ ln Lᵢᵢ − (n/2) ln 2π` from a cached factor (the second
/// term is `-½ ln|K|`).
fn log_marginal_of(l: &PackedLower, ysc: &[f64]) -> f64 {
    let n = l.n();
    let alpha = l.solve_lower_t(&l.solve_lower(ysc));
    let fit: f64 = ysc.iter().zip(&alpha).map(|(y, a)| y * a).sum();
    let half_logdet: f64 = (0..n).map(|i| l.at(i, i).ln()).sum();
    -0.5 * fit - half_logdet - 0.5 * (n as f64) * (2.0 * std::f64::consts::PI).ln()
}

impl GpSession for GpSurrogate {
    fn len(&self) -> usize {
        self.y.len()
    }

    fn ys(&self) -> &[f64] {
        &self.y
    }

    fn observe(&mut self, x: &[f64], y: f64) -> Result<()> {
        anyhow::ensure!(
            x.len() == self.x.cols,
            "GP point dim {} != {}",
            x.len(),
            self.x.cols
        );
        anyhow::ensure!(self.y.len() < self.cap, "GP training rows at cap {}", self.cap);
        let n = self.y.len();
        // One distance pass fills both caches (the distance cache only
        // under Adapt — Fixed never reads it); the kernel values are the
        // same f64s the old direct kval produced.
        let adaptive = matches!(self.hyper, HyperMode::Adapt { .. });
        let mut drow = Vec::with_capacity(if adaptive { n + 1 } else { 0 });
        let mut krow = Vec::with_capacity(n + 1);
        for j in 0..n {
            let sq = sqdist(x, self.x.row(j));
            if adaptive {
                drow.push(sq);
            }
            krow.push(self.kval_from_sq(sq));
        }
        let sq0 = sqdist(x, x);
        if adaptive {
            drow.push(sq0);
        }
        krow.push(self.kval_from_sq(sq0) + self.sigma_n2);
        anyhow::ensure!(
            cholesky_push(&mut self.l, &krow),
            "GP kernel matrix must be PD (jitter too small?)"
        );
        self.k.push_row(&krow);
        if adaptive {
            self.d2.push_row(&drow);
        }
        self.x.push_row(x);
        self.y.push(y);
        if let HyperMode::Adapt { every } = self.hyper {
            self.appends += 1;
            // A session being *used* — acquires interleaving the appends
            // — honours the user cadence exactly: every intermediate
            // model is read.  A bulk feed (warm start, the BO init
            // design: no acquire since the last round) amortizes to one
            // round per ~25% training-set growth instead, costing
            // O(log n) rounds rather than n/every O(n³) rounds whose
            // intermediate hypers nothing ever reads.
            let bulk = self.acquires.load(Ordering::Relaxed) == self.acquires_at_adapt;
            let gate =
                if bulk { every.max(1).max(self.y.len() / 4) } else { every.max(1) };
            if self.appends >= gate && self.y.len() >= MIN_ADAPT_OBS {
                self.appends = 0;
                self.acquires_at_adapt = self.acquires.load(Ordering::Relaxed);
                self.adapt();
            }
        }
        Ok(())
    }

    fn forget(&mut self, i: usize) -> Result<()> {
        anyhow::ensure!(i < self.y.len(), "forget({i}) of {} rows", self.y.len());
        match self.hyper {
            HyperMode::Fixed => {
                // The factor's prefix property breaks on eviction: full
                // refactor from the (still exact) kernel cache — O(n³),
                // but bit-identical to a scratch fit, which is what Fixed
                // promises.  Refactor a scratch copy first so a failure
                // leaves the session untouched (and usable) instead of
                // with a factor shorter than its training set.
                let mut k = self.k.clone();
                k.remove(i);
                let mut l = PackedLower::new();
                anyhow::ensure!(
                    cholesky_rebuild(&k, &mut l),
                    "GP kernel matrix must be PD (jitter too small?)"
                );
                self.k = k;
                self.l = l;
            }
            HyperMode::Adapt { .. } => {
                // O(n²) rank-1 downdate of the cached factor: infallible
                // on a valid factor (positive Givens pivots), equal to
                // the rebuild up to rotation round-off.
                self.k.remove(i);
                self.d2.remove(i);
                cholesky_downdate(&mut self.l, i);
            }
        }
        self.x.remove_row(i);
        self.y.remove(i);
        Ok(())
    }

    fn acquire(
        &self,
        pool: &ExecPool,
        xc: &[Vec<f64>],
        best: f64,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
        let n = self.y.len();
        anyhow::ensure!(n > 0, "GP needs observations before acquisition");
        // Counted once here, on the calling thread, before the fan-out:
        // the adaptation cadence uses it to tell an actively-driven
        // session from a bulk feed.
        self.acquires.fetch_add(1, Ordering::Relaxed);
        let scaler = TargetScaler::fit(&self.y);
        let ysc: Vec<f64> = self.y.iter().map(|&v| scaler.transform(v)).collect();
        let best_sc = scaler.transform(best);
        let alpha = self.l.solve_lower_t(&self.l.solve_lower(&ysc));

        let scored =
            pool.par_chunks(xc, EI_BLOCK, |_, block| self.score_block(block, &alpha, best_sc));
        let mut ei = Vec::with_capacity(xc.len());
        let mut mu = Vec::with_capacity(xc.len());
        let mut sigma = Vec::with_capacity(xc.len());
        for (e, m, s) in scored {
            ei.push(e);
            mu.push(m);
            sigma.push(s);
        }
        Ok((ei, mu, sigma))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::ops::gp_ei;
    use crate::util::rng::Pcg;

    fn rand_rows(n: usize, d: usize, rng: &mut Pcg) -> Vec<Vec<f64>> {
        (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect()
    }

    fn cfg(d: usize) -> GpConfig {
        GpConfig {
            dim: d,
            lengthscale: 0.8,
            sigma_f2: 1.0,
            sigma_n2: 0.01,
            cap: 64,
            hyper: HyperMode::Fixed,
        }
    }

    /// The incremental surrogate must reproduce the one-shot `gp_ei`
    /// posterior bitwise (acquire standardizes internally, so compare
    /// against gp_ei on pre-standardized targets).
    #[test]
    fn incremental_matches_one_shot_bitwise() {
        let mut rng = Pcg::new(21);
        let d = 5;
        let xs = rand_rows(30, d, &mut rng);
        let ys: Vec<f64> = xs.iter().map(|r| r[0] * 2.0 + r[1] - r[2]).collect();
        let xc = rand_rows(100, d, &mut rng);
        let c = cfg(d);

        let mut gp = GpSurrogate::new(&c);
        for (x, &y) in xs.iter().zip(&ys) {
            gp.observe(x, y).unwrap();
        }
        let best = ys.iter().cloned().fold(f64::INFINITY, f64::min);
        let (ei, mu, sigma) = gp.acquire(&ExecPool::serial(), &xc, best).unwrap();

        let scaler = TargetScaler::fit(&ys);
        let ysc: Vec<f64> = ys.iter().map(|&v| scaler.transform(v)).collect();
        let (e2, m2, s2) = gp_ei(
            &xs,
            &ysc,
            &xc,
            c.lengthscale,
            c.sigma_f2,
            c.sigma_n2,
            scaler.transform(best),
        );
        assert_eq!(bits(&ei), bits(&e2));
        assert_eq!(bits(&mu), bits(&m2));
        assert_eq!(bits(&sigma), bits(&s2));
    }

    #[test]
    fn pool_width_never_changes_acquisition() {
        let mut rng = Pcg::new(22);
        let d = 4;
        let xs = rand_rows(25, d, &mut rng);
        let ys: Vec<f64> = xs.iter().map(|r| (r[0] * 3.0).sin() + r[3]).collect();
        let xc = rand_rows(70, d, &mut rng); // not a multiple of EI_BLOCK
        let mut gp = GpSurrogate::new(&cfg(d));
        for (x, &y) in xs.iter().zip(&ys) {
            gp.observe(x, y).unwrap();
        }
        let serial = gp.acquire(&ExecPool::serial(), &xc, 0.1).unwrap();
        for width in [2, 3, 8] {
            let par = gp.acquire(&ExecPool::new(width), &xc, 0.1).unwrap();
            assert_eq!(bits(&serial.0), bits(&par.0), "width {width}");
            assert_eq!(bits(&serial.1), bits(&par.1), "width {width}");
            assert_eq!(bits(&serial.2), bits(&par.2), "width {width}");
        }
    }

    #[test]
    fn forget_rebuilds_factor_exactly() {
        let mut rng = Pcg::new(23);
        let d = 3;
        let xs = rand_rows(20, d, &mut rng);
        let ys: Vec<f64> = xs.iter().map(|r| r.iter().sum()).collect();
        let xc = rand_rows(40, d, &mut rng);
        let c = cfg(d);

        let mut gp = GpSurrogate::new(&c);
        for (x, &y) in xs.iter().zip(&ys) {
            gp.observe(x, y).unwrap();
        }
        gp.forget(7).unwrap();
        assert_eq!(gp.len(), 19);

        // reference: a fresh surrogate over the surviving points
        let mut fresh = GpSurrogate::new(&c);
        for (i, (x, &y)) in xs.iter().zip(&ys).enumerate() {
            if i != 7 {
                fresh.observe(x, y).unwrap();
            }
        }
        // Factors may differ (prefix property broke) in general, but the
        // posterior must match the scratch fit bitwise.
        let a = gp.acquire(&ExecPool::serial(), &xc, 0.5).unwrap();
        let b = fresh.acquire(&ExecPool::serial(), &xc, 0.5).unwrap();
        assert_eq!(bits(&a.0), bits(&b.0));
        assert_eq!(bits(&a.1), bits(&b.1));
        assert_eq!(bits(&a.2), bits(&b.2));
    }

    #[test]
    fn downdate_forget_keeps_session_usable() {
        let mut rng = Pcg::new(25);
        let d = 4;
        let mut c = cfg(d);
        // Adaptation disabled (`every` never reached): isolates the
        // downdate eviction path.
        c.hyper = HyperMode::Adapt { every: usize::MAX };
        let mut gp = GpSurrogate::new(&c);
        let xs = rand_rows(18, d, &mut rng);
        for (i, x) in xs.iter().enumerate() {
            gp.observe(x, (i as f64 * 0.7).sin()).unwrap();
        }
        for idx in [0usize, 8, 14] {
            gp.forget(idx).unwrap();
        }
        assert_eq!(gp.len(), 15);
        gp.observe(&[0.2, 0.4, 0.6, 0.8], 0.3).unwrap();
        let xc = rand_rows(20, d, &mut rng);
        let (ei, mu, sigma) = gp.acquire(&ExecPool::serial(), &xc, 0.1).unwrap();
        for v in ei.iter().chain(&mu).chain(&sigma) {
            assert!(v.is_finite());
        }
    }

    #[test]
    fn adapt_below_min_obs_is_a_noop() {
        let mut c = cfg(2);
        c.hyper = HyperMode::adapt();
        let mut gp = GpSurrogate::new(&c);
        for i in 0..(MIN_ADAPT_OBS - 1) {
            gp.observe(&[i as f64 * 0.1, 0.5], i as f64).unwrap();
        }
        let out = gp.adapt();
        assert_eq!(out.steps, 0);
        assert!(!out.moved);
        assert_eq!(gp.hypers(), (c.lengthscale, c.sigma_n2));
    }

    #[test]
    fn fixed_mode_never_moves_hypers() {
        let mut rng = Pcg::new(26);
        let d = 3;
        let c = cfg(d);
        let mut gp = GpSurrogate::new(&c);
        for x in rand_rows(30, d, &mut rng) {
            let y = (x[0] * 9.0).sin();
            gp.observe(&x, y).unwrap();
        }
        assert_eq!(gp.hypers(), (c.lengthscale, c.sigma_n2));
        // Even an explicit adapt() call is a no-op on a Fixed session:
        // it keeps no distance cache, and Fixed means fixed.
        let out = gp.adapt();
        assert!(!out.moved);
        assert_eq!(out.steps, 0);
        assert_eq!(gp.hypers(), (c.lengthscale, c.sigma_n2));
    }

    #[test]
    fn observe_past_cap_errors() {
        let d = 2;
        let mut c = cfg(d);
        c.cap = 3;
        let mut gp = GpSurrogate::new(&c);
        let mut rng = Pcg::new(24);
        for i in 0..3 {
            gp.observe(&[rng.f64(), rng.f64()], i as f64).unwrap();
        }
        assert!(gp.observe(&[0.5, 0.5], 9.0).is_err());
        assert!(gp.observe(&[0.5], 9.0).is_err(), "dim mismatch must error");
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }
}
