//! Pure-rust mirrors of the four L2 artifacts (EMCM scoring, GP+EI, ridge
//! LR, lasso ISTA).  Algorithmically identical to python/compile/model.py —
//! integration tests cross-check them against the HLO artifacts through
//! PJRT, and they double as the fallback backend when artifacts are absent.

use super::kernels::solve_lower_multi;
use super::linalg::{cholesky, solve_lower, solve_lower_t, solve_spd, Mat};
use crate::runtime::KernelPolicy;

pub const SQRT2: f64 = std::f64::consts::SQRT_2;
pub const INV_SQRT_2PI: f64 = 0.3989422804014327;

/// erf via Abramowitz & Stegun 7.1.26 (|err| <= 1.5e-7), enough to match
/// the f32 kernels.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

pub fn norm_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / SQRT2))
}

pub fn norm_pdf(z: f64) -> f64 {
    INV_SQRT_2PI * (-0.5 * z * z).exp()
}

/// EMCM score per candidate (mirror of kernels/emcm.py):
/// `mean_z |f_z(x) - f0(x)| * ||x||`.
pub fn emcm_score(w_ens: &[Vec<f64>], w0: &[f64], x: &[Vec<f64>]) -> Vec<f64> {
    x.iter()
        .map(|xi| {
            let fbar: f64 = xi.iter().zip(w0).map(|(a, b)| a * b).sum();
            let mut resid = 0.0;
            for wz in w_ens {
                let fz: f64 = xi.iter().zip(wz).map(|(a, b)| a * b).sum();
                resid += (fz - fbar).abs();
            }
            let norm: f64 = xi.iter().map(|a| a * a).sum::<f64>().sqrt();
            (resid / w_ens.len() as f64) * norm
        })
        .collect()
}

/// Expected improvement for minimization (mirror of kernels/ei.py).
pub fn expected_improvement(mu: f64, sigma: f64, best: f64) -> f64 {
    if sigma <= 1e-9 {
        return (best - mu).max(0.0);
    }
    let z = (best - mu) / sigma;
    (sigma * (z * norm_cdf(z) + norm_pdf(z))).max(0.0)
}

/// Ridge linear regression via normal equations (mirror of lr_fit).
pub fn lr_fit(x: &[Vec<f64>], y: &[f64], ridge: f64) -> Vec<f64> {
    assert_eq!(x.len(), y.len());
    let m = Mat::from_rows(x);
    let mut a = m.gram();
    for i in 0..a.rows {
        *a.at_mut(i, i) += ridge;
    }
    let b = m.tmatvec(y);
    solve_spd(&a, &b).expect("ridge-regularized normal equations must be SPD")
}

pub fn lr_predict(w: &[f64], x: &[f64]) -> f64 {
    w.iter().zip(x).map(|(a, b)| a * b).sum()
}

/// Lasso via ISTA with power-iteration Lipschitz estimate (mirror of
/// lasso_fit: same objective (1/2n)||y - Xw||^2 + lam ||w||_1, same
/// iteration counts).
pub fn lasso_fit(x: &[Vec<f64>], y: &[f64], lam: f64, iters: usize) -> Vec<f64> {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let m = Mat::from_rows(x);
    let mut gram = m.gram();
    for v in gram.data.iter_mut() {
        *v /= n;
    }
    let mut xty = m.tmatvec(y);
    for v in xty.iter_mut() {
        *v /= n;
    }
    let d = gram.rows;

    // Power iteration for the max eigenvalue.
    let mut v = vec![1.0 / (d as f64).sqrt(); d];
    for _ in 0..16 {
        let gv = gram.matvec(&v);
        let norm: f64 = gv.iter().map(|a| a * a).sum::<f64>().sqrt().max(1e-12);
        v = gv.into_iter().map(|a| a / norm).collect();
    }
    let gv = gram.matvec(&v);
    let lmax: f64 = v.iter().zip(&gv).map(|(a, b)| a * b).sum::<f64>().max(1e-6);
    let step = 1.0 / (lmax * 1.01);
    let thr = step * lam;

    let mut w = vec![0.0; d];
    for _ in 0..iters {
        let grad = {
            let mut g = gram.matvec(&w);
            for (gi, bi) in g.iter_mut().zip(&xty) {
                *gi -= bi;
            }
            g
        };
        for j in 0..d {
            let u = w[j] - step * grad[j];
            w[j] = u.signum() * (u.abs() - thr).max(0.0);
        }
    }
    w
}

/// `Some(ℓ)` when every per-dimension length-scale is (bitwise) the same
/// — the isotropic case.  Isotropic kernels keep the scalar summation
/// order (sum the squared distance across dimensions first, scale once),
/// so an ARD code path with all-equal length-scales stays bit-identical
/// to the pre-ARD scalar implementation; `None` selects the weighted
/// per-dimension sum.
pub fn iso_lengthscale(lengthscales: &[f64]) -> Option<f64> {
    match lengthscales.split_first() {
        Some((&l0, rest)) if rest.iter().all(|&l| l == l0) => Some(l0),
        _ => None,
    }
}

/// RBF kernel row block under per-dimension (ARD) length-scales:
/// `K[i][j] = sf2 exp(-½ Σ_k (a_ik - b_jk)²/ℓ_k²)`, returned as one flat
/// `Mat` (one contiguous row per `a` row — no per-row allocations on the
/// kernel hot path).  All-equal length-scales take the isotropic path —
/// `sf2 exp(-||a_i - b_j||²/(2ℓ²))` with the squared distance summed
/// across dimensions *before* scaling — which is bit-identical to the old
/// scalar-lengthscale kernel.
pub fn rbf(a: &[Vec<f64>], b: &[Vec<f64>], lengthscales: &[f64], sf2: f64) -> Mat {
    let mut k = Mat::with_row_capacity(a.len(), b.len());
    let mut row = vec![0.0; b.len()];
    match iso_lengthscale(lengthscales) {
        Some(l) => {
            let inv = 1.0 / (2.0 * l * l);
            for ai in a {
                for (o, bj) in row.iter_mut().zip(b) {
                    let sq: f64 =
                        ai.iter().zip(bj).map(|(x, y)| (x - y) * (x - y)).sum();
                    *o = sf2 * (-sq * inv).exp();
                }
                k.push_row(&row);
            }
        }
        None => {
            let inv2: Vec<f64> =
                lengthscales.iter().map(|l| 1.0 / (2.0 * l * l)).collect();
            for ai in a {
                for (o, bj) in row.iter_mut().zip(b) {
                    let e: f64 = ai
                        .iter()
                        .zip(bj)
                        .zip(&inv2)
                        .map(|((x, y), w)| (x - y) * (x - y) * w)
                        .sum();
                    *o = sf2 * (-e).exp();
                }
                k.push_row(&row);
            }
        }
    }
    k
}

/// GP posterior + EI at candidates (mirror of gp_ei) under per-dimension
/// length-scales: returns (ei, mu, sigma) per candidate.
pub fn gp_ei(
    xtr: &[Vec<f64>],
    ytr: &[f64],
    xc: &[Vec<f64>],
    lengthscales: &[f64],
    sigma_f2: f64,
    sigma_n2: f64,
    best: f64,
) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let n = xtr.len();
    assert_eq!(ytr.len(), n);
    let mut km = rbf(xtr, xtr, lengthscales, sigma_f2);
    for i in 0..n {
        *km.at_mut(i, i) += sigma_n2;
    }
    let l = cholesky(&km).expect("GP kernel matrix must be PD (jitter too small?)");
    let alpha = solve_lower_t(&l, &solve_lower(&l, ytr));

    let kc = rbf(xc, xtr, lengthscales, sigma_f2);
    let mc = xc.len();
    // One scalar-order multi-RHS forward solve over all candidates: the
    // per-candidate operation order is exactly `solve_lower`'s, so the
    // posterior stays bitwise the per-candidate reference this function
    // has always been (pinned by `tests/gp_incremental.rs`).
    let mut v = vec![0.0; n * mc];
    for c in 0..mc {
        let kci = kc.row(c);
        for j in 0..n {
            v[j * mc + c] = kci[j];
        }
    }
    solve_lower_multi(&l, &mut v, mc, KernelPolicy::Scalar);
    let mut mu = Vec::with_capacity(mc);
    let mut sigma = Vec::with_capacity(mc);
    let mut ei = Vec::with_capacity(mc);
    for (c, kci) in (0..mc).map(|i| (i, kc.row(i))) {
        let m: f64 = kci.iter().zip(&alpha).map(|(a, b)| a * b).sum();
        let mut s2 = 0.0;
        for k in 0..n {
            let vc = v[k * mc + c];
            s2 += vc * vc;
        }
        let var = (sigma_f2 - s2).max(1e-12);
        let s = var.sqrt();
        mu.push(m);
        sigma.push(s);
        ei.push(expected_improvement(m, s, best));
    }
    (ei, mu, sigma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn rand_rows(n: usize, d: usize, rng: &mut Pcg) -> Vec<Vec<f64>> {
        (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect()
    }

    #[test]
    fn erf_reference_values() {
        // erf(0)=0, erf(1)=0.8427007929, erf(-1)=-erf(1), erf(inf)->1
        assert!(erf(0.0).abs() < 1e-6); // A&S 7.1.26 is ~1e-7 accurate
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((erf(4.0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ei_properties() {
        // certain improvement
        assert!((expected_improvement(0.0, 1e-12, 1.0) - 1.0).abs() < 1e-9);
        // no improvement, no uncertainty
        assert_eq!(expected_improvement(2.0, 0.0, 1.0), 0.0);
        // more uncertainty -> more EI at same mean
        let lo = expected_improvement(1.0, 0.1, 0.0);
        let hi = expected_improvement(1.0, 2.0, 0.0);
        assert!(hi > lo);
        assert!(expected_improvement(0.5, 0.5, 0.0) >= 0.0);
    }

    #[test]
    fn emcm_zero_for_identical_ensemble() {
        let mut rng = Pcg::new(5);
        let w0: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let w_ens = vec![w0.clone(), w0.clone(), w0.clone()];
        let x = rand_rows(10, 8, &mut rng);
        let s = emcm_score(&w_ens, &w0, &x);
        assert!(s.iter().all(|&v| v.abs() < 1e-12));
    }

    #[test]
    fn lr_fit_recovers_weights() {
        let mut rng = Pcg::new(6);
        let w_true: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let x = rand_rows(200, 6, &mut rng);
        let y: Vec<f64> = x.iter().map(|r| lr_predict(&w_true, r)).collect();
        let w = lr_fit(&x, &y, 1e-8);
        for (a, b) in w.iter().zip(&w_true) {
            assert!((a - b).abs() < 1e-5, "{w:?} vs {w_true:?}");
        }
    }

    #[test]
    fn lasso_sparsifies_and_finds_support() {
        let mut rng = Pcg::new(7);
        let d = 30;
        let x = rand_rows(150, d, &mut rng);
        let mut w_true = vec![0.0; d];
        w_true[3] = 2.0;
        w_true[17] = -1.5;
        let y: Vec<f64> = x
            .iter()
            .map(|r| lr_predict(&w_true, r) + 0.01 * rng.normal())
            .collect();
        let w = lasso_fit(&x, &y, 0.02, 400);
        assert!(w[3] > 0.5, "{}", w[3]);
        assert!(w[17] < -0.5, "{}", w[17]);
        let nnz = w.iter().filter(|v| v.abs() > 1e-6).count();
        assert!(nnz < d / 2, "nnz={nnz}");
    }

    #[test]
    fn lasso_huge_lambda_all_zero() {
        let mut rng = Pcg::new(8);
        let x = rand_rows(50, 10, &mut rng);
        let y: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let w = lasso_fit(&x, &y, 1e6, 100);
        assert!(w.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gp_interpolates_with_small_noise() {
        let mut rng = Pcg::new(9);
        let x = rand_rows(25, 4, &mut rng);
        let y: Vec<f64> = x.iter().map(|r| (r[0] * 3.0).sin() + r[1]).collect();
        // predicting at the training points themselves
        let (_, mu_tr, sig_tr) = gp_ei(&x, &y, &x, &[1.0; 4], 1.0, 1e-6, 0.0);
        for (m, yi) in mu_tr.iter().zip(&y) {
            assert!((m - yi).abs() < 1e-3, "{m} vs {yi}");
        }
        assert!(sig_tr.iter().all(|&s| s < 1e-2));
    }

    #[test]
    fn gp_uncertainty_grows_away_from_data() {
        let xtr = vec![vec![0.0], vec![0.1], vec![0.2]];
        let ytr = vec![0.0, 0.1, 0.2];
        let xc = vec![vec![0.1], vec![5.0]];
        let (_, _, sigma) = gp_ei(&xtr, &ytr, &xc, &[0.5], 1.0, 1e-4, 0.0);
        assert!(sigma[1] > sigma[0] * 5.0, "{sigma:?}");
    }

    #[test]
    fn rbf_diag_is_sf2() {
        let mut rng = Pcg::new(10);
        let x = rand_rows(5, 3, &mut rng);
        let k = rbf(&x, &x, &[1.0; 3], 2.5);
        for i in 0..5 {
            assert!((k.at(i, i) - 2.5).abs() < 1e-12);
        }
    }

    #[test]
    fn iso_lengthscale_detects_equal_and_unequal() {
        assert_eq!(iso_lengthscale(&[0.7, 0.7, 0.7]), Some(0.7));
        assert_eq!(iso_lengthscale(&[0.7]), Some(0.7));
        assert_eq!(iso_lengthscale(&[0.7, 0.8]), None);
        assert_eq!(iso_lengthscale(&[]), None);
    }

    /// ARD kernel with a large length-scale on one dimension must ignore
    /// differences along it; the isotropic path must match the weighted
    /// path bitwise when the weights coincide.
    #[test]
    fn rbf_ard_downweights_long_lengthscale_dims() {
        let a = vec![vec![0.0, 0.0]];
        let near = vec![vec![0.0, 5.0]]; // far only along the long dim
        let k_iso = rbf(&a, &near, &[1.0, 1.0], 1.0);
        let k_ard = rbf(&a, &near, &[1.0, 1e3], 1.0);
        assert!(k_iso.at(0, 0) < 1e-5, "{}", k_iso.at(0, 0));
        assert!(k_ard.at(0, 0) > 0.999, "{}", k_ard.at(0, 0));
    }
}
