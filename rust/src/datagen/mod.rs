//! Phase 1 — application characterization by batch-mode active learning
//! (paper §III-B, Algorithm 1).
//!
//! A pool of random flag configurations is scored by BEMCM (expected model
//! change of a bootstrap LR ensemble, computed by the L1 Pallas kernel via
//! PJRT); the top-k batch is labelled by actually running the benchmark on
//! the simulated cluster; the loop stops when validation RMSE plateaus —
//! "no significant improvement in validation RMSE between runs" (§III-A).
//!
//! QBC (committee variance) and uniform-random selection are the baselines
//! of Fig 5.

use std::sync::Arc;

use anyhow::Result;

use crate::exec::{self, ExecPool, JobControl};
use crate::flags::{FeatureEncoder, FlagConfig, GcMode};
use crate::runtime::{MlBackend, N_TRAIN, Z_ENS};
use crate::sparksim::{FailureHisto, RunOutcome, SparkRunner};
use crate::util::csv::Table;
use crate::util::rng::Pcg;
use crate::util::stats::{self, TargetScaler};
use crate::Metric;

/// Sampling strategy for the AL loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Batch-mode Expected Model Change Maximization (the paper's choice).
    Bemcm,
    /// Query-by-committee: label where the bootstrap ensemble disagrees.
    Qbc,
    /// Uniform random batches (the "without AL" baseline).
    Random,
}

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Bemcm => "bemcm",
            Strategy::Qbc => "qbc",
            Strategy::Random => "random",
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "bemcm" | "al" => Some(Strategy::Bemcm),
            "qbc" => Some(Strategy::Qbc),
            "random" | "rand" => Some(Strategy::Random),
            _ => None,
        }
    }
}

/// Data-generation parameters (scaled-down mirror of §IV-A: pool, 10% seed,
/// ~20% test, ~3% of the pool per AL round, 10 rounds max).
#[derive(Clone, Debug)]
pub struct DataGenConfig {
    pub pool_size: usize,
    pub seed_runs: usize,
    pub test_runs: usize,
    pub batch_k: usize,
    pub max_rounds: usize,
    /// Stop when |RMSE_t - RMSE_{t-1}| / RMSE_{t-1} falls below this.
    pub rmse_rel_tol: f64,
    pub ridge: f64,
    pub seed: u64,
}

impl Default for DataGenConfig {
    fn default() -> Self {
        DataGenConfig {
            pool_size: 660,
            seed_runs: 24,
            test_runs: 40,
            batch_k: 20,
            max_rounds: 10,
            rmse_rel_tol: 0.01,
            ridge: 1e-3,
            seed: 0x0115_70b7,
        }
    }
}

/// The labelled dataset phase 1 produces ("the collected data is stored in
/// a csv file", §III-A).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub mode: GcMode,
    pub metric: Metric,
    /// Unit-normalized flag vectors (one entry per flag in the GC group).
    pub unit_rows: Vec<Vec<f64>>,
    /// Encoded feature rows (flags + squared terms).
    pub feat_rows: Vec<Vec<f64>>,
    /// Recorded metric values (original units).
    pub y: Vec<f64>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Serialize as CSV: flag columns (unit values) then the metric column.
    pub fn to_table(&self) -> Table {
        let enc = FeatureEncoder::new(self.mode);
        let mut cols: Vec<String> =
            (0..enc.n_flags()).map(|p| enc.flag_name(p).to_string()).collect();
        cols.push(self.metric.name().to_string());
        let mut t = Table::new(cols);
        for (u, &yv) in self.unit_rows.iter().zip(&self.y) {
            let mut row = u.clone();
            row.push(yv);
            t.push(row);
        }
        t
    }

    /// Rebuild from a CSV table written by `to_table`.
    pub fn from_table(t: &Table, mode: GcMode, metric: Metric) -> Result<Dataset> {
        let enc = FeatureEncoder::new(mode);
        anyhow::ensure!(
            t.columns.len() == enc.n_flags() + 1,
            "csv has {} columns, expected {}",
            t.columns.len(),
            enc.n_flags() + 1
        );
        let mut unit_rows = Vec::with_capacity(t.rows.len());
        let mut feat_rows = Vec::with_capacity(t.rows.len());
        let mut y = Vec::with_capacity(t.rows.len());
        for row in &t.rows {
            let (u, yv) = row.split_at(row.len() - 1);
            let cfg = FlagConfig::from_unit(mode, u);
            unit_rows.push(u.to_vec());
            feat_rows.push(enc.encode(&cfg));
            y.push(yv[0]);
        }
        Ok(Dataset { mode, metric, unit_rows, feat_rows, y })
    }
}

/// Everything phase 1 reports.
#[derive(Clone, Debug)]
pub struct CharacterizeResult {
    pub strategy: Strategy,
    pub dataset: Dataset,
    /// Validation RMSE after the seed fit and after each AL round.
    pub rmse_history: Vec<f64>,
    /// Benchmark executions consumed (seed + test + labelled batches).
    pub runs_executed: usize,
    pub rounds: usize,
    /// Total simulated benchmark time spent generating data (seconds).
    pub sim_time_s: f64,
    /// Per-kind measurement-failure counts over all labelling runs.
    pub failures: FailureHisto,
}

/// Labels pool entries by running the benchmark on the simulated cluster.
///
/// Config `i` of a batch gets the seed `seed + count + 1 + i` — the seed
/// the old strictly-sequential labeller (one mutable `count`, incremented
/// per run) would have assigned.  Deriving it from the batch-start index
/// *before* dispatch is what makes batches safe to label in parallel:
/// labels depend only on (config, index), never on evaluation order, so
/// serial and parallel labelling produce bit-identical datasets.
struct Labeller<'a> {
    runner: &'a SparkRunner,
    metric: Metric,
    seed: u64,
    count: usize,
    sim_time_s: f64,
    /// Adaptive cap on recorded exec-time labels (Ashouri et al.'s capped
    /// algorithm runs, paper SectionII): failed/thrashing configurations are
    /// recorded as `cap` rather than the raw timeout, so a handful of OOM
    /// outliers cannot dominate the regression model phase 1 trains.
    cap: f64,
    /// Per-kind counts of failed labelling runs (OOM, wall-cap, injected).
    failures: FailureHisto,
}

/// Flat heap-usage label recorded for a failed run.  A failed run's heap
/// trace is not a measurement — an OOM pins it near 100% while a crashed
/// executor leaves it near 0% — so the raw value is *replaced* rather than
/// penalized additively: a crash must not look memory-efficient, and an
/// OOM's garbage reading must not drift above the dataset's sanity bound.
const HEAP_FAIL_LABEL: f64 = 140.0;

impl<'a> Labeller<'a> {
    /// Run every config of the batch on `pool` and return their labels in
    /// batch order.
    fn label_batch(&mut self, pool: &ExecPool, cfgs: &[FlagConfig]) -> Vec<f64> {
        let runner = self.runner;
        let seed = self.seed;
        let base = self.count as u64;
        // The batch owns the fan-out; each run simulates its executors
        // serially rather than nesting a second pool per run.
        let inner = ExecPool::serial();
        let runs: Vec<RunOutcome> = pool.par_map(cfgs, |i, cfg| {
            runner.run_outcome_on(&inner, cfg, seed.wrapping_add(base + 1 + i as u64))
        });
        // Bookkeeping and label post-processing stay in batch order so the
        // floating-point `sim_time_s` accumulation matches a serial run.
        let mut labels = Vec::with_capacity(runs.len());
        for out in &runs {
            let m = out.metrics();
            self.count += 1;
            self.sim_time_s += m.wall_clock_s;
            let mut v = self.metric.of(m);
            if let Some(kind) = out.failure() {
                self.failures.record(kind);
            }
            match self.metric {
                // The timeout-shaped exec time of a failed run is capped
                // like any other outlier.
                Metric::ExecTime => v = v.min(self.cap),
                Metric::HeapUsage => {
                    if out.failure().is_some() {
                        v = HEAP_FAIL_LABEL;
                    }
                }
            }
            labels.push(v);
        }
        labels
    }
}

/// Pool-scoring chunk size for backends that prefer sharded scoring
/// (`MlBackend::prefers_sharded_scoring`): per-candidate scores are
/// independent, so the fixed size only tiles the fan-out — chunking (and
/// pool width) can never change a value.  Batched backends (XLA: padded
/// fixed-shape executable behind an engine lock) keep one call instead.
const SCORE_CHUNK: usize = 64;

/// Indices of the `k` highest scores, descending.  NaN scores (a
/// degenerate bootstrap resample can produce one) rank strictly last
/// instead of poisoning the comparator — `partial_cmp().unwrap()` here
/// used to abort the whole characterization.
fn select_top_k(scores: &[f64], k: usize) -> Vec<usize> {
    let key = |i: usize| {
        let s = scores[i];
        if s.is_nan() {
            f64::NEG_INFINITY
        } else {
            s
        }
    };
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| key(b).total_cmp(&key(a)));
    order.truncate(k);
    order
}

/// Run phase 1: characterize `runner`'s benchmark for `metric` under the
/// given GC mode, returning the dataset + convergence history.  Runs on
/// the process-global execution pool.
pub fn characterize(
    runner: &SparkRunner,
    mode: GcMode,
    metric: Metric,
    strategy: Strategy,
    cfg: &DataGenConfig,
    backend: &Arc<dyn MlBackend>,
) -> Result<CharacterizeResult> {
    characterize_on(exec::global(), runner, mode, metric, strategy, cfg, backend)
}

/// `characterize` on an explicit pool.  Benchmark labelling batches, the
/// bootstrap-ensemble fits, and the per-round EMCM/QBC pool scoring fan
/// out on `pool`; all seeds are index-derived and all reductions run in
/// index order, so the result is bit-identical for every pool width
/// (guarded by `tests/exec_parallel.rs`).
#[allow(clippy::too_many_arguments)]
pub fn characterize_on(
    epool: &ExecPool,
    runner: &SparkRunner,
    mode: GcMode,
    metric: Metric,
    strategy: Strategy,
    cfg: &DataGenConfig,
    backend: &Arc<dyn MlBackend>,
) -> Result<CharacterizeResult> {
    characterize_ctl(epool, runner, mode, metric, strategy, cfg, backend, &JobControl::default())
}

/// `characterize_on` under a [`JobControl`]: after the seed/test fit and
/// after every AL round the loop publishes progress (completed `round`,
/// `runs_executed`, `last_rmse`) and polls for cooperative cancellation at
/// round boundaries.  A cancelled characterization is not an error — it
/// returns the partial dataset labelled so far.
#[allow(clippy::too_many_arguments)]
pub fn characterize_ctl(
    epool: &ExecPool,
    runner: &SparkRunner,
    mode: GcMode,
    metric: Metric,
    strategy: Strategy,
    cfg: &DataGenConfig,
    backend: &Arc<dyn MlBackend>,
    ctl: &JobControl,
) -> Result<CharacterizeResult> {
    let enc = FeatureEncoder::new(mode);
    let mut rng = Pcg::new(cfg.seed);
    // One default-config run fixes the adaptive label cap (5x default).
    let default_run = runner.run(&FlagConfig::default_for(mode), cfg.seed ^ 0xca55);
    let mut labeller = Labeller {
        runner,
        metric,
        seed: cfg.seed ^ 0xda7a,
        count: 1,
        sim_time_s: default_run.wall_clock_s,
        cap: 5.0 * default_run.exec_time_s,
        failures: FailureHisto::default(),
    };

    // Unlabelled pool.
    let mut pool: Vec<(Vec<f64>, Vec<f64>)> = (0..cfg.pool_size)
        .map(|_| {
            let c = FlagConfig::random(mode, &mut rng);
            (c.to_unit(), enc.encode(&c))
        })
        .collect();

    // EMCM scores and LR fits operate on *standardized* features (Cai et
    // al. assume centered inputs; on raw [0,1] features the ||x|| factor in
    // the model-change norm just favours cube corners).  The standardizer
    // is fit once on the pool — the sampling distribution.
    let pool_feats_raw: Vec<Vec<f64>> = pool.iter().map(|(_, f)| f.clone()).collect();
    let fstd = stats::Standardizer::fit(&pool_feats_raw);

    // Standardized pool features, cached once and kept in lockstep with
    // `pool` (which only ever shrinks — `swap_remove` both) instead of
    // being recomputed from scratch every AL round.
    let mut pool_std: Vec<Vec<f64>> =
        pool_feats_raw.iter().map(|f| fstd.transform_row(f)).collect();
    drop(pool_feats_raw);

    // Seed set (10% of the labelling budget) + held-out test set.  Both
    // are drawn serially (the RNG stream is order-sensitive) and labelled
    // as a parallel batch (labels touch no shared state).
    let mut unit_rows = Vec::new();
    let mut feat_rows = Vec::new();
    let mut feat_std_rows = Vec::new();
    let mut seed_cfgs = Vec::with_capacity(cfg.seed_runs);
    for _ in 0..cfg.seed_runs {
        let idx = rng.below(pool.len());
        let (u, f) = pool.swap_remove(idx);
        feat_std_rows.push(pool_std.swap_remove(idx));
        seed_cfgs.push(FlagConfig::from_unit(mode, &u));
        unit_rows.push(u);
        feat_rows.push(f);
    }
    let mut y = labeller.label_batch(epool, &seed_cfgs);

    let mut test_x = Vec::new();
    let mut test_cfgs = Vec::with_capacity(cfg.test_runs);
    for _ in 0..cfg.test_runs {
        let c = FlagConfig::random(mode, &mut rng);
        test_x.push(enc.encode(&c));
        test_cfgs.push(c);
    }
    let test_y = labeller.label_batch(epool, &test_cfgs);
    ctl.note_failures(labeller.failures.total());

    let ridge = cfg.ridge;
    let test_std: Vec<Vec<f64>> = test_x.iter().map(|x| fstd.transform_row(x)).collect();
    let fit_and_rmse = |feat_std: &[Vec<f64>],
                        yv: &[f64],
                        backend: &Arc<dyn MlBackend>|
     -> Result<(Vec<f64>, TargetScaler, f64)> {
        let scaler = TargetScaler::fit(yv);
        let ys: Vec<f64> = yv.iter().map(|&v| scaler.transform(v)).collect();
        let w = backend.lr_fit(feat_std, &ys, ridge)?;
        let preds: Vec<f64> = test_std
            .iter()
            .map(|x| scaler.inverse(crate::native::ops::lr_predict(&w, x)))
            .collect();
        let r = stats::rmse(&preds, &test_y);
        Ok((w, scaler, r))
    };

    let (_, _, rmse0) = fit_and_rmse(&feat_std_rows, &y, backend)?;
    let mut rmse_history = vec![rmse0];
    ctl.update(|p| {
        p.round = Some(0);
        p.max_rounds = Some(cfg.max_rounds);
        p.runs_executed = Some(labeller.count);
        p.last_rmse = Some(rmse0);
        p.failures = Some(labeller.failures);
    });

    let mut rounds = 0;
    for round in 0..cfg.max_rounds {
        // Stopped (cancelled or failure budget exhausted): keep the rounds
        // already labelled as a partial dataset.
        if ctl.should_stop() {
            break;
        }
        if pool.is_empty() || y.len() + cfg.batch_k > N_TRAIN {
            break;
        }
        rounds = round + 1;

        // Fit central model + bootstrap ensemble on the labelled set.  The
        // Z_ENS resamples are drawn serially from the main RNG stream (the
        // fork order is the serial loop's), then fit concurrently — each
        // fit is a pure function of its resample.
        let scaler = TargetScaler::fit(&y);
        let ys: Vec<f64> = y.iter().map(|&v| scaler.transform(v)).collect();
        let w0 = backend.lr_fit(&feat_std_rows, &ys, cfg.ridge)?;
        let resamples: Vec<Vec<usize>> = (0..Z_ENS)
            .map(|z| rng.fork(0xb007 + z as u64).bootstrap_indices(y.len()))
            .collect();
        let fits = epool.par_map(&resamples, |_, idx| {
            let bx: Vec<Vec<f64>> = idx.iter().map(|&i| feat_std_rows[i].clone()).collect();
            let by: Vec<f64> = idx.iter().map(|&i| ys[i]).collect();
            backend.lr_fit(&bx, &by, cfg.ridge)
        });
        let mut w_ens = Vec::with_capacity(Z_ENS);
        for fit in fits {
            w_ens.push(fit?);
        }

        // Score the pool (cached standardized features), sharded over the
        // exec pool in fixed-size chunks with index-ordered results.
        let scores: Vec<f64> = match strategy {
            Strategy::Bemcm if backend.prefers_sharded_scoring() => {
                let chunks: Vec<&[Vec<f64>]> = pool_std.chunks(SCORE_CHUNK).collect();
                let per = epool.par_map(&chunks, |_, c| backend.emcm_score(&w_ens, &w0, c));
                let mut s = Vec::with_capacity(pool_std.len());
                for r in per {
                    s.extend(r?);
                }
                s
            }
            Strategy::Bemcm => backend.emcm_score(&w_ens, &w0, &pool_std)?,
            Strategy::Qbc => {
                epool.par_chunks(&pool_std, SCORE_CHUNK, |_, c| qbc_scores(&w_ens, c))
            }
            Strategy::Random => (0..pool.len()).map(|_| rng.f64()).collect(),
        };

        // Select the top-k batch, then label it as one parallel batch.
        let mut batch = select_top_k(&scores, cfg.batch_k);
        batch.sort_unstable_by(|a, b| b.cmp(a)); // remove from the back
        let mut batch_cfgs = Vec::with_capacity(batch.len());
        for i in batch {
            let (u, f) = pool.swap_remove(i);
            feat_std_rows.push(pool_std.swap_remove(i));
            batch_cfgs.push(FlagConfig::from_unit(mode, &u));
            unit_rows.push(u);
            feat_rows.push(f);
        }
        y.extend(labeller.label_batch(epool, &batch_cfgs));
        ctl.note_failures(labeller.failures.total());

        // Convergence check on validation RMSE.
        let (_, _, r) = fit_and_rmse(&feat_std_rows, &y, backend)?;
        let prev = *rmse_history.last().unwrap();
        rmse_history.push(r);
        ctl.update(|p| {
            p.round = Some(rounds);
            p.runs_executed = Some(labeller.count);
            p.last_rmse = Some(r);
            p.failures = Some(labeller.failures);
        });
        if (prev - r).abs() / prev.max(1e-9) < cfg.rmse_rel_tol {
            break;
        }
    }

    Ok(CharacterizeResult {
        strategy,
        dataset: Dataset { mode, metric, unit_rows, feat_rows, y },
        rmse_history,
        runs_executed: labeller.count,
        rounds,
        sim_time_s: labeller.sim_time_s,
        failures: labeller.failures,
    })
}

/// QBC disagreement: committee prediction variance per candidate.
fn qbc_scores(w_ens: &[Vec<f64>], x: &[Vec<f64>]) -> Vec<f64> {
    x.iter()
        .map(|xi| {
            let preds: Vec<f64> = w_ens
                .iter()
                .map(|w| crate::native::ops::lr_predict(w, xi))
                .collect();
            let m = preds.iter().sum::<f64>() / preds.len() as f64;
            preds.iter().map(|p| (p - m) * (p - m)).sum::<f64>() / preds.len() as f64
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;
    use crate::Benchmark;

    fn quick_cfg() -> DataGenConfig {
        DataGenConfig {
            pool_size: 120,
            seed_runs: 12,
            test_runs: 10,
            batch_k: 8,
            max_rounds: 4,
            rmse_rel_tol: 1e-4,
            ridge: 1e-3,
            seed: 7,
        }
    }

    fn backend() -> Arc<dyn MlBackend> {
        Arc::new(NativeBackend)
    }

    #[test]
    fn select_top_k_is_descending_and_nan_safe() {
        // plain descending selection
        assert_eq!(select_top_k(&[0.1, 3.0, 2.0, 5.0], 2), vec![3, 1]);
        // an injected NaN (degenerate bootstrap resample) must neither
        // panic nor be selected while finite scores remain
        let scores = [1.0, f64::NAN, 2.0, f64::NAN, 0.5];
        assert_eq!(select_top_k(&scores, 3), vec![2, 0, 4]);
        // NaNs fill the tail only once finite scores are exhausted
        let picked = select_top_k(&scores, 5);
        assert_eq!(&picked[..3], &[2, 0, 4]);
        assert_eq!(picked.len(), 5);
        // degenerate inputs
        assert!(select_top_k(&[], 3).is_empty());
        assert_eq!(select_top_k(&[f64::NAN; 4], 2).len(), 2);
    }

    #[test]
    fn characterize_produces_labelled_dataset() {
        let runner = SparkRunner::paper_default(Benchmark::Lda);
        let r = characterize(
            &runner,
            GcMode::G1GC,
            Metric::ExecTime,
            Strategy::Bemcm,
            &quick_cfg(),
            &backend(),
        )
        .unwrap();
        assert!(r.dataset.len() >= 12);
        assert_eq!(r.dataset.unit_rows.len(), r.dataset.y.len());
        assert_eq!(r.dataset.feat_rows.len(), r.dataset.y.len());
        assert!(r.rounds >= 1);
        assert!(r.runs_executed >= r.dataset.len());
        assert!(r.sim_time_s > 0.0);
        // exec times look like seconds
        assert!(r.dataset.y.iter().all(|&v| v > 10.0 && v < 10_000.0));
    }

    #[test]
    fn rmse_history_tracks_rounds() {
        let runner = SparkRunner::paper_default(Benchmark::Lda);
        let r = characterize(
            &runner,
            GcMode::ParallelGC,
            Metric::ExecTime,
            Strategy::Bemcm,
            &quick_cfg(),
            &backend(),
        )
        .unwrap();
        assert_eq!(r.rmse_history.len(), r.rounds + 1);
        assert!(r.rmse_history.iter().all(|&v| v.is_finite() && v > 0.0));
    }

    #[test]
    fn strategies_differ_in_selection() {
        let runner = SparkRunner::paper_default(Benchmark::Lda);
        let a = characterize(
            &runner,
            GcMode::G1GC,
            Metric::ExecTime,
            Strategy::Bemcm,
            &quick_cfg(),
            &backend(),
        )
        .unwrap();
        let b = characterize(
            &runner,
            GcMode::G1GC,
            Metric::ExecTime,
            Strategy::Random,
            &quick_cfg(),
            &backend(),
        )
        .unwrap();
        // same seed pool, different selections -> different datasets
        assert_ne!(a.dataset.unit_rows, b.dataset.unit_rows);
    }

    #[test]
    fn dataset_csv_roundtrip() {
        let runner = SparkRunner::paper_default(Benchmark::Lda);
        let mut cfg = quick_cfg();
        cfg.max_rounds = 1;
        let r = characterize(
            &runner,
            GcMode::G1GC,
            Metric::ExecTime,
            Strategy::Random,
            &cfg,
            &backend(),
        )
        .unwrap();
        let t = r.dataset.to_table();
        assert_eq!(t.columns.len(), 141 + 1);
        let back = Dataset::from_table(&t, GcMode::G1GC, Metric::ExecTime).unwrap();
        assert_eq!(back.len(), r.dataset.len());
        for (a, b) in back.y.iter().zip(&r.dataset.y) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn heap_metric_characterization() {
        let runner = SparkRunner::paper_default(Benchmark::Lda);
        let mut cfg = quick_cfg();
        cfg.max_rounds = 2;
        let r = characterize(
            &runner,
            GcMode::G1GC,
            Metric::HeapUsage,
            Strategy::Bemcm,
            &cfg,
            &backend(),
        )
        .unwrap();
        assert!(r.dataset.y.iter().all(|&v| v > 0.0 && v < 150.0));
    }

    #[test]
    fn failed_runs_get_penalty_labels_not_garbage() {
        // Regression test for the heap-usage label bug: an OOMing config's
        // raw `hu_avg_pct` is pinned near 100% by its death throes; adding
        // a +50 penalty on top used to push the label toward the dataset
        // sanity bound while still *ranking* the config as if its heap
        // reading were real.  The label must be the flat replacement
        // penalty, and the exec-time label must stay capped.
        let runner = SparkRunner::paper_default(Benchmark::DenseKMeans);
        let good = FlagConfig::default_for(GcMode::ParallelGC);
        let mut oom = good.clone();
        oom.set("MaxHeapSize", 2048.0); // live set cannot fit: deterministic OOM
        let cfgs = [good, oom];
        let pool = ExecPool::serial();

        let mut heap = Labeller {
            runner: &runner,
            metric: Metric::HeapUsage,
            seed: 11,
            count: 0,
            sim_time_s: 0.0,
            cap: 500.0,
            failures: FailureHisto::default(),
        };
        let labels = heap.label_batch(&pool, &cfgs);
        assert!(labels[0] > 0.0 && labels[0] < 100.0, "healthy label: {}", labels[0]);
        assert_eq!(labels[1], HEAP_FAIL_LABEL, "failed label is replaced, not offset");
        assert_eq!(heap.failures.oom, 1);
        assert_eq!(heap.failures.total(), 1);

        let mut time = Labeller {
            runner: &runner,
            metric: Metric::ExecTime,
            seed: 11,
            count: 0,
            sim_time_s: 0.0,
            cap: 500.0,
            failures: FailureHisto::default(),
        };
        let labels = time.label_batch(&pool, &cfgs);
        assert!(labels[0] < 500.0, "healthy exec time under the cap");
        assert_eq!(labels[1], 500.0, "failed exec time lands exactly on the cap");
    }

    #[test]
    fn cancelled_characterization_returns_partial_dataset_and_progress() {
        let runner = SparkRunner::paper_default(Benchmark::Lda);
        let ctl = JobControl::default();
        ctl.cancel();
        let cfg = quick_cfg();
        let r = characterize_ctl(
            &ExecPool::serial(),
            &runner,
            GcMode::G1GC,
            Metric::ExecTime,
            Strategy::Bemcm,
            &cfg,
            &backend(),
            &ctl,
        )
        .unwrap();
        // Cancelled before round 1: seed set only, no AL rounds.
        assert_eq!(r.rounds, 0);
        assert_eq!(r.dataset.len(), cfg.seed_runs);
        assert_eq!(r.rmse_history.len(), 1);
        // The seed fit still published its progress snapshot.
        let p = ctl.progress();
        assert_eq!(p.round, Some(0));
        assert_eq!(p.max_rounds, Some(cfg.max_rounds));
        assert!(p.last_rmse.unwrap().is_finite());
        assert!(p.runs_executed.unwrap() >= cfg.seed_runs);
    }

    #[test]
    fn respects_n_train_cap() {
        let runner = SparkRunner::paper_default(Benchmark::Lda);
        let mut cfg = quick_cfg();
        cfg.pool_size = 400;
        cfg.batch_k = 60;
        cfg.max_rounds = 10;
        cfg.rmse_rel_tol = 0.0; // never converge early
        let r = characterize(
            &runner,
            GcMode::G1GC,
            Metric::ExecTime,
            Strategy::Bemcm,
            &cfg,
            &backend(),
        )
        .unwrap();
        assert!(r.dataset.len() <= N_TRAIN);
    }
}
