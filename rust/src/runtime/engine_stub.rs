//! Stub `XlaEngine` for builds without the `xla` feature.
//!
//! The offline image does not ship the PJRT `xla` crate, so the real
//! engine (engine.rs) only compiles behind `--features xla`.  This stub
//! keeps the public surface — `XlaEngine::load` and the `MlBackend`
//! impl — so callers (`load_backend`, the cross-check tests, the
//! benches) compile unchanged; `load` always fails and every caller
//! falls back to `NativeBackend`.

use std::path::Path;

use anyhow::Result;

use super::{GpConfig, GpSession, MlBackend};

/// Placeholder for the PJRT engine; cannot be constructed.
pub struct XlaEngine {
    _private: (),
}

impl XlaEngine {
    /// Always fails: this build has no PJRT runtime.
    pub fn load(dir: impl AsRef<Path>) -> Result<XlaEngine> {
        anyhow::bail!(
            "built without the `xla` feature — cannot load PJRT artifacts from {} \
             (rebuild with `--features xla` on an image that ships the `xla` crate)",
            dir.as_ref().display()
        )
    }
}

impl MlBackend for XlaEngine {
    fn name(&self) -> &'static str {
        "xla-unavailable"
    }

    fn emcm_score(
        &self,
        _w_ens: &[Vec<f64>],
        _w0: &[f64],
        _x: &[Vec<f64>],
    ) -> Result<Vec<f64>> {
        unreachable!("XlaEngine cannot be constructed without the `xla` feature")
    }

    fn lr_fit(&self, _x: &[Vec<f64>], _y: &[f64], _ridge: f64) -> Result<Vec<f64>> {
        unreachable!("XlaEngine cannot be constructed without the `xla` feature")
    }

    fn lasso_fit(&self, _x: &[Vec<f64>], _y: &[f64], _lam: f64) -> Result<Vec<f64>> {
        unreachable!("XlaEngine cannot be constructed without the `xla` feature")
    }

    fn gp_ei(
        &self,
        _xtr: &[Vec<f64>],
        _ytr: &[f64],
        _xc: &[Vec<f64>],
        _lengthscales: &[f64],
        _sigma_f2: f64,
        _sigma_n2: f64,
        _best: f64,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
        unreachable!("XlaEngine cannot be constructed without the `xla` feature")
    }

    /// Mirrors the real engine's contract: were it constructible, this
    /// would serve the one-shot wrapper, which ignores `HyperMode::Adapt`
    /// (no cached factor to adapt — one-shot sessions are always fixed).
    fn gp_open(&self, _cfg: &GpConfig) -> Result<Box<dyn GpSession + '_>> {
        unreachable!("XlaEngine cannot be constructed without the `xla` feature")
    }
}
