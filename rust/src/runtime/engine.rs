//! XlaEngine: compile the four HLO artifacts on the PJRT CPU client and run
//! them with padded/masked f32 literals.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax >= 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::{GpConfig, GpSession, MlBackend, D_FEAT, M_CAND, N_TRAIN, Z_ENS};
use crate::util::json::Json;

pub struct XlaEngine {
    /// PJRT executables are not documented thread-safe; serialize calls.
    inner: Mutex<Inner>,
}

struct Inner {
    _client: xla::PjRtClient,
    emcm: xla::PjRtLoadedExecutable,
    gp_ei: xla::PjRtLoadedExecutable,
    lr_fit: xla::PjRtLoadedExecutable,
    lasso_fit: xla::PjRtLoadedExecutable,
}

// SAFETY: the `xla` crate wraps PJRT handles in `Rc` purely to share them
// between the client and its executables within one object graph; all of
// those `Rc` clones live inside this `Inner` and are only ever touched
// while holding the surrounding `Mutex`, so no reference count is ever
// mutated concurrently.  The underlying TFRT CPU client itself is
// thread-safe.
unsafe impl Send for Inner {}

impl XlaEngine {
    /// Load and compile all artifacts from `dir` (validating the manifest
    /// against the shape constants this runtime was built for).
    pub fn load(dir: impl AsRef<Path>) -> Result<XlaEngine> {
        let dir = dir.as_ref();
        let manifest_path = dir.join("manifest.json");
        let manifest = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Json::parse(&manifest)
            .map_err(|e| anyhow::anyhow!("manifest.json: {e}"))?;
        let shapes = manifest
            .get("shapes")
            .context("manifest missing shapes")?;
        let check = |key: &str, want: usize| -> Result<()> {
            let got = shapes
                .get(key)
                .and_then(Json::as_f64)
                .with_context(|| format!("manifest missing shapes.{key}"))?;
            anyhow::ensure!(
                got as usize == want,
                "artifact shape {key}={got} but runtime expects {want}; re-run `make artifacts`"
            );
            Ok(())
        };
        check("d_feat", D_FEAT)?;
        check("n_train", N_TRAIN)?;
        check("m_cand", M_CAND)?;
        check("z_ens", Z_ENS)?;

        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))
        };

        Ok(XlaEngine {
            inner: Mutex::new(Inner {
                emcm: compile("emcm_score")?,
                gp_ei: compile("gp_ei")?,
                lr_fit: compile("lr_fit")?,
                lasso_fit: compile("lasso_fit")?,
                _client: client,
            }),
        })
    }
}

// --- padding helpers -------------------------------------------------------

/// Flatten rows into a zero-padded row-major f32 buffer of (n, d).
fn pad_matrix(rows: &[Vec<f64>], n: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * d];
    for (i, r) in rows.iter().enumerate() {
        for (j, &v) in r.iter().enumerate() {
            out[i * d + j] = v as f32;
        }
    }
    out
}

fn pad_vec(v: &[f64], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for (o, &x) in out.iter_mut().zip(v) {
        *o = x as f32;
    }
    out
}

fn mask(live: usize, n: usize) -> Vec<f32> {
    let mut m = vec![0.0f32; n];
    for v in m.iter_mut().take(live) {
        *v = 1.0;
    }
    m
}

fn lit_mat(buf: &[f32], n: usize, d: usize) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(buf).reshape(&[n as i64, d as i64])?)
}

fn lit_vec(buf: &[f32]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(buf))
}

fn run1(exe: &xla::PjRtLoadedExecutable, args: &[&xla::Literal]) -> Result<xla::Literal> {
    let result = exe.execute::<&xla::Literal>(args)?[0][0].to_literal_sync()?;
    Ok(result)
}

impl MlBackend for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn emcm_score(
        &self,
        w_ens: &[Vec<f64>],
        w0: &[f64],
        x: &[Vec<f64>],
    ) -> Result<Vec<f64>> {
        anyhow::ensure!(w_ens.len() == Z_ENS, "EMCM needs exactly {Z_ENS} ensembles");
        let d_live = w0.len();
        anyhow::ensure!(d_live <= D_FEAT, "feature dim {d_live} > {D_FEAT}");
        let inner = self.inner.lock().unwrap();
        let wens_lit = lit_mat(&pad_matrix(w_ens, Z_ENS, D_FEAT), Z_ENS, D_FEAT)?;
        let w0_lit = lit_vec(&pad_vec(w0, D_FEAT))?;
        let mask_lit = lit_vec(&mask(d_live, D_FEAT))?;

        let mut scores = Vec::with_capacity(x.len());
        for chunk in x.chunks(M_CAND) {
            let x_lit = lit_mat(&pad_matrix(chunk, M_CAND, D_FEAT), M_CAND, D_FEAT)?;
            let out = run1(&inner.emcm, &[&wens_lit, &w0_lit, &x_lit, &mask_lit])?
            .to_tuple1()?;
            let v = out.to_vec::<f32>()?;
            scores.extend(v[..chunk.len()].iter().map(|&s| s as f64));
        }
        Ok(scores)
    }

    fn lr_fit(&self, x: &[Vec<f64>], y: &[f64], ridge: f64) -> Result<Vec<f64>> {
        let n_live = x.len();
        anyhow::ensure!(n_live <= N_TRAIN, "training rows {n_live} > {N_TRAIN}");
        anyhow::ensure!(n_live == y.len());
        let d_live = x.first().map(|r| r.len()).unwrap_or(0);
        anyhow::ensure!(d_live <= D_FEAT);
        let inner = self.inner.lock().unwrap();
        let args = [
            lit_mat(&pad_matrix(x, N_TRAIN, D_FEAT), N_TRAIN, D_FEAT)?,
            lit_vec(&pad_vec(y, N_TRAIN))?,
            lit_vec(&mask(n_live, N_TRAIN))?,
            lit_vec(&mask(d_live, D_FEAT))?,
            lit_vec(&[ridge as f32])?,
        ];
        let out = run1(&inner.lr_fit, &args.iter().collect::<Vec<_>>())?
        .to_tuple1()?;
        let w = out.to_vec::<f32>()?;
        Ok(w[..d_live].iter().map(|&v| v as f64).collect())
    }

    fn lasso_fit(&self, x: &[Vec<f64>], y: &[f64], lam: f64) -> Result<Vec<f64>> {
        let n_live = x.len();
        anyhow::ensure!(n_live <= N_TRAIN, "training rows {n_live} > {N_TRAIN}");
        anyhow::ensure!(n_live == y.len());
        let d_live = x.first().map(|r| r.len()).unwrap_or(0);
        anyhow::ensure!(d_live <= D_FEAT);
        let inner = self.inner.lock().unwrap();
        let args = [
            lit_mat(&pad_matrix(x, N_TRAIN, D_FEAT), N_TRAIN, D_FEAT)?,
            lit_vec(&pad_vec(y, N_TRAIN))?,
            lit_vec(&mask(n_live, N_TRAIN))?,
            lit_vec(&mask(d_live, D_FEAT))?,
            lit_vec(&[lam as f32])?,
        ];
        let out = run1(&inner.lasso_fit, &args.iter().collect::<Vec<_>>())?
        .to_tuple1()?;
        let w = out.to_vec::<f32>()?;
        Ok(w[..d_live].iter().map(|&v| v as f64).collect())
    }

    fn gp_ei(
        &self,
        xtr: &[Vec<f64>],
        ytr: &[f64],
        xc: &[Vec<f64>],
        lengthscales: &[f64],
        sigma_f2: f64,
        sigma_n2: f64,
        best: f64,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
        // The AOT artifact's theta vector carries one length-scale: only
        // the isotropic (all-equal) case maps onto it.  ARD length-scales
        // never reach here — adaptation is native-session-only
        // (`supports_hyper_adaptation` is false for this engine).
        let lengthscale = crate::native::ops::iso_lengthscale(lengthscales)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "the XLA gp_ei artifact is isotropic: per-dimension (ARD) \
                     length-scales require the native backend"
                )
            })?;
        let n_live = xtr.len();
        anyhow::ensure!(n_live <= N_TRAIN, "GP training rows {n_live} > {N_TRAIN}");
        anyhow::ensure!(n_live == ytr.len());
        let d_live = xtr.first().map(|r| r.len()).unwrap_or(0);
        anyhow::ensure!(d_live <= D_FEAT);
        let inner = self.inner.lock().unwrap();
        let xtr_lit = lit_mat(&pad_matrix(xtr, N_TRAIN, D_FEAT), N_TRAIN, D_FEAT)?;
        let ytr_lit = lit_vec(&pad_vec(ytr, N_TRAIN))?;
        let rmask_lit = lit_vec(&mask(n_live, N_TRAIN))?;
        let fmask_lit = lit_vec(&mask(d_live, D_FEAT))?;
        let theta = lit_vec(&[
            lengthscale as f32,
            sigma_f2 as f32,
            sigma_n2 as f32,
            best as f32,
        ])?;

        let mut ei = Vec::with_capacity(xc.len());
        let mut mu = Vec::with_capacity(xc.len());
        let mut sigma = Vec::with_capacity(xc.len());
        for chunk in xc.chunks(M_CAND) {
            let xc_lit = lit_mat(&pad_matrix(chunk, M_CAND, D_FEAT), M_CAND, D_FEAT)?;
            let (e, m, s) = run1(
                &inner.gp_ei,
                &[&xtr_lit, &ytr_lit, &rmask_lit, &xc_lit, &fmask_lit, &theta],
            )?
            .to_tuple3()?;
            let (e, m, s) = (e.to_vec::<f32>()?, m.to_vec::<f32>()?, s.to_vec::<f32>()?);
            ei.extend(e[..chunk.len()].iter().map(|&v| v as f64));
            mu.extend(m[..chunk.len()].iter().map(|&v| v as f64));
            sigma.extend(s[..chunk.len()].iter().map(|&v| v as f64));
        }
        Ok((ei, mu, sigma))
    }

    /// No incremental artifact exists for the AOT `gp_ei` executable, so
    /// XLA sessions re-run it per acquire (the one-shot path).  This also
    /// means `HyperMode::Adapt` is ignored here: there is no cached
    /// factor to run the marginal-likelihood ascent on, and the AOT
    /// executable bakes its hyper-parameters in per call — XLA sessions
    /// always behave as `HyperMode::Fixed`.
    fn gp_open(&self, cfg: &GpConfig) -> Result<Box<dyn GpSession + '_>> {
        Ok(super::one_shot_gp(self, cfg))
    }
}
