//! PJRT runtime: load the AOT HLO artifacts produced by `make artifacts`
//! and expose them as the `MlBackend` the pipeline calls.  Python never
//! runs here — the artifacts are self-contained HLO text compiled once at
//! engine construction.
//!
//! `NativeBackend` (pure rust, `native::ops`) implements the same trait;
//! integration tests cross-check the two and benches compare them.

/// The real PJRT engine needs the `xla` crate (artifact-build image only);
/// plain builds get a stub whose `load` always fails, so `load_backend`
/// falls back to the native mirror.
#[cfg(feature = "xla")]
pub mod engine;
#[cfg(not(feature = "xla"))]
#[path = "engine_stub.rs"]
pub mod engine;

use anyhow::Result;

use crate::exec::ExecPool;

/// Hyper-parameter policy of a GP session — the Fixed-vs-Adapt contract
/// under the **vector hyper model** (`GpConfig.lengthscales` holds one RBF
/// length-scale per tuning dimension; ln ℓ₁..ln ℓ_d and ln σₙ² are the
/// d+1 free parameters adaptation can move):
///
/// * [`HyperMode::Fixed`] freezes the [`GpConfig`] hyper-parameters and
///   rebuilds the Cholesky factor from the cached kernel on eviction —
///   every posterior is **bitwise** equal to the one-shot `gp_ei`
///   reference (the PR-2 guarantee, guarded by `tests/gp_incremental.rs`).
///   This holds for *any* length-scale vector: with all entries equal the
///   kernel takes the isotropic summation order (squared distance summed
///   across dimensions first, scaled once) and is bit-identical to the
///   pre-ARD scalar implementation; with unequal entries both the session
///   and the one-shot reference use the same weighted per-dimension sum.
/// * [`HyperMode::Adapt`] trades bitwise reproducibility for speed and
///   model quality: evictions run the O(n²) rank-1 `cholesky_downdate`
///   (predictions pinned to the rebuild path within 1e-8 by
///   `tests/gp_downdate.rs`), and every `every` appends the session takes
///   a few bounded marginal-likelihood ascent steps (monotone per accepted
///   step), refactoring the cached kernel only when the hyper-parameters
///   actually move.  With [`GpConfig::ard`] **off** the length-scales move
///   as one tied parameter — ascent over (ln ℓ, ln σₙ²), exactly the
///   scalar behaviour; with `ard` **on** every dimension's length-scale
///   moves independently (Automatic Relevance Determination) and the
///   analytic gradient grows from 2 to d+1 entries.  ARD traces stay
///   monotone per accepted step (`tests/gp_ard.rs` validates the gradient
///   against central finite differences).
///
/// One-shot sessions ([`one_shot_gp`], the XLA engine's `gp_open`) have no
/// cached factor to adapt and always behave as `Fixed`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum HyperMode {
    #[default]
    Fixed,
    Adapt {
        /// Appends between adaptation rounds on an actively-driven
        /// session.  During a bulk feed (no acquisitions between the
        /// appends — e.g. a warm start) the session amortizes to ~one
        /// round per 25% training-set growth instead, since nothing
        /// reads the intermediate hyper-parameters.
        every: usize,
    },
}

impl HyperMode {
    /// Default adaptation cadence: one ascent round per 8 appends keeps
    /// the amortized cost well under one kernel refactor per append.
    pub const DEFAULT_ADAPT_EVERY: usize = 8;

    /// `Adapt` at the default cadence.
    pub fn adapt() -> HyperMode {
        HyperMode::Adapt { every: Self::DEFAULT_ADAPT_EVERY }
    }

    pub fn parse(s: &str) -> Option<HyperMode> {
        match s.to_ascii_lowercase().as_str() {
            "fixed" => Some(HyperMode::Fixed),
            "adapt" | "adaptive" => Some(HyperMode::adapt()),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            HyperMode::Fixed => "fixed",
            HyperMode::Adapt { .. } => "adapt",
        }
    }
}

/// Linear-algebra kernel tier of a GP session — which *implementation*
/// of the numeric hot loops (multi-RHS triangular solves in EI scoring,
/// weighted-sum trial-kernel rebuilds, the O(n³) Cholesky rebuild) the
/// session runs.  Orthogonal to [`HyperMode`]: the policy never changes
/// *what* is computed, only the floating-point summation order it is
/// computed in.
///
/// * [`KernelPolicy::Scalar`] (the default) keeps today's arithmetic
///   exactly: every reduction runs in the scalar loop order the
///   bitwise pins were recorded against (`tests/gp_incremental.rs`,
///   `tests/gp_downdate.rs`, `tests/gp_ard.rs`).  A Scalar session is
///   byte-for-byte the pre-policy tuner.
/// * [`KernelPolicy::Blocked`] runs the blocked/SIMD-friendly tier in
///   `native::kernels`: panel-blocked multi-RHS solves with fixed-width
///   lane accumulators, a blocked-panel Cholesky rebuild, and
///   fixed-lane weighted sums for trial-kernel evaluation.  Blocking
///   changes the float reduction order, so Blocked is **not** bitwise
///   equal to Scalar — it is pinned to Scalar within 1e-8 by
///   `tests/gp_kernels.rs` — but every block size and reduction tree is
///   a constant of the algorithm (never derived from pool width or data
///   values), so a Blocked session is bitwise self-reproducible at any
///   `ExecPool` width, the same width-invariance contract Scalar
///   carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelPolicy {
    #[default]
    Scalar,
    Blocked,
}

impl KernelPolicy {
    pub fn parse(s: &str) -> Option<KernelPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelPolicy::Scalar),
            "blocked" => Some(KernelPolicy::Blocked),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelPolicy::Scalar => "scalar",
            KernelPolicy::Blocked => "blocked",
        }
    }
}

/// Hyper-parameters + shape of a GP surrogate session.
#[derive(Clone, Debug)]
pub struct GpConfig {
    /// Input dimension (the tuning subspace, not the encoded feature dim).
    pub dim: usize,
    /// Per-dimension RBF length-scales (`lengthscales.len() == dim`).
    /// All-equal entries select the isotropic summation order, keeping
    /// the kernel bit-identical to the scalar implementation it replaced.
    pub lengthscales: Vec<f64>,
    pub sigma_f2: f64,
    pub sigma_n2: f64,
    /// Training-row budget (`observe` past it errors) — [`N_TRAIN`] for
    /// the artifact-backed pipeline.
    pub cap: usize,
    /// Hyper-parameter policy (see [`HyperMode`] for the equality
    /// contract each side carries).
    pub hyper: HyperMode,
    /// Automatic Relevance Determination: under [`HyperMode::Adapt`],
    /// move every per-dimension length-scale independently instead of as
    /// one tied parameter.  Has no effect under [`HyperMode::Fixed`].
    pub ard: bool,
    /// Linear-algebra kernel tier (see [`KernelPolicy`]): `Scalar`
    /// keeps the bitwise-pinned loop order, `Blocked` runs the
    /// panel/lane tier pinned to it at 1e-8.  One-shot sessions ignore
    /// this and always score through the scalar reference arithmetic.
    pub kernels: KernelPolicy,
}

impl GpConfig {
    /// Isotropic configuration: one `lengthscale` replicated across `dim`
    /// (the pre-ARD scalar behaviour), ARD off.
    pub fn isotropic(
        dim: usize,
        lengthscale: f64,
        sigma_f2: f64,
        sigma_n2: f64,
        cap: usize,
        hyper: HyperMode,
    ) -> GpConfig {
        GpConfig {
            dim,
            lengthscales: vec![lengthscale; dim],
            sigma_f2,
            sigma_n2,
            cap,
            hyper,
            ard: false,
            kernels: KernelPolicy::Scalar,
        }
    }
}

/// A stateful GP surrogate that persists across BO iterations, so the
/// per-iteration cost is an incremental update instead of a from-scratch
/// refit.  Obtained from [`MlBackend::gp_open`] (backend's best
/// implementation) or [`one_shot_gp`] (the cross-check reference that
/// re-fits through `gp_ei` every call).  Under [`HyperMode::Fixed`] both
/// paths are bit-identical (guarded by `tests/gp_incremental.rs`); under
/// [`HyperMode::Adapt`] the native session downdates on eviction and
/// adapts its hyper-parameters, and is pinned to the reference at 1e-8
/// tolerance instead (`tests/gp_downdate.rs`).
pub trait GpSession: Send {
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Raw (unstandardized) targets, in observation order.
    fn ys(&self) -> &[f64];

    /// Current hyper-parameters: per-dimension length-scales (tuning-space
    /// dimension order) + noise variance.  Moves under
    /// [`HyperMode::Adapt`] on sessions that support adaptation; frozen at
    /// the [`GpConfig`] values otherwise.  The warm-start payload for a
    /// follow-up job (`tune --gp-init-hypers`, REST `gp_init_hypers`).
    fn hypers(&self) -> (Vec<f64>, f64);

    /// Append one observation.
    fn observe(&mut self, x: &[f64], y: f64) -> Result<()>;

    /// Drop observation `i` (`Vec::remove` semantics: the order of the
    /// remaining observations is preserved).
    fn forget(&mut self, i: usize) -> Result<()>;

    /// Append a *fantasy* observation (constant-liar q-EI): a transient
    /// point the session must be able to retract bitwise via
    /// [`GpSession::pop_fantasy`].  The contract: any sequence of
    /// `fantasize` calls followed by the same number of `pop_fantasy`
    /// calls leaves the session bit-for-bit where it started — no
    /// hyper-parameter adaptation, no cadence bookkeeping, no other side
    /// effect may fire on a fantasy.  The default routes through
    /// `observe`, which satisfies the contract for sessions without
    /// adaptation state (the one-shot wrapper); stateful sessions
    /// override it to skip their adaptation bookkeeping.
    fn fantasize(&mut self, x: &[f64], y_liar: f64) -> Result<()> {
        self.observe(x, y_liar)
    }

    /// Retract the most recent [`GpSession::fantasize`] — the bitwise
    /// inverse of the fantasy append (last-row truncation, which
    /// `cholesky_downdate(last)` performs exactly; pinned by
    /// `tests/property_invariants.rs`).  The default forgets the last
    /// row, correct for any session whose `forget(len-1)` is a pure
    /// truncation.
    fn pop_fantasy(&mut self) -> Result<()> {
        anyhow::ensure!(self.len() > 0, "pop_fantasy on an empty session");
        self.forget(self.len() - 1)
    }

    /// Expected improvement, posterior mean and std (all in
    /// standardized-target space) at the candidates, sharded over `pool`
    /// in fixed-size blocks — results are index-ordered, so pool width
    /// never changes a value.  `best` is the *raw* incumbent objective.
    fn acquire(
        &self,
        pool: &ExecPool,
        xc: &[Vec<f64>],
        best: f64,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)>;
}

/// The four ML operations the pipeline needs (mirrors python/compile/model
/// exports).  All matrices are row-major `Vec<Vec<f64>>`.
///
/// Shape limits (from python/compile/shapes.py): feature dim <= 320,
/// training rows <= 256 per fit, EMCM ensembles of exactly 8 models;
/// candidate batches are chunked internally, so any M is accepted.
pub trait MlBackend: Send + Sync {
    fn name(&self) -> &'static str;

    /// BEMCM scores for a candidate pool.
    fn emcm_score(
        &self,
        w_ens: &[Vec<f64>],
        w0: &[f64],
        x: &[Vec<f64>],
    ) -> Result<Vec<f64>>;

    /// Ridge LR weights.
    fn lr_fit(&self, x: &[Vec<f64>], y: &[f64], ridge: f64) -> Result<Vec<f64>>;

    /// Lasso weights (ISTA, 400 iterations).
    fn lasso_fit(&self, x: &[Vec<f64>], y: &[f64], lam: f64) -> Result<Vec<f64>>;

    /// GP posterior + EI at candidates: (ei, mu, sigma), under
    /// per-dimension (ARD) length-scales.  All-equal `lengthscales` are
    /// the isotropic kernel, bit-identical (native backend) to the old
    /// scalar-lengthscale call; the XLA artifact only supports that
    /// isotropic case.
    #[allow(clippy::too_many_arguments)]
    fn gp_ei(
        &self,
        xtr: &[Vec<f64>],
        ytr: &[f64],
        xc: &[Vec<f64>],
        lengthscales: &[f64],
        sigma_f2: f64,
        sigma_n2: f64,
        best: f64,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)>;

    /// Open a stateful GP surrogate session.  The native backend returns
    /// the incremental cached-Cholesky surrogate (`native::gp`); the XLA
    /// engine has no incremental artifact and returns the [`one_shot_gp`]
    /// wrapper over its `gp_ei` executable.
    fn gp_open(&self, cfg: &GpConfig) -> Result<Box<dyn GpSession + '_>>;

    /// Whether this backend's `gp_open` sessions honour
    /// [`HyperMode::Adapt`].  True only for the native incremental
    /// surrogate; one-shot wrappers (the XLA engine's fixed-shape AOT
    /// `gp_ei`) have no cached factor to adapt and always behave as
    /// `Fixed` — callers reporting the *effective* policy (the REST tune
    /// job record) consult this instead of echoing the request.
    fn supports_hyper_adaptation(&self) -> bool {
        false
    }

    /// Whether callers should shard `emcm_score` into small chunks for
    /// the exec pool.  True for the per-row native mirror; false (the
    /// default) for backends like the XLA engine, whose executable pads
    /// every call to [`M_CAND`] rows and serializes on an engine lock —
    /// there one batched call is strictly cheaper.  Chunking never
    /// changes values (scores are per-row), only the fan-out shape.
    fn prefers_sharded_scoring(&self) -> bool {
        false
    }
}

/// Ensemble size every backend expects for EMCM (shapes.py Z_ENS).
pub const Z_ENS: usize = 8;
/// Max feature dimension (shapes.py D_FEAT).
pub const D_FEAT: usize = 320;
/// Max training rows per fit (shapes.py N_TRAIN).
pub const N_TRAIN: usize = 256;
/// Candidate chunk size (shapes.py M_CAND).
pub const M_CAND: usize = 512;

/// Pure-rust backend (native::ops).
pub struct NativeBackend;

impl MlBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn emcm_score(
        &self,
        w_ens: &[Vec<f64>],
        w0: &[f64],
        x: &[Vec<f64>],
    ) -> Result<Vec<f64>> {
        anyhow::ensure!(w_ens.len() == Z_ENS, "EMCM needs exactly {Z_ENS} ensembles");
        Ok(crate::native::ops::emcm_score(w_ens, w0, x))
    }

    fn lr_fit(&self, x: &[Vec<f64>], y: &[f64], ridge: f64) -> Result<Vec<f64>> {
        Ok(crate::native::ops::lr_fit(x, y, ridge))
    }

    fn lasso_fit(&self, x: &[Vec<f64>], y: &[f64], lam: f64) -> Result<Vec<f64>> {
        Ok(crate::native::ops::lasso_fit(x, y, lam, 400))
    }

    fn gp_ei(
        &self,
        xtr: &[Vec<f64>],
        ytr: &[f64],
        xc: &[Vec<f64>],
        lengthscales: &[f64],
        sigma_f2: f64,
        sigma_n2: f64,
        best: f64,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
        Ok(crate::native::ops::gp_ei(
            xtr, ytr, xc, lengthscales, sigma_f2, sigma_n2, best,
        ))
    }

    fn gp_open(&self, cfg: &GpConfig) -> Result<Box<dyn GpSession + '_>> {
        Ok(Box::new(crate::native::gp::GpSurrogate::new(cfg)))
    }

    fn supports_hyper_adaptation(&self) -> bool {
        true
    }

    fn prefers_sharded_scoring(&self) -> bool {
        true
    }
}

/// [`GpSession`] over any backend's one-shot `gp_ei`: the training set is
/// kept as plain rows and every `acquire` re-fits from scratch.  This is
/// the cross-check reference for the incremental surrogate and the session
/// the XLA engine serves (its `gp_ei` executable is a fixed-shape AOT
/// artifact with no incremental variant).  [`HyperMode::Adapt`] (and with
/// it `GpConfig::ard`) is ignored here: a one-shot refit has no cached
/// factor to run the marginal-likelihood ascent on, so one-shot sessions
/// always behave as `Fixed` — which is also what makes them the bitwise
/// reference, at any length-scale vector.
struct OneShotGp<'a> {
    backend: &'a dyn MlBackend,
    cfg: GpConfig,
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
}

/// Open a one-shot (refit-per-acquire) session over `backend`'s `gp_ei`.
pub fn one_shot_gp<'a>(backend: &'a dyn MlBackend, cfg: &GpConfig) -> Box<dyn GpSession + 'a> {
    Box::new(OneShotGp { backend, cfg: cfg.clone(), xs: Vec::new(), ys: Vec::new() })
}

impl GpSession for OneShotGp<'_> {
    fn len(&self) -> usize {
        self.ys.len()
    }

    fn ys(&self) -> &[f64] {
        &self.ys
    }

    fn hypers(&self) -> (Vec<f64>, f64) {
        (self.cfg.lengthscales.clone(), self.cfg.sigma_n2)
    }

    fn observe(&mut self, x: &[f64], y: f64) -> Result<()> {
        anyhow::ensure!(
            x.len() == self.cfg.dim,
            "GP point dim {} != {}",
            x.len(),
            self.cfg.dim
        );
        anyhow::ensure!(
            self.ys.len() < self.cfg.cap,
            "GP training rows at cap {}",
            self.cfg.cap
        );
        self.xs.push(x.to_vec());
        self.ys.push(y);
        Ok(())
    }

    fn forget(&mut self, i: usize) -> Result<()> {
        anyhow::ensure!(i < self.ys.len(), "forget({i}) of {} rows", self.ys.len());
        self.xs.remove(i);
        self.ys.remove(i);
        Ok(())
    }

    fn acquire(
        &self,
        _pool: &ExecPool,
        xc: &[Vec<f64>],
        best: f64,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
        anyhow::ensure!(!self.ys.is_empty(), "GP needs observations before acquisition");
        let scaler = crate::util::stats::TargetScaler::fit(&self.ys);
        let ysc: Vec<f64> = self.ys.iter().map(|&v| scaler.transform(v)).collect();
        self.backend.gp_ei(
            &self.xs,
            &ysc,
            xc,
            &self.cfg.lengthscales,
            self.cfg.sigma_f2,
            self.cfg.sigma_n2,
            scaler.transform(best),
        )
    }
}

/// Load the best available backend: the XLA engine if `artifacts/` is
/// present and loads cleanly, the native mirror otherwise.
pub fn load_backend(artifacts_dir: &str) -> std::sync::Arc<dyn MlBackend> {
    match engine::XlaEngine::load(artifacts_dir) {
        Ok(e) => std::sync::Arc::new(e),
        Err(err) => {
            eprintln!(
                "warning: XLA artifacts unavailable ({err:#}); using native backend"
            );
            std::sync::Arc::new(NativeBackend)
        }
    }
}
