//! PJRT runtime: load the AOT HLO artifacts produced by `make artifacts`
//! and expose them as the `MlBackend` the pipeline calls.  Python never
//! runs here — the artifacts are self-contained HLO text compiled once at
//! engine construction.
//!
//! `NativeBackend` (pure rust, `native::ops`) implements the same trait;
//! integration tests cross-check the two and benches compare them.

/// The real PJRT engine needs the `xla` crate (artifact-build image only);
/// plain builds get a stub whose `load` always fails, so `load_backend`
/// falls back to the native mirror.
#[cfg(feature = "xla")]
pub mod engine;
#[cfg(not(feature = "xla"))]
#[path = "engine_stub.rs"]
pub mod engine;

use anyhow::Result;

/// The four ML operations the pipeline needs (mirrors python/compile/model
/// exports).  All matrices are row-major `Vec<Vec<f64>>`.
///
/// Shape limits (from python/compile/shapes.py): feature dim <= 320,
/// training rows <= 256 per fit, EMCM ensembles of exactly 8 models;
/// candidate batches are chunked internally, so any M is accepted.
pub trait MlBackend: Send + Sync {
    fn name(&self) -> &'static str;

    /// BEMCM scores for a candidate pool.
    fn emcm_score(
        &self,
        w_ens: &[Vec<f64>],
        w0: &[f64],
        x: &[Vec<f64>],
    ) -> Result<Vec<f64>>;

    /// Ridge LR weights.
    fn lr_fit(&self, x: &[Vec<f64>], y: &[f64], ridge: f64) -> Result<Vec<f64>>;

    /// Lasso weights (ISTA, 400 iterations).
    fn lasso_fit(&self, x: &[Vec<f64>], y: &[f64], lam: f64) -> Result<Vec<f64>>;

    /// GP posterior + EI at candidates: (ei, mu, sigma).
    #[allow(clippy::too_many_arguments)]
    fn gp_ei(
        &self,
        xtr: &[Vec<f64>],
        ytr: &[f64],
        xc: &[Vec<f64>],
        lengthscale: f64,
        sigma_f2: f64,
        sigma_n2: f64,
        best: f64,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)>;
}

/// Ensemble size every backend expects for EMCM (shapes.py Z_ENS).
pub const Z_ENS: usize = 8;
/// Max feature dimension (shapes.py D_FEAT).
pub const D_FEAT: usize = 320;
/// Max training rows per fit (shapes.py N_TRAIN).
pub const N_TRAIN: usize = 256;
/// Candidate chunk size (shapes.py M_CAND).
pub const M_CAND: usize = 512;

/// Pure-rust backend (native::ops).
pub struct NativeBackend;

impl MlBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn emcm_score(
        &self,
        w_ens: &[Vec<f64>],
        w0: &[f64],
        x: &[Vec<f64>],
    ) -> Result<Vec<f64>> {
        anyhow::ensure!(w_ens.len() == Z_ENS, "EMCM needs exactly {Z_ENS} ensembles");
        Ok(crate::native::ops::emcm_score(w_ens, w0, x))
    }

    fn lr_fit(&self, x: &[Vec<f64>], y: &[f64], ridge: f64) -> Result<Vec<f64>> {
        Ok(crate::native::ops::lr_fit(x, y, ridge))
    }

    fn lasso_fit(&self, x: &[Vec<f64>], y: &[f64], lam: f64) -> Result<Vec<f64>> {
        Ok(crate::native::ops::lasso_fit(x, y, lam, 400))
    }

    fn gp_ei(
        &self,
        xtr: &[Vec<f64>],
        ytr: &[f64],
        xc: &[Vec<f64>],
        lengthscale: f64,
        sigma_f2: f64,
        sigma_n2: f64,
        best: f64,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
        Ok(crate::native::ops::gp_ei(
            xtr, ytr, xc, lengthscale, sigma_f2, sigma_n2, best,
        ))
    }
}

/// Load the best available backend: the XLA engine if `artifacts/` is
/// present and loads cleanly, the native mirror otherwise.
pub fn load_backend(artifacts_dir: &str) -> std::sync::Arc<dyn MlBackend> {
    match engine::XlaEngine::load(artifacts_dir) {
        Ok(e) => std::sync::Arc::new(e),
        Err(err) => {
            eprintln!(
                "warning: XLA artifacts unavailable ({err:#}); using native backend"
            );
            std::sync::Arc::new(NativeBackend)
        }
    }
}
