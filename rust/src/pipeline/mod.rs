//! End-to-end orchestration of the three phases (Fig 1): characterize →
//! select flags → tune, with the bookkeeping the experiments need
//! (default-config baselines, per-algorithm results, timing).

pub mod experiments;

use std::sync::Arc;

use anyhow::Result;

use crate::datagen::{self, CharacterizeResult, DataGenConfig, Strategy};
use crate::exec::{self, ExecPool, JobControl};
use crate::featsel::{self, Selection, DEFAULT_LAMBDA};
use crate::flags::{FlagConfig, GcMode};
use crate::runtime::MlBackend;
use crate::sparksim::SparkRunner;
use crate::tuner::{
    bo::BoConfig, sa::SaConfig, BoTuner, RboTuner, SaTuner, SimObjective, TuneResult,
    TuneSpace, Tuner,
};
use crate::util::stats::{summarize, Summary};
use crate::{Benchmark, Metric};

/// Which phase-3 algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    Bo,
    Rbo,
    BoWarm,
    Sa,
}

impl Algo {
    pub fn name(self) -> &'static str {
        match self {
            Algo::Bo => "BO",
            Algo::Rbo => "RBO",
            Algo::BoWarm => "BO, warm start",
            Algo::Sa => "SA",
        }
    }

    pub fn parse(s: &str) -> Option<Algo> {
        match s.to_ascii_lowercase().as_str() {
            "bo" => Some(Algo::Bo),
            "rbo" => Some(Algo::Rbo),
            "bo-warm" | "bowarm" | "warm" | "bo_warm" => Some(Algo::BoWarm),
            "sa" => Some(Algo::Sa),
            _ => None,
        }
    }

    pub fn all() -> [Algo; 4] {
        [Algo::Bo, Algo::Rbo, Algo::BoWarm, Algo::Sa]
    }
}

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub datagen: DataGenConfig,
    pub lambda: f64,
    pub bo: BoConfig,
    pub sa: SaConfig,
    pub tune_iters: usize,
    /// Repeats for the baseline/final measurement (paper: 10).
    pub repeats: usize,
    pub seed: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            datagen: DataGenConfig::default(),
            lambda: DEFAULT_LAMBDA,
            bo: BoConfig::default(),
            sa: SaConfig::default(),
            tune_iters: 20,
            repeats: 10,
            seed: 0x0057_0944,
        }
    }
}

/// Result of tuning one (benchmark, GC mode, metric) with one algorithm.
#[derive(Clone, Debug)]
pub struct AlgoOutcome {
    pub algo: Algo,
    pub tune: TuneResult,
    /// Final measurement of the recommended config (paper: mean±std of 10).
    pub tuned_summary: Summary,
    /// Improvement factor default/tuned (speedup for time; >1 is better).
    pub improvement: f64,
    /// Total tuning time: simulated benchmark runs + optimizer wall time.
    pub tuning_time_s: f64,
}

/// Full pipeline record for one (benchmark, mode, metric).
#[derive(Clone, Debug)]
pub struct PipelineOutcome {
    pub bench: Benchmark,
    pub mode: GcMode,
    pub metric: Metric,
    pub characterization: CharacterizeResult,
    pub selection: Selection,
    pub default_summary: Summary,
    pub outcomes: Vec<AlgoOutcome>,
}

/// Measure a config `repeats` times and summarize the chosen metric.
/// Runs on the process-global execution pool.
pub fn measure(
    runner: &SparkRunner,
    cfg: &FlagConfig,
    metric: Metric,
    repeats: usize,
    seed: u64,
) -> Summary {
    measure_on(exec::global(), runner, cfg, metric, repeats, seed)
}

/// `measure` on an explicit pool.  Each repeat's seed derives from its
/// index alone, so the summary is bit-identical at every pool width.
pub fn measure_on(
    pool: &ExecPool,
    runner: &SparkRunner,
    cfg: &FlagConfig,
    metric: Metric,
    repeats: usize,
    seed: u64,
) -> Summary {
    // Repeats own the fan-out; each run simulates its executors serially
    // rather than nesting a second pool per run.
    let inner = ExecPool::serial();
    let vals = pool.par_run(repeats.max(1), |i| {
        metric.of(&runner.run_on(&inner, cfg, seed.wrapping_add(i as u64 * 7919)))
    });
    summarize(&vals)
}

/// Run one algorithm on an already-characterized problem, with the
/// per-run executor fan-out on the global pool (right for a lone tuning
/// job, e.g. one `/api/tune` request).
#[allow(clippy::too_many_arguments)]
pub fn run_algo(
    algo: Algo,
    runner: &SparkRunner,
    space: &TuneSpace,
    ch: &CharacterizeResult,
    metric: Metric,
    cfg: &PipelineConfig,
    backend: &Arc<dyn MlBackend>,
    default_mean: f64,
) -> Result<AlgoOutcome> {
    run_algo_on(exec::global(), algo, runner, space, ch, metric, cfg, backend, default_mean)
}

/// `run_algo` with an explicit pool for the objective's per-run executor
/// fan-out and the final measurement.  Callers that already fan several
/// algorithms out in parallel pass `ExecPool::serial()` — the sweep owns
/// the cores, and nesting a second fan-out per simulated run would only
/// pay thread churn.  Results are identical either way.
#[allow(clippy::too_many_arguments)]
pub fn run_algo_on(
    epool: &ExecPool,
    algo: Algo,
    runner: &SparkRunner,
    space: &TuneSpace,
    ch: &CharacterizeResult,
    metric: Metric,
    cfg: &PipelineConfig,
    backend: &Arc<dyn MlBackend>,
    default_mean: f64,
) -> Result<AlgoOutcome> {
    run_algo_ctl(
        epool,
        algo,
        runner,
        space,
        ch,
        metric,
        cfg,
        backend,
        default_mean,
        &JobControl::default(),
    )
}

/// `run_algo_on` under a [`JobControl`] (the REST server's async tune
/// jobs): the tuner loop publishes per-iteration progress and honours
/// cooperative cancellation, returning the best-so-far configuration —
/// which is then still measured for the final summary, so a cancelled
/// tune reports a real partial result.
#[allow(clippy::too_many_arguments)]
pub fn run_algo_ctl(
    epool: &ExecPool,
    algo: Algo,
    runner: &SparkRunner,
    space: &TuneSpace,
    ch: &CharacterizeResult,
    metric: Metric,
    cfg: &PipelineConfig,
    backend: &Arc<dyn MlBackend>,
    default_mean: f64,
    ctl: &JobControl,
) -> Result<AlgoOutcome> {
    // Per-algo objective stream via a splitmix on the discriminant:
    // `cfg.seed ^ algo as u64` left Algo::Bo (discriminant 0) sharing the
    // pipeline's baseline-measurement stream.
    let mut objective =
        SimObjective::new_on(runner, metric, exec::index_seed(cfg.seed, algo as u64), *epool);
    // The acquisition sweep shards on the same pool as the objective:
    // `BoConfig::default()` captures the *global* pool at construction
    // time, which would oversubscribe the CPU whenever the caller fans
    // several algorithms out and hands us a serial pool.  Pool width
    // never changes results (exec module invariant), only scheduling.
    // The default configuration's measured mean doubles as the BO safe
    // baseline: candidates the surrogate predicts to be worse than the
    // untuned starting point are not worth a real (possibly failing) run.
    let bo_cfg = BoConfig {
        epool: *epool,
        safe_baseline: cfg.bo.safe_baseline.or(Some(default_mean)),
        ..cfg.bo.clone()
    };
    let mut tuner: Box<dyn Tuner> = match algo {
        Algo::Bo => Box::new(BoTuner::new(backend.clone(), bo_cfg)),
        Algo::BoWarm => Box::new(BoTuner::warm_start(
            backend.clone(),
            bo_cfg,
            space,
            &ch.dataset,
        )),
        Algo::Rbo => Box::new(RboTuner::new(
            backend.clone(),
            bo_cfg,
            ch.dataset.clone(),
        )),
        Algo::Sa => Box::new(SaTuner::new(cfg.sa.clone())),
    };
    let tune = tuner.tune_ctl(space, &mut objective, cfg.tune_iters, ctl)?;
    let tuned_summary =
        measure_on(epool, runner, &tune.best_config, metric, cfg.repeats, cfg.seed ^ 0xf17a1);
    let improvement = default_mean / tuned_summary.mean.max(1e-9);
    let tuning_time_s = tune.sim_time_s + tune.algo_wall_ms / 1e3;
    Ok(AlgoOutcome { algo, tune, tuned_summary, improvement, tuning_time_s })
}

/// The whole pipeline for one (benchmark, GC mode, metric): phases 1-3 with
/// every requested algorithm.
pub fn run_pipeline(
    bench: Benchmark,
    mode: GcMode,
    metric: Metric,
    algos: &[Algo],
    cfg: &PipelineConfig,
    backend: &Arc<dyn MlBackend>,
) -> Result<PipelineOutcome> {
    let runner = SparkRunner::paper_default(bench);

    let characterization = datagen::characterize(
        &runner,
        mode,
        metric,
        Strategy::Bemcm,
        &cfg.datagen,
        backend,
    )?;
    let selection = featsel::select_flags(&characterization.dataset, cfg.lambda, backend)?;
    let space = TuneSpace::from_selection(mode, &selection);

    let default_cfg = FlagConfig::default_for(mode);
    let default_summary = measure(&runner, &default_cfg, metric, cfg.repeats, cfg.seed);

    // Algorithms are independent (each owns its objective stream), so the
    // phase-3 sweep fans out on the global pool; outcomes keep `algos`
    // order and per-algo results are unaffected by the fan-out.  When the
    // sweep is actually parallel, each algorithm simulates its runs
    // serially (the sweep owns the cores); a single algorithm keeps the
    // per-run executor fan-out instead.
    let obj_pool = if algos.len() > 1 { ExecPool::serial() } else { *exec::global() };
    let algo_results = exec::global().par_map(algos, |_, &algo| {
        run_algo_on(
            &obj_pool,
            algo,
            &runner,
            &space,
            &characterization,
            metric,
            cfg,
            backend,
            default_summary.mean,
        )
    });
    let mut outcomes = Vec::with_capacity(algos.len());
    for r in algo_results {
        outcomes.push(r?);
    }

    Ok(PipelineOutcome {
        bench,
        mode,
        metric,
        characterization,
        selection,
        default_summary,
        outcomes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    pub fn quick_config() -> PipelineConfig {
        PipelineConfig {
            datagen: DataGenConfig {
                pool_size: 150,
                seed_runs: 16,
                test_runs: 8,
                batch_k: 12,
                max_rounds: 3,
                rmse_rel_tol: 0.0,
                ridge: 1e-3,
                seed: 5,
            },
            lambda: 0.01,
            bo: BoConfig { n_init: 5, n_candidates: 128, ..Default::default() },
            sa: SaConfig { n_init: 4, ..Default::default() },
            tune_iters: 6,
            repeats: 3,
            seed: 42,
        }
    }

    #[test]
    fn full_pipeline_smoke() {
        let backend: Arc<dyn MlBackend> = Arc::new(NativeBackend);
        let out = run_pipeline(
            Benchmark::Lda,
            GcMode::G1GC,
            Metric::ExecTime,
            &[Algo::Bo, Algo::Sa],
            &quick_config(),
            &backend,
        )
        .unwrap();
        assert_eq!(out.outcomes.len(), 2);
        assert!(out.selection.n_selected() > 0);
        assert!(out.default_summary.mean > 0.0);
        for o in &out.outcomes {
            assert!(o.tuned_summary.mean > 0.0);
            assert!(o.improvement > 0.5, "{:?} improvement {}", o.algo, o.improvement);
            assert!(o.tuning_time_s > 0.0);
        }
    }

    #[test]
    fn measure_summary_has_spread() {
        let runner = SparkRunner::paper_default(Benchmark::Lda);
        let s = measure(
            &runner,
            &FlagConfig::default_for(GcMode::G1GC),
            Metric::ExecTime,
            5,
            1,
        );
        assert_eq!(s.n, 5);
        assert!(s.std > 0.0);
        assert!(s.min <= s.mean && s.mean <= s.max);
    }
}
