//! Experiment drivers regenerating every table and figure of the paper's
//! evaluation (§V).  Each `run_*` returns rendered text and writes text +
//! CSV under `results/`.  DESIGN.md's per-experiment index maps paper
//! artifact -> driver here -> modules exercised.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use super::{Algo, PipelineConfig, PipelineOutcome};
use crate::datagen::{self, DataGenConfig, Strategy};
use crate::exec::ExecPool;
use crate::featsel;
use crate::flags::{FlagConfig, GcMode};
use crate::report::{bar_chart, line_plot, save_result, TextTable};
use crate::runtime::MlBackend;
use crate::sparksim::{ClusterSpec, ExecutorSpec, SparkRunner};
use crate::tuner::{BoTuner, ParallelSimObjective, TuneSpace, Tuner};
use crate::util::csv::Table;
use crate::{Benchmark, Metric};

/// Shared context for all experiment drivers.
pub struct ExperimentCtx {
    pub backend: Arc<dyn MlBackend>,
    pub cfg: PipelineConfig,
    pub out_dir: PathBuf,
    /// Fan-out pool for independent experiment cells (GRID cases, AL
    /// strategies, Fig 6 panels).  Every cell is seeded independently, so
    /// rendered artifacts are identical at every pool width.
    pub pool: ExecPool,
}

impl ExperimentCtx {
    pub fn new(backend: Arc<dyn MlBackend>, out_dir: impl Into<PathBuf>) -> Self {
        ExperimentCtx {
            backend,
            cfg: PipelineConfig::default(),
            out_dir: out_dir.into(),
            pool: ExecPool::from_env(),
        }
    }

    /// Override the cell fan-out pool (serial/parallel equivalence tests).
    pub fn with_pool(mut self, pool: ExecPool) -> Self {
        self.pool = pool;
        self
    }

    /// Reduced-budget settings for smoke runs (`repro --fast`).
    pub fn fast(mut self) -> Self {
        self.cfg.datagen = DataGenConfig {
            pool_size: 200,
            seed_runs: 20,
            test_runs: 12,
            batch_k: 16,
            max_rounds: 4,
            rmse_rel_tol: 0.0,
            ridge: 1e-3,
            seed: self.cfg.datagen.seed,
        };
        self.cfg.tune_iters = 8;
        self.cfg.repeats = 4;
        self.cfg.bo.n_candidates = 512;
        self
    }

    fn save(&self, name: &str, text: &str) -> Result<()> {
        save_result(&self.out_dir, name, text)?;
        Ok(())
    }
}

const GRID: [(Benchmark, GcMode); 4] = [
    (Benchmark::Lda, GcMode::ParallelGC),
    (Benchmark::Lda, GcMode::G1GC),
    (Benchmark::DenseKMeans, GcMode::ParallelGC),
    (Benchmark::DenseKMeans, GcMode::G1GC),
];

fn case_name(bench: Benchmark, mode: GcMode) -> String {
    let short = if bench == Benchmark::DenseKMeans { "DK" } else { "LDA" };
    format!("{short}, {}", mode.name())
}

// ---------------------------------------------------------------------------
// Table II — flags selected by lasso
// ---------------------------------------------------------------------------

/// Table II: lasso-selected flag counts per (benchmark, GC, metric).
pub fn run_table2(ctx: &ExperimentCtx) -> Result<String> {
    let mut table = TextTable::new(
        "Table II: Flags selected by lasso regression (of group size)",
        &["benchmark", "# flags exec. time", "# flags heap usage", "group"],
    );
    let mut csv = Table::new(vec![
        "bench".into(),
        "g1".into(),
        "exec_flags".into(),
        "heap_flags".into(),
        "group_size".into(),
    ]);
    // One GRID cell = two characterizations + selections; cells are
    // independent, so they fan out on the ctx pool and are rendered in
    // GRID order below.
    let cell_counts = ctx.pool.par_map(&GRID, |_, &(bench, mode)| -> Result<Vec<(usize, usize)>> {
        let runner = SparkRunner::paper_default(bench);
        let mut counts = Vec::new();
        for metric in [Metric::ExecTime, Metric::HeapUsage] {
            let ch = datagen::characterize(
                &runner,
                mode,
                metric,
                Strategy::Bemcm,
                &ctx.cfg.datagen,
                &ctx.backend,
            )?;
            let sel = featsel::select_flags(&ch.dataset, ctx.cfg.lambda, &ctx.backend)?;
            counts.push((sel.n_selected(), sel.group_size));
        }
        Ok(counts)
    });
    for ((bench, mode), counts) in GRID.into_iter().zip(cell_counts) {
        let counts = counts?;
        table.row(vec![
            case_name(bench, mode),
            counts[0].0.to_string(),
            counts[1].0.to_string(),
            counts[0].1.to_string(),
        ]);
        csv.push(vec![
            if bench == Benchmark::Lda { 0.0 } else { 1.0 },
            if mode == GcMode::G1GC { 1.0 } else { 0.0 },
            counts[0].0 as f64,
            counts[1].0 as f64,
            counts[0].1 as f64,
        ]);
    }
    let text = table.render();
    ctx.save("table2.txt", &text)?;
    csv.save(ctx.out_dir.join("table2.csv")).map_err(anyhow::Error::from)?;
    Ok(text)
}

// ---------------------------------------------------------------------------
// Table III + Fig 3 — execution-time tuning
// ---------------------------------------------------------------------------

/// Table III (speedups) + Fig 3 (default-vs-tuned bars), one pipeline run
/// per (benchmark, GC) with all four algorithms.
pub fn run_exec_time(ctx: &ExperimentCtx) -> Result<String> {
    let algos = [Algo::Bo, Algo::Rbo, Algo::BoWarm, Algo::Sa];
    let mut table = TextTable::new(
        "Table III: Execution-time speedups over default",
        &["Benchmark, GC", "BO", "RBO", "BO, warm start", "SA"],
    );
    let mut csv = Table::new(vec![
        "case".into(),
        "default_mean".into(),
        "bo".into(),
        "rbo".into(),
        "bo_warm".into(),
        "sa".into(),
    ]);
    let mut figs = String::new();
    let mut timing_rows: Vec<(String, f64, f64)> = Vec::new();

    // The 4 GRID pipelines are independent end-to-end runs: fan them out
    // on the ctx pool, then render rows/figures in GRID order.
    let outs = ctx.pool.par_map(&GRID, |_, &(bench, mode)| {
        super::run_pipeline(bench, mode, Metric::ExecTime, &algos, &ctx.cfg, &ctx.backend)
    });
    let outs: Vec<PipelineOutcome> = outs.into_iter().collect::<Result<_>>()?;

    for (i, ((bench, mode), out)) in GRID.iter().zip(&outs).enumerate() {
        let sp: Vec<f64> = out.outcomes.iter().map(|o| o.improvement).collect();
        table.row(vec![
            case_name(*bench, *mode),
            format!("{:.2}x", sp[0]),
            format!("{:.2}x", sp[1]),
            format!("{:.2}x", sp[2]),
            format!("{:.2}x", sp[3]),
        ]);
        csv.push(vec![i as f64, out.default_summary.mean, sp[0], sp[1], sp[2], sp[3]]);

        // Fig 3 panel: mean +- std execution times.
        let mut labels = vec!["default".to_string()];
        let mut values = vec![out.default_summary.mean];
        for o in &out.outcomes {
            labels.push(o.algo.name().to_string());
            values.push(o.tuned_summary.mean);
        }
        figs.push_str(&bar_chart(
            &format!(
                "Fig 3({}): execution time, {} (mean of {} runs, default std {:.1})",
                char::from(b'a' + i as u8),
                case_name(*bench, *mode),
                out.default_summary.n,
                out.default_summary.std
            ),
            &labels,
            &values,
            "s",
        ));
        figs.push('\n');

        // §V-C timing inputs: OneStopTuner (BO warm) vs SA tuning time.
        let warm_t = out.outcomes[2].tuning_time_s + out.characterization.sim_time_s * 0.0;
        let sa_t = out.outcomes[3].tuning_time_s;
        timing_rows.push((case_name(*bench, *mode), warm_t, sa_t));
    }

    let table_text = table.render();
    ctx.save("table3.txt", &table_text)?;
    csv.save(ctx.out_dir.join("table3.csv")).map_err(anyhow::Error::from)?;
    ctx.save("fig3.txt", &figs)?;

    let mut timing = TextTable::new(
        "SectionV-C: time to tune (20 iterations, excluding data generation)",
        &["case", "OneStopTuner (BO warm) [s]", "SA [s]", "speedup"],
    );
    for (case, a, b) in &timing_rows {
        timing.row(vec![
            case.clone(),
            format!("{a:.0}"),
            format!("{b:.0}"),
            format!("{:.2}x", b / a.max(1e-9)),
        ]);
    }
    let timing_text = timing.render();
    ctx.save("timing.txt", &timing_text)?;

    Ok(format!("{table_text}\n{figs}\n{timing_text}"))
}

// ---------------------------------------------------------------------------
// Table IV + Fig 7 — heap-usage tuning
// ---------------------------------------------------------------------------

/// Table IV (heap-usage improvement %) + Fig 7 (default-vs-tuned HU bars).
pub fn run_heap_usage(ctx: &ExperimentCtx) -> Result<String> {
    let algos = [Algo::Bo, Algo::Rbo, Algo::BoWarm, Algo::Sa];
    let mut table = TextTable::new(
        "Table IV: Heap-usage improvements over default usage",
        &["benchmark, GC", "BO", "RBO", "BO, warm start", "SA"],
    );
    let mut csv = Table::new(vec![
        "case".into(),
        "default_hu".into(),
        "bo".into(),
        "rbo".into(),
        "bo_warm".into(),
        "sa".into(),
    ]);
    let mut figs = String::new();
    let outs = ctx.pool.par_map(&GRID, |_, &(bench, mode)| {
        super::run_pipeline(bench, mode, Metric::HeapUsage, &algos, &ctx.cfg, &ctx.backend)
    });
    let outs: Vec<PipelineOutcome> = outs.into_iter().collect::<Result<_>>()?;
    for (i, ((bench, mode), out)) in GRID.iter().zip(&outs).enumerate() {
        // Improvement = % reduction of average HU.
        let impr: Vec<f64> = out
            .outcomes
            .iter()
            .map(|o| {
                100.0 * (out.default_summary.mean - o.tuned_summary.mean)
                    / out.default_summary.mean.max(1e-9)
            })
            .collect();
        table.row(vec![
            case_name(*bench, *mode),
            format!("{:.2}%", impr[0]),
            format!("{:.2}%", impr[1]),
            format!("{:.2}%", impr[2]),
            format!("{:.2}%", impr[3]),
        ]);
        csv.push(vec![i as f64, out.default_summary.mean, impr[0], impr[1], impr[2], impr[3]]);

        let mut labels = vec!["default".to_string()];
        let mut values = vec![out.default_summary.mean];
        for o in &out.outcomes {
            labels.push(o.algo.name().to_string());
            values.push(o.tuned_summary.mean);
        }
        figs.push_str(&bar_chart(
            &format!(
                "Fig 7({}): heap usage %, {}",
                char::from(b'a' + i as u8),
                case_name(*bench, *mode)
            ),
            &labels,
            &values,
            "%",
        ));
        figs.push('\n');
    }
    let text = table.render();
    ctx.save("table4.txt", &text)?;
    csv.save(ctx.out_dir.join("table4.csv")).map_err(anyhow::Error::from)?;
    ctx.save("fig7.txt", &figs)?;
    Ok(format!("{text}\n{figs}"))
}

// ---------------------------------------------------------------------------
// Fig 4 — RBO with AL-trained LR vs LR on more random data
// ---------------------------------------------------------------------------

/// Fig 4: predicted-vs-actual execution time for the AL-trained LR (fewer
/// samples) against an LR trained on ~3x more randomly-selected samples.
pub fn run_fig4(ctx: &ExperimentCtx) -> Result<String> {
    let bench = Benchmark::Lda;
    let mode = GcMode::G1GC;
    let metric = Metric::ExecTime;
    let runner = SparkRunner::paper_default(bench);

    // AL dataset (scaled mirror of the paper's 600-sample AL model).
    let ch = datagen::characterize(
        &runner,
        mode,
        metric,
        Strategy::Bemcm,
        &ctx.cfg.datagen,
        &ctx.backend,
    )?;
    let al_pred =
        crate::tuner::objective::PredictorObjective::fit(&ch.dataset, 1e-3, &ctx.backend)?;

    // Random dataset ~3x larger (the paper's 2000-sample non-AL model).
    // It exceeds the 256-row XLA artifact budget, so this *baseline* model
    // is fit with the native mirror (the AL model above went through the
    // artifact path).
    let enc = crate::flags::FeatureEncoder::new(mode);
    let mut rng = crate::util::rng::Pcg::new(0xf1644);
    let default_run = runner.run(&FlagConfig::default_for(mode), 0xf00);
    let cap = 5.0 * default_run.exec_time_s;
    let n_big = 3 * ch.dataset.len();
    let mut big_x = Vec::with_capacity(n_big);
    let mut big_y = Vec::with_capacity(n_big);
    for i in 0..n_big {
        let cfg = FlagConfig::random(mode, &mut rng);
        big_x.push(enc.encode(&cfg));
        big_y.push(runner.run(&cfg, 0xb16 + i as u64).exec_time_s.min(cap));
    }
    let xsc = crate::util::stats::Standardizer::fit(&big_x);
    let ysc = crate::util::stats::TargetScaler::fit(&big_y);
    let ystd: Vec<f64> = big_y.iter().map(|&v| ysc.transform(v)).collect();
    let w_rnd = crate::native::ops::lr_fit(&xsc.transform(&big_x), &ystd, 1e-3);
    let rnd_predict = |cfg: &FlagConfig| -> f64 {
        let f = xsc.transform_row(&enc.encode(cfg));
        ysc.inverse(crate::native::ops::lr_predict(&w_rnd, &f))
    };
    // ... and a random model at the *same* budget as the AL model (the
    // like-for-like comparison of sample efficiency).
    let n_match = ch.dataset.len().min(big_x.len());
    let w_match = crate::native::ops::lr_fit(
        &xsc.transform(&big_x[..n_match]),
        &ystd[..n_match],
        1e-3,
    );
    let match_predict = |cfg: &FlagConfig| -> f64 {
        let f = xsc.transform_row(&enc.encode(cfg));
        ysc.inverse(crate::native::ops::lr_predict(&w_match, &f))
    };

    // Evaluate both predictors on fresh configs that actually complete
    // (failed runs are what the adaptive cap screens out during data
    // generation; the paper's Fig 4 plots completing runs).
    let n_eval = 24u64;
    let mut actual = Vec::new();
    let mut pred_al = Vec::new();
    let mut pred_rnd = Vec::new();
    let mut pred_match = Vec::new();
    let mut tries = 0u64;
    while actual.len() < n_eval as usize && tries < 400 {
        tries += 1;
        let cfg = FlagConfig::random(mode, &mut rng);
        let m = runner.run(&cfg, 0xeef + tries);
        if m.failed() {
            continue;
        }
        actual.push(m.exec_time_s);
        pred_al.push(al_pred.predict(&cfg));
        pred_rnd.push(rnd_predict(&cfg));
        pred_match.push(match_predict(&cfg));
    }
    let rmse_al = crate::util::stats::rmse(&pred_al, &actual);
    let rmse_rnd = crate::util::stats::rmse(&pred_rnd, &actual);
    let rmse_match = crate::util::stats::rmse(&pred_match, &actual);
    let corr_al = crate::util::stats::pearson(&pred_al, &actual);
    let corr_rnd = crate::util::stats::pearson(&pred_rnd, &actual);
    let corr_match = crate::util::stats::pearson(&pred_match, &actual);

    let mut text = format!(
        "Fig 4: RBO predictor quality, LDA (target: execution time)\n\
         AL-trained LR:        {} samples, RMSE {:.1} s, corr {:.3}\n\
         random LR (matched):  {} samples, RMSE {:.1} s, corr {:.3}\n\
         random LR (3x data):  {} samples, RMSE {:.1} s, corr {:.3}\n\n",
        ch.dataset.len(),
        rmse_al,
        corr_al,
        n_match,
        rmse_match,
        corr_match,
        n_big,
        rmse_rnd,
        corr_rnd
    );
    text.push_str(&line_plot(
        "predicted vs actual (sorted by actual)",
        &{
            let mut idx: Vec<usize> = (0..actual.len()).collect();
            idx.sort_by(|&a, &b| actual[a].partial_cmp(&actual[b]).unwrap());
            vec![
                ("actual".to_string(), idx.iter().map(|&i| actual[i]).collect()),
                ("AL LR".to_string(), idx.iter().map(|&i| pred_al[i]).collect()),
                ("random LR".to_string(), idx.iter().map(|&i| pred_rnd[i]).collect()),
            ]
        },
        14,
    ));

    let mut csv = Table::new(vec!["actual".into(), "pred_al".into(), "pred_random".into()]);
    for i in 0..actual.len() {
        csv.push(vec![actual[i], pred_al[i], pred_rnd[i]]);
    }
    csv.save(ctx.out_dir.join("fig4.csv")).map_err(anyhow::Error::from)?;
    ctx.save("fig4.txt", &text)?;
    Ok(text)
}

// ---------------------------------------------------------------------------
// Fig 5 — AL convergence: BEMCM vs QBC vs random
// ---------------------------------------------------------------------------

/// Fig 5: validation RMSE vs AL round for BEMCM / QBC / random, plus the
/// §V-B claim (data-generation run reduction at matched RMSE).
pub fn run_fig5(ctx: &ExperimentCtx) -> Result<String> {
    let bench = Benchmark::Lda;
    let mode = GcMode::G1GC;
    let runner = SparkRunner::paper_default(bench);
    let mut dg = ctx.cfg.datagen.clone();
    dg.rmse_rel_tol = 0.0; // run all rounds so the curves are comparable

    // The three selection strategies are independent characterizations of
    // the same problem; fan them out and keep strategy order.
    let strategies = [Strategy::Bemcm, Strategy::Qbc, Strategy::Random];
    let runs = ctx.pool.par_map(&strategies, |_, &strategy| {
        datagen::characterize(&runner, mode, Metric::ExecTime, strategy, &dg, &ctx.backend)
    });
    let mut series = Vec::new();
    for (strategy, r) in strategies.iter().zip(runs) {
        let r = r?;
        series.push((strategy.name().to_string(), r.rmse_history.clone()));
    }

    let mut text = line_plot(
        "Fig 5: validation RMSE vs AL round (LDA, target: execution time)",
        &series,
        14,
    );

    // Runs-reduction claim: rounds BEMCM needs to reach random's final RMSE.
    let random_final = *series[2].1.last().unwrap();
    let bemcm = &series[0].1;
    let batch = dg.batch_k as f64;
    let seed = dg.seed_runs as f64;
    let rounds_needed = bemcm.iter().position(|&r| r <= random_final).unwrap_or(bemcm.len() - 1);
    let bemcm_runs = seed + rounds_needed as f64 * batch;
    let random_runs = seed + (series[2].1.len() - 1) as f64 * batch;
    let reduction = 100.0 * (1.0 - bemcm_runs / random_runs.max(1.0));
    text.push_str(&format!(
        "\nBEMCM reaches random-selection final RMSE ({random_final:.2} s) after \
         {bemcm_runs:.0} labelled runs vs {random_runs:.0} for random: \
         {reduction:.0}% fewer data-generation runs\n",
    ));

    let mut csv = Table::new(vec!["round".into(), "bemcm".into(), "qbc".into(), "random".into()]);
    let len = series.iter().map(|(_, v)| v.len()).min().unwrap();
    for i in 0..len {
        csv.push(vec![i as f64, series[0].1[i], series[1].1[i], series[2].1[i]]);
    }
    csv.save(ctx.out_dir.join("fig5.csv")).map_err(anyhow::Error::from)?;
    ctx.save("fig5.txt", &text)?;
    Ok(text)
}

// ---------------------------------------------------------------------------
// Fig 6 — tuning with benchmarks running in parallel
// ---------------------------------------------------------------------------

/// Fig 6: tuning results with LDA and DenseKMeans running concurrently, in
/// the two executor topologies of the paper (2x15c/60GB and 3x10c/44-50GB).
pub fn run_fig6(ctx: &ExperimentCtx) -> Result<String> {
    let cluster = ClusterSpec::paper();
    let metric = Metric::ExecTime;
    let mut text = String::new();
    let mut csv = Table::new(vec![
        "panel".into(),
        "default_mean".into(),
        "bo".into(),
        "bo_warm".into(),
    ]);

    let setups: [(&str, Benchmark, GcMode, ExecutorSpec, Benchmark, ExecutorSpec); 4] = [
        (
            "a: LDA G1GC, 2 exec x 15 cores x 60GB",
            Benchmark::Lda,
            GcMode::G1GC,
            ExecutorSpec::parallel_2x15(),
            Benchmark::DenseKMeans,
            ExecutorSpec::parallel_2x15(),
        ),
        (
            "b: DK G1GC, 2 exec x 15 cores x 60GB",
            Benchmark::DenseKMeans,
            GcMode::G1GC,
            ExecutorSpec::parallel_2x15(),
            Benchmark::Lda,
            ExecutorSpec::parallel_2x15(),
        ),
        (
            "c: LDA G1GC, 3 exec x 10 cores, 44GB",
            Benchmark::Lda,
            GcMode::G1GC,
            ExecutorSpec::parallel_3x10(44.0),
            Benchmark::DenseKMeans,
            ExecutorSpec::parallel_3x10(50.0),
        ),
        (
            "d: DK G1GC, 3 exec x 10 cores, 50GB",
            Benchmark::DenseKMeans,
            GcMode::G1GC,
            ExecutorSpec::parallel_3x10(50.0),
            Benchmark::Lda,
            ExecutorSpec::parallel_3x10(44.0),
        ),
    ];

    // Each Fig 6 panel is an independent characterize-then-tune run under
    // contention; panels fan out on the ctx pool and render in order.
    struct PanelOut {
        labels: Vec<String>,
        vals: Vec<f64>,
        base_mean: f64,
    }
    let panel_results = ctx.pool.par_map(&setups, |pi, setup| -> Result<PanelOut> {
        let (_, bench, mode, exec, other_bench, other_exec) = setup;
        // Characterize on the exclusive cluster (phase 1 is per-benchmark),
        // then tune under the parallel-run objective.
        let runner = SparkRunner::paper_default(*bench);
        let ch = datagen::characterize(
            &runner,
            *mode,
            metric,
            Strategy::Bemcm,
            &ctx.cfg.datagen,
            &ctx.backend,
        )?;
        let sel = featsel::select_flags(&ch.dataset, ctx.cfg.lambda, &ctx.backend)?;
        let space = TuneSpace::from_selection(*mode, &sel);

        let default_cfg = FlagConfig::default_for(*mode);
        let mk_obj = |seed: u64| {
            ParallelSimObjective::new(
                cluster,
                (*bench, *exec),
                (*other_bench, default_cfg.clone(), *other_exec),
                metric,
                seed,
            )
        };

        // Default baseline in the parallel setting.
        let mut base_obj = mk_obj(0xba5e ^ pi as u64);
        let base: Vec<f64> = (0..ctx.cfg.repeats)
            .map(|_| metric.of(&base_obj.run_once(&default_cfg)))
            .collect();
        let base_mean = crate::util::stats::mean(&base);

        // The characterization ran on the exclusive cluster, where
        // execution times sit on a different scale than under contention;
        // rescale its labels by the default-config ratio so the
        // warm-started GP sees consistent targets.
        let exclusive_default = metric.of(&runner.run(&default_cfg, 0xdef));
        let scale = base_mean / exclusive_default.max(1e-9);
        let mut warm_ds = ch.dataset.clone();
        for y in warm_ds.y.iter_mut() {
            *y *= scale;
        }

        let mut vals = vec![base_mean];
        let mut labels = vec!["default".to_string()];
        for (ai, algo) in [Algo::Bo, Algo::BoWarm].into_iter().enumerate() {
            let mut tuner: Box<dyn Tuner> = match algo {
                Algo::Bo => Box::new(BoTuner::new(ctx.backend.clone(), ctx.cfg.bo.clone())),
                Algo::BoWarm => Box::new(BoTuner::warm_start(
                    ctx.backend.clone(),
                    ctx.cfg.bo.clone(),
                    &space,
                    &warm_ds,
                )),
                _ => unreachable!(),
            };
            let mut obj = mk_obj(0x7e5 + (pi * 2 + ai) as u64);
            let r = tuner.tune(&space, &mut obj, ctx.cfg.tune_iters)?;
            // Final measurement in the parallel setting.
            let mut meas_obj = mk_obj(0x3a5);
            let vs: Vec<f64> = (0..ctx.cfg.repeats)
                .map(|_| metric.of(&meas_obj.run_once(&r.best_config)))
                .collect();
            vals.push(crate::util::stats::mean(&vs));
            labels.push(algo.name().to_string());
        }

        Ok(PanelOut { labels, vals, base_mean })
    });

    for (pi, (setup, panel_out)) in setups.iter().zip(panel_results).enumerate() {
        let PanelOut { labels, vals, base_mean } = panel_out?;
        let panel = setup.0;
        text.push_str(&bar_chart(
            &format!(
                "Fig 6({panel}) — exec time, speedups: BO {:.2}x, warm {:.2}x",
                base_mean / vals[1],
                base_mean / vals[2]
            ),
            &labels,
            &vals,
            "s",
        ));
        text.push('\n');
        csv.push(vec![pi as f64, base_mean, base_mean / vals[1], base_mean / vals[2]]);
    }

    csv.save(ctx.out_dir.join("fig6.csv")).map_err(anyhow::Error::from)?;
    ctx.save("fig6.txt", &text)?;
    Ok(text)
}

// ---------------------------------------------------------------------------
// Table I — benchmark descriptions (trivial, but part of the index)
// ---------------------------------------------------------------------------

pub fn run_table1(ctx: &ExperimentCtx) -> Result<String> {
    let mut t = TextTable::new(
        "Table I: Benchmark applications used in evaluation",
        &["Application", "Dataset"],
    );
    for b in Benchmark::all() {
        let s = b.spec();
        t.row(vec![
            if b == Benchmark::Lda {
                "Latent Dirichlet Allocation".into()
            } else {
                "Dense K-Means".into()
            },
            s.dataset.to_string(),
        ]);
    }
    let text = t.render();
    ctx.save("table1.txt", &text)?;
    Ok(text)
}

/// Everything, in paper order.
pub fn run_all(ctx: &ExperimentCtx) -> Result<String> {
    let mut out = String::new();
    for (name, f) in [
        ("table1", run_table1 as fn(&ExperimentCtx) -> Result<String>),
        ("table2", run_table2),
        ("exec (table3+fig3+timing)", run_exec_time),
        ("heap (table4+fig7)", run_heap_usage),
        ("fig4", run_fig4),
        ("fig5", run_fig5),
        ("fig6", run_fig6),
    ] {
        eprintln!("[repro] running {name} ...");
        out.push_str(&f(ctx)?);
        out.push('\n');
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn tiny_ctx() -> ExperimentCtx {
        let dir = std::env::temp_dir().join("ost_experiments_test");
        let mut ctx =
            ExperimentCtx::new(Arc::new(NativeBackend), dir).fast();
        // even faster for unit tests
        ctx.cfg.datagen.pool_size = 80;
        ctx.cfg.datagen.seed_runs = 14;
        ctx.cfg.datagen.test_runs = 6;
        ctx.cfg.datagen.batch_k = 8;
        ctx.cfg.datagen.max_rounds = 2;
        ctx.cfg.tune_iters = 3;
        ctx.cfg.repeats = 2;
        ctx
    }

    #[test]
    fn table1_renders() {
        let ctx = tiny_ctx();
        let t = run_table1(&ctx).unwrap();
        assert!(t.contains("Dense K-Means"));
        assert!(t.contains("20M samples"));
    }

    #[test]
    fn table2_counts_within_group_bounds() {
        let ctx = tiny_ctx();
        let t = run_table2(&ctx).unwrap();
        assert!(t.contains("126") || t.contains("141"));
        let csv = Table::load(ctx.out_dir.join("table2.csv")).unwrap();
        for row in &csv.rows {
            let (exec_flags, heap_flags, group) = (row[2], row[3], row[4]);
            assert!(exec_flags > 0.0 && exec_flags <= group);
            assert!(heap_flags > 0.0 && heap_flags <= group);
        }
    }

    #[test]
    fn fig5_produces_three_series() {
        let ctx = tiny_ctx();
        let t = run_fig5(&ctx).unwrap();
        assert!(t.contains("bemcm"));
        assert!(t.contains("qbc"));
        assert!(t.contains("random"));
        assert!(t.contains("fewer data-generation runs"));
    }
}
