//! Reporters: machine-readable JSON (`mutants.json` / `mutants_smoke.json`)
//! and the CLI/markdown summary with kill rate per file and per operator.
//!
//! Scoring convention (mirrors mutation-testing practice): build-failed
//! mutants are excluded from the denominator — a mutant the compiler
//! rejects says nothing about the test suites.  Timed-out mutants count
//! as killed (a hung loop is a detected fault) but stay visible as their
//! own column so a timeout regression cannot hide inside the kill rate.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::runner::{MutantResult, Verdict};
use super::scanner::Op;
use crate::util::json::Json;

/// An explicit disposition for a surviving mutant, loaded from
/// `rust/mutants.dispositions.json`.  Addressed structurally like a smoke
/// pin, so dispositions survive unrelated edits.
#[derive(Clone, Debug)]
pub struct Disposition {
    pub file: String,
    pub op: Op,
    pub original: String,
    pub contains: String,
    pub occurrence: usize,
    /// `equivalent` is the only status that excuses a survivor.
    pub status: String,
    pub reason: String,
}

/// Load dispositions; a missing file means "no dispositions yet".
pub fn load_dispositions(path: &Path) -> Result<Vec<Disposition>> {
    if !path.exists() {
        return Ok(Vec::new());
    }
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let json = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    let mut out = Vec::new();
    for (i, d) in json
        .get("dispositions")
        .and_then(Json::as_arr)
        .context("dispositions file needs a `dispositions` array")?
        .iter()
        .enumerate()
    {
        let field = |k: &str| -> Result<String> {
            Ok(d.get(k)
                .and_then(Json::as_str)
                .with_context(|| format!("dispositions[{i}] missing `{k}`"))?
                .to_string())
        };
        let op_label = field("operator")?;
        out.push(Disposition {
            file: field("file")?,
            op: Op::parse(&op_label)
                .with_context(|| format!("dispositions[{i}]: unknown operator `{op_label}`"))?,
            original: field("original")?,
            contains: field("contains")?,
            occurrence: d.get("occurrence").and_then(Json::as_f64).unwrap_or(0.0) as usize,
            status: field("status")?,
            reason: field("reason")?,
        });
    }
    Ok(out)
}

/// Find the disposition covering result `r`, honoring occurrence order
/// within the (file, op, original, contains) bucket across `all` results.
pub fn disposition_for<'a>(
    r: &MutantResult,
    all: &[MutantResult],
    dispositions: &'a [Disposition],
) -> Option<&'a Disposition> {
    dispositions.iter().find(|d| {
        if !(r.site.file == d.file
            && r.site.op == d.op
            && r.site.original == d.original
            && r.site.line_text.contains(&d.contains))
        {
            return false;
        }
        let index_in_bucket = all
            .iter()
            .filter(|o| {
                o.site.file == d.file
                    && o.site.op == d.op
                    && o.site.original == d.original
                    && o.site.line_text.contains(&d.contains)
            })
            .position(|o| std::ptr::eq(o, r));
        index_in_bucket == Some(d.occurrence)
    })
}

#[derive(Clone, Copy, Debug, Default)]
pub struct Tally {
    pub total: usize,
    pub killed: usize,
    pub survived: usize,
    pub build_failed: usize,
    pub timed_out: usize,
}

impl Tally {
    pub fn add(&mut self, v: Verdict) {
        self.total += 1;
        match v {
            Verdict::Killed => self.killed += 1,
            Verdict::Survived => self.survived += 1,
            Verdict::BuildFailed => self.build_failed += 1,
            Verdict::TimedOut => self.timed_out += 1,
        }
    }

    /// `(killed + timed_out) / (killed + timed_out + survived)`; 1.0 when
    /// the denominator is empty (nothing scoreable means nothing missed).
    pub fn score(&self) -> f64 {
        let hits = self.killed + self.timed_out;
        let denom = hits + self.survived;
        if denom == 0 {
            1.0
        } else {
            hits as f64 / denom as f64
        }
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("total", Json::num(self.total as f64)),
            ("killed", Json::num(self.killed as f64)),
            ("survived", Json::num(self.survived as f64)),
            ("build_failed", Json::num(self.build_failed as f64)),
            ("timed_out", Json::num(self.timed_out as f64)),
            ("score", Json::num(self.score())),
        ])
    }
}

pub fn tally(results: &[MutantResult]) -> Tally {
    let mut t = Tally::default();
    for r in results {
        t.add(r.verdict);
    }
    t
}

fn group_tallies<K: Ord, F: Fn(&MutantResult) -> K>(
    results: &[MutantResult],
    key: F,
) -> BTreeMap<K, Tally> {
    let mut map: BTreeMap<K, Tally> = BTreeMap::new();
    for r in results {
        map.entry(key(r)).or_default().add(r.verdict);
    }
    map
}

/// The full machine-readable report.
pub fn to_json(
    mode: &str,
    shard: Option<(usize, usize)>,
    results: &[MutantResult],
    dispositions: &[Disposition],
) -> Json {
    let per_file = group_tallies(results, |r| r.site.file.clone());
    let per_op = group_tallies(results, |r| r.site.op.label().to_string());
    let mutants: Vec<Json> = results
        .iter()
        .map(|r| {
            let disp = disposition_for(r, results, dispositions);
            Json::obj(vec![
                ("id", Json::str(r.site.id())),
                ("file", Json::str(r.site.file.clone())),
                ("line", Json::num(r.site.line as f64)),
                ("col", Json::num(r.site.col as f64)),
                ("operator", Json::str(r.site.op.label())),
                ("original", Json::str(r.site.original.clone())),
                ("replacement", Json::str(r.site.replacement.clone())),
                ("diff", Json::str(r.site.diff())),
                ("verdict", Json::str(r.verdict.label())),
                (
                    "killing_suite",
                    r.killing_suite.clone().map(Json::str).unwrap_or(Json::Null),
                ),
                (
                    "killing_test",
                    r.killing_test.clone().map(Json::str).unwrap_or(Json::Null),
                ),
                ("secs", Json::num((r.secs * 10.0).round() / 10.0)),
                (
                    "disposition",
                    disp.map(|d| Json::str(d.status.clone())).unwrap_or(Json::Null),
                ),
                (
                    "disposition_reason",
                    disp.map(|d| Json::str(d.reason.clone())).unwrap_or(Json::Null),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("mode", Json::str(mode)),
        (
            "shard",
            match shard {
                Some((i, n)) => Json::obj(vec![
                    ("index", Json::num(i as f64)),
                    ("total", Json::num(n as f64)),
                ]),
                None => Json::Null,
            },
        ),
        ("summary", tally(results).to_json()),
        (
            "per_file",
            Json::Obj(per_file.into_iter().map(|(k, v)| (k, v.to_json())).collect()),
        ),
        (
            "per_operator",
            Json::Obj(per_op.into_iter().map(|(k, v)| (k, v.to_json())).collect()),
        ),
        ("mutants", Json::Arr(mutants)),
    ])
}

/// Human summary: headline score, per-file and per-operator tables, and
/// the survivor list with dispositions.  Valid markdown, readable as CLI
/// output.
pub fn summary_markdown(
    mode: &str,
    results: &[MutantResult],
    dispositions: &[Disposition],
) -> String {
    use std::fmt::Write as _;
    let t = tally(results);
    let mut out = String::new();
    let _ = writeln!(out, "# Mutation report ({mode})\n");
    let _ = writeln!(
        out,
        "**score {:.1}%** — {} mutants: {} killed, {} timed out, {} survived, {} build-failed (excluded)\n",
        t.score() * 100.0,
        t.total,
        t.killed,
        t.timed_out,
        t.survived,
        t.build_failed,
    );
    for (title, groups) in [
        ("Per file", group_tallies(results, |r| r.site.file.clone())),
        ("Per operator", group_tallies(results, |r| r.site.op.label().to_string())),
    ] {
        let _ = writeln!(out, "## {title}\n");
        let _ = writeln!(out, "| {} | total | killed | timed out | survived | build-failed | score |", title.to_lowercase());
        let _ = writeln!(out, "|---|---|---|---|---|---|---|");
        for (k, g) in groups {
            let _ = writeln!(
                out,
                "| {k} | {} | {} | {} | {} | {} | {:.1}% |",
                g.total,
                g.killed,
                g.timed_out,
                g.survived,
                g.build_failed,
                g.score() * 100.0,
            );
        }
        let _ = writeln!(out);
    }
    let survivors: Vec<&MutantResult> =
        results.iter().filter(|r| r.verdict == Verdict::Survived).collect();
    if survivors.is_empty() {
        let _ = writeln!(out, "No survivors.");
    } else {
        let _ = writeln!(out, "## Survivors\n");
        for r in survivors {
            match disposition_for(r, results, dispositions) {
                Some(d) => {
                    let _ = writeln!(
                        out,
                        "- `{}` {} — **dispositioned {}**: {}",
                        r.site.id(),
                        r.site.diff(),
                        d.status,
                        d.reason
                    );
                }
                None => {
                    let _ = writeln!(
                        out,
                        "- `{}` {} — **UNDISPOSITIONED**: add a killing test or an \
                         `equivalent` entry in rust/mutants.dispositions.json",
                        r.site.id(),
                        r.site.diff()
                    );
                }
            }
        }
    }
    out
}

/// Survivors with no `equivalent` disposition — the full sweep's failure
/// condition.
pub fn undispositioned<'a>(
    results: &'a [MutantResult],
    dispositions: &[Disposition],
) -> Vec<&'a MutantResult> {
    results
        .iter()
        .filter(|r| r.verdict == Verdict::Survived)
        .filter(|r| {
            disposition_for(r, results, dispositions)
                .map(|d| d.status != "equivalent")
                .unwrap_or(true)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mutate::scanner::Site;

    fn mk(file: &str, op: Op, verdict: Verdict) -> MutantResult {
        MutantResult {
            site: Site {
                file: file.to_string(),
                line: 1,
                col: 1,
                byte_start: 0,
                byte_end: 3,
                op,
                original: " + ".into(),
                replacement: " - ".into(),
                line_text: "let a = b + c;".into(),
            },
            verdict,
            killing_suite: None,
            killing_test: None,
            secs: 1.0,
        }
    }

    #[test]
    fn score_excludes_build_failures_counts_timeouts() {
        let results = vec![
            mk("a.rs", Op::ArithSwap, Verdict::Killed),
            mk("a.rs", Op::ArithSwap, Verdict::TimedOut),
            mk("a.rs", Op::CmpSwap, Verdict::Survived),
            mk("b.rs", Op::CmpSwap, Verdict::BuildFailed),
        ];
        let t = tally(&results);
        assert_eq!(t.total, 4);
        assert!((t.score() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn json_report_has_schema_fields() {
        let results = vec![mk("a.rs", Op::ArithSwap, Verdict::Killed)];
        let j = to_json("smoke", None, &results, &[]);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("mode").unwrap().as_str(), Some("smoke"));
        let summary = back.get("summary").unwrap();
        for k in ["total", "killed", "survived", "build_failed", "timed_out", "score"] {
            assert!(summary.get(k).is_some(), "missing summary.{k}");
        }
        let m = &back.get("mutants").unwrap().as_arr().unwrap()[0];
        for k in ["id", "file", "line", "operator", "original", "replacement", "verdict"] {
            assert!(m.get(k).is_some(), "missing mutants[0].{k}");
        }
        assert!(back.get("per_file").unwrap().get("a.rs").is_some());
        assert!(back.get("per_operator").unwrap().get("arith-swap").is_some());
    }

    #[test]
    fn undispositioned_survivors_flagged() {
        let results = vec![
            mk("a.rs", Op::ArithSwap, Verdict::Survived),
            mk("a.rs", Op::ArithSwap, Verdict::Survived),
        ];
        let disp = vec![Disposition {
            file: "a.rs".into(),
            op: Op::ArithSwap,
            original: " + ".into(),
            contains: "b + c".into(),
            occurrence: 0,
            status: "equivalent".into(),
            reason: "test".into(),
        }];
        let open = undispositioned(&results, &disp);
        assert_eq!(open.len(), 1, "occurrence 0 excused, occurrence 1 not");
        let md = summary_markdown("full", &results, &disp);
        assert!(md.contains("UNDISPOSITIONED"));
        assert!(md.contains("dispositioned equivalent"));
    }
}
