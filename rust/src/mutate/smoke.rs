//! The pinned smoke mutant set: a curated list of faults the differential
//! suites MUST kill, small enough to run on every CI push.
//!
//! Each pin is addressed structurally — (file, operator, original text,
//! line substring, occurrence index) — not by line number, so the set
//! survives unrelated edits.  If the pinned line itself is edited or
//! removed, [`resolve_pin`] fails loudly ("pin rot") and the smoke run
//! exits non-zero: whoever changes a kernel line that carries a pin must
//! re-point the pin, which is exactly the review moment the pin exists
//! to create.
//!
//! Every pin carries a `kill_argument`: the reason the fast differential
//! tier cannot miss it.  A pin whose argument goes stale (e.g. a suite
//! stops covering the path) shows up immediately as a smoke failure.

use anyhow::{bail, Result};

use super::scanner::{Op, Site};

/// A structural address of one curated mutant plus the reason it dies.
#[derive(Clone, Debug)]
pub struct Pin {
    /// Short stable id used in reports, e.g. `linalg-push-mul`.
    pub id: &'static str,
    /// Repo-relative target file.
    pub file: &'static str,
    pub op: Op,
    /// The pristine text the operator replaces (disambiguates multiple
    /// operators matching one line).
    pub original: &'static str,
    /// Substring the pristine line must contain.
    pub contains: &'static str,
    /// Index within the (op, original, contains)-filtered site list.
    pub occurrence: usize,
    /// Why the fast-tier suites must kill this mutant.
    pub kill_argument: &'static str,
}

/// The curated set.  Keep each entry's kill argument airtight: a pin that
/// *might* survive (e.g. a mutation in code shared by both sides of a
/// differential contract) belongs in the full sweep, not here.
pub fn pinned() -> Vec<Pin> {
    vec![
        Pin {
            id: "linalg-push-mul",
            file: "rust/src/native/linalg.rs",
            op: Op::ArithSwap,
            original: " * ",
            contains: "sum -= row[k] * lj[k];",
            occurrence: 0,
            kill_argument: "breaks cholesky_push only; property_invariants compares the \
                            packed factor against the dense cholesky (independent code) \
                            far beyond 1e-8",
        },
        Pin {
            id: "linalg-givens-plus",
            file: "rust/src/native/linalg.rs",
            op: Op::ArithSwap,
            original: " + ",
            contains: "(l.at(i, k) + s * v[i - idx]) / c;",
            occurrence: 0,
            kill_argument: "corrupts the Givens rotation update; \
                            prop_packed_downdate_matches_scratch_factor_of_reduced_kernel \
                            rebuilds the reduced kernel densely and pins 1e-8",
        },
        Pin {
            id: "linalg-givens-vupdate-del",
            file: "rust/src/native/linalg.rs",
            op: Op::StmtDelete,
            original: "v[i - idx] = c * v[i - idx] - s * lik;",
            contains: "v[i - idx] = c * v[i - idx] - s * lik;",
            occurrence: 0,
            kill_argument: "drops the sweep's carry-column update so every later \
                            rotation uses stale v; same dense cross-check kills it",
        },
        Pin {
            id: "linalg-splice-guard-flip",
            file: "rust/src/native/linalg.rs",
            op: Op::EvictFlip,
            original: "== idx",
            contains: "if c == idx {",
            occurrence: 0,
            kill_argument: "PackedLower::remove keeps ONLY the deleted column; killed \
                            directly by prop_packed_remove_edge_indices and through \
                            every downdate property",
        },
        Pin {
            id: "linalg-dims-pre-move-del",
            file: "rust/src/native/linalg.rs",
            op: Op::StmtDelete,
            original: "self.data.copy_within(start..start + pre, w);",
            contains: "self.data.copy_within(start..start + pre, w);",
            occurrence: 0,
            kill_argument: "PackedDims::remove leaves every row's pre-idx block stale \
                            (the write cursor still advances); killed directly by \
                            prop_packed_dims_remove_edge_indices",
        },
        Pin {
            id: "linalg-remove-row-off-by-one",
            file: "rust/src/native/linalg.rs",
            op: Op::OffByOne,
            original: " + 1",
            contains: "self.data.drain(i * c..(i + 1) * c);",
            occurrence: 0,
            kill_argument: "Mat::remove_row drains two rows (or panics on the last); \
                            killed directly by prop_mat_remove_row_edge_indices",
        },
        Pin {
            id: "kernels-lane-acc-del",
            file: "rust/src/native/kernels.rs",
            op: Op::StmtDelete,
            original: "*pp += lk * xv;",
            contains: "*pp += lk * xv;",
            occurrence: 0,
            kill_argument: "the blocked forward solve drops one lane group's \
                            contribution per panel; gp_kernels' \
                            blocked_solves_match_scalar_directly pins the blocked \
                            solve against the scalar one at 1e-10 on sizes that \
                            exercise full panels",
        },
        Pin {
            id: "kernels-panel-start-off-by-one",
            file: "rust/src/native/kernels.rs",
            op: Op::OffByOne,
            original: " + 1",
            contains: "let mut p0 = i + 1;",
            occurrence: 0,
            kill_argument: "the blocked transpose solve's first panel skips row i+1's \
                            coefficient (or reads past the factor on the last row); \
                            the same 1e-10 direct differential in gp_kernels kills it \
                            at every tested size",
        },
        Pin {
            id: "ops-rbf-sqdist-div",
            file: "rust/src/native/ops.rs",
            op: Op::ArithSwap,
            original: " * ",
            contains: "(x - y) * (x - y)).sum();",
            occurrence: 0,
            kill_argument: "the isotropic RBF diagonal becomes 0/0 = NaN, the one-shot \
                            reference kernel is no longer PD and gp_ei panics inside \
                            gp_incremental's reference path",
        },
        Pin {
            id: "gp-sqdist-dims-div",
            file: "rust/src/native/gp.rs",
            op: Op::ArithSwap,
            original: " * ",
            contains: "*o = d * d;",
            occurrence: 0,
            kill_argument: "the session's per-dimension distance cache degenerates \
                            (d/d) while the one-shot reference keeps true distances; \
                            gp_incremental's bitwise contract breaks on the first \
                            prediction",
        },
        Pin {
            id: "gp-forget-downdate-index",
            file: "rust/src/native/gp.rs",
            op: Op::EvictFlip,
            original: "i",
            contains: "cholesky_downdate(&mut self.l, i);",
            occurrence: 0,
            kill_argument: "Adapt-mode forget downdates the wrong row (or asserts on \
                            the last index); gp_downdate pins downdate-vs-rebuild \
                            predictions to 1e-8 across eviction churn",
        },
        Pin {
            id: "gp-fantasize-counter-del",
            file: "rust/src/native/gp.rs",
            op: Op::StmtDelete,
            original: "self.fantasies += 1;",
            contains: "self.fantasies += 1;",
            occurrence: 0,
            kill_argument: "fantasize no longer opens a fantasy scope, so the paired \
                            pop_fantasy trips its no-open-fantasy ensure; \
                            gp_incremental's fantasize/pop round-trip property and \
                            every batched (q>1) tuner test unwrap that error",
        },
        Pin {
            id: "gp-pop-fantasy-downdate-del",
            file: "rust/src/native/gp.rs",
            op: Op::StmtDelete,
            original: "cholesky_downdate(&mut self.l, last);",
            contains: "cholesky_downdate(&mut self.l, last);",
            occurrence: 0,
            kill_argument: "pop_fantasy shrinks the kernel cache and data rows but \
                            leaves the Cholesky factor one row too long; the next \
                            acquire after retraction diverges (or panics on shape), \
                            killed by gp_incremental's round-trip bitwise pin",
        },
        Pin {
            id: "stats-var-divisor-mul",
            file: "rust/src/util/stats.rs",
            op: Op::ArithSwap,
            original: " / ",
            contains: "(n - 1) as f64",
            occurrence: 0,
            kill_argument: "variance becomes sum * (n-1); \
                            prop_summarize_matches_naive_reference recomputes the \
                            Bessel-corrected variance inline",
        },
        Pin {
            id: "stats-var-bessel-off-by-one",
            file: "rust/src/util/stats.rs",
            op: Op::OffByOne,
            original: " - 1",
            contains: "(n - 1) as f64",
            occurrence: 0,
            kill_argument: "divisor n-2 skews std for every n >= 2; the same naive \
                            reference property kills it",
        },
        Pin {
            id: "stats-argmin-tie-break",
            file: "rust/src/util/stats.rs",
            op: Op::CmpSwap,
            original: " <= ",
            contains: "Some(b) if xs[b] <= *x => {}",
            occurrence: 0,
            kill_argument: "ties now move best to the LAST minimum; \
                            prop_argminmax_match_naive_reference generates discrete \
                            values so ties occur on nearly every seed",
        },
    ]
}

/// Resolve a pin against the scanned sites of its file.  Errors describe
/// pin rot precisely enough to re-point the pin.
pub fn resolve_pin<'a>(pin: &Pin, sites: &'a [Site]) -> Result<&'a Site> {
    let matches: Vec<&Site> = sites
        .iter()
        .filter(|s| {
            s.file == pin.file
                && s.op == pin.op
                && s.original == pin.original
                && s.line_text.contains(pin.contains)
        })
        .collect();
    match matches.get(pin.occurrence) {
        Some(site) => Ok(site),
        None => bail!(
            "pin rot: `{}` matched {} site(s) in {} (need occurrence {}). The pinned line \
             (`{}`, operator {}, original `{}`) was edited or removed — re-point the pin in \
             rust/src/mutate/smoke.rs",
            pin.id,
            matches.len(),
            pin.file,
            pin.occurrence,
            pin.contains,
            pin.op,
            pin.original,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mutate::scanner::scan_source;

    #[test]
    fn pins_have_unique_ids() {
        let pins = pinned();
        let mut ids: Vec<_> = pins.iter().map(|p| p.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), pins.len());
    }

    #[test]
    fn resolve_reports_rot_on_missing_line() {
        let pins = pinned();
        let sites = scan_source("rust/src/native/linalg.rs", "fn nothing_here() {}\n");
        let err = resolve_pin(&pins[0], &sites).unwrap_err().to_string();
        assert!(err.contains("pin rot"), "{err}");
        assert!(err.contains(pins[0].id), "{err}");
    }
}
