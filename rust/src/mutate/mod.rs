//! Self-hosted mutation testing for the numeric kernels (`mutant-hunter`).
//!
//! The repo's correctness story rests on differential contracts — bitwise
//! session-vs-one-shot GP (`tests/gp_incremental.rs`), 1e-8
//! downdate-vs-rebuild (`tests/gp_downdate.rs`), finite-difference ARD
//! gradients (`tests/gp_ard.rs`) and the seeded property sweeps
//! (`tests/property_invariants.rs`).  Those suites exist to make future
//! SIMD/blocked-kernel refactors safe, but a green suite only proves the
//! code *currently* passes it.  This module closes the loop: it plants
//! deliberate faults in the kernels and measures whether the suites notice.
//!
//! Pipeline (all hand-rolled — the crate is dependency-free by design):
//!
//! 1. [`scanner`] — a line-based Rust source scanner (no parser, no new
//!    deps) discovers mutation sites in the six numeric kernel files
//!    ([`TARGET_FILES`]) and applies the operator catalog ([`Op`]):
//!    arithmetic swaps, comparison boundary swaps, range
//!    inclusive/exclusive flips, off-by-one on index arithmetic, constant
//!    perturbation of tolerances/init values, statement deletion targeting
//!    the Givens-sweep and splice loops, and eviction-index flips.
//! 2. [`runner`] — for each mutant, materializes a patched copy of the
//!    crate in a persistent per-worker scratch workspace (own
//!    `CARGO_TARGET_DIR`, so rebuilds are incremental), runs the
//!    per-file-targeted subset of the suites (`cargo test -q --release
//!    --test …` via [`runner::suites_for`]) and classifies the mutant
//!    killed / survived / build-failed / timed-out.  Execution fans out
//!    over a bounded worker pool.
//! 3. [`report`] — emits machine-readable `mutants.json` (per-mutant site,
//!    operator, diff excerpt, verdict, killing test) plus a CLI/markdown
//!    summary with kill rate per file and per operator.
//! 4. [`smoke`] — a pinned, curated mutant set small enough for CI
//!    (`mutant-hunter --smoke`): every pin is a fault the differential
//!    suites must kill, so CI asserts a 100% kill rate on it.  Pins are
//!    addressed by (file, operator, original text, line substring,
//!    occurrence), so they survive unrelated edits and fail loudly —
//!    "pin rot" — when the pinned line itself changes.
//!
//! Scoring: `score = (killed + timed_out) / (killed + timed_out +
//! survived)`.  Build-failed mutants are excluded from the denominator
//! (they prove nothing about the tests); timeouts count as killed (a hung
//! loop is a detected fault) but are reported separately.
//!
//! Survivors from a full sweep must each either get a new killing test or
//! an explicit `equivalent` disposition in `rust/mutants.dispositions.json`
//! (see `MUTANTS.md` for the workflow); the full sweep exits non-zero while
//! any survivor is undispositioned.

pub mod report;
pub mod runner;
pub mod scanner;
pub mod smoke;

pub use runner::{MutantResult, RunConfig, Verdict};
pub use scanner::{scan_source, Op, Site};
pub use smoke::{pinned, resolve_pin, Pin};

use std::path::Path;

use anyhow::{Context, Result};

/// The numeric kernel files under mutation, relative to the repo root.
pub const TARGET_FILES: [&str; 6] = [
    "rust/src/native/linalg.rs",
    "rust/src/native/kernels.rs",
    "rust/src/native/ops.rs",
    "rust/src/native/gp.rs",
    "rust/src/featsel/mod.rs",
    "rust/src/util/stats.rs",
];

/// Scan every target file under `root`, returning sites in deterministic
/// (file, line, col, operator) order.
pub fn scan_targets(root: &Path) -> Result<Vec<Site>> {
    let mut sites = Vec::new();
    for file in TARGET_FILES {
        let path = root.join(file);
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        sites.extend(scan_source(file, &src));
    }
    Ok(sites)
}

/// Locate the repo root (the directory holding `rust/Cargo.toml` and
/// `examples/`) from the current working directory — works from the repo
/// root and from inside `rust/` (where CI invokes the bin).
pub fn find_root() -> Result<std::path::PathBuf> {
    let mut dir = std::env::current_dir().context("getting cwd")?;
    loop {
        if dir.join("rust").join("Cargo.toml").exists() && dir.join("examples").is_dir() {
            return Ok(dir);
        }
        if !dir.pop() {
            anyhow::bail!(
                "not inside the repo: no ancestor directory holds rust/Cargo.toml + examples/"
            );
        }
    }
}
