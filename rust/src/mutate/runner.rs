//! Mutant execution: patched scratch workspaces, targeted `cargo test`
//! runs, and a bounded worker pool.
//!
//! Each worker owns one persistent scratch workspace under
//! `target/mutants/w<i>/` — a copy of the crate (`Cargo.toml` workspace
//! shim + `rust/` + `examples/`) plus its own `CARGO_TARGET_DIR` — so
//! consecutive mutants rebuild incrementally (one changed file, not a
//! cold build).  The workspace source copy is refreshed at the start of
//! every run; the target dir persists across runs.
//!
//! Classification is two-phase per mutant: `cargo test --no-run` first
//! (a mutant that does not compile is **build-failed** and proves nothing
//! about the suites — it is excluded from the score), then each mapped
//! suite in order until one fails (**killed**, recording the killing
//! suite and test) or all pass (**survived**).  A command exceeding the
//! timeout marks the mutant **timed-out**: a hung loop is a detected
//! fault, so timeouts count toward the kill rate but are reported
//! separately.

use std::collections::BTreeMap;
use std::fs;
use std::io::Read;
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::scanner::{apply, Site};

/// One targeted test suite.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Suite {
    /// An integration test under `rust/tests/` (`cargo test --test <name>`).
    Test(&'static str),
    /// The crate's unit tests (`cargo test --lib`).
    Lib,
}

impl Suite {
    pub fn name(self) -> &'static str {
        match self {
            Suite::Test(n) => n,
            Suite::Lib => "lib",
        }
    }

    fn cargo_args(self) -> Vec<&'static str> {
        match self {
            Suite::Test(n) => vec!["--test", n],
            Suite::Lib => vec!["--lib"],
        }
    }
}

/// The file → suites map.  The fast tier is the differential suites that
/// exercise the file *through an independent reference implementation* —
/// that is what the smoke set pins.  The full tier adds the crate unit
/// tests (`--lib`), which also catch mutants in code shared by both sides
/// of a differential contract (e.g. `erf` feeds both the session and the
/// one-shot EI, so a differential compare alone cannot see it drift).
pub fn suites_for(file: &str, full: bool) -> Vec<Suite> {
    let fast: &[Suite] = match file {
        "rust/src/native/linalg.rs" => {
            &[Suite::Test("property_invariants"), Suite::Test("gp_downdate"), Suite::Test("gp_incremental")]
        }
        "rust/src/native/kernels.rs" => {
            &[Suite::Test("gp_kernels"), Suite::Test("gp_incremental")]
        }
        "rust/src/native/ops.rs" => &[Suite::Test("gp_incremental"), Suite::Test("gp_ard")],
        "rust/src/native/gp.rs" => {
            &[Suite::Test("gp_incremental"), Suite::Test("gp_downdate"), Suite::Test("gp_ard")]
        }
        "rust/src/featsel/mod.rs" => &[Suite::Test("pipeline_e2e")],
        "rust/src/util/stats.rs" => &[Suite::Test("property_invariants")],
        _ => &[Suite::Lib],
    };
    let mut suites = fast.to_vec();
    if full {
        suites.push(Suite::Lib);
    }
    suites
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Killed,
    Survived,
    BuildFailed,
    TimedOut,
}

impl Verdict {
    pub fn label(self) -> &'static str {
        match self {
            Verdict::Killed => "killed",
            Verdict::Survived => "survived",
            Verdict::BuildFailed => "build-failed",
            Verdict::TimedOut => "timed-out",
        }
    }
}

#[derive(Clone, Debug)]
pub struct MutantResult {
    pub site: Site,
    pub verdict: Verdict,
    /// Suite that killed the mutant (or timed out on it).
    pub killing_suite: Option<String>,
    /// First failing test parsed from the killing suite's output.
    pub killing_test: Option<String>,
    pub secs: f64,
}

#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Repo root (holds `rust/`, `examples/`, the workspace `Cargo.toml`).
    pub root: PathBuf,
    pub workers: usize,
    /// Per-command timeout (build or one suite run).
    pub timeout_s: u64,
    /// Include the `--lib` tier on top of the differential suites.
    pub full_suites: bool,
}

impl RunConfig {
    pub fn new(root: PathBuf) -> RunConfig {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        RunConfig {
            root,
            // Each worker runs its own parallel cargo build; oversubscribing
            // cores makes every build slower without finishing more mutants.
            workers: (cores / 4).clamp(1, 4),
            timeout_s: 600,
            full_suites: false,
        }
    }
}

/// Run every site and return results in site order.  `None` entries never
/// occur in the returned vec — a worker failure (workspace I/O, cargo
/// missing) aborts the run with the underlying error instead of silently
/// shrinking the result set.
pub fn run_mutants(cfg: &RunConfig, sites: &[Site]) -> Result<Vec<MutantResult>> {
    let pristine = read_pristine(&cfg.root, sites)?;
    let n = sites.len();
    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<MutantResult>>> = Mutex::new(vec![None; n]);
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());

    std::thread::scope(|scope| {
        for w in 0..cfg.workers.max(1) {
            let (next, done, results, errors, pristine) =
                (&next, &done, &results, &errors, &pristine);
            scope.spawn(move || {
                let ws = match setup_workspace(&cfg.root, w) {
                    Ok(ws) => ws,
                    Err(e) => {
                        errors.lock().unwrap().push(format!("worker {w}: {e:#}"));
                        return;
                    }
                };
                loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= n {
                        return;
                    }
                    let site = &sites[i];
                    match run_one(cfg, &ws, site, pristine) {
                        Ok(res) => {
                            let finished = done.fetch_add(1, Ordering::SeqCst) + 1;
                            eprintln!(
                                "[{finished}/{n}] {:<12} {} ({:.0}s){}",
                                res.verdict.label(),
                                site.id(),
                                res.secs,
                                res.killing_suite
                                    .as_deref()
                                    .map(|s| format!(" by {s}"))
                                    .unwrap_or_default(),
                            );
                            results.lock().unwrap()[i] = Some(res);
                        }
                        Err(e) => {
                            errors.lock().unwrap().push(format!("mutant {}: {e:#}", site.id()));
                            return;
                        }
                    }
                }
            });
        }
    });

    let errors = errors.into_inner().unwrap();
    if !errors.is_empty() {
        anyhow::bail!("mutation run aborted:\n  {}", errors.join("\n  "));
    }
    let results = results.into_inner().unwrap();
    Ok(results.into_iter().map(|r| r.expect("no error, so every slot is filled")).collect())
}

/// Pristine content of every file referenced by the sites.
fn read_pristine(root: &Path, sites: &[Site]) -> Result<BTreeMap<String, String>> {
    let mut map = BTreeMap::new();
    for site in sites {
        if !map.contains_key(&site.file) {
            let path = root.join(&site.file);
            let src = fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            map.insert(site.file.clone(), src);
        }
    }
    Ok(map)
}

/// Build (or refresh) worker `w`'s scratch workspace and return its path.
/// Layout: `<root>/target/mutants/w<w>/ws` (fresh copy every run) and
/// `<root>/target/mutants/w<w>/target` (persistent, for incremental
/// rebuilds).
fn setup_workspace(root: &Path, w: usize) -> Result<PathBuf> {
    let base = root.join("target").join("mutants").join(format!("w{w}"));
    let ws = base.join("ws");
    if ws.exists() {
        fs::remove_dir_all(&ws).with_context(|| format!("clearing {}", ws.display()))?;
    }
    fs::create_dir_all(&ws)?;
    fs::create_dir_all(base.join("target"))?;
    fs::copy(root.join("Cargo.toml"), ws.join("Cargo.toml"))
        .context("copying workspace Cargo.toml")?;
    copy_tree(&root.join("rust"), &ws.join("rust"))?;
    copy_tree(&root.join("examples"), &ws.join("examples"))?;
    Ok(ws)
}

/// Recursive copy skipping build products and VCS state.
fn copy_tree(src: &Path, dst: &Path) -> Result<()> {
    fs::create_dir_all(dst)?;
    for entry in fs::read_dir(src).with_context(|| format!("reading {}", src.display()))? {
        let entry = entry?;
        let name = entry.file_name();
        let skip = matches!(
            name.to_str().unwrap_or(""),
            "target" | ".git" | "results" | "__pycache__"
        );
        if skip {
            continue;
        }
        let from = entry.path();
        let to = dst.join(&name);
        if entry.file_type()?.is_dir() {
            copy_tree(&from, &to)?;
        } else {
            fs::copy(&from, &to)
                .with_context(|| format!("copying {} -> {}", from.display(), to.display()))?;
        }
    }
    Ok(())
}

/// Classify one mutant inside worker workspace `ws`.
fn run_one(
    cfg: &RunConfig,
    ws: &Path,
    site: &Site,
    pristine: &BTreeMap<String, String>,
) -> Result<MutantResult> {
    let start = Instant::now();
    let src = &pristine[&site.file];
    let target_file = ws.join(&site.file);
    fs::write(&target_file, apply(src, site))
        .with_context(|| format!("patching {}", target_file.display()))?;

    let suites = suites_for(&site.file, cfg.full_suites);
    let result = classify(cfg, ws, site, &suites);

    // Always restore the pristine file so the workspace is clean for the
    // next mutant, even when classification errored.
    fs::write(&target_file, src)
        .with_context(|| format!("restoring {}", target_file.display()))?;

    let (verdict, killing_suite, killing_test) = result?;
    Ok(MutantResult {
        site: site.clone(),
        verdict,
        killing_suite,
        killing_test,
        secs: start.elapsed().as_secs_f64(),
    })
}

type Classification = (Verdict, Option<String>, Option<String>);

fn classify(cfg: &RunConfig, ws: &Path, site: &Site, suites: &[Suite]) -> Result<Classification> {
    // Phase 1: build everything the suites need.
    let mut build_args = vec!["test", "--release", "-q", "--no-run"];
    for s in suites {
        build_args.extend(s.cargo_args());
    }
    match cargo(cfg, ws, &build_args, format!("{}-build", site.line))? {
        CmdOutcome::TimedOut => return Ok((Verdict::TimedOut, Some("build".into()), None)),
        CmdOutcome::Failed(_) => return Ok((Verdict::BuildFailed, None, None)),
        CmdOutcome::Passed => {}
    }
    // Phase 2: run suites in order; first failure kills.
    for s in suites {
        let mut args = vec!["test", "--release", "-q"];
        args.extend(s.cargo_args());
        match cargo(cfg, ws, &args, format!("{}-{}", site.line, s.name()))? {
            CmdOutcome::TimedOut => {
                return Ok((Verdict::TimedOut, Some(s.name().to_string()), None))
            }
            CmdOutcome::Failed(log) => {
                return Ok((Verdict::Killed, Some(s.name().to_string()), first_failed_test(&log)))
            }
            CmdOutcome::Passed => {}
        }
    }
    Ok((Verdict::Survived, None, None))
}

enum CmdOutcome {
    Passed,
    Failed(String),
    TimedOut,
}

/// Run cargo in `ws/rust` with the worker's own target dir, polling for
/// completion (std has no wait_timeout).  Output goes to a log file so a
/// chatty compile can never deadlock a pipe.
fn cargo(cfg: &RunConfig, ws: &Path, args: &[&str], tag: String) -> Result<CmdOutcome> {
    let log_path = ws.parent().expect("ws has a parent").join(format!("log-{tag}.txt"));
    let log = fs::File::create(&log_path)
        .with_context(|| format!("creating {}", log_path.display()))?;
    let log_err = log.try_clone()?;
    let mut child = Command::new("cargo")
        .args(args)
        .current_dir(ws.join("rust"))
        .env("CARGO_TARGET_DIR", ws.parent().expect("ws has a parent").join("target"))
        .env("CARGO_TERM_COLOR", "never")
        .stdin(Stdio::null())
        .stdout(Stdio::from(log))
        .stderr(Stdio::from(log_err))
        .spawn()
        .context("spawning cargo (is a rust toolchain on PATH?)")?;

    let deadline = Instant::now() + Duration::from_secs(cfg.timeout_s);
    let status = loop {
        if let Some(status) = child.try_wait()? {
            break status;
        }
        if Instant::now() >= deadline {
            child.kill().ok();
            child.wait().ok();
            return Ok(CmdOutcome::TimedOut);
        }
        std::thread::sleep(Duration::from_millis(200));
    };
    if status.success() {
        Ok(CmdOutcome::Passed)
    } else {
        let mut text = String::new();
        if let Ok(mut f) = fs::File::open(&log_path) {
            f.read_to_string(&mut text).ok();
        }
        Ok(CmdOutcome::Failed(text))
    }
}

/// First failing test name from `cargo test` output (the `failures:` list
/// entries are indented bare test paths).
fn first_failed_test(log: &str) -> Option<String> {
    let mut in_failures = false;
    for line in log.lines() {
        if line.trim_end() == "failures:" {
            in_failures = true;
            continue;
        }
        if in_failures {
            let t = line.trim();
            if t.is_empty() {
                continue;
            }
            if line.starts_with("    ")
                && t.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
            {
                return Some(t.to_string());
            }
            in_failures = false;
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_map_covers_every_target_fast_and_full() {
        for file in crate::mutate::TARGET_FILES {
            let fast = suites_for(file, false);
            assert!(!fast.is_empty(), "{file}");
            assert!(
                fast.iter().all(|s| *s != Suite::Lib),
                "fast tier must stay differential-only for {file}"
            );
            let full = suites_for(file, true);
            assert_eq!(full.len(), fast.len() + 1);
            assert_eq!(*full.last().unwrap(), Suite::Lib);
        }
    }

    #[test]
    fn parses_failing_test_name_from_quiet_output() {
        let log = "\nrunning 12 tests\n....F.......\nfailures:\n\n---- prop_x stdout ----\n\
                   thread 'prop_x' panicked at src/x.rs:1:1:\nboom\n\nfailures:\n    prop_x\n\n\
                   test result: FAILED. 11 passed; 1 failed\n";
        assert_eq!(first_failed_test(log).as_deref(), Some("prop_x"));
        assert_eq!(first_failed_test("all good"), None);
    }

    #[test]
    fn verdict_labels_stable() {
        // The JSON schema (and CI's jq assert) depend on these strings.
        assert_eq!(Verdict::Killed.label(), "killed");
        assert_eq!(Verdict::Survived.label(), "survived");
        assert_eq!(Verdict::BuildFailed.label(), "build-failed");
        assert_eq!(Verdict::TimedOut.label(), "timed-out");
    }
}
