//! Line-based mutation-site scanner.
//!
//! Not a Rust parser: the kernels under test are rustfmt'd, numeric,
//! macro-light code, so spaced-token matching on comment/string-masked
//! lines is enough to find every operator site without false positives.
//! The rules that keep it honest:
//!
//! * string literals (plain, raw, multi-line) and comments are masked
//!   (replaced by spaces, so byte offsets survive) before any pattern
//!   runs — the masking lives in [`crate::util::source`], shared with
//!   the `detlint` determinism lint;
//! * lines that are comments, attributes, or `use` items are skipped, as
//!   is anything mentioning `assert`/`ensure!`/`panic!` (mutating an
//!   assertion weakens the *oracle*, not the code under test);
//! * scanning stops at the first `#[cfg(test)]` line — unit tests are
//!   oracles too;
//! * arithmetic/comparison operators only match with a space on both
//!   sides, which rustfmt guarantees for binary operators and which
//!   excludes `+=`, `->`, `=>`, unary `-`, deref `*`, and generics.

use std::fmt;

use crate::util::source::{is_ident_byte, Masker};

/// Mutation operator catalog.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Op {
    /// `+`↔`-`, `*`↔`/` on spaced binary operators.
    ArithSwap,
    /// `<`↔`<=`, `>`↔`>=` boundary swaps.
    CmpSwap,
    /// `..`↔`..=` inclusive/exclusive range flips.
    RangeSwap,
    /// `+ 1`→`+ 2`, `- 1`→`- 2` on index arithmetic.
    OffByOne,
    /// Float literal `X`→`(X * 10.0)` (tolerances, init values).
    ConstPerturb,
    /// Delete a single-line assignment or mutating-call statement
    /// (Givens-sweep updates, splice-loop writes, cache maintenance).
    StmtDelete,
    /// Eviction-index flips: `== idx`→`!= idx`, and `x.remove(i)`-style
    /// final index arguments bumped to `i + 1`.
    EvictFlip,
}

impl Op {
    pub const ALL: [Op; 7] = [
        Op::ArithSwap,
        Op::CmpSwap,
        Op::RangeSwap,
        Op::OffByOne,
        Op::ConstPerturb,
        Op::StmtDelete,
        Op::EvictFlip,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Op::ArithSwap => "arith-swap",
            Op::CmpSwap => "cmp-swap",
            Op::RangeSwap => "range-swap",
            Op::OffByOne => "off-by-one",
            Op::ConstPerturb => "const-perturb",
            Op::StmtDelete => "stmt-delete",
            Op::EvictFlip => "evict-flip",
        }
    }

    pub fn parse(s: &str) -> Option<Op> {
        Op::ALL.iter().copied().find(|o| o.label() == s)
    }
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// One mutation site: a byte range of the pristine source plus the text
/// that replaces it.
#[derive(Clone, Debug, PartialEq)]
pub struct Site {
    /// Repo-relative path, e.g. `rust/src/native/linalg.rs`.
    pub file: String,
    /// 1-based line number in the pristine source.
    pub line: usize,
    /// 1-based byte column of `byte_start` within the line.
    pub col: usize,
    pub byte_start: usize,
    pub byte_end: usize,
    pub op: Op,
    /// The pristine bytes being replaced.
    pub original: String,
    /// The mutated replacement text.
    pub replacement: String,
    /// The trimmed pristine line, for reports and pin matching.
    pub line_text: String,
}

impl Site {
    /// Stable human-readable id: `file:line:col:op`.
    pub fn id(&self) -> String {
        format!("{}:{}:{}:{}", self.file, self.line, self.col, self.op.label())
    }

    /// One-line diff excerpt for reports.
    pub fn diff(&self) -> String {
        format!("`{}` -> `{}` in `{}`", self.original, self.replacement, self.line_text)
    }
}

/// Apply a site to the pristine source it was scanned from.
pub fn apply(src: &str, site: &Site) -> String {
    debug_assert_eq!(&src[site.byte_start..site.byte_end], site.original);
    let mut out = String::with_capacity(src.len() + site.replacement.len());
    out.push_str(&src[..site.byte_start]);
    out.push_str(&site.replacement);
    out.push_str(&src[site.byte_end..]);
    out
}

/// Scan one source file for mutation sites, in (line, col, op) order.
pub fn scan_source(file: &str, src: &str) -> Vec<Site> {
    let mut sites = Vec::new();
    let mut offset = 0usize;
    let mut masker = Masker::new();
    for (idx, line) in src.split_inclusive('\n').enumerate() {
        let body = line.trim_end_matches(['\n', '\r']);
        let trimmed = body.trim_start();
        if trimmed.starts_with("#[cfg(test)]") {
            break; // everything below is test oracle, not code under test
        }
        let masked = masker.mask_line(body);
        if !skip_line(trimmed) {
            let indent = body.len() - trimmed.len();
            let mut line_sites = Vec::new();
            arith_swap(&masked, &mut line_sites);
            cmp_swap(&masked, &mut line_sites);
            range_swap(&masked, &mut line_sites);
            off_by_one(&masked, &mut line_sites);
            const_perturb(&masked, body, &mut line_sites);
            stmt_delete(&masked, indent, &mut line_sites);
            evict_flip(&masked, &mut line_sites);
            for (start, end, op, replacement) in line_sites {
                sites.push(Site {
                    file: file.to_string(),
                    line: idx + 1,
                    col: start + 1,
                    byte_start: offset + start,
                    byte_end: offset + end,
                    op,
                    original: body[start..end].to_string(),
                    replacement,
                    line_text: trimmed.to_string(),
                });
            }
        }
        offset += line.len();
    }
    sites.sort_by(|a, b| {
        (a.line, a.col, a.op, &a.replacement).cmp(&(b.line, b.col, b.op, &b.replacement))
    });
    sites.dedup_by(|a, b| {
        a.byte_start == b.byte_start && a.byte_end == b.byte_end && a.replacement == b.replacement
    });
    sites
}

/// Skip whole lines that are not code under test.
fn skip_line(trimmed: &str) -> bool {
    trimmed.is_empty()
        || trimmed.starts_with("//")
        || trimmed.starts_with('#')
        || trimmed.starts_with("use ")
        || trimmed.contains("assert")
        || trimmed.contains("ensure!")
        || trimmed.contains("panic!")
}

type RawSite = (usize, usize, Op, String);

fn find_all(masked: &str, pat: &str) -> Vec<usize> {
    masked.match_indices(pat).map(|(i, _)| i).collect()
}

fn byte_at(masked: &str, i: usize) -> u8 {
    masked.as_bytes().get(i).copied().unwrap_or(b'\n')
}

/// ` + `↔` - `, ` * `↔` / `.  Spacing excludes `+=`, `-=`, `->`, unary
/// minus, deref `*`, and `//` (already masked as comments anyway).
fn arith_swap(masked: &str, out: &mut Vec<RawSite>) {
    for (pat, to) in [(" + ", " - "), (" - ", " + "), (" * ", " / "), (" / ", " * ")] {
        for i in find_all(masked, pat) {
            out.push((i, i + pat.len(), Op::ArithSwap, to.to_string()));
        }
    }
}

/// ` < `↔` <= `, ` > `↔` >= `.  ` < ` cannot match inside ` <= ` (the byte
/// after `<` is `=`), and ` > ` cannot match inside ` => ` or ` >= `.
fn cmp_swap(masked: &str, out: &mut Vec<RawSite>) {
    for (pat, to) in [(" < ", " <= "), (" <= ", " < "), (" > ", " >= "), (" >= ", " > ")] {
        for i in find_all(masked, pat) {
            out.push((i, i + pat.len(), Op::CmpSwap, to.to_string()));
        }
    }
}

/// `..=`→`..` and `..`→`..=`.  A bare `..` followed by a space or `}` is a
/// rest pattern (`Adapt { .. }`), not a range — skipped.
fn range_swap(masked: &str, out: &mut Vec<RawSite>) {
    for i in find_all(masked, "..") {
        if i > 0 && byte_at(masked, i - 1) == b'.' {
            continue; // second half of a previous match
        }
        let next = byte_at(masked, i + 2);
        if next == b'=' {
            out.push((i, i + 3, Op::RangeSwap, "..".to_string()));
        } else if next != b'.' && next != b' ' && next != b'}' {
            out.push((i, i + 2, Op::RangeSwap, "..=".to_string()));
        }
    }
}

/// ` + 1`→` + 2` and ` - 1`→` - 2` where the `1` is a standalone integer
/// (index arithmetic), not part of a larger number or float.  `+ 1..` is
/// allowed (range starts like `idx + 1..n` are prime off-by-one sites);
/// `+ 1.0` is not (that's a float, const-perturb territory).
fn off_by_one(masked: &str, out: &mut Vec<RawSite>) {
    for (pat, to) in [(" + 1", " + 2"), (" - 1", " - 2")] {
        for i in find_all(masked, pat) {
            let after = byte_at(masked, i + pat.len());
            let ok = match after {
                b')' | b']' | b'}' | b';' | b',' | b' ' | b'\n' => true,
                b'.' => byte_at(masked, i + pat.len() + 1) == b'.', // range, not float
                _ => false,
            };
            if ok {
                out.push((i, i + pat.len(), Op::OffByOne, to.to_string()));
            }
        }
    }
}

/// Float literals `X` → `(X * 10.0)`.  Integer literals are left alone
/// (they are sizes and indices, covered by off-by-one); zero is left alone
/// (scaling it is a no-op, i.e. an equivalent mutant by construction).
fn const_perturb(masked: &str, body: &str, out: &mut Vec<RawSite>) {
    let b = masked.as_bytes();
    let mut i = 0;
    while i < b.len() {
        if !b[i].is_ascii_digit() || (i > 0 && (is_ident_byte(b[i - 1]) || b[i - 1] == b'.')) {
            i += 1;
            continue;
        }
        let start = i;
        let mut j = i;
        let mut has_dot = false;
        let mut has_exp = false;
        while j < b.len() && b[j].is_ascii_digit() {
            j += 1;
        }
        // fractional part — but not `..` (range) and not a method call `1.max(…)`
        if j + 1 < b.len() && b[j] == b'.' && b[j + 1].is_ascii_digit() {
            has_dot = true;
            j += 1;
            while j < b.len() && b[j].is_ascii_digit() {
                j += 1;
            }
        }
        // exponent part: `e`/`E`, optional sign, digits
        if j < b.len() && (b[j] == b'e' || b[j] == b'E') {
            let mut k = j + 1;
            if k < b.len() && (b[k] == b'+' || b[k] == b'-') {
                k += 1;
            }
            if k < b.len() && b[k].is_ascii_digit() {
                has_exp = true;
                j = k;
                while j < b.len() && b[j].is_ascii_digit() {
                    j += 1;
                }
            }
        }
        // `1.0f64`-style suffixes would end the token here; targets don't
        // use them, and an `_` or letter after the literal means it's part
        // of an identifier-ish token we don't understand — skip those.
        if (has_dot || has_exp) && !(j < b.len() && is_ident_byte(b[j])) {
            let lit = &body[start..j];
            if lit.parse::<f64>().map(|v| v != 0.0).unwrap_or(false) {
                out.push((start, j, Op::ConstPerturb, format!("({lit} * 10.0)")));
            }
        }
        i = j.max(i + 1);
    }
}

/// Mutating method calls whose whole-statement deletion is a meaningful
/// fault (splice loops, factor maintenance, cache upkeep).
const MUTATING_CALLS: [&str; 11] = [
    ".push(",
    ".push_row(",
    ".truncate(",
    ".extend_from_slice(",
    ".copy_within(",
    ".clear(",
    ".remove(",
    ".remove_row(",
    ".drain(",
    ".swap_remove(",
    "cholesky_downdate(",
];

const STMT_DELETE_EXCLUDED_STARTS: [&str; 14] = [
    "let ", "use ", "return", "break", "continue", "fn ", "pub ", "const ", "static ", "type ",
    "impl ", "mod ", "else", "loop",
];

/// Delete one complete single-line statement: an assignment (`x = …;`,
/// `x += …;`, …) or a mutating method call.  Restricted to lines that are
/// a whole statement (balanced brackets, trailing `;`, no braces) and not
/// a binding (`let` deletion would break later uses at compile time —
/// a build-failed mutant proves nothing).
fn stmt_delete(masked: &str, indent: usize, out: &mut Vec<RawSite>) {
    let t = masked.trim_end();
    let stmt = &t[indent.min(t.len())..];
    if !stmt.ends_with(';') {
        return;
    }
    let first = stmt.as_bytes().first().copied().unwrap_or(b' ');
    if !(first.is_ascii_alphabetic() || first == b'_' || first == b'*') {
        return;
    }
    if STMT_DELETE_EXCLUDED_STARTS.iter().any(|p| stmt.starts_with(p)) {
        return;
    }
    if stmt.contains('{') || stmt.contains('}') {
        return;
    }
    for (open, close) in [('(', ')'), ('[', ']')] {
        let mut depth = 0i32;
        for c in stmt.chars() {
            if c == open {
                depth += 1;
            } else if c == close {
                depth -= 1;
                if depth < 0 {
                    return; // fragment of a multi-line expression
                }
            }
        }
        if depth != 0 {
            return;
        }
    }
    let is_assign = [" = ", " += ", " -= ", " *= ", " /= "].iter().any(|p| stmt.contains(p));
    let is_call = MUTATING_CALLS.iter().any(|p| stmt.contains(p));
    if is_assign || is_call {
        out.push((indent, indent + stmt.len(), Op::StmtDelete, String::new()));
    }
}

/// Eviction-index flips: `== idx`→`!= idx` guards inside splice loops,
/// and `x.remove(i)`-style calls whose final argument is a bare index
/// identifier, bumped to `i + 1`.
fn evict_flip(masked: &str, out: &mut Vec<RawSite>) {
    for i in find_all(masked, "== idx") {
        if !is_ident_byte(byte_at(masked, i + 6)) {
            out.push((i, i + 6, Op::EvictFlip, "!= idx".to_string()));
        }
    }
    for pat in [".remove(", ".remove_row(", ".swap_remove(", "cholesky_downdate("] {
        for i in find_all(masked, pat) {
            if !pat.starts_with('.')
                && i > 0
                && (is_ident_byte(byte_at(masked, i - 1)) || byte_at(masked, i - 1) == b'.')
            {
                continue; // substring of a longer identifier or a method path
            }
            let args_start = i + pat.len();
            let Some(rel_close) = masked[args_start..].find(')') else { continue };
            let args = &masked[args_start..args_start + rel_close];
            if args.contains('(') {
                continue; // nested call — too clever for a line matcher
            }
            let last = args.rsplit(',').next().unwrap_or(args).trim();
            if !last.is_empty()
                && last.bytes().all(is_ident_byte)
                && !last.bytes().next().unwrap().is_ascii_digit()
                && last != "self"
            {
                let last_start = args_start + args.rfind(last).unwrap_or(0);
                out.push((
                    last_start,
                    last_start + last.len(),
                    Op::EvictFlip,
                    format!("{last} + 1"),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arith_swap_respects_spacing() {
        let s = scan_source("f.rs", "fn f() {\n    let a = b + c;\n    w += 1;\n}\n");
        let arith: Vec<_> = s.iter().filter(|x| x.op == Op::ArithSwap).collect();
        assert_eq!(arith.len(), 1, "{s:?}");
        assert_eq!(arith[0].original, " + ");
        assert_eq!(arith[0].replacement, " - ");
        assert_eq!(arith[0].line, 2);
    }

    #[test]
    fn cmp_swap_handles_boundaries_not_arrows() {
        let s = scan_source("f.rs", "    if a < b && c <= d => {}\n");
        let cmp: Vec<_> =
            s.iter().filter(|x| x.op == Op::CmpSwap).map(|x| x.original.clone()).collect();
        assert_eq!(cmp, vec![" < ".to_string(), " <= ".to_string()]);
    }

    #[test]
    fn range_swap_skips_rest_patterns() {
        let s = scan_source("f.rs", "    for i in 0..n {}\n    for j in 0..=m {}\n    Adapt { .. } => {}\n");
        let rs: Vec<_> = s
            .iter()
            .filter(|x| x.op == Op::RangeSwap)
            .map(|x| (x.original.clone(), x.replacement.clone()))
            .collect();
        assert_eq!(
            rs,
            vec![("..".to_string(), "..=".to_string()), ("..=".to_string(), "..".to_string())]
        );
    }

    #[test]
    fn off_by_one_skips_floats_allows_ranges() {
        let s = scan_source(
            "f.rs",
            "    a(i * c..(i + 1) * c);\n    let x = y + 1.5;\n    for r in idx + 1..n {}\n",
        );
        let ob: Vec<_> = s.iter().filter(|x| x.op == Op::OffByOne).collect();
        assert_eq!(ob.len(), 2, "{ob:?}");
        assert!(ob.iter().all(|x| x.replacement == " + 2"));
        assert_eq!(ob[0].line, 1);
        assert_eq!(ob[1].line, 3);
    }

    #[test]
    fn const_perturb_floats_only_nonzero_only() {
        let s = scan_source("f.rs", "    if sigma <= 1e-9 { t(0.0, 2.5, 3, x[1]); }\n");
        let cp: Vec<_> = s.iter().filter(|x| x.op == Op::ConstPerturb).collect();
        let origs: Vec<_> = cp.iter().map(|x| x.original.clone()).collect();
        assert_eq!(origs, vec!["1e-9".to_string(), "2.5".to_string()]);
        assert_eq!(cp[0].replacement, "(1e-9 * 10.0)");
    }

    #[test]
    fn stmt_delete_targets_assignments_and_mutators_only() {
        let src = "    w += 1;\n    let q = 3;\n    self.data.truncate(w);\n    x.frob();\n        .sum();\n";
        let s = scan_source("f.rs", src);
        let sd: Vec<_> = s.iter().filter(|x| x.op == Op::StmtDelete).collect();
        assert_eq!(sd.len(), 2, "{sd:?}");
        assert_eq!(sd[0].original, "w += 1;");
        assert_eq!(sd[1].original, "self.data.truncate(w);");
        assert!(sd.iter().all(|x| x.replacement.is_empty()));
    }

    #[test]
    fn evict_flip_guard_and_index_bump() {
        let src = "    if c == idx {\n    self.k.remove(i);\n    cholesky_downdate(&mut self.l, i);\n    v.drain(a..b);\n";
        let s = scan_source("f.rs", src);
        let ef: Vec<_> = s.iter().filter(|x| x.op == Op::EvictFlip).collect();
        let pairs: Vec<_> =
            ef.iter().map(|x| (x.original.clone(), x.replacement.clone())).collect();
        assert_eq!(
            pairs,
            vec![
                ("== idx".to_string(), "!= idx".to_string()),
                ("i".to_string(), "i + 1".to_string()),
                ("i".to_string(), "i + 1".to_string()),
            ]
        );
    }

    #[test]
    fn skips_comments_attributes_asserts_and_test_module() {
        let src = "\
fn f(n: usize) {
    // a + b in a comment
    /// doc + doc
    #[inline]
    assert!(a + b < 3);
    debug_assert!(j <= i && i < n);
    let s = \"x + y\";
}
#[cfg(test)]
mod tests {
    fn g() { let z = a + b; }
}
";
        let s = scan_source("f.rs", src);
        assert!(s.is_empty(), "{s:?}");
    }

    #[test]
    fn apply_roundtrip_preserves_everything_else() {
        let src = "fn f() {\n    let a = b + c;\n}\n";
        let s = scan_source("f.rs", src);
        let site = s.iter().find(|x| x.op == Op::ArithSwap).unwrap();
        let patched = apply(src, site);
        assert_eq!(patched, "fn f() {\n    let a = b - c;\n}\n");
    }

    #[test]
    fn sites_are_sorted_and_deduped() {
        let src = "    let a = b + c;\n    let d = e * f;\n";
        let s = scan_source("f.rs", src);
        let mut sorted = s.clone();
        sorted.sort_by(|a, b| {
            (a.line, a.col, a.op, &a.replacement).cmp(&(b.line, b.col, b.op, &b.replacement))
        });
        assert_eq!(s, sorted);
        for w in s.windows(2) {
            assert!(
                !(w[0].byte_start == w[1].byte_start
                    && w[0].byte_end == w[1].byte_end
                    && w[0].replacement == w[1].replacement),
                "dup {w:?}"
            );
        }
    }

    #[test]
    fn mask_preserves_offsets() {
        let line = r#"    foo("a + b", x + y); // c + d"#;
        let m = Masker::new().mask_line(line);
        assert_eq!(m.len(), line.len());
        assert!(!m.contains("a + b"));
        assert!(!m.contains("c + d"));
        assert_eq!(&m[..4], "    ");
        let i = m.find(" + ").unwrap();
        assert_eq!(&line[i..i + 3], " + ");
        assert_eq!(&line[i - 1..i + 5], "x + y)");
    }

    #[test]
    fn scan_skips_sites_inside_multiline_raw_strings() {
        let src = "fn f() {\n    let s = r#\"a + b\n c + d\"#;\n    let x = y + z;\n}\n";
        let s = scan_source("f.rs", src);
        let arith: Vec<_> = s.iter().filter(|x| x.op == Op::ArithSwap).collect();
        assert_eq!(arith.len(), 1, "{s:?}");
        assert_eq!(arith[0].line, 4);
    }
}
