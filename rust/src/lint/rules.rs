//! Rule detectors and allow-annotation resolution.
//!
//! Everything here works on *masked* lines (string/char-literal
//! contents and comments blanked by [`crate::util::source::Masker`]),
//! so a banned token inside a string or comment never fires.  The
//! annotation syntax itself is parsed from the raw line, since it lives
//! in a comment by design.
//!
//! Heuristics, stated honestly:
//!
//! * `hash-iter` tracks identifiers declared with a `HashMap`/`HashSet`
//!   type in the same file (let-bindings, struct fields, fn params,
//!   statics) and flags lines where a tracked name is followed by an
//!   iteration token (`.iter()`, `.keys()`, `.values()`, `.retain(`,
//!   `.drain(`, …) or appears as a `for … in` source.  Cross-file
//!   tracking is out of scope — a map handed across a module boundary
//!   is invisible, which is why the real fix (BTreeMap at the
//!   declaration) is always preferred over an allow.
//! * `lock-across-io` tracks `.lock()` guards: a `let g = ….lock()
//!   .unwrap();` binding stays live until its block dedents (or
//!   `drop(`), a temporary in a larger expression until its statement's
//!   `;`.  Any line containing a blocking token while a guard is live
//!   is flagged.  Opaque calls (a closure invoked under a lock) are
//!   beyond a line scanner — reviews still own those.

use crate::util::source::{is_ident_byte, Masker};

use super::{AllowedFinding, FileScan, Finding, Problem, Rule, StaleAllow};

/// Iteration tokens for `hash-iter` (order-bearing accessors only;
/// `get`/`contains_key`/`insert`/`entry` are point ops and stay legal).
const ITER_TOKENS: [&str; 8] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".into_iter()",
    ".retain(",
    ".drain(",
];

/// Wall-clock constructors for `wall-clock`.
const CLOCK_TOKENS: [&str; 3] = ["Instant::now", "SystemTime", "UNIX_EPOCH"];

/// Ambient-entropy constructors for `ambient-rng`.
const ENTROPY_TOKENS: [&str; 7] = [
    "thread_rng",
    "from_entropy",
    "RandomState",
    "DefaultHasher",
    "getrandom",
    "OsRng",
    "rand::",
];

/// Raw-parallelism constructors for `thread-outside-exec`.
const THREAD_TOKENS: [&str; 3] = ["thread::spawn", "thread::scope", "thread::Builder"];

/// `unordered-float-reduce`: a reduction chained onto a fan-out …
const PAR_TOKENS: [&str; 3] = ["par_run(", "par_map(", "par_chunks("];
const REDUCE_TOKENS: [&str; 5] = [".sum()", ".sum::<", ".product()", ".product::<", ".fold("];
/// … or a shared mutable float accumulator.
const SHARED_ACC_TOKENS: [&str; 4] = ["Mutex<f64", "Mutex<f32", "RwLock<f64", "RwLock<f32"];

/// Blocking calls for `lock-across-io`.  `persist::save` is the repo's
/// own state-file writer — known blocking, listed by name.
const BLOCKING_TOKENS: [&str; 15] = [
    "std::fs::",
    "fs::write",
    "fs::read",
    "fs::create_dir",
    "fs::rename",
    "fs::remove",
    "File::",
    ".write_all(",
    ".read_to_string(",
    ".read_to_end(",
    ".sync_all(",
    "TcpStream::connect",
    "thread::sleep",
    "Command::new",
    "persist::save(",
];

const ANNOTATION: &str = "// detlint:";

struct LineInfo {
    /// 1-based line number.
    num: usize,
    raw: String,
    masked: String,
    /// Raw line, whitespace-trimmed (excerpts, structure checks).
    trimmed: String,
    /// Leading-whitespace byte count.
    indent: usize,
}

struct AllowAnn {
    /// Line the annotation sits on.
    line: usize,
    /// Line the annotation suppresses.
    target: usize,
    rule: Rule,
    reason: String,
    used: bool,
}

/// Scan one file's source.  `file` is the repo-relative path (forward
/// slashes) — it drives the per-rule path scopes.
pub fn scan_source(file: &str, src: &str) -> FileScan {
    let mut scan = FileScan::default();
    let lines = prepare_lines(src);

    let mut allows = collect_allows(file, &lines, &mut scan.problems);

    let mut raw: Vec<(usize, Rule, String)> = Vec::new();
    hash_iter(&lines, &mut raw);
    token_rule(&lines, Rule::WallClock, &CLOCK_TOKENS, &mut raw);
    token_rule(&lines, Rule::AmbientRng, &ENTROPY_TOKENS, &mut raw);
    token_rule(&lines, Rule::ThreadOutsideExec, &THREAD_TOKENS, &mut raw);
    float_reduce(&lines, &mut raw);
    lock_across_io(&lines, &mut raw);

    raw.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    raw.dedup_by(|a, b| a.0 == b.0 && a.1 == b.1);

    for (line, rule, excerpt) in raw {
        if !rule.applies_to(file) {
            continue;
        }
        match allows.iter_mut().find(|a| a.target == line && a.rule == rule && !a.used) {
            Some(a) => {
                a.used = true;
                scan.allows.push(AllowedFinding {
                    file: file.to_string(),
                    line,
                    rule,
                    reason: a.reason.clone(),
                    excerpt,
                });
            }
            None => scan.findings.push(Finding {
                file: file.to_string(),
                line,
                rule,
                excerpt,
            }),
        }
    }

    for a in allows.into_iter().filter(|a| !a.used) {
        scan.stale_allows.push(StaleAllow {
            file: file.to_string(),
            line: a.line,
            rule: a.rule,
            reason: a.reason,
        });
    }
    scan
}

/// Mask the code region of the file: everything up to (not including)
/// the first top-level `#[cfg(test)]`.
fn prepare_lines(src: &str) -> Vec<LineInfo> {
    let mut out = Vec::new();
    let mut masker = Masker::new();
    for (idx, line) in src.lines().enumerate() {
        let trimmed = line.trim();
        if trimmed.starts_with("#[cfg(test)]") {
            break; // tests are oracles, not result paths
        }
        let masked = masker.mask_line(line);
        out.push(LineInfo {
            num: idx + 1,
            raw: line.to_string(),
            masked,
            trimmed: trimmed.to_string(),
            indent: line.len() - line.trim_start().len(),
        });
    }
    out
}

fn excerpt_of(li: &LineInfo) -> String {
    let mut e = li.trimmed.clone();
    if e.len() > 120 {
        let mut cut = 117;
        while !e.is_char_boundary(cut) {
            cut -= 1;
        }
        e.truncate(cut);
        e.push_str("...");
    }
    e
}

/// The byte offset of this line's real `//` comment start, if the
/// comment is a detlint annotation.  "Real" means the masked line is
/// blank from the `//` to end-of-line — that rejects `// detlint:`
/// inside string literals (the closing delimiter stays visible after
/// it) — and the comment text must *begin* with the annotation marker,
/// which rejects doc comments and prose that merely mention the syntax.
fn annotation_start(li: &LineInfo) -> Option<usize> {
    let mut from = 0;
    while let Some(rel) = li.raw[from..].find("//") {
        let pos = from + rel;
        if li.masked[pos..].trim().is_empty() {
            return li.raw[pos..].starts_with(ANNOTATION).then_some(pos);
        }
        from = pos + 1;
    }
    None
}

/// Parse every `// detlint: allow(rule) -- reason` annotation and
/// resolve which line each one suppresses: a trailing annotation
/// suppresses its own line; a standalone annotation line suppresses the
/// next non-annotation line.
fn collect_allows(file: &str, lines: &[LineInfo], problems: &mut Vec<Problem>) -> Vec<AllowAnn> {
    let mut out = Vec::new();
    for (i, li) in lines.iter().enumerate() {
        let Some(pos) = annotation_start(li) else { continue };
        let ann = li.raw[pos..].trim();
        match parse_allow(ann) {
            Err(msg) => problems.push(Problem {
                file: file.to_string(),
                line: li.num,
                message: msg,
            }),
            Ok((rule, reason)) => {
                let standalone = li.raw[..pos].trim().is_empty();
                let target = if standalone {
                    // skip over further standalone annotation lines
                    let mut j = i + 1;
                    while j < lines.len() {
                        let l = &lines[j];
                        let is_ann = l.raw.trim_start().starts_with(ANNOTATION);
                        if !is_ann {
                            break;
                        }
                        j += 1;
                    }
                    lines.get(j).map_or(li.num, |l| l.num)
                } else {
                    li.num
                };
                out.push(AllowAnn { line: li.num, target, rule, reason, used: false });
            }
        }
    }
    out
}

/// Parse one annotation comment, starting at `// detlint:`.
fn parse_allow(ann: &str) -> Result<(Rule, String), String> {
    let body = ann[ANNOTATION.len()..].trim_start();
    let Some(rest) = body.strip_prefix("allow(") else {
        return Err(format!(
            "malformed detlint annotation (expected `// detlint: allow(<rule>) -- <reason>`): `{ann}`"
        ));
    };
    let Some(close) = rest.find(')') else {
        return Err(format!("unclosed allow(…) in detlint annotation: `{ann}`"));
    };
    let id = rest[..close].trim();
    let Some(rule) = Rule::parse(id) else {
        let known: Vec<&str> = Rule::ALL.iter().map(|r| r.id()).collect();
        return Err(format!(
            "unknown detlint rule `{id}` (known: {})",
            known.join(", ")
        ));
    };
    let tail = rest[close + 1..].trim_start();
    let Some(reason) = tail.strip_prefix("--") else {
        return Err(format!(
            "detlint allow({id}) is missing its mandatory `-- <reason>`"
        ));
    };
    let reason = reason.trim();
    if reason.is_empty() {
        return Err(format!(
            "detlint allow({id}) has an empty reason — say why the site is legitimate"
        ));
    }
    Ok((rule, reason.to_string()))
}

fn is_word_at(masked: &str, pos: usize, len: usize) -> bool {
    let b = masked.as_bytes();
    let before_ok = pos == 0 || !is_ident_byte(b[pos - 1]);
    let after_ok = pos + len >= b.len() || !is_ident_byte(b[pos + len]);
    before_ok && after_ok
}

fn find_word(masked: &str, word: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(rel) = masked[from..].find(word) {
        let pos = from + rel;
        if is_word_at(masked, pos, word.len()) {
            return Some(pos);
        }
        from = pos + 1;
    }
    None
}

/// True if any whole-word occurrence of `word` is immediately followed
/// by one of `suffixes` (adjacent, so `map.get(k).map(|v| v.iter())`
/// does not blame `map` for the Vec's iteration).
fn word_followed_by(masked: &str, word: &str, suffixes: &[&str]) -> bool {
    let mut from = 0;
    while let Some(rel) = masked[from..].find(word) {
        let pos = from + rel;
        from = pos + 1;
        if !is_word_at(masked, pos, word.len()) {
            continue;
        }
        let rest = &masked[pos + word.len()..];
        if suffixes.iter().any(|s| rest.starts_with(s)) {
            return true;
        }
    }
    false
}

/// Generic token rule: flag any code line containing one of `tokens`.
/// `use` items are declarations, not calls — skipped.
fn token_rule(lines: &[LineInfo], rule: Rule, tokens: &[&str], out: &mut Vec<(usize, Rule, String)>) {
    for li in lines {
        if li.trimmed.starts_with("use ") {
            continue;
        }
        if tokens.iter().any(|t| li.masked.contains(t)) {
            out.push((li.num, rule, excerpt_of(li)));
        }
    }
}

/// R1 — see module docs for the tracking heuristic.
fn hash_iter(lines: &[LineInfo], out: &mut Vec<(usize, Rule, String)>) {
    // pass 1: collect hash-typed binding names declared in this file
    let mut names: Vec<String> = Vec::new();
    for li in lines {
        if li.trimmed.starts_with("use ") {
            continue;
        }
        for tok in ["HashMap<", "HashSet<", "HashMap::new", "HashSet::new"] {
            let mut from = 0;
            while let Some(rel) = li.masked[from..].find(tok) {
                let pos = from + rel;
                from = pos + tok.len();
                if pos > 0 && is_ident_byte(li.masked.as_bytes()[pos - 1]) {
                    continue; // tail of a longer identifier
                }
                // a return type (`-> HashMap<…>`) binds nothing here
                if li.masked[..pos].trim_end().ends_with("->") {
                    continue;
                }
                if let Some(name) = binding_name(li, pos) {
                    if !names.contains(&name) {
                        names.push(name);
                    }
                }
            }
        }
    }
    if names.is_empty() {
        return;
    }
    // pass 2: flag iteration over a tracked name
    for li in lines {
        if li.trimmed.starts_with("use ") {
            continue;
        }
        let hit = names.iter().any(|name| {
            if word_followed_by(&li.masked, name, &ITER_TOKENS) {
                return true;
            }
            // `for k in tracked { … }` — iteration without a method call
            if let (Some(fpos), Some(ipos)) = (li.masked.find("for "), li.masked.find(" in ")) {
                if ipos > fpos {
                    let after_in = &li.masked[ipos + 4..];
                    return find_word(after_in, name).is_some();
                }
            }
            false
        });
        if hit {
            out.push((li.num, Rule::HashIter, excerpt_of(li)));
        }
    }
}

/// The identifier a hash-typed declaration at `pos` binds: the ident
/// after `let [mut]`, or the ident before the `name: Type` colon
/// (struct fields, fn params, statics).
fn binding_name(li: &LineInfo, pos: usize) -> Option<String> {
    if let Some(rest) = li.masked.trim_start().strip_prefix("let ") {
        let rest = rest.trim_start().trim_start_matches("mut ").trim_start();
        let end = rest.bytes().position(|b| !is_ident_byte(b)).unwrap_or(rest.len());
        return (end > 0).then(|| rest[..end].to_string());
    }
    // last single `:` (not `::`) before the type token
    let head = li.masked[..pos].as_bytes();
    let mut k = head.len();
    let mut colon = None;
    while k > 0 {
        k -= 1;
        if head[k] == b':' {
            let pair_left = k > 0 && head[k - 1] == b':';
            let pair_right = k + 1 < head.len() && head[k + 1] == b':';
            if !pair_left && !pair_right {
                colon = Some(k);
                break;
            }
            if pair_left {
                k -= 1; // skip the `::` pair wholesale
            }
        }
    }
    let colon = colon?;
    let ident_zone = li.masked[..colon].trim_end();
    let start = ident_zone
        .bytes()
        .rposition(|b| !is_ident_byte(b))
        .map_or(0, |p| p + 1);
    let name = &ident_zone[start..];
    (!name.is_empty() && !name.bytes().next().unwrap().is_ascii_digit())
        .then(|| name.to_string())
}

/// R5 — a float reduction chained onto a fan-out on one line, or a
/// shared float accumulator type anywhere.
fn float_reduce(lines: &[LineInfo], out: &mut Vec<(usize, Rule, String)>) {
    for li in lines {
        if li.trimmed.starts_with("use ") {
            continue;
        }
        let chained = PAR_TOKENS.iter().any(|t| li.masked.contains(t))
            && REDUCE_TOKENS.iter().any(|t| li.masked.contains(t));
        let shared = SHARED_ACC_TOKENS.iter().any(|t| li.masked.contains(t));
        if chained || shared {
            out.push((li.num, Rule::UnorderedFloatReduce, excerpt_of(li)));
        }
    }
}

/// R6 — guard-lifetime tracking, see module docs.
fn lock_across_io(lines: &[LineInfo], out: &mut Vec<(usize, Rule, String)>) {
    #[derive(PartialEq)]
    enum Kind {
        /// `let g = ….lock().unwrap();` — lives until its block dedents.
        Bound,
        /// lock temporary inside a larger expression — lives until the
        /// statement's terminating `;`.
        Temp,
    }
    struct Guard {
        indent: usize,
        kind: Kind,
    }
    let mut guards: Vec<Guard> = Vec::new();
    for li in lines {
        if li.trimmed.is_empty() {
            continue;
        }
        let code = li.masked.trim();
        // scope pops first: fn boundaries clear everything, a dedenting
        // `}` closes the blocks that own deeper guards
        if li.trimmed.starts_with("fn ") || li.trimmed.starts_with("pub fn ") {
            guards.clear();
        }
        if code.starts_with('}') {
            guards.retain(|g| match g.kind {
                Kind::Bound => g.indent <= li.indent,
                Kind::Temp => g.indent < li.indent,
            });
        }
        if code.starts_with("drop(") {
            guards.pop();
        }
        if li.masked.contains(".lock()") {
            let kind = if li.trimmed.starts_with("let ") && code.ends_with(".lock().unwrap();") {
                Kind::Bound
            } else {
                Kind::Temp
            };
            guards.push(Guard { indent: li.indent, kind });
        }
        if !guards.is_empty()
            && !li.trimmed.starts_with("use ")
            && BLOCKING_TOKENS.iter().any(|t| li.masked.contains(t))
        {
            out.push((li.num, Rule::LockAcrossIo, excerpt_of(li)));
        }
        // a statement's `;` at-or-left-of a temp guard's indent ends it
        if code.ends_with(';') {
            guards.retain(|g| g.kind != Kind::Temp || li.indent > g.indent);
        }
    }
}
